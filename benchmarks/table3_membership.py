"""Table 3: proof of (non-)membership -- tree construction time, proof
size (# hash values released) and verification time across hash functions,
query sizes, and positivity ratios (CIFAR-10-scale training set)."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import merkle

N_DATA = 50_000          # CIFAR-10 training-set size


def make_commitments(n: int, seed: int = 0) -> List[bytes]:
    rng = np.random.default_rng(seed)
    return [rng.bytes(32) for _ in range(n)]


def main(hashes: List[str] | None = None,
         query_sizes: List[int] | None = None,
         ratios: List[float] | None = None,
         n_data: int = N_DATA):
    hashes = hashes or ["md5", "sha1", "sha256"]
    query_sizes = query_sizes or [10, 100, 1000]
    ratios = ratios or [0.0, 0.1, 0.5, 0.9, 1.0]
    data = make_commitments(n_data)
    outside = make_commitments(max(query_sizes), seed=10**6)
    rows = []
    for h in hashes:
        t0 = time.perf_counter()
        tree = merkle.MerkleTree(data, h)
        t_tree = time.perf_counter() - t0
        for nq in query_sizes:
            for ratio in ratios:
                n_pos = int(round(nq * ratio))
                queried = data[:n_pos] + outside[:nq - n_pos]
                t0 = time.perf_counter()
                proof = tree.prove_membership(queried)
                t_prove = time.perf_counter() - t0
                t0 = time.perf_counter()
                ok = merkle.verify_membership(queried, tree.root, proof, h)
                t_verify = (time.perf_counter() - t0) * 1e3
                assert ok
                rows.append((h, nq, ratio, t_tree, proof.size_nodes(),
                             t_verify))
                print(f"table3,hash={h},n_query={nq},ratio={ratio},"
                      f"t_tree_s={t_tree:.1f},size_nodes={proof.size_nodes()},"
                      f"t_verify_ms={t_verify:.2f},"
                      f"t_prove_ms={t_prove*1e3:.2f}", flush=True)
    return rows


if __name__ == "__main__":
    main()
