"""Table 3: proof of (non-)membership -- binding construction time,
audit size (# hash values released) and verification time across hash
functions, query sizes, and positivity ratios.

Runs on the `repro.audit` membership API: synthetic u64 sample
commitments (the proof format's scalar encoding) are bound into a
`DatasetBinding`, each cell round-trips a serialized `MembershipAudit`
through `verify_membership`, and every verdict's per-query answers are
checked against ground truth — the benchmark measures the REAL
audit path, not the bare Merkle layer.

    PYTHONPATH=src python benchmarks/table3_membership.py \
        [--n-data 10000] [--bench]   # --bench writes the BENCH cell
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np

from repro.audit import membership as mem

N_DATA = 50_000          # CIFAR-10 training-set size (paper's Table 3)


def make_commitments(n: int, seed: int = 0) -> List[int]:
    """Synthetic per-sample commitments: uniform u61 scalars, the same
    encoding domain the proof format serializes group elements into."""
    rng = np.random.default_rng(seed)
    return [int(v) for v in rng.integers(1, 1 << 61, size=n, dtype=np.uint64)]


def main(hashes: List[str] | None = None,
         query_sizes: List[int] | None = None,
         ratios: List[float] | None = None,
         n_data: int = N_DATA):
    hashes = hashes or ["md5", "sha1", "sha256"]
    query_sizes = query_sizes or [10, 100, 1000]
    ratios = ratios or [0.0, 0.1, 0.5, 0.9, 1.0]
    data = make_commitments(n_data)
    outside = [mem.com_to_bytes(c)
               for c in make_commitments(max(query_sizes), seed=10**6)]
    rows = []
    for h in hashes:
        t0 = time.perf_counter()
        tree, binding = mem.build_binding({0: data}, hash_name=h)
        t_bind = time.perf_counter() - t0
        binding_rt = mem.DatasetBinding.from_bytes(binding.to_bytes())
        for nq in query_sizes:
            for ratio in ratios:
                n_pos = int(round(nq * ratio))
                queried = ([mem.com_to_bytes(c) for c in data[:n_pos]]
                           + outside[:nq - n_pos])
                t0 = time.perf_counter()
                audit = mem.prove_membership(tree, binding, -1, queried)
                raw = audit.to_bytes()
                t_prove = time.perf_counter() - t0
                t0 = time.perf_counter()
                verdict = mem.verify_membership(
                    binding_rt, mem.MembershipAudit.from_bytes(raw))
                t_verify = (time.perf_counter() - t0) * 1e3
                assert verdict.ok, verdict.reason
                assert verdict.n_members == n_pos, (verdict.n_members,
                                                    n_pos)
                size = audit.proof.size_nodes()
                rows.append({"hash": h, "n_query": nq, "ratio": ratio,
                             "t_bind_s": round(t_bind, 3),
                             "size_nodes": size,
                             "audit_bytes": len(raw),
                             "t_prove_ms": round(t_prove * 1e3, 3),
                             "t_verify_ms": round(t_verify, 3)})
                print(f"table3,hash={h},n_query={nq},ratio={ratio},"
                      f"t_bind_s={t_bind:.1f},size_nodes={size},"
                      f"t_verify_ms={t_verify:.2f},"
                      f"t_prove_ms={t_prove*1e3:.2f}", flush=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-data", type=int, default=None)
    ap.add_argument("--bench", action="store_true",
                    help="reduced standard cell -> "
                         "BENCH_table3_membership.json")
    ap.add_argument("--out", default="BENCH_table3_membership.json")
    args = ap.parse_args()
    if args.bench:
        n = args.n_data or 10_000
        rows = main(query_sizes=[10, 100], ratios=[0.0, 0.5, 1.0],
                    n_data=n)
        with open(args.out, "w") as f:
            json.dump({"n_data": n, "rows": rows}, f, indent=1)
            f.write("\n")
        print(f"table3: wrote {len(rows)} cells -> {args.out}")
    else:
        main(n_data=args.n_data or N_DATA)
