"""Pippenger MSM window microbench: fixed WINDOW=8 vs length-adaptive.

Small vectors (the IPA's halving fold lengths) used to pay the full
256-bucket scatter per window; `group.best_window` picks ~log2(n)
instead.  Reports best-of-N wall time per length and the speedup.

    PYTHONPATH=src python benchmarks/msm_window.py \
        [--sizes 4,16,64,256,1024] [--repeats 3] [--out BENCH_msm.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def bench_one(n: int, repeats: int, window):
    import jax.numpy as jnp
    from repro.core import group

    rng = np.random.default_rng(n)
    pts_int = [pow(int(rng.integers(2, 1 << 40)), 2, group.P)
               for _ in range(n)]
    pts = jnp.asarray(np.stack([np.asarray(group.encode_group(p))
                                for p in pts_int]))
    exps = group.exps_from_ints(
        [int(rng.integers(0, group.Q)) for _ in range(n)])
    out = group.msm(pts, exps, window=window)       # warmup / compile
    out.block_until_ready()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        group.msm(pts, exps, window=window).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="4,16,64,256,1024")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="BENCH_msm.json")
    args = ap.parse_args(argv)

    from repro.util import enable_compilation_cache
    enable_compilation_cache()
    from repro.core import group

    rows = []
    for n in sorted({int(s) for s in args.sizes.split(",")}):
        fixed_s = bench_one(n, args.repeats, window=8)
        adapt_s = bench_one(n, args.repeats, window=None)
        w = group.best_window(group._pad4(n))
        rows.append({"n": n, "window_fixed8_s": fixed_s,
                     "window_adaptive": w, "adaptive_s": adapt_s,
                     "speedup": fixed_s / adapt_s})
        print(f"msm,n={n},fixed8={fixed_s * 1e3:.2f}ms,"
              f"adaptive(w={w})={adapt_s * 1e3:.2f}ms,"
              f"speedup={fixed_s / adapt_s:.2f}x", flush=True)

    result = {"repeats": args.repeats, "rows": rows}
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"msm_window: wrote {args.out}", flush=True)
    return result


if __name__ == "__main__":
    main()
