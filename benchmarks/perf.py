"""Perf hillclimb harness: lower one (arch x shape) cell under a named
variant, report the three roofline terms and a collective 'profile'
(per-computation, trip-count-scaled) to attribute wire bytes to program
structure.  This is the measure step of the hypothesis -> change ->
measure -> validate loop logged in EXPERIMENTS.md §Perf.

    python -m benchmarks.perf --arch deepseek-7b --shape train_4k \
        --variant baseline|fsdp|fsdp_seqshard|... [--multi-pod]
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
from typing import Dict

from repro.launch.dryrun import (HBM_BW, ICI_BW, PEAK_FLOPS, _COMP_HEAD,
                                 _TRIP_RE, _WHILE_BODY_RE, _line_collective,
                                 collective_bytes_scaled)

CHIPS = 256


def variant_config(cfg, name: str):
    """Named config variants for the hillclimb (framework-level knobs)."""
    table = {
        "baseline": {},
        "fsdp": {"fsdp": True},
        "nofsdp": {"fsdp": False},
        "noremat": {"remat": False},
        "fsdp_noremat": {"fsdp": True, "remat": False},
        "remat_dots": {"remat_policy": "dots"},
        "nofsdp_remat_dots": {"fsdp": False, "remat_policy": "dots"},
        "sp": {"seq_shard_carry": True},
        "sp_remat_dots": {"seq_shard_carry": True, "remat_policy": "dots"},
        "sp_nofsdp": {"seq_shard_carry": True, "fsdp": False},
    }
    if name not in table:
        raise SystemExit(f"unknown variant {name!r}: {sorted(table)}")
    return dataclasses.replace(cfg, **table[name])


def comp_profile(hlo_text: str, top: int = 12):
    """Per-computation trip-scaled collective bytes, descending."""
    comps: Dict[str, list] = {}
    entry = None
    current = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            m = _COMP_HEAD.match(line)
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
                continue
        if current is not None:
            comps[current].append(line)
    per_comp, edges = {}, {}
    for name, lines in comps.items():
        tot, edge = 0, []
        for ln in lines:
            hit = _line_collective(ln)
            if hit:
                tot += hit[1]
            if "while(" in ln and "body=" in ln:
                bm = _WHILE_BODY_RE.search(ln)
                tm = _TRIP_RE.search(ln)
                if bm:
                    edge.append((bm.group(1),
                                 int(tm.group(1)) if tm else 1))
        per_comp[name] = tot
        edges[name] = edge
    mult = {n: 0 for n in comps}
    mult[entry or next(iter(comps))] = 1
    work = [entry]
    while work:
        p = work.pop()
        for body, trip in edges.get(p, ()):
            if body in mult:
                before = mult[body]
                mult[body] += mult[p] * trip
                if mult[body] != before:
                    work.append(body)
    rows = [(n, per_comp[n] * (mult.get(n, 0) or 1), mult.get(n, 0) or 1,
             per_comp[n])
            for n in comps if per_comp[n]]
    rows.sort(key=lambda r: -r[1])
    return rows[:top]


def run_zkdl(arch: str, shape: str, variant: str) -> Dict:
    """Proof-pipeline perf cell for the fcnn (zkDL) family: there is no
    XLA train cell to lower, so the measure step is the aggregated
    prover itself -- per-step proving time and proof size at T=1 vs T=4
    (the FAC4DNN amortization; full curve in benchmarks/agg_steps.py).

    Uses the agg_steps smoke cell, where the amortizable fixed costs
    dominate; this module's forced 512-device XLA env inflates per-op
    dispatch cost, so absolute times are not comparable to a standalone
    benchmarks/agg_steps.py run."""
    from benchmarks.agg_steps import bench_T

    if variant != "baseline":
        print(f"perf,{arch}: variant {variant!r} has no effect on the "
              f"zkdl proof pipeline (no XLA knobs); running baseline",
              flush=True)
    rows = [bench_T(T, layers=2, batch=2, width=4, q_bits=16, r_bits=4,
                    repeats=2, verify=(T == 1)) for T in (1, 4)]
    rec = {
        "arch": arch, "shape": shape, "variant": variant, "mesh": "n/a",
        "mode": "zkdl-proof-pipeline", "rows": rows,
        "amortization_t4": rows[1]["per_step_s"] / rows[0]["per_step_s"],
    }
    for r in rows:
        print(f"perf,{arch},zkdl,T={r['T']},"
              f"per_step_s={r['per_step_s']:.2f},"
              f"per_step_kB={r['per_step_bytes'] / 1024:.2f}", flush=True)
    print(f"perf,{arch},zkdl,amortization_t4="
          f"{rec['amortization_t4']:.2f}", flush=True)
    return rec


def run(arch: str, shape: str, variant: str, multi_pod: bool = False,
        profile: bool = True) -> Dict:
    from repro.util import enable_compilation_cache
    enable_compilation_cache()
    from repro.configs.registry import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell
    from benchmarks import costmodel

    if get_config(arch).family == "fcnn":
        return run_zkdl(arch, shape, variant)
    cfg = variant_config(get_config(arch), variant)
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered = lower_cell(cfg, mesh, shape)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    coll, per_kind = collective_bytes_scaled(hlo)
    mem = compiled.memory_analysis()
    live = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    fl = costmodel.flops_cell(cfg, shape)
    by = costmodel.bytes_cell(cfg, shape)
    rec = {
        "arch": arch, "shape": shape, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "compute_s": fl["total"] / CHIPS / PEAK_FLOPS,
        "memory_s": by / CHIPS / HBM_BW,
        "collective_s": coll / ICI_BW,
        "collective_bytes": coll,
        "per_kind": {k: v for k, v in per_kind.items() if v},
        "live_gib": live / 2**30,
        "model_flops_s": fl["model"] / CHIPS / PEAK_FLOPS,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=rec.__getitem__)
    rec["dominant"] = dom
    rec["roofline_frac"] = rec["model_flops_s"] / rec[dom]
    print(f"perf,{arch},{shape},{variant},mesh={rec['mesh']},"
          f"compute_s={rec['compute_s']:.3f},memory_s={rec['memory_s']:.3f},"
          f"collective_s={rec['collective_s']:.3f},live_gib={rec['live_gib']:.1f},"
          f"dominant={dom},frac={rec['roofline_frac']:.3f}", flush=True)
    print(" kinds:", {k: f"{v:.2e}" for k, v in rec["per_kind"].items()},
          flush=True)
    if profile:
        for name, scaled, m, raw in comp_profile(hlo):
            print(f"  comp {name}  x{m}  {scaled:.3e} B (raw {raw:.3e})",
                  flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rec = run(args.arch, args.shape, args.variant, args.multi_pod)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
