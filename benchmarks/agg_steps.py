"""Cross-step aggregation benchmark: the FAC4DNN amortization curve.

For T in --steps-list, proves ONE aggregated session over T consecutive
batch updates (shared commitments, sumchecks, validity argument and IPA
openings; the step axis is log2(T) extra sumcheck variables) and reports
per-step proving time and per-step proof size.  The T=1 row doubles as
the "T independent proofs" baseline: independent proving costs exactly
T * row(1), so amortization = per_step(T) / per_step(1).

    PYTHONPATH=src python benchmarks/agg_steps.py \
        [--steps-list 1,2,4,8] [--width 4] [--batch 2] [--layers 2] \
        [--repeats 2] [--no-verify] [--out BENCH_agg_steps.json] \
        [--phases-out BENCH_prover_phases.json] \
        [--het-widths 16,8,4,2] [--smoke]

Emits BENCH_agg_steps.json with the full curve, the monotonicity
verdicts on the T=1..4 prefix, and a heterogeneous cell comparing a
pyramid MLP against a uniform MLP of (approximately) equal parameter
count in one aggregated session.  Both prove and verify run an untimed
warm-up first; the warm-up durations are recorded separately as
``prove_compile_s`` / ``verify_compile_s`` so jit compilation never
pollutes (or de-monotonizes) the reported numbers.

``prove_compile_warm_s`` is the warm-start cost: what a FRESH process
pays on its first prove once the serialized-executable cache
(`repro.core.execache`) is populated.  It is measured in a controlled
fresh subprocess (--warm-probe): the parent's cold warm-up populates
the disk cache, then the child proves twice and reports
first_prove - steady_prove along with the executable-cache hit/miss
counters (a correct warm start shows ``misses == 0``).  The old
in-process ``jax.clear_caches()`` + re-prove measurement is gone — it
dropped executables a fresh process would load from disk while KEEPING
warm host state a fresh process wouldn't have, so it could read higher
than the cold path at small T and was neither cold nor warm.

Each row also carries the per-phase prover profile (commit / matmul /
anchor / openings wall clock plus the openings sub-phases, see
`repro.core.pipeline.profile`), emitted standalone as
BENCH_prover_phases.json.  ``--smoke`` is the CI guard: tiny shapes,
every cell must verify, the phase profile must account for ~all prove
time, serialized per-step bytes at T=8 must stay strictly below the
recorded v1 baseline, the zkReLU validity prep sub-phase must stay
under its share budget of T=8 prove time, and the warm start must be
genuinely warm: zero executable-cache misses in the probe subprocess,
T=8 warm overhead under WARM_COMPILE_MAX_S and within
WARM_T_INVARIANCE_MAX of the T=1 overhead (compile cost flat in T); no
JSON written.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

_PROBE_TAG = "WARM_PROBE_RESULT "


def _warm_probe_child(params: dict) -> None:
    """Body of the ``--warm-probe`` subprocess: starting from a populated
    executable-cache disk (the parent's cold warm-up wrote it), rebuild
    the keys, prove twice, and report first/steady timings plus the
    execache counters as one tagged JSON line on stdout.  This IS the
    warm-start scenario: a fresh prover process for a config someone has
    proved before on this machine."""
    from repro.core import execache
    from repro.core.quantfc import (QuantConfig,
                                    synthetic_sgd_trajectory_widths)
    from repro.core.pipeline import PipelineConfig, ProofSession, make_keys
    from repro.util import enable_compilation_cache

    enable_compilation_cache()        # mirror what a real prover enables
    widths = tuple(params["widths"])
    cfg = PipelineConfig(n_layers=len(widths) - 1, batch=params["batch"],
                         q_bits=params["q_bits"], r_bits=params["r_bits"],
                         n_steps=params["T"], widths=widths)
    qc = QuantConfig(q_bits=params["q_bits"], r_bits=params["r_bits"])
    t0 = time.perf_counter()
    keys = make_keys(cfg)
    setup_s = time.perf_counter() - t0
    wits = synthetic_sgd_trajectory_widths(params["T"], widths,
                                           params["batch"], qc,
                                           seed=params["T"])

    def prove_once(seed):
        session = ProofSession(keys, np.random.default_rng(seed))
        for w in wits:
            session.add_step(w)
        t0 = time.perf_counter()
        session.prove()
        return time.perf_counter() - t0

    execache.reset_stats()
    first = prove_once(0)
    stats = execache.stats()          # counters for the FIRST prove only
    steady = min(prove_once(s) for s in (1, 2))
    print(_PROBE_TAG + json.dumps({
        "setup_s": setup_s,
        "first_prove_s": first,
        "steady_prove_s": steady,
        "warm_overhead_s": max(0.0, first - steady),
        "exec_stats": stats,
        "exec_warm": execache.enabled() and execache.cache_dir() is not None,
    }), flush=True)


def _measure_warm(T: int, batch: int, q_bits: int, r_bits: int, widths,
                  attempts: int = 2):
    """Run the warm-start probe in a controlled FRESH subprocess and
    return its JSON report (best of ``attempts`` runs by warm overhead —
    the probe is pure wall clock, so background load can only inflate
    it).  The parent must have proved this exact config already (so the
    executable-cache disk is populated)."""
    params = {"T": T, "batch": batch, "q_bits": q_bits, "r_bits": r_bits,
              "widths": list(widths)}
    here = os.path.abspath(__file__)
    src = os.path.join(os.path.dirname(os.path.dirname(here)), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    best = None
    for _ in range(attempts):
        proc = subprocess.run(
            [sys.executable, here, "--warm-probe", json.dumps(params)],
            capture_output=True, text=True, env=env, timeout=1800)
        report = None
        for line in proc.stdout.splitlines():
            if line.startswith(_PROBE_TAG):
                report = json.loads(line[len(_PROBE_TAG):])
        if report is None:
            raise RuntimeError(
                f"warm probe subprocess failed (rc={proc.returncode}):\n"
                f"{proc.stdout[-1000:]}\n{proc.stderr[-2000:]}")
        # a single re-traced program anywhere disqualifies the whole
        # warm start — never let a lucky fast attempt mask it
        if report["exec_stats"]["misses"] > 0:
            return report
        if best is None or (report["warm_overhead_s"]
                            < best["warm_overhead_s"]):
            best = report
    return best


def bench_T(T: int, layers: int, batch: int, width: int, q_bits: int,
            r_bits: int, repeats: int, verify: bool, widths=None,
            warm_probe: bool = True):
    from repro.core.quantfc import (QuantConfig,
                                    synthetic_sgd_trajectory_widths)
    from repro.core.pipeline import (PipelineConfig, ProofSession,
                                     encode_proof, make_keys,
                                     verify_session)

    if widths is None:
        widths = (width,) * (layers + 1)
    cfg = PipelineConfig(n_layers=len(widths) - 1, batch=batch,
                         q_bits=q_bits, r_bits=r_bits, n_steps=T,
                         widths=widths)
    qc = QuantConfig(q_bits=q_bits, r_bits=r_bits)
    keys = make_keys(cfg)
    wits = synthetic_sgd_trajectory_widths(T, widths, batch, qc, seed=T)

    def prove_once(seed):
        session = ProofSession(keys, np.random.default_rng(seed))
        for w in wits:
            session.add_step(w)
        t0 = time.perf_counter()
        proof = session.prove()
        return time.perf_counter() - t0, proof, session.last_profile

    # warmup run (jit compilation / caches), then best-of-N timed runs;
    # the warmup duration is recorded SEPARATELY so compile time never
    # leaks into (and never jitters) the reported prove/verify numbers
    prove_compile_s, proof, _ = prove_once(0)

    # warm-start cost: what a FRESH process pays on its first prove with
    # the executable-cache disk populated (which the cold warm-up above
    # just did).  Measured in a controlled fresh subprocess — an
    # in-process jax.clear_caches() probe is neither cold nor warm: it
    # drops executables a fresh process would load from disk while
    # keeping warm host state a fresh process wouldn't have
    prove_compile_warm_s, warm = None, None
    if warm_probe:
        warm = _measure_warm(T, batch, q_bits, r_bits, widths)
        prove_compile_warm_s = warm["warm_overhead_s"]

    best, phases = float("inf"), None
    for rep in range(repeats):
        dt, proof, prof = prove_once(rep + 1)
        if dt < best:
            best, phases = dt, prof

    ok, verify_s, verify_compile_s = None, None, None
    if verify:
        t0 = time.perf_counter()
        ok = verify_session(keys, proof)          # untimed warm-up cell
        verify_compile_s = time.perf_counter() - t0
        assert ok, f"aggregated proof rejected at T={T}"
        verify_s = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            ok = verify_session(keys, proof)
            verify_s = min(verify_s, time.perf_counter() - t0)
        assert ok, f"aggregated proof rejected at T={T}"

    # proof size is the CANONICAL WIRE FORMAT (len(encode_proof)), not
    # an in-memory estimate: what actually crosses the network per window
    proof_bytes = len(encode_proof(proof))
    return {
        "T": T,
        "prove_s": best,
        "per_step_s": best / T,
        "proof_bytes": proof_bytes,
        "per_step_bytes": proof_bytes / T,
        "prove_compile_s": prove_compile_s,
        "prove_compile_warm_s": prove_compile_warm_s,
        "warm_first_prove_s": warm["first_prove_s"] if warm else None,
        "warm_steady_prove_s": warm["steady_prove_s"] if warm else None,
        "warm_setup_s": warm["setup_s"] if warm else None,
        "warm_exec_stats": warm["exec_stats"] if warm else None,
        "warm_exec_warm": warm["exec_warm"] if warm else None,
        "verify_s": verify_s,
        "verify_compile_s": verify_compile_s,
        "verify_ok": ok,
        "phases": phases.as_dict() if phases is not None else None,
    }


def bench_heterogeneous(args, T: int = 2):
    """The heterogeneous cell: a pyramid MLP vs a uniform-width MLP at
    (approximately) equal parameter count, both aggregated over T steps
    in ONE ProofSession.  FAC4DNN's claim is that heterogeneous shapes
    aggregate as well as uniform ones; the acceptance bar is pyramid
    per-step prove time within 1.5x of uniform."""
    het_widths = tuple(int(w) for w in args.het_widths.split(","))
    uni = bench_T(T, args.het_uniform_layers, args.batch,
                  args.het_uniform_width, args.q_bits, args.r_bits,
                  args.repeats, verify=not args.no_verify,
                  warm_probe=False)
    het = bench_T(T, 0, args.batch, 0, args.q_bits, args.r_bits,
                  args.repeats, verify=not args.no_verify,
                  widths=het_widths, warm_probe=False)
    p_het = sum(a * b for a, b in zip(het_widths, het_widths[1:]))
    p_uni = args.het_uniform_layers * args.het_uniform_width ** 2
    cell = {
        "T": T,
        "widths": list(het_widths),
        "uniform_width": args.het_uniform_width,
        "uniform_layers": args.het_uniform_layers,
        "param_count_het": p_het,
        "param_count_uniform": p_uni,
        "het_per_step_s": het["per_step_s"],
        "uniform_per_step_s": uni["per_step_s"],
        "het_per_step_bytes": het["per_step_bytes"],
        "uniform_per_step_bytes": uni["per_step_bytes"],
        "ratio_het_vs_uniform": het["per_step_s"] / uni["per_step_s"],
        "verify_ok": het["verify_ok"] and uni["verify_ok"],
    }
    print(f"agg_steps,het,widths={'x'.join(map(str, het_widths))},"
          f"params={p_het}v{p_uni},per_step_s="
          f"{het['per_step_s']:.2f}v{uni['per_step_s']:.2f},"
          f"ratio={cell['ratio_het_vs_uniform']:.2f}", flush=True)
    return cell


# serialized per-step proof bytes at T=8 under the v1 byte format
# (committed BENCH_agg_steps.json baseline before the one-IPA direct-sum
# opening); --smoke asserts the current format stays STRICTLY smaller,
# so an opening-layout regression can never ship silently through CI
V1_T8_PER_STEP_BYTES = 494.375

# ceiling on the zkrelu-validity share of T=8 prove wall clock (the
# sub-phase now covers statement/table prep only — the validity IPA
# itself rides the merged pair IPA); under the v2 host-side per-bit
# loops this phase consumed ~45% of prove, the kernel path keeps it
# comfortably below a third
VALIDITY_SHARE_MAX_T8 = 0.35

# warm-start gates (fresh-subprocess probe, executable cache populated):
# a warm prover must come up in seconds, and the cost must be flat in T
# — the scan-shaped sumcheck bodies and masked IPA ladder make the
# executable set depend only on shape buckets, not on depth or T, so
# T=8 pays (nearly) the same warm overhead as T=1.  The absolute slack
# absorbs disk/OS noise at toy shapes where the overheads are a few
# seconds and a 0.3s wobble would otherwise flip the ratio.  The
# absolute budget carries ~30% headroom over a loaded-container
# measurement (the 5.0s budget tripped at 5.2-5.5s on a machine where
# the unchanged seed measured the same — interpreter+jax import and
# disk-cache loads drift with host load; the warm CONTRACT is the
# zero-miss assert above, the seconds bound only catches a cold start's
# ~25-30s full re-trace).
WARM_COMPILE_MAX_S = 7.0
WARM_T_INVARIANCE_MAX = 1.3
WARM_T_INVARIANCE_SLACK_S = 0.5


def monotonic_prefix(rows, key, t_max=4):
    """Strictly-decreasing verdict over the measured T<=t_max prefix;
    None (json null) when T=1 wasn't measured or the prefix is trivial,
    so a partial --steps-list never yields a vacuous True."""
    vals = [r[key] for r in rows if r["T"] <= t_max]
    if len(vals) < 2 or not any(r["T"] == 1 for r in rows):
        return None
    return all(b < a for a, b in zip(vals, vals[1:]))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps-list", default="1,2,4,8")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--width", type=int, default=4)
    ap.add_argument("--q-bits", type=int, default=16)
    ap.add_argument("--r-bits", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--no-verify", action="store_true")
    ap.add_argument("--het-widths", default="16,8,4,2",
                    help="pyramid shape table for the heterogeneous cell")
    ap.add_argument("--het-uniform-width", type=int, default=8)
    ap.add_argument("--het-uniform-layers", type=int, default=3)
    ap.add_argument("--no-het", action="store_true",
                    help="skip the heterogeneous comparison cell")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny shapes, 1 repeat, asserts every "
                         "cell verifies AND the phase profile accounts "
                         "for ~all prove time, writes no JSON unless "
                         "--out/--phases-out are passed explicitly")
    ap.add_argument("--out", default=None)
    ap.add_argument("--phases-out", default=None,
                    help="per-phase prover profile JSON "
                         "(default BENCH_prover_phases.json)")
    ap.add_argument("--warm-probe", default=None, metavar="JSON",
                    help=argparse.SUPPRESS)   # internal: subprocess body
    ap.add_argument("--no-warm-probe", action="store_true",
                    help="skip the fresh-subprocess warm-start probe")
    args = ap.parse_args(argv)
    if args.warm_probe is not None:
        _warm_probe_child(json.loads(args.warm_probe))
        return None
    if args.smoke:
        # T=8 rides along so CI can gate the serialized per-step size
        # against the recorded v1 baseline (see V1_T8_PER_STEP_BYTES)
        args.steps_list = "1,2,8"
        args.repeats = 1
        args.no_verify = False
        args.het_widths = "8,4,4,2"        # multi-bucket, but tiny
        args.het_uniform_width = 4
        args.het_uniform_layers = 2
    if args.out is None:
        args.out = None if args.smoke else "BENCH_agg_steps.json"
    if args.phases_out is None:
        args.phases_out = None if args.smoke else "BENCH_prover_phases.json"

    from repro.util import enable_compilation_cache
    enable_compilation_cache()

    steps = sorted({int(s) for s in args.steps_list.split(",")})
    rows = []
    for T in steps:
        row = bench_T(T, args.layers, args.batch, args.width,
                      args.q_bits, args.r_bits, args.repeats,
                      verify=not args.no_verify,
                      warm_probe=not args.no_warm_probe)
        base = rows[0] if rows else row
        row["amortization_vs_T1"] = (row["per_step_s"] / base["per_step_s"]
                                     if base["T"] == 1 else None)
        rows.append(row)
        amort = row["amortization_vs_T1"]
        print(f"agg_steps,T={T},prove_s={row['prove_s']:.2f},"
              f"per_step_s={row['per_step_s']:.2f},"
              f"proof_kB={row['proof_bytes'] / 1024:.1f},"
              f"per_step_kB={row['per_step_bytes'] / 1024:.2f},"
              f"amortization="
              f"{f'{amort:.2f}' if amort is not None else 'n/a'}",
              flush=True)

    result = {
        "config": {"layers": args.layers, "batch": args.batch,
                   "width": args.width, "q_bits": args.q_bits,
                   "r_bits": args.r_bits, "repeats": args.repeats},
        "rows": rows,
        "monotonic_per_step_time_1_to_4": monotonic_prefix(
            rows, "per_step_s"),
        "monotonic_per_step_size_1_to_4": monotonic_prefix(
            rows, "per_step_bytes"),
    }
    if not args.no_het:
        result["heterogeneous"] = bench_heterogeneous(args)

    phases_result = {
        "config": result["config"],
        "rows": [{"T": r["T"], "prove_s": r["prove_s"],
                  **(r["phases"] or {})} for r in rows],
    }
    if args.smoke:
        assert all(r["verify_ok"] for r in rows), "smoke: a cell rejected"
        if not args.no_het:
            assert result["heterogeneous"]["verify_ok"], \
                "smoke: heterogeneous cell rejected"
        # the phase profiler must attribute (nearly) all of prove time
        for r in rows:
            ph = r["phases"]
            assert ph is not None, f"smoke: no phase profile at T={r['T']}"
            assert ph["accounted_s"] <= ph["total_s"] * 1.001 + 1e-6 and \
                ph["accounted_s"] >= ph["total_s"] * 0.85, \
                f"smoke: phases {ph['accounted_s']:.3f}s do not sum to " \
                f"prove total {ph['total_s']:.3f}s at T={r['T']}"
            sub = ph.get("sub_phases_s")
            assert sub and set(sub) >= {"claim-combine", "ipa-rounds",
                                        "sigma", "zkrelu-validity"}, \
                f"smoke: openings sub-phases missing at T={r['T']}: {sub}"
        # proof-size regression gate: the one-IPA opening must keep the
        # serialized per-step bytes strictly under the v1 baseline
        (t8,) = [r for r in rows if r["T"] == 8]
        assert t8["per_step_bytes"] < V1_T8_PER_STEP_BYTES, (
            f"smoke: serialized per-step proof at T=8 is "
            f"{t8['per_step_bytes']:.1f} B/step, not smaller than the v1 "
            f"baseline {V1_T8_PER_STEP_BYTES} B/step")
        # phase-share gate: with the kernel-built tables and the validity
        # claims folded into the merged IPA, zkReLU validity prep must
        # stay a MINORITY cost of the T=8 prove (it was ~45% under the
        # v2 host-loop path; regressions to per-bit python show up here)
        vshare = (t8["phases"]["sub_phases_s"]["zkrelu-validity"]
                  / t8["prove_s"])
        assert vshare <= VALIDITY_SHARE_MAX_T8, (
            f"smoke: zkReLU validity prep is {vshare:.0%} of T=8 prove "
            f"time, over the {VALIDITY_SHARE_MAX_T8:.0%} budget")
        # warm-start gates: a fresh process with the executable cache
        # populated must (a) never re-trace, (b) come up fast, (c) pay
        # the same compile overhead at T=8 as at T=1 (flat in T)
        warm_line = "warm probe skipped"
        if not args.no_warm_probe:
            (t1,) = [r for r in rows if r["T"] == 1]
            for r in rows:
                es = r["warm_exec_stats"]
                if r["warm_exec_warm"]:
                    assert es["misses"] == 0, (
                        f"smoke: warm-start subprocess at T={r['T']} "
                        f"re-compiled {es['misses']} programs (expected "
                        f"0 executable-cache misses): {es}")
            t8w, t1w = t8["prove_compile_warm_s"], \
                t1["prove_compile_warm_s"]
            assert t8w <= WARM_COMPILE_MAX_S, (
                f"smoke: T=8 warm-start overhead {t8w:.2f}s over the "
                f"{WARM_COMPILE_MAX_S}s budget")
            assert t8w <= (WARM_T_INVARIANCE_MAX * t1w
                           + WARM_T_INVARIANCE_SLACK_S), (
                f"smoke: warm-start overhead not flat in T: T=8 "
                f"{t8w:.2f}s vs T=1 {t1w:.2f}s (budget "
                f"{WARM_T_INVARIANCE_MAX}x + "
                f"{WARM_T_INVARIANCE_SLACK_S}s)")
            warm_line = (f"warm start {t8w:.2f}s at T=8 vs {t1w:.2f}s "
                         f"at T=1, 0 misses")
        print(f"agg_steps: smoke ok (all cells verified; phases account "
              f"for prove time; T=8 per-step {t8['per_step_bytes']:.1f} B "
              f"< v1 baseline {V1_T8_PER_STEP_BYTES} B; validity share "
              f"{vshare:.0%} <= {VALIDITY_SHARE_MAX_T8:.0%}; "
              f"{warm_line})", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"agg_steps: wrote {args.out}; "
              f"per-step time monotonic(1..4)="
              f"{result['monotonic_per_step_time_1_to_4']}, "
              f"per-step size monotonic(1..4)="
              f"{result['monotonic_per_step_size_1_to_4']}", flush=True)
    if args.phases_out:
        with open(args.phases_out, "w") as f:
            json.dump(phases_result, f, indent=1)
        print(f"agg_steps: wrote {args.phases_out}", flush=True)
    return result


if __name__ == "__main__":
    main()
