"""Cross-step aggregation benchmark: the FAC4DNN amortization curve.

For T in --steps-list, proves ONE aggregated session over T consecutive
batch updates (shared commitments, sumchecks, validity argument and IPA
openings; the step axis is log2(T) extra sumcheck variables) and reports
per-step proving time and per-step proof size.  The T=1 row doubles as
the "T independent proofs" baseline: independent proving costs exactly
T * row(1), so amortization = per_step(T) / per_step(1).

    PYTHONPATH=src python benchmarks/agg_steps.py \
        [--steps-list 1,2,4,8] [--width 4] [--batch 2] [--layers 2] \
        [--repeats 2] [--no-verify] [--out BENCH_agg_steps.json]

Emits BENCH_agg_steps.json with the full curve plus the monotonicity
verdicts on the T=1..4 prefix.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def bench_T(T: int, layers: int, batch: int, width: int, q_bits: int,
            r_bits: int, repeats: int, verify: bool):
    from repro.core.quantfc import QuantConfig, synthetic_sgd_trajectory
    from repro.core.pipeline import (PipelineConfig, make_keys,
                                     prove_session, verify_session)

    cfg = PipelineConfig(n_layers=layers, batch=batch, width=width,
                         q_bits=q_bits, r_bits=r_bits, n_steps=T)
    qc = QuantConfig(q_bits=q_bits, r_bits=r_bits)
    keys = make_keys(cfg)
    wits = synthetic_sgd_trajectory(T, layers, batch, width, qc, seed=T)

    # warmup run (jit compilation / caches), then best-of-N timed runs
    proof = prove_session(keys, wits, np.random.default_rng(0))
    best = float("inf")
    for rep in range(repeats):
        t0 = time.perf_counter()
        proof = prove_session(keys, wits, np.random.default_rng(rep + 1))
        best = min(best, time.perf_counter() - t0)

    ok = None
    if verify:
        t0 = time.perf_counter()
        ok = verify_session(keys, proof)
        verify_s = time.perf_counter() - t0
        assert ok, f"aggregated proof rejected at T={T}"
    else:
        verify_s = None

    return {
        "T": T,
        "prove_s": best,
        "per_step_s": best / T,
        "proof_bytes": proof.size_bytes(),
        "per_step_bytes": proof.size_bytes() / T,
        "verify_s": verify_s,
        "verify_ok": ok,
    }


def monotonic_prefix(rows, key, t_max=4):
    """Strictly-decreasing verdict over the measured T<=t_max prefix;
    None (json null) when T=1 wasn't measured or the prefix is trivial,
    so a partial --steps-list never yields a vacuous True."""
    vals = [r[key] for r in rows if r["T"] <= t_max]
    if len(vals) < 2 or not any(r["T"] == 1 for r in rows):
        return None
    return all(b < a for a, b in zip(vals, vals[1:]))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps-list", default="1,2,4,8")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--width", type=int, default=4)
    ap.add_argument("--q-bits", type=int, default=16)
    ap.add_argument("--r-bits", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--no-verify", action="store_true")
    ap.add_argument("--out", default="BENCH_agg_steps.json")
    args = ap.parse_args(argv)

    from repro.util import enable_compilation_cache
    enable_compilation_cache()

    steps = sorted({int(s) for s in args.steps_list.split(",")})
    rows = []
    for T in steps:
        row = bench_T(T, args.layers, args.batch, args.width,
                      args.q_bits, args.r_bits, args.repeats,
                      verify=not args.no_verify)
        base = rows[0] if rows else row
        row["amortization_vs_T1"] = (row["per_step_s"] / base["per_step_s"]
                                     if base["T"] == 1 else None)
        rows.append(row)
        amort = row["amortization_vs_T1"]
        print(f"agg_steps,T={T},prove_s={row['prove_s']:.2f},"
              f"per_step_s={row['per_step_s']:.2f},"
              f"proof_kB={row['proof_bytes'] / 1024:.1f},"
              f"per_step_kB={row['per_step_bytes'] / 1024:.2f},"
              f"amortization="
              f"{f'{amort:.2f}' if amort is not None else 'n/a'}",
              flush=True)

    result = {
        "config": {"layers": args.layers, "batch": args.batch,
                   "width": args.width, "q_bits": args.q_bits,
                   "r_bits": args.r_bits, "repeats": args.repeats},
        "rows": rows,
        "monotonic_per_step_time_1_to_4": monotonic_prefix(
            rows, "per_step_s"),
        "monotonic_per_step_size_1_to_4": monotonic_prefix(
            rows, "per_step_bytes"),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"agg_steps: wrote {args.out}; "
          f"per-step time monotonic(1..4)="
          f"{result['monotonic_per_step_time_1_to_4']}, "
          f"per-step size monotonic(1..4)="
          f"{result['monotonic_per_step_size_1_to_4']}", flush=True)
    return result


if __name__ == "__main__":
    main()
