"""Roofline report: compute / memory / collective terms per (arch x shape)
cell on the single-pod 16x16 production mesh (TPU v5e constants).

Sources (see costmodel.py docstring for why):
  * compute term  = analytic FLOPs  / (chips * 197 TFLOP/s)
  * memory term   = analytic bytes  / (chips * 819 GB/s)
  * collective    = trip-count-scaled HLO collective bytes / (chips * 50 GB/s)

The analytic model is validated against an UNROLLED compile of a reduced
config (`validate_costmodel`, run by tests/test_roofline.py), since XLA's
HloCostAnalysis counts a scanned layer stack once.  MODEL_FLOPS = 6*N*D
(dense) / 6*N_active*D (MoE); the useful-compute ratio MODEL/analytic
catches remat and redundancy waste.

Reads results/dryrun/*.json (the dry-run artifacts); writes
results/roofline.json and prints the table.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS

CHIPS = 256          # single-pod 16x16


def _arch_id(stem: str) -> str:
    return stem.replace("-", "_")


def cell_report(arch: str, shape: str, dry: Dict) -> Dict:
    from repro.configs.registry import get_config
    from benchmarks import costmodel

    cfg = get_config(arch)
    fl = costmodel.flops_cell(cfg, shape)
    by = costmodel.bytes_cell(cfg, shape)
    coll_dev = dry["per_device_collective_bytes"]
    compute_s = fl["total"] / CHIPS / PEAK_FLOPS
    memory_s = by / CHIPS / HBM_BW
    coll_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # roofline fraction: useful-compute time over the achievable step time
    # (bound below by the dominant term; terms overlap in the best case)
    model_s = fl["model"] / CHIPS / PEAK_FLOPS
    frac = model_s / bound if bound > 0 else 0.0
    return {
        "arch": arch, "shape": shape, "mesh": dry.get("mesh", "16x16"),
        "compute_term_s": compute_s, "memory_term_s": memory_s,
        "collective_term_s": coll_s, "dominant": dominant,
        "model_flops": fl["model"], "hlo_flops_analytic": fl["total"],
        "useful_ratio": fl["model"] / fl["total"] if fl["total"] else 0.0,
        "roofline_fraction": frac,
        "live_bytes_per_dev": dry.get("per_device_live_bytes"),
        "fits_16g": (dry.get("per_device_live_bytes") or 0) < 16 * 2**30,
    }


def load_cells(out_dir: str = "results/dryrun", mesh_tag: str = "16_16"):
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh_tag}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        cells.append(rec)
    if not cells:
        raise FileNotFoundError(f"no dry-run artifacts under {out_dir}")
    return cells


def main(out_dir: str = "results/dryrun", print_table: bool = True,
         save: str = "results/roofline.json") -> List[Dict]:
    rows = []
    for rec in load_cells(out_dir):
        arch = _arch_id(rec["arch"])
        try:
            rows.append(cell_report(arch, rec["shape"], rec))
        except KeyError as exc:
            print(f"roofline,skip={arch}x{rec['shape']},err={exc}")
    if print_table:
        for r in rows:
            print(f"roofline,arch={r['arch']},shape={r['shape']},"
                  f"compute_s={r['compute_term_s']:.4f},"
                  f"memory_s={r['memory_term_s']:.4f},"
                  f"collective_s={r['collective_term_s']:.4f},"
                  f"dominant={r['dominant']},"
                  f"useful_ratio={r['useful_ratio']:.3f},"
                  f"roofline_frac={r['roofline_fraction']:.3f},"
                  f"fits_16g={r['fits_16g']}", flush=True)
    if save:
        os.makedirs(os.path.dirname(save), exist_ok=True)
        with open(save, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def validate_costmodel(arch: str = "qwen3-0.6b", layers: int = 2,
                       seq: int = 512, batch: int = 8) -> Dict:
    """Compare the analytic model against an UNROLLED single-device compile
    of a reduced config, where HloCostAnalysis counts every layer."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.models import transformer
    from benchmarks import costmodel
    from repro.launch.specs import SHAPE_GRID

    cfg = dataclasses.replace(get_config(arch), n_layers=layers,
                              scan_layers=False, remat=False)
    params = jax.eval_shape(
        lambda k: transformer.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    batch_spec = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }

    def fwd_loss(p, b):
        return transformer.loss_fn(cfg, p, b)

    compiled = jax.jit(jax.value_and_grad(fwd_loss)).lower(
        params, batch_spec).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo_flops = float(cost.get("flops", 0.0))

    # analytic: same reduced config, train kind = 3x forward
    toks = batch * seq
    lin = costmodel._layer_linear_flops_per_tok(cfg) * toks * layers
    core = costmodel._attn_score_flops(cfg, batch, seq, seq) * layers
    head = 2 * toks * cfg.d_model * cfg.vocab
    analytic = 3 * (lin + core + head)
    return {"hlo_flops": hlo_flops, "analytic_flops": analytic,
            "ratio": analytic / hlo_flops if hlo_flops else float("nan")}


if __name__ == "__main__":
    import sys
    if "--validate" in sys.argv:
        print(validate_costmodel())
    main()
