"""Table 2: zkReLU vs SC-BD proving time / proof size on 2-layer FCNNs.

Sweeps (width x batch-size) cells.  For each cell:
  * zkReLU column: the full zkDL Protocol-2 prover (commit + prove) on the
    2-layer quantized witness, proof size from the wire format.
  * SC-BD column: the general-purpose bit-decomposition sumcheck
    (`repro.core.scbd`) run on the two aux tensors (Z''^1, G_A'^1) that
    zkReLU would range-prove, one D^2 Q-table sumcheck per tensor.

Substrate note (recorded in EXPERIMENTS.md): the paper's absolute numbers
use the MCL bignum library on a 64-core CPU; this repo's substrate is the
TPU-native limb arithmetic validated on 1 CPU core, so ABSOLUTE times are
not comparable to the paper -- the deliverable is the RELATIVE zkReLU vs
SC-BD gap and its scaling, which isolates the protocol difference on a
common substrate.  Cells whose SC-BD tables exceed the memory/time budget
are reported as ">limit" exactly as the paper reports ">10^3".
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import quantfc, scbd
from repro.core.pipeline import (PipelineConfig, ProofSession, encode_proof,
                                 make_keys, verify_session)
from repro.core.quantfc import QuantConfig, train_step_witness

Q_BITS = 16
R_BITS = 8

QUICK_CELLS: List[Tuple[int, int]] = [(64, 4), (64, 16), (256, 16)]
FULL_CELLS: List[Tuple[int, int]] = [(64, 16), (64, 32), (256, 16),
                                     (256, 32), (1024, 16)]
SCBD_ELEM_LIMIT = 64 * (1 << 20)      # max D^2 Q table elements (memory)
SCBD_TIME_LIMIT = 900.0               # seconds, like the paper's 10^3 cap


def make_witness(width: int, bs: int, n_layers: int = 2, seed: int = 0):
    rng = np.random.default_rng(seed)
    qc = QuantConfig(q_bits=Q_BITS, r_bits=R_BITS)
    x = quantfc.quantize(rng.uniform(-1, 1, (bs, width)), qc)
    y = quantfc.quantize(rng.uniform(-1, 1, (bs, width)), qc)
    ws = [quantfc.quantize(rng.uniform(-1, 1, (width, width)) * 0.3, qc)
          for _ in range(n_layers)]
    return train_step_witness(x, y, ws, qc)


def run_zkrelu_cell(width: int, bs: int, verify: bool = False):
    cfg = PipelineConfig(n_layers=2, batch=bs, width=width,
                         q_bits=Q_BITS, r_bits=R_BITS, n_steps=1)
    keys = make_keys(cfg)
    wit = make_witness(width, bs)
    session = ProofSession(keys, np.random.default_rng(1))
    session.add_step(wit)
    t0 = time.perf_counter()
    proof = session.prove()
    t_prove = time.perf_counter() - t0
    ok = None
    if verify:
        ok = verify_session(keys, proof)
        assert ok, "zkReLU proof rejected"
    return {"time_s": t_prove,
            "size_kB": len(encode_proof(proof)) / 1024,
            "n_aux": 5 * 2 * bs * width, "verified": ok}


def run_scbd_cell(width: int, bs: int):
    d = bs * width
    if scbd.workload_elems(d, Q_BITS) > SCBD_ELEM_LIMIT:
        return {"time_s": float("inf"), "size_kB": float("nan"),
                "note": f">limit (D^2Q = {scbd.workload_elems(d, Q_BITS):.1e} elems)"}
    wit = make_witness(width, bs)
    zpp = wit.zpp[0].reshape(-1)          # Z''^(1)
    gap = wit.gap[0].reshape(-1)          # G_A'^(1)
    t0 = time.perf_counter()
    p1 = scbd.prove(zpp, Q_BITS, Transcript(b"scbd/zpp"))
    p2 = scbd.prove(gap, Q_BITS, Transcript(b"scbd/gap"))
    t_prove = time.perf_counter() - t0
    assert scbd.verify(p1, d, Q_BITS, Transcript(b"scbd/zpp"))
    assert scbd.verify(p2, d, Q_BITS, Transcript(b"scbd/gap"))
    return {"time_s": t_prove,
            "size_kB": (p1.size_bytes() + p2.size_bytes()) / 1024}


def main(full: bool = False, verify_smallest: bool = True):
    cells = FULL_CELLS if full else QUICK_CELLS
    rows = []
    for i, (width, bs) in enumerate(cells):
        zk = run_zkrelu_cell(width, bs, verify=(verify_smallest and i == 0))
        bd = run_scbd_cell(width, bs)
        ratio = bd["time_s"] / zk["time_s"]
        rows.append((width, bs, zk, bd, ratio))
        bd_t = ("%.2f" % bd["time_s"]) if np.isfinite(bd["time_s"]) \
            else bd.get("note", ">limit")
        print(f"table2,width={width},bs={bs},"
              f"zkrelu_s={zk['time_s']:.2f},zkrelu_kB={zk['size_kB']:.1f},"
              f"scbd_s={bd_t},scbd_kB={bd.get('size_kB', float('nan')):.1f},"
              f"ratio={ratio:.1f}", flush=True)
    return rows


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
