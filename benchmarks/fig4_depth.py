"""Figure 4: parallel (zkDL, same randomness for all layers) vs
conventional sequential (layer-by-layer, Liu et al. 2021-style) proof
generation as network depth L grows.

Parallel column: the production `pipeline.ProofSession` (T=1) -- one batched sumcheck per
step over the STACKED tensors, one validity IPA, one multi-opened IPA per
tensor; proving time ~O(DQ + log L) and size ~O(log(DQL)).

Sequential column: an explicit per-layer prover built from the SAME
primitives (sumcheck_prove / zkrelu / ipa) but with fresh randomness per
layer and no batching: each layer pays its own matmul sumchecks, Hadamard
sumchecks, validity IPA over (2 D Q)-bit tables and five aux openings.
Proof size concatenates, so it grows as O(L log(DQ)) -- exactly the
baseline ordering formalized in [1] that Fig. 4 compares against.
(The sequential path is a cost-faithful prover; its verifier is not
implemented -- component soundness is covered by the unit tests.)
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import ipa, mle, pedersen, zkrelu
from repro.core.pipeline import (PipelineConfig, ProofSession, encode_proof,
                                 make_keys)
from repro.core.pipeline.tables import dec_scalar, fix_cols, fix_rows
from repro.core.sumcheck import sumcheck_prove
from repro.core.transcript import Transcript
from repro.field import FQ, add, mont_mul, sub
from benchmarks.table2_zkrelu import Q_BITS, R_BITS, make_witness

import jax.numpy as jnp

Q_MOD = FQ.modulus


def _rand(rng) -> int:
    return int(rng.integers(0, Q_MOD, dtype=np.uint64)) % Q_MOD


def _enc_tensor(x: np.ndarray) -> jnp.ndarray:
    from repro.field import encode_i64
    return jnp.asarray(encode_i64(FQ, x.reshape(-1))).reshape(-1, 4)


class SequentialKeys:
    """Per-layer commitment/validity keys (same sizes for every layer)."""

    def __init__(self, width: int, bs: int):
        d_elem = bs * width
        self.kd = pedersen.make_key(b"seq/aux", d_elem)
        self.kw = pedersen.make_key(b"seq/w", width * width)
        self.validity = zkrelu.make_validity_keys(d_elem, Q_BITS, R_BITS)
        self.k_bq = pedersen.CommitKey(self.validity.g_col,
                                       self.validity.h_blind, b"seq/bq")
        self.d_elem = d_elem
        self.width = width
        self.bs = bs


def prove_sequential(keys: SequentialKeys, wit, rng) -> Dict:
    """Layer-by-layer proof: fresh randomness and separate proofs per layer."""
    L = wit.n_layers
    bs, width, d_elem = keys.bs, keys.width, keys.d_elem
    lb, ld = bs.bit_length() - 1, width.bit_length() - 1
    size_bytes = 0
    for l in range(L):
        t = Transcript(b"seq/layer%d" % l)
        # --- commitments for this layer's aux tensors --------------------
        zpp = wit.zpp[l].reshape(-1) if l < L - 1 else wit.zpp[-1].reshape(-1)
        has_relu = l < L - 1
        bq = wit.b[l].reshape(-1) if has_relu else np.zeros(d_elem, np.int64)
        rz = wit.rz[l].reshape(-1) if has_relu else np.zeros(d_elem, np.int64)
        gap = wit.gap[l].reshape(-1) if l < L - 1 else np.zeros(d_elem, np.int64)
        rga = wit.rga[l].reshape(-1) if l < L - 1 else np.zeros(d_elem, np.int64)
        blinds = {n: _rand(rng) for n in ("zpp", "bq", "rz", "gap", "rga", "w")}
        zpp_t, gap_t = _enc_tensor(zpp), _enc_tensor(gap)
        rz_t, rga_t = _enc_tensor(rz), _enc_tensor(rga)
        bq_t = _enc_tensor(bq)
        com = {}
        com["zpp"] = pedersen.commit(keys.kd, zpp_t, blinds["zpp"], nbits=Q_BITS)
        com["bq"] = pedersen.commit_bits(keys.k_bq, bq.astype(np.uint32),
                                         blinds["bq"])
        com["rz"] = pedersen.commit(keys.kd, rz_t, blinds["rz"], nbits=R_BITS + 1)
        com["gap"] = pedersen.commit(keys.kd, gap_t, blinds["gap"])
        com["rga"] = pedersen.commit(keys.kd, rga_t, blinds["rga"])
        size_bytes += 5 * 32
        bits = zkrelu.build_aux_bits(zpp, gap, bq, rz, rga, Q_BITS, R_BITS)
        vcoms, vblinds = zkrelu.commit_validity(keys.validity, bits, rng)
        size_bytes += 3 * 32

        # --- per-layer matmul sumchecks (eqs 30 / 33 / 34) ----------------
        u_r = t.challenge_ints(b"u_r", Q_MOD, lb)
        u_c = t.challenge_ints(b"u_c", Q_MOD, ld)
        a_tab = _enc_tensor(wit.a[l]).reshape(bs, width, 4)
        w_tab = _enc_tensor(wit.w[l]).reshape(width, width, 4)
        gz_tab = _enc_tensor(wit.gz[l]).reshape(bs, width, 4)
        fa = fix_rows(a_tab, u_r)
        fw = fix_cols(w_tab, u_c)
        sc1, _, f1 = sumcheck_prove([fa, fw], [(0, 1)], t, b"fwd")
        size_bytes += 32 * (sum(len(m) for m in sc1.messages) + len(f1))
        if l + 1 < L:
            gz2 = _enc_tensor(wit.gz[l + 1]).reshape(bs, width, 4)
            w2 = _enc_tensor(wit.w[l + 1]).reshape(width, width, 4)
            fg = fix_rows(gz2, u_r)
            fw2 = fix_rows(w2, u_c)
            sc2, _, f2 = sumcheck_prove([fg, fw2], [(0, 1)], t, b"bwd")
            size_bytes += 32 * (sum(len(m) for m in sc2.messages) + len(f2))
        u_i = t.challenge_ints(b"u_i", Q_MOD, ld)
        u_j = t.challenge_ints(b"u_j", Q_MOD, ld)
        fgw = fix_cols(gz_tab, u_i)
        fa2 = fix_cols(a_tab, u_j)
        sc3, _, f3 = sumcheck_prove([fgw, fa2], [(0, 1)], t, b"gw")
        size_bytes += 32 * (sum(len(m) for m in sc3.messages) + len(f3))

        # --- per-layer Hadamard anchor (eqs 31 / 35) ----------------------
        one_tab = jnp.broadcast_to(mle.enc(1), (d_elem, 4)).astype(jnp.uint32)
        one_b = sub(FQ, one_tab, bq_t)
        u_a = t.challenge_ints(b"u_a", Q_MOD, lb + ld)
        pa = mle.expand_point(u_a)
        sc4, u_star, f4 = sumcheck_prove([one_b, zpp_t, gap_t, pa],
                                         [(0, 3, 1), (0, 3, 2)], t, b"anchor")
        size_bytes += 32 * (sum(len(m) for m in sc4.messages) + len(f4))

        # --- per-layer validity + openings --------------------------------
        upp = t.challenge_int(b"upp", Q_MOD)
        u_relu = u_star + [upp]
        e_star = mle.expand_point(u_star)
        v_zpp = int(mle.hmul(1, dec_scalar(mle.fdot(zpp_t, e_star))))
        v_gap = dec_scalar(mle.fdot(gap_t, e_star))
        v_bq = dec_scalar(mle.fdot(bq_t, e_star))
        v_rz = dec_scalar(mle.fdot(rz_t, e_star))
        v_rga = dec_scalar(mle.fdot(rga_t, e_star))
        v = ((1 - upp) * v_zpp + upp * v_gap) % Q_MOD
        v_r = ((1 - upp) * v_rz + upp * v_rga) % Q_MOD
        t.absorb_ints(b"vclaims", [v, v_bq, v_r])
        vproof = zkrelu.prove_validity(keys.validity, bits, vblinds, u_relu,
                                       v, v_bq, v_r, blinds["bq"], t, rng)
        size_bytes += vproof.size_bytes()
        for name, tab, blind in (("zpp", zpp_t, blinds["zpp"]),
                                 ("bq", bq_t, blinds["bq"]),
                                 ("rz", rz_t, blinds["rz"]),
                                 ("gap", gap_t, blinds["gap"]),
                                 ("rga", rga_t, blinds["rga"])):
            key = keys.k_bq if name == "bq" else keys.kd
            claim = dec_scalar(mle.fdot(tab, e_star))
            pr = ipa.open_prove(key, tab, e_star, blind, claim, t, rng)
            size_bytes += pr.size_bytes()
    return {"size_kB": size_bytes / 1024}


def run_parallel(width: int, bs: int, depth: int):
    cfg = PipelineConfig(n_layers=depth, batch=bs, width=width,
                         q_bits=Q_BITS, r_bits=R_BITS, n_steps=1)
    keys = make_keys(cfg)
    wit = make_witness(width, bs, n_layers=depth)
    session = ProofSession(keys, np.random.default_rng(depth))
    session.add_step(wit)
    t0 = time.perf_counter()
    proof = session.prove()
    dt = time.perf_counter() - t0
    return dt, len(encode_proof(proof)) / 1024


def run_sequential(width: int, bs: int, depth: int):
    keys = SequentialKeys(width, bs)
    wit = make_witness(width, bs, n_layers=depth)
    rng = np.random.default_rng(depth)
    t0 = time.perf_counter()
    out = prove_sequential(keys, wit, rng)
    dt = time.perf_counter() - t0
    return dt, out["size_kB"]


def main(depths: List[int] | None = None, width: int = 64, bs: int = 4):
    depths = depths or [2, 4, 8]
    rows = []
    for L in depths:
        tp, sp = run_parallel(width, bs, L)
        ts, ss = run_sequential(width, bs, L)
        rows.append((L, tp, sp, ts, ss))
        print(f"fig4,depth={L},width={width},bs={bs},"
              f"parallel_s={tp:.2f},parallel_kB={sp:.1f},"
              f"sequential_s={ts:.2f},sequential_kB={ss:.1f},"
              f"speedup={ts / tp:.2f}", flush=True)
    return rows


if __name__ == "__main__":
    import sys
    full = "--full" in sys.argv
    main(depths=[2, 4, 8, 16] if full else None)
