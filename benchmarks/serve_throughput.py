"""Gateway throughput benchmark: proofs/sec under concurrent multi-
tenant load (PR 10 tentpole measurement).

Starts one `launch.serve.ProvingGateway` with a pool of prove workers,
registers N tenants (each with its own journal/manifest/vk directory
under ``out_dir/tenants/<name>/``), and drives each tenant from its own
client thread — the same shape as N training jobs sharing one warm
proving sidecar.  Reported throughput is end-to-end: preflight
validation, durable journal append, weighted-fair admission, proving,
atomic proof write and manifest commit, measured from the first submit
to a fully drained close.

    PYTHONPATH=src python benchmarks/serve_throughput.py \
        [--tenants 2] [--steps 8] [--window 2] [--pool 2] \
        [--width 4] [--batch 2] [--out BENCH_serve_throughput.json] \
        [--smoke]

Emits BENCH_serve_throughput.json with the per-tenant ledger and the
``totals`` block.  The acceptance invariants are checked on EVERY run,
not just asserted in CI:

* zero lost windows — every submitted full window ends COMMITTED with
  exactly ONE commit line in its tenant's manifest (nothing shed or
  dropped under a fault-free run, nothing double-committed);
* every proof verifies from the bytes on disk against the tenant's
  vk.bin;
* every tenant's journal is fully GC'd at close (durability debt paid).

``--smoke`` is the CI guard: 2 tenants x 1 window on a pool of 2, the
same invariants plus ``proofs_per_sec > 0`` and a schema check; no JSON
written.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCHEMA_KEYS = ("config", "tenants", "totals")
TOTALS_KEYS = ("windows_expected", "windows_committed", "windows_lost",
               "proofs_verified", "wall_s", "proofs_per_sec",
               "steps_per_sec", "worker_respawns")


def run_bench(n_tenants: int, steps: int, window: int, pool: int,
              width: int, batch: int, out_dir: str) -> dict:
    from repro.core.quantfc import (QuantConfig,
                                    synthetic_sgd_trajectory_widths)
    from repro.core.pipeline import build_fcnn_graph
    from repro.core.pipeline.proofio import decode_vk
    from repro.core.pipeline.verifier import verify_bytes
    from repro.launch import serve
    from repro.launch.serve import ProvingGateway

    qc = QuantConfig(q_bits=16, r_bits=4)
    widths = (width, width, width)
    graph = build_fcnn_graph(widths, batch=batch)
    label = b"zkdl/train"
    names = [f"tenant{i}" for i in range(n_tenants)]

    gw = ProvingGateway(out_dir, n_workers=pool).start()
    handles = {}
    for i, name in enumerate(names):
        handles[name] = gw.add_tenant(name, graph, qc, n_steps=window,
                                      rng_seed=100 + i, label=label,
                                      warm=(i == 0))
    trajs = {name: synthetic_sgd_trajectory_widths(
        steps, widths, batch, qc, seed=100 + i)
        for i, name in enumerate(names)}

    errors = []

    def client(name):
        try:
            for wit in trajs[name]:
                gw.submit(name, wit)
        except Exception as exc:            # surfaces in the report
            errors.append(f"{name}: {type(exc).__name__}: {exc}")

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(n,), name=f"client-{n}")
               for n in names]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    gw.close(timeout=1200)
    wall = time.perf_counter() - t0
    if errors:
        raise SystemExit(f"client submit errors: {errors}")

    expected_per_tenant = steps // window
    tenants_out = {}
    committed = lost = verified = 0
    for name in names:
        t = handles[name]
        man = serve.read_manifest(t.dir)
        counts = serve.manifest_commit_counts(t.dir)
        with open(os.path.join(t.dir, "vk.bin"), "rb") as f:
            vk = decode_vk(f.read())
        t_committed = t_lost = t_verified = 0
        for w in range(expected_per_tenant):
            if counts.get(w, 0) == 1 \
                    and man.get(w, {}).get("status") == serve.COMMITTED:
                t_committed += 1
                with open(t.proof_path(w), "rb") as f:
                    raw = f.read()
                if verify_bytes(vk, raw, label=label):
                    t_verified += 1
            else:
                t_lost += 1
        journal_left = serve.journal_steps(serve.journal_dir(t.dir))
        if journal_left:
            raise SystemExit(f"{name}: journal not GC'd at close: "
                             f"{journal_left}")
        committed += t_committed
        lost += t_lost
        verified += t_verified
        tenants_out[name] = {
            "windows_expected": expected_per_tenant,
            "windows_committed": t_committed,
            "windows_lost": t_lost,
            "proofs_verified": t_verified,
            "proof_bytes": [n for _w, _p, n, _dt in t.proofs],
            "prove_s": [round(dt, 4) for _w, _p, _n, dt in t.proofs],
            "stats": dict(t.stats),
        }

    totals = {
        "windows_expected": expected_per_tenant * n_tenants,
        "windows_committed": committed,
        "windows_lost": lost,
        "proofs_verified": verified,
        "wall_s": round(wall, 4),
        "proofs_per_sec": round(committed / wall, 4) if wall > 0 else 0.0,
        "steps_per_sec": round(committed * window / wall, 4)
        if wall > 0 else 0.0,
        "worker_respawns": gw.stats["worker_respawns"],
    }
    return {
        "config": {"n_tenants": n_tenants, "steps_per_tenant": steps,
                   "window": window, "pool": pool, "widths": list(widths),
                   "batch": batch, "q_bits": qc.q_bits,
                   "r_bits": qc.r_bits},
        "tenants": tenants_out,
        "totals": totals,
    }


def check_invariants(report: dict, smoke: bool) -> None:
    for key in SCHEMA_KEYS:
        assert key in report, f"schema: missing {key!r}"
    for key in TOTALS_KEYS:
        assert key in report["totals"], f"schema: missing totals.{key!r}"
    tot = report["totals"]
    assert tot["windows_lost"] == 0, \
        f"LOST WINDOWS: {tot['windows_lost']} (durability bug)"
    assert tot["windows_committed"] == tot["windows_expected"]
    assert tot["proofs_verified"] == tot["windows_committed"], \
        "a committed proof failed verification from bytes"
    assert report["config"]["n_tenants"] >= 2, \
        "throughput is only meaningful under concurrent tenants"
    if smoke:
        assert tot["proofs_per_sec"] > 0, "no throughput measured"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--steps", type=int, default=8,
                    help="steps submitted per tenant")
    ap.add_argument("--window", type=int, default=2,
                    help="steps aggregated per proof window")
    ap.add_argument("--pool", type=int, default=2)
    ap.add_argument("--width", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--out-dir", default=None,
                    help="gateway dir (default: a fresh temp dir)")
    ap.add_argument("--out", default=os.path.join(
        REPO_ROOT, "BENCH_serve_throughput.json"))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, assert invariants, write no JSON")
    args = ap.parse_args(argv)

    if args.smoke:
        args.steps = min(args.steps, 2 * args.window)
    out_dir = args.out_dir
    if out_dir is None:
        import tempfile
        out_dir = tempfile.mkdtemp(prefix="zkdl-gw-bench-")

    report = run_bench(args.tenants, args.steps, args.window, args.pool,
                       args.width, args.batch, out_dir)
    check_invariants(report, smoke=args.smoke)
    tot = report["totals"]
    print(f"[serve_throughput] {report['config']['n_tenants']} tenants x "
          f"{tot['windows_committed'] // report['config']['n_tenants']} "
          f"windows on pool={report['config']['pool']}: "
          f"{tot['proofs_per_sec']} proofs/s "
          f"({tot['steps_per_sec']} steps/s, wall {tot['wall_s']}s, "
          f"lost {tot['windows_lost']})")
    if args.smoke:
        print("[serve_throughput] smoke OK (no JSON written)")
        return 0
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"[serve_throughput] wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
