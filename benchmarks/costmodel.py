"""Analytic FLOP / HBM-byte model per (arch x shape) cell.

Why analytic: XLA's HloCostAnalysis counts a while-loop body ONCE, so the
scanned-layer structure (essential for 512-device compile times) makes
``compiled.cost_analysis()`` report ~1/L of the real compute.  The
roofline therefore uses this first-principles model for the compute and
memory terms, validated against an UNROLLED compile of the smallest arch
(see EXPERIMENTS.md §Roofline validation), while the collective term comes
from the partitioned HLO with explicit trip-count scaling.

All figures are GLOBAL (whole cluster); divide by chip count for
per-device terms.  bf16 compute, f32 master weights + Adam states.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig
from repro.launch.specs import SHAPE_GRID

BF16 = 2
F32 = 4


def _attn_dims(cfg: ModelConfig):
    if cfg.mla:
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        return cfg.n_heads, qk, cfg.v_head_dim
    return cfg.n_heads, cfg.head_dim, cfg.head_dim


def _layer_linear_flops_per_tok(cfg: ModelConfig) -> float:
    """Forward matmul FLOPs per token per layer (attention + FFN)."""
    d = cfg.d_model
    if cfg.family == "ssm":
        di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads
        return 2 * d * (2 * di + 2 * g * n + h) + 2 * di * d
    h, qk, dv = _attn_dims(cfg)
    if cfg.mla:
        r = cfg.kv_lora_rank
        attn = (2 * d * h * qk + 2 * d * (r + cfg.qk_rope_dim)
                + 2 * r * h * (cfg.qk_nope_dim + dv) + 2 * h * dv * d)
    else:
        kv = cfg.n_kv_heads
        attn = 2 * d * h * qk + 4 * d * kv * qk + 2 * h * dv * d
    if cfg.is_moe:
        ffn = (2 * d * cfg.n_experts                       # router
               + (cfg.top_k + cfg.n_shared_experts) * 6 * d * cfg.moe_d_ff)
    else:
        ffn = 6 * d * cfg.d_ff
    return attn + ffn


def _attn_score_flops(cfg: ModelConfig, b: int, s: int, t: int) -> float:
    """Forward QK^T + AV FLOPs for one layer, query len s vs key len t."""
    h, qk, dv = _attn_dims(cfg)
    causal = 0.5 if (cfg.causal and s == t) else 1.0
    return (2 * b * s * t * h * qk + 2 * b * s * t * h * dv) * causal


def _ssd_core_flops(cfg: ModelConfig, b: int, t: int) -> float:
    ck = cfg.ssm_chunk
    h, p, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    return 2 * b * t * h * (ck * (n + p) + 2 * n * p)


def _n_layers_eff(cfg: ModelConfig) -> int:
    if cfg.family == "encdec":
        return cfg.enc_layers + cfg.dec_layers
    if cfg.family == "hybrid":
        return cfg.n_layers + cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def flops_cell(cfg: ModelConfig, shape_name: str) -> Dict[str, float]:
    s, b, kind = SHAPE_GRID[shape_name]
    d, v = cfg.d_model, cfg.vocab
    toks = b * s
    L = _n_layers_eff(cfg)

    lin = _layer_linear_flops_per_tok(cfg) * toks * L
    if cfg.family == "ssm":
        core = _ssd_core_flops(cfg, b, s) * L
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        core = (_ssd_core_flops(cfg, b, s) * cfg.n_layers
                + _attn_score_flops(cfg, b, s, s) * n_attn)
    elif cfg.family == "encdec":
        core = (_attn_score_flops(cfg, b, s, s) * cfg.enc_layers      # enc
                + _attn_score_flops(cfg, b, s, s) * cfg.dec_layers    # self
                + _attn_score_flops(cfg, b, s, s) * cfg.dec_layers)   # cross
    else:
        core = _attn_score_flops(cfg, b, s, s) * cfg.n_layers
    head = 2 * toks * d * v

    if kind == "train":
        total = 3 * (lin + core + head)
        model = 6 * cfg.active_param_count() * toks
    elif kind == "prefill":
        total = lin + core + head
        model = 2 * cfg.active_param_count() * toks
    else:  # decode: one token against an S-long cache
        lin1 = _layer_linear_flops_per_tok(cfg) * b * L
        if cfg.family == "ssm":
            h, p, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
            core1 = 4 * b * h * p * n * cfg.n_layers
        elif cfg.family == "hybrid":
            h, p, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
            n_attn = cfg.n_layers // cfg.attn_every
            core1 = (4 * b * h * p * n * cfg.n_layers
                     + _attn_score_flops(cfg, b, 1, s) * n_attn)
        elif cfg.mla:
            # absorbed MLA decode: attention runs in the rank-r latent space
            r = cfg.kv_lora_rank
            h = cfg.n_heads
            per_layer = (2 * b * s * h * r          # latent scores
                         + 2 * b * s * h * cfg.qk_rope_dim
                         + 2 * b * s * h * r)       # latent AV
            core1 = per_layer * cfg.n_layers
        else:
            core1 = _attn_score_flops(cfg, b, 1, s) * cfg.n_layers
        total = lin1 + core1 + 2 * b * d * v
        model = 2 * cfg.active_param_count() * b
    return {"total": total, "model": model}


def bytes_cell(cfg: ModelConfig, shape_name: str) -> float:
    """Estimated global HBM traffic per step (reads + writes)."""
    s, b, kind = SHAPE_GRID[shape_name]
    d = cfg.d_model
    toks = b * s
    L = _n_layers_eff(cfg)
    p_count = cfg.param_count()

    if kind == "train":
        # fwd read + bwd read (f32 casts) + grad write/read + Adam 3r+3w f32
        param_traffic = p_count * (2 * F32 + 2 * F32 + 6 * F32)
        # activations: ~6 tensor r/w of (toks, d) per layer + remat recompute
        act_traffic = L * toks * d * BF16 * (8 if cfg.remat else 6)
        logit_traffic = toks * cfg.vocab * (BF16 + F32) * 2
        return param_traffic + act_traffic + logit_traffic
    if kind == "prefill":
        param_traffic = p_count * F32
        act_traffic = L * toks * d * BF16 * 4
        cache_traffic = _cache_bytes(cfg, b, s)
        logit_traffic = toks * cfg.vocab * BF16
        return param_traffic + act_traffic + cache_traffic + logit_traffic
    # decode: weights + full cache read dominate
    param_traffic = cfg.active_param_count() * F32
    cache_traffic = _cache_bytes(cfg, b, s)           # read the window
    return param_traffic + cache_traffic + b * d * L * BF16 * 6


def _cache_bytes(cfg: ModelConfig, b: int, s: int) -> float:
    if cfg.family == "ssm":
        return cfg.n_layers * b * (cfg.ssm_nheads * cfg.ssm_headdim
                                   * cfg.ssm_state) * F32 * 2
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        ssm = cfg.n_layers * b * (cfg.ssm_nheads * cfg.ssm_headdim
                                  * cfg.ssm_state) * F32 * 2
        kv = n_attn * b * s * cfg.n_kv_heads * cfg.head_dim * BF16 * 2
        return ssm + kv
    if cfg.mla:
        return cfg.n_layers * b * s * (cfg.kv_lora_rank + cfg.qk_rope_dim) * BF16
    n = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
    mult = 4 if cfg.family == "encdec" else 2         # + cross K/V
    return n * b * s * cfg.n_kv_heads * cfg.head_dim * BF16 * mult
