#!/usr/bin/env bash
# One-command tier-1 verify: sets PYTHONPATH, installs dev extras when the
# environment allows it (offline/sealed containers just skip the install;
# hypothesis-based tests then self-skip), and runs the tier-1 pytest
# command verbatim (see ROADMAP.md).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if ! python -c "import hypothesis" 2>/dev/null; then
    pip install -q -r requirements-dev.txt 2>/dev/null \
        || echo "[ci] dev extras unavailable (offline?); property tests will skip"
fi

python -m pytest -x -q "$@"

# benchmark-path smoke: tiny shapes, every cell must verify and the
# per-phase prover profiler must account for ~all prove time (keeps the
# aggregation benchmark AND the phase attribution from rotting between
# PRs)
python benchmarks/agg_steps.py --smoke
