#!/usr/bin/env bash
# One-command tier-1 verify: sets PYTHONPATH, installs dev extras when the
# environment allows it (offline/sealed containers just skip the install;
# hypothesis-based tests then self-skip), and runs the tier-1 pytest
# command verbatim (see ROADMAP.md).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if ! python -c "import hypothesis" 2>/dev/null; then
    pip install -q -r requirements-dev.txt 2>/dev/null \
        || echo "[ci] dev extras unavailable (offline?); property tests will skip"
fi

python -m pytest -x -q "$@"

# benchmark-path smoke: tiny shapes, every cell (T=1/2/8 + het) must
# verify, the per-phase prover profiler (incl. the openings sub-phases)
# must account for ~all prove time, and the serialized per-step proof at
# T=8 must stay STRICTLY smaller than the recorded v1 baseline
# (0.48 kB/step) — the one-IPA opening's size win is a CI invariant,
# not just a benchmark number.  Also gates the warm start (fresh
# subprocess, populated executable cache): zero cache misses, under
# 5s at T=8, and flat in T.
python benchmarks/agg_steps.py --smoke

# cross-process verify smoke: prove + serialize (proof.bin, vk.bin) in
# one process, verify in a FRESH process that imports only the verifier
# modules -- the deployment contract of the compile/prove/verify split.
SMOKE_DIR="$(mktemp -d)"
trap 'rm -rf "$SMOKE_DIR"' EXIT
python - "$SMOKE_DIR" <<'PY'
import sys

import numpy as np

from repro.util import enable_compilation_cache
enable_compilation_cache()
from repro.core.quantfc import QuantConfig, synthetic_sgd_trajectory_widths
from repro.core.pipeline import (GraphBuilder, ProofSession,
                                 compile as zk_compile, encode_proof,
                                 graph_skips, graph_widths)

out = sys.argv[1]
qc = QuantConfig(q_bits=16, r_bits=4)
graph = (GraphBuilder(batch=2).input(4)
         .dense(4).relu().dense(4).relu()
         .residual(to=1).dense(4).relu().output())
pk, vk = zk_compile(graph, qc, n_steps=2)
wits = synthetic_sgd_trajectory_widths(2, graph_widths(graph), 2, qc,
                                       seed=3, skips=graph_skips(graph))
session = ProofSession(pk, np.random.default_rng(3))
for w in wits:
    session.add_step(w)
open(f"{out}/proof.bin", "wb").write(encode_proof(session.prove()))
open(f"{out}/vk.bin", "wb").write(vk.to_bytes())
print("ci: wrote proof.bin + vk.bin")
PY
python - "$SMOKE_DIR" <<'PY'
import sys

from repro.util import enable_compilation_cache
enable_compilation_cache()
# fresh process, verifier modules only: no session, no prover state
from repro.core.pipeline.proofio import decode_vk
from repro.core.pipeline.verifier import verify_bytes

out = sys.argv[1]
vk = decode_vk(open(f"{out}/vk.bin", "rb").read())
raw = open(f"{out}/proof.bin", "rb").read()
assert verify_bytes(vk, raw), "ci: cross-process verify REJECTED"
bad = bytearray(raw)
bad[len(bad) // 2] ^= 1
assert not verify_bytes(vk, bytes(bad)), "ci: tampered proof ACCEPTED"
# legacy-version negotiation: the same bytes restamped as format v2
# (separate zkReLU validity IPAs) must reject with the migration
# message, never crash or misparse the section table
import struct
as_v2 = bytearray(raw)
as_v2[4:6] = struct.pack("<H", 2)
trace = []
assert not verify_bytes(vk, bytes(as_v2), trace=trace), \
    "ci: v2-stamped proof ACCEPTED"
assert "v2" in trace[0] and "no longer supported" in trace[0], \
    f"ci: v2 rejection lacks the migration message: {trace}"
print("ci: cross-process verify ok (accept + tamper-reject + v2-reject)")
PY

# warm prover-service gate: the service proves two windows in one
# process (the second must be steady-state: executables compiled at
# start, nothing re-traced per window), then a FRESH process with the
# now-populated executable-cache dir must come up warm — zero cache
# misses and setup in seconds, not the ~25-30s a full re-trace costs.
python - "$SMOKE_DIR" <<'PY'
import sys

from repro.core import execache
from repro.core.quantfc import QuantConfig, synthetic_sgd_trajectory_widths
from repro.core.pipeline import build_fcnn_graph
from repro.launch.serve import ProverService

out = sys.argv[1]
qc = QuantConfig(q_bits=16, r_bits=4)
widths = (4, 4, 4)
service = ProverService(build_fcnn_graph(widths, batch=2), qc, n_steps=2,
                        out_dir=f"{out}/proofs", verify=True, rng_seed=5)
service.start(warm=True)
misses_after_start = execache.stats()["misses"]
wits = synthetic_sgd_trajectory_widths(4, widths, 2, qc, seed=5)
for w in wits:
    service.submit(w)
service.close()
assert service.n_proofs == 2, f"ci: {service.n_proofs} proofs, wanted 2"
dts = [dt for _, _, _, dt in service.proofs]
s = execache.stats()
assert s["misses"] == misses_after_start, \
    f"ci: proving windows re-compiled programs after the warm start: {s}"
assert dts[1] <= 2.0, \
    f"ci: second window proved in {dts[1]:.2f}s, not steady-state"
print(f"ci: warm service ok (windows {dts[0]:.2f}s / {dts[1]:.2f}s, "
      f"warm-up {service.warm_seconds:.1f}s)")
PY
python - "$SMOKE_DIR" <<'PY'
# fresh process, populated executable cache: a restarted service must
# start warm — no re-tracing (misses == 0) and setup latency bounded
import sys

from repro.core.quantfc import QuantConfig, synthetic_sgd_trajectory_widths
from repro.core.pipeline import build_fcnn_graph
from repro.launch.serve import ProverService

out = sys.argv[1]
qc = QuantConfig(q_bits=16, r_bits=4)
widths = (4, 4, 4)
service = ProverService(build_fcnn_graph(widths, batch=2), qc, n_steps=2,
                        out_dir=f"{out}/proofs-restart", verify=True,
                        rng_seed=5)
service.start(warm=True)
assert service.warm_stats is not None and \
    service.warm_stats["misses"] == 0, \
    f"ci: restarted service re-traced programs: {service.warm_stats}"
assert service.warm_seconds <= 20.0, \
    f"ci: restarted service took {service.warm_seconds:.1f}s to warm " \
    f"(executable cache not effective)"
wits = synthetic_sgd_trajectory_widths(2, widths, 2, qc, seed=6)
for w in wits:
    service.submit(w)
service.close()
assert service.n_proofs == 1, "ci: restarted service produced no proof"
print(f"ci: warm restart ok ({service.warm_seconds:.1f}s setup, "
      f"0 executable-cache misses)")
PY

# chaos smoke: the serve CLI is SIGKILLed mid-run by an injected fault
# at the nastiest point (after a proof write, before its manifest
# commit); a rerun of the SAME command against the same out-dir must
# replay the witness journal, re-prove every uncommitted window, and
# leave a gap-free manifest with each window COMMITTED exactly once and
# verifying from disk.  This is the durability contract of
# launch/serve.py (PR 8) exercised through a real signal death.
CHAOS_DIR="$SMOKE_DIR/chaos"
set +e
ZKDL_FAULTS="commit/pre-manifest@0:kill" python -m repro.launch.serve \
    --widths 4,4,4 --batch 2 --window 2 --steps 6 \
    --q-bits 16 --r-bits 4 --out-dir "$CHAOS_DIR" --seed 5
chaos_rc=$?
set -e
if [ "$chaos_rc" -eq 0 ]; then
    echo "ci: chaos kill never fired (service exited cleanly)"; exit 1
fi
python -m repro.launch.serve \
    --widths 4,4,4 --batch 2 --window 2 --steps 6 \
    --q-bits 16 --r-bits 4 --out-dir "$CHAOS_DIR" --seed 5
python - "$CHAOS_DIR" <<'PY'
import os, sys

from repro.launch import serve
from repro.audit.membership import bind_service_dir, verify_membership, \
    prove_membership, com_to_bytes, sample_coms
from repro.core.pipeline.proofio import decode_vk
from repro.core.pipeline.verifier import verify_bytes

out = sys.argv[1]
man = serve.read_manifest(out)
counts = serve.manifest_commit_counts(out)
vk = decode_vk(open(os.path.join(out, "vk.bin"), "rb").read())
for w in range(3):
    assert man.get(w, {}).get("status") == "COMMITTED", \
        f"ci: window {w} not committed after restart: {man.get(w)}"
    assert counts[w] == 1, \
        f"ci: window {w} committed {counts[w]} times (exactly-once broken)"
    raw = open(os.path.join(out, f"proof_{w:06d}.bin"), "rb").read()
    assert verify_bytes(vk, raw, label=b"zkdl/train"), \
        f"ci: window {w} proof REJECTED after crash+restart"
assert serve.journal_steps(serve.journal_dir(out)) == [], \
    "ci: journal not GC'd after commits"
print("ci: chaos smoke ok (SIGKILL -> restart -> 3/3 windows verify, "
      "no duplicate commits, no manifest gaps)")
# bind the crash-recovered run's windows into a dataset root and audit
# a trained-on sample from the service artifacts alone — membership
# must survive the same durability story the proofs do
tree, binding = bind_service_dir(out)
assert os.path.exists(os.path.join(out, "dataset.bin"))
raw0 = open(os.path.join(out, "proof_000000.bin"), "rb").read()
q = [com_to_bytes(sample_coms(raw0)[0])]
v = verify_membership(binding, prove_membership(tree, binding, 0, q),
                      proof_bytes=raw0, vk=vk, label=b"zkdl/train")
assert v.ok and v.n_window_members == 1, \
    f"ci: service membership audit failed: {v.reason}"
assert not verify_membership(
    binding, prove_membership(tree, binding, 1, q),
    proof_bytes=raw0, vk=vk, label=b"zkdl/train").ok, \
    "ci: cross-window replay accepted by service binding"
print("ci: service dataset binding ok (root bound, member verified, "
      "cross-window replay rejected)")
PY

# multi-tenant gateway chaos smoke (PR 10): two tenants on a pool of 2;
# the first run is SIGKILLed mid-window by an injected worker kill and
# additionally eats one transient ENOSPC at a journal write (retried
# transparently under the block policy).  The rerun against the SAME
# out_dir must steal the dead owner's lockfile, replay every tenant's
# journal, and leave BOTH tenants with every window COMMITTED exactly
# once and verifying from bytes — the PR-8 durability contract enforced
# per tenant.
GW_DIR="$SMOKE_DIR/gateway"
set +e
ZKDL_FAULTS="pool/worker-kill@1:kill,storage/journal@2:enospc" \
    python -m repro.launch.serve --tenants alice:2,bob --pool 2 \
    --widths 4,4,4 --batch 2 --window 2 --steps 4 \
    --q-bits 16 --r-bits 4 --out-dir "$GW_DIR" --seed 7
gw_rc=$?
set -e
if [ "$gw_rc" -eq 0 ]; then
    echo "ci: gateway chaos kill never fired (gateway exited cleanly)"
    exit 1
fi
python -m repro.launch.serve --tenants alice:2,bob --pool 2 \
    --widths 4,4,4 --batch 2 --window 2 --steps 4 \
    --q-bits 16 --r-bits 4 --out-dir "$GW_DIR" --seed 7
python - "$GW_DIR" <<'PY'
import os, sys

from repro.launch import serve
from repro.launch.serve import dir_status
from repro.core.pipeline.proofio import decode_vk
from repro.core.pipeline.verifier import verify_bytes

out = sys.argv[1]
st = dir_status(out)
assert st["lock"] is None, f"ci: gateway lock leaked after close: {st['lock']}"
for name in ("alice", "bob"):
    d = os.path.join(out, "tenants", name)
    man = serve.read_manifest(d)
    counts = serve.manifest_commit_counts(d)
    vk = decode_vk(open(os.path.join(d, "vk.bin"), "rb").read())
    for w in range(2):
        assert man.get(w, {}).get("status") == "COMMITTED", \
            f"ci: {name} window {w} not committed: {man.get(w)}"
        assert counts[w] == 1, \
            f"ci: {name} window {w} committed {counts[w]} times"
        raw = open(os.path.join(d, f"proof_{w:06d}.bin"), "rb").read()
        assert verify_bytes(vk, raw, label=b"zkdl/train"), \
            f"ci: {name} window {w} proof REJECTED after crash+restart"
    assert serve.journal_steps(serve.journal_dir(d)) == [], \
        f"ci: {name} journal not GC'd after commits"
    assert st["tenants"][name]["commit_lines"] == 2, st["tenants"][name]
print("ci: gateway chaos smoke ok (SIGKILL + ENOSPC -> restart -> "
      "2 tenants x 2/2 windows verify, no duplicate commits)")
PY
# single ownership: while one gateway holds the out_dir lock, a second
# gateway AND a plain ProverService must be refused with the typed
# busy error (and the lock must survive the refused attempts)
python - "$GW_DIR" <<'PY'
import os, sys

from repro.core.quantfc import QuantConfig
from repro.core.pipeline import build_fcnn_graph
from repro.launch.admission import GatewayBusyError
from repro.launch.serve import ProverService, ProvingGateway

out = sys.argv[1]
gw = ProvingGateway(out, n_workers=1).start()
try:
    try:
        ProvingGateway(out).start()
        raise SystemExit("ci: second gateway was NOT refused")
    except GatewayBusyError:
        pass
    try:
        ProverService(build_fcnn_graph((4, 4, 4), batch=2),
                      QuantConfig(q_bits=16, r_bits=4), n_steps=2,
                      out_dir=out).start(warm=False)
        raise SystemExit("ci: service on a locked gateway dir NOT refused")
    except GatewayBusyError:
        pass
finally:
    gw.close(timeout=60)
assert not os.path.exists(os.path.join(out, "GATEWAY.lock"))
print("ci: gateway lockfile ok (second gateway + service refused, "
      "lock released on close)")
PY

# gateway throughput smoke: >= 2 concurrent tenants, proofs/sec > 0,
# zero lost windows, report schema intact; no JSON written
python benchmarks/serve_throughput.py --smoke

# adversarial soundness battery + membership audit (repro.audit): every
# structured forgery — spoofed SGD trajectory, cross-slot claim swaps
# inside the merged one-IPA, replay/splicing, zkReLU validity-table
# forgeries — must be REJECTED, and the data-membership audit must
# round-trip from bytes through a fresh verifier process.  The process
# exit status gates on zero accepted forgeries; the report is evidence.
python -m repro.audit run --smoke --out "$SMOKE_DIR/AUDIT_report.json" \
    --dir "$SMOKE_DIR/audit"
