"""Collection-time import smoke for the whole benchmarks/ directory:
every module must import cleanly under the post-zkdl API (stale
references to retired modules fail here, not at benchmark time)."""
import importlib
import pathlib
import sys

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_DIR = REPO_ROOT / "benchmarks"
MODULES = sorted(p.stem for p in BENCH_DIR.glob("*.py"))


@pytest.mark.parametrize("name", MODULES)
def test_benchmark_module_imports(name):
    if str(REPO_ROOT) not in sys.path:
        sys.path.insert(0, str(REPO_ROOT))
    mod = importlib.import_module(f"benchmarks.{name}")
    assert mod.__file__ and "benchmarks" in mod.__file__


def test_all_benchmarks_collected():
    # the sweep is only meaningful if it actually sees the directory
    assert "run" in MODULES and "perf" in MODULES and \
        "table3_membership" in MODULES
    assert len(MODULES) >= 9
