"""Multi-tenant chaos harness for `launch/serve.ProvingGateway` (PR 10
tentpole).

The contract under test is the PR-8 durability invariant enforced PER
TENANT, under the gateway's concurrency-era fault points: every window
that was not load-shed or storage-dropped ends with EXACTLY ONE
``COMMITTED`` manifest line in that tenant's directory, its proof bytes
verify from disk, and its journal segments are GC'd — across worker
deaths, ENOSPC at every write site, expired deadlines, tripped breakers
and full gateway restarts.  Timing-sensitive policies (fair-share
ratios, shed victim selection, half-open single-trial) are proved
deterministically in tests/test_admission.py; here they are driven
end-to-end only where the outcome is order-independent.
"""
import os
import time

import pytest

from repro.core.quantfc import QuantConfig, synthetic_sgd_trajectory_widths
from repro.core.pipeline import build_fcnn_graph
from repro.core.pipeline.proofio import decode_vk
from repro.core.pipeline.verifier import verify_bytes
from repro.launch import serve
from repro.launch.admission import GatewayBusyError, ServiceClosedError
from repro.launch.preflight import (WitnessQuantError, WitnessStepError)
from repro.launch.serve import ProverService, ProvingGateway
from repro.train.checkpoint import StorageError
from repro.train.resilience import FailureInjector, SimulatedFailure

QC = QuantConfig(q_bits=16, r_bits=4)
WIDTHS = (4, 4, 4)
B = 2
T = 2
LABEL = b"zkdl/train"
GRAPH = build_fcnn_graph(WIDTHS, batch=B)


def _gateway(out_dir, **kw):
    kw.setdefault("backoff_base", 0.01)
    kw.setdefault("backoff_cap", 0.05)
    return ProvingGateway(str(out_dir), **kw).start()


def _add(gw, name, seed, **kw):
    return gw.add_tenant(name, GRAPH, QC, n_steps=T, rng_seed=seed, **kw)


def _wits(n, seed):
    return synthetic_sgd_trajectory_widths(n, WIDTHS, B, QC, seed=seed)


def _wait(pred, timeout=600):
    t0 = time.monotonic()
    while not pred():
        if time.monotonic() - t0 > timeout:
            raise AssertionError("condition not reached before timeout")
        time.sleep(0.02)


def _assert_exactly_once(tdir, windows):
    """Per-tenant acceptance: exactly one COMMITTED line per window,
    proof verifies from bytes, journal GC'd."""
    man = serve.read_manifest(tdir)
    counts = serve.manifest_commit_counts(tdir)
    with open(os.path.join(tdir, "vk.bin"), "rb") as f:
        vk = decode_vk(f.read())
    for w in windows:
        assert man.get(w, {}).get("status") == serve.COMMITTED, \
            f"{tdir} window {w}: {man.get(w)}"
        assert counts[w] == 1, f"window {w} committed {counts[w]} times"
        with open(os.path.join(tdir, f"proof_{w:06d}.bin"), "rb") as f:
            raw = f.read()
        assert verify_bytes(vk, raw, label=LABEL), f"window {w} rejected"
    for w in windows:
        assert not any(s // T == w for s in
                       serve.journal_steps(serve.journal_dir(tdir))), \
            f"window {w} left journal segments behind"


# ---------------------------------------------------------------------------
# Baseline: two tenants, shared pool, isolated directories
# ---------------------------------------------------------------------------

def test_two_tenants_commit_exactly_once_and_verify(tmp_path):
    gw = _gateway(tmp_path, n_workers=2)
    ta = _add(gw, "alice", 11, weight=2.0)
    tb = _add(gw, "bob", 22)
    wa, wb = _wits(4, 11), _wits(4, 22)
    for i in range(4):                  # interleaved client threads' view
        gw.submit("alice", wa[i])
        gw.submit("bob", wb[i])
    gw.close(timeout=600)
    _assert_exactly_once(ta.dir, [0, 1])
    _assert_exactly_once(tb.dir, [0, 1])
    assert ta.stats["proved"] == 2 and tb.stats["proved"] == 2
    # one lock for the whole gateway dir, released on close
    assert not os.path.exists(os.path.join(str(tmp_path), "GATEWAY.lock"))
    st = gw.status()
    assert st["closed"] and st["queue"]["depth"] == 0


# ---------------------------------------------------------------------------
# Worker pool: deaths are reclaimed, jobs requeued, nothing double-commits
# ---------------------------------------------------------------------------

def test_worker_death_reclaims_job_and_respawns(tmp_path):
    """The first two dequeues kill their worker thread outright; the
    monitor must requeue the in-flight window and respawn the slot, and
    every window still commits exactly once."""
    gw = _gateway(tmp_path, n_workers=2,
                  injector=FailureInjector.from_spec("pool/worker-kill@0-1"))
    ta = _add(gw, "alice", 11)
    tb = _add(gw, "bob", 22)
    wa, wb = _wits(4, 11), _wits(4, 22)
    for i in range(4):
        gw.submit("alice", wa[i])
        gw.submit("bob", wb[i])
    _wait(lambda: ta.stats["proved"] == 2 and tb.stats["proved"] == 2)
    gw.close(timeout=600)
    assert gw.stats["worker_respawns"] == 2
    assert len(gw.status()["workers"]["events"]) == 2
    _assert_exactly_once(ta.dir, [0, 1])
    _assert_exactly_once(tb.dir, [0, 1])


def test_job_that_kills_every_worker_fails_terminally(tmp_path):
    """A poison window that reliably kills workers must stop being
    retried after max_attempts deaths — FAILED reason worker-death, and
    the pool keeps serving other work."""
    gw = _gateway(tmp_path, n_workers=1, max_attempts=2,
                  injector=FailureInjector.from_spec("pool/worker-kill@0-1"))
    ta = _add(gw, "alice", 11)
    for wit in _wits(4, 11):
        gw.submit("alice", wit)
    _wait(lambda: ta.stats["proved"] == 1
          and ta.stats["failed_windows"] == 1)
    gw.close(timeout=600)
    man = serve.read_manifest(ta.dir)
    assert man[0]["status"] == serve.FAILED
    assert man[0]["reason"] == "worker-death"
    _assert_exactly_once(ta.dir, [1])
    # the failed window's journal is retained: a restart re-proves it
    assert serve.journal_steps(serve.journal_dir(ta.dir)) == [0, 1]


# ---------------------------------------------------------------------------
# ENOSPC at every write site (satellite 1)
# ---------------------------------------------------------------------------

def test_journal_enospc_drop_window_policy(tmp_path):
    gw = _gateway(tmp_path, n_workers=1, backpressure="drop_window",
                  injector=FailureInjector.from_spec(
                      "storage/journal@0:enospc"))
    ta = _add(gw, "alice", 11)
    for wit in _wits(4, 11):
        gw.submit("alice", wit)     # never raises under drop_window
    gw.close(timeout=600)
    man = serve.read_manifest(ta.dir)
    assert man[0]["status"] == serve.DROPPED
    assert man[0]["reason"] == "storage"
    assert ta.stats["dropped_windows"] == 1
    assert ta.stats["dropped_steps"] >= 1
    assert ta.stats["storage_errors"] == 1
    _assert_exactly_once(ta.dir, [1])
    # no orphan tmp files anywhere in the tenant dir
    for root, _dirs, files in os.walk(ta.dir):
        assert not [f for f in files if ".tmp." in f], (root, files)


def test_journal_enospc_block_policy_retries_with_backoff(tmp_path):
    gw = _gateway(tmp_path, n_workers=1,
                  injector=FailureInjector.from_spec(
                      "storage/journal@0:enospc"))
    ta = _add(gw, "alice", 11)
    for wit in _wits(2, 11):
        gw.submit("alice", wit)     # first write retried transparently
    gw.close(timeout=600)
    assert ta.stats["storage_errors"] == 1
    assert ta.stats["journaled"] == 2
    _assert_exactly_once(ta.dir, [0])


def test_journal_enospc_block_policy_exhausted_raises_typed(tmp_path):
    """A disk that STAYS full surfaces the typed StorageError to the
    caller with nothing half-durable; freeing space (dropping the
    injector) lets the same step go through."""
    gw = _gateway(tmp_path, n_workers=1, max_attempts=2,
                  injector=FailureInjector.from_spec(
                      "storage/journal@*:enospc"))
    ta = _add(gw, "alice", 11)
    wits = _wits(2, 11)
    with pytest.raises(StorageError) as ei:
        gw.submit("alice", wits[0])
    assert ei.value.is_enospc
    assert ta.stats["journaled"] == 0
    assert ta.next_step == 0        # nothing advanced: resubmit is safe
    assert serve.journal_steps(serve.journal_dir(ta.dir)) == []
    gw.injector = None              # "disk freed"
    for wit in wits:
        gw.submit("alice", wit)
    gw.close(timeout=600)
    _assert_exactly_once(ta.dir, [0])


def test_proof_write_enospc_fails_window_keeps_journal(tmp_path):
    """ENOSPC at the proof write: the window FAILS (reason storage) with
    its journal retained, the next window commits, and the breaker does
    NOT count an infra failure as prover poison."""
    gw = _gateway(tmp_path, n_workers=1,
                  injector=FailureInjector.from_spec(
                      "storage/proof@0:enospc"))
    ta = _add(gw, "alice", 11)
    for wit in _wits(4, 11):
        gw.submit("alice", wit)
    gw.close(timeout=600)
    man = serve.read_manifest(ta.dir)
    assert man[0]["status"] == serve.FAILED
    assert man[0]["reason"] == "storage"
    assert ta.stats["storage_errors"] == 1
    assert ta.breaker.state == "closed"
    _assert_exactly_once(ta.dir, [1])
    assert serve.journal_steps(serve.journal_dir(ta.dir)) == [0, 1]
    # restart with space: the failed window replays and commits
    gw2 = _gateway(tmp_path, n_workers=1)
    ta2 = _add(gw2, "alice", 11)
    gw2.close(timeout=600)
    _assert_exactly_once(ta2.dir, [0, 1])


def test_manifest_enospc_never_gcs_ahead_of_commit_line(tmp_path):
    """The proof bytes land but the COMMITTED line does not: the journal
    must be retained, and the restarted gateway re-proves and commits
    EXACTLY once (not zero, not two)."""
    gw = _gateway(tmp_path, n_workers=1,
                  injector=FailureInjector.from_spec(
                      "storage/manifest@0:enospc"))
    ta = _add(gw, "alice", 11)
    for wit in _wits(2, 11):
        gw.submit("alice", wit)
    _wait(lambda: ta.stats["storage_errors"] >= 1)
    gw.close(timeout=600)
    assert ta.stats["proved"] == 0
    assert serve.manifest_commit_counts(ta.dir) == {}
    assert serve.journal_steps(serve.journal_dir(ta.dir)) == [0, 1]
    gw2 = _gateway(tmp_path, n_workers=1)
    ta2 = _add(gw2, "alice", 11)
    assert ta2.stats["replayed"] == 2
    gw2.close(timeout=600)
    _assert_exactly_once(ta2.dir, [0])


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------

def test_expired_deadline_fails_window_and_frees_worker(tmp_path):
    """deadline_s=0 expires every window at dispatch: FAILED reason
    deadline, worker reclaimed immediately (no prove attempted), breaker
    untouched (capacity, not prover health), journal retained."""
    gw = _gateway(tmp_path, n_workers=1)
    ta = _add(gw, "alice", 11, deadline_s=0.0)
    for wit in _wits(4, 11):
        gw.submit("alice", wit)
    gw.close(timeout=600)
    man = serve.read_manifest(ta.dir)
    for w in (0, 1):
        assert man[w]["status"] == serve.FAILED
        assert man[w]["reason"] == "deadline"
        assert "waited_s" in man[w]
    assert ta.stats["deadline_expired"] == 2
    assert ta.stats["failed_windows"] == 2
    assert ta.stats["proved"] == 0
    assert ta.breaker.state == "closed"
    assert serve.journal_steps(serve.journal_dir(ta.dir)) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# Circuit breaker: trip -> journal-only -> half-open trial -> recovery
# ---------------------------------------------------------------------------

def test_breaker_trips_parks_then_half_open_recovers(tmp_path):
    """Two consecutive prove failures trip the breaker: later windows
    PARK (journal-only degradation — durable, not proved) until the
    half-open trial succeeds, then everything drains.  A restart then
    re-proves the two FAILED windows from their retained journals."""
    gw = _gateway(tmp_path, n_workers=1, max_attempts=1,
                  breaker_threshold=2, breaker_reset_s=0.5,
                  injector=FailureInjector.from_spec(
                      "gateway/pre-prove@0-1"))
    ta = _add(gw, "alice", 11)
    for wit in _wits(8, 11):
        gw.submit("alice", wit)
    _wait(lambda: ta.stats["proved"] == 2)      # w2 (trial) + w3
    gw.close(timeout=600)
    man = serve.read_manifest(ta.dir)
    assert man[0]["status"] == serve.FAILED and man[0]["reason"] == "prove"
    assert man[1]["status"] == serve.FAILED and man[1]["reason"] == "prove"
    assert ta.breaker.trips == 1
    assert ta.stats["deferred"] >= 2            # parked while open
    _assert_exactly_once(ta.dir, [2, 3])
    assert serve.journal_steps(serve.journal_dir(ta.dir)) == [0, 1, 2, 3]
    gw2 = _gateway(tmp_path, n_workers=1)
    ta2 = _add(gw2, "alice", 11)
    assert ta2.stats["replayed"] == 4
    gw2.close(timeout=600)
    _assert_exactly_once(ta2.dir, [0, 1, 2, 3])


# ---------------------------------------------------------------------------
# Load-shedding accounting (policy itself is proved in test_admission)
# ---------------------------------------------------------------------------

def test_shed_window_is_terminal_and_accounted(tmp_path):
    gw = _gateway(tmp_path, n_workers=1)
    ta = _add(gw, "alice", 11)
    job = serve.WindowJob(window=5, wits=[], enqueued_t=0.0)
    gw._mark_shed(ta, job)
    assert ta.stats["shed_windows"] == 1
    assert 5 in ta.dropped
    man = serve.read_manifest(ta.dir)
    assert man[5]["status"] == serve.SHED
    assert man[5]["reason"] == "admission"
    assert ta.snapshot(0)["shed"] == 1
    gw.close(timeout=600)
    # SHED is terminal: the reopened tenant resumes after it
    gw2 = _gateway(tmp_path, n_workers=1)
    ta2 = _add(gw2, "alice", 11)
    assert ta2.next_step == 6 * T
    gw2.close(timeout=600)


# ---------------------------------------------------------------------------
# Single ownership: one lock for gateway AND service
# ---------------------------------------------------------------------------

def test_lockfile_blocks_second_gateway_and_service(tmp_path):
    gw = _gateway(tmp_path, n_workers=1)
    with pytest.raises(GatewayBusyError):
        ProvingGateway(str(tmp_path)).start()
    with pytest.raises(GatewayBusyError):
        ProverService(GRAPH, QC, n_steps=T,
                      out_dir=str(tmp_path)).start(warm=False)
    gw.close(timeout=600)
    gw2 = _gateway(tmp_path, n_workers=1)   # released on close
    gw2.close(timeout=600)


# ---------------------------------------------------------------------------
# Preflight: typed rejection BEFORE anything is journaled
# ---------------------------------------------------------------------------

def test_preflight_rejects_before_journal(tmp_path):
    import dataclasses

    gw = _gateway(tmp_path, n_workers=1)
    ta = _add(gw, "alice", 11)
    wits = _wits(2, 11)
    bad = dataclasses.replace(wits[0], cfg=QuantConfig(q_bits=8, r_bits=2))
    with pytest.raises(WitnessQuantError):
        gw.submit("alice", bad)
    with pytest.raises(WitnessStepError):
        gw.submit("alice", wits[0], step=3)     # gap vs next_step=0
    assert ta.stats["rejected"] == 2
    assert ta.stats["journaled"] == 0
    assert serve.journal_steps(serve.journal_dir(ta.dir)) == []
    for wit in wits:                            # valid work still flows
        gw.submit("alice", wit)
    gw.close(timeout=600)
    _assert_exactly_once(ta.dir, [0])


# ---------------------------------------------------------------------------
# Restart: every tenant resumes where its manifest says
# ---------------------------------------------------------------------------

def test_gateway_restart_resumes_every_tenant(tmp_path):
    gw = _gateway(tmp_path, n_workers=2)
    _add(gw, "alice", 11)
    _add(gw, "bob", 22)
    wa, wb = _wits(4, 11), _wits(4, 22)
    for wit in wa[:3]:                  # window 0 + trailing partial
        gw.submit("alice", wit)
    for wit in wb[:2]:                  # window 0 only
        gw.submit("bob", wit)
    gw.close(timeout=600)
    man_a = serve.read_manifest(os.path.join(str(tmp_path),
                                             "tenants", "alice"))
    assert man_a[1]["status"] == serve.PARTIAL
    gw2 = _gateway(tmp_path, n_workers=2)
    ta = _add(gw2, "alice", 11)
    tb = _add(gw2, "bob", 22)
    assert ta.next_step == 3 and ta.stats["replayed"] == 1
    assert tb.next_step == 2 and tb.stats["replayed"] == 0
    gw2.submit("alice", wa[3])          # completes the partial window
    for wit in wb[2:]:
        gw2.submit("bob", wit)
    gw2.close(timeout=600)
    _assert_exactly_once(ta.dir, [0, 1])
    _assert_exactly_once(tb.dir, [0, 1])


# ---------------------------------------------------------------------------
# Lifecycle edges (satellite 6, gateway side)
# ---------------------------------------------------------------------------

def test_lifecycle_close_idempotent_and_submit_after_close(tmp_path):
    gw = ProvingGateway(str(tmp_path / "never"))
    gw.close()                          # never started: clean no-op
    gw.close()                          # idempotent
    with pytest.raises(ServiceClosedError):
        gw.start()

    gw2 = _gateway(tmp_path / "real", n_workers=1)
    ta = _add(gw2, "alice", 11)
    wit = _wits(1, 11)[0]
    with pytest.raises(ValueError):
        gw2.submit("nobody", wit)
    with pytest.raises(ValueError):
        _add(gw2, "alice", 11)          # duplicate
    with pytest.raises(ValueError):
        _add(gw2, "../escape", 11)      # it becomes a directory name
    gw2.close(timeout=600)
    gw2.close(timeout=600)              # idempotent after real run
    with pytest.raises(ServiceClosedError):
        gw2.submit("alice", wit)
    with pytest.raises(ServiceClosedError):
        _add(gw2, "late", 33)
    assert ta.stats["submitted"] == 0
