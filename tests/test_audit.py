"""The repro.audit subsystem: adversarial battery, membership audits,
and the report schema CI gates on.

One smoke-scale ``run_audit`` (T=2, fresh-process round-trip included)
is shared module-wide — it IS the product path `python -m repro.audit
run --smoke` executes, so these assertions pin the CI gate's semantics,
not a parallel implementation.  Byte-format unit tests for the binding
and audit artifacts run against synthetic commitments (no proving).
"""
import json

import numpy as np
import pytest

from repro.audit import membership as mem
from repro.audit.report import run_audit, validate_report

REQUIRED_FAMILIES = {"spoofed-trajectory", "cross-slot-claim-swap",
                     "replay"}


@pytest.fixture(scope="module")
def report(tmp_path_factory):
    work = tmp_path_factory.mktemp("audit-artifacts")
    return run_audit(smoke=True, work_dir=str(work))


def test_every_attack_rejected(report):
    s = report["summary"]
    assert s["all_rejected"], [o["name"] for o in report["attacks"]
                               if not o["rejected"]]
    assert s["n_attacks"] >= 8
    for o in report["attacks"]:
        assert o["variants"], o["name"]
        assert all(v["rejected"] for v in o["variants"]), o


def test_battery_covers_required_attack_classes(report):
    names = {o["name"] for o in report["attacks"]}
    assert {"spoofed_sgd_trajectory", "cross_slot_claim_swap",
            "cross_vk_replay", "cross_window_replay",
            "proof_splice"} <= names
    assert REQUIRED_FAMILIES <= set(report["summary"]["families"])


def test_membership_roundtrip_from_bytes(report):
    m = report["membership"]
    assert m["ok"], m["reason"]
    assert m["n_members"] == 5
    assert m["n_window_members"] == 3
    assert m["n_non_members"] == 3
    # the fresh-process leg: a separate interpreter verified the same
    # artifacts from disk (vk.bin + dataset.bin + proof + audit bytes)
    assert m["cross_process"]["ran"]
    assert m["cross_process"]["ok"], m["cross_process"]["detail"]


def test_scbd_revived_on_real_transcript_tensor(report):
    sc = report["scbd"]
    assert sc["ok"]
    assert sc["tamper_rejected"]
    assert sc["d"] >= 32 and sc["d"] & (sc["d"] - 1) == 0


def test_report_schema_validates_and_serializes(report):
    validate_report(report)                      # must not raise
    rt = json.loads(json.dumps(report))
    validate_report(rt)                          # survives JSON round-trip
    assert report["ok"]


@pytest.mark.parametrize("mutate,msg", [
    (lambda r: r.update(schema="zkdl-audit-report/v0"), "schema"),
    (lambda r: r.pop("membership"), "missing key"),
    (lambda r: r["summary"].update(n_attacks=99), "n_attacks"),
    (lambda r: r["attacks"][0].update(rejected=False), "inconsistent"),
])
def test_schema_violations_raise(report, mutate, msg):
    bad = json.loads(json.dumps(report))
    mutate(bad)
    with pytest.raises(ValueError, match=msg):
        validate_report(bad)


# -- binding / audit byte formats (no proving) ------------------------------

def _synthetic_windows():
    rng = np.random.default_rng(5)
    return {w: [int(v) for v in rng.integers(1, 1 << 61, size=6,
                                             dtype=np.uint64)]
            for w in (0, 1, 3)}        # window ids need not be contiguous


def test_binding_bytes_roundtrip():
    wcoms = _synthetic_windows()
    _, binding = mem.build_binding(wcoms)
    rt = mem.DatasetBinding.from_bytes(binding.to_bytes())
    assert rt.hash_name == binding.hash_name
    assert rt.root == binding.root
    assert set(rt.windows) == {0, 1, 3}
    for w, span in binding.windows.items():
        assert (rt.windows[w].start, rt.windows[w].count,
                rt.windows[w].digest) == (span.start, span.count,
                                          span.digest)
    assert rt.n_samples == 18
    with pytest.raises(mem.AuditDecodeError):
        mem.DatasetBinding.from_bytes(binding.to_bytes()[:-1])
    with pytest.raises(mem.AuditDecodeError):
        mem.DatasetBinding.from_bytes(b"XXXX" + binding.to_bytes()[4:])


def test_dataset_level_audit_roundtrip_and_forgery_rejection():
    wcoms = _synthetic_windows()
    tree, binding = mem.build_binding(wcoms)
    members = [mem.com_to_bytes(c) for c in wcoms[1][:2]]
    rng = np.random.default_rng(77)
    outsiders = [mem.com_to_bytes(int(v))
                 for v in rng.integers(1, 1 << 61, size=2,
                                       dtype=np.uint64)]
    audit = mem.prove_membership(tree, binding, -1, members + outsiders)
    rt = mem.MembershipAudit.from_bytes(audit.to_bytes())
    assert rt.window == -1
    verdict = mem.verify_membership(binding, rt)
    assert verdict.ok
    assert [r.in_dataset for r in verdict.results] == [True, True,
                                                       False, False]
    assert all(r.in_window is None for r in verdict.results)

    # flipped answer: move a member to the excluded list
    from repro.core import merkle
    forged = mem.MembershipAudit.from_bytes(audit.to_bytes())
    h = merkle.hash_bits(members[0], binding.hash_name)
    forged.proof.included.remove(h)
    forged.proof.excluded.append(h)
    assert not mem.verify_membership(binding, forged).ok

    # wrong root
    bad_root = mem.DatasetBinding(hash_name=binding.hash_name,
                                  root=b"\x00" * len(binding.root),
                                  windows=binding.windows)
    assert not mem.verify_membership(bad_root, rt).ok


def test_window_audit_requires_matching_proof_bytes():
    wcoms = _synthetic_windows()
    tree, binding = mem.build_binding(wcoms)
    audit = mem.prove_membership(tree, binding, 0,
                                 [mem.com_to_bytes(wcoms[0][0])])
    v = mem.verify_membership(binding, audit)      # no bytes presented
    assert not v.ok and "proof bytes" in v.reason
    v = mem.verify_membership(binding, audit, proof_bytes=b"garbage")
    assert not v.ok and "undecodable" in v.reason
    with pytest.raises(ValueError, match="not in binding"):
        mem.prove_membership(tree, binding, 7, [])
    with pytest.raises(TypeError, match="bytes"):
        mem.prove_membership(tree, binding, 0, [wcoms[0][0]])
