"""Tests for MLE, sumcheck, and group/MSM primitives.

Property-based (hypothesis) variants live in test_property_based.py so
this module collects in environments without dev extras installed."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.field import FQ, FP, encode_ints, decode
from repro.core import mle, group
from repro.core.mle import enc, enc_vec, eval_mle, expand_point, fdot, fsum
from repro.core.sumcheck import sumcheck_prove, sumcheck_verify, combine_final
from repro.core.transcript import Transcript

Q = FQ.modulus
P = FP.modulus


def table_from_ints(vals):
    return jnp.asarray(encode_ints(FQ, np.array([v % Q for v in vals], dtype=object)))


def test_eval_mle_on_hypercube():
    rng = np.random.default_rng(0)
    vals = [int(x) for x in rng.integers(0, 1000, size=8)]
    t = table_from_ints(vals)
    for i in range(8):
        pt = [(i >> j) & 1 for j in range(3)]
        got = int(decode(FQ, eval_mle(t, pt))[()])
        assert got == vals[i]


def test_expand_point_matches_eval():
    rng = np.random.default_rng(1)
    vals = [int(x) for x in rng.integers(0, Q, size=16, dtype=np.int64)]
    t = table_from_ints(vals)
    pt = [int(rng.integers(0, Q, dtype=np.int64)) for _ in range(4)]
    direct = int(decode(FQ, eval_mle(t, pt))[()])
    e = expand_point(pt)
    via_dot = int(decode(FQ, fdot(t, e))[()])
    assert direct == via_dot
    # partition of unity
    s = int(decode(FQ, fsum(e))[()])
    assert s == 1


def test_hexpand_matches_device():
    rng = np.random.default_rng(5)
    pt = [int(rng.integers(0, Q, dtype=np.int64)) for _ in range(3)]
    host = mle.hexpand_point(pt)
    dev = [int(v) for v in decode(FQ, expand_point(pt))]
    assert host == dev


@pytest.mark.parametrize("arity,d", [(1, 3), (2, 4), (3, 3)])
def test_sumcheck_roundtrip(arity, d):
    rng = np.random.default_rng(arity * 10 + d)
    n = 1 << d
    tables = [table_from_ints([int(x) for x in rng.integers(0, Q, size=n, dtype=np.int64)])
              for _ in range(arity)]
    products = [tuple(range(arity))]
    claim = 0
    hv = [[int(v) for v in decode(FQ, t)] for t in tables]
    for i in range(n):
        term = 1
        for k in range(arity):
            term = term * hv[k][i] % Q
        claim = (claim + term) % Q
    tp = Transcript(b"t")
    proof, point, finals = sumcheck_prove(tables, products, tp, b"sc")
    tv = Transcript(b"t")
    vpoint, expected = sumcheck_verify(claim, proof, arity, d, tv, b"sc")
    assert vpoint == point
    assert expected == combine_final(products, finals)
    # final values really are MLE evals at the point
    for k in range(arity):
        assert finals[k] == int(decode(FQ, eval_mle(tables[k], point))[()])


def test_sumcheck_rejects_bad_claim():
    rng = np.random.default_rng(9)
    n = 8
    t = table_from_ints([int(x) for x in rng.integers(0, Q, size=n, dtype=np.int64)])
    tp = Transcript(b"t")
    proof, _, _ = sumcheck_prove([t], [(0,)], tp, b"sc")
    tv = Transcript(b"t")
    with pytest.raises(ValueError):
        sumcheck_verify(12345, proof, 1, 3, tv, b"sc")


def test_sumcheck_two_products_shared_table():
    rng = np.random.default_rng(11)
    n = 16
    tabs = [table_from_ints([int(x) for x in rng.integers(0, Q, size=n, dtype=np.int64)])
            for _ in range(3)]
    products = [(0, 1), (0, 2, 2)]
    hv = [[int(v) for v in decode(FQ, t)] for t in tabs]
    claim = 0
    for i in range(n):
        claim = (claim + hv[0][i] * hv[1][i] + hv[0][i] * hv[2][i] * hv[2][i]) % Q
    tp, tv = Transcript(b"x"), Transcript(b"x")
    proof, point, finals = sumcheck_prove(tabs, products, tp, b"s")
    _, expected = sumcheck_verify(claim, proof, 3, 4, tv, b"s")
    assert expected == combine_final(products, finals)


# ---------------------------------------------------------------------------
# Group / MSM
# ---------------------------------------------------------------------------

def test_group_pow_int():
    g = group.group_gen()
    x = group.decode_group(group.g_pow_int(g, 5))
    assert x == pow(4, 5, P)
    assert group.decode_group(group.g_pow_int(g, 0)) == 1
    assert group.decode_group(group.g_pow_int(g, Q)) == 1  # order q subgroup


def test_g_pow_vectorized():
    gens = group.derive_generators(b"t1", 6)
    exps = [3, 0, 1, Q - 1, 12345, 2**60]
    out = group.g_pow(gens, group.exps_from_ints(exps))
    for i, e in enumerate(exps):
        base = group.decode_group(gens[i])
        assert group.decode_group(out[i]) == pow(base, e % Q, P)


@pytest.mark.parametrize("n,nbits", [(1, 61), (7, 61), (32, 61), (100, 16)])
def test_msm_matches_naive(n, nbits):
    rng = np.random.default_rng(n)
    gens = group.derive_generators(b"t2", n)
    exps = [int(rng.integers(0, 1 << min(nbits, 60), dtype=np.int64)) for _ in range(n)]
    got = group.decode_group(group.msm(gens, group.exps_from_ints(exps), nbits=nbits))
    expect = 1
    for i, e in enumerate(exps):
        expect = expect * pow(group.decode_group(gens[i]), e, P) % P
    assert got == expect


def test_msm_bits():
    rng = np.random.default_rng(3)
    n = 37
    gens = group.derive_generators(b"t3", n)
    bits = rng.integers(0, 2, size=n)
    got = group.decode_group(group.msm_bits(gens, jnp.asarray(bits.astype(np.uint32))))
    expect = 1
    for i in range(n):
        if bits[i]:
            expect = expect * group.decode_group(gens[i]) % P
    assert got == expect
