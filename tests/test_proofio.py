"""Byte-format contract tests: canonical encoding goldens, roundtrip
equality, per-section tamper rejection, vk serialization, and the
acceptance path — a residual MLP built with `GraphBuilder` whose proof
verifies FROM SERIALIZED BYTES in a separate process."""
import hashlib
import os
import struct
import subprocess
import sys

import numpy as np
import pytest

from repro.core.quantfc import (QuantConfig, synthetic_sgd_trajectory,
                                synthetic_sgd_trajectory_widths)
from repro.core.pipeline import (GraphBuilder, ProofSession, VerifyingKey,
                                 compile as zk_compile, decode_proof,
                                 encode_proof, graph_skips, graph_widths,
                                 prove_session, verify_bytes)
from repro.core.pipeline.proofio import (MAGIC_PROOF, ProofDecodeError,
                                         _SECTIONS)

QC = QuantConfig(q_bits=16, r_bits=4)


def _make_uniform(T):
    graph = GraphBuilder(batch=2).input(4).dense(4).relu() \
        .dense(4).relu().output()
    pk, vk = zk_compile(graph, QC, n_steps=T)
    wits = synthetic_sgd_trajectory(T, 2, 2, 4, QC, seed=7)
    return pk, vk, prove_session(pk, wits, np.random.default_rng(7))


@pytest.fixture(scope="module")
def uniform_t2():
    return _make_uniform(2)


# recorded canonical v3 encodings of the seed-7 uniform trajectory (the
# same proofs whose scalar digests are pinned in test_proof_session.py);
# any byte-format or transcript change must re-record BOTH goldens
GOLDEN_SHA256 = {
    1: "a538160f1da619bd39439420f78d24af9089dd1eacd770f3ce24d76dd80c2422",
    2: "17e8be25e9320abb55694a27615bf0093a7c0c08e290f2e11856a8d4f09b08f6",
}


@pytest.mark.parametrize("T", [1, 2])
def test_golden_serialized_bytes(T):
    _, _, proof = _make_uniform(T)
    raw = encode_proof(proof)
    assert hashlib.sha256(raw).hexdigest() == GOLDEN_SHA256[T]


def test_roundtrip_identity(uniform_t2):
    _, vk, proof = uniform_t2
    raw = encode_proof(proof)
    decoded = decode_proof(raw)
    assert decoded == proof
    assert encode_proof(decoded) == raw          # canonical: re-encode fixed
    assert verify_bytes(vk, raw)


def _section_spans(raw):
    """(name, payload_start, payload_len) for each framed section."""
    assert raw[:4] == MAGIC_PROOF
    pos, spans = 6, []
    for name in _SECTIONS:
        tag = raw[pos]
        (length,) = struct.unpack("<I", raw[pos + 1: pos + 5])
        assert tag == len(spans) + 1
        spans.append((name, pos + 5, length))
        pos += 5 + length
    assert pos == len(raw)
    return spans


def test_tamper_each_section_rejects(uniform_t2):
    """Flipping ONE byte in EVERY section must reject (either a framing
    error or a diverged transcript) — no byte of the wire format is
    slack."""
    _, vk, proof = uniform_t2
    raw = encode_proof(proof)
    for name, start, length in _section_spans(raw):
        assert length > 0, name
        bad = bytearray(raw)
        bad[start + length // 2] ^= 1
        assert not verify_bytes(vk, bytes(bad)), f"tampered {name} accepted"


def test_malformed_streams_reject(uniform_t2):
    _, vk, proof = uniform_t2
    raw = encode_proof(proof)
    assert not verify_bytes(vk, b"")                        # empty
    assert not verify_bytes(vk, b"JUNK" + raw[4:])          # bad magic
    assert not verify_bytes(vk, raw[:-3])                   # truncated
    assert not verify_bytes(vk, raw + b"\x00")              # trailing
    wrong_ver = bytearray(raw)
    wrong_ver[4] = 99
    assert not verify_bytes(vk, bytes(wrong_ver))           # version
    with pytest.raises(ProofDecodeError):
        decode_proof(raw[:-3])


def test_version_negotiation_rejects_v1_with_migration_hint(uniform_t2):
    """v1 streams (per-slot IPA dict, old key layout) must reject with a
    message naming the migration — not a generic 'unsupported' and never
    a crash from misparsing the old IPAS section layout."""
    _, vk, proof = uniform_t2
    as_v1 = bytearray(encode_proof(proof))
    as_v1[4:6] = struct.pack("<H", 1)
    with pytest.raises(ProofDecodeError, match="v1.*no longer supported"):
        decode_proof(bytes(as_v1))
    trace = []
    assert not verify_bytes(vk, bytes(as_v1), trace=trace)
    assert "v1" in trace[0]

    vk_v1 = bytearray(vk.to_bytes())
    vk_v1[4:6] = struct.pack("<H", 1)
    with pytest.raises(ProofDecodeError, match="v1"):
        VerifyingKey.from_bytes(bytes(vk_v1))

    # v2 streams (separate zkReLU validity IPAs, 7-section layout) reject
    # with their own migration message pointing at the v3 merged fold
    as_v2 = bytearray(encode_proof(proof))
    as_v2[4:6] = struct.pack("<H", 2)
    with pytest.raises(ProofDecodeError, match="v2.*no longer supported"):
        decode_proof(bytes(as_v2))
    trace = []
    assert not verify_bytes(vk, bytes(as_v2), trace=trace)
    assert "v2" in trace[0]

    for future in (4, 250):
        fut = bytearray(encode_proof(proof))
        fut[4:6] = struct.pack("<H", future)
        with pytest.raises(ProofDecodeError, match="unsupported"):
            decode_proof(bytes(fut))


def test_single_ipa_section_tamper_rejects(uniform_t2):
    """Per-element tamper inside the one-IPA section: every L/R element
    and every sigma scalar of the aggregated opening is load-bearing."""
    _, vk, proof = uniform_t2
    raw = encode_proof(proof)
    name, start, length = _section_spans(raw)[5]
    assert name == "IPA"
    n_rounds = len(proof.ipa_agg.ls)
    # u16 round count | ls | rs | u8 sigma count | sigma
    assert length == 2 + 8 * 2 * n_rounds + 1 + 8 * len(proof.ipa_agg.sigma)
    for off in (0,                       # round-count framing
                2,                       # first L
                2 + 8 * n_rounds,        # first R
                2 + 8 * 2 * n_rounds + 1,        # sigma K
                length - 8):             # last sigma scalar
        bad = bytearray(raw)
        bad[start + off] ^= 1
        assert not verify_bytes(vk, bytes(bad)), f"IPA tamper at {off}"


def test_renamed_slot_rejects_without_crash(uniform_t2):
    """A well-framed forgery renaming a commitment slot (dict order —
    and hence the transcript — unchanged) must REJECT via the schema
    check, never crash the verifier with an attribute error."""
    _, vk, proof = uniform_t2
    forged = decode_proof(encode_proof(proof))
    forged.coms.slots = {("zqq" if k == "zpp" else k): v
                         for k, v in forged.coms.slots.items()}
    trace = []
    assert not verify_bytes(vk, encode_proof(forged), trace=trace)
    assert trace == ["commitment-schema"]


def test_invalid_geometry_vk_rejects_as_decode_error():
    """A well-framed vk whose graph fails config derivation (1 layer)
    must raise ProofDecodeError, not leak an AssertionError."""
    from repro.core.pipeline import LayerOp
    from repro.core.pipeline.proofio import encode_vk

    nodes = (LayerOp("x", "input", (), (2, 4)),
             LayerOp("mm1", "qmatmul", ("x",), (2, 4), layer=1),
             LayerOp("act1", "zkrelu", ("mm1",), (2, 4), layer=1),
             LayerOp("loss", "output_grad", ("act1",), (2, 4), layer=1))

    class _FakeVK:
        class cfg:
            q_bits, r_bits, n_steps = 16, 4, 1

            class graph:
                pass
    _FakeVK.cfg.graph.nodes = nodes
    with pytest.raises(ProofDecodeError, match="invalid graph"):
        VerifyingKey.from_bytes(encode_vk(_FakeVK))


def test_nested_residual_skip_map_raises():
    """Nested residual_add is valid IR, but quantfc's emitter supports
    single-level skips only — graph_skips must refuse loudly instead of
    silently dropping the inner branch."""
    graph = (GraphBuilder(batch=2).input(4)
             .dense(4).relu().dense(4).relu().residual(to=1)
             .dense(4).relu().residual(to="res1")
             .dense(4).relu().output())
    with pytest.raises(ValueError, match="single-level"):
        graph_skips(graph)


def test_vk_roundtrip(uniform_t2):
    _, vk, proof = uniform_t2
    blob = vk.to_bytes()
    assert len(blob) < 1024                      # graph + geometry only
    vk2 = VerifyingKey.from_bytes(blob)
    assert vk2.cfg == vk.cfg
    assert vk2.to_bytes() == blob
    assert verify_bytes(vk2, encode_proof(proof))
    with pytest.raises(ProofDecodeError):
        VerifyingKey.from_bytes(blob[:-2])


# ---------------------------------------------------------------------------
# Acceptance: residual MLP via GraphBuilder -> serialized bytes -> a
# SEPARATE process (importing only the verifier modules) accepts, and
# rejects a tampered byte.
# ---------------------------------------------------------------------------

_VERIFY_SCRIPT = r"""
import sys
from repro.util import enable_compilation_cache
enable_compilation_cache()
from repro.core.pipeline.proofio import decode_vk
from repro.core.pipeline.verifier import verify_bytes

vk = decode_vk(open(sys.argv[1], "rb").read())
raw = open(sys.argv[2], "rb").read()
ok = verify_bytes(vk, raw)
bad = bytearray(raw)
bad[len(bad) // 2] ^= 1
rej = not verify_bytes(vk, bytes(bad))
print("CROSS_PROCESS_" + ("OK" if (ok and rej) else
                          f"FAIL ok={ok} tamper_rejected={rej}"))
"""


def test_residual_mlp_cross_process_verify(tmp_path):
    graph = (GraphBuilder(batch=2).input(4)
             .dense(4).relu().dense(4).relu()
             .residual(to=1)
             .dense(4).relu()
             .output())
    assert graph_skips(graph) == {3: 1}
    pk, vk = zk_compile(graph, QC, n_steps=2)
    wits = synthetic_sgd_trajectory_widths(
        2, graph_widths(graph), 2, QC, seed=21, skips=graph_skips(graph))
    session = ProofSession(pk, np.random.default_rng(21))
    for w in wits:
        session.add_step(w)
    raw = encode_proof(session.prove())

    vk_path, pf_path = tmp_path / "vk.bin", tmp_path / "proof.bin"
    vk_path.write_bytes(vk.to_bytes())
    pf_path.write_bytes(raw)

    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", _VERIFY_SCRIPT, str(vk_path), str(pf_path)],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "CROSS_PROCESS_OK" in proc.stdout, (
        f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-2000:]}")
