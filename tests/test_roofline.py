"""Roofline machinery: cost-model validation against an unrolled compile,
and the trip-count-scaled collective parser."""
import numpy as np
import pytest

from repro.launch.dryrun import collective_bytes, collective_bytes_scaled


def test_costmodel_matches_unrolled_hlo():
    """Analytic FLOPs within 5% of HloCostAnalysis on an UNROLLED reduced
    config (the scanned form under-reports by ~1/L, which is the whole
    reason the analytic model exists -- costmodel.py docstring)."""
    from benchmarks.roofline import validate_costmodel
    rec = validate_costmodel(layers=2, seq=256, batch=4)
    assert 0.95 < rec["ratio"] < 1.05, rec


FAKE_HLO = """\
HloModule test

%inner_body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %ar.1 = f32[8,128]{1,0} all-reduce(f32[8,128]{1,0} %x), replica_groups={}
  ROOT %t = (s32[], f32[8,128]) tuple(%i, %ar.1)
}

%inner_cond (p: (s32[], f32[8,128])) -> pred[] {
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %ag.2 = f32[16,128]{1,0} all-gather(f32[8,128]{1,0} %a), dimensions={0}
  %w = (s32[], f32[8,128]) while(%init), condition=%inner_cond, body=%inner_body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[8,128] get-tuple-element(%w), index=1
}
"""


def test_collective_bytes_unscaled():
    total, kinds = collective_bytes(FAKE_HLO)
    assert kinds["all-reduce"] == 8 * 128 * 4
    assert kinds["all-gather"] == 16 * 128 * 4
    assert total == kinds["all-reduce"] + kinds["all-gather"]


def test_collective_bytes_scaled_multiplies_while_body():
    total, kinds = collective_bytes_scaled(FAKE_HLO)
    assert kinds["all-reduce"] == 12 * 8 * 128 * 4      # x trip count
    assert kinds["all-gather"] == 16 * 128 * 4          # entry: x1


def test_scaled_handles_missing_trip_count():
    hlo = FAKE_HLO.replace(', backend_config={"known_trip_count":{"n":"12"}}',
                           "")
    total, kinds = collective_bytes_scaled(hlo)
    assert kinds["all-reduce"] == 8 * 128 * 4           # conservative x1


def test_roofline_reports_from_artifacts():
    import glob
    if not glob.glob("results/dryrun/*__16_16.json"):
        pytest.skip("no dry-run artifacts in this checkout")
    from benchmarks import roofline
    rows = roofline.main(print_table=False, save=None)
    assert len(rows) >= 30
    for r in rows:
        assert r["dominant"] in ("compute", "memory", "collective")
        assert r["compute_term_s"] >= 0
        # useful-compute ratio is meaningful (documented MoE overcount
        # tolerance: active-param accounting vs analytic MLA flops)
        assert 0 < r["useful_ratio"] < 1.25
