"""Value-level parity pins for the direct-sum one-IPA opening.

The aggregated argument is only sound if three exact identities hold:
every per-tensor combined claim is a TRUE inner product of its witness
block, the aggregated claim is exactly the rho-weighted sum of the
per-block claims against the rho-scaled direct-sum basis, and the
homomorphic product of the published commitments equals a Pedersen
commitment to the concatenated witness under the unified key with the
summed blind.  These tests replay the prover pipeline up to the
aggregation boundary and check all three on real session state.
"""
import numpy as np
import pytest

from repro.field import FQ
from repro.core import group, pedersen
from repro.core.mle import fdot
from repro.core.quantfc import QuantConfig, synthetic_sgd_trajectory
from repro.core.transcript import Transcript
from repro.core.pipeline import PipelineConfig, make_keys
from repro.core.pipeline import anchor as anchor_mod
from repro.core.pipeline import matmul as matmul_mod
from repro.core.pipeline import openings as openings_mod
from repro.core.pipeline.challenges import ChallengeSchedule
from repro.core.pipeline.session import SessionProver
from repro.core.pipeline.tables import dec_scalar
from repro.core.pipeline.witness import stack_witnesses

Q = FQ.modulus

CFG = PipelineConfig(n_layers=2, batch=2, width=4, q_bits=16, r_bits=4,
                     n_steps=2)
QC = QuantConfig(q_bits=CFG.q_bits, r_bits=CFG.r_bits)


@pytest.fixture(scope="module")
def keys():
    return make_keys(CFG)


@pytest.fixture(scope="module")
def prover_state(keys):
    """Session state replayed to the aggregation boundary: the block
    table, the transcript positioned at the rho/agg draw, and the
    commitments."""
    wits = synthetic_sgd_trajectory(CFG.n_steps, CFG.n_layers, CFG.batch,
                                    CFG.width, QC, seed=51)
    sw = stack_witnesses(wits, CFG)
    prover = SessionProver(keys, np.random.default_rng(51))
    coms = prover.commit(sw)
    t = Transcript(b"zkdl")
    t.absorb_ints(b"coms", coms.as_ints())
    ch = ChallengeSchedule.draw(t, CFG)
    op = {}
    e_pi1, e_pi2, e_pi3 = openings_mod.initial_claims(
        CFG, prover.tabs, ch, op, t)
    mat = matmul_mod.prove(CFG, prover.tabs, ch, t)
    anc = anchor_mod.prove(CFG, prover.tabs, ch, mat, t)
    blocks, _ = openings_mod.prover_blocks(
        CFG, prover.tabs, prover.blinds, prover.x_blinds, ch, mat, anc,
        op, e_pi1, e_pi2, e_pi3, t)
    return prover, coms, blocks, t


def test_layout_blocks_are_disjoint_slices_of_the_unified_key(keys):
    """Offsets tile without overlap, lengths match the stacked
    commitment sizes, and each slot's commitment key IS its slice of the
    unified basis (so the direct-sum commitment algebra is exact)."""
    blocks = CFG.agg_blocks
    expect_off = 0
    for name, off, n in blocks:
        assert off == expect_off, name
        assert n & (n - 1) == 0, name
        expect_off += n
    assert CFG.agg_len >= expect_off
    assert CFG.agg_len & (CFG.agg_len - 1) == 0
    off_of = {name: (off, n) for name, off, n in blocks}
    for spec in CFG.graph.commit_slots:
        off, n = off_of[spec.name]
        key = keys.slot_keys[spec.name]
        assert key.n == n == CFG.slot_stack_len(spec)
        np.testing.assert_array_equal(
            np.asarray(key.gens), np.asarray(keys.k_agg.gens[off:off + n]))
        # shared blinding generator: the per-slot blinds must sum
        np.testing.assert_array_equal(np.asarray(key.h),
                                      np.asarray(keys.k_agg.h))
    # the two data-fold blocks share the per-sample basis (their claims
    # are additionally pinned by the bucket sumcheck finals)
    for tag in ("x1", "x2"):
        off, n = off_of[tag]
        np.testing.assert_array_equal(
            np.asarray(keys.kx.gens),
            np.asarray(keys.k_agg.gens[off:off + n]))
    # every FRESH slot slice is pairwise distinct from every other block
    names = [b[0] for b in blocks]
    gens = {name: np.asarray(keys.k_agg.gens[off:off + n])
            for name, off, n in blocks}
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            if (a, b) == ("x1", "x2"):
                continue
            m = min(len(gens[a]), len(gens[b]))
            assert not (gens[a][:m] == gens[b][:m]).all(), (a, b)


def test_merged_key_extends_unified_key_with_validity_slices(keys):
    """The v3 merged basis: G = agg gens ++ the zkReLU main/remainder
    bases at the `validity_blocks` offsets ++ fresh padding; H mirrors it
    with a fresh `h_open` head.  Every slice must be exactly the basis
    the standalone statements commit under, and the bq slot generators
    must be DISJOINT from the zkReLU column basis (repeated generators
    across merged slices would break binding)."""
    vk = keys.validity
    (mname, moff, mn), (rname, roff, rn) = CFG.validity_blocks
    assert (mname, rname) == ("vmain", "vrem")
    assert moff == CFG.agg_len and roff == moff + mn
    assert mn == np.asarray(vk.g_big).shape[0]
    assert rn == np.asarray(vk.g_r).shape[0]
    vtail = roff + rn
    assert CFG.merged_len >= vtail
    assert CFG.merged_len & (CFG.merged_len - 1) == 0
    assert np.asarray(keys.g_merged).shape[0] == CFG.merged_len
    assert np.asarray(keys.h_merged).shape[0] == CFG.merged_len
    assert np.asarray(keys.h_open).shape[0] == CFG.agg_len

    np.testing.assert_array_equal(np.asarray(keys.g_merged[:CFG.agg_len]),
                                  np.asarray(keys.k_agg.gens))
    np.testing.assert_array_equal(np.asarray(keys.g_merged[moff:moff + mn]),
                                  np.asarray(vk.g_big))
    np.testing.assert_array_equal(np.asarray(keys.g_merged[roff:roff + rn]),
                                  np.asarray(vk.g_r))
    np.testing.assert_array_equal(np.asarray(keys.h_merged[:CFG.agg_len]),
                                  np.asarray(keys.h_open))
    np.testing.assert_array_equal(np.asarray(keys.h_merged[moff:moff + mn]),
                                  np.asarray(vk.h_big))
    np.testing.assert_array_equal(np.asarray(keys.h_merged[roff:roff + rn]),
                                  np.asarray(vk.h_r))

    # h_open is fresh: no element reappears in the validity H slices
    ho = {tuple(row) for row in np.asarray(keys.h_open).tolist()}
    for basis in (vk.h_big, vk.h_r):
        assert not ho & {tuple(r) for r in np.asarray(basis).tolist()}
    # bq slot generators are fresh, NOT spliced from the zkReLU column
    # basis (g_col is a sub-basis of g_big, which sits in the vmain
    # slice of the merged key)
    bq_gens = {tuple(r)
               for r in np.asarray(keys.slot_keys["bq"].gens).tolist()}
    col = {tuple(r) for r in np.asarray(vk.g_col).tolist()}
    assert not bq_gens & col
    big = {tuple(r) for r in np.asarray(vk.g_big).tolist()}
    assert not bq_gens & big


def test_block_claims_are_true_inner_products(prover_state):
    """Each per-tensor combined claim equals <witness block, combined
    basis> — the per-slot rho folds preserve values exactly."""
    _, _, blocks, _ = prover_state
    for name, _, n in CFG.agg_blocks:
        blk = blocks[name]
        assert blk.table.shape[0] == n, name
        assert blk.basis.shape[0] == n, name
        assert dec_scalar(fdot(blk.table, blk.basis)) == blk.claim, name


def test_aggregated_claim_is_rho_weighted_sum(prover_state):
    """The direct-sum statement: claim_agg == sum_k rho^k v_k, and the
    concatenated witness against the rho-scaled concatenated basis
    evaluates to exactly that claim."""
    _, _, blocks, t = prover_state
    b_agg, claim_agg, rho = openings_mod.direct_sum(CFG, t, blocks)
    want, rpow = 0, 1
    for name, _, _ in CFG.agg_blocks:
        want = (want + rpow * blocks[name].claim) % Q
        rpow = rpow * rho % Q
    assert claim_agg == want
    a_agg = openings_mod.stacked_witness(CFG, blocks)
    assert a_agg.shape[0] == b_agg.shape[0] == CFG.agg_len
    assert dec_scalar(fdot(a_agg, b_agg)) == claim_agg


def test_homomorphic_commitment_matches_direct_sum_commitment(
        keys, prover_state):
    """Product of the published per-block commitments == Pedersen
    commitment of the concatenated witness under the unified key with
    the summed blinds — the identity the verifier's single IPA check
    rests on."""
    prover, coms, blocks, _ = prover_state
    a_agg = openings_mod.stacked_witness(CFG, blocks)
    blind_agg = sum(blk.blind for blk in blocks.values()) % Q
    direct = pedersen.commit(keys.k_agg, a_agg, blind_agg)

    acc = None
    for name, _, _ in CFG.agg_blocks:
        blk = blocks[name]
        if name in ("x1", "x2"):
            # the data blocks' commitments are what the verifier's MSM
            # over the per-sample commitments folds to: commit the
            # folded table directly (same element by homomorphism)
            el = pedersen.commit(keys.kx, blk.table, blk.blind)
        else:
            el = group.encode_group(coms.slots[name])
        acc = el if acc is None else group.g_mul(acc, el)
    assert group.decode_group(acc) == group.decode_group(direct)


def test_cross_slot_claim_swap_rejects():
    """The adversarial attack the PR 5 soundness argument invites
    (ROADMAP): the direct-sum one-IPA is only sound because every slot
    opens against its OWN disjoint generator slice of the unified key.
    A forger who swaps two slots' commitment vectors (rz <-> rga) — and,
    in the stronger variant, relocates the claimed openings with them
    (a3 <-> a5, a7 <-> a8) so each claim still 'matches' its commitment —
    must be rejected by the merged one-IPA verify: claims cannot be
    moved between slots even self-consistently."""
    from repro.core.pipeline import (GraphBuilder, compile as zk_compile,
                                     decode_proof, encode_proof,
                                     prove_session, verify_bytes)

    graph = GraphBuilder(batch=2).input(4).dense(4).relu() \
        .dense(4).relu().output()
    pk, vk = zk_compile(graph, QC, n_steps=1)
    wits = synthetic_sgd_trajectory(1, 2, 2, 4, QC, seed=7)
    raw = encode_proof(prove_session(pk, wits, np.random.default_rng(7)))
    assert verify_bytes(vk, raw)

    # variant 1: swap only the commitment vectors (key order — and hence
    # the transcript framing — unchanged; values relocated)
    forged = decode_proof(raw)
    slots = dict(forged.coms.slots)
    slots["rz"], slots["rga"] = slots["rga"], slots["rz"]
    forged.coms.slots = slots
    assert not verify_bytes(vk, encode_proof(forged)), \
        "commitment-swapped proof accepted"

    # variant 2: move the claimed openings along with the commitments —
    # the self-consistent forgery the disjoint slices must still kill
    forged = decode_proof(raw)
    slots = dict(forged.coms.slots)
    slots["rz"], slots["rga"] = slots["rga"], slots["rz"]
    forged.coms.slots = slots
    op = forged.openings
    op["a3"], op["a5"] = op["a5"], op["a3"]
    op["a7"], op["a8"] = op["a8"], op["a7"]
    assert not verify_bytes(vk, encode_proof(forged)), \
        "claim-relocated cross-slot swap accepted"
