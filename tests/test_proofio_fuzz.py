"""Decode fuzzing for the v3 byte formats (PR 8 satellite).

The deployment contract of `proofio` + `verify_bytes` is: ANY byte
stream — random mutations, truncations, garbage — either decodes to a
structurally valid object or raises `ProofDecodeError`; the verifier
then returns a clean accept/reject bool.  No input may crash with
`IndexError` / `AssertionError` / `struct.error` / anything else: a
forged proof must never take the verifier down.

The existing tamper tests flip one byte per section; this suite sweeps
hundreds of random mutations and every truncation point (cheap,
decode-only), plus a bounded budget of full `verify_bytes` calls on
mutants that survive decoding.
"""
import random

import numpy as np
import pytest

from repro.core.quantfc import QuantConfig, synthetic_sgd_trajectory
from repro.core.pipeline import (GraphBuilder, compile as zk_compile,
                                 decode_proof, encode_proof, prove_session,
                                 verify_bytes)
from repro.core.pipeline.proofio import ProofDecodeError, decode_vk

QC = QuantConfig(q_bits=16, r_bits=4)


@pytest.fixture(scope="module")
def t1_bytes():
    graph = GraphBuilder(batch=2).input(4).dense(4).relu() \
        .dense(4).relu().output()
    pk, vk = zk_compile(graph, QC, n_steps=1)
    wits = synthetic_sgd_trajectory(1, 2, 2, 4, QC, seed=7)
    proof = prove_session(pk, wits, np.random.default_rng(7))
    return vk, encode_proof(proof), vk.to_bytes()


def _decode_or_reject(decoder, data):
    """The only acceptable outcomes: a decoded object or ProofDecodeError."""
    try:
        return decoder(bytes(data))
    except ProofDecodeError:
        return None
    # any other exception propagates and fails the test


def _mutants(rng, raw, n_point, n_burst):
    """Deterministic mutation stream: single-byte XORs, multi-byte
    bursts, and every truncation length on a stride."""
    for _ in range(n_point):
        bad = bytearray(raw)
        bad[rng.randrange(len(raw))] ^= rng.randrange(1, 256)
        yield bytes(bad)
    for _ in range(n_burst):
        bad = bytearray(raw)
        start = rng.randrange(len(raw))
        for off in range(start, min(len(raw), start + rng.randrange(2, 9))):
            bad[off] = rng.randrange(256)
        yield bytes(bad)
    stride = max(1, len(raw) // 128)
    for cut in range(0, len(raw), stride):
        yield raw[:cut]
    yield raw + b"\x00"
    yield raw * 2


def test_proof_decode_fuzz_never_crashes(t1_bytes):
    _, raw, _ = t1_bytes
    rng = random.Random(0xC0FFEE)
    survivors = 0
    for data in _mutants(rng, raw, n_point=400, n_burst=100):
        if _decode_or_reject(decode_proof, data) is not None:
            survivors += 1
    # plenty of mutants DO decode (scalar flips are well-framed): the
    # crash-freedom claim must cover both branches
    assert survivors > 0


def test_vk_decode_fuzz_never_crashes(t1_bytes):
    """Exhaustive single-byte XOR over the ~300-byte vk plus every
    truncation: decode_vk returns a vk or raises ProofDecodeError —
    config derivation on hostile graphs must not leak raw exceptions."""
    _, _, vk_raw = t1_bytes
    rng = random.Random(0xBEEF)
    for pos in range(len(vk_raw)):
        bad = bytearray(vk_raw)
        bad[pos] ^= rng.randrange(1, 256)
        _decode_or_reject(decode_vk, bad)
    for cut in range(len(vk_raw)):
        _decode_or_reject(decode_vk, vk_raw[:cut])


def test_mutated_proofs_verify_reject_cleanly(t1_bytes):
    """Bounded budget of FULL verify calls: decodable mutants must
    reject (bool False), not crash — covers verifier-side arithmetic on
    decoded-but-garbage fields, beyond what decode can check."""
    vk, raw, _ = t1_bytes
    rng = random.Random(0xFACADE)
    budget = 24
    for data in _mutants(rng, raw, n_point=200, n_burst=40):
        if budget == 0:
            break
        if data == raw or _decode_or_reject(decode_proof, data) is None:
            continue
        budget -= 1
        assert verify_bytes(vk, data) is False, \
            f"mutant accepted (len {len(data)})"
    assert budget == 0, "mutation stream produced too few decodable mutants"


def test_mutated_vks_verify_cleanly_without_crash(t1_bytes):
    """A mutated vk must either fail decoding or produce a clean bool
    from verify_bytes — never crash while re-deriving generators from a
    hostile config.  (Acceptance is NOT asserted per-mutant: a few vk
    bytes are pure metadata — e.g. a node's ``layer`` index — and a
    flip there legitimately still verifies.  Any byte that feeds key
    derivation must reject, which the rejected>0 check covers.)"""
    vk, raw, vk_raw = t1_bytes
    rng = random.Random(0xD00D)
    budget, rejected = 12, 0
    for pos in rng.sample(range(6, len(vk_raw)), len(vk_raw) - 6):
        if budget == 0:
            break
        bad = bytearray(vk_raw)
        bad[pos] ^= rng.randrange(1, 256)
        forged_vk = _decode_or_reject(decode_vk, bad)
        if forged_vk is None:
            continue
        cfg = forged_vk.cfg
        # a mutant claiming huge geometry (a flipped n_steps/width byte)
        # makes KEY DERIVATION — not verification — expensive.  decode_vk
        # now caps merged_len (VK_MAX_MERGED_LEN; see
        # test_vk_geometry_cap_bounds_key_derivation), but mutants under
        # the cap can still cost seconds each — keep the sweep fast.
        if cfg.n_steps * cfg.batch * max(cfg.widths, default=1) > 4096:
            continue
        budget -= 1
        verdict = verify_bytes(forged_vk, raw)
        assert verdict in (True, False)
        rejected += not verdict
    assert budget == 0, "vk mutation stream produced too few decodable vks"
    assert rejected > 0, "every mutated vk accepted the proof"


def test_vk_geometry_cap_bounds_key_derivation():
    """The vk trusted-input DoS (found by the mutation sweep above): a
    vk claiming a huge window/width makes generator derivation — not
    verification — arbitrarily expensive.  `decode_vk` must reject such
    geometry with a ProofDecodeError BEFORE any key material derives,
    and quickly."""
    import time

    from repro.core.pipeline import GraphBuilder, PipelineConfig
    from repro.core.pipeline.api import VerifyingKey
    from repro.core.pipeline.proofio import (VK_MAX_MERGED_LEN, encode_vk,
                                             decode_vk)

    graph = GraphBuilder(batch=2).input(4).dense(4).relu() \
        .dense(4).relu().output()
    # config construction is pure arithmetic; only decode_vk's cap
    # stands between these bytes and a 2^30-generator derivation
    huge = PipelineConfig.from_graph(graph, q_bits=16, r_bits=4,
                                     n_steps=1 << 20)
    assert huge.merged_len > VK_MAX_MERGED_LEN
    raw = encode_vk(VerifyingKey(cfg=huge))
    t0 = time.perf_counter()
    with pytest.raises(ProofDecodeError, match="refusing key derivation"):
        decode_vk(raw)
    assert time.perf_counter() - t0 < 2.0, "cap check must be cheap"

    # a legitimate small vk still decodes, and the cap is overridable
    # for deployments that really prove huge windows
    small = PipelineConfig.from_graph(graph, q_bits=16, r_bits=4,
                                      n_steps=2)
    assert decode_vk(encode_vk(VerifyingKey(cfg=small))).cfg.n_steps == 2
    big_cap = decode_vk(raw, max_merged_len=huge.merged_len)
    assert big_cap.cfg.n_steps == 1 << 20
