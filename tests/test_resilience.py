"""Fault-tolerance layer: checkpoint/restart, straggler detection,
failure injection, gradient compression (with error feedback)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.train import checkpoint, compression, resilience


def small_state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((4, 4)),
                                        jnp.float32)},
            "opt": {"step": jnp.zeros((), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    state = small_state()
    policy = resilience.CheckpointPolicy(str(tmp_path), every=2)
    assert policy.maybe_save(1, state) is None
    path = policy.maybe_save(2, state)
    assert path is not None
    restored, start = policy.restore_latest(state)
    assert start == 3
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_restore_empty_dir(tmp_path):
    policy = resilience.CheckpointPolicy(str(tmp_path))
    state, start = policy.restore_latest(small_state())
    assert state is None and start == 0


def test_run_resilient_restarts(tmp_path):
    policy = resilience.CheckpointPolicy(str(tmp_path), every=2)
    injector = resilience.FailureInjector(fail_at_step=3)
    seen = []

    def loop(state, start):
        if state is None:
            state = small_state()
        for step in range(start, 6):
            seen.append(step)
            injector.check(step)
            state = {"params": {"w": state["params"]["w"] + 1.0},
                     "opt": state["opt"]}
            policy.maybe_save(step, state)
        return state

    final = resilience.run_resilient(loop, small_state(), policy)
    # failed at 3 (after saving at 2), restarted at 3, ran to completion
    assert seen == [0, 1, 2, 3, 3, 4, 5]
    assert final is not None


def test_straggler_monitor_flags_slow_step():
    mon = resilience.StragglerMonitor(threshold=2.0, warmup=2)
    for step in range(5):
        assert not mon.observe(step, 1.0)
    assert mon.observe(5, 10.0)
    assert mon.events and mon.events[0]["step"] == 5
    # EMA not polluted by the straggler step
    assert not mon.observe(6, 1.0)


@pytest.mark.parametrize("mode", ["int8", "topk"])
def test_compression_roundtrip_shapes(mode):
    rng = np.random.default_rng(0)
    grads = {"a": jnp.asarray(rng.standard_normal((64, 32)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal((7,)), jnp.float32)}
    res = compression.init_residuals(grads)
    cfg = compression.CompressionConfig(mode=mode, topk_frac=0.1)
    out, new_res = compression.compress_grads(cfg, grads, res)
    assert jax.tree.structure(out) == jax.tree.structure(grads)
    for k in grads:
        assert out[k].shape == grads[k].shape


def test_int8_error_feedback_reduces_bias():
    """With error feedback, accumulated compressed grads converge to the
    true accumulated gradient (the rounding error is carried, not lost)."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((256,)) * 1e-3, jnp.float32)
    cfg = compression.CompressionConfig(mode="int8", error_feedback=True)
    res = {"g": jnp.zeros_like(g)}
    total = jnp.zeros_like(g)
    for _ in range(50):
        out, res_new = compression.compress_grads(cfg, {"g": g}, res)
        total = total + out["g"]
        res = res_new
    mean_err = float(jnp.mean(jnp.abs(total / 50 - g)))
    assert mean_err < 5e-5, mean_err


def test_int8_quant_is_bounded():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal((1024,)) * 100, jnp.float32)
    cfg = compression.CompressionConfig(mode="int8", error_feedback=False)
    out, _ = compression.compress_grads(cfg, {"g": g},
                                        {"g": jnp.zeros_like(g)})
    # elementwise error bounded by the per-block scale (max/127)
    blocks = np.abs(np.asarray(g)).reshape(-1, 256).max(axis=1) / 127.0
    err = np.abs(np.asarray(out["g"]) - np.asarray(g)).reshape(-1, 256)
    assert (err <= blocks[:, None] * 0.5 + 1e-6).all()


def test_wire_bytes_model():
    assert compression.wire_bytes_per_param(
        compression.CompressionConfig(mode="none")) == 2.0
    assert compression.wire_bytes_per_param(
        compression.CompressionConfig(mode="int8")) < 1.1


def test_elastic_restore_under_new_sharding(tmp_path):
    """Checkpoint written on one 'mesh', restored with different placement
    (the elastic-rescale path: full host arrays -> new device_put)."""
    state = small_state()
    checkpoint.save(str(tmp_path), 5, state)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    shardings = jax.tree.map(lambda _: sharding, state)
    policy = resilience.CheckpointPolicy(str(tmp_path))
    restored, start = policy.restore_latest(state, shardings)
    assert start == 6
    assert restored["params"]["w"].sharding == sharding
