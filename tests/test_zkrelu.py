"""Standalone tests for zkReLU auxiliary-input validity (Section 4.1)."""
import numpy as np
import pytest

from repro.field import FQ
from repro.core import zkrelu
from repro.core.mle import hexpand_point
from repro.core.transcript import Transcript

Q_MOD = FQ.modulus

DS = 8        # stacked aux length (power of 2)
QB = 8        # Q bits
RB = 4        # R bits


def make_aux(rng, ds=DS):
    zpp = rng.integers(0, 1 << (QB - 1), size=ds).astype(np.int64)
    gap = rng.integers(-(1 << (QB - 1)), 1 << (QB - 1), size=ds).astype(np.int64)
    bq = rng.integers(0, 2, size=ds).astype(np.int64)
    rz = rng.integers(0, 1 << RB, size=ds).astype(np.int64)
    rga = rng.integers(0, 1 << RB, size=ds).astype(np.int64)
    return zpp, gap, bq, rz, rga


def honest_claims(zpp, gap, bq, rz, rga, u_relu):
    """Host-side MLE evals: v, v_{Q-1}, v_r at u_relu = (u_star..., u'')."""
    ds = zpp.shape[0]
    u_star, upp = u_relu[:-1], u_relu[-1]
    e = hexpand_point(u_star)
    vz = sum(int(zpp[i]) * e[i] for i in range(ds)) % Q_MOD
    vg = sum(int(gap[i]) % Q_MOD * e[i] for i in range(ds)) % Q_MOD
    vq1 = sum(int(bq[i]) * e[i] for i in range(ds)) % Q_MOD
    vrz = sum(int(rz[i]) * e[i] for i in range(ds)) % Q_MOD
    vrga = sum(int(rga[i]) * e[i] for i in range(ds)) % Q_MOD
    v = ((1 - upp) * vz + upp * vg) % Q_MOD
    vr = ((1 - upp) * vrz + upp * vrga) % Q_MOD
    return v, vq1, vr


def coms_list(coms):
    return [coms.com_b_ip, coms.com_bq1, coms.com_bq1p, coms.com_br_ip]


def run_protocol(tamper=None):
    rng = np.random.default_rng(42)
    zpp, gap, bq, rz, rga = make_aux(rng)
    keys = zkrelu.make_validity_keys(DS, QB, RB)
    bits = zkrelu.build_aux_bits(zpp, gap, bq, rz, rga, QB, RB)
    if tamper == "bitflip":
        bits.b_mat[3, 2] ^= 1
    if tamper == "value":
        # commitments honest, but the prover's raw witness disagrees
        bits.zpp[3] ^= 4

    coms, blinds = zkrelu.commit_validity(keys, bits, rng)

    n_vars = DS.bit_length() - 1
    tp = Transcript(b"zkrelu-test")
    tp.absorb_ints(b"coms", coms_list(coms))
    u_relu = tp.challenge_ints(b"urelu", Q_MOD, n_vars + 1)
    v, vq1, vr = honest_claims(zpp, gap, bq, rz, rga, u_relu)
    tp.absorb_ints(b"claims", [v, vq1, vr])

    proof = zkrelu.prove_validity(keys, bits, blinds, u_relu, v, vq1, vr,
                                  tp, rng)

    tv = Transcript(b"zkrelu-test")
    tv.absorb_ints(b"coms", coms_list(coms))
    u_relu_v = tv.challenge_ints(b"urelu", Q_MOD, n_vars + 1)
    assert u_relu_v == u_relu
    tv.absorb_ints(b"claims", [v, vq1, vr])
    return zkrelu.verify_validity(keys, coms, v, vq1, vr,
                                  u_relu, proof, tv)


def test_validity_accepts_honest():
    assert run_protocol()


def test_validity_rejects_bitflip():
    assert not run_protocol(tamper="bitflip")


def test_validity_rejects_witness_value_flip():
    assert not run_protocol(tamper="value")


def test_validity_rejects_wrong_claim():
    rng = np.random.default_rng(1)
    zpp, gap, bq, rz, rga = make_aux(rng)
    keys = zkrelu.make_validity_keys(DS, QB, RB)
    bits = zkrelu.build_aux_bits(zpp, gap, bq, rz, rga, QB, RB)
    coms, blinds = zkrelu.commit_validity(keys, bits, rng)
    n_vars = DS.bit_length() - 1
    tp = Transcript(b"t2")
    u_relu = tp.challenge_ints(b"urelu", Q_MOD, n_vars + 1)
    v, vq1, vr = honest_claims(zpp, gap, bq, rz, rga, u_relu)
    v_bad = (v + 1) % Q_MOD
    tp.absorb_ints(b"claims", [v_bad, vq1, vr])
    proof = zkrelu.prove_validity(keys, bits, blinds, u_relu, v_bad, vq1, vr,
                                  tp, rng)
    tv = Transcript(b"t2")
    u2 = tv.challenge_ints(b"urelu", Q_MOD, n_vars + 1)
    tv.absorb_ints(b"claims", [v_bad, vq1, vr])
    assert not zkrelu.verify_validity(keys, coms, v_bad, vq1, vr,
                                      u2, proof, tv)


def test_cross_statement_swap_rejects():
    """Fold-in soundness: a prover that swaps the main/remainder slices
    inside the merged direct-sum IPA (proving the right claims against
    the wrong basis positions) must be rejected."""
    from repro.field import mont_mul
    from repro.core import group, ipa
    from repro.core.mle import enc
    import jax.numpy as jnp

    rng = np.random.default_rng(7)
    zpp, gap, bq, rz, rga = make_aux(rng)
    keys = zkrelu.make_validity_keys(DS, QB, RB)
    bits = zkrelu.build_aux_bits(zpp, gap, bq, rz, rga, QB, RB)
    coms, blinds = zkrelu.commit_validity(keys, bits, rng)

    n_vars = DS.bit_length() - 1
    tp = Transcript(b"swap")
    tp.absorb_ints(b"coms", coms_list(coms))
    u_relu = tp.challenge_ints(b"urelu", Q_MOD, n_vars + 1)
    v, vq1, vr = honest_claims(zpp, gap, bq, rz, rga, u_relu)
    tp.absorb_ints(b"claims", [v, vq1, vr])

    st = zkrelu.prove_statements(keys, bits, blinds, u_relu, v, vq1, vr, tp)
    lam = tp.challenge_int(b"zkrelu/lam", Q_MOD)
    lam_m = enc(lam)
    pad = keys.merged_len - keys.n_main - keys.n_rem
    zeros = jnp.zeros((pad, 4), dtype=jnp.uint32)
    # malicious layout: remainder witness into the main slice and vice
    # versa (padded/truncated to the slice widths), claims unchanged
    a_sw = jnp.concatenate([
        jnp.concatenate([st.a_rem] * (keys.n_main // keys.n_rem)),
        mont_mul(FQ, st.a_main[:keys.n_rem], lam_m[None]), zeros])
    b_sw = jnp.concatenate([
        jnp.concatenate([st.b_rem] * (keys.n_main // keys.n_rem)),
        mont_mul(FQ, st.b_main[:keys.n_rem], lam_m[None]), zeros])
    ones = jnp.broadcast_to(enc(1), (pad, 4)).astype(jnp.uint32)
    w = jnp.concatenate([st.w_main, st.w_rem, ones])
    claim = (st.claim_main + lam * lam % Q_MOD * st.claim_rem) % Q_MOD
    blind = (st.blind_main + lam * st.blind_rem) % Q_MOD
    stmt = (keys.g_merged, None, keys.h_blind, a_sw, b_sw, blind, claim,
            (keys.g_merged_table, keys.h_merged, keys.h_merged_table, w))
    (proof,) = ipa.pair_prove_many([stmt], tp, rng)

    tv = Transcript(b"swap")
    tv.absorb_ints(b"coms", coms_list(coms))
    u2 = tv.challenge_ints(b"urelu", Q_MOD, n_vars + 1)
    tv.absorb_ints(b"claims", [v, vq1, vr])
    assert not zkrelu.verify_validity(keys, coms, v, vq1, vr, u2, proof, tv)


def test_bits_roundtrip():
    rng = np.random.default_rng(2)
    v = rng.integers(-(1 << 7), 1 << 7, size=32).astype(np.int64)
    b = zkrelu.bits_signed(v, 8)
    rec = sum(b[:, j].astype(np.int64) << j for j in range(7)) - (b[:, 7].astype(np.int64) << 7)
    assert (rec == v).all()
    u = rng.integers(0, 1 << 7, size=32).astype(np.int64)
    bu = zkrelu.bits_unsigned(u, 7)
    rec_u = sum(bu[:, j].astype(np.int64) << j for j in range(7))
    assert (rec_u == u).all()
