"""Unit contracts for `launch/supervise` — the retry/backoff library the
crash-safe prover service and conftest's flaky-subprocess quarantine
both run on.  The policy split under test: signal deaths and timeouts
are infrastructure failures (retried), clean nonzero exits are
deliberate failures (surfaced immediately unless opted in)."""
import os
import signal
import sys

import pytest

from repro.launch import supervise


# ---------------------------------------------------------------------------
# In-process supervisor
# ---------------------------------------------------------------------------

def test_backoff_schedule_is_capped_exponential():
    assert supervise.backoff_delays(5, base=0.1, cap=0.5) == \
        [0.1, 0.2, 0.4, 0.5, 0.5]
    assert supervise.backoff_delays(0) == []


def test_run_supervised_first_try_success():
    res = supervise.run_supervised(lambda: 42)
    assert res.ok and res.value == 42
    assert res.n_attempts == 1 and res.error is None
    assert res.attempts[0].error is None


def test_run_supervised_retries_then_succeeds():
    calls = {"n": 0}
    slept = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError(f"boom {calls['n']}")
        return "ok"

    res = supervise.run_supervised(flaky, max_attempts=4,
                                   backoff_base=0.1, backoff_cap=0.15,
                                   sleep=slept.append)
    assert res.ok and res.value == "ok"
    assert res.n_attempts == 3
    assert [a.error for a in res.attempts[:2]] == \
        ["RuntimeError: boom 1", "RuntimeError: boom 2"]
    assert slept == [0.1, 0.15]          # capped exponential


def test_run_supervised_exhausts_and_keeps_last_error():
    retries = []
    res = supervise.run_supervised(
        lambda: (_ for _ in ()).throw(ValueError("always")),
        max_attempts=3, sleep=lambda _: None,
        on_retry=lambda i, exc: retries.append(i))
    assert not res.ok and res.value is None
    assert isinstance(res.error, ValueError)
    assert res.n_attempts == 3 and res.last_error == "ValueError: always"
    assert retries == [0, 1]             # no retry after the final attempt


def test_run_supervised_retry_on_filter():
    """Exceptions outside retry_on propagate on the first attempt."""
    with pytest.raises(KeyError):
        supervise.run_supervised(
            lambda: (_ for _ in ()).throw(KeyError("nope")),
            retry_on=(ValueError,), sleep=lambda _: None)


# ---------------------------------------------------------------------------
# Subprocess supervisor
# ---------------------------------------------------------------------------

def _child_argv(code):
    return [sys.executable, "-c", code]


def test_subprocess_clean_success():
    res = supervise.run_subprocess_supervised(
        _child_argv("print('hi')"), capture_output=True, text=True)
    assert res.ok and res.n_attempts == 1
    assert res.value.stdout.strip() == "hi"


def test_subprocess_signal_death_retried(tmp_path):
    """The child SIGKILLs itself unless the marker exists; attempt_setup
    drops the marker before the second try — the supervisor must retry
    the signal death and report it in the attempt log."""
    marker = tmp_path / "alive"
    code = (f"import os, signal, sys\n"
            f"if not os.path.exists({str(marker)!r}):\n"
            f"    os.kill(os.getpid(), signal.SIGKILL)\n"
            f"print('survived')\n")

    def setup(attempt):
        if attempt == 1:
            marker.write_text("ok")
        return []

    res = supervise.run_subprocess_supervised(
        _child_argv(code), max_attempts=3, attempt_setup=setup,
        backoff_base=0.01, backoff_cap=0.01,
        capture_output=True, text=True)
    assert res.ok and res.n_attempts == 2
    assert res.attempts[0].signal == signal.SIGKILL
    assert res.value.stdout.strip() == "survived"


def test_subprocess_clean_nonzero_not_retried_by_default():
    res = supervise.run_subprocess_supervised(
        _child_argv("import sys; sys.exit(3)"), max_attempts=5,
        capture_output=True, text=True)
    assert not res.ok and res.n_attempts == 1
    assert res.value.returncode == 3 and res.attempts[0].signal is None


def test_subprocess_retry_nonzero_opt_in():
    res = supervise.run_subprocess_supervised(
        _child_argv("import sys; sys.exit(3)"), max_attempts=2,
        retry_nonzero=True, backoff_base=0.01, backoff_cap=0.01,
        capture_output=True, text=True)
    assert not res.ok and res.n_attempts == 2
    assert res.last_error == "exit 3"


def test_subprocess_timeout_retried_then_exhausted():
    res = supervise.run_subprocess_supervised(
        _child_argv("import time; time.sleep(60)"), max_attempts=2,
        timeout=0.5, backoff_base=0.01, backoff_cap=0.01,
        capture_output=True)
    assert not res.ok and res.n_attempts == 2
    assert all(a.timed_out for a in res.attempts)
    assert res.value is None             # no attempt ever completed


def test_subprocess_timeout_propagates_when_opted_out():
    import subprocess
    with pytest.raises(subprocess.TimeoutExpired):
        supervise.run_subprocess_supervised(
            _child_argv("import time; time.sleep(60)"), max_attempts=3,
            timeout=0.5, retry_timeouts=False, capture_output=True)


# ---------------------------------------------------------------------------
# Edge cases (PR 10 satellite): bad budgets, boundary exits, attempt log
# ---------------------------------------------------------------------------

def test_subprocess_zero_or_negative_timeout_rejected():
    """timeout=0 would kill every attempt before it starts — a config
    bug the supervisor must reject loudly, not loop over."""
    for bad in (0, 0.0, -1.0):
        with pytest.raises(ValueError):
            supervise.run_subprocess_supervised(
                _child_argv("pass"), timeout=bad, capture_output=True)
    # None stays the "no timeout" spelling
    assert supervise.run_subprocess_supervised(
        _child_argv("pass"), timeout=None, capture_output=True).ok


def test_subprocess_backoff_cap_respected_across_attempts():
    """With many attempts, injected sleep must see the capped schedule —
    the supervisor never sleeps past backoff_cap no matter how far the
    exponential has run."""
    slept = []
    res = supervise.run_subprocess_supervised(
        _child_argv("import sys; sys.exit(1)"), max_attempts=5,
        retry_nonzero=True, backoff_base=0.01, backoff_cap=0.03,
        sleep=slept.append, capture_output=True)
    assert not res.ok and res.n_attempts == 5
    assert slept == [0.01, 0.02, 0.03, 0.03]    # 4 sleeps between 5 tries
    assert max(slept) <= 0.03


def test_child_finishing_cleanly_inside_timeout_not_double_retried():
    """A slow-but-successful child that completes WITHIN the timeout
    window is one clean attempt: no spurious retry, no timed_out flag."""
    slept = []
    res = supervise.run_subprocess_supervised(
        _child_argv("import time; time.sleep(0.2)"), max_attempts=3,
        timeout=30.0, sleep=slept.append, capture_output=True)
    assert res.ok and res.n_attempts == 1
    assert slept == []                          # success never sleeps
    assert not res.attempts[0].timed_out
    assert res.attempts[0].error is None


def test_attempt_log_carries_signal_and_duration_fields():
    """Each Attempt must record index, wall seconds, the signal (for
    signal deaths) and the timed_out flag — the serve manifest and the
    chaos harness both read these."""
    res = supervise.run_subprocess_supervised(
        _child_argv("import os, signal; os.kill(os.getpid(), "
                    "signal.SIGTERM)"),
        max_attempts=2, backoff_base=0.01, backoff_cap=0.01,
        capture_output=True)
    assert not res.ok and res.n_attempts == 2
    for i, att in enumerate(res.attempts):
        assert att.index == i
        assert att.seconds >= 0.0
        assert att.signal == signal.SIGTERM
        assert att.error == f"signal {signal.SIGTERM}"
        assert att.timed_out is False
    assert res.last_error == f"signal {signal.SIGTERM}"
