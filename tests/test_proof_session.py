"""Aggregated proof pipeline tests: T=2 prove/verify roundtrip plus
tamper rejections (flipped aux bit, wrong step count, stale transcript,
cross-step claim splicing)."""
import copy

import numpy as np
import pytest

from repro.core.quantfc import QuantConfig, synthetic_sgd_trajectory
from repro.core.pipeline import (PipelineConfig, ProofSession, make_keys,
                                 prove_session, verify_session)

CFG = PipelineConfig(n_layers=2, batch=2, width=4, q_bits=16, r_bits=4,
                     n_steps=2)
QC = QuantConfig(q_bits=CFG.q_bits, r_bits=CFG.r_bits)


def make_step_witnesses(seed=0, n_steps=CFG.n_steps, cfg=CFG):
    """n_steps consecutive batch updates with real integer SGD between."""
    return synthetic_sgd_trajectory(n_steps, cfg.n_layers, cfg.batch,
                                    cfg.width, QC, seed=seed)


@pytest.fixture(scope="module")
def keys():
    return make_keys(CFG)


@pytest.fixture(scope="module")
def proof(keys):
    return prove_session(keys, make_step_witnesses(seed=1),
                         np.random.default_rng(1))


def test_aggregated_roundtrip_accepts(keys, proof):
    trace = []
    assert verify_session(keys, proof, trace=trace), trace
    assert proof.n_steps == CFG.n_steps
    # one aggregated transcript: a single set of commitments/IPAs covers
    # both steps, so the proof stays well under 2x a single-step proof
    assert proof.size_bytes() < 20_000
    assert len(proof.coms.x) == CFG.n_steps * CFG.batch


def test_rejects_flipped_aux_bit(keys):
    wits = make_step_witnesses(seed=2)
    wits[1].b[0][0, 0] ^= 1          # flip a ReLU sign bit in step 1
    bad = prove_session(keys, wits, np.random.default_rng(2))
    assert not verify_session(keys, bad)


def test_rejects_tampered_step1_gradient(keys):
    wits = make_step_witnesses(seed=3)
    wits[1].gw[0][0, 0] += 1         # forged gradient in the SECOND step
    bad = prove_session(keys, wits, np.random.default_rng(3))
    assert not verify_session(keys, bad)


def test_rejects_wrong_step_count(keys):
    session = ProofSession(keys, np.random.default_rng(4))
    session.add_step(make_step_witnesses(seed=4, n_steps=1)[0])
    with pytest.raises(ValueError, match="step"):
        session.prove()             # only 1 of 2 steps queued

    wits = make_step_witnesses(seed=5, n_steps=3)
    full = ProofSession(keys, np.random.default_rng(5))
    full.add_step(wits[0])
    full.add_step(wits[1])
    with pytest.raises(ValueError, match="already holds"):
        full.add_step(wits[2])      # session window is full


def test_rejects_step_count_tamper(keys, proof):
    bad = copy.deepcopy(proof)
    bad.n_steps = 1                 # claim fewer steps than proven
    trace = []
    assert not verify_session(keys, bad, trace=trace)
    assert trace == ["step-count"]


def test_rejects_stale_transcript(keys, proof):
    # same proof replayed against a different session label: every
    # challenge diverges, so the first sumcheck must already fail
    assert not verify_session(keys, proof, label=b"zkdl/other-session")


def test_rejects_cross_step_claim_swap(keys, proof):
    bad = copy.deepcopy(proof)
    bad.openings["zL_b/0"], bad.openings["zL_b/1"] = \
        bad.openings["zL_b/1"], bad.openings["zL_b/0"]
    assert not verify_session(keys, bad)


def test_rejects_tampered_opening(keys, proof):
    bad = copy.deepcopy(proof)
    bad.openings["a1"] = (bad.openings["a1"] + 1) % (2**61)
    assert not verify_session(keys, bad)
