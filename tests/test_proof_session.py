"""Aggregated proof pipeline tests: T=2 prove/verify roundtrip plus
tamper rejections (flipped aux bit, wrong step count, stale transcript,
cross-step claim splicing), the heterogeneous pyramid roundtrip, and the
golden-digest pins that freeze the uniform-graph transcript of the v3
merged one-IPA opening protocol (data folds + zkReLU validity in a
single pair IPA)."""
import copy
import hashlib

import numpy as np
import pytest

from repro.core.quantfc import (QuantConfig, synthetic_sgd_trajectory,
                                synthetic_sgd_trajectory_widths)
from repro.core.pipeline import (PipelineConfig, ProofSession, make_keys,
                                 prove_session, verify_session)

CFG = PipelineConfig(n_layers=2, batch=2, width=4, q_bits=16, r_bits=4,
                     n_steps=2)
QC = QuantConfig(q_bits=CFG.q_bits, r_bits=CFG.r_bits)

# pyramid MLP: 4 distinct layer widths, multi-bucket in every family
HET_WIDTHS = (16, 8, 4, 2)
HET_CFG = PipelineConfig(n_layers=3, batch=2, widths=HET_WIDTHS,
                         q_bits=16, r_bits=4, n_steps=2)


def make_step_witnesses(seed=0, n_steps=CFG.n_steps, cfg=CFG):
    """n_steps consecutive batch updates with real integer SGD between."""
    return synthetic_sgd_trajectory(n_steps, cfg.n_layers, cfg.batch,
                                    cfg.width, QC, seed=seed)


@pytest.fixture(scope="module")
def keys():
    return make_keys(CFG)


@pytest.fixture(scope="module")
def proof(keys):
    return prove_session(keys, make_step_witnesses(seed=1),
                         np.random.default_rng(1))


def test_aggregated_roundtrip_accepts(keys, proof):
    trace = []
    assert verify_session(keys, proof, trace=trace), trace
    assert proof.n_steps == CFG.n_steps
    # one aggregated transcript: a single set of commitments/IPAs covers
    # both steps, so the proof stays well under 2x a single-step proof
    assert proof.size_bytes() < 20_000
    assert len(proof.coms.x) == CFG.n_steps * CFG.batch


def test_rejects_flipped_aux_bit(keys):
    wits = make_step_witnesses(seed=2)
    wits[1].b[0][0, 0] ^= 1          # flip a ReLU sign bit in step 1
    bad = prove_session(keys, wits, np.random.default_rng(2))
    assert not verify_session(keys, bad)


def test_rejects_tampered_step1_gradient(keys):
    wits = make_step_witnesses(seed=3)
    wits[1].gw[0][0, 0] += 1         # forged gradient in the SECOND step
    bad = prove_session(keys, wits, np.random.default_rng(3))
    assert not verify_session(keys, bad)


def test_rejects_wrong_step_count(keys):
    session = ProofSession(keys, np.random.default_rng(4))
    session.add_step(make_step_witnesses(seed=4, n_steps=1)[0])
    with pytest.raises(ValueError, match="step"):
        session.prove()             # only 1 of 2 steps queued

    wits = make_step_witnesses(seed=5, n_steps=3)
    full = ProofSession(keys, np.random.default_rng(5))
    full.add_step(wits[0])
    full.add_step(wits[1])
    with pytest.raises(ValueError, match="already holds"):
        full.add_step(wits[2])      # session window is full


def test_rejects_step_count_tamper(keys, proof):
    bad = copy.deepcopy(proof)
    bad.n_steps = 1                 # claim fewer steps than proven
    trace = []
    assert not verify_session(keys, bad, trace=trace)
    assert trace == ["step-count"]


def test_rejects_stale_transcript(keys, proof):
    # same proof replayed against a different session label: every
    # challenge diverges, so the first sumcheck must already fail
    assert not verify_session(keys, proof, label=b"zkdl/other-session")


def test_rejects_cross_step_claim_swap(keys, proof):
    bad = copy.deepcopy(proof)
    bad.openings["zL_b/0"], bad.openings["zL_b/1"] = \
        bad.openings["zL_b/1"], bad.openings["zL_b/0"]
    assert not verify_session(keys, bad)


def test_rejects_tampered_opening(keys, proof):
    bad = copy.deepcopy(proof)
    bad.openings["a1"] = (bad.openings["a1"] + 1) % (2**61)
    assert not verify_session(keys, bad)


# ---------------------------------------------------------------------------
# Heterogeneous layer graph (FAC4DNN over a pyramid MLP)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def het_keys():
    return make_keys(HET_CFG)


def test_heterogeneous_pyramid_roundtrip(het_keys):
    """A pyramid MLP with 4 distinct widths proves T=2 steps in ONE
    aggregated session; every matmul family splits into shape buckets."""
    buckets = HET_CFG.graph.buckets
    assert len(buckets["fwd"]) == 3       # inner dims 16 / 8 / 4
    assert len(buckets["bwd"]) == 2       # inner dims 4 / 2
    assert len(buckets["gw"]) == 1        # inner dim = batch, always one
    wits = synthetic_sgd_trajectory_widths(2, HET_WIDTHS, HET_CFG.batch,
                                           QC, seed=11)
    proof = prove_session(het_keys, wits, np.random.default_rng(11))
    trace = []
    assert verify_session(het_keys, proof, trace=trace), trace
    assert len(proof.sc_fwd) == 3 and len(proof.fwd_claims) == 3
    assert len(proof.sc_bwd) == 2 and len(proof.gw_claims) == 0


def test_heterogeneous_rejects_tampered_witness(het_keys):
    wits = synthetic_sgd_trajectory_widths(2, HET_WIDTHS, HET_CFG.batch,
                                           QC, seed=12)
    wits[1].gw[1][0, 0] += 1              # forged gradient, narrow layer
    bad = prove_session(het_keys, wits, np.random.default_rng(12))
    assert not verify_session(het_keys, bad)

    wits = synthetic_sgd_trajectory_widths(2, HET_WIDTHS, HET_CFG.batch,
                                           QC, seed=13)
    wits[0].b[2][0, 0] ^= 1               # flipped ReLU bit, widest slot
    bad = prove_session(het_keys, wits, np.random.default_rng(13))
    assert not verify_session(het_keys, bad)


def test_heterogeneous_rejects_claim_split_tamper(het_keys):
    """Moving mass between two buckets' split claims keeps the sum (so
    the split check passes) but must break a bucket sumcheck."""
    wits = synthetic_sgd_trajectory_widths(2, HET_WIDTHS, HET_CFG.batch,
                                           QC, seed=14)
    proof = prove_session(het_keys, wits, np.random.default_rng(14))
    bad = copy.deepcopy(proof)
    bad.fwd_claims[0] = (bad.fwd_claims[0] + 1) % (2**61 - 1)
    bad.fwd_claims[1] = (bad.fwd_claims[1] - 1) % (2**61 - 1)
    assert not verify_session(het_keys, bad)


def check_stacking_invariants(widths, n_steps, seed, batch=2):
    """Graph-stacking invariants (shared with the hypothesis twin in
    test_property_based.py): slot maps are bijections onto their padded
    axes, every occupied block equals its node's zero-padded tensor, and
    every element outside the occupied blocks is exactly zero."""
    from repro.core.pipeline.witness import pad2d, stack_witnesses

    cfg = PipelineConfig(n_layers=len(widths) - 1, batch=batch,
                         widths=tuple(widths), q_bits=16, r_bits=4,
                         n_steps=n_steps)
    wits = synthetic_sgd_trajectory_widths(n_steps, widths, batch, QC,
                                           seed=seed)
    sw = stack_witnesses(wits, cfg)
    g = cfg.graph

    slots = [cfg.slot(t, i) for t in range(cfg.t_pad)
             for i in range(cfg.l_pad)]
    assert sorted(slots) == list(range(cfg.s_pad))
    wslots = [cfg.wslot(t, i) for t in range(cfg.t_pad)
              for i in range(cfg.lw_pad)]
    assert sorted(wslots) == list(range(cfg.sw_pad))

    zpp = sw.zpp_s.reshape(cfg.t_pad, cfg.l_pad, cfg.d_elem)
    occupied = np.zeros_like(zpp, dtype=bool)
    for t in range(n_steps):
        for i, node in enumerate(g.aux_nodes):
            blk = zpp[t, i, : node.elem_pad]
            want = pad2d(wits[t].zpp[node.layer - 1], node.rows_pad,
                         node.cols_pad).reshape(-1)
            np.testing.assert_array_equal(blk, want)
            occupied[t, i, : node.elem_pad] = True
    assert (zpp[~occupied] == 0).all()

    w_s = sw.w_s.reshape(cfg.t_pad, cfg.lw_pad, cfg.w_elem)
    occupied_w = np.zeros_like(w_s, dtype=bool)
    for t in range(n_steps):
        for i, node in enumerate(g.weight_nodes):
            rp, cp = g.weight_shape(node)
            blk = w_s[t, i, : rp * cp]
            want = pad2d(wits[t].w[node.layer - 1], rp, cp).reshape(-1)
            np.testing.assert_array_equal(blk, want)
            occupied_w[t, i, : rp * cp] = True
    assert (w_s[~occupied_w] == 0).all()


@pytest.mark.parametrize("widths,n_steps", [
    ((16, 8, 4, 2), 2),       # pyramid, multi-bucket
    ((6, 4, 3, 2), 1),        # non-pow2: per-dimension padding
    ((4, 4, 4), 3),           # uniform, padded step axis
])
def test_stacking_invariants(widths, n_steps):
    check_stacking_invariants(widths, n_steps, seed=21)


def test_non_pow2_widths_roundtrip():
    """Non-power-of-two widths pad per dimension inside each slot."""
    widths = (6, 4, 3, 2)
    cfg = PipelineConfig(n_layers=3, batch=2, widths=widths, q_bits=16,
                         r_bits=4, n_steps=1)
    keys = make_keys(cfg)
    wits = synthetic_sgd_trajectory_widths(1, widths, cfg.batch, QC,
                                           seed=15)
    proof = prove_session(keys, wits, np.random.default_rng(15))
    trace = []
    assert verify_session(keys, proof, trace=trace), trace


# ---------------------------------------------------------------------------
# Uniform graphs must reproduce the seed protocol bit-for-bit
# ---------------------------------------------------------------------------

def _flat_ints(x):
    if isinstance(x, (int, np.integer)):
        return [int(x)]
    out = []
    for v in x:
        out.extend(_flat_ints(v))
    return out


def proof_digest(proof):
    """Canonical digest of every scalar in an AggregatedProof."""
    h = hashlib.sha256()

    def absorb(tag, ints):
        h.update(tag.encode())
        for v in _flat_ints(ints):
            h.update(int(v).to_bytes(16, "little"))

    absorb("coms", proof.coms.as_ints())
    absorb("openings", [v for _, v in sorted(proof.openings.items())])
    for fam in ("fwd", "bwd", "gw"):
        for sc in getattr(proof, "sc_" + fam):
            absorb(fam + "/msgs", sc.messages)
        absorb(fam + "/finals", getattr(proof, fam + "_finals"))
    absorb("anchor/msgs", proof.sc_anchor.messages)
    absorb("anchor/finals", proof.anchor_finals)
    absorb("ipa/agg", [proof.ipa_agg.ls, proof.ipa_agg.rs,
                       proof.ipa_agg.sigma])
    return h.hexdigest()


# recorded for the v3 merged one-IPA opening protocol (layers=2,
# batch=2, width=4, q=16, r=4, trajectory seed=7, prover rng seed=7).
# History: originally recorded from the pre-graph-IR pipeline and kept
# bit-identical through the IR / batching / serialization refactors;
# re-recorded for PR 5 (unified commitment-key layout + direct-sum
# aggregated opening) and again for PR 6, which folds both zkReLU
# validity statements into the single pair IPA over the merged key
# (fresh bq generators, com_bq1 published, validity challenges drawn
# before rho/agg) -- the transcript changes by design; both pipelines
# verified the same seeded trajectories before re-recording
GOLDEN = {
    1: "25adee334f3087831ba4588932c3f6d5a38bfbb816b888a42f9504a94769a5c0",
    2: "d098df1fea85a092589dabc3701e040eff473b17db571331701ecb7ff99e6fef",
}


@pytest.mark.parametrize("T", [1, 2])
def test_uniform_graph_transcript_pinned(T):
    """Any unintended transcript / witness / rng change must show up as
    a digest mismatch; intended protocol changes re-record GOLDEN (and
    the byte goldens in test_proofio.py) explicitly."""
    cfg = PipelineConfig(n_layers=2, batch=2, width=4, q_bits=16,
                         r_bits=4, n_steps=T)
    keys = make_keys(cfg)
    wits = synthetic_sgd_trajectory(T, 2, 2, 4, QC, seed=7)
    proof = prove_session(keys, wits, np.random.default_rng(7))
    assert proof_digest(proof) == GOLDEN[T]
    assert proof.fwd_claims == []         # single bucket: split implicit


@pytest.mark.parametrize("fold_backend", ["jnp", "pallas"])
def test_v3_bytes_invariant_to_compile_path(fold_backend):
    """The compile-O(1) prover (scan-shaped sumcheck bodies + masked IPA
    ladder) and the legacy per-shape unrolled prover must emit
    byte-identical serialized v3 proofs, under both fold backends, and
    both must reproduce the pinned golden digest — the whole
    depth/T-invariant compile machinery is transcript-invisible."""
    from repro.core import ipa, mle, sumcheck
    from repro.core.pipeline import encode_proof

    cfg = PipelineConfig(n_layers=2, batch=2, width=4, q_bits=16,
                         r_bits=4, n_steps=1)
    keys = make_keys(cfg)

    def run():
        wits = synthetic_sgd_trajectory(1, 2, 2, 4, QC, seed=7)
        return prove_session(keys, wits, np.random.default_rng(7))

    try:
        mle.set_fold_backend(fold_backend)
        sumcheck.set_scan_mode("scan")
        ipa.set_round_mode("ladder")
        scan_proof = run()
        sumcheck.set_scan_mode("unrolled")
        ipa.set_round_mode("unrolled")
        unrolled_proof = run()
    finally:
        mle.set_fold_backend(None)
        sumcheck.set_scan_mode(None)
        ipa.set_round_mode(None)
    assert encode_proof(scan_proof) == encode_proof(unrolled_proof)
    assert proof_digest(scan_proof) == GOLDEN[1]
    assert verify_session(keys, scan_proof)


def test_uniform_stacking_matches_seed_layout():
    """Graph-driven stacking reproduces the seed's positional formula
    flat[(t * l_pad + (l-1)) * B*d + row * d + col] exactly."""
    from repro.core.pipeline.witness import stack_witnesses

    wits = synthetic_sgd_trajectory(CFG.n_steps, CFG.n_layers, CFG.batch,
                                    CFG.width, QC, seed=9)
    sw = stack_witnesses(wits, CFG)
    B, d = CFG.batch, CFG.width
    for name, per_layer in (("zpp_s", lambda w: w.zpp),
                            ("bq_s", lambda w: w.b),
                            ("rz_s", lambda w: w.rz),
                            ("gap_s", lambda w: w.gap),
                            ("rga_s", lambda w: w.rga)):
        seed_flat = np.zeros((CFG.t_pad, CFG.l_pad, B * d), dtype=np.int64)
        for t, w in enumerate(wits):
            for i, tensor in enumerate(per_layer(w)):
                seed_flat[t, i] = tensor.reshape(-1)
        np.testing.assert_array_equal(getattr(sw, name),
                                      seed_flat.reshape(-1), err_msg=name)
    seed_w = np.zeros((CFG.t_pad, CFG.l_pad, d * d), dtype=np.int64)
    for t, w in enumerate(wits):
        for i in range(CFG.n_layers):
            seed_w[t, i] = w.w[i].reshape(-1)
    np.testing.assert_array_equal(sw.w_s, seed_w.reshape(-1))


def test_batched_commit_phase_matches_sequential_commits(keys):
    """The commit phase's two msm_many dispatches must reproduce the
    per-tensor `pedersen.commit` elements exactly (same blinds), so
    batching can never alter a transcript byte."""
    from repro.core import group, pedersen
    from repro.core.pipeline.session import SessionProver
    from repro.core.pipeline.tables import enc_tensor
    from repro.core.pipeline.witness import stack_witnesses

    sw = stack_witnesses(make_step_witnesses(seed=31), CFG)
    prover = SessionProver(keys, np.random.default_rng(31))
    coms = prover.commit(sw)
    tabs, blinds = prover.tabs, prover.blinds
    seq = {name: pedersen.commit(keys.slot_keys[name], tabs.tabs[name],
                                 blinds[name])
           for name in ("y", "w", "gw", "zpp", "rz", "gap", "rga")}
    for name, el in seq.items():
        assert getattr(coms, name) == group.decode_group(el), name
    for ci, x, xb in zip(coms.x, sw.x, prover.x_blinds):
        assert ci == group.decode_group(
            pedersen.commit(keys.kx, enc_tensor(x), xb))


def test_prover_phase_profile_accounts_for_total(keys):
    """The per-phase profiler must cover ~all of prove() wall clock."""
    session = ProofSession(keys, np.random.default_rng(33))
    for w in make_step_witnesses(seed=33):
        session.add_step(w)
    session.prove()
    prof = session.last_profile
    assert prof is not None and prof.total_s > 0
    assert set(prof.phases_s) >= {"stack", "commit", "challenges",
                                  "matmul", "anchor", "openings"}
    assert prof.accounted_s <= prof.total_s * 1.001 + 1e-6
    assert prof.accounted_s >= prof.total_s * 0.9
