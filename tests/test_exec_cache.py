"""Executable-cache contracts (`repro.core.execache`).

Two layers of guarantees:

* unit: wrap() keys on (name, backend, statics, shapes/dtypes), reuses
  the in-process registry, round-trips executables through the disk
  directory into a FRESH process (the serialization must be portable —
  a regression here is the "Symbols not found" class of failure where
  an executable loads in the process that wrote it but nowhere else),
  and falls back to plain jit under tracers / ZKDL_EXEC_MODE=off;
* integration: the cross-process warm-start contract — process A
  compiles + proves, process B reconstructs the ProvingKey for the same
  config and proves WITHOUT re-tracing or re-compiling a single wrapped
  program (``stats()["misses"] == 0``), and B's proof still verifies
  and matches the pinned golden bytes.  This is what makes a restarted
  prover service warm (tentpole of the depth/T-invariant compile work).
"""
import hashlib
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def _run_child(code: str, cache_dir: str) -> dict:
    """Run ``code`` in a fresh interpreter with the exec cache pointed
    at ``cache_dir``; the child must print one JSON object on stdout."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["ZKDL_EXEC_CACHE"] = cache_dir
    env.pop("ZKDL_EXEC_MODE", None)
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=1200)
    assert proc.returncode == 0, \
        f"child failed:\n{proc.stdout[-1000:]}\n{proc.stderr[-3000:]}"
    return json.loads(proc.stdout.splitlines()[-1])


# ---------------------------------------------------------------------------
# Unit: registry, keys, fallbacks
# ---------------------------------------------------------------------------

def test_registry_hit_and_stats(monkeypatch, tmp_path):
    import jax.numpy as jnp
    from repro.core import execache

    monkeypatch.setenv("ZKDL_EXEC_CACHE", str(tmp_path))
    fn = execache.wrap("t_add1", lambda x: x + 1)
    execache.reset_stats()
    x = jnp.arange(8, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(fn(x)), np.arange(1, 9))
    s1 = execache.stats()
    assert s1["misses"] == 1 and s1["disk_writes"] == 1
    fn(x)                                   # same shape: registry hit
    s2 = execache.stats()
    assert s2["hits"] == s1["hits"] + 1 and s2["misses"] == 1
    fn(jnp.arange(16, dtype=jnp.int32))     # new shape: new executable
    assert execache.stats()["misses"] == 2


def test_static_args_partition_the_key(monkeypatch, tmp_path):
    import jax.numpy as jnp
    from repro.core import execache

    monkeypatch.setenv("ZKDL_EXEC_CACHE", str(tmp_path))
    fn = execache.wrap("t_scale", lambda x, k: x * k,
                       static_argnames=("k",))
    execache.reset_stats()
    x = jnp.arange(4, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(fn(x, k=2)), [0, 2, 4, 6])
    np.testing.assert_array_equal(np.asarray(fn(x, k=3)), [0, 3, 6, 9])
    assert execache.stats()["misses"] == 2  # distinct statics, two exes


def test_disabled_mode_falls_back_to_jit(monkeypatch):
    import jax.numpy as jnp
    from repro.core import execache

    monkeypatch.setenv("ZKDL_EXEC_MODE", "off")
    fn = execache.wrap("t_off", lambda x: x * 2)
    execache.reset_stats()
    np.testing.assert_array_equal(
        np.asarray(fn(jnp.arange(4, dtype=jnp.int32))), [0, 2, 4, 6])
    assert execache.stats() == {"hits": 0, "misses": 0, "disk_hits": 0,
                                "disk_writes": 0, "disk_corrupt": 0}


def test_corrupt_disk_entry_is_a_miss_not_a_crash(monkeypatch, tmp_path):
    """PR 8 robustness contract: a truncated/corrupt serialized
    executable (crashed writer, bit rot, the chaos harness's
    ``corrupt-cache`` fault) is treated as a MISS — counted, the bad
    file dropped, the program recompiled and REWRITTEN so the next cold
    start loads warm again."""
    import jax.numpy as jnp
    from repro.core import execache

    monkeypatch.setenv("ZKDL_EXEC_CACHE", str(tmp_path))
    fn = execache.wrap("t_corrupt", lambda x: x - 3)
    execache.reset_stats()
    x = jnp.arange(6, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(fn(x)), np.arange(-3, 3))
    assert execache.stats()["disk_writes"] == 1
    entries = [f for f in os.listdir(execache.cache_dir())
               if f.endswith(".exe.pkl")]
    assert len(entries) == 1
    path = os.path.join(execache.cache_dir(), entries[0])
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)

    execache.clear()                    # force the disk-load path
    execache.reset_stats()
    np.testing.assert_array_equal(np.asarray(fn(x)), np.arange(-3, 3))
    s = execache.stats()
    assert s["disk_corrupt"] == 1 and s["misses"] == 1 \
        and s["disk_hits"] == 0 and s["disk_writes"] == 1, s

    execache.clear()                    # rewritten entry must load clean
    execache.reset_stats()
    np.testing.assert_array_equal(np.asarray(fn(x)), np.arange(-3, 3))
    s = execache.stats()
    assert s["disk_hits"] == 1 and s["misses"] == 0 \
        and s["disk_corrupt"] == 0, s


def test_tracer_args_inline_into_outer_jit(monkeypatch, tmp_path):
    """A wrapped function traced inside another jitted program must
    inline (a Compiled can't consume tracers) and still be correct."""
    import jax
    import jax.numpy as jnp
    from repro.core import execache

    monkeypatch.setenv("ZKDL_EXEC_CACHE", str(tmp_path))
    inner = execache.wrap("t_inner", lambda x: x + 5)

    @jax.jit
    def outer(x):
        return inner(x) * 2

    np.testing.assert_array_equal(
        np.asarray(outer(jnp.arange(3, dtype=jnp.int32))), [10, 12, 14])


def test_disk_roundtrip_into_fresh_process(tmp_path):
    """An executable serialized by one process must load and RUN in a
    different process: write in child A, consume in child B with zero
    misses.  Catches non-portable serializations (e.g. executables that
    came out of the XLA persistent cache carry no object code)."""
    code = """
    import json
    import numpy as np
    import jax.numpy as jnp
    from repro.core import execache
    fn = execache.wrap("t_xproc", lambda x: (x * x + 1).sum())
    execache.reset_stats()
    out = int(fn(jnp.arange(32, dtype=jnp.int64)))
    print(json.dumps({"out": out, "stats": execache.stats()}))
    """
    a = _run_child(code, str(tmp_path))
    want = int(sum(i * i + 1 for i in range(32)))
    assert a["out"] == want
    assert a["stats"]["misses"] == 1 and a["stats"]["disk_writes"] == 1
    b = _run_child(code, str(tmp_path))
    assert b["out"] == want
    assert b["stats"]["misses"] == 0, \
        f"fresh process re-compiled despite populated disk: {b['stats']}"
    assert b["stats"]["disk_hits"] == 1


# ---------------------------------------------------------------------------
# Integration: cross-process warm prover start
# ---------------------------------------------------------------------------

# the golden byte digest pinned in tests/test_proofio.py for the seed-7
# uniform T=1 trajectory — process B must reproduce it from a cold start
GOLDEN_SHA256_T1 = \
    "a538160f1da619bd39439420f78d24af9089dd1eacd770f3ce24d76dd80c2422"

_PROVE_CHILD = """
import hashlib, json
import numpy as np
from repro.core import execache
from repro.core.quantfc import QuantConfig, synthetic_sgd_trajectory
from repro.core.pipeline import (PipelineConfig, encode_proof, make_keys,
                                 prove_session, verify_session)
cfg = PipelineConfig(n_layers=2, batch=2, width=4, q_bits=16, r_bits=4,
                     n_steps=1)
keys = make_keys(cfg)
wits = synthetic_sgd_trajectory(1, 2, 2, 4,
                                QuantConfig(q_bits=16, r_bits=4), seed=7)
execache.reset_stats()
proof = prove_session(keys, wits, np.random.default_rng(7))
print(json.dumps({
    "stats": execache.stats(),
    "sha": hashlib.sha256(encode_proof(proof)).hexdigest(),
    "verified": bool(verify_session(keys, proof)),
}))
"""


def test_cross_process_warm_start():
    """Process B (a fresh interpreter) reconstructs the ProvingKey for a
    config process A already proved and proves WITHOUT a single
    executable-cache miss — no re-trace, no re-lower, no re-compile of
    any wrapped program — and its proof verifies and matches the golden
    bytes.  Uses the session's real cache directory (default or
    $ZKDL_EXEC_CACHE): populating it is process A's job, and the suite
    itself plays process A on a genuinely cold machine."""
    from repro.core import execache

    if not (execache.enabled() and execache.cache_dir() is not None):
        pytest.skip("executable disk cache disabled in this environment")
    env_dir = os.environ.get("ZKDL_EXEC_CACHE") or os.path.join(
        os.path.expanduser("~"), ".cache", "zkdl-exec")

    # process A: prove once (fills any disk gaps for this geometry)
    a = _run_child(_PROVE_CHILD, env_dir)
    assert a["verified"] and a["sha"] == GOLDEN_SHA256_T1

    # process B: fresh interpreter, same config — must start warm
    b = _run_child(_PROVE_CHILD, env_dir)
    assert b["stats"]["misses"] == 0, (
        f"fresh process re-traced {b['stats']['misses']} programs "
        f"(warm-start contract broken): {b['stats']}")
    assert b["stats"]["disk_hits"] > 0
    assert b["verified"], "warm-started proof rejected"
    assert b["sha"] == GOLDEN_SHA256_T1, \
        "warm-started proof bytes diverge from the golden digest"
