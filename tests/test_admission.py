"""Unit contracts for `launch/admission` — the gateway's control plane
(weighted-fair queue, circuit breaker, directory lock).  These are pure
threading/stdlib units: no jax, no prover — deterministic by
construction (fake clocks, no real sleeps), so the gateway chaos suite
can lean on timing-free guarantees proved here."""
import json
import os
import subprocess
import sys
import threading

import pytest

from repro.launch import admission
from repro.launch.admission import (CircuitBreaker, GatewayBusyError,
                                    ServiceClosedError, WeightedFairQueue,
                                    acquire_dir_lock, release_dir_lock)


# ---------------------------------------------------------------------------
# WeightedFairQueue: stride scheduling
# ---------------------------------------------------------------------------

def _drain_order(q):
    out = []
    while True:
        got = q.pop(timeout=0.0)
        if got is None:
            return out
        out.append(got)


def test_weights_drive_dispatch_ratio():
    q = WeightedFairQueue()
    q.add_tenant("heavy", weight=2.0)
    q.add_tenant("light", weight=1.0)
    for i in range(6):
        q.push("heavy", f"h{i}")
    for i in range(3):
        q.push("light", f"l{i}")
    names = [n for n, _ in _drain_order(q)]
    # in any prefix, heavy gets ~2x light's dispatches (stride property)
    for k in range(3, 10):
        h = names[:k].count("heavy")
        lt = names[:k].count("light")
        assert h >= lt, f"prefix {k}: heavy={h} light={lt}"
    assert names.count("heavy") == 6 and names.count("light") == 3


def test_flooding_tenant_cannot_starve_others():
    q = WeightedFairQueue()
    q.add_tenant("spam", weight=1.0)
    q.add_tenant("vip", weight=1.0)
    for i in range(50):
        q.push("spam", i)
    q.push("vip", "a")
    q.push("vip", "b")
    names = [n for n, _ in (q.pop(timeout=0.0) for _ in range(4))]
    # both vip items dispatch within the first few slots, not after the
    # 50-deep spam backlog
    assert names.count("vip") == 2, names


def test_idle_tenant_banks_no_credit():
    q = WeightedFairQueue()
    q.add_tenant("a", weight=1.0)
    q.add_tenant("b", weight=1.0)
    for i in range(10):                 # a works alone for a while
        q.push("a", i)
        q.pop(timeout=0.0)
    q.push("a", "x")
    q.push("b", "y")                    # b was idle: re-enters at gvt
    names = [n for n, _ in (q.pop(timeout=0.0) for _ in range(2))]
    # b gets ONE fair slot, not ten banked ones; both drain promptly
    assert sorted(names) == ["a", "b"]


def test_items_within_tenant_stay_fifo():
    q = WeightedFairQueue()
    q.add_tenant("t")
    for i in range(5):
        q.push("t", i)
    assert [it for _, it in _drain_order(q)] == [0, 1, 2, 3, 4]


def test_requeue_goes_to_front():
    q = WeightedFairQueue()
    q.add_tenant("t")
    q.push("t", 1)
    q.push("t", 2)
    q.requeue("t", 0)                   # a reclaimed in-flight item
    assert [it for _, it in _drain_order(q)] == [0, 1, 2]


def test_duplicate_or_invalid_tenant_rejected():
    q = WeightedFairQueue()
    q.add_tenant("t")
    with pytest.raises(ValueError):
        q.add_tenant("t")
    with pytest.raises(ValueError):
        q.add_tenant("zero", weight=0)


# ---------------------------------------------------------------------------
# WeightedFairQueue: capacity + priority load-shedding
# ---------------------------------------------------------------------------

def test_shed_victim_is_lowest_priority_newest_item():
    q = WeightedFairQueue(capacity=2)
    q.add_tenant("lo", priority=0)
    q.add_tenant("hi", priority=1)
    q.push("lo", "old")
    q.push("lo", "new")
    shed = q.push("hi", "urgent")       # hi preempts lo's NEWEST item
    assert shed == [("lo", "new")]
    assert q.depth("hi") == 1 and q.depth("lo") == 1


def test_equal_priority_sheds_the_push_itself():
    q = WeightedFairQueue(capacity=1)
    q.add_tenant("a", priority=0)
    q.add_tenant("b", priority=0)
    q.push("a", "x")
    shed = q.push("b", "y")             # equals never preempt equals
    assert shed == [("b", "y")]
    assert q.depth("a") == 1 and q.depth("b") == 0


def test_force_push_bypasses_capacity():
    q = WeightedFairQueue(capacity=1)
    q.add_tenant("t")
    q.push("t", "x")
    assert q.push("t", "replayed", force=True) == []
    assert q.depth() == 2


def test_unbounded_queue_never_sheds():
    q = WeightedFairQueue(capacity=0)
    q.add_tenant("t")
    for i in range(100):
        assert q.push("t", i) == []
    assert q.depth() == 100


# ---------------------------------------------------------------------------
# WeightedFairQueue: drain
# ---------------------------------------------------------------------------

def test_drain_unblocks_waiters_and_rejects_push():
    q = WeightedFairQueue()
    q.add_tenant("t")
    got = []
    th = threading.Thread(target=lambda: got.append(q.pop(timeout=30)))
    th.start()
    q.drain()
    th.join(5)
    assert not th.is_alive() and got == [None]
    with pytest.raises(ServiceClosedError):
        q.push("t", "late")
    q.requeue("t", "inflight")          # reclaim still allowed mid-drain
    assert q.pop(timeout=0.0) == ("t", "inflight")


# ---------------------------------------------------------------------------
# CircuitBreaker (fake clock: no sleeps)
# ---------------------------------------------------------------------------

class Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_breaker_trips_after_threshold_consecutive_failures():
    cb = CircuitBreaker(threshold=3, reset_s=10.0, clock=Clock())
    assert cb.allow() == "proceed"
    assert cb.record_failure() is False
    assert cb.record_failure() is False
    assert cb.state == "closed"
    assert cb.record_failure() is True          # third consecutive: trip
    assert cb.state == "open" and cb.trips == 1
    assert cb.allow() == "defer"


def test_success_resets_consecutive_count():
    cb = CircuitBreaker(threshold=2, reset_s=10.0, clock=Clock())
    cb.record_failure()
    cb.record_success()
    assert cb.record_failure() is False         # count restarted
    assert cb.state == "closed"


def test_half_open_single_trial_then_close_or_reopen():
    clock = Clock()
    cb = CircuitBreaker(threshold=1, reset_s=5.0, clock=clock)
    cb.record_failure()                         # trip
    assert cb.allow() == "defer"
    clock.t = 5.0
    assert cb.ready_for_trial
    assert cb.allow() == "trial"                # exactly one probe
    assert cb.allow() == "defer"                # while trial in flight
    assert not cb.ready_for_trial
    cb.record_success()
    assert cb.state == "closed"
    # trip again; this time the trial FAILS -> re-open for another reset
    cb.record_failure()
    clock.t = 10.0
    assert cb.allow() == "trial"
    assert cb.record_failure() is True
    # re-opening from a failed trial is a fresh trip (3rd transition)
    assert cb.state == "open" and cb.trips == 3
    clock.t = 14.9
    assert cb.allow() == "defer"
    clock.t = 15.0
    assert cb.allow() == "trial"


def test_breaker_threshold_validation():
    with pytest.raises(ValueError):
        CircuitBreaker(threshold=0)


# ---------------------------------------------------------------------------
# Directory lock
# ---------------------------------------------------------------------------

def test_lock_round_trip_and_busy(tmp_path):
    d = str(tmp_path)
    path = acquire_dir_lock(d)
    assert os.path.exists(path)
    with open(path) as f:
        assert json.load(f)["pid"] == os.getpid()
    # a second gateway in the SAME process is just as corrupting as a
    # second process: the held-dir registry blocks it
    with pytest.raises(GatewayBusyError):
        acquire_dir_lock(d)
    release_dir_lock(path)
    assert not os.path.exists(path)
    release_dir_lock(path)              # idempotent


def test_own_pid_leftover_without_registry_entry_is_stolen(tmp_path):
    """A lockfile recording OUR pid that this process does not hold (a
    crashed-and-restarted gateway whose pid was recycled) is stale."""
    d = str(tmp_path)
    with open(os.path.join(d, admission.LOCKFILE), "w") as f:
        json.dump({"pid": os.getpid(), "t": 0}, f)
    path = acquire_dir_lock(d)
    with open(path) as f:
        assert json.load(f)["pid"] == os.getpid()
    release_dir_lock(path)


def test_lock_held_by_live_foreign_pid_raises(tmp_path):
    d = str(tmp_path)
    path = os.path.join(d, admission.LOCKFILE)
    # pid 1 is alive on any linux box and is never us
    with open(path, "w") as f:
        json.dump({"pid": 1, "t": 0}, f)
    with pytest.raises(GatewayBusyError):
        acquire_dir_lock(d)
    assert os.path.exists(path)         # the owner's lock is untouched


def test_stale_dead_pid_lock_is_stolen(tmp_path):
    d = str(tmp_path)
    proc = subprocess.run([sys.executable, "-c",
                           "import os; print(os.getpid())"],
                          capture_output=True, text=True, check=True)
    dead_pid = int(proc.stdout.strip())
    with open(os.path.join(d, admission.LOCKFILE), "w") as f:
        json.dump({"pid": dead_pid, "t": 0}, f)
    path = acquire_dir_lock(d)          # SIGKILLed owner: steal
    with open(path) as f:
        assert json.load(f)["pid"] == os.getpid()
    release_dir_lock(path)


def test_unreadable_lock_is_stolen(tmp_path):
    d = str(tmp_path)
    with open(os.path.join(d, admission.LOCKFILE), "w") as f:
        f.write("{torn")
    path = acquire_dir_lock(d)
    with open(path) as f:
        assert json.load(f)["pid"] == os.getpid()
    release_dir_lock(path)


def test_release_refuses_foreign_lock(tmp_path):
    d = str(tmp_path)
    path = os.path.join(d, admission.LOCKFILE)
    with open(path, "w") as f:
        json.dump({"pid": 1, "t": 0}, f)
    release_dir_lock(path)
    assert os.path.exists(path)         # not ours: left alone
