"""Per-architecture smoke tests: reduced config, one forward/train step and
one decode step on CPU; asserts output shapes and absence of NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, smoke_config
from repro.models import transformer
from repro.models.config import ModelConfig

LM_ARCHS = [a for a in ARCHS if a != "fcnn_zkdl_16l"]
B, S = 2, 32


def make_batch(cfg: ModelConfig, rng):
    if cfg.family == "vlm":
        return {
            "embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                  jnp.float32),
            "positions3": jnp.asarray(
                np.broadcast_to(np.arange(S, dtype=np.int32), (3, B, S))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32),
        }
    if cfg.family == "encdec":
        return {
            "frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                  jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_and_grad(arch):
    cfg = smoke_config(arch)
    rng = np.random.default_rng(0)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)

    loss, grads = jax.value_and_grad(
        lambda p: transformer.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss)), arch
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat), arch
    logits, _ = transformer.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_step(arch):
    cfg = smoke_config(arch)
    if cfg.family == "encdec":
        pytest.skip("encdec decode covered in test_encdec_decode")
    params = transformer.init_params(cfg, jax.random.PRNGKey(1))
    cache = transformer.make_cache(cfg, B, S)
    if cfg.family == "vlm":
        tok = jnp.zeros((B, 1, cfg.d_model), jnp.float32)
        pos3 = jnp.zeros((3, B, 1), jnp.int32)
        logits, new_cache = transformer.decode_step(cfg, params, cache, tok,
                                                    0, positions3=pos3)
    else:
        tok = jnp.zeros((B,), jnp.int32)
        logits, new_cache = transformer.decode_step(cfg, params, cache, tok, 0)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_encdec_decode():
    cfg = smoke_config("seamless_m4t_medium")
    params = transformer.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    batch = make_batch(cfg, rng)
    # prefill: encoder output feeds the cross-attention caches
    logits, (enc_out, _) = transformer.forward(cfg, params, batch,
                                               collect_cache=True)
    cache = transformer.make_cache(cfg, B, S)
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    for i in range(cfg.dec_layers):
        blk = jax.tree.map(lambda p: p[i], params["dec"])
        xk = (enc_out @ blk["cross"]["wk"].astype(enc_out.dtype)).reshape(
            B, S, kv, dh)
        xv = (enc_out @ blk["cross"]["wv"].astype(enc_out.dtype)).reshape(
            B, S, kv, dh)
        cache["xk"] = cache["xk"].at[i].set(xk.astype(cache["xk"].dtype))
        cache["xv"] = cache["xv"].at[i].set(xv.astype(cache["xv"].dtype))
    tok = jnp.zeros((B,), jnp.int32)
    logits, new_cache = transformer.decode_step(cfg, params, cache, tok, 0)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_ssm_decode_matches_scan():
    """Mamba2 decode recurrence must agree with the chunked SSD scan."""
    cfg = smoke_config("mamba2_2p7b")
    cfg = dataclasses.replace(cfg, n_layers=1)
    params = transformer.init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    T = 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, T)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    logits_scan, _ = transformer.forward(cfg, params, batch)

    cache = transformer.make_cache(cfg, 1, T)
    outs = []
    for t in range(T):
        lg, cache = transformer.decode_step(cfg, params, cache, tokens[:, t], t)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_scan, np.float32),
                               np.asarray(logits_dec, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_gqa_decode_matches_full():
    """Dense GQA decode with cache must agree with full-sequence attention."""
    cfg = smoke_config("qwen3_0p6b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    T = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, T)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    logits_full, _ = transformer.forward(cfg, params, batch)
    cache = transformer.make_cache(cfg, 1, T)
    outs = []
    for t in range(T):
        lg, cache = transformer.decode_step(cfg, params, cache, tokens[:, t], t)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_full, np.float32),
                               np.asarray(logits_dec, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_param_counts_sane():
    from repro.configs.registry import get_config
    approx = {
        "qwen3_0p6b": 0.6e9, "internlm2_1p8b": 1.8e9,
        "starcoder2_15b": 15e9, "deepseek_7b": 7e9, "grok1_314b": 314e9,
        "deepseek_v2_lite_16b": 16e9, "mamba2_2p7b": 2.7e9,
        "zamba2_2p7b": 2.7e9,
    }
    for arch, target in approx.items():
        got = get_config(arch).param_count()
        assert 0.4 * target < got < 2.6 * target, (arch, got, target)
