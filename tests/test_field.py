"""Field layer tests: limb Montgomery arithmetic vs python-int oracle.

Property-based (hypothesis) variants live in test_property_based.py so
this module collects in environments without dev extras installed."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.field import (
    FQ, FP, add, sub, neg, mont_mul, inv, batch_inv, pow_const,
    encode_ints, decode, encode_int, from_mont, to_mont, ints_to_limbs,
    limbs_to_ints,
)

SPECS = [FQ, FP]


def enc(spec, xs):
    return jnp.asarray(encode_ints(spec, np.array(xs, dtype=object)))


def dec(spec, a):
    return decode(spec, np.asarray(a))


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_roundtrip(spec):
    vals = [0, 1, 2, spec.modulus - 1, 123456789, 2**60]
    a = enc(spec, vals)
    back = dec(spec, a)
    assert [int(x) for x in back] == [v % spec.modulus for v in vals]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_add_sub_mul_known(spec):
    rng = np.random.default_rng(0)
    m = spec.modulus
    xs = [int(rng.integers(0, 2**61)) % m for _ in range(64)]
    ys = [int(rng.integers(0, 2**61)) % m for _ in range(64)]
    a, b = enc(spec, xs), enc(spec, ys)
    assert [int(v) for v in dec(spec, add(spec, a, b))] == [(x + y) % m for x, y in zip(xs, ys)]
    assert [int(v) for v in dec(spec, sub(spec, a, b))] == [(x - y) % m for x, y in zip(xs, ys)]
    assert [int(v) for v in dec(spec, mont_mul(spec, a, b))] == [(x * y) % m for x, y in zip(xs, ys)]
    assert [int(v) for v in dec(spec, neg(spec, a))] == [(-x) % m for x in xs]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_edge_values(spec):
    m = spec.modulus
    edge = [0, 1, m - 1, m - 2, 2**16 - 1, 2**32 - 1, 2**48 - 1, m // 2]
    a = enc(spec, edge)
    for i, x in enumerate(edge):
        for j, y in enumerate(edge):
            got = int(dec(spec, mont_mul(spec, a[i], a[j]))[()])
            assert got == (x * y) % m, (x, y)
    s = int(dec(spec, add(spec, a[2], a[2]))[()])
    assert s == (2 * (m - 1)) % m


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_inv_and_pow(spec):
    rng = np.random.default_rng(1)
    m = spec.modulus
    xs = [int(rng.integers(1, 2**60)) for _ in range(8)]
    a = enc(spec, xs)
    ia = inv(spec, a)
    prod = mont_mul(spec, a, ia)
    assert all(int(v) == 1 for v in dec(spec, prod))
    p5 = pow_const(spec, a, 5)
    assert [int(v) for v in dec(spec, p5)] == [pow(x, 5, m) for x in xs]
    p0 = pow_const(spec, a, 0)
    assert all(int(v) == 1 for v in dec(spec, p0))


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_batch_inv(spec):
    rng = np.random.default_rng(2)
    xs = [int(rng.integers(1, spec.modulus)) for _ in range(33)]
    a = enc(spec, xs)
    b = batch_inv(spec, a)
    m = spec.modulus
    assert [int(v) for v in dec(spec, b)] == [pow(x, m - 2, m) for x in xs]


def test_limb_roundtrip_multidim():
    rng = np.random.default_rng(3)
    vals = np.array([[int(rng.integers(0, 2**61)) for _ in range(3)]
                     for _ in range(2)], dtype=object)
    limbs = ints_to_limbs(vals)
    assert limbs.shape == (2, 3, 4)
    back = limbs_to_ints(limbs)
    assert (back == vals).all()


def test_mont_form_identity():
    a = enc(FQ, [7])
    std = from_mont(FQ, a)
    again = to_mont(FQ, std)
    assert (np.asarray(a) == np.asarray(again)).all()
