"""True elastic rescale, end to end: a training job checkpointed on a
4x2 mesh resumes on a 2x2 mesh (half the devices) and completes.

Needs forced host devices before jax init -> subprocess, like the
dry-run entry point.  XLA-CPU's forced-host-device runtime intermittently
corrupts the glibc heap (a native jax/XLA flake, reproduced on the
pristine seed): reliably at PROCESS TEARDOWN after the work completed
(malloc_consolidate aborts that would discard the buffered success
marker), and occasionally mid-run when one process switches meshes.
The test therefore (a) runs each mesh phase in its OWN subprocess — a
production rescale is a new process anyway — and (b) has each phase
flush its marker and `os._exit(0)` past the doomed teardown.  The
`flaky_subprocess` quarantine + signal-death-only retry policy
(conftest.py) remains as the backstop for the rarer mid-run crashes.
"""
import os
import shutil
import sys

import pytest

_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import sys
from repro.launch import train as train_mod

ckpt = sys.argv[1]
base = ["--arch", "qwen3-0.6b", "--layers", "2", "--d-model", "128",
        "--seq", "64", "--global-batch", "4",
        "--ckpt-dir", ckpt, "--ckpt-every", "3", "--log-every", "2"]
"""

# phase 1: 4x2 mesh, die at step 5 (checkpoint exists at step 3); the
# resilient loop restarts and completes on 4x2
SCRIPT_P1 = _PRELUDE + r"""
try:
    train_mod.main(base + ["--steps", "8", "--mesh", "4x2",
                           "--fail-at", "5"])
except Exception:
    pass
print("PHASE1_OK", flush=True)
os._exit(0)    # skip interpreter/runtime teardown (native heap flake)
"""

# phase 2 (the elastic part): a FRESH process resumes the SAME
# checkpoint dir on 2x2, extending the run -- restore re-places leaves
# under the new, smaller mesh
SCRIPT_P2 = _PRELUDE + r"""
train_mod.main(base + ["--steps", "12", "--mesh", "2x2"])
print("ELASTIC_OK", flush=True)
os._exit(0)    # skip interpreter/runtime teardown (native heap flake)
"""


@pytest.mark.flaky_subprocess(retries=6)
def test_elastic_restart_smaller_mesh(tmp_path, run_flaky_subprocess):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # single-threading the host BLAS lowers the native crash rate
    env.setdefault("OMP_NUM_THREADS", "1")
    env.setdefault("OPENBLAS_NUM_THREADS", "1")
    ckpt_used = {}

    def fresh_ckpt(attempt):
        ckpt = str(tmp_path / f"elastic{attempt}")
        shutil.rmtree(ckpt, ignore_errors=True)
        ckpt_used["dir"] = ckpt
        return [ckpt]

    p1 = run_flaky_subprocess(
        [sys.executable, "-c", SCRIPT_P1], attempt_setup=fresh_ckpt,
        env=env, capture_output=True, text=True, timeout=900)
    assert "PHASE1_OK" in p1.stdout, (
        f"returncode: {p1.returncode}\n"
        f"stdout:\n{p1.stdout[-2000:]}\nstderr:\n{p1.stderr[-3000:]}")

    # retries of phase 2 reuse phase 1's checkpoint dir (restore is
    # read-only on the committed step directories)
    p2 = run_flaky_subprocess(
        [sys.executable, "-c", SCRIPT_P2],
        attempt_setup=lambda attempt: [ckpt_used["dir"]],
        env=env, capture_output=True, text=True, timeout=900)
    assert "ELASTIC_OK" in p2.stdout, (
        f"returncode: {p2.returncode}\n"
        f"stdout:\n{p2.stdout[-2000:]}\nstderr:\n{p2.stderr[-3000:]}")
