"""True elastic rescale, end to end: a training job checkpointed on a
4x2 mesh resumes on a 2x2 mesh (half the devices) and completes.

Needs forced host devices before jax init -> subprocess, like the
dry-run entry point.  The subprocess intermittently SIGABRTs with glibc
heap corruption inside XLA-CPU's forced-host-device cross-mesh restore
(a native jax/XLA flake, reproduced on the pristine seed) — hence the
`flaky_subprocess` quarantine marker; the signal-death-only retry
policy lives in conftest.py.
"""
import os
import shutil
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import sys
from repro.launch import train as train_mod

ckpt = sys.argv[1]
base = ["--arch", "qwen3-0.6b", "--layers", "2", "--d-model", "128",
        "--steps", "8", "--seq", "64", "--global-batch", "4",
        "--ckpt-dir", ckpt, "--ckpt-every", "3", "--log-every", "2"]
# phase 1: 4x2 mesh, die at step 5 (checkpoint exists at step 3)
try:
    train_mod.main(base + ["--mesh", "4x2", "--fail-at", "5"])
except Exception:
    pass
# ... the resilient loop already restarted and completed on 4x2.
# phase 2 (the elastic part): resume the SAME checkpoint dir on 2x2,
# extending the run -- restore re-places leaves under the new mesh.
train_mod.main([a if a != "8" else "12" for a in base] + ["--mesh", "2x2"])
print("ELASTIC_OK")
"""


@pytest.mark.flaky_subprocess(retries=3)
def test_elastic_restart_smaller_mesh(tmp_path, run_flaky_subprocess):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # single-threading the host BLAS lowers the native crash rate
    env.setdefault("OMP_NUM_THREADS", "1")
    env.setdefault("OPENBLAS_NUM_THREADS", "1")

    def fresh_ckpt(attempt):
        ckpt = str(tmp_path / f"elastic{attempt}")
        shutil.rmtree(ckpt, ignore_errors=True)
        return [ckpt]

    proc = run_flaky_subprocess(
        [sys.executable, "-c", SCRIPT], attempt_setup=fresh_ckpt, env=env,
        capture_output=True, text=True, timeout=900)
    assert "ELASTIC_OK" in proc.stdout, (
        f"returncode: {proc.returncode}\n"
        f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-3000:]}")
