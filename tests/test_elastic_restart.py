"""True elastic rescale, end to end: a training job checkpointed on a
4x2 mesh resumes on a 2x2 mesh (half the devices) and completes.

Needs forced host devices before jax init -> subprocess, like the
dry-run entry point.
"""
import os
import shutil
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import sys
from repro.launch import train as train_mod

ckpt = sys.argv[1]
base = ["--arch", "qwen3-0.6b", "--layers", "2", "--d-model", "128",
        "--steps", "8", "--seq", "64", "--global-batch", "4",
        "--ckpt-dir", ckpt, "--ckpt-every", "3", "--log-every", "2"]
# phase 1: 4x2 mesh, die at step 5 (checkpoint exists at step 3)
try:
    train_mod.main(base + ["--mesh", "4x2", "--fail-at", "5"])
except Exception:
    pass
# ... the resilient loop already restarted and completed on 4x2.
# phase 2 (the elastic part): resume the SAME checkpoint dir on 2x2,
# extending the run -- restore re-places leaves under the new mesh.
train_mod.main([a if a != "8" else "12" for a in base] + ["--mesh", "2x2"])
print("ELASTIC_OK")
"""


def test_elastic_restart_smaller_mesh(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # XLA's forced-host-device path intermittently aborts with glibc
    # heap corruption ("malloc_consolidate(): invalid chunk size",
    # SIGABRT) during the cross-mesh restore -- a native jax/XLA-CPU
    # flake, not a repo regression.  Single-threading the host BLAS
    # lowers the crash rate; retry the subprocess on signal deaths
    # only -- real assertion failures (missing ELASTIC_OK with a clean
    # exit) are never retried.
    env.setdefault("OMP_NUM_THREADS", "1")
    env.setdefault("OPENBLAS_NUM_THREADS", "1")
    for attempt in range(3):
        ckpt = str(tmp_path / f"elastic{attempt}")
        shutil.rmtree(ckpt, ignore_errors=True)
        proc = subprocess.run([sys.executable, "-c", SCRIPT, ckpt], env=env,
                              capture_output=True, text=True, timeout=900)
        if proc.returncode >= 0 or attempt == 2:
            break
        print(f"[elastic] native crash (rc={proc.returncode}); retrying")
    assert "ELASTIC_OK" in proc.stdout, (
        f"returncode: {proc.returncode}\n"
        f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-3000:]}")
