"""Fold backend dispatch: Pallas fold_planes vs the pure-jnp
`repro.core.mle.fold` must agree bit-exactly across sizes, on both the
interpret path and the jnp fallback, and `sumcheck_prove` must emit an
identical transcript whichever backend folds its tables."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.field import FQ, modarith, decode
from repro.core import mle
from repro.core.mle import enc
from repro.core.sumcheck import sumcheck_prove, sumcheck_verify
from repro.core.transcript import Transcript
from repro.kernels.sumcheck_fold import fold as pallas_fold
from repro.kernels.sumcheck_fold.kernel import fold_planes
from repro.kernels.limb_planes import LANE, NLIMB, pack_planes, unpack_planes

Q = FQ.modulus
RNG = np.random.default_rng(42)


def rand_table(n):
    vals = RNG.integers(0, Q, size=n, dtype=np.uint64)
    return jnp.asarray(modarith.encode_ints(
        FQ, np.array([int(v) % Q for v in vals], dtype=object)))


def rand_r():
    return int(RNG.integers(0, Q, dtype=np.uint64)) % Q


@pytest.fixture(autouse=True)
def _restore_backend():
    yield
    mle.set_fold_backend(None)


@pytest.mark.parametrize("n", [2, 8, 64, 512, 2048])
def test_fold_planes_matches_jnp_fold(n):
    table = rand_table(n)
    r = rand_r()
    want = np.asarray(mle.fold_jnp(table, enc(r)))
    # raw plane-form kernel invocation (interpret mode)
    even, odd = table[0::2], table[1::2]
    ep, _ = pack_planes(even)
    op_, _ = pack_planes(odd)
    r_tile = jnp.broadcast_to(jnp.asarray(enc(r)).reshape(NLIMB, 1, 1),
                              (NLIMB, 1, LANE)).astype(jnp.uint32)
    rows = ep.shape[1]
    out = fold_planes(ep, op_, r_tile, spec=FQ, block_rows=rows,
                      interpret=True)
    got = np.asarray(unpack_planes(out, n // 2))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", [2, 16, 256, 1024])
def test_wrapped_fold_matches_jnp_fold(n):
    table = rand_table(n)
    r_l = enc(rand_r())
    np.testing.assert_array_equal(
        np.asarray(pallas_fold(table, r_l, interpret=True)),
        np.asarray(mle.fold_jnp(table, r_l)))


def test_backend_dispatch_selects_pallas():
    table = rand_table(64)
    r_l = enc(rand_r())
    want = np.asarray(mle.fold_jnp(table, r_l))
    mle.set_fold_backend("pallas")
    got = np.asarray(mle.fold(table, r_l))
    np.testing.assert_array_equal(got, want)
    mle.set_fold_backend("jnp")
    np.testing.assert_array_equal(np.asarray(mle.fold(table, r_l)), want)


def test_backend_env_and_validation(monkeypatch):
    mle.set_fold_backend(None)
    monkeypatch.delenv("ZKDL_FOLD_BACKEND", raising=False)
    assert mle.fold_backend() == "jnp"
    monkeypatch.setenv("ZKDL_FOLD_BACKEND", "pallas")
    assert mle.fold_backend() == "pallas"
    mle.set_fold_backend("jnp")          # override beats the env var
    assert mle.fold_backend() == "jnp"
    with pytest.raises(ValueError):
        mle.set_fold_backend("cuda")
    monkeypatch.setenv("ZKDL_FOLD_BACKEND", "nonsense")
    mle.set_fold_backend(None)
    with pytest.raises(ValueError):
        mle.fold_backend()


def test_sumcheck_transcript_identical_across_backends():
    """The fold backend is a pure implementation detail: proofs, bound
    points and finals must be bit-identical under jnp and pallas."""
    n, arity = 16, 2
    tables = [rand_table(n) for _ in range(arity)]
    products = [tuple(range(arity))]

    mle.set_fold_backend("jnp")
    p_jnp, pt_jnp, fin_jnp = sumcheck_prove(
        [t for t in tables], products, Transcript(b"fd"), b"sc")
    mle.set_fold_backend("pallas")
    p_pal, pt_pal, fin_pal = sumcheck_prove(
        [t for t in tables], products, Transcript(b"fd"), b"sc")

    assert p_jnp.messages == p_pal.messages
    assert pt_jnp == pt_pal
    assert fin_jnp == fin_pal

    # and the proof still verifies with the host-side verifier
    hv = [[int(v) for v in decode(FQ, t)] for t in tables]
    claim = 0
    for i in range(n):
        term = 1
        for k in range(arity):
            term = term * hv[k][i] % Q
        claim = (claim + term) % Q
    point, expected = sumcheck_verify(claim, p_pal, arity, 4,
                                      Transcript(b"fd"), b"sc")
    assert point == pt_pal
    acc = 1
    for f in fin_pal:
        acc = acc * f % Q
    assert expected == acc


def test_eval_mle_via_pallas_backend():
    d = 5
    table = rand_table(1 << d)
    point = [rand_r() for _ in range(d)]
    want = np.asarray(mle.eval_mle(table, point))
    mle.set_fold_backend("pallas")
    got = np.asarray(mle.eval_mle(table, point))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# The unified IPA's halves folds (scalar + generator) through the same
# pallas backend: bit-exact parity against the XLA path.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [4, 64, 512])
def test_fold_halves_matches_xla(n):
    from repro.core import ipa
    from repro.kernels.sumcheck_fold import fold_halves

    table = rand_table(n)
    al = rand_r()
    ali = pow(al, Q - 2, Q)
    want = np.asarray(ipa._fold_halves(table, enc(al), enc(ali)))
    got = np.asarray(fold_halves(table, enc(al), enc(ali), interpret=True))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", [4, 256])
def test_pow_mul_halves_matches_xla_gens_fold(n):
    from repro.core import group, ipa
    from repro.kernels.sumcheck_fold import pow_mul_halves

    gens = group.derive_generators(b"pmh-test", n)
    al = rand_r()
    ali = pow(al, Q - 2, Q)
    want = np.asarray(ipa._fold_gens(gens, ali, al))
    got = np.asarray(pow_mul_halves(gens, ipa._exp1(ali), ipa._exp1(al),
                                    interpret=True))
    np.testing.assert_array_equal(got, want)


def test_ipa_open_transcript_identical_across_backends():
    """The aggregated opening IPA must emit bit-identical proofs under
    both fold backends (same L/R chain, same sigma), and the pallas-side
    proof must verify against the jnp-side verifier."""
    from repro.core import ipa, pedersen
    from repro.field import modarith

    n = 64
    key = pedersen.make_key(b"fd-ipa", n)
    a = rand_table(n)
    b = rand_table(n)
    av = [int(v) for v in decode(FQ, a)]
    bv = [int(v) for v in decode(FQ, b)]
    claim = sum(x * y for x, y in zip(av, bv)) % Q
    blind = rand_r()
    com = pedersen.commit(key, a, blind)

    mle.set_fold_backend("jnp")
    p_jnp = ipa.open_prove(key, a, b, blind, claim, Transcript(b"fdi"),
                           np.random.default_rng(5))
    mle.set_fold_backend("pallas")
    p_pal = ipa.open_prove(key, a, b, blind, claim, Transcript(b"fdi"),
                           np.random.default_rng(5))
    assert (p_jnp.ls, p_jnp.rs, p_jnp.sigma) == \
        (p_pal.ls, p_pal.rs, p_pal.sigma)
    mle.set_fold_backend(None)
    assert ipa.open_verify(key, com, b, claim, p_pal, Transcript(b"fdi"))


# ---------------------------------------------------------------------------
# Compile-O(1) round bodies vs the legacy per-shape schedules: the
# scan-shaped sumcheck and the masked IPA ladder are pure implementation
# detail, so their transcripts must be bit-identical to the unrolled
# paths under BOTH fold backends.
# ---------------------------------------------------------------------------

@pytest.fixture
def _restore_round_modes():
    from repro.core import ipa, sumcheck
    yield
    sumcheck.set_scan_mode(None)
    ipa.set_round_mode(None)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_sumcheck_scan_matches_unrolled(backend, _restore_round_modes):
    """Fixed-shape scan round bodies emit the same messages / bound
    point / finals as the shrinking-shape unrolled prover."""
    from repro.core import sumcheck as sc

    n, arity = 32, 3
    tables = [rand_table(n) for _ in range(arity)]
    products = [(0, 1), (1, 2)]
    mle.set_fold_backend(backend)

    runs = {}
    for mode in sc.SCAN_MODES:
        sc.set_scan_mode(mode)
        runs[mode] = sumcheck_prove([t for t in tables], products,
                                    Transcript(b"scan-par"), b"sc")
    p_s, pt_s, fin_s = runs["scan"]
    p_u, pt_u, fin_u = runs["unrolled"]
    assert p_s.messages == p_u.messages
    assert pt_s == pt_u
    assert fin_s == fin_u


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_ipa_ladder_matches_unrolled(backend, _restore_round_modes):
    """The masked fixed-size ladder folds produce the same L/R chain and
    sigma response as the exact-shape unrolled rounds, and the ladder
    proof verifies."""
    from repro.core import ipa, pedersen

    n = 128
    key = pedersen.make_key(b"ladder-par", n)
    a, b = rand_table(n), rand_table(n)
    av = [int(v) for v in decode(FQ, a)]
    bv = [int(v) for v in decode(FQ, b)]
    claim = sum(x * y for x, y in zip(av, bv)) % Q
    blind = rand_r()
    com = pedersen.commit(key, a, blind)
    mle.set_fold_backend(backend)

    runs = {}
    for mode in ipa.IPA_MODES:
        ipa.set_round_mode(mode)
        runs[mode] = ipa.open_prove(key, a, b, blind, claim,
                                    Transcript(b"lp"),
                                    np.random.default_rng(17))
    lad, unr = runs["ladder"], runs["unrolled"]
    assert (lad.ls, lad.rs, lad.sigma) == (unr.ls, unr.rs, unr.sigma)
    ipa.set_round_mode(None)
    assert ipa.open_verify(key, com, b, claim, lad, Transcript(b"lp"))
