"""Parity tests for the batched commitment engine.

The multi-MSM entry point (`group.msm_many`), the batched Pedersen
commitments (`pedersen.commit_many`) and the vectorized host encoders
must all be BIT-IDENTICAL to their sequential counterparts: the prover
batches purely for dispatch count, and any drift would change transcript
bytes (pinned separately by the golden digests in
tests/test_proof_session.py).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.field import FQ, FP, NLIMB, encode_ints, int_to_limbs, ints_to_limbs
from repro.core import group, pedersen

Q = FQ.modulus
P = FP.modulus


def rand_ints(rng, n, lo=0, hi=Q):
    return [int(v) for v in rng.integers(lo, hi, size=n, dtype=np.uint64)]


def field_vec(vals):
    return jnp.asarray(encode_ints(FQ, np.array(vals, dtype=object)))


# ---------------------------------------------------------------------------
# msm_many == sequential msm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,n", [(1, 4), (3, 16), (7, 33), (2, 128)])
def test_msm_many_matches_sequential_msm(r, n):
    rng = np.random.default_rng(r * 100 + n)
    gens = group.derive_generators(b"batch-msm", n)
    exps = jnp.stack([group.exps_from_ints(rand_ints(rng, n))
                      for _ in range(r)])
    batched = group.msm_many(gens, exps)
    for i in range(r):
        want = group.decode_group(group.msm(gens, exps[i]))
        assert group.decode_group(batched[i]) == want


def test_msm_many_per_row_points_and_zero_exponents():
    rng = np.random.default_rng(5)
    n = 8
    pts = jnp.stack([group.derive_generators(b"batch-a", n),
                     group.derive_generators(b"batch-b", n)])
    rows = [rand_ints(rng, n), [0] * n]     # second row all-zero exps
    exps = jnp.stack([group.exps_from_ints(v) for v in rows])
    batched = group.msm_many(pts, exps)
    for i in range(2):
        want = group.decode_group(group.msm(pts[i], exps[i]))
        assert group.decode_group(batched[i]) == want
    assert group.decode_group(batched[1]) == group.decode_group(
        group.identity())


def test_msm_many_window_override_matches_default():
    rng = np.random.default_rng(6)
    n = 16
    gens = group.derive_generators(b"batch-w", n)
    exps = jnp.stack([group.exps_from_ints(rand_ints(rng, n))
                      for _ in range(3)])
    a = group.msm_many(gens, exps)
    b = group.msm_many(gens, exps, window=8)
    assert group.decode_group_many(a) == group.decode_group_many(b)


# ---------------------------------------------------------------------------
# commit_many == sequential pedersen.commit (blinds included)
# ---------------------------------------------------------------------------

def test_commit_many_matches_sequential_commits():
    rng = np.random.default_rng(7)
    k1 = pedersen.make_key(b"batch-c1", 32)
    k2 = pedersen.make_key(b"batch-c2", 8)
    rows = []
    for key, n in ((k1, 32), (k2, 8), (k1, 16)):   # mixed keys AND lengths
        vals = field_vec(rand_ints(rng, n))
        blind = int(rng.integers(0, Q, dtype=np.uint64))
        rows.append((key, vals, blind))
    rows.append((k2, field_vec(rand_ints(rng, 8)), 0))   # blind-free row
    batched = group.decode_group_many(pedersen.commit_many(rows))
    for got, (key, vals, blind) in zip(batched, rows):
        want = group.decode_group(pedersen.commit(key, vals, blind))
        assert got == want


# ---------------------------------------------------------------------------
# vectorized host encoders == per-element reference
# ---------------------------------------------------------------------------

def test_derive_generators_match_per_element_reference():
    from repro.field import hash_to_int
    label = b"zkdl/gens/parity-check"
    gens = np.asarray(group.derive_generators(label, 9))
    for i in range(9):
        t = max(hash_to_int(label + i.to_bytes(8, "little"), P), 2)
        gm = (t * t % P) * pow(2, 64, P) % P
        np.testing.assert_array_equal(gens[i], int_to_limbs(gm))


def test_exps_from_ints_fast_and_slow_paths_agree():
    small = [0, 1, Q - 1, 12345]                     # int64-range fast path
    big = [Q + 5, -3, 2**200 + 17, Q - 1]            # object fallback
    for vals in (small, big):
        got = np.asarray(group.exps_from_ints(vals))
        for i, v in enumerate(vals):
            np.testing.assert_array_equal(got[i], int_to_limbs(int(v) % Q))


def test_encode_ints_fast_and_slow_paths_agree():
    r = pow(2, 64, Q)
    for vals in ([0, 1, -5, 2**40], [2**100, -(2**90), Q - 1]):
        got = encode_ints(FQ, np.array(vals, dtype=object))
        for i, v in enumerate(vals):
            np.testing.assert_array_equal(got[i],
                                          int_to_limbs(int(v) * r % Q))


def test_ints_to_limbs_negative_and_huge_values():
    vals = np.array([-1, -(2**70), 2**64 - 1, 5], dtype=object)
    got = ints_to_limbs(vals)
    assert got.shape == (4, NLIMB)
    for i, v in enumerate(vals):
        for j in range(NLIMB):
            assert got[i, j] == (int(v) >> (16 * j)) & 0xFFFF
