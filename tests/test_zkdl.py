"""Single-step protocol tests (T=1 `ProofSession`), witness-relation
invariants (chain and residual topologies), and the retired
`repro.core.zkdl` stub contract."""
import numpy as np
import pytest

from repro.core import quantfc
from repro.core.quantfc import QuantConfig, train_step_witness
from repro.core.pipeline import (PipelineConfig, ProofSession, make_keys,
                                 prove_session, verify_session)

CFG = PipelineConfig(n_layers=3, batch=4, width=8, q_bits=16, r_bits=4,
                     n_steps=1)


def make_witness(seed=0, cfg=CFG, skips=None):
    rng = np.random.default_rng(seed)
    qc = QuantConfig(q_bits=cfg.q_bits, r_bits=cfg.r_bits)
    x = quantfc.quantize(rng.uniform(-1, 1, (cfg.batch, cfg.width)), qc)
    y = quantfc.quantize(rng.uniform(-1, 1, (cfg.batch, cfg.width)), qc)
    ws = [quantfc.quantize(rng.uniform(-1, 1, (cfg.width, cfg.width)) * 0.3, qc)
          for _ in range(cfg.n_layers)]
    return train_step_witness(x, y, ws, qc, skips=skips)


@pytest.fixture(scope="module")
def keys():
    return make_keys(CFG)


def test_witness_relations():
    wit = make_witness()
    cfg = wit.cfg
    for l in range(wit.n_layers):
        assert (wit.z[l] == wit.a[l] @ wit.w[l]).all()
        assert (wit.z[l] == (1 << cfg.r_bits) * wit.zpp[l]
                - (1 << (cfg.q_bits + cfg.r_bits - 1)) * wit.b[l]
                + wit.rz[l]).all()
        assert (wit.gw[l] == wit.gz[l].T @ wit.a[l]).all()
    for l in range(wit.n_layers - 1):
        assert (wit.a[l + 1] == (1 - wit.b[l]) * wit.zpp[l]).all()
        assert (wit.ga[l] == wit.gz[l + 1] @ wit.w[l + 1].T).all()
        assert (wit.gz[l] == (1 - wit.b[l]) * wit.gap[l]).all()


def test_residual_witness_relations():
    """Forward skip: layer 3's operand is A^2 + A^1; backward split: the
    gradient of the sum feeds BOTH branches, and gap/rga decompose each
    branch's accumulated total (eq. 5 over the sum)."""
    wit = make_witness(seed=8, skips={3: 1})
    r = wit.a[2] + wit.a[1]                       # residual operand
    assert (wit.z[2] == r @ wit.w[2]).all()       # forward skip
    assert (wit.gw[2] == wit.gz[2].T @ r).all()   # gw over the sum
    scale = 1 << wit.cfg.r_bits
    g_r = wit.gz[2] @ wit.w[2].T                  # gradient of the sum
    # branch act2: only consumer is the residual -> total = g_r
    assert (scale * wit.gap[1] + wit.rga[1] == g_r).all()
    # branch act1: direct path (matmul 2) PLUS the skip
    g_direct = wit.gz[1] @ wit.w[1].T
    assert (scale * wit.gap[0] + wit.rga[0] == g_direct + g_r).all()
    assert (wit.gz[0] == (1 - wit.b[0]) * wit.gap[0]).all()
    assert wit.skips == {3: 1}


def test_residual_skip_validation():
    with pytest.raises(ValueError, match="skip"):
        make_witness(seed=8, skips={2: 1})        # j must be <= l - 2


def test_prove_verify_accepts(keys):
    proof = prove_session(keys, [make_witness(seed=1)],
                          np.random.default_rng(1))
    assert verify_session(keys, proof)
    # proof is compact: well under 100 kB at this toy size
    assert proof.size_bytes() < 100_000


def test_rejects_tampered_gradient(keys):
    wit = make_witness(seed=2)
    wit.gw[1][0, 0] += 1          # forged weight gradient
    proof = prove_session(keys, [wit], np.random.default_rng(2))
    assert not verify_session(keys, proof)


def test_rejects_tampered_relu_mask(keys):
    wit = make_witness(seed=3)
    wit.b[0][0, 0] ^= 1           # flip a ReLU sign bit
    proof = prove_session(keys, [wit], np.random.default_rng(3))
    assert not verify_session(keys, proof)


def test_rejects_tampered_forward(keys):
    wit = make_witness(seed=4)
    wit.zpp[1][0, 0] = (wit.zpp[1][0, 0] + 1) % (1 << (CFG.q_bits - 1))
    proof = prove_session(keys, [wit], np.random.default_rng(4))
    assert not verify_session(keys, proof)


def test_rejects_proof_reuse_other_witness(keys):
    proof = prove_session(keys, [make_witness(seed=5)],
                          np.random.default_rng(5))
    proof2 = prove_session(keys, [make_witness(seed=6)],
                           np.random.default_rng(6))
    proof.ipa_agg = proof2.ipa_agg       # splice a foreign opening
    assert not verify_session(keys, proof)


# ---------------------------------------------------------------------------
# The retired shim: import works, any use raises with a migration hint
# ---------------------------------------------------------------------------

def test_zkdl_stub_raises_with_migration_hint():
    from repro.core import zkdl    # importing the stub itself is fine

    for name in ("ZkdlConfig", "make_keys", "Prover", "prove_step",
                 "verify_step", "verify"):
        with pytest.raises(ImportError, match="repro.core.pipeline"):
            getattr(zkdl, name)
