"""End-to-end tests for the full zkDL protocol (Protocol 2)."""
import numpy as np
import pytest

from repro.core import quantfc, zkdl
from repro.core.quantfc import QuantConfig, train_step_witness

CFG = zkdl.ZkdlConfig(n_layers=3, batch=4, width=8, q_bits=16, r_bits=4)


def make_witness(seed=0, cfg=CFG):
    rng = np.random.default_rng(seed)
    qc = QuantConfig(q_bits=cfg.q_bits, r_bits=cfg.r_bits)
    x = quantfc.quantize(rng.uniform(-1, 1, (cfg.batch, cfg.width)), qc)
    y = quantfc.quantize(rng.uniform(-1, 1, (cfg.batch, cfg.width)), qc)
    ws = [quantfc.quantize(rng.uniform(-1, 1, (cfg.width, cfg.width)) * 0.3, qc)
          for _ in range(cfg.n_layers)]
    return train_step_witness(x, y, ws, qc)


@pytest.fixture(scope="module")
def keys():
    return zkdl.make_keys(CFG)


def test_witness_relations():
    wit = make_witness()
    cfg = wit.cfg
    for l in range(wit.n_layers):
        assert (wit.z[l] == wit.a[l] @ wit.w[l]).all()
        assert (wit.z[l] == (1 << cfg.r_bits) * wit.zpp[l]
                - (1 << (cfg.q_bits + cfg.r_bits - 1)) * wit.b[l]
                + wit.rz[l]).all()
        assert (wit.gw[l] == wit.gz[l].T @ wit.a[l]).all()
    for l in range(wit.n_layers - 1):
        assert (wit.a[l + 1] == (1 - wit.b[l]) * wit.zpp[l]).all()
        assert (wit.ga[l] == wit.gz[l + 1] @ wit.w[l + 1].T).all()
        assert (wit.gz[l] == (1 - wit.b[l]) * wit.gap[l]).all()


def test_prove_verify_accepts(keys):
    rng = np.random.default_rng(1)
    wit = make_witness(seed=1)
    proof = zkdl.prove_step(keys, wit, rng)
    assert zkdl.verify_step(keys, proof)
    # proof is compact: well under 100 kB at this toy size
    assert proof.size_bytes() < 100_000


def test_rejects_tampered_gradient(keys):
    rng = np.random.default_rng(2)
    wit = make_witness(seed=2)
    wit.gw[1][0, 0] += 1          # forged weight gradient
    proof = zkdl.prove_step(keys, wit, rng)
    assert not zkdl.verify_step(keys, proof)


def test_rejects_tampered_relu_mask(keys):
    rng = np.random.default_rng(3)
    wit = make_witness(seed=3)
    wit.b[0][0, 0] ^= 1           # flip a ReLU sign bit
    proof = zkdl.prove_step(keys, wit, rng)
    assert not zkdl.verify_step(keys, proof)


def test_rejects_tampered_forward(keys):
    rng = np.random.default_rng(4)
    wit = make_witness(seed=4)
    wit.zpp[1][0, 0] = (wit.zpp[1][0, 0] + 1) % (1 << (CFG.q_bits - 1))
    proof = zkdl.prove_step(keys, wit, rng)
    assert not zkdl.verify_step(keys, proof)


def test_rejects_proof_reuse_other_witness(keys):
    rng = np.random.default_rng(5)
    proof = zkdl.prove_step(keys, make_witness(seed=5), rng)
    proof2 = zkdl.prove_step(keys, make_witness(seed=6),
                             np.random.default_rng(6))
    proof.ipas["w"] = proof2.ipas["w"]   # splice a foreign opening
    assert not zkdl.verify_step(keys, proof)
