"""Parity + dispatch tests for the zkReLU validity-table kernel.

The kernel package (`repro.kernels.validity_tables`) replaces the old
host-side per-bit python loops: `build_layout` flattens the stacked aux
tensors once, `build_tables` evaluates the eq. (19) a/b vectors for both
validity statements in one dispatch.  These tests pin the three parity
contracts the proof transcript rests on:

* the jnp backend equals the honest python-int oracle (`tables_ref`),
* the pallas backend is BIT-identical to the jnp backend (same uint32
  Montgomery limbs, so backend choice can never alter a transcript),
* the vectorized bit decompositions equal their per-bit definitions.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.field import FQ, decode, encode_ints
from repro.core.zkrelu import bits_signed, bits_unsigned
from repro.kernels import validity_tables as vtab

Q = FQ.modulus

DS, QB, RB = 8, 8, 4


def random_inputs(seed, ds=DS, qb=QB, rb=RB):
    rng = np.random.default_rng(seed)
    lim = 1 << (qb - 1)
    zpp = rng.integers(0, lim, ds).astype(np.int64)
    gap = rng.integers(-lim, lim, ds).astype(np.int64)
    bq = rng.integers(0, 2, ds).astype(np.int64)
    rz = rng.integers(0, 1 << rb, ds).astype(np.int64)
    rga = rng.integers(0, 1 << rb, ds).astype(np.int64)
    layout = vtab.build_layout(zpp, gap, bq, rz, rga, qb, rb)
    n = layout.vals.shape[0]
    k, z_main, z_rem = (int(rng.integers(0, Q)) for _ in range(3))
    e_full = [int(rng.integers(0, Q)) for _ in range(n)]
    es = [int(rng.integers(0, Q)) for _ in range(n)]
    return (zpp, gap, bq, rz, rga), layout, k, z_main, z_rem, e_full, es


@pytest.fixture(autouse=True)
def _restore_backend():
    yield
    vtab.set_backend(None)


def test_layout_geometry():
    vals, layout, *_ = random_inputs(0)
    zpp, gap, bq, rz, rga = vals
    assert layout.n_main == 2 * DS * QB and layout.n_rem == 2 * DS * RB
    n = layout.n_main + layout.n_rem
    for arr in (layout.vals, layout.shift, layout.kmask, layout.kpmask,
                layout.colmask, layout.region):
        assert arr.shape == (n,) and arr.dtype == np.uint32
    # the layout's (value >> shift) & 1 walk reproduces the row-major
    # bit matrices of the four decomposed tensors exactly
    bits = (layout.vals >> layout.shift) & 1
    main = bits[:layout.n_main].reshape(2 * DS, QB)
    rem = bits[layout.n_main:].reshape(2 * DS, RB)
    np.testing.assert_array_equal(main[:DS], bits_unsigned(zpp, QB))
    np.testing.assert_array_equal(main[DS:], bits_signed(gap, QB))
    np.testing.assert_array_equal(rem[:DS], bits_unsigned(rz, RB))
    np.testing.assert_array_equal(rem[DS:], bits_unsigned(rga, RB))
    # masks live only at the forced column (top-half rows, bit Q-1)
    km = layout.kmask[:layout.n_main].reshape(2 * DS, QB)
    kpm = layout.kpmask[:layout.n_main].reshape(2 * DS, QB)
    cm = layout.colmask[:layout.n_main].reshape(2 * DS, QB)
    np.testing.assert_array_equal(km[:DS, QB - 1], bq)
    np.testing.assert_array_equal(kpm[:DS, QB - 1], 1 - bq)
    np.testing.assert_array_equal(cm[:DS, QB - 1], np.ones(DS))
    for m in (km, kpm, cm):
        m = m.copy()
        m[:DS, QB - 1] = 0
        assert not m.any()
    assert not layout.kmask[layout.n_main:].any()
    assert layout.region[:layout.n_main].all()
    assert not layout.region[layout.n_main:].any()


@pytest.mark.parametrize("seed", [1, 2])
def test_jnp_backend_matches_python_oracle(seed):
    _, layout, k, z_main, z_rem, e_full, es = random_inputs(seed)
    want_a, want_b = vtab.tables_ref(layout, k, z_main, z_rem, e_full, es)
    a, b = vtab.build_tables(layout, k, z_main, z_rem,
                             jnp.asarray(encode_ints(FQ, e_full)),
                             jnp.asarray(encode_ints(FQ, es)))
    np.testing.assert_array_equal(decode(FQ, a), np.array(want_a, object))
    np.testing.assert_array_equal(decode(FQ, b), np.array(want_b, object))


@pytest.mark.parametrize("block_rows", [None, 2])
def test_pallas_backend_bit_identical_to_jnp(block_rows):
    """Same uint32 Montgomery limbs from both backends — the transcript
    cannot depend on ZKDL_VALIDITY_BACKEND."""
    _, layout, k, z_main, z_rem, e_full, es = random_inputs(3)
    ef = jnp.asarray(encode_ints(FQ, e_full))
    esm = jnp.asarray(encode_ints(FQ, es))
    a_j, b_j = vtab.build_tables(layout, k, z_main, z_rem, ef, esm)
    vtab.set_backend("pallas")
    assert vtab.backend() == "pallas"
    a_p, b_p = vtab.build_tables(layout, k, z_main, z_rem, ef, esm,
                                 block_rows=block_rows, interpret=True)
    np.testing.assert_array_equal(np.asarray(a_j), np.asarray(a_p))
    np.testing.assert_array_equal(np.asarray(b_j), np.asarray(b_p))


def test_backend_dispatch(monkeypatch):
    assert vtab.backend() == "jnp"                   # default
    monkeypatch.setenv("ZKDL_VALIDITY_BACKEND", "pallas")
    assert vtab.backend() == "pallas"                # env selects
    vtab.set_backend("jnp")
    assert vtab.backend() == "jnp"                   # override wins
    vtab.set_backend(None)
    assert vtab.backend() == "pallas"                # back to env
    monkeypatch.setenv("ZKDL_VALIDITY_BACKEND", "cuda")
    with pytest.raises(ValueError, match="unknown validity backend"):
        vtab.backend()
    with pytest.raises(ValueError, match="unknown validity backend"):
        vtab.set_backend("tpu")


def test_vectorized_bits_match_per_bit_definition():
    rng = np.random.default_rng(7)
    v = rng.integers(0, 1 << 15, 64).astype(np.int64)
    got = bits_unsigned(v, 16)
    for i, x in enumerate(v):
        np.testing.assert_array_equal(
            got[i], [(int(x) >> j) & 1 for j in range(16)])
    s = rng.integers(-(1 << 15), 1 << 15, 64).astype(np.int64)
    got = bits_signed(s, 16)
    for i, x in enumerate(s):
        tc = int(x) + (1 << 16) if x < 0 else int(x)
        np.testing.assert_array_equal(
            got[i], [(tc >> j) & 1 for j in range(16)])
    # reconstruction: sum_j 2^j b_j recovers the two's-complement value
    np.testing.assert_array_equal(got @ (1 << np.arange(16)),
                                  np.where(s < 0, s + (1 << 16), s))
