"""Chaos harness for the crash-safe prover service (PR 8 tentpole).

Every named fault point in `launch/serve.ProverService` gets an injected
crash; the contract under test is the durability contract from the serve
docstring:

* a restarted service replays journaled steps and re-emits every
  non-dropped window,
* `verify_bytes` passes on every committed proof read back from disk,
* the manifest records EXACTLY ONE ``COMMITTED`` line per window (the
  exactly-once audit — a crash between the proof write and the manifest
  commit must re-prove, not double-commit),
* journal segments are garbage-collected once their window is terminal,
* dropped/partial windows are accounted, never silently discarded.

Signal death is covered twice: in-process via the ``worker/kill`` raise
(worker thread dies mid-pipeline) and for real via subprocess isolation
with a ``kill`` action (the child SIGKILLs itself mid-prove and the
supervisor retries).
"""
import os

import numpy as np
import pytest

from repro.core.quantfc import QuantConfig, synthetic_sgd_trajectory_widths
from repro.core.pipeline import build_fcnn_graph
from repro.core.pipeline.proofio import decode_vk
from repro.core.pipeline.verifier import verify_bytes
from repro.launch import serve
from repro.launch.serve import ProverService
from repro.train.resilience import FailureInjector, SimulatedFailure

QC = QuantConfig(q_bits=16, r_bits=4)
WIDTHS = (4, 4, 4)
T = 2
N_STEPS = 6                      # 3 windows
LABEL = b"zkdl/train"


def _service(out_dir, **kw):
    return ProverService(build_fcnn_graph(WIDTHS, batch=2), QC, n_steps=T,
                         out_dir=str(out_dir), rng_seed=5, **kw)


def _wits(n=N_STEPS):
    return synthetic_sgd_trajectory_widths(n, WIDTHS, 2, QC, seed=5)


def _drive(service, wits, start=0):
    """Submit wits[start:]; returns the index where a submit-side crash
    surfaced (len(wits) = no crash)."""
    for i in range(start, len(wits)):
        try:
            service.submit(wits[i])
        except (SimulatedFailure, RuntimeError):
            return i
    return len(wits)


def _assert_contract(out_dir, n_windows, dropped=()):
    """The chaos acceptance criteria, from disk state alone."""
    out = str(out_dir)
    man = serve.read_manifest(out)
    counts = serve.manifest_commit_counts(out)
    with open(os.path.join(out, "vk.bin"), "rb") as f:
        vk = decode_vk(f.read())
    for w in range(n_windows):
        if w in dropped:
            assert man[w]["status"] == serve.DROPPED
            assert counts.get(w, 0) == 0
            continue
        assert man.get(w, {}).get("status") == serve.COMMITTED, \
            f"window {w}: {man.get(w)}"
        assert counts[w] == 1, f"window {w} committed {counts[w]} times"
        with open(os.path.join(out, f"proof_{w:06d}.bin"), "rb") as f:
            raw = f.read()
        assert verify_bytes(vk, raw, label=LABEL), f"window {w} rejected"
    assert serve.journal_steps(serve.journal_dir(out)) == [], \
        "terminal windows left journal segments behind"


# ---------------------------------------------------------------------------
# Crash at every fault point -> restart -> exactly-once commit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fault", [
    "submit/journal-pre@2",       # crash before the witness is durable
    "submit/journal-post@3",      # crash after journal, before enqueue
    "prove/mid@1",                # transient mid-prove failure (retried)
    "commit/pre-manifest@0",      # proof written, manifest commit lost
    "worker/kill@1",              # worker dies wholesale mid-pipeline
    "prove/mid@1:corrupt-cache",  # cache corruption mid-run: no effect
])
def test_crash_then_restart_commits_every_window_once(tmp_path, fault):
    wits = _wits()
    svc = _service(tmp_path, injector=FailureInjector.from_spec(fault))
    svc.start(warm=False)
    _drive(svc, wits)
    try:
        svc.close(timeout=600)
    except (SimulatedFailure, RuntimeError, TimeoutError):
        pass                      # worker-side faults surface here
    # restart against the same out-dir, fault-free: replay + resume
    svc2 = _service(tmp_path)
    svc2.start(warm=False)
    _drive(svc2, wits, start=min(svc2.next_step, len(wits)))
    svc2.close(timeout=600)
    _assert_contract(tmp_path, 3)
    if fault == "prove/mid@1":
        # the transient failure was retried in-place, not restarted
        man = serve.read_manifest(str(tmp_path))
        assert man[1]["attempts"] == 2


def test_exhausted_retries_mark_failed_and_keep_going(tmp_path):
    """Every attempt at window 0 fails -> FAILED in the manifest, and the
    worker proves window 1 instead of wedging the queue."""
    class FirstTwoHits(FailureInjector):
        def fire(self, point):
            self.counts[point] = self.counts.get(point, 0) + 1
            if point == "prove/mid" and self.counts[point] <= 2:
                raise SimulatedFailure(f"injected {point} "
                                       f"hit {self.counts[point]}")

    svc = _service(tmp_path, max_attempts=2, backoff_base=0.01,
                   injector=FirstTwoHits())
    svc.start(warm=False)
    for wit in _wits(4):
        svc.submit(wit)
    svc.close(timeout=600)
    man = serve.read_manifest(str(tmp_path))
    assert man[0]["status"] == serve.FAILED
    assert man[0]["attempts"] == 2
    assert man[1]["status"] == serve.COMMITTED
    assert svc.stats["failed_windows"] == 1
    assert svc.stats["retries"] >= 1
    # FAILED is terminal: a restart resumes AFTER it, not inside it
    svc2 = _service(tmp_path)
    svc2.start(warm=False)
    assert svc2.next_step == 4


def test_backpressure_drop_window_accounting(tmp_path):
    """A wedged prover with a bounded queue sheds the newest window:
    DROPPED in the manifest, journal GC'd, stats accounted — and
    training's submit() never blocks."""
    svc = _service(tmp_path, queue_size=2, backpressure="drop_window",
                   max_attempts=2, backoff_base=3.0, backoff_cap=3.0,
                   injector=FailureInjector.from_spec("prove/mid@0"))
    svc.start(warm=False)
    wits = _wits()
    svc.submit(wits[0])
    svc.submit(wits[1])
    # wait until the worker owns window 0 (queue drained) and is inside
    # its failing first attempt (then it sleeps ~3s of backoff)
    deadline = 600
    import time
    t0 = time.time()
    while svc._queue.qsize() > 0 and time.time() - t0 < deadline:
        time.sleep(0.01)
    time.sleep(0.3)
    for wit in wits[2:]:          # window 1 fills the queue, window 2 drops
        svc.submit(wit)
    svc.close(timeout=600)
    assert svc.stats["dropped_windows"] == 1
    assert svc.stats["dropped_steps"] == 2
    _assert_contract(tmp_path, 3, dropped={2})
    man = serve.read_manifest(str(tmp_path))
    assert man[2]["reason"] == "backpressure"


def test_close_handles_dead_worker_without_hanging(tmp_path):
    """Satellite: close() after a worker death must bound its join,
    surface the original error, and leave the journal intact for the
    next run."""
    svc = _service(tmp_path, max_attempts=1,
                   injector=FailureInjector.from_spec("worker/kill@0"))
    svc.start(warm=False)
    wits = _wits(4)
    _drive(svc, wits)
    with pytest.raises(SimulatedFailure):
        svc.close(timeout=60)
    # every journaled step survived for the restart
    assert serve.journal_steps(serve.journal_dir(str(tmp_path))) != []
    svc2 = _service(tmp_path)
    svc2.start(warm=False)
    _drive(svc2, wits, start=min(svc2.next_step, len(wits)))
    svc2.close(timeout=600)
    _assert_contract(tmp_path, 2)


def test_partial_trailing_window_reported_not_discarded(tmp_path):
    """Satellite: a trailing window short of T steps is reported as
    PARTIAL (stats + manifest) and its journal segments are retained;
    the restarted service finishes the window."""
    svc = _service(tmp_path)
    svc.start(warm=False)
    wits = _wits(3)               # 1 full window + 1 trailing step
    for wit in wits:
        svc.submit(wit)
    svc.close(timeout=600)
    man = serve.read_manifest(str(tmp_path))
    assert man[0]["status"] == serve.COMMITTED
    assert man[1]["status"] == serve.PARTIAL
    assert man[1]["n_steps"] == 1 and man[1]["of"] == T
    assert svc.stats["partial_steps"] == 1
    assert serve.journal_steps(serve.journal_dir(str(tmp_path))) == [2]
    svc2 = _service(tmp_path)
    svc2.start(warm=False)
    assert svc2.next_step == 3
    svc2.submit(_wits(4)[3])
    svc2.close(timeout=600)
    _assert_contract(tmp_path, 2)


def test_corrupt_journal_segment_fails_window_not_service(tmp_path):
    """A torn/corrupt journal segment marks ITS window FAILED on
    recovery; the service still starts and proves new windows."""
    svc = _service(tmp_path)
    svc.start(warm=False)
    wits = _wits(4)
    for wit in wits[:3]:
        svc.submit(wit)
    svc.close(timeout=600)        # window 0 committed, step 2 journaled
    seg = os.path.join(serve.journal_dir(str(tmp_path)), "step_00000002.npz")
    with open(seg, "r+b") as f:
        f.truncate(max(1, os.path.getsize(seg) // 3))
    svc2 = _service(tmp_path)
    svc2.start(warm=False)
    man = serve.read_manifest(str(tmp_path))
    assert man[1]["status"] == serve.FAILED
    assert "journal" in man[1]["error"]
    assert svc2.next_step == 4    # FAILED window is terminal
    for wit in _wits(6)[4:]:
        svc2.submit(wit)
    svc2.close(timeout=600)
    man = serve.read_manifest(str(tmp_path))
    assert man[2]["status"] == serve.COMMITTED


def test_close_is_idempotent_and_submit_after_close_raises(tmp_path):
    """Satellite: close() on a never-started or already-closed service is
    a clean no-op; submit()/start() afterwards raise the typed error."""
    from repro.launch.admission import ServiceClosedError

    cold = _service(tmp_path / "cold")
    cold.close()                  # never started
    cold.close()                  # already closed
    with pytest.raises(ServiceClosedError):
        cold.start(warm=False)

    svc = _service(tmp_path / "hot")
    svc.start(warm=False)
    wits = _wits(2)
    for wit in wits:
        svc.submit(wit)
    svc.close(timeout=600)
    svc.close(timeout=600)        # idempotent after a real run
    with pytest.raises(ServiceClosedError):
        svc.submit(wits[0])
    _assert_contract(tmp_path / "hot", 1)
    # the lock was released exactly once: a new service can start
    svc2 = _service(tmp_path / "hot")
    svc2.start(warm=False)
    svc2.close(timeout=600)


def test_atomic_write_storage_error_is_typed_with_no_tmp_orphan(
        tmp_path, monkeypatch):
    """Satellite: an OSError inside atomic_write_bytes (ENOSPC at the
    rename) surfaces as a typed StorageError AFTER the temp file is
    cleaned up — the target is never half-written."""
    import errno

    from repro.train import checkpoint
    from repro.train.checkpoint import StorageError, atomic_write_bytes

    target = tmp_path / "proof.bin"

    def full_disk(src, dst):
        raise OSError(errno.ENOSPC, "No space left on device")

    monkeypatch.setattr(checkpoint.os, "replace", full_disk)
    with pytest.raises(StorageError) as ei:
        atomic_write_bytes(str(target), b"x" * 64)
    assert ei.value.is_enospc
    assert isinstance(ei.value, OSError)        # typed AND catchable as OS
    assert not target.exists()
    assert [f for f in os.listdir(tmp_path) if ".tmp." in f] == []


def test_service_journal_enospc_block_retries_then_drop_drops(tmp_path):
    """Satellite: the service-side storage policy — ``block`` retries a
    transient ENOSPC at the journal write transparently; ``drop_window``
    converts a persistent one into a terminal DROPPED window."""
    svc = _service(tmp_path / "block", backoff_base=0.01,
                   injector=FailureInjector.from_spec(
                       "storage/journal@0:enospc"))
    svc.start(warm=False)
    for wit in _wits(2):
        svc.submit(wit)
    svc.close(timeout=600)
    assert svc.stats["storage_errors"] == 1
    _assert_contract(tmp_path / "block", 1)

    svc = _service(tmp_path / "drop", backpressure="drop_window",
                   injector=FailureInjector.from_spec(
                       "storage/journal@0:enospc"))
    svc.start(warm=False)
    for wit in _wits(4):
        svc.submit(wit)           # never raises under drop_window
    svc.close(timeout=600)
    man = serve.read_manifest(str(tmp_path / "drop"))
    assert man[0]["status"] == serve.DROPPED
    assert man[0]["reason"] == "storage"
    assert svc.stats["dropped_windows"] == 1
    _assert_contract(tmp_path / "drop", 2, dropped={0})


def test_compact_manifest_preserves_replay_semantics(tmp_path):
    """Satellite: compaction must be invisible to every reader —
    last-wins resolution, the exactly-once commit audit, and no-window
    lines (dataset bindings) all survive byte-identically."""
    import json

    out = tmp_path
    lines = [
        {"window": 0, "status": serve.FAILED, "reason": "prove"},
        {"window": 0, "status": serve.COMMITTED, "n_steps": 2},
        {"event": "DATASET_BINDING", "root": "aa" * 16},
        {"window": 1, "status": serve.COMMITTED, "n_steps": 2},
        {"window": 1, "status": serve.COMMITTED, "n_steps": 2},  # double!
        {"window": 2, "status": serve.PARTIAL, "n_steps": 1, "of": 2},
        {"window": 3, "status": serve.FAILED, "reason": "deadline"},
        {"window": 3, "status": serve.FAILED, "reason": "prove"},
    ]
    path = os.path.join(str(out), serve.MANIFEST)
    with open(path, "w") as f:
        for rec in lines:
            f.write(json.dumps(rec) + "\n")
        f.write('{"window": 9, "status": "COMM')   # torn final append
    before_man = serve.read_manifest(str(out))
    before_counts = serve.manifest_commit_counts(str(out))
    info = serve.compact_manifest(str(out))
    assert serve.read_manifest(str(out)) == before_man
    assert serve.manifest_commit_counts(str(out)) == before_counts
    assert before_counts[1] == 2      # the audit still sees the double
    assert info["lines_before"] == 8  # torn line was never an entry
    # kept: w0 last+commit (1 line), binding, w1 2 commits, w2 last,
    # w3 last = 6
    assert info["lines_after"] == 6
    with open(path) as f:
        kept = [json.loads(ln) for ln in f if ln.strip()]
    assert {"event": "DATASET_BINDING", "root": "aa" * 16} in kept
    assert sum(1 for r in kept if r.get("window") == 3) == 1
    assert kept[-1]["window"] == 3    # original order preserved


def test_service_start_auto_compacts_oversized_manifest(tmp_path):
    """Satellite: a manifest past compact_threshold is compacted at
    start; recovery state (next_step, terminal windows) is unchanged."""
    import json

    path = os.path.join(str(tmp_path), serve.MANIFEST)
    os.makedirs(str(tmp_path), exist_ok=True)
    with open(path, "w") as f:
        for i in range(40):           # 40 lines of retry history
            f.write(json.dumps({"window": 0, "status": serve.FAILED,
                                "reason": "prove", "attempt": i}) + "\n")
        f.write(json.dumps({"window": 0, "status": serve.COMMITTED,
                            "n_steps": T}) + "\n")
    svc = _service(tmp_path, compact_threshold=5)
    svc.start(warm=False)
    assert svc.next_step == T         # window 0 stays terminal
    assert serve.manifest_line_count(str(tmp_path)) == 1
    assert serve.manifest_commit_counts(str(tmp_path)) == {0: 1}
    svc.close(timeout=600)


def test_subprocess_isolation_survives_signal_death(tmp_path, monkeypatch):
    """The real signal-death path: each prove attempt is a subprocess;
    the first child SIGKILLs itself mid-prove (a genuine negative
    returncode), the supervisor retries, and the retry — seeing the
    cross-process once-marker — proves and commits exactly once."""
    from repro.core import execache
    if not (execache.enabled() and execache.cache_dir() is not None):
        pytest.skip("subprocess worker needs the executable disk cache")
    monkeypatch.setenv("ZKDL_FAULTS", "prove/mid@0:kill")
    monkeypatch.setenv("ZKDL_FAULTS_ONCE", str(tmp_path / "fired"))
    out = tmp_path / "out"
    svc = _service(out, isolation="subprocess", max_attempts=3,
                   backoff_base=0.1, prove_timeout=1200)
    svc.start(warm=True)          # populates the disk cache for children
    for wit in _wits(2):
        svc.submit(wit)
    svc.close(timeout=1200)
    _assert_contract(out, 1)
    man = serve.read_manifest(str(out))
    assert man[0]["attempts"] == 2, man[0]
    assert svc.stats["retries"] == 1
