"""Tests for the SC-BD baseline (general-purpose bit-decomposition proof,
the comparison column of Table 2)."""
import numpy as np
import pytest

from repro.core import scbd
from repro.core.transcript import Transcript


def rand_aux(d, qb, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(-(1 << (qb - 1)), 1 << (qb - 1), size=d
                        ).astype(np.int64)


@pytest.mark.parametrize("d,qb", [(16, 8), (64, 16), (256, 16)])
def test_scbd_roundtrip(d, qb):
    aux = rand_aux(d, qb, seed=d)
    proof = scbd.prove(aux, qb, Transcript(b"scbd"))
    assert scbd.verify(proof, d, qb, Transcript(b"scbd"))


def test_scbd_rejects_forged_claim():
    aux = rand_aux(32, 8, seed=1)
    proof = scbd.prove(aux, 8, Transcript(b"scbd"))
    proof.claim = (proof.claim + 1) % scbd.Q_MOD
    assert not scbd.verify(proof, 32, 8, Transcript(b"scbd"))


def test_scbd_rejects_tampered_round():
    aux = rand_aux(32, 8, seed=2)
    proof = scbd.prove(aux, 8, Transcript(b"scbd"))
    proof.sc_main.messages[1][0] = (proof.sc_main.messages[1][0] + 1) % scbd.Q_MOD
    assert not scbd.verify(proof, 32, 8, Transcript(b"scbd"))


def test_scbd_rejects_nonbinary_witness():
    """A prover who forges the bin sumcheck finals is caught."""
    aux = rand_aux(16, 8, seed=3)
    proof = scbd.prove(aux, 8, Transcript(b"scbd"))
    proof.bin_finals[2] = (proof.bin_finals[2] + 1) % scbd.Q_MOD
    assert not scbd.verify(proof, 16, 8, Transcript(b"scbd"))


def test_scbd_workload_is_quadratic():
    assert scbd.workload_elems(1024, 16) == 1024 * 1024 * 16
    # the asymptotic gap of Table 1: D^2 Q vs zkReLU's D Q
    assert scbd.workload_elems(2048, 16) // (2048 * 16) == 2048
