"""Tests for the SC-BD baseline (general-purpose bit-decomposition proof,
the comparison column of Table 2)."""
import dataclasses

import numpy as np
import pytest

from repro.core import scbd
from repro.core.transcript import Transcript


def rand_aux(d, qb, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(-(1 << (qb - 1)), 1 << (qb - 1), size=d
                        ).astype(np.int64)


@pytest.mark.parametrize("d,qb", [(16, 8), (64, 16), (256, 16)])
def test_scbd_roundtrip(d, qb):
    aux = rand_aux(d, qb, seed=d)
    proof = scbd.prove(aux, qb, Transcript(b"scbd"))
    assert scbd.verify(proof, d, qb, Transcript(b"scbd"))


def test_scbd_rejects_forged_claim():
    aux = rand_aux(32, 8, seed=1)
    proof = scbd.prove(aux, 8, Transcript(b"scbd"))
    proof.claim = (proof.claim + 1) % scbd.Q_MOD
    assert not scbd.verify(proof, 32, 8, Transcript(b"scbd"))


def test_scbd_rejects_tampered_round():
    aux = rand_aux(32, 8, seed=2)
    proof = scbd.prove(aux, 8, Transcript(b"scbd"))
    proof.sc_main.messages[1][0] = (proof.sc_main.messages[1][0] + 1) % scbd.Q_MOD
    assert not scbd.verify(proof, 32, 8, Transcript(b"scbd"))


def test_scbd_rejects_nonbinary_witness():
    """A prover who forges the bin sumcheck finals is caught."""
    aux = rand_aux(16, 8, seed=3)
    proof = scbd.prove(aux, 8, Transcript(b"scbd"))
    proof.bin_finals[2] = (proof.bin_finals[2] + 1) % scbd.Q_MOD
    assert not scbd.verify(proof, 16, 8, Transcript(b"scbd"))


def test_scbd_workload_is_quadratic():
    assert scbd.workload_elems(1024, 16) == 1024 * 1024 * 16
    # the asymptotic gap of Table 1: D^2 Q vs zkReLU's D Q
    assert scbd.workload_elems(2048, 16) // (2048 * 16) == 2048


def test_golden_digest_pin_on_audit_transcript_domain():
    """Canonical-encoding digest of a fixed proof on the audit label:
    any drift in the transcript domains (scbd/u, scbd/claim, scbd/main,
    scbd/u2, scbd/bin), the message layout, or the wiring tables changes
    this digest.  Re-pin ONLY for an intentional format change."""
    aux = (((np.arange(16, dtype=np.int64) * 37) % 256) - 128).astype(
        np.int64)
    proof = scbd.prove(aux, 8, Transcript(b"zkdl/scbd-audit"))
    assert scbd.verify(proof, 16, 8, Transcript(b"zkdl/scbd-audit"))
    assert proof.digest() == \
        "4b741340fd0f64f4c567b06911049dac8e71a23d0b02a47751fa430823ece455"
    # the digest covers every section: any tamper moves it and rejects
    forged = dataclasses.replace(proof,
                                 claim=(proof.claim + 1) % scbd.Q_MOD)
    assert forged.digest() != proof.digest()
    assert not scbd.verify(forged, 16, 8, Transcript(b"zkdl/scbd-audit"))
    assert len(proof.proof_ints()) == 101
