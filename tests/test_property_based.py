"""Hypothesis property tests for field / group / kernel exactness.

Collected only when the dev extras are installed: the module-level
``pytest.importorskip("hypothesis")`` guard skips the whole file in
clean environments (see requirements-dev.txt), so the tier-1 suite
never hard-fails on a missing dev dependency."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.field import (FQ, FP, add, sub, mont_mul, modarith,  # noqa: E402
                         encode_ints, decode)
from repro.core import group  # noqa: E402
from repro.kernels.modmul import modmul  # noqa: E402
from repro.kernels.qmatmul import qmatmul_i64  # noqa: E402
from repro.kernels.qmatmul.ref import qmatmul_ref  # noqa: E402

Q = FQ.modulus
P = FP.modulus


def enc(spec, xs):
    return jnp.asarray(encode_ints(spec, np.array(xs, dtype=object)))


def dec(spec, a):
    return decode(spec, np.asarray(a))


@settings(max_examples=60, deadline=None)
@given(
    x=st.integers(min_value=0, max_value=Q - 1),
    y=st.integers(min_value=0, max_value=Q - 1),
)
def test_hypothesis_mul_add_fq(x, y):
    a, b = enc(FQ, [x]), enc(FQ, [y])
    assert int(dec(FQ, mont_mul(FQ, a, b))[0]) == (x * y) % Q
    assert int(dec(FQ, add(FQ, a, b))[0]) == (x + y) % Q
    assert int(dec(FQ, sub(FQ, a, b))[0]) == (x - y) % Q


@settings(max_examples=30, deadline=None)
@given(x=st.integers(min_value=0, max_value=P - 1),
       y=st.integers(min_value=0, max_value=P - 1))
def test_hypothesis_mul_fp(x, y):
    assert int(dec(FP, mont_mul(FP, enc(FP, [x]), enc(FP, [y])))[0]) \
        == (x * y) % P


@settings(max_examples=10, deadline=None)
@given(e=st.integers(min_value=0, max_value=Q - 1))
def test_hypothesis_pow(e):
    g = group.group_gen()
    out = group.g_pow(g[None], group.exps_from_ints([e]))
    assert group.decode_group(out[0]) == pow(4, e, P)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, Q - 1), min_size=1, max_size=8),
       st.lists(st.integers(0, Q - 1), min_size=1, max_size=8))
def test_modmul_property(xs, ys):
    n = min(len(xs), len(ys))
    xs, ys = xs[:n], ys[:n]
    a = jnp.asarray(modarith.encode_ints(FQ, np.array(xs, dtype=object)))
    b = jnp.asarray(modarith.encode_ints(FQ, np.array(ys, dtype=object)))
    got = modarith.decode(FQ, modmul(FQ, a, b, interpret=True))
    for i in range(n):
        assert int(got[i]) == (xs[i] * ys[i]) % Q


@settings(max_examples=8, deadline=None)
@given(st.lists(st.sampled_from([2, 3, 4, 6, 8]), min_size=3, max_size=5),
       st.sampled_from([1, 2]), st.integers(0, 10**6))
def test_graph_stacking_invariants(widths, n_steps, seed):
    """Graph-driven witness stacking under random shape tables: the
    slot-index map is a bijection, every occupied block holds its
    node's (zero-padded) tensor exactly, and everything outside the
    occupied blocks is exactly zero (padded rows/cols, padded nodes,
    padded steps).  The checker is shared with the deterministic
    tier-1 twin in test_proof_session.py."""
    from test_proof_session import check_stacking_invariants

    check_stacking_invariants(tuple(widths), n_steps, seed)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 9), st.integers(1, 9), st.integers(1, 9),
       st.integers(0, 2**32 - 1))
def test_qmatmul_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-2**15, 2**15, size=(m, k)),
                    dtype=jnp.int16)
    b = jnp.asarray(rng.integers(-2**15, 2**15, size=(k, n)),
                    dtype=jnp.int16)
    got = qmatmul_i64(a, b, interpret=True)
    np.testing.assert_array_equal(got, qmatmul_ref(np.asarray(a),
                                                   np.asarray(b)))
