"""Tests for the zero-knowledge inner-product arguments."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.field import FQ, encode_ints, decode
from repro.core import group, ipa, pedersen
from repro.core.mle import fdot
from repro.core.transcript import Transcript

Q = FQ.modulus


def field_vec(vals):
    return jnp.asarray(encode_ints(FQ, np.array([v % Q for v in vals], dtype=object)))


@pytest.mark.parametrize("n", [4, 16, 64])
def test_open_roundtrip(n):
    rng = np.random.default_rng(n)
    key = pedersen.make_key(b"open-t", n)
    a_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    b_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    a, b = field_vec(a_int), field_vec(b_int)
    blind = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    com = pedersen.commit(key, a, blind)
    claim = sum(x * y for x, y in zip(a_int, b_int)) % Q

    tp = Transcript(b"ipa-test")
    proof = ipa.open_prove(key, a, b, blind, claim, tp, rng)
    tv = Transcript(b"ipa-test")
    assert ipa.open_verify(key, com, b, claim, proof, tv)


def test_open_rejects_wrong_claim():
    n = 16
    rng = np.random.default_rng(7)
    key = pedersen.make_key(b"open-t", n)
    a_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    b_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    a, b = field_vec(a_int), field_vec(b_int)
    blind = 12345
    com = pedersen.commit(key, a, blind)
    claim = sum(x * y for x, y in zip(a_int, b_int)) % Q

    tp = Transcript(b"ipa-test")
    proof = ipa.open_prove(key, a, b, blind, claim, tp, rng)
    tv = Transcript(b"ipa-test")
    assert not ipa.open_verify(key, com, b, (claim + 1) % Q, proof, tv)


def test_open_rejects_wrong_commitment():
    n = 8
    rng = np.random.default_rng(8)
    key = pedersen.make_key(b"open-t", n)
    a_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    b_int = [1] * n
    a, b = field_vec(a_int), field_vec(b_int)
    com = pedersen.commit(key, a, 99)
    claim = sum(a_int) % Q
    tp = Transcript(b"t")
    proof = ipa.open_prove(key, a, b, 99, claim, tp, rng)
    bad_com = group.g_mul(com, key.gens[0])
    tv = Transcript(b"t")
    assert not ipa.open_verify(key, bad_com, b, claim, proof, tv)


@pytest.mark.parametrize("n", [4, 32])
def test_pair_roundtrip(n):
    rng = np.random.default_rng(100 + n)
    g_gens = group.derive_generators(b"pair-G", n)
    h_gens = group.derive_generators(b"pair-H", n)
    h_blind = group.derive_generators(b"pair-h", 1)[0]
    a_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    b_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    a, b = field_vec(a_int), field_vec(b_int)
    blind = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    claim = sum(x * y for x, y in zip(a_int, b_int)) % Q
    # C = h^blind G^a H^b
    com = group.g_mul(
        group.g_mul(group.msm_field(g_gens, a), group.msm_field(h_gens, b)),
        group.g_pow_int(h_blind, blind))

    tp = Transcript(b"pair")
    proof = ipa.pair_prove(g_gens, h_gens, h_blind, a, b, blind, claim, tp, rng)
    tv = Transcript(b"pair")
    assert ipa.pair_verify(g_gens, h_gens, h_blind, com, claim, proof, tv, n)
    tv2 = Transcript(b"pair")
    assert not ipa.pair_verify(g_gens, h_gens, h_blind, com, (claim + 3) % Q,
                               proof, tv2, n)


def test_proof_is_logarithmic():
    rng = np.random.default_rng(3)
    sizes = {}
    for n in [16, 64, 256]:
        key = pedersen.make_key(b"open-t", n)
        a_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
        a = field_vec(a_int)
        b = field_vec([1] * n)
        com = pedersen.commit(key, a, 5)
        claim = sum(a_int) % Q
        tp = Transcript(b"t")
        proof = ipa.open_prove(key, a, b, 5, claim, tp, rng)
        sizes[n] = proof.size_bytes()
    assert sizes[64] - sizes[16] == sizes[256] - sizes[64]  # +2 group els per 4x


# ---------------------------------------------------------------------------
# Fused-round parity: the jitted ipa round (one multi-MSM + one fold
# dispatch) must be bit-identical to the unfused sequence of primitive
# group ops, blinds included.
# ---------------------------------------------------------------------------

def _unfused_open_round(gens, a, b, up, h, rho_l, rho_r):
    """The pre-fusion round: two half MSMs, two claim exps, two blind
    exps, sequential folds (kept here as the parity oracle)."""
    from repro.core.mle import fdot
    from repro.field import decode
    n2 = a.shape[0] // 2
    c_l = int(decode(FQ, fdot(a[:n2], b[n2:]))[()])
    c_r = int(decode(FQ, fdot(a[n2:], b[:n2]))[()])
    lval = group.g_mul(
        group.g_mul(group.msm_field(gens[n2:], a[:n2]),
                    group.g_pow_int(up, c_l)),
        group.g_pow_int(h, rho_l))
    rval = group.g_mul(
        group.g_mul(group.msm_field(gens[:n2], a[n2:]),
                    group.g_pow_int(up, c_r)),
        group.g_pow_int(h, rho_r))
    return lval, rval


def _unfused_pair_round(gg, hh, a, b, up, h_blind, rho_l, rho_r):
    from repro.core.mle import fdot
    from repro.field import decode
    n2 = a.shape[0] // 2
    c_l = int(decode(FQ, fdot(a[:n2], b[n2:]))[()])
    c_r = int(decode(FQ, fdot(a[n2:], b[:n2]))[()])
    lval = group.g_mul(group.g_mul(
        group.msm_field(gg[n2:], a[:n2]),
        group.msm_field(hh[:n2], b[n2:])),
        group.g_mul(group.g_pow_int(up, c_l), group.g_pow_int(h_blind, rho_l)))
    rval = group.g_mul(group.g_mul(
        group.msm_field(gg[:n2], a[n2:]),
        group.msm_field(hh[n2:], b[:n2])),
        group.g_mul(group.g_pow_int(up, c_r), group.g_pow_int(h_blind, rho_r)))
    return lval, rval


@pytest.mark.parametrize("n", [4, 32])
def test_fused_open_round_matches_unfused(n):
    rng = np.random.default_rng(300 + n)
    key = pedersen.make_key(b"fused-o", n)
    up = group.derive_generators(b"fused-up", 1)[0]
    a = field_vec([int(rng.integers(0, Q, dtype=np.uint64)) for _ in range(n)])
    b = field_vec([int(rng.integers(0, Q, dtype=np.uint64)) for _ in range(n)])
    rho_l = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    rho_r = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    fused = ipa._open_round_lr(key.gens[:n], a, b, up, key.h,
                               ipa._exp1(rho_l), ipa._exp1(rho_r))
    want = _unfused_open_round(key.gens[:n], a, b, up, key.h, rho_l, rho_r)
    assert group.decode_group_many(fused) == [group.decode_group(w)
                                              for w in want]

    al = 987654321
    ali = pow(al, Q - 2, Q)
    from repro.core.mle import enc
    a2, b2, g2 = ipa._open_fold(a, b, key.gens[:n], enc(al), enc(ali),
                                ipa._exp1(al), ipa._exp1(ali))
    np.testing.assert_array_equal(np.asarray(a2),
                                  np.asarray(ipa._fold_vec(a, al, ali)))
    np.testing.assert_array_equal(np.asarray(b2),
                                  np.asarray(ipa._fold_vec(b, ali, al)))
    np.testing.assert_array_equal(
        np.asarray(g2), np.asarray(ipa._fold_gens(key.gens[:n], ali, al)))


@pytest.mark.parametrize("n", [8, 64])
def test_fused_pair_round_matches_unfused(n):
    rng = np.random.default_rng(400 + n)
    gg = group.derive_generators(b"fused-G", n)
    hh = group.derive_generators(b"fused-H", n)
    hb = group.derive_generators(b"fused-hb", 1)[0]
    up = group.derive_generators(b"fused-up", 1)[0]
    a = field_vec([int(rng.integers(0, Q, dtype=np.uint64)) for _ in range(n)])
    b = field_vec([int(rng.integers(0, Q, dtype=np.uint64)) for _ in range(n)])
    rho_l = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    rho_r = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    from repro.core.mle import enc
    fused = ipa._pair_round_lr(gg, hh, a, b, up, hb,
                               ipa._exp1(rho_l), ipa._exp1(rho_r),
                               enc(1), enc(1))
    want = _unfused_pair_round(gg, hh, a, b, up, hb, rho_l, rho_r)
    assert group.decode_group_many(fused) == [group.decode_group(w)
                                              for w in want]

    al = 192837465
    ali = pow(al, Q - 2, Q)
    al2, ali2 = al * al % Q, ali * ali % Q
    a2, b2, gg2, hh2 = ipa._pair_fold(a, b, gg, hh, enc(al), enc(ali),
                                      ipa._exp1(al2), ipa._exp1(ali2))
    np.testing.assert_array_equal(np.asarray(a2),
                                  np.asarray(ipa._fold_vec(a, al, ali)))
    np.testing.assert_array_equal(np.asarray(b2),
                                  np.asarray(ipa._fold_vec(b, ali, al)))
    # the fold defers the outer exponents (gam_g = ali, gam_h = al):
    # applying them recovers the eager fold exactly
    np.testing.assert_array_equal(
        np.asarray(ipa._g_pow_const(gg2, ali)),
        np.asarray(ipa._fold_gens(gg, ali, al)))
    np.testing.assert_array_equal(
        np.asarray(ipa._g_pow_const(hh2, al)),
        np.asarray(ipa._fold_gens(hh, al, ali)))
    # deferred L/R on the stored bases with gam scalars equals the
    # eager L/R on the true (materialized) bases
    gg_true = ipa._g_pow_const(gg2, ali)
    hh_true = ipa._g_pow_const(hh2, al)
    lr_def = ipa._pair_round_lr(gg2, hh2, a2, b2, up, hb,
                                ipa._exp1(rho_l), ipa._exp1(rho_r),
                                enc(ali), enc(al))
    lr_eager = ipa._pair_round_lr(gg_true, hh_true, a2, b2, up, hb,
                                  ipa._exp1(rho_l), ipa._exp1(rho_r),
                                  enc(1), enc(1))
    assert group.decode_group_many(lr_def) == \
        group.decode_group_many(lr_eager)


# ---------------------------------------------------------------------------
# Lockstep pair proving: interleaved statements and the fixed-basis
# first-round acceleration must be bit-identical to the explicit path.
# ---------------------------------------------------------------------------

def test_pair_prove_many_accel_matches_explicit():
    """An accel statement (squaring tables + H-weights in exponent form)
    must emit exactly the proof of the explicit H' = H^w basis."""
    from repro.field import from_mont

    n = 64
    rng = np.random.default_rng(900)
    gbig = group.derive_generators(b"ac-G", n)
    hbig = group.derive_generators(b"ac-H", n)
    hb = group.derive_generators(b"ac-hb", 1)[0]
    a = field_vec([int(rng.integers(0, Q, dtype=np.uint64)) for _ in range(n)])
    b = field_vec([int(rng.integers(0, Q, dtype=np.uint64)) for _ in range(n)])
    w = field_vec([int(rng.integers(1, Q, dtype=np.uint64)) for _ in range(n)])
    h_prime = group.g_pow(hbig, from_mont(FQ, w))
    claim, blind = 12345, 777

    p_exp = ipa.pair_prove_many(
        [(gbig, h_prime, hb, a, b, blind, claim)],
        Transcript(b"ac"), np.random.default_rng(9))[0]
    p_acc = ipa.pair_prove_many(
        [(gbig, None, hb, a, b, blind, claim,
          (group.pow_table(gbig), hbig, group.pow_table(hbig), w))],
        Transcript(b"ac"), np.random.default_rng(9))[0]
    assert (p_exp.ls, p_exp.rs, p_exp.sigma) == \
        (p_acc.ls, p_acc.rs, p_acc.sigma)


def test_pair_prove_many_lockstep_roundtrip():
    """Two statements of different sizes proven in lockstep verify via
    `pair_verify_many`, and cross-statement proof splicing rejects."""
    rng = np.random.default_rng(77)
    stmts_p, stmts_v = [], []
    for i, n in enumerate((32, 8)):
        gg = group.derive_generators(b"ls-G%d" % i, n)
        hh = group.derive_generators(b"ls-H%d" % i, n)
        hb = group.derive_generators(b"ls-hb", 1)[0]
        a_int = [int(rng.integers(0, Q, dtype=np.uint64)) for _ in range(n)]
        b_int = [int(rng.integers(0, Q, dtype=np.uint64)) for _ in range(n)]
        a, b = field_vec(a_int), field_vec(b_int)
        blind = int(rng.integers(0, Q, dtype=np.uint64)) % Q
        claim = sum(x * y % Q for x, y in zip(a_int, b_int)) % Q
        com = group.g_mul(
            group.g_mul(group.msm_field(gg, a), group.msm_field(hh, b)),
            group.g_pow_int(hb, blind))
        stmts_p.append((gg, hh, hb, a, b, blind, claim))
        stmts_v.append((gg, hh, hb, com, claim, n))

    proofs = ipa.pair_prove_many(stmts_p, Transcript(b"ls"),
                                 np.random.default_rng(3))
    assert ipa.pair_verify_many(stmts_v, proofs, Transcript(b"ls"))
    # wrong claim on the second statement only
    bad = list(stmts_v)
    g2, h2, hb2, com2, claim2, n2 = bad[1]
    bad[1] = (g2, h2, hb2, com2, (claim2 + 1) % Q, n2)
    assert not ipa.pair_verify_many(bad, proofs, Transcript(b"ls"))
    # splice: swap the two proofs
    assert not ipa.pair_verify_many(stmts_v, proofs[::-1], Transcript(b"ls"))
