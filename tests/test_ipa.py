"""Tests for the zero-knowledge inner-product arguments."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.field import FQ, encode_ints, decode
from repro.core import group, ipa, pedersen
from repro.core.mle import fdot
from repro.core.transcript import Transcript

Q = FQ.modulus


def field_vec(vals):
    return jnp.asarray(encode_ints(FQ, np.array([v % Q for v in vals], dtype=object)))


@pytest.mark.parametrize("n", [4, 16, 64])
def test_open_roundtrip(n):
    rng = np.random.default_rng(n)
    key = pedersen.make_key(b"open-t", n)
    a_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    b_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    a, b = field_vec(a_int), field_vec(b_int)
    blind = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    com = pedersen.commit(key, a, blind)
    claim = sum(x * y for x, y in zip(a_int, b_int)) % Q

    tp = Transcript(b"ipa-test")
    proof = ipa.open_prove(key, a, b, blind, claim, tp, rng)
    tv = Transcript(b"ipa-test")
    assert ipa.open_verify(key, com, b, claim, proof, tv)


def test_open_rejects_wrong_claim():
    n = 16
    rng = np.random.default_rng(7)
    key = pedersen.make_key(b"open-t", n)
    a_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    b_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    a, b = field_vec(a_int), field_vec(b_int)
    blind = 12345
    com = pedersen.commit(key, a, blind)
    claim = sum(x * y for x, y in zip(a_int, b_int)) % Q

    tp = Transcript(b"ipa-test")
    proof = ipa.open_prove(key, a, b, blind, claim, tp, rng)
    tv = Transcript(b"ipa-test")
    assert not ipa.open_verify(key, com, b, (claim + 1) % Q, proof, tv)


def test_open_rejects_wrong_commitment():
    n = 8
    rng = np.random.default_rng(8)
    key = pedersen.make_key(b"open-t", n)
    a_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    b_int = [1] * n
    a, b = field_vec(a_int), field_vec(b_int)
    com = pedersen.commit(key, a, 99)
    claim = sum(a_int) % Q
    tp = Transcript(b"t")
    proof = ipa.open_prove(key, a, b, 99, claim, tp, rng)
    bad_com = group.g_mul(com, key.gens[0])
    tv = Transcript(b"t")
    assert not ipa.open_verify(key, bad_com, b, claim, proof, tv)


@pytest.mark.parametrize("n", [4, 32])
def test_pair_roundtrip(n):
    rng = np.random.default_rng(100 + n)
    g_gens = group.derive_generators(b"pair-G", n)
    h_gens = group.derive_generators(b"pair-H", n)
    h_blind = group.derive_generators(b"pair-h", 1)[0]
    a_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    b_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
    a, b = field_vec(a_int), field_vec(b_int)
    blind = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    claim = sum(x * y for x, y in zip(a_int, b_int)) % Q
    # C = h^blind G^a H^b
    com = group.g_mul(
        group.g_mul(group.msm_field(g_gens, a), group.msm_field(h_gens, b)),
        group.g_pow_int(h_blind, blind))

    tp = Transcript(b"pair")
    proof = ipa.pair_prove(g_gens, h_gens, h_blind, a, b, blind, claim, tp, rng)
    tv = Transcript(b"pair")
    assert ipa.pair_verify(g_gens, h_gens, h_blind, com, claim, proof, tv, n)
    tv2 = Transcript(b"pair")
    assert not ipa.pair_verify(g_gens, h_gens, h_blind, com, (claim + 3) % Q,
                               proof, tv2, n)


def test_proof_is_logarithmic():
    rng = np.random.default_rng(3)
    sizes = {}
    for n in [16, 64, 256]:
        key = pedersen.make_key(b"open-t", n)
        a_int = [int(rng.integers(0, Q, dtype=np.uint64)) % Q for _ in range(n)]
        a = field_vec(a_int)
        b = field_vec([1] * n)
        com = pedersen.commit(key, a, 5)
        claim = sum(a_int) % Q
        tp = Transcript(b"t")
        proof = ipa.open_prove(key, a, b, 5, claim, tp, rng)
        sizes[n] = proof.size_bytes()
    assert sizes[64] - sizes[16] == sizes[256] - sizes[64]  # +2 group els per 4x
