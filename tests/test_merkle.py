"""Tests for proofs of (non-)membership (Appendix B, Table 3)."""
import numpy as np
import pytest

from repro.core import merkle


def make_commitments(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.bytes(32) for _ in range(n)]


@pytest.mark.parametrize("hash_name", ["md5", "sha1", "sha256"])
def test_membership_roundtrip(hash_name):
    data = make_commitments(50)
    tree = merkle.MerkleTree(data, hash_name)
    members = data[:5]
    non_members = make_commitments(5, seed=99)
    queried = members + non_members
    proof = tree.prove_membership(queried)
    assert len(proof.included) == 5
    assert len(proof.excluded) == 5
    assert merkle.verify_membership(queried, tree.root, proof, hash_name)


def test_rejects_lying_about_membership():
    data = make_commitments(20, seed=1)
    tree = merkle.MerkleTree(data, "sha256")
    member = data[0]
    proof = tree.prove_membership([member])
    # trainer claims the member is NOT in the set
    h = merkle.hash_bits(member, "sha256")
    proof.included.remove(h)
    proof.excluded.append(h)
    assert not merkle.verify_membership([member], tree.root, proof, "sha256")


def test_rejects_wrong_root():
    data = make_commitments(20, seed=2)
    tree = merkle.MerkleTree(data, "sha256")
    proof = tree.prove_membership(data[:3])
    assert not merkle.verify_membership(data[:3], b"\x00" * 32, proof, "sha256")


def test_rejects_forged_exclusion():
    data = make_commitments(16, seed=3)
    tree = merkle.MerkleTree(data, "sha256")
    outsider = make_commitments(1, seed=4)[0]
    proof = tree.prove_membership([outsider])
    assert merkle.verify_membership([outsider], tree.root, proof, "sha256")
    # claim the outsider IS a member by forging the value
    h = merkle.hash_bits(outsider, "sha256")
    proof.excluded.remove(h)
    proof.included.append(h)
    proof.node_values[h] = outsider
    assert not merkle.verify_membership([outsider], tree.root, proof, "sha256")


def test_positivity_ratio_scaling():
    """Table 3: proof size grows with the positivity ratio."""
    data = make_commitments(200, seed=5)
    tree = merkle.MerkleTree(data, "sha256")
    outsiders = make_commitments(20, seed=6)
    p_zero = tree.prove_membership(outsiders)
    p_full = tree.prove_membership(data[:20])
    assert merkle.verify_membership(outsiders, tree.root, p_zero, "sha256")
    assert merkle.verify_membership(data[:20], tree.root, p_full, "sha256")
    assert p_zero.size_nodes() < p_full.size_nodes()


def test_membership_proof_bytes_roundtrip():
    data = make_commitments(30, seed=4)
    tree = merkle.MerkleTree(data, "sha256")
    queried = data[:3] + make_commitments(3, seed=123)
    proof = tree.prove_membership(queried)
    rt = merkle.MembershipProof.from_bytes(proof.to_bytes())
    assert rt.included == proof.included
    assert rt.excluded == proof.excluded
    assert rt.frontier_exc == proof.frontier_exc
    assert rt.node_values == proof.node_values
    assert merkle.verify_membership(queried, tree.root, rt, "sha256")
    # malformed streams reject with the typed decode error
    with pytest.raises(merkle.MembershipProofDecodeError):
        merkle.MembershipProof.from_bytes(proof.to_bytes()[:-2])
    with pytest.raises(merkle.MembershipProofDecodeError):
        merkle.MembershipProof.from_bytes(b"NOPE" + proof.to_bytes()[4:])
    with pytest.raises(merkle.MembershipProofDecodeError):
        merkle.MembershipProof.from_bytes(proof.to_bytes() + b"\x00")


def test_dataset_scale_tree_stays_fast():
    """The revived sparse tree must be linear in practice: the audit
    benchmark binds tens of thousands of leaves, which the per-level
    rescan in the old fill made quadratic (minutes for 5k leaves)."""
    import time

    data = make_commitments(2000, seed=9)
    t0 = time.perf_counter()
    tree = merkle.MerkleTree(data, "sha256")
    build_s = time.perf_counter() - t0
    queried = data[:20] + make_commitments(20, seed=10**6)
    t0 = time.perf_counter()
    proof = tree.prove_membership(queried)
    prove_s = time.perf_counter() - t0
    assert merkle.verify_membership(queried, tree.root, proof, "sha256")
    assert build_s < 30.0, f"tree build took {build_s:.1f}s for 2k leaves"
    assert prove_s < 5.0, f"query took {prove_s:.1f}s for 40 queries"
