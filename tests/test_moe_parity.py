"""The shard_map MoE dispatch (the §Perf iter-2/3 optimization) must be
numerically equivalent to the single-device fallback path.

The distributed path only activates on a multi-device mesh, and the
device count must be forced before jax initializes -- so the comparison
runs in a subprocess (same pattern as the dry-run entry point).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))
import numpy as np
import jax
import jax.numpy as jnp

from repro.distributed import hints
from repro.models import moe
from repro.models.config import ModelConfig

cfg = ModelConfig(name="moe-parity", family="moe", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=4, d_ff=0, vocab=64,
                  n_experts=4, top_k=2, moe_d_ff=16, n_shared_experts=1,
                  capacity_factor=2.0, remat=False)
rng = jax.random.PRNGKey(0)
params = moe.init_moe(rng, cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32), jnp.float32)

# 1) reference: no mesh configured -> fallback (pure SPMD-free) path
hints.clear()
ref = np.asarray(moe.moe_ffn(params, x, cfg))

# 2) distributed: (2 data, 2 model) mesh -> shard_map dispatch + psum combine
mesh = jax.make_mesh((2, 2), ("data", "model"))
hints.set_axes(("data",), "model", {"batch": 2, "model": 2}, mesh=mesh)
with mesh:
    out = np.asarray(jax.jit(lambda p, v: moe.moe_ffn(p, v, cfg))(params, x))
hints.clear()

# token order inside an expert's capacity buffer differs between global
# and per-shard dispatch, but with capacity_factor=2.0 nothing overflows,
# so the COMBINED per-token outputs must agree to float tolerance.
np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
print("MOE_PARITY_OK")
"""


def test_shard_map_moe_matches_fallback():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=420)
    assert "MOE_PARITY_OK" in proc.stdout, (
        f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-3000:]}")
