"""Test configuration: persistent XLA cache (NO forced device count here --
smoke tests and benches must see exactly 1 device; only launch/dryrun.py
sets xla_force_host_platform_device_count), plus the quarantine marker +
centralized retry policy for tests whose SUBPROCESSES die on known
native (XLA-CPU) signals."""
import pytest

from repro.launch.supervise import run_subprocess_supervised
from repro.util import enable_compilation_cache

enable_compilation_cache()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "flaky_subprocess(retries=3): quarantines a test that drives a "
        "subprocess with a known native-crash flake (e.g. the XLA-CPU "
        "forced-host-device SIGABRT / glibc heap corruption during "
        "cross-mesh restore).  The test must launch its subprocess via "
        "the run_flaky_subprocess fixture, which retries SIGNAL deaths "
        "(negative returncode) only — real test failures (a clean exit "
        "with a failed assertion) are never retried.  Deselect the whole "
        "quarantine with `-m 'not flaky_subprocess'`.")


@pytest.fixture
def run_flaky_subprocess(request):
    """Centralized retry-on-signal-death subprocess runner.

    Usage: mark the test ``@pytest.mark.flaky_subprocess`` (optionally
    ``retries=N``) and call ``run_flaky_subprocess(argv, attempt_setup=f,
    **subprocess_kwargs)``; ``attempt_setup(attempt)`` (if given) runs
    before each try and returns extra argv entries — use it to point
    every attempt at fresh scratch state.  Returns the final
    `CompletedProcess`; only NEGATIVE returncodes (signal deaths) are
    retried, so assertion failures surface on the first attempt.
    """
    marker = request.node.get_closest_marker("flaky_subprocess")
    if marker is None:
        raise pytest.UsageError(
            "run_flaky_subprocess requires @pytest.mark.flaky_subprocess "
            "on the test (the marker IS the quarantine registry)")
    retries = marker.kwargs.get("retries", 3)

    def run(argv, attempt_setup=None, **kwargs):
        def on_retry(attempt, att):
            print(f"[flaky_subprocess] {request.node.name}: native crash "
                  f"(signal {att.signal}), attempt "
                  f"{attempt + 1}/{retries}")

        # delegates to the library supervisor (launch/supervise.py) the
        # prover service uses in production: signal deaths retry with
        # capped exponential backoff (the native crash is load-sensitive
        # — let the machine settle), clean exits return immediately
        res = run_subprocess_supervised(
            list(argv), max_attempts=retries, attempt_setup=attempt_setup,
            backoff_base=2.0, backoff_cap=6.0, retry_nonzero=False,
            retry_timeouts=False, on_retry=on_retry, **kwargs)
        return res.value

    return run
