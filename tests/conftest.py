"""Test configuration: persistent XLA cache (NO forced device count here --
smoke tests and benches must see exactly 1 device; only launch/dryrun.py
sets xla_force_host_platform_device_count)."""
from repro.util import enable_compilation_cache

enable_compilation_cache()
