"""Per-kernel validation: Pallas body (interpret=True on CPU) vs ref.py
oracle, swept over shapes.  Hypothesis property tests on exactness live
in test_property_based.py (skipped when dev extras are absent)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.field import FP, FQ, modarith
from repro.core import mle
from repro.kernels.limb_planes import pack_planes, unpack_planes
from repro.kernels.modmul import modmul
from repro.kernels.modmul.ref import modmul_pyint, modmul_ref
from repro.kernels.sumcheck_fold import fold as kfold
from repro.kernels.sumcheck_fold.ref import fold_ref
from repro.kernels.qmatmul import qmatmul_i64
from repro.kernels.qmatmul.ref import qmatmul_ref

RNG = np.random.default_rng(7)


def rand_mont(spec, n):
    vals = RNG.integers(0, spec.modulus, size=n, dtype=np.uint64)
    return jnp.asarray(modarith.encode_ints(
        spec, np.array([int(v) % spec.modulus for v in vals], dtype=object)))


# ---------------------------------------------------------------------------
# layout transforms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 128, 1000, 4096])
def test_pack_unpack_roundtrip(n):
    a = rand_mont(FQ, n)
    planes, n_out = pack_planes(a)
    assert n_out == n
    assert planes.shape[0] == 4 and planes.shape[2] == 128
    back = unpack_planes(planes, n)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(a))


# ---------------------------------------------------------------------------
# modmul kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [FQ, FP], ids=["Fq", "Fp"])
@pytest.mark.parametrize("n", [1, 5, 128, 777, 2048])
def test_modmul_matches_ref(spec, n):
    a = rand_mont(spec, n)
    b = rand_mont(spec, n)
    got = modmul(spec, a, b, interpret=True)
    want = modmul_ref(spec, a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_modmul_matches_pyint():
    a = rand_mont(FQ, 64)
    b = rand_mont(FQ, 64)
    got = modmul(FQ, a, b, interpret=True)
    want = modmul_pyint(FQ, a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_modmul_block_row_sweep():
    a = rand_mont(FQ, 2048)
    b = rand_mont(FQ, 2048)
    want = np.asarray(modmul_ref(FQ, a, b))
    for br in (8, 16):
        got = modmul(FQ, a, b, block_rows=br, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_modmul_nd_shapes():
    a = rand_mont(FQ, 24).reshape(2, 3, 4, 4)
    b = rand_mont(FQ, 24).reshape(2, 3, 4, 4)
    got = modmul(FQ, a, b, interpret=True)
    want = modmul_ref(FQ, a, b)
    assert got.shape == (2, 3, 4, 4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# sumcheck_fold kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 8, 256, 1024, 4096])
def test_fold_matches_ref(n):
    table = rand_mont(FQ, n)
    r = int(RNG.integers(0, FQ.modulus, dtype=np.uint64)) % FQ.modulus
    r_l = mle.enc(r)
    got = kfold(table, r_l, interpret=True)
    want = fold_ref(table, r_l)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fold_repeated_rounds_full_eval():
    """Folding all variables with the kernel == eval_mle with jnp path."""
    d = 6
    table = rand_mont(FQ, 1 << d)
    point = [int(RNG.integers(0, FQ.modulus, dtype=np.uint64)) % FQ.modulus
             for _ in range(d)]
    t = table
    for r in point:
        t = kfold(t, mle.enc(r), interpret=True)
    want = mle.eval_mle(table, point)
    np.testing.assert_array_equal(np.asarray(t[0]), np.asarray(want))


def test_fold_at_zero_and_one():
    """fold(T, 0) = evens, fold(T, 1) = odds (multilinearity edge cases)."""
    table = rand_mont(FQ, 64)
    got0 = kfold(table, mle.enc(0), interpret=True)
    got1 = kfold(table, mle.enc(1), interpret=True)
    np.testing.assert_array_equal(np.asarray(got0), np.asarray(table[0::2]))
    np.testing.assert_array_equal(np.asarray(got1), np.asarray(table[1::2]))


# ---------------------------------------------------------------------------
# qmatmul kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (3, 5, 7), (8, 16, 8), (64, 64, 64),
    (100, 200, 50), (128, 512, 256),
])
def test_qmatmul_matches_ref(m, k, n):
    a = jnp.asarray(RNG.integers(-2**15, 2**15, size=(m, k)), dtype=jnp.int16)
    b = jnp.asarray(RNG.integers(-2**15, 2**15, size=(k, n)), dtype=jnp.int16)
    got = qmatmul_i64(a, b, interpret=True)
    want = qmatmul_ref(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(got, want)


def test_qmatmul_extreme_values():
    """Corner values: int16 min/max hit every digit-boundary case."""
    vals = np.array([-32768, -32767, -129, -128, -1, 0, 1, 127, 128,
                     255, 256, 32767], dtype=np.int16)
    a = jnp.asarray(np.tile(vals, (8, 1)))            # (8, 12)
    b = jnp.asarray(np.tile(vals[:, None], (1, 8)))   # (12, 8)
    got = qmatmul_i64(a, b, interpret=True)
    want = qmatmul_ref(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(got, want)


def test_qmatmul_block_sweep():
    a = jnp.asarray(RNG.integers(-2**15, 2**15, size=(64, 128)),
                    dtype=jnp.int16)
    b = jnp.asarray(RNG.integers(-2**15, 2**15, size=(128, 64)),
                    dtype=jnp.int16)
    want = qmatmul_ref(np.asarray(a), np.asarray(b))
    for bm, bn, bk in [(8, 8, 16), (16, 32, 64), (64, 64, 128)]:
        got = qmatmul_i64(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
        np.testing.assert_array_equal(got, want)


def test_qmatmul_witness_shapes():
    """The kernel reproduces a quantfc-style forward matmul exactly."""
    from repro.core import quantfc
    cfg = quantfc.QuantConfig(q_bits=12, r_bits=4)
    a = RNG.standard_normal((16, 32)).astype(np.float32)
    w = (RNG.standard_normal((32, 32)) / np.sqrt(32)).astype(np.float32)
    aq = quantfc.quantize(a, cfg)
    wq = quantfc.quantize(w, cfg)
    want = aq @ wq
    got = qmatmul_i64(jnp.asarray(aq, jnp.int16), jnp.asarray(wq, jnp.int16),
                      interpret=True)
    np.testing.assert_array_equal(got, want)
