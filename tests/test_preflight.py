"""Typed-rejection contracts for `launch/preflight` — the gateway's
submit-time witness validation.  Each malformed-witness family must
raise ITS error class (so clients can tell "fix your config" from "fix
your tensors"), and a witness that passes must be exactly the kind the
prover accepts.  Nothing here journals or proves: preflight runs before
any byte hits disk."""
import dataclasses

import numpy as np
import pytest

from repro.core.quantfc import QuantConfig, synthetic_sgd_trajectory_widths
from repro.core.pipeline import build_fcnn_graph, compile as zk_compile
from repro.launch import preflight
from repro.launch.preflight import (WitnessDtypeError, WitnessQuantError,
                                    WitnessRangeError, WitnessShapeError,
                                    WitnessStepError, WitnessTopologyError,
                                    WitnessValidationError,
                                    check_step_monotonic, validate_witness)

QC = QuantConfig(q_bits=16, r_bits=4)
WIDTHS = (4, 4, 4)
B = 2


@pytest.fixture(scope="module")
def cfg():
    pk, _vk = zk_compile(build_fcnn_graph(WIDTHS, batch=B), QC, n_steps=1)
    return pk.keys.cfg


@pytest.fixture()
def wit():
    w = synthetic_sgd_trajectory_widths(1, WIDTHS, B, QC, seed=9)[0]
    # deep-copy every array so tests can mutate freely
    lists = {f: [a.copy() for a in getattr(w, f)]
             for f in ("w", "z", "zpp", "b", "rz", "a", "gz", "ga",
                       "gap", "rga", "gw")}
    return dataclasses.replace(w, x=w.x.copy(), y=w.y.copy(),
                               skips=dict(w.skips), **lists)


def test_valid_witness_passes(cfg, wit):
    assert validate_witness(cfg, wit) is None


def test_quant_mismatch(cfg, wit):
    bad = dataclasses.replace(wit, cfg=QuantConfig(q_bits=8, r_bits=2))
    with pytest.raises(WitnessQuantError):
        validate_witness(cfg, bad)


def test_layer_count_mismatch(cfg, wit):
    bad = dataclasses.replace(wit, w=wit.w[:1])
    with pytest.raises(WitnessShapeError):
        validate_witness(cfg, bad)


def test_tensor_shape_mismatch(cfg, wit):
    wit.w[0] = wit.w[0][:, :3]          # wrong output width
    with pytest.raises(WitnessShapeError) as ei:
        validate_witness(cfg, wit)
    assert "w[0]" in str(ei.value)


def test_batch_mismatch(cfg, wit):
    bad = dataclasses.replace(wit, x=wit.x[:1])
    with pytest.raises(WitnessShapeError):
        validate_witness(cfg, bad)


def test_dtype_rejected(cfg, wit):
    bad = dataclasses.replace(wit, x=wit.x.astype(np.int32))
    with pytest.raises(WitnessDtypeError):
        validate_witness(cfg, bad)


def test_topology_mismatch(cfg, wit):
    bad = dataclasses.replace(wit, skips={2: 1})
    with pytest.raises(WitnessTopologyError):
        validate_witness(cfg, bad)


def test_zpp_out_of_range(cfg, wit):
    wit.zpp[0][0, 0] = 1 << (QC.q_bits - 1)     # == lim: out of [0, lim)
    with pytest.raises(WitnessRangeError):
        validate_witness(cfg, wit)


def test_bit_plane_not_binary(cfg, wit):
    wit.b[0][0, 0] = 2
    with pytest.raises(WitnessRangeError):
        validate_witness(cfg, wit)


def test_remainder_out_of_range(cfg, wit):
    wit.rz[0][0, 0] = 1 << QC.r_bits            # == 2^R: out of [0, 2^R)
    with pytest.raises(WitnessRangeError):
        validate_witness(cfg, wit)


def test_zkrelu_decomposition_must_hold(cfg, wit):
    wit.z[0][0, 0] += 1                         # break eq. (3)
    with pytest.raises(WitnessRangeError) as ei:
        validate_witness(cfg, wit)
    assert "eq. 3" in str(ei.value)


def test_grad_rescale_decomposition_must_hold(cfg, wit):
    wit.ga[0][0, 0] += 1                        # break eq. (5)
    with pytest.raises(WitnessRangeError) as ei:
        validate_witness(cfg, wit)
    assert "eq. 5" in str(ei.value)


def test_every_error_is_a_validation_and_value_error():
    for cls in (WitnessQuantError, WitnessShapeError, WitnessDtypeError,
                WitnessTopologyError, WitnessRangeError, WitnessStepError):
        assert issubclass(cls, WitnessValidationError)
        assert issubclass(cls, ValueError)


def test_step_monotonic_contract():
    assert check_step_monotonic("t", 5, None) == 5      # service-assigned
    assert check_step_monotonic("t", 5, 5) == 5         # declared, correct
    with pytest.raises(WitnessStepError):
        check_step_monotonic("t", 5, 4)                 # replayed/dup step
    with pytest.raises(WitnessStepError):
        check_step_monotonic("t", 5, 7)                 # gap


def test_validation_cheaper_than_a_prove(cfg, wit):
    """Preflight is meant to run on EVERY submit: keep it elementwise
    numpy, no group ops (a rough ceiling keeps it honest)."""
    import time
    t0 = time.perf_counter()
    for _ in range(20):
        validate_witness(cfg, wit)
    assert (time.perf_counter() - t0) / 20 < 0.05
