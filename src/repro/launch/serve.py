"""Crash-safe proving: a resident single-config service and a
multi-tenant gateway on one durability contract.

Two entry points share this module's journal/manifest machinery:

`ProverService`
    One (graph, quant, T) config, one worker thread, one out_dir —
    compile once, prove windows forever.  The prover's one-time costs
    (generator derivation, AOT-compiling every executable for the graph
    geometry) are paid at `start()`; after that each training window is
    proved from the warm in-process registry with zero re-tracing — and
    because the executables are also serialized to the on-disk cache
    (`repro.core.execache`), a RESTARTED service for the same config
    comes back warm too.

`ProvingGateway`
    Many named tenants, one shared pool of N supervised prove workers
    draining a weighted-fair admission queue
    (`launch/admission.WeightedFairQueue`).  Each tenant lives under
    ``<out_dir>/tenants/<name>/`` with its OWN vk.bin, journal,
    manifest and proof files — byte-compatible with a single
    `ProverService` out_dir, so `verify_bytes`, the membership audit
    and the recovery protocol below apply per tenant unchanged.

Gateway control plane (PR 10)
=============================

Admission
    ``submit(tenant, wit)`` PREFLIGHT-validates the witness against the
    tenant's key geometry (`launch/preflight.validate_witness`: shapes,
    dtypes, quantization ranges, eq. (3)/(5) decompositions, skip
    topology, step monotonicity) and rejects malformed input with typed
    `WitnessValidationError`\\ s BEFORE any byte is journaled.  Valid
    steps journal durably, then full windows enter the weighted-fair
    queue: dispatch is stride-scheduled by tenant weight (a flooding
    tenant cannot starve the rest), and when a ``queue_windows`` bound
    saturates, the newest window of the lowest-priority backlogged
    tenant is load-shed — terminal ``SHED`` manifest line, journal
    GC'd, counted in its stats — never silently lost.

Deadlines
    A tenant's ``deadline_s`` stamps each window at admission; a window
    still queued past its deadline is marked ``FAILED`` with reason
    ``deadline`` at dispatch (the worker is immediately free for live
    work).  Under subprocess isolation the remaining budget also bounds
    the child's wall clock.

Circuit breaker
    ``breaker_threshold`` consecutive prove failures trip a tenant to
    degraded journal-only mode: its windows PARK in memory (journal
    retained — durability is never degraded) instead of burning pool
    capacity.  After ``breaker_reset_s`` the breaker half-opens and
    releases ONE probe window; success re-closes it and unparks the
    backlog, failure re-opens it.

Worker pool
    Workers run window proves under `launch/supervise` (thread or
    subprocess isolation).  A monitor thread respawns dead workers and
    requeues the job a dead worker held at the FRONT of its tenant's
    queue; before re-proving, workers re-check the tenant manifest, so
    a worker that died after its COMMITTED line cannot double-commit.
    A job that kills workers repeatedly is marked ``FAILED`` (reason
    ``worker-death``) rather than crash-looping the pool.

Single ownership
    `start()` takes an advisory lockfile (``GATEWAY.lock``) on out_dir;
    a second gateway (or service) on the same directory raises
    `GatewayBusyError` while the owner is alive, and steals the lock
    when the recorded pid is dead.  ``status()`` (live) and
    `dir_status` / ``--status`` (from disk) expose queue depths,
    breaker states, worker liveness and per-tenant commit/failed/
    dropped/shed counters.  ``close()`` drains gracefully: every queued
    window proves, trailing partials get PARTIAL lines, the lock is
    released; close is idempotent and a later submit raises
    `ServiceClosedError`.

Storage failures
    Every durable write (journal npz, proof bin, manifest line) that
    hits an `OSError` surfaces as `train/checkpoint.StorageError` with
    no ``*.tmp`` orphan left behind.  Journal writes retry with backoff
    under ``backpressure="block"`` (then raise — nothing half-durable)
    or terminally DROP the window under ``drop_window``; proof/manifest
    write failures mark the window FAILED (reason ``storage``) or leave
    it non-terminal for restart re-prove — the worker loop never
    crashes on a full disk.

Durability contract (PR 8)
==========================

The service never loses a submitted witness to a crash, and never
commits a window twice — and the gateway holds the same invariant PER
TENANT across worker deaths, SIGKILL, ENOSPC and restarts (the
multi-tenant chaos suite, tests/test_gateway_chaos.py, drives every
fault point and asserts it).  Concretely:

Journal (write-ahead witness log)
    ``submit()`` appends the step witness to
    ``<out_dir>/journal/step_<s>.npz`` (atomic tmp+rename, the
    `train/checkpoint.atomic_write_bytes` pattern) BEFORE enqueueing it
    for the worker.  Step indices ``s`` are global and monotonic; window
    ``w`` owns steps ``[w*T, (w+1)*T)``.  A journal segment is
    garbage-collected only after its window reaches a terminal manifest
    state (``COMMITTED`` or ``DROPPED``).

Manifest (append-only commit log)
    ``<out_dir>/MANIFEST.jsonl``: one JSON line per event, fsync'd.
    Per-window status is LAST-WINS on read; a torn trailing line (crash
    mid-append) is skipped, not an error.  States:

    * ``COMMITTED`` — ``proof_<w>.bin`` is durable and verified-sized;
      written AFTER the atomic proof write, so a committed line implies
      readable proof bytes.
    * ``FAILED``    — every supervised prove attempt failed (or the
      journal for the window was corrupt/gapped); the service keeps
      going instead of wedging.
    * ``DROPPED``   — backpressure policy ``drop_window`` shed the
      window; its journal steps are GC'd and accounted in ``stats``.
    * ``PARTIAL``   — informational: close() drained with a trailing
      window short of T steps.  Its journal steps are RETAINED; a
      restarted service resumes the window (a later ``COMMITTED`` line
      supersedes it).

Restart / replay protocol
    ``start()`` on a non-empty out_dir: read the manifest, delete
    leftover ``*.tmp.*`` turds, GC journal steps of terminal windows,
    then replay the remaining journaled steps (complete un-committed
    windows and the trailing partial window) into the prove queue in
    order.  New submissions continue at
    ``next_step = max(highest journaled step + 1,
    (highest manifest window + 1) * T)``.  A proof file without a
    manifest line (crash between proof write and commit) is re-proved
    and overwritten — the manifest, not the file system, is the source
    of truth, which is what keeps "exactly one COMMITTED line per
    window" true under crashes at every fault point.

Supervised proving
    Each window proves under `launch/supervise.run_supervised`
    (``isolation="thread"``: in-process attempts, capped exponential
    backoff) or `run_subprocess_supervised` (``isolation="subprocess"``:
    each attempt is a fresh ``python -m repro.launch.serve
    --prove-window w`` child that rebuilds the ProvingKey warm from the
    executable cache, proves from the journal, atomically writes the
    proof, and hard-exits — signal deaths and timeouts retry, clean
    rejections don't).  Repeated failure marks the window ``FAILED``;
    the worker moves on.

Backpressure
    ``queue_size=0`` (default) keeps the historical unbounded queue.
    With a bound, policy ``block`` makes submit() wait (checking worker
    liveness so a dead worker raises instead of deadlocking), policy
    ``drop_window`` sheds the NEWEST window on overflow: mark
    ``DROPPED``, GC its journal, count it in
    ``stats["dropped_windows"]``, and ignore the window's remaining
    submissions.

Fault injection
    Pass a `train/resilience.FailureInjector` (or set ``ZKDL_FAULTS``
    for the CLI/subprocess workers).  Fault points: ``submit/journal-pre``,
    ``submit/journal-post``, ``prove/mid``, ``commit/pre-manifest``,
    ``worker/kill``.  The chaos tests (tests/test_serve_chaos.py) and
    the ci.sh chaos smoke drive every point and assert the contract
    above.

Layout of the output directory (created on start):

    GATEWAY.lock        advisory owner lock (pid + timestamp JSON)
    vk.bin              the serialized VerifyingKey (a few hundred bytes)
    proof_000000.bin    aggregated proof for window 0 (v3 byte format)
    MANIFEST.jsonl      append-only commit log (see above)
    journal/            write-ahead step witnesses (empty when idle)
    tenants/<name>/     gateway mode: one full sub-layout (vk.bin,
                        proofs, MANIFEST.jsonl, journal/) per tenant

Training never blocks on proving (default config): `submit(wit)`
journals + enqueues a step witness and returns; the background worker
assembles full windows, proves, and streams `proof_NNNNNN.bin` files.

    service = ProverService(graph, quant, n_steps=T, out_dir="proofs/")
    service.start()                       # warm keys, replay journal
    for step in range(service.next_step, n):
        ws, wit = train_step(ws, batch)   # training thread
        service.submit(wit)               # journaled, non-blocking
    service.close()                       # drain remaining full windows

CLI (synthetic trajectory driver, doubles as the chaos smoke):

    python -m repro.launch.serve --widths 4,4,4 --batch 2 \
        --window 2 --steps 4 --out-dir /tmp/proofs \
        [--warm-only] [--inject point@HITS[:action],...] [--isolation ...]

    # multi-tenant gateway: 2 tenants, pool of 2 workers
    python -m repro.launch.serve --tenants alice:2,bob --pool 2 \
        --steps 4 --window 2 --out-dir /tmp/gw

    # from-disk health snapshot (runbook entry point)
    python -m repro.launch.serve --status --out-dir /tmp/gw

Operator runbook: see "Operating the gateway" in
src/repro/core/pipeline/README.md (symptom -> manifest state ->
action table).
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import io
import json
import os
import queue
import re
import sys
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.launch import supervise
from repro.launch.admission import (CircuitBreaker, GatewayBusyError,
                                    ServiceClosedError, WeightedFairQueue,
                                    acquire_dir_lock, release_dir_lock)
from repro.launch.preflight import (WitnessValidationError,
                                    check_step_monotonic, validate_witness)

MANIFEST = "MANIFEST.jsonl"
JOURNAL_DIR = "journal"
TENANTS_DIR = "tenants"

COMMITTED = "COMMITTED"
FAILED = "FAILED"
DROPPED = "DROPPED"
SHED = "SHED"
PARTIAL = "PARTIAL"

#: manifest states after which a window will never be (re)proved
TERMINAL_STATES = (COMMITTED, DROPPED, SHED, FAILED)
#: terminal states whose journal segments are GC'd on recovery
GC_STATES = (COMMITTED, DROPPED, SHED)

# StepWitness list fields and their lengths as a function of the layer
# count L (scalars x/y and the skips dict are handled separately)
_WIT_LISTS = ("w", "z", "zpp", "b", "rz", "a", "gz", "ga", "gap", "rga",
              "gw")


# ---------------------------------------------------------------------------
# Witness journal
# ---------------------------------------------------------------------------

def journal_dir(out_dir: str) -> str:
    return os.path.join(out_dir, JOURNAL_DIR)


def _step_path(jdir: str, step: int) -> str:
    return os.path.join(jdir, f"step_{step:08d}.npz")


def journal_append(jdir: str, step: int, wit) -> str:
    """Durably persist one step witness (atomic tmp+rename npz)."""
    from repro.train.checkpoint import atomic_write_bytes

    os.makedirs(jdir, exist_ok=True)
    arrays = {"x": wit.x, "y": wit.y}
    lens = {}
    for field in _WIT_LISTS:
        vals = getattr(wit, field)
        lens[field] = len(vals)
        for i, arr in enumerate(vals):
            arrays[f"{field}.{i}"] = arr
    meta = {"q_bits": wit.cfg.q_bits, "r_bits": wit.cfg.r_bits,
            "lens": lens,
            "skips": sorted((int(k), int(v)) for k, v in wit.skips.items())}
    arrays["meta"] = np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    path = _step_path(jdir, step)
    atomic_write_bytes(path, buf.getvalue())
    return path


def journal_load(jdir: str, step: int):
    """Reconstruct a StepWitness from its journal segment.  Raises on a
    missing/corrupt segment — callers decide the failure policy."""
    from repro.core.quantfc import QuantConfig, StepWitness

    with np.load(_step_path(jdir, step)) as z:
        meta = json.loads(bytes(bytearray(np.asarray(z["meta"]))).decode())
        lists = {f: [np.asarray(z[f"{f}.{i}"])
                     for i in range(meta["lens"][f])]
                 for f in _WIT_LISTS}
        return StepWitness(
            cfg=QuantConfig(q_bits=meta["q_bits"], r_bits=meta["r_bits"]),
            x=np.asarray(z["x"]), y=np.asarray(z["y"]),
            skips={int(k): int(v) for k, v in meta["skips"]},
            **lists)


def journal_steps(jdir: str) -> List[int]:
    """Sorted step indices with a committed (fully renamed) segment."""
    if not os.path.isdir(jdir):
        return []
    out = []
    for f in os.listdir(jdir):
        if f.startswith("step_") and f.endswith(".npz"):
            try:
                out.append(int(f[5:-4]))
            except ValueError:
                pass
    return sorted(out)


def journal_gc(jdir: str, lo: int, hi: int) -> None:
    """Delete journal segments for steps in [lo, hi)."""
    for s in range(lo, hi):
        try:
            os.remove(_step_path(jdir, s))
        except FileNotFoundError:
            pass


def _clean_tmp_files(out_dir: str) -> None:
    """Remove torn-write turds (``*.tmp.*``) left by a crashed writer."""
    for root in (out_dir, journal_dir(out_dir)):
        if not os.path.isdir(root):
            continue
        for f in os.listdir(root):
            if ".tmp." in f:
                try:
                    os.remove(os.path.join(root, f))
                except OSError:
                    pass


# ---------------------------------------------------------------------------
# Manifest
# ---------------------------------------------------------------------------

def read_manifest(out_dir: str) -> Dict[int, dict]:
    """Last-wins view of MANIFEST.jsonl keyed by window.  Unparseable
    (torn) lines are skipped: a crash mid-append loses at most the event
    being written, never the file."""
    path = os.path.join(out_dir, MANIFEST)
    out: Dict[int, dict] = {}
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(rec, dict) and "window" in rec:
                out[int(rec["window"])] = rec
    return out


def manifest_commit_counts(out_dir: str) -> Dict[int, int]:
    """COMMITTED lines per window — the exactly-once audit."""
    path = os.path.join(out_dir, MANIFEST)
    counts: Dict[int, int] = {}
    if not os.path.exists(path):
        return counts
    with open(path) as f:
        for line in f:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(rec, dict) and rec.get("status") == COMMITTED:
                w = int(rec["window"])
                counts[w] = counts.get(w, 0) + 1
    return counts


def manifest_line_count(out_dir: str) -> int:
    path = os.path.join(out_dir, MANIFEST)
    if not os.path.exists(path):
        return 0
    with open(path, "rb") as f:
        return sum(1 for line in f if line.strip())


def compact_manifest(out_dir: str) -> dict:
    """Rewrite MANIFEST.jsonl keeping only the lines its readers can
    still observe, via the same tmp+rename+fsync discipline as every
    other durable write.  Replay semantics are UNCHANGED:

    * per window, the LAST line is kept (that is what `read_manifest`
      last-wins resolves to) plus every COMMITTED line — so
      `manifest_commit_counts`, the exactly-once audit, is preserved
      byte-for-byte even for the pathological double-commit it exists
      to catch;
    * lines WITHOUT a ``window`` key (e.g. the membership audit's
      DATASET_BINDING events) are kept verbatim, in order;
    * torn/unparseable lines are dropped — readers already skip them,
      and compaction is the natural point to shed them.

    Returns ``{"lines_before", "lines_after", "windows"}``.  A service
    run compacts automatically at start when the manifest exceeds its
    ``compact_threshold`` — a long-lived window cadence appends
    FAILED/retry/PARTIAL history forever, and replaying a multi-million
    line manifest on every restart is recovery-time debt."""
    from repro.train.checkpoint import atomic_write_bytes

    path = os.path.join(out_dir, MANIFEST)
    if not os.path.exists(path):
        return {"lines_before": 0, "lines_after": 0, "windows": 0}
    entries = []                # (idx, window_or_None, status, text)
    with open(path) as f:
        for idx, line in enumerate(f):
            text = line.strip()
            if not text:
                continue
            try:
                rec = json.loads(text)
            except json.JSONDecodeError:
                continue                  # torn line: shed at compaction
            if isinstance(rec, dict) and "window" in rec:
                entries.append((idx, int(rec["window"]),
                                rec.get("status"), text))
            else:
                entries.append((idx, None, None, text))
    last_per_window: Dict[int, int] = {}
    for idx, w, _status, _text in entries:
        if w is not None:
            last_per_window[w] = idx
    keep = []
    for idx, w, status, text in entries:
        if w is None or status == COMMITTED or last_per_window[w] == idx:
            keep.append(text)
    atomic_write_bytes(path, ("\n".join(keep) + "\n").encode()
                       if keep else b"")
    return {"lines_before": len(entries), "lines_after": len(keep),
            "windows": len(last_per_window)}


def recover_journal_dir(out_dir: str, T: int, manifest: Dict[int, dict],
                        append) -> Tuple[List[Tuple[int, object]], int]:
    """Shared restart/replay protocol for one service/tenant directory:
    GC journal segments of terminal windows, mark gapped/corrupt windows
    FAILED via ``append`` (which must also update ``manifest``), load
    the replayable steps, and compute ``next_step``.  Returns
    ``(replay, next_step)`` with ``replay`` ordered by step."""
    jdir = journal_dir(out_dir)
    steps = journal_steps(jdir)
    terminal = {w for w, rec in manifest.items()
                if rec.get("status") in GC_STATES}
    live = []
    for s in steps:
        if s // T in terminal:
            journal_gc(jdir, s, s + 1)   # crash between commit and GC
        else:
            live.append(s)
    # a PARTIAL window is non-terminal (its steps replay below), so
    # only terminal windows push next_step past their range
    max_terminal_w = max(
        (w for w, rec in manifest.items()
         if rec.get("status") in TERMINAL_STATES),
        default=-1)
    next_step = max([0, (max_terminal_w + 1) * T]
                    + [s + 1 for s in steps])
    by_window: Dict[int, List[int]] = {}
    for s in live:
        by_window.setdefault(s // T, []).append(s)
    replay: List[Tuple[int, object]] = []
    for w in sorted(by_window):
        ss = sorted(by_window[w])
        complete = ss == list(range(w * T, (w + 1) * T))
        tail = (w == max(by_window)
                and ss == list(range(w * T, w * T + len(ss))))
        if not (complete or tail):
            # a gap inside a non-trailing window: unprovable
            append({"window": w, "status": FAILED,
                    "error": "journal gap", "steps": ss})
            journal_gc(jdir, w * T, (w + 1) * T)
            continue
        loaded = []
        try:
            for s in ss:
                loaded.append((s, journal_load(jdir, s)))
        except Exception as exc:
            append({"window": w, "status": FAILED,
                    "error": f"journal corrupt: {exc}"})
            journal_gc(jdir, w * T, (w + 1) * T)
            continue
        replay.extend(loaded)
    # windows FAILED during this scan (gap/corrupt) are terminal too:
    # resume training after them, not inside them
    max_terminal_w = max(
        (w for w, rec in manifest.items()
         if rec.get("status") in TERMINAL_STATES),
        default=-1)
    next_step = max(next_step, (max_terminal_w + 1) * T)
    return replay, next_step


# ---------------------------------------------------------------------------
# Service
# ---------------------------------------------------------------------------

class ProverService:
    """Crash-safe warm resident prover for ONE (graph, quant, T) config.

    Thread model: `submit()` is called from the training thread; it
    journals the witness, then enqueues it.  The internal worker thread
    owns every ProofSession and does all proving/manifest IO (manifest
    appends share a lock with the submit path's DROPPED records).
    `stats` and `proofs` are safe to read at any time."""

    FAULT_POINTS = ("submit/journal-pre", "submit/journal-post",
                    "prove/mid", "commit/pre-manifest", "worker/kill",
                    "storage/journal", "storage/proof", "storage/manifest",
                    "lock/acquire")

    def __init__(self, graph, quant=None, n_steps: int = 1,
                 out_dir: str = "proofs", label: bytes = b"zkdl/train",
                 verify: bool = False, rng_seed: int = 0, *,
                 journal: bool = True, queue_size: int = 0,
                 backpressure: str = "block", max_attempts: int = 3,
                 backoff_base: float = 0.05, backoff_cap: float = 2.0,
                 prove_timeout: Optional[float] = None,
                 isolation: str = "thread",
                 compact_threshold: int = 10000,
                 injector=None):
        if backpressure not in ("block", "drop_window"):
            raise ValueError(f"unknown backpressure policy {backpressure!r}")
        if isolation not in ("thread", "subprocess"):
            raise ValueError(f"unknown isolation mode {isolation!r}")
        self.graph = graph
        self.quant = quant
        self.n_steps = n_steps
        self.out_dir = out_dir
        self.label = label
        self.verify = verify
        self.rng_seed = rng_seed
        self.journal = journal
        self.backpressure = backpressure
        self.max_attempts = max_attempts
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.prove_timeout = prove_timeout
        self.isolation = isolation
        self.compact_threshold = compact_threshold
        self.injector = injector
        self.pk = None
        self.vk = None
        self.proofs: List[Tuple[int, str, int, float]] = []
        self.warm_stats: Optional[dict] = None
        self.warm_seconds: float = 0.0
        self.stats = {"submitted": 0, "journaled": 0, "replayed": 0,
                      "proved": 0, "failed_windows": 0, "retries": 0,
                      "dropped_windows": 0, "dropped_steps": 0,
                      "partial_steps": 0, "storage_errors": 0}
        self._queue: "queue.Queue" = queue.Queue(maxsize=queue_size)
        self._worker: Optional[threading.Thread] = None
        self._errors: list = []
        self._mlock = threading.Lock()
        self._manifest: Dict[int, dict] = {}
        self._dropped: set = set()
        self._next_step = 0
        self._closed = False
        self._lock_path: Optional[str] = None

    # -- lifecycle ---------------------------------------------------------

    def start(self, warm: bool = True) -> "ProverService":
        """Compile keys (optionally AOT-warming every executable), write
        vk.bin, recover journal/manifest state, replay unproved windows,
        and launch the proving worker."""
        from repro.core import execache
        from repro.core.pipeline import compile as zk_compile
        from repro.train.checkpoint import atomic_write_bytes

        if self._closed:
            raise ServiceClosedError("service already closed")
        os.makedirs(self.out_dir, exist_ok=True)
        self._lock_path = acquire_dir_lock(self.out_dir,
                                           injector=self.injector)
        try:
            _clean_tmp_files(self.out_dir)
            if (self.compact_threshold
                    and manifest_line_count(self.out_dir)
                    > self.compact_threshold):
                compact_manifest(self.out_dir)
            t0 = time.perf_counter()
            self.pk, self.vk = zk_compile(self.graph, self.quant,
                                          n_steps=self.n_steps)
            if warm:
                before = execache.stats()
                self.pk.warm(seed=self.rng_seed)
                after = execache.stats()
                self.warm_stats = {k: after[k] - before[k] for k in after}
            self.warm_seconds = time.perf_counter() - t0
            atomic_write_bytes(os.path.join(self.out_dir, "vk.bin"),
                               self.vk.to_bytes())
            self._manifest = read_manifest(self.out_dir)
            self._dropped = {w for w, rec in self._manifest.items()
                             if rec.get("status") in (DROPPED, SHED)}
            replay = self._recover_journal() if self.journal else []
        except BaseException:
            release_dir_lock(self._lock_path)
            self._lock_path = None
            raise
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="zkdl-prover")
        self._worker.start()
        for step, wit in replay:
            self._queue.put((step, wit))    # durable steps never drop
            self.stats["replayed"] += 1
        return self

    @property
    def next_step(self) -> int:
        """Global index the next submit() will journal under — after a
        restart this is where training should resume."""
        return self._next_step

    def submit(self, wit) -> None:
        """Journal + queue one step witness.  Non-blocking with the
        default unbounded queue; under a bound, behavior follows the
        backpressure policy.  Raises if the worker has died (its original
        error chained) — the journal retains the step for a restart.

        A `StorageError` from the journal write (ENOSPC, IO error) is
        retried with backoff under ``backpressure="block"`` (then raised
        if the disk stays full — nothing was enqueued, nothing is
        half-durable); under ``drop_window`` the window is terminally
        DROPPED with reason ``storage`` instead."""
        if self._closed:
            raise ServiceClosedError(
                "submit() after close(): the service accepts no new work")
        if self._worker is None:
            raise RuntimeError("service not started")
        self._check_worker()
        step = self._next_step
        window = step // self.n_steps
        self.stats["submitted"] += 1
        if self.injector is not None:
            self.injector.fire("submit/journal-pre")
        if self.journal:
            if not self._journal_step(window, step, wit):
                self._next_step = step + 1
                return                  # window terminally DROPPED
            self.stats["journaled"] += 1
        if self.injector is not None:
            self.injector.fire("submit/journal-post")
        self._next_step = step + 1
        if window in self._dropped:
            self.stats["dropped_steps"] += 1
            if self.journal:
                journal_gc(journal_dir(self.out_dir), step, step + 1)
            return
        item = (step, wit)
        if self.backpressure == "drop_window" and self._queue.maxsize > 0:
            try:
                self._queue.put_nowait(item)
            except queue.Full:
                self._drop_window(window, step)
            return
        while True:
            try:
                self._queue.put(item, timeout=0.2)
                return
            except queue.Full:
                self._check_worker()

    def _journal_step(self, window: int, step: int, wit) -> bool:
        """Durably journal one step, applying the storage-failure policy.
        Returns False when the window was dropped (``drop_window`` under
        a persistent `StorageError`); raises under ``block`` when the
        retries are exhausted."""
        from repro.train.checkpoint import StorageError

        jdir = journal_dir(self.out_dir)

        def write():
            if self.injector is not None:
                self.injector.fire("storage/journal")
            journal_append(jdir, step, wit)

        if self.backpressure == "block":
            res = supervise.run_supervised(
                write, max_attempts=self.max_attempts,
                backoff_base=self.backoff_base,
                backoff_cap=self.backoff_cap, retry_on=(StorageError,))
            self.stats["storage_errors"] += res.n_attempts - (1 if res.ok
                                                              else 0)
            if not res.ok:
                raise res.error
            return True
        try:
            write()
            return True
        except StorageError as exc:
            self.stats["storage_errors"] += 1
            self._drop_window(window, step, reason="storage",
                              error=str(exc))
            return False

    def close(self, timeout: Optional[float] = None) -> None:
        """Drain queued FULL windows and stop the worker.  A trailing
        partial window is reported as PARTIAL in stats/manifest and its
        journal segments are retained for the next service run.  Never
        hangs on a dead worker: the sentinel is best-effort, the join is
        bounded, and the worker's original error is re-raised.

        Idempotent: closing a never-started or already-closed service is
        a no-op (a later ``submit()`` raises `ServiceClosedError`).  The
        directory lock is released on every exit path except a live
        worker still draining past ``timeout`` (the TimeoutError case —
        the worker keeps running, so the directory is still owned)."""
        if self._closed:
            return
        if self._worker is None:
            self._closed = True
            self._release_lock()
            return
        while True:
            try:
                self._queue.put(None, timeout=0.2)
                break
            except queue.Full:
                if not self._worker.is_alive():
                    break               # dead worker: nothing will drain
        self._worker.join(timeout)
        if self._worker.is_alive():
            raise TimeoutError(
                f"prover worker did not drain within {timeout}s "
                f"({self._queue.qsize()} items still queued; the journal "
                f"retains every submitted step)")
        self._worker = None
        self._closed = True
        self._release_lock()
        if self._errors:
            raise self._errors[0]

    def _release_lock(self) -> None:
        if self._lock_path is not None:
            release_dir_lock(self._lock_path)
            self._lock_path = None

    @property
    def n_proofs(self) -> int:
        return len(self.proofs)

    # -- internal ----------------------------------------------------------

    def _check_worker(self) -> None:
        if self._errors:
            raise RuntimeError(
                "prover worker died; journaled steps will replay on "
                "restart") from self._errors[0]
        if self._worker is not None and not self._worker.is_alive():
            raise RuntimeError("prover worker is not running")

    def _manifest_append(self, rec: dict) -> None:
        if self.injector is not None:
            self.injector.fire("storage/manifest")
        with self._mlock:
            with open(os.path.join(self.out_dir, MANIFEST), "a") as f:
                f.write(json.dumps(rec) + "\n")
                f.flush()
                os.fsync(f.fileno())
            self._manifest[int(rec["window"])] = rec

    def _manifest_append_safe(self, rec: dict) -> bool:
        """Manifest append that survives a full disk: a `StorageError`
        (injected or real OSError at the append) is counted, the record
        stays unwritten, and the caller keeps going — the window simply
        has no terminal line yet, so a restart re-derives its fate from
        the journal (the manifest stays the source of truth precisely
        because we never fake a line we could not fsync)."""
        from repro.train.checkpoint import StorageError

        try:
            self._manifest_append(rec)
            return True
        except (StorageError, OSError):
            self.stats["storage_errors"] += 1
            return False

    def _drop_window(self, window: int, step: int,
                     reason: str = "backpressure",
                     error: Optional[str] = None) -> None:
        """Backpressure/storage shed: the window's queued-or-journaled
        steps are discarded and the window is terminally DROPPED."""
        self._dropped.add(window)
        self.stats["dropped_windows"] += 1
        self.stats["dropped_steps"] += step - window * self.n_steps + 1
        if self.journal:
            journal_gc(journal_dir(self.out_dir),
                       window * self.n_steps, step + 1)
        rec = {"window": window, "status": DROPPED, "reason": reason,
               "n_steps": self.n_steps}
        if error is not None:
            rec["error"] = error
        self._manifest_append_safe(rec)

    def _recover_journal(self) -> List[Tuple[int, object]]:
        """Restart path: GC terminal windows' segments, load replayable
        steps, and position ``next_step`` (shared `recover_journal_dir`
        protocol — the gateway runs the same scan per tenant)."""
        replay, self._next_step = recover_journal_dir(
            self.out_dir, self.n_steps, self._manifest,
            self._manifest_append)
        return replay

    # -- worker ------------------------------------------------------------

    def _run(self) -> None:
        self._rng = np.random.default_rng(self.rng_seed)
        pending: Dict[int, Dict[int, object]] = {}
        try:
            while True:
                item = self._queue.get()
                if item is None:
                    for w in sorted(pending):
                        if w in self._dropped:
                            continue
                        k = len(pending[w])
                        self.stats["partial_steps"] += k
                        self._manifest_append_safe(
                            {"window": w, "status": PARTIAL,
                             "n_steps": k, "of": self.n_steps})
                    return
                step, wit = item
                w = step // self.n_steps
                if w in self._dropped:
                    pending.pop(w, None)
                    continue
                pending.setdefault(w, {})[step] = wit
                if len(pending[w]) < self.n_steps:
                    continue
                wits = [pending[w][s] for s in sorted(pending[w])]
                del pending[w]
                if w in self._dropped:
                    continue
                self._prove_window(w, wits)
        except Exception as exc:          # surfaced by submit()/close()
            self._errors.append(exc)

    def _proof_path(self, window: int) -> str:
        return os.path.join(self.out_dir, f"proof_{window:06d}.bin")

    def _prove_window(self, window: int, wits) -> None:
        from repro.core.pipeline import ProofSession, encode_proof
        from repro.train.checkpoint import atomic_write_bytes

        if self.injector is not None:
            self.injector.fire("worker/kill")
        t0 = time.perf_counter()
        path = self._proof_path(window)

        if self.isolation == "subprocess":
            res = supervise.run_subprocess_supervised(
                self._child_argv(window), max_attempts=self.max_attempts,
                backoff_base=self.backoff_base, backoff_cap=self.backoff_cap,
                timeout=self.prove_timeout, retry_nonzero=True,
                capture_output=True, text=True, env=self._child_env())
            data = None
            if res.ok:
                with open(path, "rb") as f:
                    data = f.read()     # the child wrote it atomically
            error = res.last_error
            if not res.ok and res.value is not None and res.value.stderr:
                error = f"{error}: {res.value.stderr.strip()[-400:]}"
        else:
            def attempt():
                if self.injector is not None:
                    self.injector.fire("prove/mid")
                session = ProofSession(self.pk, self._rng, label=self.label)
                for wit in wits:
                    session.add_step(wit)
                proof = session.prove()
                if self.verify and not session.verify(proof):
                    raise RuntimeError(f"window {window}: proof REJECTED")
                return encode_proof(proof)

            res = supervise.run_supervised(
                attempt, max_attempts=self.max_attempts,
                backoff_base=self.backoff_base,
                backoff_cap=self.backoff_cap)
            data = res.value if res.ok else None
            error = res.last_error

        self.stats["retries"] += max(0, res.n_attempts - 1)
        if not res.ok:
            self.stats["failed_windows"] += 1
            self._manifest_append_safe({"window": window, "status": FAILED,
                                        "error": error,
                                        "attempts": res.n_attempts})
            return
        if self.isolation != "subprocess":
            from repro.train.checkpoint import StorageError
            try:
                if self.injector is not None:
                    self.injector.fire("storage/proof")
                atomic_write_bytes(path, data)
            except StorageError as exc:
                # disk full at the proof write: the window FAILS (its
                # journal is retained for a restart with free space) and
                # the worker loop keeps serving the next window
                self.stats["storage_errors"] += 1
                self.stats["failed_windows"] += 1
                self._manifest_append_safe(
                    {"window": window, "status": FAILED,
                     "reason": "storage", "error": str(exc)})
                return
        if self.injector is not None:
            self.injector.fire("commit/pre-manifest")
        dt = time.perf_counter() - t0
        batch = self.pk.keys.cfg.batch
        committed = self._manifest_append_safe(
            {"window": window, "status": COMMITTED,
             "n_steps": self.n_steps, "bytes": len(data),
             # global sample-index range [start, count]
             # of the window's per-sample commitments —
             # the membership audit (repro.audit) binds
             # these into the dataset root
             "samples": [window * self.n_steps * batch,
                         self.n_steps * batch],
             "prove_s": round(dt, 4),
             "attempts": res.n_attempts})
        if not committed:
            # proof bytes are durable but the commit line is not: leave
            # the journal in place so a restart re-proves and commits —
            # NEVER GC ahead of the manifest
            return
        if self.journal:
            journal_gc(journal_dir(self.out_dir),
                       window * self.n_steps, (window + 1) * self.n_steps)
        self.stats["proved"] += 1
        self.proofs.append((window, path, len(data), dt))

    def _child_argv(self, window: int) -> List[str]:
        argv = [sys.executable, "-m", "repro.launch.serve",
                "--prove-window", str(window), "--out-dir", self.out_dir,
                "--seed", str(self.rng_seed),
                "--label", self.label.decode()]
        if self.verify:
            argv.append("--verify")
        return argv

    def _child_env(self) -> Dict[str, str]:
        return _subprocess_env()


def _subprocess_env() -> Dict[str, str]:
    env = dict(os.environ)
    src = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


# ---------------------------------------------------------------------------
# Multi-tenant proving gateway
# ---------------------------------------------------------------------------

_TENANT_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]{0,63}$")


@dataclasses.dataclass
class WindowJob:
    """One full window queued for proving."""
    window: int
    wits: List[object]
    enqueued_t: float                  # time.monotonic() at admission
    deadline_t: Optional[float] = None
    trial: bool = False                # breaker half-open probe
    kills: int = 0                     # workers that died holding this job


class _Tenant:
    """Per-tenant state: its own directory (journal + manifest + vk +
    proofs — byte-compatible with a single `ProverService` out_dir, so
    `verify_bytes`, the membership audit and the recovery protocol all
    work unchanged per tenant), its own keys, breaker, window assembly
    and counters."""

    def __init__(self, gateway: "ProvingGateway", name: str, n_steps: int,
                 weight: float, priority: int, deadline_s: Optional[float],
                 label: bytes, verify: bool, rng_seed: int):
        self.gateway = gateway
        self.name = name
        self.dir = os.path.join(gateway.out_dir, TENANTS_DIR, name)
        self.n_steps = n_steps
        self.weight = weight
        self.priority = priority
        self.deadline_s = deadline_s
        self.label = label
        self.verify = verify
        self.rng_seed = rng_seed
        self.pk = None
        self.vk = None
        self.cfg = None
        self.breaker = CircuitBreaker(gateway.breaker_threshold,
                                      gateway.breaker_reset_s)
        self.lock = threading.RLock()   # pending/manifest/stats/next_step
        self.pending: Dict[int, Dict[int, object]] = {}
        self.parked: "collections.deque" = collections.deque()
        self.manifest: Dict[int, dict] = {}
        self.dropped: set = set()
        self.next_step = 0
        self.proofs: List[Tuple[int, str, int, float]] = []
        self.stats = {"submitted": 0, "journaled": 0, "replayed": 0,
                      "rejected": 0, "proved": 0, "failed_windows": 0,
                      "deadline_expired": 0, "shed_windows": 0,
                      "dropped_windows": 0, "dropped_steps": 0,
                      "partial_steps": 0, "retries": 0, "deferred": 0,
                      "storage_errors": 0}

    def proof_path(self, window: int) -> str:
        return os.path.join(self.dir, f"proof_{window:06d}.bin")

    def child_argv(self, window: int) -> List[str]:
        argv = [sys.executable, "-m", "repro.launch.serve",
                "--prove-window", str(window), "--out-dir", self.dir,
                "--seed", str(self.rng_seed),
                "--label", self.label.decode()]
        if self.verify:
            argv.append("--verify")
        return argv

    def _manifest_append(self, rec: dict) -> None:
        if self.gateway.injector is not None:
            self.gateway.injector.fire("storage/manifest")
        with self.lock:
            with open(os.path.join(self.dir, MANIFEST), "a") as f:
                f.write(json.dumps(rec) + "\n")
                f.flush()
                os.fsync(f.fileno())
            self.manifest[int(rec["window"])] = rec

    def _manifest_append_safe(self, rec: dict) -> bool:
        from repro.train.checkpoint import StorageError

        try:
            self._manifest_append(rec)
            return True
        except (StorageError, OSError):
            with self.lock:
                self.stats["storage_errors"] += 1
            return False

    def snapshot(self, queued: int) -> dict:
        with self.lock:
            return {"queued": queued, "parked": len(self.parked),
                    "pending_steps": sum(len(v)
                                         for v in self.pending.values()),
                    "next_step": self.next_step,
                    "breaker": self.breaker.state,
                    "breaker_trips": self.breaker.trips,
                    "weight": self.weight, "priority": self.priority,
                    "deadline_s": self.deadline_s,
                    "committed": self.stats["proved"],
                    "failed": self.stats["failed_windows"],
                    "dropped": self.stats["dropped_windows"],
                    "shed": self.stats["shed_windows"],
                    "rejected": self.stats["rejected"],
                    "deadline_expired": self.stats["deadline_expired"],
                    "deferred": self.stats["deferred"],
                    "retries": self.stats["retries"],
                    "replayed": self.stats["replayed"],
                    "storage_errors": self.stats["storage_errors"]}


class ProvingGateway:
    """Multi-tenant proving gateway: one warm process, N supervised
    prove workers, many isolated tenants.

    Each tenant registered with `add_tenant` gets its own directory
    under ``<out_dir>/tenants/<name>/`` with its own vk.bin, journal,
    manifest and proof files — the SAME durability contract as a
    single `ProverService` out_dir, enforced per tenant (exactly one
    COMMITTED line per non-shed window, journal GC only after a
    terminal line, manifest as the sole source of truth).  On top of
    that, the gateway adds the multi-tenant control plane:

    * preflight validation — `submit()` rejects malformed witnesses
      with typed `WitnessValidationError`\\ s BEFORE journaling;
    * weighted-fair scheduling + priority load-shedding
      (`admission.WeightedFairQueue`);
    * per-window deadlines (expired at dispatch -> ``FAILED`` with
      reason ``deadline``; the worker is reclaimed immediately);
    * a per-tenant circuit breaker (K consecutive prove failures trip
      the tenant to journal-only; a half-open trial window re-closes
      it) — tripped windows are PARKED in memory with their journal
      retained, so nothing durable is lost while degraded;
    * a worker pool with a monitor thread that respawns dead workers
      and requeues the job a dead worker held (re-commit is impossible:
      the worker re-checks the tenant manifest before proving);
    * one advisory lockfile for the whole ``out_dir``
      (`admission.acquire_dir_lock`).

    Thread model: `submit()` may be called from MANY client threads
    (one per tenant or otherwise); per-tenant state is guarded by the
    tenant lock, cross-tenant dispatch by the queue's condition, and
    every worker owns a job exclusively from dequeue to terminal line.
    """

    FAULT_POINTS = ("pool/worker-kill", "gateway/pre-prove", "prove/mid",
                    "commit/pre-manifest", "storage/journal",
                    "storage/proof", "storage/manifest", "lock/acquire",
                    "breaker/trip")

    def __init__(self, out_dir: str, *, n_workers: int = 2,
                 queue_windows: int = 0, backpressure: str = "block",
                 isolation: str = "thread", max_attempts: int = 3,
                 backoff_base: float = 0.05, backoff_cap: float = 2.0,
                 prove_timeout: Optional[float] = None,
                 breaker_threshold: int = 3, breaker_reset_s: float = 30.0,
                 compact_threshold: int = 10000, preflight: bool = True,
                 injector=None):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        if backpressure not in ("block", "drop_window"):
            raise ValueError(f"unknown backpressure policy {backpressure!r}")
        if isolation not in ("thread", "subprocess"):
            raise ValueError(f"unknown isolation mode {isolation!r}")
        self.out_dir = out_dir
        self.n_workers = n_workers
        self.backpressure = backpressure
        self.isolation = isolation
        self.max_attempts = max_attempts
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.prove_timeout = prove_timeout
        self.breaker_threshold = breaker_threshold
        self.breaker_reset_s = breaker_reset_s
        self.compact_threshold = compact_threshold
        self.preflight = preflight
        self.injector = injector
        self.queue = WeightedFairQueue(capacity=queue_windows)
        self.tenants: Dict[str, _Tenant] = {}
        self.stats = {"worker_respawns": 0, "storage_errors": 0}
        self._workers: List[Optional[threading.Thread]] = []
        self._worker_done: List[bool] = []
        self._worker_events: List[dict] = []
        self._inflight: Dict[int, Tuple[str, WindowJob]] = {}
        self._monitor: Optional[threading.Thread] = None
        self._monitor_stop = threading.Event()
        self._lock_path: Optional[str] = None
        self._started = False
        self._draining = False
        self._closed = False

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "ProvingGateway":
        """Take the directory lock and launch the worker pool + monitor.
        Tenants are registered afterwards with `add_tenant` (their
        recovery replay starts proving immediately)."""
        if self._closed:
            raise ServiceClosedError("gateway already closed")
        if self._started:
            raise RuntimeError("gateway already started")
        os.makedirs(self.out_dir, exist_ok=True)
        self._lock_path = acquire_dir_lock(self.out_dir,
                                           injector=self.injector)
        self._workers = [None] * self.n_workers
        self._worker_done = [False] * self.n_workers
        for wid in range(self.n_workers):
            self._spawn_worker(wid)
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         daemon=True, name="zkdl-gw-monitor")
        self._monitor.start()
        self._started = True
        return self

    def add_tenant(self, name: str, graph, quant=None, n_steps: int = 1, *,
                   weight: float = 1.0, priority: int = 0,
                   deadline_s: Optional[float] = None,
                   label: bytes = b"zkdl/train", verify: bool = False,
                   rng_seed: int = 0, warm: bool = False) -> _Tenant:
        """Register (or re-open after a restart) one tenant: compile its
        keys, write its vk.bin, auto-compact an oversized manifest,
        recover its journal, and admit the replayable windows.  Returns
        the tenant handle (stats / proofs / dir are public on it)."""
        from repro.core.pipeline import compile as zk_compile
        from repro.train.checkpoint import atomic_write_bytes

        if not self._started:
            raise RuntimeError("gateway not started")
        if self._closed or self._draining:
            raise ServiceClosedError("gateway is closing")
        if not _TENANT_NAME_RE.match(name):
            raise ValueError(
                f"invalid tenant name {name!r}: must match "
                f"{_TENANT_NAME_RE.pattern} (it becomes a directory name)")
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        t = _Tenant(self, name, n_steps, weight, priority, deadline_s,
                    label, verify, rng_seed)
        os.makedirs(t.dir, exist_ok=True)
        _clean_tmp_files(t.dir)
        if (self.compact_threshold
                and manifest_line_count(t.dir) > self.compact_threshold):
            compact_manifest(t.dir)
        t.pk, t.vk = zk_compile(graph, quant, n_steps=n_steps)
        t.cfg = t.pk.keys.cfg
        if warm:
            t.pk.warm(seed=rng_seed)
        atomic_write_bytes(os.path.join(t.dir, "vk.bin"), t.vk.to_bytes())
        t.manifest = read_manifest(t.dir)
        t.dropped = {w for w, rec in t.manifest.items()
                     if rec.get("status") in (DROPPED, SHED)}
        replay, t.next_step = recover_journal_dir(
            t.dir, n_steps, t.manifest, t._manifest_append)
        self.queue.add_tenant(name, weight=weight, priority=priority)
        self.tenants[name] = t
        # reassemble replayed steps into windows; full windows are
        # force-admitted (durable work is never shed), the trailing
        # partial window waits in pending for its remaining submits
        by_window: Dict[int, Dict[int, object]] = {}
        for s, wit in replay:
            by_window.setdefault(s // n_steps, {})[s] = wit
            t.stats["replayed"] += 1
        now = time.monotonic()
        for w in sorted(by_window):
            if len(by_window[w]) < n_steps:
                t.pending[w] = by_window[w]
                continue
            wits = [by_window[w][s] for s in sorted(by_window[w])]
            job = WindowJob(window=w, wits=wits, enqueued_t=now,
                            deadline_t=(None if deadline_s is None
                                        else now + deadline_s))
            self.queue.push(name, job, force=True)
        return t

    # -- submit path -------------------------------------------------------

    def submit(self, tenant: str, wit, step: Optional[int] = None) -> None:
        """Validate, journal and enqueue one step witness for ``tenant``.

        Order of checks (nothing is journaled unless ALL pass):
        preflight geometry/range validation (`WitnessValidationError`
        subclasses), step monotonicity (`WitnessStepError`), then the
        durable journal append under the storage policy (``block``
        retries a full disk with backoff then raises; ``drop_window``
        terminally DROPs the window).  When the step completes a window,
        the window enters the weighted-fair queue — which may shed a
        lower-priority tenant's newest window (terminal ``SHED`` line,
        journal GC'd, counted in its stats)."""
        if self._closed or self._draining:
            raise ServiceClosedError(
                "submit() after close(): the gateway accepts no new work")
        if not self._started:
            raise RuntimeError("gateway not started")
        t = self.tenants.get(tenant)
        if t is None:
            raise ValueError(f"unknown tenant {tenant!r}")
        job = None
        with t.lock:
            t.stats["submitted"] += 1
            try:
                if self.preflight:
                    validate_witness(t.cfg, wit)
                s = check_step_monotonic(tenant, t.next_step, step)
            except WitnessValidationError:
                t.stats["rejected"] += 1
                raise
            w = s // t.n_steps
            if not self._journal_tenant_step(t, w, s, wit):
                t.next_step = s + 1
                return                  # window terminally DROPPED
            t.stats["journaled"] += 1
            t.next_step = s + 1
            if w in t.dropped:
                t.stats["dropped_steps"] += 1
                journal_gc(journal_dir(t.dir), s, s + 1)
                return
            t.pending.setdefault(w, {})[s] = wit
            if len(t.pending[w]) < t.n_steps:
                return
            wits = [t.pending[w][k] for k in sorted(t.pending[w])]
            del t.pending[w]
            now = time.monotonic()
            job = WindowJob(window=w, wits=wits, enqueued_t=now,
                            deadline_t=(None if t.deadline_s is None
                                        else now + t.deadline_s))
        self._admit(t, job)

    def _journal_tenant_step(self, t: _Tenant, window: int, step: int,
                             wit) -> bool:
        from repro.train.checkpoint import StorageError

        jdir = journal_dir(t.dir)

        def write():
            if self.injector is not None:
                self.injector.fire("storage/journal")
            journal_append(jdir, step, wit)

        if self.backpressure == "block":
            res = supervise.run_supervised(
                write, max_attempts=self.max_attempts,
                backoff_base=self.backoff_base,
                backoff_cap=self.backoff_cap, retry_on=(StorageError,))
            t.stats["storage_errors"] += res.n_attempts - (1 if res.ok
                                                           else 0)
            if not res.ok:
                raise res.error
            return True
        try:
            write()
            return True
        except StorageError as exc:
            t.stats["storage_errors"] += 1
            t.dropped.add(window)
            t.stats["dropped_windows"] += 1
            t.stats["dropped_steps"] += (
                len(t.pending.pop(window, {})) + 1)
            journal_gc(jdir, window * t.n_steps, step + 1)
            t._manifest_append_safe(
                {"window": window, "status": DROPPED, "reason": "storage",
                 "error": str(exc), "n_steps": t.n_steps})
            return False

    def _admit(self, t: _Tenant, job: WindowJob) -> None:
        shed = self.queue.push(t.name, job)
        for victim_name, victim_job in shed:
            self._mark_shed(self.tenants[victim_name], victim_job)

    def _mark_shed(self, t: _Tenant, job: WindowJob) -> None:
        with t.lock:
            t.dropped.add(job.window)
            t.stats["shed_windows"] += 1
        t._manifest_append_safe(
            {"window": job.window, "status": SHED, "reason": "admission",
             "n_steps": t.n_steps})
        journal_gc(journal_dir(t.dir), job.window * t.n_steps,
                   (job.window + 1) * t.n_steps)

    # -- worker pool -------------------------------------------------------

    def _spawn_worker(self, wid: int) -> None:
        th = threading.Thread(target=self._worker_entry, args=(wid,),
                              daemon=True, name=f"zkdl-gw-worker-{wid}")
        self._worker_done[wid] = False
        self._workers[wid] = th
        th.start()

    def _worker_entry(self, wid: int) -> None:
        try:
            while True:
                got = self.queue.pop(timeout=0.1)
                if got is None:
                    if self._draining:
                        self._worker_done[wid] = True
                        return
                    continue
                name, job = got
                t = self.tenants[name]
                self._inflight[wid] = (name, job)
                if self.injector is not None:
                    self.injector.fire("pool/worker-kill")
                self._process(wid, t, job)
                self._inflight.pop(wid, None)
        except BaseException as exc:    # worker death: monitor reclaims
            self._worker_events.append(
                {"worker": wid, "error": f"{type(exc).__name__}: {exc}",
                 "at": round(time.monotonic(), 3)})

    def _monitor_loop(self) -> None:
        while not self._monitor_stop.wait(0.05):
            for wid, th in enumerate(self._workers):
                if (th is not None and not th.is_alive()
                        and not self._worker_done[wid]):
                    self._reclaim(wid)
            for t in list(self.tenants.values()):
                self._pump_parked(t)

    def _reclaim(self, wid: int) -> None:
        """A worker died mid-job: requeue its in-flight window at the
        front of its tenant's queue (or FAIL it after repeated deaths —
        a job that reliably kills workers must not loop forever) and
        respawn the worker slot."""
        inflight = self._inflight.pop(wid, None)
        if inflight is not None:
            name, job = inflight
            t = self.tenants.get(name)
            job.kills += 1
            if t is not None:
                if job.kills >= self.max_attempts:
                    with t.lock:
                        t.stats["failed_windows"] += 1
                    t._manifest_append_safe(
                        {"window": job.window, "status": FAILED,
                         "reason": "worker-death",
                         "error": f"{job.kills} workers died holding "
                                  f"this window"})
                else:
                    self.queue.requeue(name, job)
        self.stats["worker_respawns"] += 1
        self._spawn_worker(wid)

    def _pump_parked(self, t: _Tenant) -> None:
        """Release parked (breaker-gated) windows back into the queue:
        all of them once the breaker is closed, exactly one probe when
        it is ready to half-open."""
        with t.lock:
            if not t.parked:
                return
            if t.breaker.state == "closed":
                jobs = list(t.parked)
                t.parked.clear()
            elif t.breaker.ready_for_trial:
                jobs = [t.parked.popleft()]
            else:
                return
        for job in jobs:
            job.trial = False           # re-gated at dispatch
            self.queue.requeue(t.name, job)

    # -- window processing -------------------------------------------------

    def _process(self, wid: int, t: _Tenant, job: WindowJob) -> None:
        from repro.train.checkpoint import StorageError, atomic_write_bytes

        with t.lock:
            rec = t.manifest.get(job.window)
            if ((rec is not None and rec.get("status") in GC_STATES)
                    or job.window in t.dropped):
                return                  # requeued after its terminal line
        now = time.monotonic()
        if job.deadline_t is not None and now > job.deadline_t:
            with t.lock:
                t.stats["deadline_expired"] += 1
                t.stats["failed_windows"] += 1
            t._manifest_append_safe(
                {"window": job.window, "status": FAILED,
                 "reason": "deadline",
                 "waited_s": round(now - job.enqueued_t, 3)})
            if job.trial:               # an expired probe re-opens
                t.breaker.record_failure()
            return
        if not job.trial:
            verdict = t.breaker.allow()
            if verdict == "defer":
                with t.lock:
                    t.stats["deferred"] += 1
                    t.parked.append(job)
                return
            job.trial = verdict == "trial"
        t0 = time.perf_counter()
        res, data, error, timed_out = self._attempt_window(t, job, now)
        with t.lock:
            t.stats["retries"] += max(0, res.n_attempts - 1)
        if not res.ok:
            reason = "deadline" if timed_out else "prove"
            with t.lock:
                t.stats["failed_windows"] += 1
                if timed_out:
                    t.stats["deadline_expired"] += 1
            t._manifest_append_safe(
                {"window": job.window, "status": FAILED, "reason": reason,
                 "error": error, "attempts": res.n_attempts})
            if reason == "deadline" and not job.trial:
                return                  # capacity, not prover health
            tripped = t.breaker.record_failure()
            if tripped and self.injector is not None:
                self.injector.fire("breaker/trip")
            return
        path = t.proof_path(job.window)
        if self.isolation != "subprocess":
            try:
                if self.injector is not None:
                    self.injector.fire("storage/proof")
                atomic_write_bytes(path, data)
            except StorageError as exc:
                with t.lock:
                    t.stats["failed_windows"] += 1
                    t.stats["storage_errors"] += 1
                t._manifest_append_safe(
                    {"window": job.window, "status": FAILED,
                     "reason": "storage", "error": str(exc)})
                if job.trial:           # infra failure still ends the probe
                    t.breaker.record_failure()
                return
        if self.injector is not None:
            self.injector.fire("commit/pre-manifest")
        dt = time.perf_counter() - t0
        batch = t.cfg.batch
        committed = t._manifest_append_safe(
            {"window": job.window, "status": COMMITTED,
             "n_steps": t.n_steps, "bytes": len(data),
             "samples": [job.window * t.n_steps * batch,
                         t.n_steps * batch],
             "prove_s": round(dt, 4), "attempts": res.n_attempts,
             "worker": wid})
        if not committed:
            # proof durable, commit line not: journal stays, restart
            # re-proves and commits — never GC ahead of the manifest
            if job.trial:
                t.breaker.record_failure()
            return
        journal_gc(journal_dir(t.dir), job.window * t.n_steps,
                   (job.window + 1) * t.n_steps)
        with t.lock:
            t.stats["proved"] += 1
            t.proofs.append((job.window, path, len(data), dt))
        t.breaker.record_success()

    def _attempt_window(self, t: _Tenant, job: WindowJob, now: float):
        """One supervised prove of a window.  Returns ``(result, data,
        error, timed_out)``; ``timed_out`` means the failure was the
        deadline/timeout budget, not the prover."""
        from repro.core.pipeline import ProofSession, encode_proof

        if self.isolation == "subprocess":
            budget = self.prove_timeout
            if job.deadline_t is not None:
                remaining = max(0.01, job.deadline_t - now)
                budget = (remaining if budget is None
                          else min(budget, remaining))
            res = supervise.run_subprocess_supervised(
                t.child_argv(job.window), max_attempts=self.max_attempts,
                backoff_base=self.backoff_base,
                backoff_cap=self.backoff_cap, timeout=budget,
                retry_nonzero=True, capture_output=True, text=True,
                env=_subprocess_env())
            data = None
            if res.ok:
                with open(t.proof_path(job.window), "rb") as f:
                    data = f.read()
            error = res.last_error
            if not res.ok and res.value is not None and res.value.stderr:
                error = f"{error}: {res.value.stderr.strip()[-400:]}"
            timed_out = ((not res.ok)
                         and any(a.timed_out for a in res.attempts))
            return res, data, error, timed_out

        def attempt():
            if self.injector is not None:
                self.injector.fire("gateway/pre-prove")
                self.injector.fire("prove/mid")
            rng = np.random.default_rng((t.rng_seed, job.window))
            session = ProofSession(t.pk, rng, label=t.label)
            for wit in job.wits:
                session.add_step(wit)
            proof = session.prove()
            if t.verify and not session.verify(proof):
                raise RuntimeError(f"window {job.window}: proof REJECTED")
            return encode_proof(proof)

        res = supervise.run_supervised(
            attempt, max_attempts=self.max_attempts,
            backoff_base=self.backoff_base, backoff_cap=self.backoff_cap)
        return (res, res.value if res.ok else None, res.last_error, False)

    # -- status + shutdown -------------------------------------------------

    def status(self) -> dict:
        """Point-in-time health snapshot (the ``--status`` CLI reads the
        same shape from disk via `dir_status` when no gateway is live)."""
        alive = sum(1 for wid, th in enumerate(self._workers)
                    if th is not None and th.is_alive()
                    and not self._worker_done[wid])
        return {
            "started": self._started, "draining": self._draining,
            "closed": self._closed,
            "workers": {"pool": self.n_workers, "alive": alive,
                        "respawns": self.stats["worker_respawns"],
                        "inflight": {wid: (name, job.window)
                                     for wid, (name, job)
                                     in dict(self._inflight).items()},
                        "events": list(self._worker_events)},
            "queue": {"depth": self.queue.depth(),
                      "capacity": self.queue.capacity},
            "storage_errors": self.stats["storage_errors"],
            "tenants": {name: t.snapshot(self.queue.depth(name))
                        for name, t in self.tenants.items()},
        }

    def close(self, timeout: Optional[float] = None) -> None:
        """Graceful drain: stop admitting, let the pool finish every
        queued window, stop the monitor, record trailing partial windows
        as PARTIAL (journal retained), release the directory lock.
        Idempotent; never hangs on a dead pool (the monitor respawns
        workers during the drain, and the join is bounded)."""
        if self._closed:
            return
        if not self._started:
            self._closed = True
            return
        self._draining = True           # submit() rejects from here on
        self.queue.drain()
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        for wid in range(self.n_workers):
            while True:
                th = self._workers[wid]
                if th is None or not th.is_alive() or self._worker_done[wid]:
                    break
                budget = (0.2 if deadline is None
                          else min(0.2, deadline - time.monotonic()))
                if budget <= 0:
                    raise TimeoutError(
                        f"gateway pool did not drain within {timeout}s "
                        f"({self.queue.depth()} windows still queued; "
                        f"every journaled step is retained)")
                th.join(budget)
        self._monitor_stop.set()
        if self._monitor is not None:
            self._monitor.join(5.0)
            self._monitor = None
        for t in self.tenants.values():
            with t.lock:
                for w in sorted(t.pending):
                    if w in t.dropped:
                        continue
                    k = len(t.pending[w])
                    t.stats["partial_steps"] += k
                    t._manifest_append_safe(
                        {"window": w, "status": PARTIAL,
                         "n_steps": k, "of": t.n_steps})
        self._closed = True
        if self._lock_path is not None:
            release_dir_lock(self._lock_path)
            self._lock_path = None


def dir_status(out_dir: str) -> dict:
    """Offline (from-disk) health snapshot of a gateway or service
    directory: lock ownership, per-tenant manifest/journal/proof
    counts.  Safe to run next to a LIVE gateway — it only reads."""
    from repro.launch.admission import LOCKFILE, _pid_alive

    def summary(d: str) -> dict:
        man = read_manifest(d)
        by_status: Dict[str, int] = {}
        for rec in man.values():
            st = rec.get("status", "?")
            by_status[st] = by_status.get(st, 0) + 1
        proof_files = [f for f in os.listdir(d)
                       if f.startswith("proof_") and f.endswith(".bin")] \
            if os.path.isdir(d) else []
        return {"windows": len(man), "by_status": by_status,
                "commit_lines": sum(manifest_commit_counts(d).values()),
                "journal_steps": len(journal_steps(journal_dir(d))),
                "proof_files": len(proof_files)}

    out: dict = {"out_dir": out_dir, "lock": None, "tenants": {}}
    lock_path = os.path.join(out_dir, LOCKFILE)
    if os.path.exists(lock_path):
        try:
            with open(lock_path) as f:
                owner = json.load(f)
            pid = int(owner.get("pid"))
            out["lock"] = {"pid": pid, "alive": _pid_alive(pid)}
        except (OSError, TypeError, ValueError, json.JSONDecodeError):
            out["lock"] = {"pid": None, "alive": False}
    tdir = os.path.join(out_dir, TENANTS_DIR)
    if os.path.isdir(tdir):
        for name in sorted(os.listdir(tdir)):
            d = os.path.join(tdir, name)
            if os.path.isdir(d):
                out["tenants"][name] = summary(d)
    if (os.path.exists(os.path.join(out_dir, MANIFEST))
            or os.path.isdir(journal_dir(out_dir))):
        out["service"] = summary(out_dir)
    return out


# ---------------------------------------------------------------------------
# Subprocess prove worker + CLI
# ---------------------------------------------------------------------------

def _prove_window_child(args) -> int:
    """One isolated prove attempt: rebuild the ProvingKey from vk.bin
    (warm via the executable cache), load the window's witnesses from
    the journal, prove, atomically write the proof, hard-exit.  The
    PARENT commits the manifest line — this process crashing after the
    proof write therefore cannot double-commit."""
    from repro.core.pipeline import (ProofSession, compile as zk_compile,
                                     encode_proof)
    from repro.core.pipeline.proofio import decode_vk
    from repro.core.quantfc import QuantConfig
    from repro.train.checkpoint import atomic_write_bytes
    from repro.train.resilience import FailureInjector

    injector = FailureInjector.from_env()
    out = args.out_dir
    with open(os.path.join(out, "vk.bin"), "rb") as f:
        vk = decode_vk(f.read())
    cfg = vk.cfg
    pk, _ = zk_compile(cfg.graph,
                       QuantConfig(q_bits=cfg.q_bits, r_bits=cfg.r_bits),
                       n_steps=cfg.n_steps)
    w, T = args.prove_window, cfg.n_steps
    jdir = journal_dir(out)
    wits = [journal_load(jdir, s) for s in range(w * T, (w + 1) * T)]
    if injector is not None:
        injector.fire("prove/mid")
    rng = np.random.default_rng((args.seed, w))
    session = ProofSession(pk, rng, label=args.label.encode())
    for wit in wits:
        session.add_step(wit)
    proof = session.prove()
    if args.verify and not session.verify(proof):
        print(f"[serve:child] window {w}: proof REJECTED", flush=True)
        return 1
    data = encode_proof(proof)
    atomic_write_bytes(os.path.join(out, f"proof_{w:06d}.bin"), data)
    print(f"[serve:child] window {w}: {len(data)} B proved", flush=True)
    # skip interpreter/XLA teardown (known SIGABRT flake) — the proof is
    # already durable, and the parent reads only files + returncode
    supervise.hard_exit(0)
    return 0                              # unreachable


def _gateway_main(args) -> int:
    """Synthetic multi-tenant driver: one gateway, --pool workers, one
    synthetic SGD trajectory per tenant (tenant i seeds with seed+i),
    submissions interleaved round-robin.  Rerunning on the same out_dir
    after a crash resumes each tenant from its recovered next_step —
    the CLI form of the multi-tenant chaos smoke."""
    from repro.core.quantfc import (QuantConfig,
                                    synthetic_sgd_trajectory_widths)
    from repro.core.pipeline import build_fcnn_graph
    from repro.train.resilience import FailureInjector

    specs = []
    for part in args.tenants.split(","):
        bits = part.strip().split(":")
        if not bits[0]:
            continue
        specs.append((bits[0],
                      float(bits[1]) if len(bits) > 1 else 1.0,
                      int(bits[2]) if len(bits) > 2 else 0))
    if not specs:
        print("[gateway] --tenants parsed to nothing", file=sys.stderr)
        return 2
    injector = (FailureInjector.from_spec(args.inject) if args.inject
                else FailureInjector.from_env())
    widths = tuple(int(w) for w in args.widths.split(","))
    quant = QuantConfig(q_bits=args.q_bits, r_bits=args.r_bits)
    graph = build_fcnn_graph(widths, batch=args.batch)
    gw = ProvingGateway(args.out_dir, n_workers=args.pool,
                        queue_windows=args.queue_windows,
                        backpressure=args.backpressure,
                        isolation=args.isolation,
                        max_attempts=args.max_attempts,
                        prove_timeout=args.prove_timeout,
                        breaker_threshold=args.breaker_threshold,
                        breaker_reset_s=args.breaker_reset,
                        injector=injector)
    gw.start()
    t0 = time.perf_counter()
    tenants = {}
    for i, (name, weight, priority) in enumerate(specs):
        tenants[name] = gw.add_tenant(
            name, graph, quant, n_steps=args.window, weight=weight,
            priority=priority, deadline_s=args.deadline,
            label=args.label.encode(), verify=args.verify,
            rng_seed=args.seed + i, warm=(i == 0))
        print(f"[gateway] tenant {name}: weight={weight} "
              f"priority={priority} resume at step "
              f"{tenants[name].next_step} "
              f"({tenants[name].stats['replayed']} steps replayed)",
              flush=True)
    if args.warm_only:
        gw.close()
        return 0
    trajs = {name: synthetic_sgd_trajectory_widths(
                 args.steps, widths, args.batch, quant,
                 seed=args.seed + i)
             for i, (name, _w, _p) in enumerate(specs)}
    cursors = {name: min(tenants[name].next_step, args.steps)
               for name in trajs}
    progressed = True
    while progressed:
        progressed = False
        for name in trajs:              # round-robin interleave
            c = cursors[name]
            if c >= args.steps:
                continue
            gw.submit(name, trajs[name][c])
            cursors[name] = c + 1
            progressed = True
    gw.close()
    dt = time.perf_counter() - t0
    total = 0
    for name, t in tenants.items():
        total += t.stats["proved"]
        print(f"[gateway] tenant {name}: {t.stats['proved']} proofs, "
              f"stats={t.stats}", flush=True)
    print(f"[gateway] {total} proofs across {len(tenants)} tenants in "
          f"{dt:.1f}s; status={json.dumps(gw.status()['workers'])}",
          flush=True)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Crash-safe warm zkDL prover service (synthetic driver)")
    ap.add_argument("--widths", default="4,4,4",
                    help="layer-width table d_0..d_L")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--window", type=int, default=2,
                    help="T: steps aggregated per proof")
    ap.add_argument("--steps", type=int, default=4,
                    help="synthetic training steps to drive through")
    ap.add_argument("--q-bits", type=int, default=16)
    ap.add_argument("--r-bits", type=int, default=4)
    ap.add_argument("--out-dir", default="proofs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--label", default="zkdl/train")
    ap.add_argument("--verify", action="store_true",
                    help="verify each proof before writing it")
    ap.add_argument("--warm-only", action="store_true",
                    help="compile + warm the executable cache, then exit")
    ap.add_argument("--queue-size", type=int, default=0,
                    help="bound the submit queue (0 = unbounded)")
    ap.add_argument("--backpressure", default="block",
                    choices=["block", "drop_window"])
    ap.add_argument("--max-attempts", type=int, default=3)
    ap.add_argument("--prove-timeout", type=float, default=None)
    ap.add_argument("--isolation", default="thread",
                    choices=["thread", "subprocess"])
    ap.add_argument("--inject", default=None,
                    help="fault spec point@HITS[:action],... "
                         "(ZKDL_FAULTS env works too)")
    ap.add_argument("--bind-dataset", action="store_true",
                    help="after the run, bind every COMMITTED window's "
                         "sample commitments into dataset.bin "
                         "(repro.audit membership root)")
    ap.add_argument("--status", action="store_true",
                    help="print the from-disk health snapshot of "
                         "--out-dir (lock owner, per-tenant manifest/"
                         "journal/proof counts) and exit")
    ap.add_argument("--tenants", default=None,
                    help="run the multi-tenant gateway instead of the "
                         "single service: NAME[:WEIGHT[:PRIORITY]],... "
                         "(e.g. 'alice:2,bob:1:1')")
    ap.add_argument("--pool", type=int, default=2,
                    help="gateway worker pool size")
    ap.add_argument("--queue-windows", type=int, default=0,
                    help="gateway admission-queue capacity in windows "
                         "(0 = unbounded)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-window deadline in seconds (gateway)")
    ap.add_argument("--breaker-threshold", type=int, default=3)
    ap.add_argument("--breaker-reset", type=float, default=30.0)
    ap.add_argument("--prove-window", type=int, default=None,
                    help=argparse.SUPPRESS)   # internal: subprocess worker
    args = ap.parse_args(argv)

    if args.status:
        print(json.dumps(dir_status(args.out_dir), indent=1, sort_keys=True))
        return 0
    if args.prove_window is not None:
        return _prove_window_child(args)
    if args.tenants is not None:
        return _gateway_main(args)

    from repro.core.quantfc import (QuantConfig,
                                    synthetic_sgd_trajectory_widths)
    from repro.core.pipeline import build_fcnn_graph
    from repro.train.resilience import FailureInjector

    injector = (FailureInjector.from_spec(args.inject) if args.inject
                else FailureInjector.from_env())
    widths = tuple(int(w) for w in args.widths.split(","))
    quant = QuantConfig(q_bits=args.q_bits, r_bits=args.r_bits)
    graph = build_fcnn_graph(widths, batch=args.batch)
    service = ProverService(graph, quant, n_steps=args.window,
                            out_dir=args.out_dir, verify=args.verify,
                            rng_seed=args.seed,
                            label=args.label.encode(),
                            queue_size=args.queue_size,
                            backpressure=args.backpressure,
                            max_attempts=args.max_attempts,
                            prove_timeout=args.prove_timeout,
                            isolation=args.isolation, injector=injector)
    service.start(warm=True)
    print(f"[serve] warm in {service.warm_seconds:.1f}s "
          f"(exec cache: {service.warm_stats})", flush=True)
    if args.warm_only:
        service.close()
        return 0

    wits = synthetic_sgd_trajectory_widths(
        args.steps, widths, args.batch, quant, seed=args.seed)
    start_at = min(service.next_step, len(wits))
    if start_at or service.stats["replayed"]:
        print(f"[serve] resuming at step {start_at} "
              f"({service.stats['replayed']} journaled steps replayed)",
              flush=True)
    t0 = time.perf_counter()
    for wit in wits[start_at:]:
        service.submit(wit)
    service.close()
    dt = time.perf_counter() - t0
    for window, path, n_bytes, secs in service.proofs:
        print(f"[serve] window {window}: {n_bytes} B -> {path} "
              f"({secs:.2f}s)", flush=True)
    print(f"[serve] {service.n_proofs} proofs for {args.steps} steps "
          f"in {dt:.1f}s total; stats={service.stats}", flush=True)
    if args.bind_dataset:
        from repro.audit.membership import bind_service_dir
        _, binding = bind_service_dir(args.out_dir)
        print(f"[serve] dataset root {binding.root.hex()} "
              f"({binding.n_samples} samples across "
              f"{len(binding.windows)} windows) -> "
              f"{os.path.join(args.out_dir, 'dataset.bin')}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
