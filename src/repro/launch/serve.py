"""Resident warm prover service: compile once, prove windows forever.

The prover's one-time costs (generator derivation, AOT-compiling every
executable for the graph geometry) are paid at `ProverService.start()`;
after that each training window is proved from the warm in-process
registry with zero re-tracing — and because the executables are also
serialized to the on-disk cache (`repro.core.execache`), a RESTARTED
service for the same config comes back warm too.

Layout of the output directory (created on start):

    vk.bin              the serialized VerifyingKey (a few hundred bytes)
    proof_000000.bin    aggregated proof for window 0 (v3 byte format)
    proof_000001.bin    ...
    MANIFEST.jsonl      one line per proof: window, steps, bytes, seconds

Training never blocks on proving: `submit(wit)` enqueues a step witness
and returns; a background worker assembles full windows, proves, and
streams `proof_NNNNNN.bin` files while the training loop keeps going.

    service = ProverService(graph, quant, n_steps=T, out_dir="proofs/")
    service.start()                       # warm keys, write vk.bin
    for step in range(n):
        ws, wit = train_step(ws, batch)   # training thread
        service.submit(wit)               # non-blocking
    service.close()                       # drain remaining full windows

CLI (synthetic trajectory driver, doubles as the warm-service smoke):

    python -m repro.launch.serve --widths 4,4,4 --batch 2 \
        --window 2 --steps 4 --out-dir /tmp/proofs [--warm-only]
"""
from __future__ import annotations

import argparse
import json
import os
import queue
import threading
import time
from typing import Optional

import numpy as np


class ProverService:
    """Warm resident prover for ONE (graph, quant, T) configuration.

    Thread model: `submit()` is called from the training thread and only
    appends to a queue; the internal worker thread owns every
    ProofSession and does all proving/IO.  `stats` and `proofs` are
    safe to read at any time (list appends are atomic)."""

    def __init__(self, graph, quant=None, n_steps: int = 1,
                 out_dir: str = "proofs", label: bytes = b"zkdl/train",
                 verify: bool = False, rng_seed: int = 0):
        self.graph = graph
        self.quant = quant
        self.n_steps = n_steps
        self.out_dir = out_dir
        self.label = label
        self.verify = verify
        self.rng_seed = rng_seed
        self.pk = None
        self.vk = None
        self.proofs = []          # (window_idx, path, n_bytes, seconds)
        self.warm_stats: Optional[dict] = None
        self.warm_seconds: float = 0.0
        self._queue: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._window = 0
        self._errors = []

    # -- lifecycle ---------------------------------------------------------

    def start(self, warm: bool = True) -> "ProverService":
        """Compile keys (optionally AOT-warming every executable), write
        vk.bin, and launch the proving worker."""
        from repro.core import execache
        from repro.core.pipeline import compile as zk_compile

        os.makedirs(self.out_dir, exist_ok=True)
        t0 = time.perf_counter()
        self.pk, self.vk = zk_compile(self.graph, self.quant,
                                      n_steps=self.n_steps)
        if warm:
            before = execache.stats()
            self.pk.warm(seed=self.rng_seed)
            after = execache.stats()
            self.warm_stats = {k: after[k] - before[k] for k in after}
        self.warm_seconds = time.perf_counter() - t0
        with open(os.path.join(self.out_dir, "vk.bin"), "wb") as f:
            f.write(self.vk.to_bytes())
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="zkdl-prover")
        self._worker.start()
        return self

    def submit(self, wit) -> None:
        """Queue one step witness (non-blocking; training continues)."""
        if self._worker is None:
            raise RuntimeError("service not started")
        self._queue.put(wit)

    def close(self, timeout: Optional[float] = None) -> None:
        """Drain queued FULL windows and stop the worker.  A trailing
        partial window (fewer than n_steps pending witnesses) is
        dropped — it belongs to the next service run."""
        if self._worker is None:
            return
        self._queue.put(None)
        self._worker.join(timeout)
        self._worker = None
        if self._errors:
            raise self._errors[0]

    @property
    def n_proofs(self) -> int:
        return len(self.proofs)

    # -- worker ------------------------------------------------------------

    def _run(self) -> None:
        from repro.core.pipeline import ProofSession, encode_proof

        rng = np.random.default_rng(self.rng_seed)
        session = ProofSession(self.pk, rng, label=self.label)
        try:
            while True:
                wit = self._queue.get()
                if wit is None:
                    return
                session.add_step(wit)
                if not session.is_full:
                    continue
                t0 = time.perf_counter()
                proof = session.prove()
                if self.verify and not session.verify(proof):
                    raise RuntimeError(
                        f"window {self._window}: proof REJECTED")
                dt = time.perf_counter() - t0
                data = encode_proof(proof)
                path = os.path.join(self.out_dir,
                                    f"proof_{self._window:06d}.bin")
                tmp = f"{path}.tmp"
                with open(tmp, "wb") as f:
                    f.write(data)
                os.replace(tmp, path)
                with open(os.path.join(self.out_dir, "MANIFEST.jsonl"),
                          "a") as f:
                    f.write(json.dumps({
                        "window": self._window,
                        "n_steps": proof.n_steps,
                        "bytes": len(data),
                        "prove_s": round(dt, 4),
                    }) + "\n")
                self.proofs.append((self._window, path, len(data), dt))
                self._window += 1
                session = ProofSession(self.pk, rng, label=self.label)
        except Exception as exc:          # surfaced by close()
            self._errors.append(exc)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Warm zkDL prover service (synthetic driver)")
    ap.add_argument("--widths", default="4,4,4",
                    help="layer-width table d_0..d_L")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--window", type=int, default=2,
                    help="T: steps aggregated per proof")
    ap.add_argument("--steps", type=int, default=4,
                    help="synthetic training steps to drive through")
    ap.add_argument("--q-bits", type=int, default=16)
    ap.add_argument("--r-bits", type=int, default=4)
    ap.add_argument("--out-dir", default="proofs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="verify each proof before writing it")
    ap.add_argument("--warm-only", action="store_true",
                    help="compile + warm the executable cache, then exit")
    args = ap.parse_args(argv)

    from repro.core.quantfc import (QuantConfig,
                                    synthetic_sgd_trajectory_widths)
    from repro.core.pipeline import build_fcnn_graph

    widths = tuple(int(w) for w in args.widths.split(","))
    quant = QuantConfig(q_bits=args.q_bits, r_bits=args.r_bits)
    graph = build_fcnn_graph(widths, batch=args.batch)
    service = ProverService(graph, quant, n_steps=args.window,
                            out_dir=args.out_dir, verify=args.verify,
                            rng_seed=args.seed)
    service.start(warm=True)
    print(f"[serve] warm in {service.warm_seconds:.1f}s "
          f"(exec cache: {service.warm_stats})", flush=True)
    if args.warm_only:
        service.close()
        return 0

    wits = synthetic_sgd_trajectory_widths(
        args.steps, widths, args.batch, quant, seed=args.seed)
    t0 = time.perf_counter()
    for step, wit in enumerate(wits):
        service.submit(wit)
    service.close()
    dt = time.perf_counter() - t0
    for window, path, n_bytes, secs in service.proofs:
        print(f"[serve] window {window}: {n_bytes} B -> {path} "
              f"({secs:.2f}s)", flush=True)
    print(f"[serve] {service.n_proofs} proofs for {args.steps} steps "
          f"in {dt:.1f}s total", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
