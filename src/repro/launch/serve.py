"""Crash-safe resident prover service: compile once, prove windows forever.

The prover's one-time costs (generator derivation, AOT-compiling every
executable for the graph geometry) are paid at `ProverService.start()`;
after that each training window is proved from the warm in-process
registry with zero re-tracing — and because the executables are also
serialized to the on-disk cache (`repro.core.execache`), a RESTARTED
service for the same config comes back warm too.

Durability contract (PR 8)
==========================

The service never loses a submitted witness to a crash, and never
commits a window twice.  Concretely:

Journal (write-ahead witness log)
    ``submit()`` appends the step witness to
    ``<out_dir>/journal/step_<s>.npz`` (atomic tmp+rename, the
    `train/checkpoint.atomic_write_bytes` pattern) BEFORE enqueueing it
    for the worker.  Step indices ``s`` are global and monotonic; window
    ``w`` owns steps ``[w*T, (w+1)*T)``.  A journal segment is
    garbage-collected only after its window reaches a terminal manifest
    state (``COMMITTED`` or ``DROPPED``).

Manifest (append-only commit log)
    ``<out_dir>/MANIFEST.jsonl``: one JSON line per event, fsync'd.
    Per-window status is LAST-WINS on read; a torn trailing line (crash
    mid-append) is skipped, not an error.  States:

    * ``COMMITTED`` — ``proof_<w>.bin`` is durable and verified-sized;
      written AFTER the atomic proof write, so a committed line implies
      readable proof bytes.
    * ``FAILED``    — every supervised prove attempt failed (or the
      journal for the window was corrupt/gapped); the service keeps
      going instead of wedging.
    * ``DROPPED``   — backpressure policy ``drop_window`` shed the
      window; its journal steps are GC'd and accounted in ``stats``.
    * ``PARTIAL``   — informational: close() drained with a trailing
      window short of T steps.  Its journal steps are RETAINED; a
      restarted service resumes the window (a later ``COMMITTED`` line
      supersedes it).

Restart / replay protocol
    ``start()`` on a non-empty out_dir: read the manifest, delete
    leftover ``*.tmp.*`` turds, GC journal steps of terminal windows,
    then replay the remaining journaled steps (complete un-committed
    windows and the trailing partial window) into the prove queue in
    order.  New submissions continue at
    ``next_step = max(highest journaled step + 1,
    (highest manifest window + 1) * T)``.  A proof file without a
    manifest line (crash between proof write and commit) is re-proved
    and overwritten — the manifest, not the file system, is the source
    of truth, which is what keeps "exactly one COMMITTED line per
    window" true under crashes at every fault point.

Supervised proving
    Each window proves under `launch/supervise.run_supervised`
    (``isolation="thread"``: in-process attempts, capped exponential
    backoff) or `run_subprocess_supervised` (``isolation="subprocess"``:
    each attempt is a fresh ``python -m repro.launch.serve
    --prove-window w`` child that rebuilds the ProvingKey warm from the
    executable cache, proves from the journal, atomically writes the
    proof, and hard-exits — signal deaths and timeouts retry, clean
    rejections don't).  Repeated failure marks the window ``FAILED``;
    the worker moves on.

Backpressure
    ``queue_size=0`` (default) keeps the historical unbounded queue.
    With a bound, policy ``block`` makes submit() wait (checking worker
    liveness so a dead worker raises instead of deadlocking), policy
    ``drop_window`` sheds the NEWEST window on overflow: mark
    ``DROPPED``, GC its journal, count it in
    ``stats["dropped_windows"]``, and ignore the window's remaining
    submissions.

Fault injection
    Pass a `train/resilience.FailureInjector` (or set ``ZKDL_FAULTS``
    for the CLI/subprocess workers).  Fault points: ``submit/journal-pre``,
    ``submit/journal-post``, ``prove/mid``, ``commit/pre-manifest``,
    ``worker/kill``.  The chaos tests (tests/test_serve_chaos.py) and
    the ci.sh chaos smoke drive every point and assert the contract
    above.

Layout of the output directory (created on start):

    vk.bin              the serialized VerifyingKey (a few hundred bytes)
    proof_000000.bin    aggregated proof for window 0 (v3 byte format)
    MANIFEST.jsonl      append-only commit log (see above)
    journal/            write-ahead step witnesses (empty when idle)

Training never blocks on proving (default config): `submit(wit)`
journals + enqueues a step witness and returns; the background worker
assembles full windows, proves, and streams `proof_NNNNNN.bin` files.

    service = ProverService(graph, quant, n_steps=T, out_dir="proofs/")
    service.start()                       # warm keys, replay journal
    for step in range(service.next_step, n):
        ws, wit = train_step(ws, batch)   # training thread
        service.submit(wit)               # journaled, non-blocking
    service.close()                       # drain remaining full windows

CLI (synthetic trajectory driver, doubles as the chaos smoke):

    python -m repro.launch.serve --widths 4,4,4 --batch 2 \
        --window 2 --steps 4 --out-dir /tmp/proofs \
        [--warm-only] [--inject point@N[:action],...] [--isolation ...]
"""
from __future__ import annotations

import argparse
import io
import json
import os
import queue
import sys
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.launch import supervise

MANIFEST = "MANIFEST.jsonl"
JOURNAL_DIR = "journal"

COMMITTED = "COMMITTED"
FAILED = "FAILED"
DROPPED = "DROPPED"
PARTIAL = "PARTIAL"

# StepWitness list fields and their lengths as a function of the layer
# count L (scalars x/y and the skips dict are handled separately)
_WIT_LISTS = ("w", "z", "zpp", "b", "rz", "a", "gz", "ga", "gap", "rga",
              "gw")


# ---------------------------------------------------------------------------
# Witness journal
# ---------------------------------------------------------------------------

def journal_dir(out_dir: str) -> str:
    return os.path.join(out_dir, JOURNAL_DIR)


def _step_path(jdir: str, step: int) -> str:
    return os.path.join(jdir, f"step_{step:08d}.npz")


def journal_append(jdir: str, step: int, wit) -> str:
    """Durably persist one step witness (atomic tmp+rename npz)."""
    from repro.train.checkpoint import atomic_write_bytes

    os.makedirs(jdir, exist_ok=True)
    arrays = {"x": wit.x, "y": wit.y}
    lens = {}
    for field in _WIT_LISTS:
        vals = getattr(wit, field)
        lens[field] = len(vals)
        for i, arr in enumerate(vals):
            arrays[f"{field}.{i}"] = arr
    meta = {"q_bits": wit.cfg.q_bits, "r_bits": wit.cfg.r_bits,
            "lens": lens,
            "skips": sorted((int(k), int(v)) for k, v in wit.skips.items())}
    arrays["meta"] = np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    path = _step_path(jdir, step)
    atomic_write_bytes(path, buf.getvalue())
    return path


def journal_load(jdir: str, step: int):
    """Reconstruct a StepWitness from its journal segment.  Raises on a
    missing/corrupt segment — callers decide the failure policy."""
    from repro.core.quantfc import QuantConfig, StepWitness

    with np.load(_step_path(jdir, step)) as z:
        meta = json.loads(bytes(bytearray(np.asarray(z["meta"]))).decode())
        lists = {f: [np.asarray(z[f"{f}.{i}"])
                     for i in range(meta["lens"][f])]
                 for f in _WIT_LISTS}
        return StepWitness(
            cfg=QuantConfig(q_bits=meta["q_bits"], r_bits=meta["r_bits"]),
            x=np.asarray(z["x"]), y=np.asarray(z["y"]),
            skips={int(k): int(v) for k, v in meta["skips"]},
            **lists)


def journal_steps(jdir: str) -> List[int]:
    """Sorted step indices with a committed (fully renamed) segment."""
    if not os.path.isdir(jdir):
        return []
    out = []
    for f in os.listdir(jdir):
        if f.startswith("step_") and f.endswith(".npz"):
            try:
                out.append(int(f[5:-4]))
            except ValueError:
                pass
    return sorted(out)


def journal_gc(jdir: str, lo: int, hi: int) -> None:
    """Delete journal segments for steps in [lo, hi)."""
    for s in range(lo, hi):
        try:
            os.remove(_step_path(jdir, s))
        except FileNotFoundError:
            pass


def _clean_tmp_files(out_dir: str) -> None:
    """Remove torn-write turds (``*.tmp.*``) left by a crashed writer."""
    for root in (out_dir, journal_dir(out_dir)):
        if not os.path.isdir(root):
            continue
        for f in os.listdir(root):
            if ".tmp." in f:
                try:
                    os.remove(os.path.join(root, f))
                except OSError:
                    pass


# ---------------------------------------------------------------------------
# Manifest
# ---------------------------------------------------------------------------

def read_manifest(out_dir: str) -> Dict[int, dict]:
    """Last-wins view of MANIFEST.jsonl keyed by window.  Unparseable
    (torn) lines are skipped: a crash mid-append loses at most the event
    being written, never the file."""
    path = os.path.join(out_dir, MANIFEST)
    out: Dict[int, dict] = {}
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(rec, dict) and "window" in rec:
                out[int(rec["window"])] = rec
    return out


def manifest_commit_counts(out_dir: str) -> Dict[int, int]:
    """COMMITTED lines per window — the exactly-once audit."""
    path = os.path.join(out_dir, MANIFEST)
    counts: Dict[int, int] = {}
    if not os.path.exists(path):
        return counts
    with open(path) as f:
        for line in f:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(rec, dict) and rec.get("status") == COMMITTED:
                w = int(rec["window"])
                counts[w] = counts.get(w, 0) + 1
    return counts


# ---------------------------------------------------------------------------
# Service
# ---------------------------------------------------------------------------

class ProverService:
    """Crash-safe warm resident prover for ONE (graph, quant, T) config.

    Thread model: `submit()` is called from the training thread; it
    journals the witness, then enqueues it.  The internal worker thread
    owns every ProofSession and does all proving/manifest IO (manifest
    appends share a lock with the submit path's DROPPED records).
    `stats` and `proofs` are safe to read at any time."""

    FAULT_POINTS = ("submit/journal-pre", "submit/journal-post",
                    "prove/mid", "commit/pre-manifest", "worker/kill")

    def __init__(self, graph, quant=None, n_steps: int = 1,
                 out_dir: str = "proofs", label: bytes = b"zkdl/train",
                 verify: bool = False, rng_seed: int = 0, *,
                 journal: bool = True, queue_size: int = 0,
                 backpressure: str = "block", max_attempts: int = 3,
                 backoff_base: float = 0.05, backoff_cap: float = 2.0,
                 prove_timeout: Optional[float] = None,
                 isolation: str = "thread",
                 injector=None):
        if backpressure not in ("block", "drop_window"):
            raise ValueError(f"unknown backpressure policy {backpressure!r}")
        if isolation not in ("thread", "subprocess"):
            raise ValueError(f"unknown isolation mode {isolation!r}")
        self.graph = graph
        self.quant = quant
        self.n_steps = n_steps
        self.out_dir = out_dir
        self.label = label
        self.verify = verify
        self.rng_seed = rng_seed
        self.journal = journal
        self.backpressure = backpressure
        self.max_attempts = max_attempts
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.prove_timeout = prove_timeout
        self.isolation = isolation
        self.injector = injector
        self.pk = None
        self.vk = None
        self.proofs: List[Tuple[int, str, int, float]] = []
        self.warm_stats: Optional[dict] = None
        self.warm_seconds: float = 0.0
        self.stats = {"submitted": 0, "journaled": 0, "replayed": 0,
                      "proved": 0, "failed_windows": 0, "retries": 0,
                      "dropped_windows": 0, "dropped_steps": 0,
                      "partial_steps": 0}
        self._queue: "queue.Queue" = queue.Queue(maxsize=queue_size)
        self._worker: Optional[threading.Thread] = None
        self._errors: list = []
        self._mlock = threading.Lock()
        self._manifest: Dict[int, dict] = {}
        self._dropped: set = set()
        self._next_step = 0

    # -- lifecycle ---------------------------------------------------------

    def start(self, warm: bool = True) -> "ProverService":
        """Compile keys (optionally AOT-warming every executable), write
        vk.bin, recover journal/manifest state, replay unproved windows,
        and launch the proving worker."""
        from repro.core import execache
        from repro.core.pipeline import compile as zk_compile
        from repro.train.checkpoint import atomic_write_bytes

        os.makedirs(self.out_dir, exist_ok=True)
        _clean_tmp_files(self.out_dir)
        t0 = time.perf_counter()
        self.pk, self.vk = zk_compile(self.graph, self.quant,
                                      n_steps=self.n_steps)
        if warm:
            before = execache.stats()
            self.pk.warm(seed=self.rng_seed)
            after = execache.stats()
            self.warm_stats = {k: after[k] - before[k] for k in after}
        self.warm_seconds = time.perf_counter() - t0
        atomic_write_bytes(os.path.join(self.out_dir, "vk.bin"),
                           self.vk.to_bytes())
        self._manifest = read_manifest(self.out_dir)
        self._dropped = {w for w, rec in self._manifest.items()
                         if rec.get("status") == DROPPED}
        replay = self._recover_journal() if self.journal else []
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="zkdl-prover")
        self._worker.start()
        for step, wit in replay:
            self._queue.put((step, wit))    # durable steps never drop
            self.stats["replayed"] += 1
        return self

    @property
    def next_step(self) -> int:
        """Global index the next submit() will journal under — after a
        restart this is where training should resume."""
        return self._next_step

    def submit(self, wit) -> None:
        """Journal + queue one step witness.  Non-blocking with the
        default unbounded queue; under a bound, behavior follows the
        backpressure policy.  Raises if the worker has died (its original
        error chained) — the journal retains the step for a restart."""
        if self._worker is None:
            raise RuntimeError("service not started")
        self._check_worker()
        step = self._next_step
        window = step // self.n_steps
        self.stats["submitted"] += 1
        if self.injector is not None:
            self.injector.fire("submit/journal-pre")
        if self.journal:
            journal_append(journal_dir(self.out_dir), step, wit)
            self.stats["journaled"] += 1
        if self.injector is not None:
            self.injector.fire("submit/journal-post")
        self._next_step = step + 1
        if window in self._dropped:
            self.stats["dropped_steps"] += 1
            if self.journal:
                journal_gc(journal_dir(self.out_dir), step, step + 1)
            return
        item = (step, wit)
        if self.backpressure == "drop_window" and self._queue.maxsize > 0:
            try:
                self._queue.put_nowait(item)
            except queue.Full:
                self._drop_window(window, step)
            return
        while True:
            try:
                self._queue.put(item, timeout=0.2)
                return
            except queue.Full:
                self._check_worker()

    def close(self, timeout: Optional[float] = None) -> None:
        """Drain queued FULL windows and stop the worker.  A trailing
        partial window is reported as PARTIAL in stats/manifest and its
        journal segments are retained for the next service run.  Never
        hangs on a dead worker: the sentinel is best-effort, the join is
        bounded, and the worker's original error is re-raised."""
        if self._worker is None:
            return
        while True:
            try:
                self._queue.put(None, timeout=0.2)
                break
            except queue.Full:
                if not self._worker.is_alive():
                    break               # dead worker: nothing will drain
        self._worker.join(timeout)
        if self._worker.is_alive():
            raise TimeoutError(
                f"prover worker did not drain within {timeout}s "
                f"({self._queue.qsize()} items still queued; the journal "
                f"retains every submitted step)")
        self._worker = None
        if self._errors:
            raise self._errors[0]

    @property
    def n_proofs(self) -> int:
        return len(self.proofs)

    # -- internal ----------------------------------------------------------

    def _check_worker(self) -> None:
        if self._errors:
            raise RuntimeError(
                "prover worker died; journaled steps will replay on "
                "restart") from self._errors[0]
        if self._worker is not None and not self._worker.is_alive():
            raise RuntimeError("prover worker is not running")

    def _manifest_append(self, rec: dict) -> None:
        with self._mlock:
            with open(os.path.join(self.out_dir, MANIFEST), "a") as f:
                f.write(json.dumps(rec) + "\n")
                f.flush()
                os.fsync(f.fileno())
            self._manifest[int(rec["window"])] = rec

    def _drop_window(self, window: int, step: int) -> None:
        """Backpressure shed: the window's queued-or-journaled steps are
        discarded and the window is terminally DROPPED."""
        self._dropped.add(window)
        self.stats["dropped_windows"] += 1
        self.stats["dropped_steps"] += step - window * self.n_steps + 1
        if self.journal:
            journal_gc(journal_dir(self.out_dir),
                       window * self.n_steps, step + 1)
        self._manifest_append({"window": window, "status": DROPPED,
                               "reason": "backpressure",
                               "n_steps": self.n_steps})

    def _recover_journal(self) -> List[Tuple[int, object]]:
        """Restart path: GC terminal windows' segments, load replayable
        steps, and position ``next_step``."""
        jdir = journal_dir(self.out_dir)
        steps = journal_steps(jdir)
        T = self.n_steps
        terminal = {w for w, rec in self._manifest.items()
                    if rec.get("status") in (COMMITTED, DROPPED)}
        live = []
        for s in steps:
            if s // T in terminal:
                journal_gc(jdir, s, s + 1)   # crash between commit and GC
            else:
                live.append(s)
        # a PARTIAL window is non-terminal (its steps replay below), so
        # only terminal windows push next_step past their range
        max_terminal_w = max(
            (w for w, rec in self._manifest.items()
             if rec.get("status") in (COMMITTED, DROPPED, FAILED)),
            default=-1)
        self._next_step = max([0, (max_terminal_w + 1) * T]
                              + [s + 1 for s in steps])
        by_window: Dict[int, List[int]] = {}
        for s in live:
            by_window.setdefault(s // T, []).append(s)
        replay: List[Tuple[int, object]] = []
        for w in sorted(by_window):
            ss = sorted(by_window[w])
            complete = ss == list(range(w * T, (w + 1) * T))
            tail = (w == max(by_window)
                    and ss == list(range(w * T, w * T + len(ss))))
            if not (complete or tail):
                # a gap inside a non-trailing window: unprovable
                self._manifest_append({"window": w, "status": FAILED,
                                       "error": "journal gap",
                                       "steps": ss})
                journal_gc(jdir, w * T, (w + 1) * T)
                continue
            loaded = []
            try:
                for s in ss:
                    loaded.append((s, journal_load(jdir, s)))
            except Exception as exc:
                self._manifest_append({"window": w, "status": FAILED,
                                       "error": f"journal corrupt: {exc}"})
                journal_gc(jdir, w * T, (w + 1) * T)
                continue
            replay.extend(loaded)
        # windows FAILED during this scan (gap/corrupt) are terminal too:
        # resume training after them, not inside them
        max_terminal_w = max(
            (w for w, rec in self._manifest.items()
             if rec.get("status") in (COMMITTED, DROPPED, FAILED)),
            default=-1)
        self._next_step = max(self._next_step, (max_terminal_w + 1) * T)
        return replay

    # -- worker ------------------------------------------------------------

    def _run(self) -> None:
        self._rng = np.random.default_rng(self.rng_seed)
        pending: Dict[int, Dict[int, object]] = {}
        try:
            while True:
                item = self._queue.get()
                if item is None:
                    for w in sorted(pending):
                        if w in self._dropped:
                            continue
                        k = len(pending[w])
                        self.stats["partial_steps"] += k
                        self._manifest_append(
                            {"window": w, "status": PARTIAL,
                             "n_steps": k, "of": self.n_steps})
                    return
                step, wit = item
                w = step // self.n_steps
                if w in self._dropped:
                    pending.pop(w, None)
                    continue
                pending.setdefault(w, {})[step] = wit
                if len(pending[w]) < self.n_steps:
                    continue
                wits = [pending[w][s] for s in sorted(pending[w])]
                del pending[w]
                if w in self._dropped:
                    continue
                self._prove_window(w, wits)
        except Exception as exc:          # surfaced by submit()/close()
            self._errors.append(exc)

    def _proof_path(self, window: int) -> str:
        return os.path.join(self.out_dir, f"proof_{window:06d}.bin")

    def _prove_window(self, window: int, wits) -> None:
        from repro.core.pipeline import ProofSession, encode_proof
        from repro.train.checkpoint import atomic_write_bytes

        if self.injector is not None:
            self.injector.fire("worker/kill")
        t0 = time.perf_counter()
        path = self._proof_path(window)

        if self.isolation == "subprocess":
            res = supervise.run_subprocess_supervised(
                self._child_argv(window), max_attempts=self.max_attempts,
                backoff_base=self.backoff_base, backoff_cap=self.backoff_cap,
                timeout=self.prove_timeout, retry_nonzero=True,
                capture_output=True, text=True, env=self._child_env())
            data = None
            if res.ok:
                with open(path, "rb") as f:
                    data = f.read()     # the child wrote it atomically
            error = res.last_error
            if not res.ok and res.value is not None and res.value.stderr:
                error = f"{error}: {res.value.stderr.strip()[-400:]}"
        else:
            def attempt():
                if self.injector is not None:
                    self.injector.fire("prove/mid")
                session = ProofSession(self.pk, self._rng, label=self.label)
                for wit in wits:
                    session.add_step(wit)
                proof = session.prove()
                if self.verify and not session.verify(proof):
                    raise RuntimeError(f"window {window}: proof REJECTED")
                return encode_proof(proof)

            res = supervise.run_supervised(
                attempt, max_attempts=self.max_attempts,
                backoff_base=self.backoff_base,
                backoff_cap=self.backoff_cap)
            data = res.value if res.ok else None
            error = res.last_error

        self.stats["retries"] += max(0, res.n_attempts - 1)
        if not res.ok:
            self.stats["failed_windows"] += 1
            self._manifest_append({"window": window, "status": FAILED,
                                   "error": error,
                                   "attempts": res.n_attempts})
            return
        if self.isolation != "subprocess":
            atomic_write_bytes(path, data)
        if self.injector is not None:
            self.injector.fire("commit/pre-manifest")
        dt = time.perf_counter() - t0
        batch = self.pk.keys.cfg.batch
        self._manifest_append({"window": window, "status": COMMITTED,
                               "n_steps": self.n_steps, "bytes": len(data),
                               # global sample-index range [start, count]
                               # of the window's per-sample commitments —
                               # the membership audit (repro.audit) binds
                               # these into the dataset root
                               "samples": [window * self.n_steps * batch,
                                           self.n_steps * batch],
                               "prove_s": round(dt, 4),
                               "attempts": res.n_attempts})
        if self.journal:
            journal_gc(journal_dir(self.out_dir),
                       window * self.n_steps, (window + 1) * self.n_steps)
        self.stats["proved"] += 1
        self.proofs.append((window, path, len(data), dt))

    def _child_argv(self, window: int) -> List[str]:
        argv = [sys.executable, "-m", "repro.launch.serve",
                "--prove-window", str(window), "--out-dir", self.out_dir,
                "--seed", str(self.rng_seed),
                "--label", self.label.decode()]
        if self.verify:
            argv.append("--verify")
        return argv

    def _child_env(self) -> Dict[str, str]:
        env = dict(os.environ)
        src = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        return env


# ---------------------------------------------------------------------------
# Subprocess prove worker + CLI
# ---------------------------------------------------------------------------

def _prove_window_child(args) -> int:
    """One isolated prove attempt: rebuild the ProvingKey from vk.bin
    (warm via the executable cache), load the window's witnesses from
    the journal, prove, atomically write the proof, hard-exit.  The
    PARENT commits the manifest line — this process crashing after the
    proof write therefore cannot double-commit."""
    from repro.core.pipeline import (ProofSession, compile as zk_compile,
                                     encode_proof)
    from repro.core.pipeline.proofio import decode_vk
    from repro.core.quantfc import QuantConfig
    from repro.train.checkpoint import atomic_write_bytes
    from repro.train.resilience import FailureInjector

    injector = FailureInjector.from_env()
    out = args.out_dir
    with open(os.path.join(out, "vk.bin"), "rb") as f:
        vk = decode_vk(f.read())
    cfg = vk.cfg
    pk, _ = zk_compile(cfg.graph,
                       QuantConfig(q_bits=cfg.q_bits, r_bits=cfg.r_bits),
                       n_steps=cfg.n_steps)
    w, T = args.prove_window, cfg.n_steps
    jdir = journal_dir(out)
    wits = [journal_load(jdir, s) for s in range(w * T, (w + 1) * T)]
    if injector is not None:
        injector.fire("prove/mid")
    rng = np.random.default_rng((args.seed, w))
    session = ProofSession(pk, rng, label=args.label.encode())
    for wit in wits:
        session.add_step(wit)
    proof = session.prove()
    if args.verify and not session.verify(proof):
        print(f"[serve:child] window {w}: proof REJECTED", flush=True)
        return 1
    data = encode_proof(proof)
    atomic_write_bytes(os.path.join(out, f"proof_{w:06d}.bin"), data)
    print(f"[serve:child] window {w}: {len(data)} B proved", flush=True)
    # skip interpreter/XLA teardown (known SIGABRT flake) — the proof is
    # already durable, and the parent reads only files + returncode
    supervise.hard_exit(0)
    return 0                              # unreachable


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Crash-safe warm zkDL prover service (synthetic driver)")
    ap.add_argument("--widths", default="4,4,4",
                    help="layer-width table d_0..d_L")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--window", type=int, default=2,
                    help="T: steps aggregated per proof")
    ap.add_argument("--steps", type=int, default=4,
                    help="synthetic training steps to drive through")
    ap.add_argument("--q-bits", type=int, default=16)
    ap.add_argument("--r-bits", type=int, default=4)
    ap.add_argument("--out-dir", default="proofs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--label", default="zkdl/train")
    ap.add_argument("--verify", action="store_true",
                    help="verify each proof before writing it")
    ap.add_argument("--warm-only", action="store_true",
                    help="compile + warm the executable cache, then exit")
    ap.add_argument("--queue-size", type=int, default=0,
                    help="bound the submit queue (0 = unbounded)")
    ap.add_argument("--backpressure", default="block",
                    choices=["block", "drop_window"])
    ap.add_argument("--max-attempts", type=int, default=3)
    ap.add_argument("--prove-timeout", type=float, default=None)
    ap.add_argument("--isolation", default="thread",
                    choices=["thread", "subprocess"])
    ap.add_argument("--inject", default=None,
                    help="fault spec point@N[:action],... "
                         "(ZKDL_FAULTS env works too)")
    ap.add_argument("--bind-dataset", action="store_true",
                    help="after the run, bind every COMMITTED window's "
                         "sample commitments into dataset.bin "
                         "(repro.audit membership root)")
    ap.add_argument("--prove-window", type=int, default=None,
                    help=argparse.SUPPRESS)   # internal: subprocess worker
    args = ap.parse_args(argv)

    if args.prove_window is not None:
        return _prove_window_child(args)

    from repro.core.quantfc import (QuantConfig,
                                    synthetic_sgd_trajectory_widths)
    from repro.core.pipeline import build_fcnn_graph
    from repro.train.resilience import FailureInjector

    injector = (FailureInjector.from_spec(args.inject) if args.inject
                else FailureInjector.from_env())
    widths = tuple(int(w) for w in args.widths.split(","))
    quant = QuantConfig(q_bits=args.q_bits, r_bits=args.r_bits)
    graph = build_fcnn_graph(widths, batch=args.batch)
    service = ProverService(graph, quant, n_steps=args.window,
                            out_dir=args.out_dir, verify=args.verify,
                            rng_seed=args.seed,
                            label=args.label.encode(),
                            queue_size=args.queue_size,
                            backpressure=args.backpressure,
                            max_attempts=args.max_attempts,
                            prove_timeout=args.prove_timeout,
                            isolation=args.isolation, injector=injector)
    service.start(warm=True)
    print(f"[serve] warm in {service.warm_seconds:.1f}s "
          f"(exec cache: {service.warm_stats})", flush=True)
    if args.warm_only:
        service.close()
        return 0

    wits = synthetic_sgd_trajectory_widths(
        args.steps, widths, args.batch, quant, seed=args.seed)
    start_at = min(service.next_step, len(wits))
    if start_at or service.stats["replayed"]:
        print(f"[serve] resuming at step {start_at} "
              f"({service.stats['replayed']} journaled steps replayed)",
              flush=True)
    t0 = time.perf_counter()
    for wit in wits[start_at:]:
        service.submit(wit)
    service.close()
    dt = time.perf_counter() - t0
    for window, path, n_bytes, secs in service.proofs:
        print(f"[serve] window {window}: {n_bytes} B -> {path} "
              f"({secs:.2f}s)", flush=True)
    print(f"[serve] {service.n_proofs} proofs for {args.steps} steps "
          f"in {dt:.1f}s total; stats={service.stats}", flush=True)
    if args.bind_dataset:
        from repro.audit.membership import bind_service_dir
        _, binding = bind_service_dir(args.out_dir)
        print(f"[serve] dataset root {binding.root.hex()} "
              f"({binding.n_samples} samples across "
              f"{len(binding.windows)} windows) -> "
              f"{os.path.join(args.out_dir, 'dataset.bin')}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
