"""Admission control for the multi-tenant proving gateway.

Three small, independently-testable primitives `launch/serve.py`'s
`ProvingGateway` composes (none of them import jax or any prover
module — like `launch/supervise`, the control plane must stay correct
even when the proving data plane is what's failing):

`WeightedFairQueue`
    A priority-aware, weighted-fair admission queue over named tenants.
    Dispatch order is stride scheduling: each tenant carries a virtual
    time that advances by ``1/weight`` per dispatched item, and the
    backlogged tenant with the smallest virtual time goes next — a
    tenant with weight 2 drains twice as fast as one with weight 1, and
    a flooding tenant cannot starve the rest (its virtual time runs
    ahead, so everyone else's queued work schedules first).  A tenant
    idle-then-busy re-enters at the global virtual time, not at zero —
    idleness banks no credit.

    With a ``capacity`` bound, `push` load-sheds by PRIORITY when the
    queue is full: the newest queued item of the lowest-priority
    backlogged tenant is shed to admit a higher-priority push; a push
    that is itself lowest-priority (or ties the minimum) sheds itself.
    Shed items are RETURNED to the caller, never silently dropped — the
    gateway turns them into terminal ``SHED`` manifest records.

`CircuitBreaker`
    Per-tenant trip-out: ``threshold`` consecutive prove failures open
    the breaker (the tenant degrades to journal-only — witnesses stay
    durable, proving stops burning pool capacity on a poisoned config);
    after ``reset_s`` it half-opens and admits ONE trial window.  Trial
    success closes the breaker, trial failure re-opens it for another
    ``reset_s``.  `allow()` returns one of ``"proceed" | "trial" |
    "defer"`` so the worker loop stays a flat three-way branch.

`acquire_dir_lock` / `release_dir_lock`
    An advisory owner lockfile for a service output directory.  Two
    gateways (or crash-safe services) sharing one ``out_dir`` would
    interleave journal GC, manifest appends and proof writes — each
    internally atomic, jointly corrupting (double commits, GC of the
    other's live segments).  The lock is an ``O_EXCL``-created JSON file
    recording the owner pid; a second acquire raises `GatewayBusyError`
    while the owner lives, and STEALS the lock when the recorded pid is
    dead (a SIGKILLed gateway must not brick its directory).
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import threading
import time
from typing import Deque, Dict, List, Optional, Tuple


class GatewayBusyError(RuntimeError):
    """Another live gateway owns this output directory's lockfile."""


class ServiceClosedError(RuntimeError):
    """submit() after close(): the service accepts no new work."""


# ---------------------------------------------------------------------------
# Weighted-fair admission queue
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _TenantQueue:
    weight: float
    priority: int
    q: Deque = dataclasses.field(default_factory=collections.deque)
    vtime: float = 0.0


class WeightedFairQueue:
    """Thread-safe weighted-fair queue with priority load-shedding.

    ``capacity`` bounds the TOTAL queued items across tenants (0 =
    unbounded).  `push` returns the list of ``(tenant, item)`` pairs
    shed to admit the push — possibly including the pushed item itself.
    `pop` blocks up to ``timeout`` and returns ``(tenant, item)`` or
    None (timeout, or draining with nothing left).  `drain()` wakes all
    waiters; after it, `pop` returns None once the queue is empty
    instead of blocking forever."""

    def __init__(self, capacity: int = 0):
        self.capacity = capacity
        self._cond = threading.Condition()
        self._tenants: Dict[str, _TenantQueue] = {}
        self._gvt = 0.0               # global virtual time (last dispatch)
        self._draining = False

    def add_tenant(self, name: str, weight: float = 1.0,
                   priority: int = 0) -> None:
        if weight <= 0:
            raise ValueError(f"tenant {name!r}: weight must be > 0")
        with self._cond:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            self._tenants[name] = _TenantQueue(weight=float(weight),
                                               priority=int(priority))

    # -- introspection ----------------------------------------------------
    def depth(self, name: Optional[str] = None) -> int:
        with self._cond:
            if name is not None:
                return len(self._tenants[name].q)
            return sum(len(t.q) for t in self._tenants.values())

    def empty(self) -> bool:
        return self.depth() == 0

    # -- producer side ----------------------------------------------------
    def push(self, name: str, item,
             force: bool = False) -> List[Tuple[str, object]]:
        """``force=True`` bypasses the capacity bound (recovery replay:
        already-durable windows are admitted, never shed)."""
        with self._cond:
            if self._draining:
                raise ServiceClosedError(
                    "admission queue is draining; no new work accepted")
            t = self._tenants[name]
            shed: List[Tuple[str, object]] = []
            total = sum(len(q.q) for q in self._tenants.values())
            if self.capacity and not force and total >= self.capacity:
                # lowest-priority backlogged tenant gives up its newest
                # item; ties (or a push that IS the minimum) shed the
                # push itself — equals never preempt equals
                backlogged = [(n, q) for n, q in self._tenants.items()
                              if q.q]
                victim_name, victim = min(
                    backlogged, key=lambda nq: (nq[1].priority, nq[0]))
                if victim.priority < t.priority:
                    shed.append((victim_name, victim.q.pop()))
                else:
                    shed.append((name, item))
                    return shed
            if not t.q:               # idle -> busy: no banked credit
                t.vtime = max(t.vtime, self._gvt)
            t.q.append(item)
            self._cond.notify()
            return shed

    # -- consumer side ----------------------------------------------------
    def pop(self, timeout: Optional[float] = None
            ) -> Optional[Tuple[str, object]]:
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        with self._cond:
            while True:
                backlogged = [(n, t) for n, t in self._tenants.items()
                              if t.q]
                if backlogged:
                    name, t = min(backlogged,
                                  key=lambda nt: (nt[1].vtime, nt[0]))
                    item = t.q.popleft()
                    self._gvt = t.vtime
                    t.vtime += 1.0 / t.weight
                    return name, item
                if self._draining:
                    return None
                if deadline is None:
                    self._cond.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                    self._cond.wait(remaining)

    def requeue(self, name: str, item) -> None:
        """Put an in-flight item back at the FRONT of its tenant's queue
        (a reclaimed worker's job must not lose its turn)."""
        with self._cond:
            self._tenants[name].q.appendleft(item)
            self._cond.notify()

    def drain(self) -> None:
        with self._cond:
            self._draining = True
            self._cond.notify_all()


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"


class CircuitBreaker:
    """Consecutive-failure trip-out with timed half-open recovery."""

    def __init__(self, threshold: int = 3, reset_s: float = 30.0,
                 clock=time.monotonic):
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.threshold = threshold
        self.reset_s = reset_s
        self._clock = clock
        self._lock = threading.Lock()
        self._state = CLOSED
        self._failures = 0
        self._open_until = 0.0
        self._trial_inflight = False
        self.trips = 0

    @property
    def state(self) -> str:
        with self._lock:
            if (self._state == OPEN and not self._trial_inflight
                    and self._clock() >= self._open_until):
                return HALF_OPEN        # would half-open on next allow()
            return self._state

    @property
    def ready_for_trial(self) -> bool:
        """True exactly when the next `allow()` would return ``"trial"``
        — the unpark pump uses this to release ONE probe window without
        flooding the queue while a trial is already in flight."""
        with self._lock:
            return (self._state != CLOSED and not self._trial_inflight
                    and self._clock() >= self._open_until)

    def allow(self) -> str:
        """``"proceed"`` (closed), ``"trial"`` (half-open: caller runs
        ONE probe and MUST report its outcome), or ``"defer"`` (open, or
        a trial is already in flight)."""
        with self._lock:
            if self._state == CLOSED:
                return "proceed"
            if self._trial_inflight:
                return "defer"
            if self._clock() >= self._open_until:
                self._state = HALF_OPEN
                self._trial_inflight = True
                return "trial"
            return "defer"

    def record_success(self) -> None:
        with self._lock:
            self._state = CLOSED
            self._failures = 0
            self._trial_inflight = False

    def record_failure(self) -> bool:
        """Returns True when THIS failure tripped the breaker open."""
        with self._lock:
            self._failures += 1
            tripped = False
            if self._state == HALF_OPEN or self._failures >= self.threshold:
                tripped = self._state != OPEN
                self._state = OPEN
                self._open_until = self._clock() + self.reset_s
                if tripped:
                    self.trips += 1
            self._trial_inflight = False
            return tripped


# ---------------------------------------------------------------------------
# Advisory directory lock
# ---------------------------------------------------------------------------

LOCKFILE = "GATEWAY.lock"

# directories locked by THIS process (two gateways in one process would
# corrupt a directory exactly like two processes — the pid in the
# lockfile cannot tell them apart, so acquire also checks here)
_held_dirs: set = set()
_held_mutex = threading.Lock()


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


def acquire_dir_lock(out_dir: str, injector=None) -> str:
    """Take the advisory owner lock on ``out_dir``.  Raises
    `GatewayBusyError` while another LIVE process holds it; a lock whose
    recorded pid is dead is stale (SIGKILLed owner) and is stolen.
    Returns the lock path for `release_dir_lock`."""
    os.makedirs(out_dir, exist_ok=True)
    if injector is not None:
        injector.fire("lock/acquire")
    path = os.path.join(out_dir, LOCKFILE)
    real = os.path.realpath(out_dir)
    with _held_mutex:
        if real in _held_dirs:
            raise GatewayBusyError(
                f"{out_dir!r} is already owned by a live gateway in THIS "
                f"process (lockfile {path})")
    for _ in range(3):                # steal-then-race needs one retry
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            try:
                with open(path) as f:
                    owner = json.load(f)
            except (OSError, json.JSONDecodeError):
                owner = {}
            pid = owner.get("pid")
            if pid is not None and int(pid) != os.getpid() \
                    and _pid_alive(int(pid)):
                raise GatewayBusyError(
                    f"{out_dir!r} is owned by live gateway pid {pid} "
                    f"(lockfile {path}); refusing to run two gateways "
                    f"against one output directory")
            # stale (dead or unreadable owner) or our own leftover: steal
            try:
                os.remove(path)
            except FileNotFoundError:
                pass
            continue
        with os.fdopen(fd, "w") as f:
            json.dump({"pid": os.getpid(), "t": time.time()}, f)
            f.flush()
            os.fsync(f.fileno())
        with _held_mutex:
            _held_dirs.add(real)
        return path
    raise GatewayBusyError(
        f"could not acquire {path}: lost the steal race repeatedly")


def release_dir_lock(path: str) -> None:
    """Release an advisory lock THIS process owns (no-op otherwise)."""
    with _held_mutex:
        _held_dirs.discard(os.path.realpath(os.path.dirname(path)))
    try:
        with open(path) as f:
            owner = json.load(f)
    except (OSError, json.JSONDecodeError):
        return
    if owner.get("pid") == os.getpid():
        try:
            os.remove(path)
        except FileNotFoundError:
            pass
