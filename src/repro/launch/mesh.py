"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module touches no jax device state -- required because the dry-run forces
512 host devices while smoke tests must see exactly one.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (2,16,16) = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)}; "
            "run under launch/dryrun.py (which forces host devices) or on "
            "real hardware")
    import numpy as np
    dev_array = np.asarray(devices[:need]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Single-device mesh for tests."""
    import numpy as np
    return jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(shape), axes)


def batch_axes(mesh) -> tuple:
    """The mesh axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
