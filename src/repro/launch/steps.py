"""pjit-able train / prefill / decode steps with full sharding annotations.

``lower_cell`` is the single entry point the dry-run, roofline, and real
launchers share: given (config, mesh, shape-name) it returns the lowered
computation for that cell.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import hints
from repro.distributed import sharding as shard_rules
from repro.launch import specs as specs_mod
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.train import optim


def build_proof_pipeline_config(model_cfg, batch: int, n_steps: int,
                                q_bits: int = 16, r_bits: int = 8,
                                widths=None):
    """ArchConfig -> graph-first `PipelineConfig`, gated by the
    proof-graph registry.

    Families without a registered layer-graph builder raise a clear
    LookupError instead of silently training unproven; ``widths``
    overrides the uniform d_0..d_L table derived from the model config
    (heterogeneous pyramids, reduced runs).  The registered graph is the
    config's single source of truth (`PipelineConfig.from_graph`);
    callers wanting the full setup artifacts should pass the same graph
    to `repro.core.pipeline.compile`."""
    from repro.core.pipeline import PipelineConfig
    from repro.core.pipeline.graph import proof_graph_for_family

    if widths is None:
        widths = (model_cfg.d_model,) * (model_cfg.n_layers + 1)
    widths = tuple(int(w) for w in widths)
    # registry gate: raises LookupError for unprovable families
    graph = proof_graph_for_family(model_cfg.family, widths=widths,
                                   batch=batch)
    return PipelineConfig.from_graph(graph, q_bits=q_bits, r_bits=r_bits,
                                     n_steps=n_steps)


def build_zkdl_step(zk_cfg, lr_shift: int = 8):
    """Train step for a provable integer-SGD family: exact integer SGD
    whose per-batch witness feeds the proof pipeline (any layer-graph
    shape table, uniform or pyramid).

    Returns ``step(ws, batch) -> (new_ws, StepWitness)`` with batch a
    dict of int64 arrays {"x": (B, d_0), "y": (B, d_L)} at scale 2^R."""
    from repro.core import quantfc

    qc = quantfc.QuantConfig(q_bits=zk_cfg.q_bits, r_bits=zk_cfg.r_bits)

    def step(ws, batch):
        wit = quantfc.train_step_witness(batch["x"], batch["y"], ws, qc)
        return quantfc.sgd_apply(ws, wit.gw, lr_shift, qc), wit

    return step


class ZkdlProveHook:
    """Prove-while-train: observe each step's witness; every
    ``keys.cfg.n_steps`` steps one aggregated proof covering the whole
    window is emitted (and optionally verified) via `ProofSession`.

    The trainer never blocks on a per-step proof: proofs are per-window,
    which is the FAC4DNN cross-step amortization."""

    def __init__(self, keys, rng, verify: bool = True, on_proof=None,
                 label: bytes = b"zkdl/train"):
        from repro.core.pipeline import ProofSession

        self._mk = lambda: ProofSession(keys, rng, label=label)
        self._session = self._mk()
        self.keys = keys
        self.verify = verify
        self.on_proof = on_proof
        self.proofs = []           # (last_step, proof, prove_seconds)

    @property
    def n_pending(self) -> int:
        return self._session.n_pending

    def observe(self, step: int, wit) -> None:
        import time

        self._session.add_step(wit)
        if not self._session.is_full:
            return
        t0 = time.perf_counter()
        proof = self._session.prove()
        dt = time.perf_counter() - t0
        if self.verify:
            ok = self._session.verify(proof)
            if not ok:
                raise RuntimeError(f"aggregated proof REJECTED at step {step}")
        self.proofs.append((step, proof, dt))
        if self.on_proof is not None:
            self.on_proof(step, proof, dt)
        self._session = self._mk()


def build_train_step(cfg: ModelConfig, opt_cfg: optim.AdamWConfig):
    def train_step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: transformer.loss_fn(cfg, p, batch))(state["params"])
        new_params, new_opt, metrics = optim.apply_updates(
            opt_cfg, state["params"], grads, state["opt"])
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics
    return train_step


def build_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, caches = transformer.forward(cfg, params, batch,
                                             collect_cache=True,
                                             head_last_only=True)
        return logits[:, -1], caches
    return prefill_step


def build_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, token, pos, positions3=None):
        return transformer.decode_step(cfg, params, cache, token, pos,
                                       positions3=positions3)
    return decode_step


def default_opt_cfg(cfg: ModelConfig) -> optim.AdamWConfig:
    """bf16 Adam moments for >=100B-param models (fits 16 GB/chip)."""
    big = cfg.param_count() > 100e9
    return optim.AdamWConfig(state_dtype="bfloat16" if big else "float32")


def state_specs(cfg: ModelConfig, opt_cfg: optim.AdamWConfig):
    p = specs_mod.param_specs(cfg)
    opt = jax.eval_shape(functools.partial(optim.init_opt_state,
                                           cfg=opt_cfg), p)
    return {"params": p, "opt": opt}


def state_shardings(cfg: ModelConfig, mesh, state_tree):
    p_sh = shard_rules.param_shardings(cfg, mesh, state_tree["params"])
    mu_sh = shard_rules.param_shardings(cfg, mesh, state_tree["opt"]["mu"])
    nu_sh = shard_rules.param_shardings(cfg, mesh, state_tree["opt"]["nu"])
    return {"params": p_sh,
            "opt": {"mu": mu_sh, "nu": nu_sh,
                    "step": shard_rules.replicated(mesh)}}


def lower_cell(cfg: ModelConfig, mesh, shape_name: str,
               opt_cfg: optim.AdamWConfig | None = None):
    """Lower the computation for one (arch x shape x mesh) cell.

    Returns the jax.stages.Lowered object (call .compile() on it)."""
    if opt_cfg is None:
        opt_cfg = default_opt_cfg(cfg)
    spec = specs_mod.input_specs(cfg, shape_name)
    repl = shard_rules.replicated(mesh)
    from repro.launch.mesh import batch_axes
    bax = batch_axes(mesh)
    sizes = {"batch": 1, "model": mesh.shape.get("model", 1)}
    for a in bax:
        sizes["batch"] *= mesh.shape[a]
    hints.set_axes(bax, "model" if "model" in mesh.axis_names else None,
                   sizes, mesh=mesh)

    if spec["kind"] == "train":
        st_spec = state_specs(cfg, opt_cfg)
        st_shard = state_shardings(cfg, mesh, st_spec)
        b_shard = shard_rules.batch_shardings(cfg, mesh, spec["batch"])
        step = build_train_step(cfg, opt_cfg)
        jitted = jax.jit(step,
                         in_shardings=(st_shard, b_shard),
                         out_shardings=(st_shard, None),
                         donate_argnums=(0,))
        with mesh:
            return jitted.lower(st_spec, spec["batch"])

    if spec["kind"] == "prefill":
        p_spec = specs_mod.param_specs(cfg)
        p_shard = shard_rules.param_shardings(cfg, mesh, p_spec)
        b_shard = shard_rules.batch_shardings(cfg, mesh, spec["batch"])
        step = build_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                         out_shardings=None)
        with mesh:
            return jitted.lower(p_spec, spec["batch"])

    # decode
    p_spec = specs_mod.param_specs(cfg)
    p_shard = shard_rules.param_shardings(cfg, mesh, p_spec)
    c_shard = shard_rules.cache_shardings(cfg, mesh, spec["cache"])
    step = build_decode_step(cfg)
    if cfg.family == "vlm":
        tok_shard = shard_rules.batch_shardings(
            cfg, mesh, {"embeds": spec["token"]})["embeds"]
        pos3_shard = shard_rules.batch_shardings(
            cfg, mesh, {"positions3": spec["positions3"]})["positions3"]
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, c_shard, tok_shard, repl, pos3_shard),
            out_shardings=(None, c_shard), donate_argnums=(1,))
        with mesh:
            return jitted.lower(p_spec, spec["cache"], spec["token"],
                                spec["pos"], spec["positions3"])
    tok_shard = shard_rules.batch_shardings(
        cfg, mesh, {"tokens": spec["token"]})["tokens"]
    jitted = jax.jit(step,
                     in_shardings=(p_shard, c_shard, tok_shard, repl),
                     out_shardings=(None, c_shard), donate_argnums=(1,))
    with mesh:
        return jitted.lower(p_spec, spec["cache"], spec["token"], spec["pos"])
