import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and extract memory / cost / collective
figures for the roofline analysis.

This module MUST be the process entry point (python -m repro.launch.dryrun)
so the device-count flag above lands before jax initializes. Nothing else
in the repo sets this flag -- smoke tests and benchmarks see 1 device.

Usage:
    python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results/]
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.util import enable_compilation_cache

# TPU v5e constants (targets; the host CPU only compiles, never runs)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
             "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
             "s8": 1, "u8": 1, "pred": 1}
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")


def _line_collective(line: str):
    """(kind, bytes) if the line is a collective op, else None."""
    stripped = line.lstrip()
    m = re.search(r"=\s*(.+?)\s+(%?[a-z0-9\-]+)\(", stripped)
    if not m:
        return None
    op = m.group(2).lstrip("%")
    kind = next((k for k in _COLLECTIVES if op == k or
                 op.startswith(k + ".") or op.rstrip("0123456789.") == k),
                None)
    if kind is None:
        return None
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(m.group(1)):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * _DT_BYTES[dt]
    return kind, nbytes


def collective_bytes(hlo_text: str):
    """Sum result-operand bytes of every collective op (per-device module).

    Returns (total_bytes, per_op_kind dict).  UNSCALED: a collective inside
    a scanned layer stack (while loop) is counted once."""
    per_kind = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        hit = _line_collective(line)
        if hit:
            per_kind[hit[0]] += hit[1]
    return sum(per_kind.values()), per_kind


_COMP_HEAD = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(")
_WHILE_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count\"?:?\{\"n\":\"(\d+)\"")


def collective_bytes_scaled(hlo_text: str):
    """Trip-count-aware collective totals.

    A jax.lax.scan over L layers compiles to ONE while body, so its
    collectives appear once in the module text but execute L times.  This
    parser splits the module into computations, sums collective operand
    bytes per computation, and multiplies by the product of enclosing
    while-loop ``known_trip_count``s (propagated from ENTRY through
    arbitrarily nested whiles, e.g. remat-of-scan).

    Returns (total_bytes, per_kind dict)."""
    comps: dict[str, list[str]] = {}
    entry = None
    current = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            m = _COMP_HEAD.match(line)
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
                continue
        if current is not None:
            comps[current].append(line)

    per_comp_kind: dict[str, dict] = {}
    edges: dict[str, list] = {}
    for name, lines in comps.items():
        kinds = {}
        edge = []
        for ln in lines:
            hit = _line_collective(ln)
            if hit:
                kinds[hit[0]] = kinds.get(hit[0], 0) + hit[1]
            if "while(" in ln and "body=" in ln:
                bm = _WHILE_BODY_RE.search(ln)
                tm = _TRIP_RE.search(ln)
                if bm:
                    edge.append((bm.group(1),
                                 int(tm.group(1)) if tm else 1))
        per_comp_kind[name] = kinds
        edges[name] = edge

    mult = {name: 0 for name in comps}
    if entry is None and comps:
        entry = next(iter(comps))
    mult[entry] = 1
    # propagate multipliers through the while DAG (worklist)
    work = [entry]
    while work:
        parent = work.pop()
        for body, trip in edges.get(parent, ()):
            if body in mult:
                before = mult[body]
                mult[body] += mult[parent] * trip
                if mult[body] != before:
                    work.append(body)
    per_kind = {k: 0 for k in _COLLECTIVES}
    for name, kinds in per_comp_kind.items():
        if not kinds:
            continue
        m = mult.get(name, 0) or 1     # unreachable-with-collectives: 1x
        for k, v in kinds.items():
            per_kind[k] += m * v
    return sum(per_kind.values()), per_kind


def analyze_compiled(lowered, compiled, n_chips: int):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll_raw, per_kind_raw = collective_bytes(hlo)
    coll, per_kind = collective_bytes_scaled(hlo)
    mem = compiled.memory_analysis()
    memory = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        memory[attr] = int(getattr(mem, attr, 0) or 0)
    live = (memory["argument_size_in_bytes"] + memory["temp_size_in_bytes"]
            + memory["output_size_in_bytes"]
            - memory.get("alias_size_in_bytes", 0))
    return {
        "per_device_flops": flops,
        "per_device_bytes": bytes_acc,
        "per_device_collective_bytes": coll,
        "per_device_collective_bytes_unscaled": coll_raw,
        "collective_breakdown": per_kind,
        "collective_breakdown_unscaled": per_kind_raw,
        "memory": memory,
        "per_device_live_bytes": live,
        "compute_term_s": flops / PEAK_FLOPS,
        "memory_term_s": bytes_acc / HBM_BW,
        "collective_term_s": coll / ICI_BW,
        "n_chips": n_chips,
    }


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str | None):
    from repro.configs.registry import get_config
    from repro.launch import specs as specs_mod
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell

    cfg = get_config(arch)
    ok, why = specs_mod.applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        print(f"[dryrun] {arch} x {shape}: SKIP ({why})")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, mesh, shape)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        rec.update(analyze_compiled(lowered, compiled, n_chips))
        rec["status"] = "ok"
        rec["lower_s"] = round(t_lower, 1)
        rec["compile_s"] = round(t_compile, 1)
        print(f"[dryrun] {arch} x {shape} ({rec['mesh']}): OK  "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s  "
              f"flops/dev {rec['per_device_flops']:.3e}  "
              f"bytes/dev {rec['per_device_bytes']:.3e}  "
              f"coll/dev {rec['per_device_collective_bytes']:.3e}  "
              f"live/dev {rec['per_device_live_bytes']/2**30:.2f} GiB")
        mem = compiled.memory_analysis()
        print("  memory_analysis:", {k: rec["memory"][k]
                                     for k in rec["memory"]})
        ca = compiled.cost_analysis()
        print("  cost_analysis keys: flops=%.3e bytes=%.3e"
              % (rec["per_device_flops"], rec["per_device_bytes"]))
    except Exception as exc:            # noqa: BLE001 -- report, keep going
        rec["status"] = "error"
        rec["error"] = f"{type(exc).__name__}: {exc}"
        traceback.print_exc()
        print(f"[dryrun] {arch} x {shape}: FAILED {rec['error'][:200]}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape}__{rec['mesh'].replace('x','_')}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1, default=float)
    return rec


def main():
    enable_compilation_cache()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    from repro.configs.registry import ARCHS
    from repro.launch.specs import SHAPE_GRID

    lm_archs = [a for a in ARCHS if a != "fcnn_zkdl_16l"]
    cells = []
    if args.all:
        for a in lm_archs:
            for s in SHAPE_GRID:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    results = []
    for mp in meshes:
        for arch, shape in cells:
            results.append(run_cell(arch, shape, mp, args.out))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} failed")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
