"""Production training driver.

    python -m repro.launch.train --arch qwen3-0.6b --steps 100 \
        --seq 512 --global-batch 8 --mesh 1x1 \
        --ckpt-dir /tmp/run0 --ckpt-every 20 \
        --compress int8 [--fail-at 37] [--resume]

One entry point for the debug mesh (CPU), the single-pod 16x16 and the
multi-pod 2x16x16 production meshes (--mesh accepts "DxM" or "PxDxM").
Fault tolerance: periodic checkpoints, restart-from-latest (elastic: the
restore re-places leaves under whatever mesh the job came back with),
straggler monitoring, and optional injected failures to drill the path.
Distributed-optimization: gradient compression (int8 + error feedback or
top-k) before the optimizer; bf16 Adam moments for >=100B models.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import numpy as np


def parse_mesh(spec: str):
    import jax

    dims = tuple(int(x) for x in spec.lower().split("x"))
    if len(dims) == 2:
        return jax.make_mesh(dims, ("data", "model"))
    if len(dims) == 3:
        return jax.make_mesh(dims, ("pod", "data", "model"))
    raise SystemExit(f"bad --mesh {spec!r} (want DxM or PxDxM)")


def build_state(cfg, opt_cfg, mesh, rng_seed: int = 0):
    import jax
    import jax.numpy as jnp
    from repro.distributed import sharding as shard_rules
    from repro.launch import steps as steps_mod
    from repro.models import transformer
    from repro.train import optim

    st_spec = steps_mod.state_specs(cfg, opt_cfg)
    st_shard = steps_mod.state_shardings(cfg, mesh, st_spec)

    @functools.partial(jax.jit, out_shardings=st_shard)
    def init(key):
        params = transformer.init_params(cfg, key)
        return {"params": params,
                "opt": optim.init_opt_state(params, opt_cfg)}

    with mesh:
        state = init(jax.random.PRNGKey(rng_seed))
    return state, st_spec, st_shard


def run_zkdl_train(cfg, args) -> int:
    """Prove-while-train for provable integer-SGD families: one
    aggregated proof per --prove-window steps, over the family's layer
    graph (uniform or a heterogeneous pyramid via --widths).

        python -m repro.launch.train --arch fcnn-zkdl-16l \
            --layers 2 --d-model 8 --global-batch 4 --steps 8 \
            --prove-window 4 [--widths 16,8,4,2] [--no-verify]

    Without overrides this runs the paper-scale 16x4096 network -- the
    same code path, just slow on a CPU substrate.

    With ``--proof-dir`` the resident warm prover service
    (`repro.launch.serve.ProverService`) takes over: setup AOT-compiles
    every prover executable (so the first window proves at steady-state
    speed), training never blocks on proving, and each window's proof
    streams to ``proof_NNNNNN.bin`` beside a serialized ``vk.bin``."""
    import numpy as np
    from repro.core import quantfc
    from repro.core.pipeline import compile as zk_compile
    from repro.launch import steps as steps_mod

    if args.widths:
        widths = tuple(int(w) for w in args.widths.split(","))
    else:
        layers = args.layers or cfg.n_layers
        width = args.d_model or cfg.d_model
        widths = (width,) * (layers + 1)
    window = max(1, args.prove_window)
    zk_cfg = steps_mod.build_proof_pipeline_config(
        cfg, batch=args.global_batch, n_steps=window, widths=widths)
    qc = quantfc.QuantConfig(q_bits=zk_cfg.q_bits, r_bits=zk_cfg.r_bits)
    shape = ("x".join(str(w) for w in widths) if len(set(widths)) > 1
             else f"{zk_cfg.n_layers} layers x {widths[0]} wide")
    print(f"[train] zkdl {cfg.family}: {shape}, "
          f"batch {args.global_batch}, aggregating {window} step(s)/proof",
          flush=True)

    service = None
    if args.proof_dir:
        from repro.launch.serve import ProverService
        service = ProverService(zk_cfg.graph, qc, n_steps=zk_cfg.n_steps,
                                out_dir=args.proof_dir,
                                verify=not args.no_verify)
        service.start(warm=True)
        pk, vk = service.pk, service.vk
        print(f"[train] prover service warm in {service.warm_seconds:.1f}s "
              f"(exec cache: {service.warm_stats}); streaming proofs to "
              f"{args.proof_dir}", flush=True)
    else:
        # one-time setup over the registered graph: the pk drives every
        # window's session; the vk alone (serializable, a few hundred
        # bytes) is what a remote verifier would hold
        pk, vk = zk_compile(zk_cfg.graph, qc, n_steps=zk_cfg.n_steps)
    rng = np.random.default_rng(0)
    ws = [quantfc.quantize(
        rng.uniform(-1, 1, (widths[l], widths[l + 1])) * 0.3, qc)
        for l in range(zk_cfg.n_layers)]
    data_x = rng.uniform(-1, 1, (args.global_batch * 8, widths[0]))
    data_y = rng.uniform(-1, 1, (args.global_batch * 8, widths[-1]))

    def on_proof(step, proof, dt):
        print(f"[train] step {step}: aggregated proof over "
              f"{proof.n_steps} steps, {proof.size_bytes() / 1024:.1f} kB "
              f"in {dt:.1f}s ({dt / proof.n_steps:.1f}s/step, "
              f"verified={not args.no_verify})", flush=True)

    hook = None
    if service is None:
        hook = steps_mod.ZkdlProveHook(pk, rng, verify=not args.no_verify,
                                       on_proof=on_proof)
    step_fn = steps_mod.build_zkdl_step(zk_cfg)
    for step in range(args.steps):
        lo = (step * args.global_batch) % data_x.shape[0]
        batch = {
            "x": quantfc.quantize(data_x[lo:lo + args.global_batch], qc),
            "y": quantfc.quantize(data_y[lo:lo + args.global_batch], qc),
        }
        t0 = time.perf_counter()
        ws, wit = step_fn(ws, batch)
        step_s = time.perf_counter() - t0          # training only; proving
        if service is not None:
            service.submit(wit)                    # non-blocking
        else:
            hook.observe(step, wit)                # logged per window
        if step % args.log_every == 0:
            print(f"[train] step {step} {step_s:.2f}s", flush=True)
    if service is not None:
        service.close()
        for window, path, n_bytes, secs in service.proofs:
            print(f"[train] window {window}: {n_bytes} B -> {path} "
                  f"({secs:.2f}s, verified={not args.no_verify})",
                  flush=True)
        n_proofs, pending = service.n_proofs, args.steps % window
    else:
        n_proofs, pending = len(hook.proofs), hook.n_pending
    print(f"[train] done: {args.steps} steps, {n_proofs} "
          f"aggregated proofs, {pending} step(s) pending "
          f"(next window)", flush=True)
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--layers", type=int, default=0,
                    help="override n_layers (reduced runs)")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (drills restart)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--prove", action="store_true",
                    help="require prove-while-train (families without a "
                         "registered proof graph fail loudly)")
    ap.add_argument("--prove-window", type=int, default=4,
                    help="provable families: steps per aggregated proof")
    ap.add_argument("--widths", default=None,
                    help="provable families: heterogeneous shape table "
                         "d_0..d_L, e.g. 784,512,256,128,10")
    ap.add_argument("--no-verify", action="store_true",
                    help="provable families: skip verifying emitted proofs")
    ap.add_argument("--proof-dir", default=None,
                    help="provable families: run the resident warm prover "
                         "service and stream proof_NNNNNN.bin + vk.bin "
                         "into this directory (training never blocks)")
    args = ap.parse_args(argv)

    from repro.util import enable_compilation_cache
    enable_compilation_cache()
    from repro.configs.registry import get_config
    from repro.core.pipeline.graph import PROOF_GRAPH_BUILDERS
    arch_cfg = get_config(args.arch)
    if arch_cfg.family in PROOF_GRAPH_BUILDERS:
        return run_zkdl_train(arch_cfg, args)
    if args.prove:
        # one registry lookup; raises "no proof graph registered for
        # family ..." with the list of provable families
        from repro.core.pipeline.graph import proof_graph_for_family
        try:
            proof_graph_for_family(arch_cfg.family)
        except LookupError as exc:
            raise SystemExit(f"--prove: {exc}") from None
    import jax
    from repro.data import pipeline
    from repro.distributed import hints
    from repro.distributed import sharding as shard_rules
    from repro.launch import steps as steps_mod
    from repro.launch.mesh import batch_axes
    from repro.launch.specs import train_batch_specs
    from repro.train import compression, optim, resilience

    cfg = get_config(args.arch)
    overrides = {}
    if args.layers:
        overrides["n_layers"] = args.layers
        if cfg.family == "encdec":
            overrides.update(enc_layers=args.layers, dec_layers=args.layers)
    if args.d_model:
        overrides["d_model"] = args.d_model
        overrides["head_dim"] = 0
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    mesh = parse_mesh(args.mesh)
    opt_cfg = steps_mod.default_opt_cfg(cfg)
    comp_cfg = compression.CompressionConfig(mode=args.compress)

    bax = batch_axes(mesh)
    sizes = {"batch": 1, "model": mesh.shape.get("model", 1)}
    for a in bax:
        sizes["batch"] *= mesh.shape[a]
    hints.set_axes(bax, "model" if "model" in mesh.axis_names else None,
                   sizes, mesh=mesh)

    # --- data + step -------------------------------------------------------
    source = pipeline.make_source(cfg, args.seq, args.global_batch)
    base_step = steps_mod.build_train_step(cfg, opt_cfg)

    def train_step(state, batch):
        import jax as _jax
        from repro.models import transformer as _t

        def loss_grads(p):
            return _t.loss_fn(cfg, p, batch)

        loss, grads = _jax.value_and_grad(loss_grads)(state["params"])
        grads, new_res = compression.compress_grads(
            comp_cfg, grads, state["residual"])
        new_params, new_opt, metrics = optim.apply_updates(
            opt_cfg, state["params"], grads, state["opt"])
        metrics["loss"] = loss
        return ({"params": new_params, "opt": new_opt,
                 "residual": new_res}, metrics)

    state, st_spec, st_shard = build_state(cfg, opt_cfg, mesh)
    state["residual"] = compression.init_residuals(state["params"]) \
        if comp_cfg.mode != "none" else {}
    res_shard = jax.tree.map(lambda _: shard_rules.replicated(mesh),
                             state["residual"])
    if comp_cfg.mode != "none":
        res_shard = shard_rules.param_shardings(cfg, mesh, state["residual"])
    full_shard = dict(st_shard, residual=res_shard)
    b_shard = shard_rules.batch_shardings(
        cfg, mesh, train_batch_specs(cfg, args.seq, args.global_batch))
    jitted = jax.jit(train_step, in_shardings=(full_shard, b_shard),
                     out_shardings=(full_shard, None), donate_argnums=(0,))

    policy = (resilience.CheckpointPolicy(args.ckpt_dir, args.ckpt_every)
              if args.ckpt_dir else None)
    injector = resilience.FailureInjector(args.fail_at)
    monitor = resilience.StragglerMonitor()

    def loop(st, start):
        nonlocal state
        if st is not None:
            state = st
        step = start
        while step < args.steps:
            t0 = time.perf_counter()
            injector.check(step)
            batch = source.batch(step)
            with mesh:
                state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            monitor.observe(step, dt, lambda s, d: print(
                f"[train] straggler at step {s}: {d:.2f}s", flush=True))
            if step % args.log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} {dt:.2f}s",
                      flush=True)
            if policy:
                policy.maybe_save(step, state)
            step += 1
        return state

    if policy:
        template = dict(st_spec, residual=state["residual"])
        state = resilience.run_resilient(loop, template, policy,
                                         shardings=full_shard)
    else:
        state = loop(None, 0)
    print(f"[train] done: {args.steps} steps, "
          f"straggler events: {len(monitor.events)}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
