"""Supervised execution: retry/backoff for functions and subprocesses.

This is the library form of two patterns the repo grew organically:

* the PR 5 elastic-restart template — run risky native-adjacent work in
  its own subprocess, flush the success marker, and ``os._exit`` past
  interpreter teardown (the known XLA-CPU heap-corruption flake fires at
  process teardown, AFTER the work succeeded);
* conftest's ``run_flaky_subprocess`` — retry subprocesses that die on a
  SIGNAL (negative returncode) while never retrying clean failures.

Both are generalized here with capped exponential backoff and a
structured attempt log, so production components (the crash-safe
`launch.serve.ProverService`) and tests share one supervisor:

    res = run_supervised(prove_once, max_attempts=3)
    if not res.ok:
        mark_failed(res.attempts[-1].error)

    res = run_subprocess_supervised(argv, timeout=120.0,
                                    retry_nonzero=True, ...)
    # signal deaths and timeouts retry; res.value is the final
    # CompletedProcess either way

Nothing here imports jax: the supervisor must stay importable (and
correct) even when the supervised work is what crashes the runtime.
"""
from __future__ import annotations

import dataclasses
import subprocess
import sys
import time
from typing import Any, Callable, List, Optional, Sequence


@dataclasses.dataclass
class Attempt:
    """One supervised try: what happened and how long it took."""
    index: int
    seconds: float
    error: Optional[str] = None     # None = success
    signal: Optional[int] = None    # set when a subprocess died on a signal
    timed_out: bool = False


@dataclasses.dataclass
class SuperviseResult:
    """Outcome of a supervised run.  ``value`` is the wrapped function's
    return value (in-process) or the final `CompletedProcess`
    (subprocess); ``error`` keeps the last exception object so callers
    can re-raise with full context."""
    ok: bool
    value: Any = None
    attempts: List[Attempt] = dataclasses.field(default_factory=list)
    error: Optional[BaseException] = None

    @property
    def n_attempts(self) -> int:
        return len(self.attempts)

    @property
    def last_error(self) -> Optional[str]:
        for att in reversed(self.attempts):
            if att.error is not None:
                return att.error
        return None


def backoff_delays(n: int, base: float = 0.05, cap: float = 2.0
                   ) -> List[float]:
    """Capped exponential backoff schedule: base * 2^i, clipped to cap."""
    return [min(cap, base * (2.0 ** i)) for i in range(max(0, n))]


def run_supervised(fn: Callable[[], Any], *, max_attempts: int = 3,
                   backoff_base: float = 0.05, backoff_cap: float = 2.0,
                   retry_on=(Exception,),
                   on_retry: Optional[Callable[[int, BaseException], None]]
                   = None,
                   sleep: Callable[[float], None] = time.sleep
                   ) -> SuperviseResult:
    """Call ``fn()`` up to ``max_attempts`` times with capped exponential
    backoff between failures.

    Only exceptions matching ``retry_on`` are caught (so
    KeyboardInterrupt / SystemExit always propagate); the last exception
    rides out in ``result.error``.  ``on_retry(attempt_index, exc)``
    fires after each failed attempt that will be retried."""
    if max_attempts < 1:
        raise ValueError("max_attempts must be >= 1")
    result = SuperviseResult(ok=False)
    delays = backoff_delays(max_attempts, backoff_base, backoff_cap)
    for i in range(max_attempts):
        t0 = time.perf_counter()
        try:
            value = fn()
        except retry_on as exc:
            result.attempts.append(Attempt(
                index=i, seconds=time.perf_counter() - t0,
                error=f"{type(exc).__name__}: {exc}"))
            result.error = exc
            if i + 1 < max_attempts:
                if on_retry is not None:
                    on_retry(i, exc)
                sleep(delays[i])
            continue
        result.attempts.append(Attempt(index=i,
                                       seconds=time.perf_counter() - t0))
        result.ok, result.value, result.error = True, value, None
        return result
    return result


def run_subprocess_supervised(
        argv: Sequence[str], *, max_attempts: int = 3,
        backoff_base: float = 0.5, backoff_cap: float = 10.0,
        timeout: Optional[float] = None, retry_nonzero: bool = False,
        retry_timeouts: bool = True,
        attempt_setup: Optional[Callable[[int], Sequence[str]]] = None,
        on_retry: Optional[Callable[[int, Attempt], None]] = None,
        sleep: Callable[[float], None] = time.sleep,
        **popen_kwargs) -> SuperviseResult:
    """Run ``argv`` as a subprocess under retry supervision.

    Retry policy (the conftest ``run_flaky_subprocess`` contract,
    generalized):

    * NEGATIVE returncodes (signal deaths: SIGKILL, SIGABRT, native
      crashes) always retry — that is the failure mode supervision
      exists for;
    * timeouts (``timeout`` seconds; the child is killed) retry when
      ``retry_timeouts`` (else the `TimeoutExpired` propagates);
    * clean nonzero exits retry only with ``retry_nonzero=True`` —
      a deliberate failure (a failed assertion, a rejected proof) must
      surface on the first attempt by default.

    ``attempt_setup(attempt_index)``, if given, runs before each try and
    returns extra argv entries (e.g. fresh scratch paths).  ``value`` is
    the final `CompletedProcess` (None only if every attempt timed out).
    """
    if max_attempts < 1:
        raise ValueError("max_attempts must be >= 1")
    if timeout is not None and timeout <= 0:
        raise ValueError(
            f"timeout must be positive (got {timeout!r}); pass None for "
            f"no timeout — a zero/negative timeout would kill every "
            f"attempt before it starts")
    result = SuperviseResult(ok=False)
    delays = backoff_delays(max_attempts, backoff_base, backoff_cap)
    for i in range(max_attempts):
        extra = list(attempt_setup(i)) if attempt_setup is not None else []
        t0 = time.perf_counter()
        try:
            proc = subprocess.run(list(argv) + extra, timeout=timeout,
                                  **popen_kwargs)
        except subprocess.TimeoutExpired as exc:
            att = Attempt(index=i, seconds=time.perf_counter() - t0,
                          error=f"timeout after {timeout}s", timed_out=True)
            result.attempts.append(att)
            result.error = exc
            if not retry_timeouts:
                raise
            if i + 1 < max_attempts:
                if on_retry is not None:
                    on_retry(i, att)
                sleep(delays[i])
            continue
        result.value = proc
        rc = proc.returncode
        if rc == 0:
            result.attempts.append(Attempt(index=i,
                                           seconds=time.perf_counter() - t0))
            result.ok, result.error = True, None
            return result
        att = Attempt(index=i, seconds=time.perf_counter() - t0,
                      error=(f"signal {-rc}" if rc < 0 else f"exit {rc}"),
                      signal=(-rc if rc < 0 else None))
        result.attempts.append(att)
        if rc > 0 and not retry_nonzero:
            return result           # clean failure: never retried
        if i + 1 < max_attempts:
            if on_retry is not None:
                on_retry(i, att)
            sleep(delays[i])
    return result


def hard_exit(status: int = 0) -> None:
    """Flush stdio and ``os._exit``: the PR 5 template for skipping
    interpreter/runtime teardown after the work (and its success
    markers) are already durable.  Use at the end of subprocess workers
    whose native runtime is known to corrupt the heap AT teardown — a
    crash after the atomic result write must not be read as failure."""
    import os
    sys.stdout.flush()
    sys.stderr.flush()
    os._exit(status)
