"""Preflight witness validation: reject malformed witnesses BEFORE they
are journaled.

The write-ahead journal (PR 8) makes every submitted witness durable —
which means a malformed witness from a bad client would be durable too:
it would replay on every restart, fail the window's prove attempts
forever, and burn pool capacity retrying garbage.  The gateway therefore
validates each witness against the tenant's ProvingKey geometry at
``submit()`` time and rejects with a TYPED error before any byte hits
disk, so a bad client poisons nothing (not the journal, not the queue,
not the worker pool).

Checks, in order (cheapest first), each with its own error class so
clients can distinguish "fix your config" from "fix your tensors":

* `WitnessQuantError`    — the witness was built under a different
  quantization (q_bits / r_bits) than the key.
* `WitnessShapeError`    — layer count, widths, batch, or any per-tensor
  shape disagrees with the compiled graph geometry.
* `WitnessDtypeError`    — a tensor is not int64 (the exact-integer
  carrier every relation is proved over; narrower ints would overflow
  the 2^{2R}-scale products silently).
* `WitnessTopologyError` — the residual skip topology the witness was
  computed under differs from the graph's.
* `WitnessRangeError`    — a committed tensor violates its quantization
  range or decomposition: Z'' outside [0, 2^{Q-1}), B not a bit plane,
  a rescale remainder outside [0, 2^R), or the eq. (3)/(5) rescale
  decompositions not holding elementwise.  (A witness that passes these
  can still fail to prove — preflight is a cheap filter, not the
  soundness argument — but one that fails them provably cannot.)
* `WitnessStepError`     — the client-declared step index breaks the
  tenant's monotonic step sequence (raised by the gateway's ``submit``,
  which owns the step counter; exported here with the family).

All of them subclass `WitnessValidationError` (a `ValueError`), so
"reject anything malformed" is one except clause.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class WitnessValidationError(ValueError):
    """A submitted witness failed preflight validation (never journaled)."""


class WitnessQuantError(WitnessValidationError):
    """Witness quantization config != key quantization config."""


class WitnessShapeError(WitnessValidationError):
    """A tensor shape / layer count disagrees with the key geometry."""


class WitnessDtypeError(WitnessValidationError):
    """A witness tensor is not int64."""


class WitnessTopologyError(WitnessValidationError):
    """The witness residual-skip topology differs from the graph's."""


class WitnessRangeError(WitnessValidationError):
    """A tensor violates its quantization range or decomposition."""


class WitnessStepError(WitnessValidationError):
    """A declared step index breaks the tenant's monotonic sequence."""


def _require_int64(name: str, arr: np.ndarray) -> None:
    a = np.asarray(arr)
    if a.dtype != np.int64:
        raise WitnessDtypeError(
            f"witness tensor {name!r} has dtype {a.dtype}, expected int64 "
            f"(exact-integer fixed-point carrier)")


def _require_shape(name: str, arr: np.ndarray, shape: tuple) -> None:
    a = np.asarray(arr)
    if tuple(a.shape) != tuple(shape):
        raise WitnessShapeError(
            f"witness tensor {name!r} has shape {tuple(a.shape)}, key "
            f"geometry expects {tuple(shape)}")


def _require_range(name: str, arr: np.ndarray, lo: int, hi: int) -> None:
    """Require every element in [lo, hi)."""
    a = np.asarray(arr)
    if a.size and (int(a.min()) < lo or int(a.max()) >= hi):
        raise WitnessRangeError(
            f"witness tensor {name!r} out of range [{lo}, {hi}): "
            f"min={int(a.min())} max={int(a.max())}")


def validate_witness(cfg, wit) -> None:
    """Validate one `StepWitness` against a compiled `PipelineConfig`
    (``pk.cfg`` / ``vk.cfg``).  Raises a `WitnessValidationError`
    subclass on the first violation; returns None when the witness is
    admissible.  Cost is O(witness size) elementwise numpy — cheap next
    to a prove, safe to run on every submit."""
    from repro.core.pipeline.graph import graph_skips

    # 1. quantization config
    if (wit.cfg.q_bits, wit.cfg.r_bits) != (cfg.q_bits, cfg.r_bits):
        raise WitnessQuantError(
            f"witness quantization (Q={wit.cfg.q_bits}, R={wit.cfg.r_bits})"
            f" != key quantization (Q={cfg.q_bits}, R={cfg.r_bits})")

    # 2. layer count + list lengths
    widths, B, L = cfg.widths, cfg.batch, cfg.n_layers
    if wit.n_layers != L:
        raise WitnessShapeError(
            f"witness has {wit.n_layers} layers, key geometry has {L}")
    lens = {"w": L, "z": L, "zpp": L, "b": L, "rz": L, "a": L, "gz": L,
            "ga": L - 1, "gap": L - 1, "rga": L - 1, "gw": L}
    for field, n in lens.items():
        got = len(getattr(wit, field))
        if got != n:
            raise WitnessShapeError(
                f"witness list {field!r} has {got} entries, expected {n}")

    # 3. per-tensor shapes + dtypes
    _require_shape("x", wit.x, (B, widths[0]))
    _require_shape("y", wit.y, (B, widths[L]))
    _require_int64("x", wit.x)
    _require_int64("y", wit.y)
    for l in range(L):
        _require_shape(f"w[{l}]", wit.w[l], (widths[l], widths[l + 1]))
        for field in ("z", "zpp", "b", "rz", "gz"):
            _require_shape(f"{field}[{l}]", getattr(wit, field)[l],
                           (B, widths[l + 1]))
        _require_shape(f"gw[{l}]", wit.gw[l], (widths[l + 1], widths[l]))
        _require_shape(f"a[{l}]", wit.a[l], (B, widths[l]))
        for field in ("w", "z", "zpp", "b", "rz", "a", "gz", "gw"):
            _require_int64(f"{field}[{l}]", getattr(wit, field)[l])
    for m in range(L - 1):
        for field in ("ga", "gap", "rga"):
            _require_shape(f"{field}[{m}]", getattr(wit, field)[m],
                           (B, widths[m + 1]))
            _require_int64(f"{field}[{m}]", getattr(wit, field)[m])

    # 4. residual topology
    expected_skips = graph_skips(cfg.graph)
    got_skips = {int(k): int(v) for k, v in wit.skips.items()}
    if got_skips != expected_skips:
        raise WitnessTopologyError(
            f"witness skip topology {got_skips} != graph topology "
            f"{expected_skips}")

    # 5. quantization ranges + rescale decompositions
    lim = 1 << (cfg.q_bits - 1)
    scale = 1 << cfg.r_bits
    _require_range("x", wit.x, -lim, lim)
    _require_range("y", wit.y, -lim, lim)
    for l in range(L):
        _require_range(f"w[{l}]", wit.w[l], -lim, lim)
        _require_range(f"zpp[{l}]", wit.zpp[l], 0, lim)
        _require_range(f"b[{l}]", wit.b[l], 0, 2)
        _require_range(f"rz[{l}]", wit.rz[l], 0, scale)
        # eq. (3): Z = 2^R (Z'' - 2^{Q-1} B) + R_Z
        zp = wit.zpp[l] - lim * wit.b[l]
        if not np.array_equal(wit.z[l], scale * zp + wit.rz[l]):
            raise WitnessRangeError(
                f"layer {l}: zkReLU decomposition (eq. 3) does not hold "
                f"— z != 2^R*(zpp - 2^(Q-1)*b) + rz")
    for m in range(L - 1):
        _require_range(f"gap[{m}]", wit.gap[m], -lim, lim)
        _require_range(f"rga[{m}]", wit.rga[m], 0, scale)
        # eq. (5): G_A = 2^R G_A' + R_GA
        if not np.array_equal(wit.ga[m],
                              scale * wit.gap[m] + wit.rga[m]):
            raise WitnessRangeError(
                f"grad layer {m}: rescale decomposition (eq. 5) does not "
                f"hold — ga != 2^R*gap + rga")


def check_step_monotonic(tenant: str, expected: int,
                         declared: Optional[int]) -> int:
    """Gateway-side step-monotonicity check: a client that declares a
    step index must declare exactly the tenant's next one (steps are
    global and gap-free per tenant — the journal/window math depends on
    it).  Returns the step the submit will use."""
    if declared is not None and declared != expected:
        raise WitnessStepError(
            f"tenant {tenant!r}: declared step {declared} breaks the "
            f"monotonic sequence (next step is {expected}); steps are "
            f"assigned per tenant, gap-free and strictly increasing")
    return expected
