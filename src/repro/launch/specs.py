"""ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
shardable, no device allocation) -- the dry-run's raw material.

The shape grid assigned to this paper:
    train_4k     seq_len=4096   global_batch=256   (train_step)
    prefill_32k  seq_len=32768  global_batch=32    (prefill_step)
    decode_32k   seq_len=32768  global_batch=128   (decode_step, KV=32k)
    long_500k    seq_len=524288 global_batch=1     (decode; SSM/hybrid only)
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct

SHAPE_GRID: Dict[str, Tuple[int, int, str]] = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

SUBQUADRATIC = ("ssm", "hybrid")


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch x shape) cell."""
    seq, gb, kind = SHAPE_GRID[shape_name]
    if shape_name == "long_500k" and cfg.family not in SUBQUADRATIC:
        return False, "full-attention arch: 512k dense decode is the quadratic regime this shape excludes (DESIGN.md §4)"
    return True, ""


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def train_batch_specs(cfg: ModelConfig, seq: int, batch: int) -> Dict:
    if cfg.family == "vlm":
        return {
            "embeds": SDS((batch, seq, cfg.d_model), _dt(cfg)),
            "positions3": SDS((3, batch, seq), jnp.int32),
            "labels": SDS((batch, seq), jnp.int32),
        }
    if cfg.family == "encdec":
        return {
            "frames": SDS((batch, seq, cfg.d_model), _dt(cfg)),
            "tokens": SDS((batch, seq), jnp.int32),
            "labels": SDS((batch, seq), jnp.int32),
        }
    return {
        "tokens": SDS((batch, seq), jnp.int32),
        "labels": SDS((batch, seq), jnp.int32),
    }


def decode_token_specs(cfg: ModelConfig, batch: int) -> Dict:
    if cfg.family == "vlm":
        return {"token": SDS((batch, 1, cfg.d_model), _dt(cfg)),
                "positions3": SDS((3, batch, 1), jnp.int32)}
    return {"token": SDS((batch,), jnp.int32)}


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.eval_shape(
        functools.partial(transformer.make_cache, cfg, batch, max_seq))


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(transformer.init_params, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict:
    """All abstract inputs for the given cell, keyed by role."""
    seq, batch, kind = SHAPE_GRID[shape_name]
    if kind == "train":
        return {"kind": "train",
                "batch": train_batch_specs(cfg, seq, batch)}
    if kind == "prefill":
        return {"kind": "prefill",
                "batch": train_batch_specs(cfg, seq, batch)}
    return {"kind": "decode",
            "cache": cache_specs(cfg, batch, seq),
            **decode_token_specs(cfg, batch),
            "pos": SDS((), jnp.int32)}
