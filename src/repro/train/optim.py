"""AdamW implemented natively (no external optimizer dependency).

State is a pytree mirroring params (mu, nu) + a scalar step, so it shards
exactly like the parameters under every mesh in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # bf16 moments halve optimizer HBM -- required to fit grok-1-scale
    # models on 16 GB chips (f32 master params are kept either way)
    state_dtype: str = "float32"


def init_opt_state(params, cfg: AdamWConfig = AdamWConfig()) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return {"mu": z,
            "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mf / b1c
        vhat = vf / b2c
        new_p = (p.astype(jnp.float32)
                 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                         + cfg.weight_decay * p.astype(jnp.float32)))
        return new_p.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["mu"])
    flat_v = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return (new_params,
            {"mu": new_mu, "nu": new_nu, "step": step},
            {"grad_norm": gnorm, "lr": lr})
