"""Gradient compression with error feedback (distributed-optimization
trick for the 1000+-node regime).

At 16x16+ scale the data-parallel gradient all-reduce moves
2 bytes/param/step (bf16); int8 block-quantized compression halves it and
top-k sparsification cuts it by ~kx.  Both are implemented as pure-jnp
transforms compatible with pjit (the quantize/dequantize runs inside the
train step; XLA reduces the compressed payload).

Error feedback keeps the residual (g - dequant(quant(g))) in the optimizer
state and adds it back the next step, which restores convergence to the
uncompressed fixed point (Karimireddy et al. 2019) -- without it, int8
rounding bias accumulates.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    mode: str = "none"           # none | int8 | topk
    block: int = 256             # int8: scale-block length
    topk_frac: float = 0.01      # topk: fraction of entries kept
    error_feedback: bool = True


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                        params)


def _int8_quant(g, block: int):
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def _int8_dequant(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def _topk_mask(g, frac: float):
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jnp.sort(jnp.abs(flat))[-k]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compress_grads(cfg: CompressionConfig, grads, residuals):
    """Returns (compressed-then-decompressed grads, new residuals).

    The round trip models exactly what the wire sees; with pjit the
    quantized representation is what crosses the data axis.
    """
    if cfg.mode == "none":
        return grads, residuals

    def one(g, r):
        g32 = g.astype(jnp.float32)
        if cfg.error_feedback:
            g32 = g32 + r
        if cfg.mode == "int8":
            q, scale, pad = _int8_quant(g32, cfg.block)
            out = _int8_dequant(q, scale, pad, g32.shape)
        elif cfg.mode == "topk":
            out = g32 * _topk_mask(g32, cfg.topk_frac)
        else:
            raise ValueError(cfg.mode)
        new_r = (g32 - out) if cfg.error_feedback else r
        return out.astype(g.dtype), new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_g, new_r


def wire_bytes_per_param(cfg: CompressionConfig) -> float:
    """Analytic bytes/param crossing the data axis (for the roofline)."""
    if cfg.mode == "int8":
        return 1.0 + 4.0 / cfg.block
    if cfg.mode == "topk":
        return cfg.topk_frac * 8.0       # value + index
    return 2.0                           # bf16
