"""Reshardable, atomic checkpointing with restart support.

Layout:  <dir>/step_<n>/
             manifest.json            tree structure + shapes + dtypes
             <leaf-id>.npy            one file per pytree leaf
             _COMPLETE                commit marker (atomicity)

Leaves are written from host copies (single-process) or per-process shards
(``process_<i>`` suffix under multi-host -- the manifest records the
layout).  Restore takes target shardings, so a checkpoint written on one
mesh restores onto any other mesh (elastic rescale): jax.device_put with a
NamedSharding reshards on load.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional

import jax
import numpy as np


class StorageError(OSError):
    """A durable-write failure (full disk, IO error, permission): the
    typed form every `atomic_write_bytes` caller in the proving service
    sees instead of a raw `OSError`.  The write is all-or-nothing — on
    failure the temp file is removed, so a full disk leaves no orphan
    ``*.tmp`` turds and the target path is never half-written.  Service
    policy on catching it: mark the window FAILED (worker side) or
    retry with backoff / drop the window per the backpressure policy
    (submit side) — never crash the worker loop."""

    @property
    def is_enospc(self) -> bool:
        import errno
        return self.errno == errno.ENOSPC


def atomic_write_bytes(path: str, data: bytes, fsync: bool = True) -> None:
    """Single-file form of the checkpoint commit pattern (tmp + rename):
    readers never observe a torn write, and a crash mid-write leaves only
    a ``*.tmp.<pid>`` turd, never a half-valid ``path``.  Used by the
    crash-safe prover service for journal segments, proof files, and
    vk.bin (`launch/serve.py`).

    Any `OSError` during the write (ENOSPC on a full disk being the
    canonical case) is re-raised as a typed `StorageError` AFTER the
    temp file has been cleaned up: callers get a precise failure class
    and the directory stays free of orphan temp files."""
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            f.write(data)
            if fsync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, path)
    except OSError as exc:
        try:
            os.remove(tmp)
        except OSError:
            pass
        if isinstance(exc, StorageError):
            raise
        raise StorageError(exc.errno or 0,
                           f"durable write of {path!r} failed: "
                           f"{exc.strerror or exc}") from exc


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                        for k in path)
        out.append((name.replace("/", "__"), leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, state) -> str:
    """Atomic checkpoint write; returns the committed directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _leaf_paths(state)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"][name] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMPLETE"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep=3)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "_COMPLETE")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, state_template,
            shardings=None):
    """Load into the structure of state_template; reshard per `shardings`."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    leaves, treedef = _leaf_paths(state_template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (name, tmpl), sh in zip(leaves, shard_leaves):
        arr = np.load(os.path.join(d, name + ".npy"))
        assert tuple(arr.shape) == tuple(tmpl.shape), (name, arr.shape)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "_COMPLETE")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
