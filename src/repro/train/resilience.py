"""Fault tolerance for long-running multi-pod jobs.

Three mechanisms, all host-side (they wrap the pjit'd step, never enter
the compiled graph):

* ``CheckpointPolicy`` -- periodic save + restart-from-latest.  Restore is
  sharding-agnostic: checkpoints store full host arrays, so a job can come
  back on a SMALLER or LARGER mesh (elastic rescale) -- the restore path
  re-places every leaf under the new mesh's shardings.

* ``StragglerMonitor`` -- per-step wall-time EMA; a step slower than
  ``threshold`` x EMA flags a straggler event.  On real pods the action is
  to quarantine the slow host and continue on the survivors (elastic
  rescale); here the hook records the event and triggers the caller's
  callback.

* ``FailureInjector`` -- deterministic fault simulation for tests/examples
  (raise at step k), proving the restart path end-to-end.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.train import checkpoint


@dataclasses.dataclass
class CheckpointPolicy:
    ckpt_dir: str
    every: int = 50
    keep: int = 3

    def maybe_save(self, step: int, state) -> Optional[str]:
        if step % self.every == 0 and step > 0:
            return checkpoint.save(self.ckpt_dir, step, state)
        return None

    def restore_latest(self, state_template, shardings=None):
        """Returns (state, start_step). state_template supplies the pytree
        structure; `shardings` (optional) re-places leaves for the current
        mesh -- this is the elastic-rescale path."""
        step = checkpoint.latest_step(self.ckpt_dir)
        if step is None:
            return None, 0
        state = checkpoint.restore(self.ckpt_dir, step, state_template)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return state, step + 1


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 3.0
    ema_decay: float = 0.9
    warmup: int = 3
    _ema: float = 0.0
    _n: int = 0
    events: List[Dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float,
                on_straggler: Optional[Callable[[int, float], None]] = None
                ) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._ema = dt if self._ema == 0 else (
                self.ema_decay * self._ema + (1 - self.ema_decay) * dt)
            return False
        is_straggler = dt > self.threshold * self._ema
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "ema": self._ema})
            if on_straggler:
                on_straggler(step, dt)
        else:
            self._ema = self.ema_decay * self._ema + (1 - self.ema_decay) * dt
        return is_straggler


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_step: Optional[int] = None
    fired: bool = False

    def check(self, step: int) -> None:
        if (self.fail_at_step is not None and step == self.fail_at_step
                and not self.fired):
            self.fired = True
            raise SimulatedFailure(f"injected failure at step {step}")


def run_resilient(train_loop: Callable[[Any, int], Any],
                  state_template, policy: CheckpointPolicy,
                  shardings=None, max_restarts: int = 3):
    """Drive ``train_loop(state, start_step) -> state`` with
    restart-from-latest-checkpoint on failure.  Returns final state."""
    restarts = 0
    while True:
        state, start = policy.restore_latest(state_template, shardings)
        try:
            return train_loop(state, start)
        except SimulatedFailure as exc:
            restarts += 1
            print(f"[resilience] {exc}; restarting from latest checkpoint "
                  f"(restart {restarts}/{max_restarts})", flush=True)
            if restarts > max_restarts:
                raise
