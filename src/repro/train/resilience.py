"""Fault tolerance for long-running multi-pod jobs.

Three mechanisms, all host-side (they wrap the pjit'd step, never enter
the compiled graph):

* ``CheckpointPolicy`` -- periodic save + restart-from-latest.  Restore is
  sharding-agnostic: checkpoints store full host arrays, so a job can come
  back on a SMALLER or LARGER mesh (elastic rescale) -- the restore path
  re-places every leaf under the new mesh's shardings.

* ``StragglerMonitor`` -- per-step wall-time EMA; a step slower than
  ``threshold`` x EMA flags a straggler event.  On real pods the action is
  to quarantine the slow host and continue on the survivors (elastic
  rescale); here the hook records the event and triggers the caller's
  callback.

* ``FailureInjector`` -- deterministic fault simulation for tests/examples.
  Two interfaces: the legacy step trigger (``fail_at_step=k`` +
  ``check(step)``, used by the training loop) and NAMED FAULT POINTS
  (``faults={"point": "HITS[:action]"}`` + ``fire(point)``), used by the
  crash-safe prover service and the multi-tenant proving gateway
  (`launch/serve.py`) to inject crashes at exact pipeline locations:
  before/after the journal append, mid-prove, between the proof write
  and the manifest commit, a hard worker kill — plus the concurrency-era
  points PR 10 added: ``pool/worker-kill`` (top of each gateway pool
  worker's job loop: kill one worker thread under load), ``storage/
  journal`` / ``storage/proof`` / ``storage/manifest`` (immediately
  before the corresponding durable write — pair with the ``enospc``
  action for full-disk chaos), ``lock/acquire`` (gateway lockfile
  acquisition: simulate contention), ``gateway/pre-prove`` (before each
  pool prove attempt: a range spec here produces the consecutive
  failures that trip a tenant's circuit breaker) and ``breaker/trip``
  (the instant a breaker opens: storm amplification).

  ``HITS`` selects WHICH hits of the point act: ``N`` (the N-th, 0-based),
  ``N-M`` (every hit in the inclusive range) or ``*`` (every hit).
  Actions: ``raise`` (default, a `SimulatedFailure`), ``kill`` (SIGKILL
  the whole process — a real signal death), ``corrupt-cache`` (truncate
  one on-disk `core/execache` entry, then continue), ``enospc`` (raise a
  typed `train/checkpoint.StorageError` with errno ENOSPC — the
  injected full-disk).  ``from_env()`` reads ``ZKDL_FAULTS`` so
  subprocess workers inherit faults, and ``ZKDL_FAULTS_ONCE=<dir>``
  makes each fault fire at most once ACROSS processes (markers on
  disk) — without it a retried subprocess would re-fire the same fault
  forever.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import sys
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.train import checkpoint


@dataclasses.dataclass
class CheckpointPolicy:
    ckpt_dir: str
    every: int = 50
    keep: int = 3

    def maybe_save(self, step: int, state) -> Optional[str]:
        if step % self.every == 0 and step > 0:
            return checkpoint.save(self.ckpt_dir, step, state)
        return None

    def restore_latest(self, state_template, shardings=None):
        """Returns (state, start_step). state_template supplies the pytree
        structure; `shardings` (optional) re-places leaves for the current
        mesh -- this is the elastic-rescale path."""
        step = checkpoint.latest_step(self.ckpt_dir)
        if step is None:
            return None, 0
        state = checkpoint.restore(self.ckpt_dir, step, state_template)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return state, step + 1


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 3.0
    ema_decay: float = 0.9
    warmup: int = 3
    _ema: float = 0.0
    _n: int = 0
    events: List[Dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float,
                on_straggler: Optional[Callable[[int, float], None]] = None
                ) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._ema = dt if self._ema == 0 else (
                self.ema_decay * self._ema + (1 - self.ema_decay) * dt)
            return False
        is_straggler = dt > self.threshold * self._ema
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "ema": self._ema})
            if on_straggler:
                on_straggler(step, dt)
        else:
            self._ema = self.ema_decay * self._ema + (1 - self.ema_decay) * dt
        return is_straggler


class SimulatedFailure(RuntimeError):
    pass


def _hit_matches(hits: str, hit: int) -> bool:
    """Does hit number ``hit`` (0-based) fall inside the ``HITS`` spec?
    ``N`` = exactly the N-th, ``N-M`` = the inclusive range, ``*`` =
    every hit."""
    if hits == "*":
        return True
    lo, sep, hi = hits.partition("-")
    if sep:
        return int(lo) <= hit <= int(hi)
    return hit == int(hits)


@dataclasses.dataclass
class FailureInjector:
    fail_at_step: Optional[int] = None
    fired: bool = False
    # named fault points: {"point": "HITS" | "HITS:raise" | "HITS:kill" |
    # "HITS:corrupt-cache" | "HITS:enospc"} with HITS one of N / N-M / *
    # (0-based hit numbers of fire(point))
    faults: Dict[str, str] = dataclasses.field(default_factory=dict)
    once_dir: Optional[str] = None      # cross-process fire-once markers
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    events: List[str] = dataclasses.field(default_factory=list)

    def check(self, step: int) -> None:
        if (self.fail_at_step is not None and step == self.fail_at_step
                and not self.fired):
            self.fired = True
            raise SimulatedFailure(f"injected failure at step {step}")

    def fire(self, point: str) -> None:
        """Hit the named fault point; acts only when a matching spec is
        armed and this hit falls in its HITS selector (and, with
        ``once_dir``, the fault has not already fired in ANY process —
        range/``*`` specs keep one marker per HIT, so each selected hit
        fires at most once across processes)."""
        hit = self.counts.get(point, 0)
        self.counts[point] = hit + 1
        spec = self.faults.get(point)
        if spec is None:
            return
        hits_str, _, action = str(spec).partition(":")
        if not _hit_matches(hits_str, hit):
            return
        action = action or "raise"
        if self.once_dir is not None:
            marker = os.path.join(
                self.once_dir,
                f"fired_{point.replace('/', '_')}_{hit}")
            if os.path.exists(marker):
                return
            os.makedirs(self.once_dir, exist_ok=True)
            with open(marker, "w") as f:
                f.write(action)
        self.events.append(f"{point}#{hit}:{action}")
        if action == "kill":
            sys.stdout.flush()
            sys.stderr.flush()
            os.kill(os.getpid(), signal.SIGKILL)
        if action == "corrupt-cache":
            corrupt_exec_cache_entry()
            return
        if action == "enospc":
            import errno
            raise checkpoint.StorageError(
                errno.ENOSPC, f"injected ENOSPC at {point} (hit {hit})")
        raise SimulatedFailure(f"injected fault at {point} (hit {hit})")

    @classmethod
    def from_spec(cls, spec: str,
                  once_dir: Optional[str] = None) -> "FailureInjector":
        """Parse ``"point@HITS[:action][,point2@HITS[:action]]..."`` with
        ``HITS`` one of ``N`` / ``N-M`` / ``*``; a bare ``point`` means
        ``point@0`` (fire on the first hit)."""
        faults: Dict[str, str] = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            point, _, rest = part.partition("@")
            faults[point] = rest or "0"
        return cls(faults=faults, once_dir=once_dir)

    @classmethod
    def from_env(cls, var: str = "ZKDL_FAULTS"
                 ) -> Optional["FailureInjector"]:
        spec = os.environ.get(var, "")
        if not spec:
            return None
        return cls.from_spec(spec,
                             once_dir=os.environ.get(var + "_ONCE") or None)


def corrupt_exec_cache_entry() -> Optional[str]:
    """Truncate one serialized executable in the on-disk exec cache (the
    oldest entry by name) to half its size — the ``corrupt-cache`` fault
    action.  Returns the corrupted path, or None when the cache is
    disabled/empty.  The cache contract (PR 8) is that such an entry is
    treated as a MISS: recompiled and rewritten, never a crash."""
    from repro.core import execache
    d = execache.cache_dir()
    if d is None or not os.path.isdir(d):
        return None
    entries = sorted(f for f in os.listdir(d) if f.endswith(".pkl"))
    if not entries:
        return None
    path = os.path.join(d, entries[0])
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(1, size // 2))
    return path


def run_resilient(train_loop: Callable[[Any, int], Any],
                  state_template, policy: CheckpointPolicy,
                  shardings=None, max_restarts: int = 3):
    """Drive ``train_loop(state, start_step) -> state`` with
    restart-from-latest-checkpoint on failure.  Returns final state."""
    restarts = 0
    while True:
        state, start = policy.restore_latest(state_template, shardings)
        try:
            return train_loop(state, start)
        except SimulatedFailure as exc:
            restarts += 1
            print(f"[resilience] {exc}; restarting from latest checkpoint "
                  f"(restart {restarts}/{max_restarts})", flush=True)
            if restarts > max_restarts:
                raise
