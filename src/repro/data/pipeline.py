"""Deterministic sharded data pipeline.

Two sources behind one interface:
  * ``SyntheticTokens`` -- counter-based PRNG stream (step, rank) ->
    tokens, so any (step) batch is reproducible on any topology;
  * ``BinTokenFile`` -- memory-mapped flat token file (the production
    path), sliced per (step, dp_rank) without overlap.

Determinism across restarts: the batch for step N depends only on N (and
the file), never on consumed state -- resume needs no data checkpointing
beyond the step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class SyntheticTokens:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(key=self.seed + step))
        b, s, cfg = self.global_batch, self.seq_len, self.cfg
        if cfg.family == "vlm":
            return {
                "embeds": rng.normal(size=(b, s, cfg.d_model)).astype(np.float32),
                "positions3": np.broadcast_to(
                    np.arange(s, dtype=np.int32), (3, b, s)).copy(),
                "labels": rng.integers(0, cfg.vocab, (b, s), dtype=np.int32),
            }
        if cfg.family == "encdec":
            return {
                "frames": rng.normal(size=(b, s, cfg.d_model)).astype(np.float32),
                "tokens": rng.integers(0, cfg.vocab, (b, s), dtype=np.int32),
                "labels": rng.integers(0, cfg.vocab, (b, s), dtype=np.int32),
            }
        toks = rng.integers(0, cfg.vocab, (b, s + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}


@dataclasses.dataclass
class BinTokenFile:
    """Flat binary token file (uint16/uint32), deterministic slicing."""
    path: str
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    dtype: str = "uint16"

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._tokens_per_step = self.global_batch * (self.seq_len + 1)
        self.n_steps = len(self._data) // self._tokens_per_step

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        step = step % max(self.n_steps, 1)
        off = step * self._tokens_per_step
        chunk = np.asarray(
            self._data[off: off + self._tokens_per_step], dtype=np.int32)
        chunk = chunk.reshape(self.global_batch, self.seq_len + 1)
        chunk = np.remainder(chunk, self.cfg.vocab)
        return {"tokens": chunk[:, :-1].copy(), "labels": chunk[:, 1:].copy()}


def make_source(cfg: ModelConfig, seq_len: int, global_batch: int,
                path: Optional[str] = None, seed: int = 0):
    if path:
        return BinTokenFile(path, cfg, seq_len, global_batch)
    return SyntheticTokens(cfg, seq_len, global_batch, seed)
