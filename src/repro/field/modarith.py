"""Prime-field arithmetic in 16-bit-limb form, pure jnp uint32.

This is the TPU-native adaptation layer of zkDL: the reference CUDA
implementation relies on 64-bit integer units; TPUs expose 32-bit integer
lanes only, so every field element is held as four 16-bit limbs packed in a
trailing ``(..., 4)`` uint32 axis and multiplied with CIOS Montgomery
reduction (radix 2^16).  Products of 16-bit limbs and all CIOS accumulators
provably fit in uint32, so the same code runs bit-exactly on CPU (used for
validation here) and inside Pallas TPU kernels.

Two fields are instantiated:

* ``FQ`` -- the proof/scalar field, q = 2^61 - 5283 (prime).  All sumcheck,
  MLE, and quantized-training arithmetic of zkDL lives here (the paper's
  |F| with 2^{Q+R} << |F|).
* ``FP`` -- the group field, p = 2q + 1 (prime, Sophie-Germain pair).  The
  Pedersen commitment group is the order-q subgroup of quadratic residues
  of F_p^*; "group add" is modmul in FP and scalars live in FQ.

Elements are kept in Montgomery form (x * 2^64 mod m) between operations.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

WORD = 16
WMASK = 0xFFFF
NLIMB = 4

U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """Constants describing one prime field in 16-bit limb Montgomery form."""

    name: str
    modulus: int
    nprime16: int          # -modulus^{-1} mod 2^16
    r1: int                # 2^64 mod modulus  (Montgomery form of 1)
    r2: int                # 2^128 mod modulus (to_mont multiplier)

    @property
    def bits(self) -> int:
        return self.modulus.bit_length()

    @functools.cached_property
    def mod_limbs(self):
        return tuple((self.modulus >> (WORD * i)) & WMASK for i in range(NLIMB))

    @functools.cached_property
    def one(self) -> np.ndarray:
        """Montgomery form of 1, as a (4,) uint32 numpy array."""
        return int_to_limbs(self.r1)

    @functools.cached_property
    def zero(self) -> np.ndarray:
        return np.zeros(NLIMB, dtype=np.uint32)

    @functools.cached_property
    def r2_limbs(self) -> np.ndarray:
        return int_to_limbs(self.r2)


FQ = FieldSpec(
    name="Fq", modulus=2305843009213688669, nprime16=16139,
    r1=42264, r2=1786245696,
)
FP = FieldSpec(
    name="Fp", modulus=4611686018427377339, nprime16=397,
    r1=42260, r2=1785907600,
)
# Generator of the order-q subgroup (quadratic residues) of F_p^*.
GROUP_GEN = 4


# ---------------------------------------------------------------------------
# Host-side converters (numpy / python int <-> limb arrays).
# ---------------------------------------------------------------------------

def int_to_limbs(x: int) -> np.ndarray:
    return np.array([(x >> (WORD * i)) & WMASK for i in range(NLIMB)],
                    dtype=np.uint32)


def ints_to_limbs(xs) -> np.ndarray:
    """Vectorized python-int array -> (..., 4) uint32 limb array.

    Non-negative values below 2^64 (every canonical field element) pack
    via pure-numpy uint64 shifts; arbitrary python ints fall back to
    batched object-array shifts (still no per-element Python loop)."""
    arr = np.asarray(xs, dtype=object)
    flat = arr.reshape(-1)
    out = np.empty(flat.shape + (NLIMB,), dtype=np.uint32)
    try:
        u = flat.astype(np.uint64)
    except (OverflowError, TypeError):
        u = None
    if u is None:
        for j in range(NLIMB):
            out[:, j] = ((flat >> (WORD * j)) & WMASK).astype(np.uint32)
    else:
        for j in range(NLIMB):
            out[:, j] = ((u >> np.uint64(WORD * j))
                         & np.uint64(WMASK)).astype(np.uint32)
    return out.reshape(arr.shape + (NLIMB,))


def limbs_to_ints(limbs) -> np.ndarray:
    """(..., 4) uint32 limb array -> object array of python ints."""
    limbs = np.asarray(limbs)
    flat = limbs.reshape(-1, NLIMB)
    out = np.empty(flat.shape[0], dtype=object)
    for i in range(flat.shape[0]):
        v = 0
        for j in range(NLIMB):
            v |= int(flat[i, j]) << (WORD * j)
        out[i] = v
    return out.reshape(limbs.shape[:-1])


# ---------------------------------------------------------------------------
# Core limb primitives (shape (..., 4) uint32, each limb < 2^16).
# All arithmetic stays inside uint32; see module docstring for bounds.
# ---------------------------------------------------------------------------

def _split(t):
    return t & WMASK, t >> WORD


def mont_mul(spec: FieldSpec, a, b):
    """CIOS Montgomery multiplication: returns a*b*2^-64 mod m (canonical).

    jit'd with the field spec static: eager call sites (the prover's
    per-round host loops) pay ONE dispatch instead of ~150 tiny-op
    dispatches; inside other jitted code it inlines as before.
    """
    al = [a[..., j] for j in range(NLIMB)]
    bl = [b[..., j] for j in range(NLIMB)]
    pl = [jnp.uint32(x) for x in spec.mod_limbs]
    npr = jnp.uint32(spec.nprime16)

    zero = jnp.zeros(jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1]), U32)
    t = [zero] * (NLIMB + 2)
    for i in range(NLIMB):
        # t += a * b[i]
        c = zero
        for j in range(NLIMB):
            acc = t[j] + al[j] * bl[i] + c
            t[j], c = _split(acc)
        acc = t[NLIMB] + c
        t[NLIMB], t[NLIMB + 1] = _split(acc)
        # Montgomery reduction step
        m = (t[0] * npr) & WMASK
        acc = t[0] + m * pl[0]
        _, c = _split(acc)
        for j in range(1, NLIMB):
            acc = t[j] + m * pl[j] + c
            t[j - 1], c = _split(acc)
        acc = t[NLIMB] + c
        t[NLIMB - 1], c = _split(acc)
        t[NLIMB] = t[NLIMB + 1] + c
        t[NLIMB + 1] = zero
    return _cond_sub_mod(spec, t[:NLIMB + 1])


def _cond_sub_mod(spec: FieldSpec, t):
    """t (5 words, value < 2m) -> canonical t mod m as (..., 4) stack."""
    pl = list(spec.mod_limbs) + [0]
    borrow = jnp.zeros_like(t[0])
    u = []
    for j in range(NLIMB + 1):
        d = t[j] - jnp.uint32(pl[j]) - borrow
        u.append(d & WMASK)
        borrow = (d >> 31)  # top bit set iff wrapped below zero
    keep_t = borrow.astype(bool)  # borrow out => t < m
    limbs = [jnp.where(keep_t, t[j], u[j]) for j in range(NLIMB)]
    return jnp.stack(limbs, axis=-1)


def add(spec: FieldSpec, a, b):
    c = jnp.zeros(jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1]), U32)
    t = []
    for j in range(NLIMB):
        acc = a[..., j] + b[..., j] + c
        s, c = _split(acc)
        t.append(s)
    t.append(c)
    return _cond_sub_mod(spec, t)


def sub(spec: FieldSpec, a, b):
    pl = spec.mod_limbs
    borrow = jnp.zeros(jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1]), U32)
    d = []
    for j in range(NLIMB):
        x = a[..., j] - b[..., j] - borrow
        d.append(x & WMASK)
        borrow = x >> 31
    # if borrow: add modulus back
    wrapped = borrow.astype(bool)
    c = jnp.zeros_like(borrow)
    e = []
    for j in range(NLIMB):
        acc = d[j] + jnp.uint32(pl[j]) + c
        s, c = _split(acc)
        e.append(s)
    limbs = [jnp.where(wrapped, e[j], d[j]) for j in range(NLIMB)]
    return jnp.stack(limbs, axis=-1)


def neg(spec: FieldSpec, a):
    z = jnp.zeros_like(a)
    return sub(spec, z, a)


def is_zero(a):
    return jnp.all(a == 0, axis=-1)


def eq(a, b):
    return jnp.all(a == b, axis=-1)


def pow_const(spec: FieldSpec, a, e: int):
    """a^e for a python-int exponent (unrolled square & multiply)."""
    if e == 0:
        return jnp.broadcast_to(jnp.asarray(spec.one), a.shape)
    result = None
    base = a
    while e:
        if e & 1:
            result = base if result is None else mont_mul(spec, result, base)
        e >>= 1
        if e:
            base = mont_mul(spec, base, base)
    return result


def inv(spec: FieldSpec, a):
    """Field inverse via Fermat (a^(m-2)); a must be nonzero."""
    return pow_const(spec, a, spec.modulus - 2)


def batch_inv(spec: FieldSpec, a):
    """Montgomery batch inversion of a flat (n, 4) array: one inv + 3n muls.

    jit'd: the two lax.scans otherwise re-trace (and re-compile) on every
    eager call because their body closures are fresh function objects."""
    n = a.shape[0]
    if n == 0:
        return a
    one = jnp.asarray(spec.one)

    def fwd(carry, x):
        nxt = mont_mul(spec, carry, x)
        return nxt, carry  # prefix product *excluding* x

    total, prefix_ex = jax.lax.scan(fwd, one, a)
    inv_total = inv(spec, total)

    def bwd(carry, xs):
        x, pre = xs
        out = mont_mul(spec, carry, pre)
        nxt = mont_mul(spec, carry, x)
        return nxt, out

    _, outs = jax.lax.scan(bwd, inv_total, (a, prefix_ex), reverse=True)
    return outs


def to_mont(spec: FieldSpec, x_limbs):
    return mont_mul(spec, x_limbs, jnp.asarray(spec.r2_limbs))


def from_mont(spec: FieldSpec, a):
    one_std = jnp.zeros((1,) * (a.ndim - 1) + (NLIMB,), U32).at[..., 0].set(1)
    return mont_mul(spec, a, one_std)


# Executable-cache wrapping of the eager-callable primitives: the spec
# is a positional static (frozen dataclass, deterministic repr), so a
# fresh process replays mont_mul/add/sub dispatches from serialized
# executables instead of re-tracing each (spec, shape) signature.
# Deferred import: repro.core.execache is stdlib-only at module level.
from repro.core import execache as _execache

mont_mul = _execache.wrap("f_mont_mul", mont_mul, static_argnums=(0,))
add = _execache.wrap("f_add", add, static_argnums=(0,))
sub = _execache.wrap("f_sub", sub, static_argnums=(0,))
batch_inv = _execache.wrap("f_batch_inv", batch_inv, static_argnums=(0,))
to_mont = _execache.wrap("f_to_mont", to_mont, static_argnums=(0,))
from_mont = _execache.wrap("f_from_mont", from_mont, static_argnums=(0,))


# ---------------------------------------------------------------------------
# Host helpers: encoding integers / arrays into Montgomery limb form.
# ---------------------------------------------------------------------------

def encode_int(spec: FieldSpec, x: int) -> np.ndarray:
    """Python int (possibly negative) -> Montgomery limb form (4,) uint32."""
    v = (x * pow(2, 64, spec.modulus)) % spec.modulus
    return int_to_limbs(v)


def encode_ints(spec: FieldSpec, xs) -> np.ndarray:
    """Array of python/np ints -> (..., 4) uint32 Montgomery form (host).

    int64-range inputs (bit matrices, reduced challenge products, witness
    tensors) take the vectorized `encode_i64` path; arbitrary-precision
    inputs run the same computation as batched object-array ops."""
    arr = np.asarray(xs, dtype=object)
    try:
        return encode_i64(spec, arr.astype(np.int64)).reshape(
            arr.shape + (NLIMB,))
    except (OverflowError, TypeError):
        pass
    r = pow(2, 64, spec.modulus)
    return ints_to_limbs(arr * r % spec.modulus)


def decode(spec: FieldSpec, a) -> np.ndarray:
    """Montgomery limb array -> object array of canonical python ints (host)."""
    std = np.asarray(from_mont(spec, jnp.asarray(a)))
    return limbs_to_ints(std)


def decode_centered(spec: FieldSpec, a) -> np.ndarray:
    """Decode to signed representatives in (-m/2, m/2]."""
    vals = decode(spec, a)
    m = spec.modulus
    flat = vals.reshape(-1)
    for i in range(flat.shape[0]):
        if flat[i] > m // 2:
            flat[i] -= m
    return vals


def encode_i64(spec: FieldSpec, xs: np.ndarray) -> np.ndarray:
    """Fast path: int64 numpy array -> Montgomery limbs (vectorized host)."""
    xs = np.asarray(xs, dtype=np.int64)
    m = spec.modulus
    r = pow(2, 64, m)
    # int64 values are < 2^63 in magnitude; do the modmul in python-object
    # space only when needed.  (m * r fits in object ints.)
    vals = (xs.astype(object) * r) % m
    return ints_to_limbs(vals)


def rand_elements(spec: FieldSpec, rng: np.random.Generator, shape) -> np.ndarray:
    """Uniform field elements in Montgomery form (host-side sampling)."""
    n = int(np.prod(shape)) if shape else 1
    vals = [int(rng.integers(0, spec.modulus, dtype=np.uint64)) % spec.modulus
            for _ in range(n)]
    out = encode_ints(spec, np.array(vals, dtype=object).reshape(shape))
    return out


def hash_to_int(data: bytes, modulus: int) -> int:
    h = hashlib.sha256(data).digest()
    return int.from_bytes(h, "little") % modulus
