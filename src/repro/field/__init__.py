from repro.field.modarith import (  # noqa: F401
    FQ, FP, GROUP_GEN, FieldSpec, NLIMB,
    add, sub, neg, mont_mul, inv, batch_inv, pow_const,
    to_mont, from_mont, is_zero, eq,
    int_to_limbs, ints_to_limbs, limbs_to_ints,
    encode_int, encode_ints, encode_i64, decode, decode_centered,
    rand_elements, hash_to_int,
)
