"""Shared neural layers: norms, rotary embeddings, MLPs (pure functional)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(dt)


def init_rms(d: int):
    return jnp.ones((d,), jnp.float32)


def dense_init(key, shape, in_axis: int = 0):
    fan_in = shape[in_axis]
    scale = 1.0 / np.sqrt(fan_in)
    return jax.random.uniform(key, shape, jnp.float32, -scale, scale)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B,S,half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: Tuple[int, ...]):
    """Multimodal RoPE (qwen2-vl): positions3 (3, B, S); the rotary half-dim
    is split into `sections` (t, h, w), each using its own position stream."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    # choose the position stream per frequency-section
    sec_id = np.repeat(np.arange(len(sections)), sections)      # (half,)
    pos = positions3[sec_id, :, :]                              # (half, B, S)
    ang = jnp.transpose(pos, (1, 2, 0)).astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU-style; act configurable so ReLU nets are zkReLU-provable)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, (d_model, d_ff)),
        "wg": dense_init(k2, (d_model, d_ff)),
        "wo": dense_init(k3, (d_ff, d_model)),
    }


def mlp(params: Dict, x, act_name: str):
    act = activation(act_name)
    h = act(x @ params["wg"].astype(x.dtype)) * (x @ params["wi"].astype(x.dtype))
    return h @ params["wo"].astype(x.dtype)


def cross_entropy(logits, labels, vocab: int):
    """Mean CE over tokens; logits (..., V) any float dtype."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
