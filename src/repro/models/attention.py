"""Attention: grouped-query (GQA), MLA (DeepSeek-V2), cross-attention.

Grouped einsums keep the repeated-KV heads implicit (no materialized
repeat), and the decode path consumes a (B, S_max, KV, Dh) cache updated
with lax.dynamic_update_slice so the same code lowers for every serve
shape in the dry-run.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import (apply_mrope, apply_rope, dense_init,
                                 init_rms, rms_norm)

NEG_INF = -1e9


def init_attention(key, cfg: ModelConfig) -> Dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, h * dh)),
        "wk": dense_init(ks[1], (d, kv * dh)),
        "wv": dense_init(ks[2], (d, kv * dh)),
        "wo": dense_init(ks[3], (h * dh, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms(dh)
        p["k_norm"] = init_rms(dh)
    return p


def _gqa_scores(q, k, cfg: ModelConfig):
    """q: (B,S,H,Dh), k: (B,T,KV,Dh) -> scores (B,KV,G,S,T) without repeat."""
    b, s, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, dh)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k) / np.sqrt(dh)


def _gqa_out(scores, v):
    """scores (B,KV,G,S,T), v (B,T,KV,Dh) -> (B,S,KV*G*Dh)."""
    b, kv, g, s, t = scores.shape
    out = jnp.einsum("bkgst,btkd->bskgd", scores, v)
    return out.reshape(b, s, kv * g * v.shape[-1])


def attention(params: Dict, x, cfg: ModelConfig, positions,
              mask: Optional[jnp.ndarray] = None,
              positions3: Optional[jnp.ndarray] = None,
              kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None):
    """Full-sequence attention (training / prefill).

    mask: (S, T) boolean (True = attend) or None for causal-by-default
    when cfg.causal; kv_override supplies cross-attention keys/values.
    """
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, h, dh)
    if kv_override is None:
        k = (x @ params["wk"].astype(x.dtype)).reshape(b, s, kv, dh)
        v = (x @ params["wv"].astype(x.dtype)).reshape(b, s, kv, dh)
    else:
        k, v = kv_override
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"]) if kv_override is None else k
    if kv_override is None:
        if cfg.mrope_sections:
            q = apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    scores = _gqa_scores(q, k, cfg)
    t = k.shape[1]
    if mask is None and cfg.causal and kv_override is None:
        mask = jnp.tril(jnp.ones((s, t), bool))
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v)
    return out @ params["wo"].astype(x.dtype), (k, v)


def decode_attention(params: Dict, x, cfg: ModelConfig, cache_k, cache_v,
                     pos, positions3=None):
    """Single-token decode: x (B,1,d); cache (B,S_max,KV,Dh); pos scalar.

    Returns (out, new_cache_k, new_cache_v)."""
    b, _, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, 1, h, dh)
    k_new = (x @ params["wk"].astype(x.dtype)).reshape(b, 1, kv, dh)
    v_new = (x @ params["wv"].astype(x.dtype)).reshape(b, 1, kv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k_new = rms_norm(k_new, params["k_norm"])
    posb = jnp.full((b, 1), pos, jnp.int32)
    if cfg.mrope_sections:
        q = apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
        k_new = apply_mrope(k_new, positions3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, posb, cfg.rope_theta)
        k_new = apply_rope(k_new, posb, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k_new.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v_new.astype(cache_v.dtype), (0, pos, 0, 0))
    scores = _gqa_scores(q, cache_k.astype(x.dtype), cfg)
    t = cache_k.shape[1]
    valid = (jnp.arange(t) <= pos)[None, None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = _gqa_out(probs, cache_v.astype(x.dtype))
    return out @ params["wo"].astype(x.dtype), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA: multi-head latent attention (DeepSeek-V2).  The KV cache stores only
# the compressed c_kv (kv_lora_rank) + the shared RoPE key (qk_rope_dim).
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig) -> Dict:
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h * (dn + dr))),
        "wdkv": dense_init(ks[1], (d, r)),
        "wkpe": dense_init(ks[2], (d, dr)),
        "wuk": dense_init(ks[3], (r, h * dn)),
        "wuv": dense_init(ks[4], (r, h * dv)),
        "wo": dense_init(ks[5], (h * dv, d)),
        "ckv_norm": init_rms(r),
    }


def mla_attention(params: Dict, x, cfg: ModelConfig, positions):
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, h, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    c_kv = rms_norm(x @ params["wdkv"].astype(x.dtype), params["ckv_norm"])
    k_pe = apply_rope((x @ params["wkpe"].astype(x.dtype))[:, :, None, :],
                      positions, cfg.rope_theta)          # (B,S,1,dr)
    k_nope = (c_kv @ params["wuk"].astype(x.dtype)).reshape(b, s, h, dn)
    v = (c_kv @ params["wuv"].astype(x.dtype)).reshape(b, s, h, dv)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (b, s, h, dr))], -1)
    q_full = jnp.concatenate([q_nope, q_pe], -1)
    scores = jnp.einsum("bshd,bthd->bhst", q_full, k) / np.sqrt(dn + dr)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(b, s, h * dv)
    return out @ params["wo"].astype(x.dtype), (c_kv, k_pe[:, :, 0, :])


def mla_decode(params: Dict, x, cfg: ModelConfig, cache_ckv, cache_kpe, pos,
               absorbed: bool = True):
    """MLA decode against the compressed cache.

    absorbed=True uses the W_uk-absorbed query trick (beyond-paper perf
    iteration: attention runs in the rank-r latent space, avoiding the
    per-step re-expansion of K from the whole cache).
    """
    b, _, d = x.shape
    h = cfg.n_heads
    dn, dr, dv, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, 1, h, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    posb = jnp.full((b, 1), pos, jnp.int32)
    q_pe = apply_rope(q_pe, posb, cfg.rope_theta)
    c_new = rms_norm(x @ params["wdkv"].astype(x.dtype), params["ckv_norm"])
    kpe_new = apply_rope((x @ params["wkpe"].astype(x.dtype))[:, :, None, :],
                         posb, cfg.rope_theta)[:, :, 0, :]
    cache_ckv = jax.lax.dynamic_update_slice(
        cache_ckv, c_new.astype(cache_ckv.dtype), (0, pos, 0))
    cache_kpe = jax.lax.dynamic_update_slice(
        cache_kpe, kpe_new.astype(cache_kpe.dtype), (0, pos, 0))
    t = cache_ckv.shape[1]
    ckv = cache_ckv.astype(x.dtype)
    if absorbed:
        # q_abs = q_nope @ W_uk^T per head: (B,1,H,r)
        wuk = params["wuk"].astype(x.dtype).reshape(r, h, dn)
        q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, wuk)
        s_nope = jnp.einsum("bshr,btr->bhst", q_abs, ckv)
    else:
        k_nope = (ckv @ params["wuk"].astype(x.dtype)).reshape(b, t, h, dn)
        s_nope = jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
    s_pe = jnp.einsum("bshd,btd->bhst", q_pe, cache_kpe.astype(x.dtype))
    scores = (s_nope + s_pe) / np.sqrt(dn + dr)
    valid = (jnp.arange(t) <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
    if absorbed:
        # out latent = probs @ ckv, then expand through W_uv per head
        lat = jnp.einsum("bhst,btr->bshr", probs, ckv)
        wuv = params["wuv"].astype(x.dtype).reshape(r, h, dv)
        out = jnp.einsum("bshr,rhd->bshd", lat, wuv).reshape(b, 1, h * dv)
    else:
        v = (ckv @ params["wuv"].astype(x.dtype)).reshape(b, t, h, dv)
        out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(b, 1, h * dv)
    return out @ params["wo"].astype(x.dtype), cache_ckv, cache_kpe
