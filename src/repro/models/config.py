"""Model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # -- attention options ---------------------------------------------------
    act: str = "silu"            # silu | gelu | relu
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE half-dim split
    causal: bool = True

    # -- MoE -------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense: int = 0         # leading dense layers before MoE layers
    capacity_factor: float = 1.25

    # -- MLA (deepseek-v2) ------------------------------------------------------
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # -- SSM (mamba2) -------------------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1
    attn_every: int = 0          # hybrid: shared attn block period (zamba2)

    # -- encoder-decoder ----------------------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0
    frontend: str = "none"       # none | audio | vision (stub: embeddings in)

    # -- training -------------------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save matmul outputs)
    seq_shard_carry: bool = False  # Megatron-SP: S-shard the residual carry
    # blockwise-attention sharding anchor: "auto" applies it when the kv
    # dim divides the model axis (always a win); "on" forces it even when
    # that means replicating heads once per layer (wins for wide archs
    # like starcoder2 where SPMD otherwise re-gathers inside the kv loop;
    # loses for small archs -- EXPERIMENTS.md §Perf); "off" disables.
    blockwise_anchor: str = "auto"
    scan_layers: bool = True     # False: unroll blocks (costmodel validation)
    tie_embeddings: bool = True

    # -- distribution hints (overridable by the launcher) -----------------------------
    fsdp: bool = False           # shard weights over the data axis too

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            per = (d * (2 * self.d_inner + 2 * self.ssm_groups * self.ssm_state
                        + self.ssm_nheads)
                   + self.d_inner * d + 2 * d)
            return emb + self.n_layers * per
        if self.mla:
            attn = (d * (self.n_heads * (self.qk_nope_dim + self.qk_rope_dim))
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        else:
            attn = (d * self.n_heads * self.head_dim
                    + 2 * d * self.n_kv_heads * self.head_dim
                    + self.n_heads * self.head_dim * d)
        dense_ff = 3 * d * self.d_ff
        if self.is_moe:
            moe_ff = (self.n_experts + self.n_shared_experts) * 3 * d * self.moe_d_ff
            n_moe = self.n_layers - self.first_dense
            ff_total = self.first_dense * dense_ff + n_moe * moe_ff
            router = n_moe * d * self.n_experts
        else:
            layers = (self.enc_layers + self.dec_layers
                      if self.family == "encdec" else self.n_layers)
            ff_total = layers * dense_ff
            router = 0
        layers = (self.enc_layers + self.dec_layers
                  if self.family == "encdec" else self.n_layers)
        cross = layers // 2 * attn if self.family == "encdec" else 0
        if self.family == "hybrid":
            per_ssm = (d * (2 * self.d_inner + 2 * self.ssm_groups * self.ssm_state
                            + self.ssm_nheads) + self.d_inner * d)
            shared = attn + dense_ff
            return emb + self.n_layers * per_ssm + shared
        return emb + layers * (attn + 2 * d) + ff_total + router + cross

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top_k experts count)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_ff = (self.n_layers - self.first_dense) * self.n_experts * 3 * d * self.moe_d_ff
        act_ff = ((self.n_layers - self.first_dense)
                  * (self.top_k + self.n_shared_experts) * 3 * d * self.moe_d_ff)
        return full - all_ff + act_ff
