"""Mixture-of-experts with sorted capacity dispatch.

Tokens-choose-experts top-k routing; assignments are sorted by expert and
scattered into an (E, C, d) buffer, so expert FFN compute scales with
top_k (not E) and the buffer's expert axis shards cleanly over the mesh
'model' axis.  Overflow beyond capacity is dropped (standard;
capacity_factor controls head-room).

Distribution (GShard/Switch pattern): the token->slot gather/scatter has
data-dependent indices, so under plain SPMD it crosses the data axis and
XLA materializes an all-reduce of the full (n*k, d) dispatch tensor PER
LAYER (measured 5.2e10 B/layer on deepseek-v2-lite -- EXPERIMENTS.md
§Perf iter 2).  The fix is per-shard dispatch: a shard_map over the batch
axes routes each data shard's tokens into its own capacity slice
(C_local = C / n_shards), keeping every gather/scatter local; the only
cross-device movement left is the (E, C, d) buffer's expert all-to-all,
which is the irreducible MoE traffic.  Outside a configured mesh (unit
tests, 1 device) the unsharded path runs unchanged.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import hints
from repro.models.config import ModelConfig
from repro.models.layers import activation, dense_init

# jax.shard_map is top-level only from jax 0.5; fall back to experimental
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map


def init_moe(key, cfg: ModelConfig) -> Dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e)),
        "wi": dense_init(ks[1], (e, d, f), in_axis=1),
        "wg": dense_init(ks[2], (e, d, f), in_axis=1),
        "wo": dense_init(ks[3], (e, f, d), in_axis=1),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        km = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": dense_init(km[0], (d, fs)),
            "wg": dense_init(km[1], (d, fs)),
            "wo": dense_init(km[2], (fs, d)),
        }
    return p


def _route_and_dispatch(xf, router_w, e: int, k: int, cap: int):
    """Route xf (n, d) -> dispatch buffer (e, cap, d) + combine metadata.

    Pure function of LOCAL data; called once globally (fallback) or once
    per data shard inside shard_map (distributed path).
    """
    n, d = xf.shape
    logits = (xf @ router_w.astype(xf.dtype)).astype(jnp.float32)
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)   # (n,k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    flat_e = idx.reshape(-1)                                   # (n*k,)
    flat_t = jnp.repeat(jnp.arange(n), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    ar = jnp.arange(n * k)
    seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos_in_e = ar - seg_start[se]
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)       # overflow slot

    buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].set(
        xf[st], mode="drop")
    buf = buf[:-1].reshape(e, cap, d)
    return buf, (st, sg, keep, slot)


def _combine(out_e, meta, n: int, cap: int, dtype):
    """Inverse of dispatch: (e, cap, d) expert outputs -> (n, d) tokens."""
    st, sg, keep, slot = meta
    e_cap = out_e.shape[0] * cap
    out_flat = out_e.reshape(e_cap, -1)
    contrib = jnp.where(keep[:, None],
                        out_flat[jnp.clip(slot, 0, e_cap - 1)]
                        * sg[:, None].astype(dtype), 0)
    return jnp.zeros((n, out_flat.shape[-1]), dtype).at[st].add(contrib)


def moe_ffn(params: Dict, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = b * s
    cap = int(cfg.capacity_factor * n * k / e)
    cap = max(8, min(cap, n))
    act = activation(cfg.act)
    xf = x.reshape(n, d)

    mesh = hints.mesh()
    bax = hints.batch_axis_names()
    nshard = hints.axis_size("BATCH")
    use_shard_map = (mesh is not None and bax and nshard > 1
                     and n % nshard == 0 and (n // nshard) >= k)

    if use_shard_map:
        cap_loc = max(8, cap // nshard)
        n_loc = n // nshard

        def dispatch_shard(xf_l, rw):
            buf_l, (st, sg, keep, slot) = _route_and_dispatch(
                xf_l, rw, e, k, cap_loc)
            return buf_l, st, sg, keep, slot

        buf, st, sg, keep, slot = _shard_map(
            dispatch_shard, mesh=mesh,
            in_specs=(P(bax), P()),
            out_specs=(P(None, bax), P(bax), P(bax), P(bax), P(bax)),
        )(xf, params["router"])
        # buf: logical (e, nshard*cap_loc, d), capacity data-sharded.
        # Re-shard the expert axis onto 'model' => XLA's all-to-all, the
        # irreducible expert-parallel traffic.
        ep = e % hints.axis_size("MODEL") == 0
        e_ax = "MODEL" if ep else None
        c_ax = "BATCH"
        f_ax = None if ep else "MODEL"
        buf = hints.constrain(buf, (e_ax, c_ax, None))
        h = act(jnp.einsum("ecd,edf->ecf", buf, params["wg"].astype(x.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, params["wi"].astype(x.dtype))
        h = hints.constrain(h, (e_ax, c_ax, f_ax))
        out_e = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(x.dtype))
        out_e = hints.constrain(out_e, (e_ax, c_ax, None))

        model_ax = hints._AXES["model"]
        msize = hints.axis_size("MODEL")
        if ep and model_ax:
            # Combine WITHOUT replicating the expert axis: each model
            # shard combines its e_loc experts' outputs into a partial
            # (n_loc, d) and psums over 'model' -- wire bytes n_loc*d vs
            # e*cap_loc*d for the all-gather alternative (~9x less at
            # top-6; EXPERIMENTS.md §Perf iter 3).
            e_loc = e // msize
            span = e_loc * cap_loc

            def combine_shard(out_l, st_l, sg_l, keep_l, slot_l):
                m_idx = jax.lax.axis_index(model_ax)
                base = m_idx * span
                mine = keep_l & (slot_l >= base) & (slot_l < base + span)
                out_flat = out_l.reshape(span, d)
                contrib = jnp.where(
                    mine[:, None],
                    out_flat[jnp.clip(slot_l - base, 0, span - 1)]
                    * sg_l[:, None].astype(x.dtype), 0)
                y_l = jnp.zeros((n_loc, d), x.dtype).at[st_l].add(contrib)
                return jax.lax.psum(y_l, model_ax)

            y = _shard_map(
                combine_shard, mesh=mesh,
                in_specs=(P(model_ax, bax, None), P(bax), P(bax), P(bax),
                          P(bax)),
                out_specs=P(bax),
            )(out_e, st, sg, keep, slot)
        else:
            def combine_shard(out_l, st_l, sg_l, keep_l, slot_l):
                return _combine(out_l, (st_l, sg_l, keep_l, slot_l), n_loc,
                                cap_loc, x.dtype)

            y = _shard_map(
                combine_shard, mesh=mesh,
                in_specs=(P(None, bax), P(bax), P(bax), P(bax), P(bax)),
                out_specs=P(bax),
            )(out_e, st, sg, keep, slot)
        y = hints.constrain(y, ("BATCH", None))
    else:
        buf, meta = _route_and_dispatch(xf, params["router"], e, k, cap)
        # expert-parallel layout: E over 'model' when divisible, else
        # capacity over batch axes + FFN hidden over 'model' (TP experts)
        ep = e % hints.axis_size("MODEL") == 0
        e_ax = "MODEL" if ep else None
        c_ax = None if ep else "BATCH"
        f_ax = None if ep else "MODEL"
        buf = hints.constrain(buf, (e_ax, c_ax, None))
        h = act(jnp.einsum("ecd,edf->ecf", buf, params["wg"].astype(x.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, params["wi"].astype(x.dtype))
        h = hints.constrain(h, (e_ax, c_ax, f_ax))
        out_e = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(x.dtype))
        out_e = hints.constrain(out_e, (e_ax, c_ax, None))
        y = _combine(out_e, meta, n, cap, x.dtype)
        y = hints.constrain(y, ("BATCH", None))

    if cfg.n_shared_experts:
        sh = params["shared"]
        hs = act(xf @ sh["wg"].astype(x.dtype)) * (xf @ sh["wi"].astype(x.dtype))
        y = y + hs @ sh["wo"].astype(x.dtype)
    return y.reshape(b, s, d)
