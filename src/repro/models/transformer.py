"""Model assembly for all assigned architectures.

Functional style (param pytrees + pure functions).  Uniform layer stacks
are scanned (jax.lax.scan over stacked params) so the compiled HLO holds
one layer body regardless of depth -- essential for the 512-device
dry-run compile times and the standard production pattern (MaxText).

Exposes, per model: init / loss / prefill / decode_step, plus cache
constructors. The launcher (repro.launch) wraps these into pjit'd
train/serve steps with sharding rules.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.blockwise import blockwise_attention
from repro.models.layers import (apply_rope, cross_entropy, dense_init,
                                 init_mlp, init_rms, mlp, rms_norm)

DENSE_ATTN_MAX_SEQ = 2048     # above this, use blockwise attention


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_dense_block(key, cfg: ModelConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": init_rms(cfg.d_model),
        "ln2": init_rms(cfg.d_model),
        "attn": (attn_mod.init_mla(k1, cfg) if cfg.mla
                 else attn_mod.init_attention(k1, cfg)),
    }
    if cfg.is_moe:
        p["moe"] = moe_mod.init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff)
    return p


def _init_cross_block(key, cfg: ModelConfig) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rms(cfg.d_model), "ln2": init_rms(cfg.d_model),
        "ln3": init_rms(cfg.d_model),
        "attn": attn_mod.init_attention(k1, cfg),
        "cross": attn_mod.init_attention(k2, cfg),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def _stack(keys, fn):
    ps = [fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def init_params(cfg: ModelConfig, rng) -> Dict:
    d, v = cfg.d_model, cfg.vocab
    ks = jax.random.split(rng, 8)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (v, d), jnp.float32) * 0.02,
        "final_norm": init_rms(d),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[1], (d, v))

    if cfg.family == "ssm":
        params["blocks"] = _stack(
            jax.random.split(ks[2], cfg.n_layers),
            lambda k: {"ln": init_rms(d), "ssm": ssm_mod.init_ssm(k, cfg)})
    elif cfg.family == "hybrid":
        params["blocks"] = _stack(
            jax.random.split(ks[2], cfg.n_layers),
            lambda k: {"ln": init_rms(d), "ssm": ssm_mod.init_ssm(k, cfg)})
        params["shared_attn"] = _init_dense_block(ks[3], cfg)
    elif cfg.family == "encdec":
        params["enc"] = _stack(jax.random.split(ks[2], cfg.enc_layers),
                               lambda k: _init_dense_block(k, cfg))
        params["dec"] = _stack(jax.random.split(ks[3], cfg.dec_layers),
                               lambda k: _init_cross_block(k, cfg))
    else:   # dense / moe / vlm
        n_moe = cfg.n_layers - cfg.first_dense
        if cfg.first_dense:
            params["dense_blocks"] = _stack(
                jax.random.split(ks[4], cfg.first_dense),
                lambda k: _init_dense_block(
                    k, dataclasses.replace(cfg, n_experts=0)))
        params["blocks"] = _stack(jax.random.split(ks[2], n_moe),
                                  lambda k: _init_dense_block(k, cfg))
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _attention_any(params, x, cfg: ModelConfig, positions, positions3):
    s = x.shape[1]
    if cfg.mla:
        out, kvc = attn_mod.mla_attention(params, x, cfg, positions)
        return out, kvc
    if s > DENSE_ATTN_MAX_SEQ and not cfg.mrope_sections:
        # blockwise path (rope applied inside attention helper below)
        b, _, d = x.shape
        h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, h, dh)
        k = (x @ params["wk"].astype(x.dtype)).reshape(b, s, kv, dh)
        v = (x @ params["wv"].astype(x.dtype)).reshape(b, s, kv, dh)
        if cfg.qk_norm:
            q = rms_norm(q, params["q_norm"])
            k = rms_norm(k, params["k_norm"])
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        out = blockwise_attention(q, k, v, causal=cfg.causal,
                                  anchor=cfg.blockwise_anchor)
        out = out.reshape(b, s, h * dh) @ params["wo"].astype(x.dtype)
        return out, (k, v)
    out, kvc = attn_mod.attention(params, x, cfg, positions,
                                  positions3=positions3)
    return out, kvc


def _dense_block_fwd(blk, x, cfg: ModelConfig, positions, positions3=None,
                     collect_cache: bool = False):
    from repro.distributed import hints
    if cfg.seq_shard_carry:
        # Megatron-SP: the residual carry lives S-sharded over 'model'
        # (the scan carry + remat-saved input shrink 16x on the 16x16
        # mesh); gather S here, re-shard at block exit.
        x = hints.constrain(x, ("BATCH", None, None))
    h, kvc = _attention_any(blk["attn"], rms_norm(x, blk["ln1"]), cfg,
                            positions, positions3)
    x = x + h
    y = rms_norm(x, blk["ln2"])
    if cfg.is_moe and "moe" in blk:
        x = x + moe_mod.moe_ffn(blk["moe"], y, cfg)
    else:
        x = x + mlp(blk["mlp"], y, cfg.act)
    if cfg.seq_shard_carry:
        x = hints.constrain(x, ("BATCH", "MODEL", None))
    return (x, kvc) if collect_cache else (x, None)


def _scan_blocks(blocks, x, fwd, remat: bool, collect=False,
                 scan: bool = True, remat_policy: str = "full"):
    body = fwd
    if remat:
        if remat_policy == "dots":
            # Save matmul outputs: the bwd pass recomputes only cheap
            # elementwise work, so the fwd TP collectives (which sit
            # downstream of dots) are NOT replayed in the bwd body.
            body = jax.checkpoint(
                fwd,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            body = jax.checkpoint(fwd)

    def step(carry, blk):
        out, cache = body(blk, carry)
        return out, cache

    if scan:
        return jax.lax.scan(step, x, blocks)
    # Unrolled python loop: same math, every layer its own HLO.  Used to
    # validate the analytic cost model (XLA's HloCostAnalysis counts a
    # while body once, so scanned flops under-report by ~1/L; an unrolled
    # compile of a reduced config is the ground truth it is checked
    # against) and available to the perf loop for overlap experiments.
    n = jax.tree.leaves(blocks)[0].shape[0]
    stashes = []
    for i in range(n):
        blk = jax.tree.map(lambda a: a[i], blocks)
        x, stash = step(x, blk)
        stashes.append(stash)
    if stashes and stashes[0] is not None:
        stashes = jax.tree.map(lambda *xs: jnp.stack(xs), *stashes)
    else:
        stashes = None
    return x, stashes


def forward(cfg: ModelConfig, params: Dict, batch: Dict,
            collect_cache: bool = False, head_last_only: bool = False):
    """Returns (logits, caches) for LM-style models (incl. vlm/ssm/hybrid).

    head_last_only: compute logits only for the final position (prefill
    serving path -- avoids the full (B,S,V) logit tensor)."""
    if cfg.family == "encdec":
        return _forward_encdec(cfg, params, batch, collect_cache,
                               head_last_only)
    if "embeds" in batch:
        x = batch["embeds"].astype(_dt(cfg))
        b, s = x.shape[:2]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = params["embed"].astype(_dt(cfg))[tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    positions3 = batch.get("positions3")

    caches = None
    if cfg.family in ("ssm", "hybrid"):
        def blk_fwd(blk, carry):
            y, st = ssm_mod.ssd_scan(blk["ssm"], rms_norm(carry, blk["ln"]), cfg)
            out = carry + y
            return out, (st if collect_cache else None)

        if cfg.family == "ssm":
            x, caches = _scan_blocks(params["blocks"], x, blk_fwd, cfg.remat,
                                     collect_cache, scan=cfg.scan_layers,
                                     remat_policy=cfg.remat_policy)
        else:
            # zamba2-style: shared attention block every cfg.attn_every layers
            per = cfg.attn_every
            n_groups = cfg.n_layers // per
            cache_list = []
            shared = params["shared_attn"]
            shared_fwd = functools.partial(_dense_block_fwd, cfg=cfg,
                                           positions=positions,
                                           collect_cache=collect_cache)
            if cfg.remat:
                # the shared blocks run OUTSIDE the scanned stacks, so
                # without this they save every intermediate for bwd
                # (zamba2 train_4k: 9 un-remat'd attention blocks)
                shared_fwd = jax.checkpoint(shared_fwd)
            for gidx in range(n_groups):
                hshared, kvc = shared_fwd(shared, x)
                x = hshared[0] if isinstance(hshared, tuple) else hshared
                if collect_cache:
                    x, kvc = hshared if isinstance(hshared, tuple) else (hshared, None)
                grp = jax.tree.map(lambda p: p[gidx * per:(gidx + 1) * per],
                                   params["blocks"])
                x, st = _scan_blocks(grp, x, blk_fwd, cfg.remat, collect_cache,
                                     scan=cfg.scan_layers,
                                     remat_policy=cfg.remat_policy)
                cache_list.append((kvc, st))
            caches = cache_list if collect_cache else None
    else:
        def blk_fwd(blk, carry):
            out, kvc = _dense_block_fwd(blk, carry, cfg, positions,
                                        positions3, collect_cache)
            return out, kvc

        if cfg.first_dense:
            x, c0 = _scan_blocks(params["dense_blocks"], x, blk_fwd,
                                 cfg.remat, collect_cache,
                                 scan=cfg.scan_layers,
                                 remat_policy=cfg.remat_policy)
        x, caches = _scan_blocks(params["blocks"], x, blk_fwd, cfg.remat,
                                 collect_cache, scan=cfg.scan_layers,
                                     remat_policy=cfg.remat_policy)

    if head_last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = x @ head.astype(x.dtype)
    return logits, caches


def _forward_encdec(cfg: ModelConfig, params: Dict, batch: Dict,
                    collect_cache: bool, head_last_only: bool = False):
    enc_x = batch["frames"].astype(_dt(cfg))
    b, t_src = enc_x.shape[:2]
    enc_pos = jnp.broadcast_to(jnp.arange(t_src, dtype=jnp.int32)[None],
                               (b, t_src))
    enc_cfg = dataclasses.replace(cfg, causal=False)

    def enc_fwd(blk, carry):
        out, _ = _dense_block_fwd(blk, carry, enc_cfg, enc_pos)
        return out, None

    enc_out, _ = _scan_blocks(params["enc"], enc_x, enc_fwd, cfg.remat)

    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = params["embed"].astype(_dt(cfg))[tokens]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def dec_fwd(blk, carry):
        h, kvc = attn_mod.attention(blk["attn"], rms_norm(carry, blk["ln1"]),
                                    cfg, pos)
        carry = carry + h
        # cross attention over encoder output
        y = rms_norm(carry, blk["ln2"])
        kv = cfg.n_kv_heads
        dh = cfg.head_dim
        k = (enc_out @ blk["cross"]["wk"].astype(carry.dtype)).reshape(
            b, t_src, kv, dh)
        v = (enc_out @ blk["cross"]["wv"].astype(carry.dtype)).reshape(
            b, t_src, kv, dh)
        h2, _ = attn_mod.attention(blk["cross"], y, enc_cfg, pos,
                                   kv_override=(k, v))
        carry = carry + h2
        carry = carry + mlp(blk["mlp"], rms_norm(carry, blk["ln3"]), cfg.act)
        return carry, (kvc if collect_cache else None)

    x, caches = _scan_blocks(params["dec"], x, dec_fwd, cfg.remat,
                             collect_cache)
    if head_last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    return x @ head.astype(x.dtype), (enc_out, caches)


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict):
    logits, _ = forward(cfg, params, batch)
    return cross_entropy(logits, batch["labels"], cfg.vocab)


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Serving: cache init + decode step
# ---------------------------------------------------------------------------

def make_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    dt = _dt(cfg)
    if cfg.family == "ssm":
        return {
            "state": jnp.zeros((cfg.n_layers, batch, cfg.ssm_nheads,
                                cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1,
                               cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state),
                              dt),
        }
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        return {
            "state": jnp.zeros((cfg.n_layers, batch, cfg.ssm_nheads,
                                cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1,
                               cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state),
                              dt),
            "k": jnp.zeros((n_groups, batch, max_seq, cfg.n_kv_heads,
                            cfg.head_dim), dt),
            "v": jnp.zeros((n_groups, batch, max_seq, cfg.n_kv_heads,
                            cfg.head_dim), dt),
        }
    if cfg.mla:
        n = cfg.n_layers
        return {
            "ckv": jnp.zeros((n, batch, max_seq, cfg.kv_lora_rank), dt),
            "kpe": jnp.zeros((n, batch, max_seq, cfg.qk_rope_dim), dt),
        }
    n = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
    cache = {
        "k": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dt),
    }
    if cfg.family == "encdec":
        # cross-attention K/V precomputed at prefill over source frames
        cache["xk"] = jnp.zeros((n, batch, max_seq, cfg.n_kv_heads,
                                 cfg.head_dim), dt)
        cache["xv"] = jnp.zeros((n, batch, max_seq, cfg.n_kv_heads,
                                 cfg.head_dim), dt)
    return cache


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, token, pos,
                positions3=None):
    """One decode step. token: (B,) int32 (or embeds (B,1,d) for vlm);
    pos: scalar int32. Returns (logits (B,V), new_cache)."""
    dt = _dt(cfg)
    if cfg.family in ("ssm", "hybrid"):
        return _decode_ssm(cfg, params, cache, token, pos)
    if token.ndim == 1:
        x = params["embed"].astype(dt)[token][:, None, :]
    else:
        x = token.astype(dt)
    b = x.shape[0]

    if cfg.family == "encdec":
        return _decode_encdec(cfg, params, cache, x, pos)

    if cfg.mla:
        def step(carry, inp):
            blk, ckv, kpe = inp
            h, ckv, kpe = attn_mod.mla_decode(blk["attn"],
                                              rms_norm(carry, blk["ln1"]),
                                              cfg, ckv, kpe, pos)
            carry = carry + h
            y = rms_norm(carry, blk["ln2"])
            if cfg.is_moe and "moe" in blk:
                carry = carry + moe_mod.moe_ffn(blk["moe"], y, cfg)
            else:
                carry = carry + mlp(blk["mlp"], y, cfg.act)
            return carry, (ckv, kpe)

        blocks = params["blocks"]
        if cfg.first_dense:
            nd = cfg.first_dense
            x, (c0, p0) = jax.lax.scan(
                step, x, (params["dense_blocks"], cache["ckv"][:nd],
                          cache["kpe"][:nd]))
            x, (c1, p1) = jax.lax.scan(
                step, x, (blocks, cache["ckv"][nd:], cache["kpe"][nd:]))
            new_cache = {"ckv": jnp.concatenate([c0, c1]),
                         "kpe": jnp.concatenate([p0, p1])}
        else:
            x, (c1, p1) = jax.lax.scan(step, x, (blocks, cache["ckv"],
                                                 cache["kpe"]))
            new_cache = {"ckv": c1, "kpe": p1}
    else:
        def step(carry, inp):
            blk, ck, cv = inp
            h, ck, cv = attn_mod.decode_attention(
                blk["attn"], rms_norm(carry, blk["ln1"]), cfg, ck, cv, pos,
                positions3)
            carry = carry + h
            y = rms_norm(carry, blk["ln2"])
            if cfg.is_moe and "moe" in blk:
                carry = carry + moe_mod.moe_ffn(blk["moe"], y, cfg)
            else:
                carry = carry + mlp(blk["mlp"], y, cfg.act)
            return carry, (ck, cv)

        x, (nk, nv) = jax.lax.scan(step, x, (params["blocks"], cache["k"],
                                             cache["v"]))
        new_cache = {"k": nk, "v": nv}

    x = rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = (x @ head.astype(x.dtype))[:, 0]
    return logits, new_cache


def _decode_ssm(cfg: ModelConfig, params, cache, token, pos):
    dt = _dt(cfg)
    x = params["embed"].astype(dt)[token][:, None, :]

    def step(carry, inp):
        blk, st, cb = inp
        y, st, cb = ssm_mod.ssd_decode(blk["ssm"],
                                       rms_norm(carry, blk["ln"]), cfg, st, cb)
        return carry + y, (st, cb)

    if cfg.family == "ssm":
        x, (ns, ncv) = jax.lax.scan(step, x, (params["blocks"],
                                              cache["state"], cache["conv"]))
        new_cache = {"state": ns, "conv": ncv}
    else:
        per = cfg.attn_every
        n_groups = cfg.n_layers // per
        shared = params["shared_attn"]
        states, convs, ks, vs = [], [], [], []
        for gidx in range(n_groups):
            h, ck, cv = attn_mod.decode_attention(
                shared["attn"], rms_norm(x, shared["ln1"]), cfg,
                cache["k"][gidx], cache["v"][gidx], pos)
            x = x + h
            x = x + mlp(shared["mlp"], rms_norm(x, shared["ln2"]), cfg.act)
            ks.append(ck); vs.append(cv)
            grp = jax.tree.map(lambda p: p[gidx * per:(gidx + 1) * per],
                               params["blocks"])
            x, (st, cb) = jax.lax.scan(
                step, x, (grp, cache["state"][gidx * per:(gidx + 1) * per],
                          cache["conv"][gidx * per:(gidx + 1) * per]))
            states.append(st); convs.append(cb)
        new_cache = {"state": jnp.concatenate(states),
                     "conv": jnp.concatenate(convs),
                     "k": jnp.stack(ks), "v": jnp.stack(vs)}
    x = rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    return (x @ head.astype(x.dtype))[:, 0], new_cache


def _decode_encdec(cfg: ModelConfig, params, cache, x, pos):
    b = x.shape[0]

    def step(carry, inp):
        blk, ck, cv, xk, xv = inp
        h, ck, cv = attn_mod.decode_attention(
            blk["attn"], rms_norm(carry, blk["ln1"]), cfg, ck, cv, pos)
        carry = carry + h
        y = rms_norm(carry, blk["ln2"])
        q_cfg = dataclasses.replace(cfg, causal=False)
        h2, _ = attn_mod.attention(blk["cross"], y, q_cfg, None,
                                   kv_override=(xk.astype(carry.dtype),
                                                xv.astype(carry.dtype)))
        carry = carry + h2
        carry = carry + mlp(blk["mlp"], rms_norm(carry, blk["ln3"]), cfg.act)
        return carry, (ck, cv)

    x, (nk, nv) = jax.lax.scan(step, x, (params["dec"], cache["k"], cache["v"],
                                         cache["xk"], cache["xv"]))
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = nk, nv
    x = rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    return (x @ head.astype(x.dtype))[:, 0], new_cache
