"""Blockwise (flash-style) attention in pure JAX: online softmax over KV
chunks inside a q-chunk scan.  Required for the 32k prefill shapes, where
dense (S x T) score materialization is impossible; also the baseline the
Pallas attention kernel is validated against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import hints

NEG_INF = -1e9


def blockwise_attention(q, k, v, *, causal: bool, q_chunk: int = 1024,
                        kv_chunk: int = 1024, anchor: str = "auto"):
    """q: (B,S,H,D), k/v: (B,T,KV,D) grouped-query; returns (B,S,H,D)."""
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    nq, nk = s // q_chunk, t // kv_chunk
    assert s % q_chunk == 0 and t % kv_chunk == 0

    qg = q.reshape(b, nq, q_chunk, kv, g, d)
    kc = k.reshape(b, nk, kv_chunk, kv, d)
    vc = v.reshape(b, nk, kv_chunk, kv, d)
    # Anchor the kv-group axis to MODEL: SPMD loses the head sharding
    # across the reshape + scan boundary and re-gathers q/k/v inside the
    # kv-chunk loop otherwise -- per-layer wire bytes blow up ~10x
    # (EXPERIMENTS.md §Perf, deepseek-7b iter 1).  When kv does NOT
    # divide the model axis the constraint pins the head dims replicated
    # (one up-front gather per layer) -- a win for wide archs, a loss for
    # small ones, hence the per-arch "auto"/"on"/"off" policy.
    msize = hints.axis_size("MODEL")
    apply_anchor = (anchor == "on"
                    or (anchor == "auto" and msize > 1 and kv % msize == 0))
    if apply_anchor:
        qg = hints.constrain(qg, ("BATCH", None, None, "MODEL", None, None))
        kc = hints.constrain(kc, ("BATCH", None, None, "MODEL", None))
        vc = hints.constrain(vc, ("BATCH", None, None, "MODEL", None))
    scale = 1.0 / np.sqrt(d)

    def q_block(qi, q_blk):
        # online softmax state (sharded like the inputs: kv on MODEL)
        acc = jnp.zeros((b, kv, g, q_chunk, d), jnp.float32)
        m = jnp.full((b, kv, g, q_chunk), -jnp.inf, jnp.float32)
        l = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        if apply_anchor:
            acc = hints.constrain(acc, ("BATCH", "MODEL", None, None, None))
            m = hints.constrain(m, ("BATCH", "MODEL", None, None))
            l = hints.constrain(l, ("BATCH", "MODEL", None, None))

        def kv_block(carry, inp):
            acc, m, l = carry
            ki, k_blk, v_blk = inp
            scores = jnp.einsum("bskgd,btkd->bkgst", q_blk, k_blk) * scale
            scores = scores.astype(jnp.float32)
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= kpos[None, :]
                scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m - m_new)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p, v_blk.astype(jnp.float32))
            l = l * corr + jnp.sum(p, axis=-1)
            return (acc, m_new, l), None

        ks_idx = jnp.arange(nk)
        (acc, m, l), _ = jax.lax.scan(
            kv_block, (acc, m, l),
            (ks_idx, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        # emit bf16 immediately: halves the stacked q-block output buffers
        return jnp.moveaxis(out, 3, 1).astype(q.dtype)     # (b,qc,kv,g,d)

    idx = jnp.arange(nq)
    outs = jax.lax.map(lambda inp: q_block(inp[0], inp[1]),
                       (idx, jnp.moveaxis(qg, 1, 0)))
    if apply_anchor:
        outs = hints.constrain(outs,
                               (None, "BATCH", None, "MODEL", None, None))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, d)
    return out.astype(q.dtype)
