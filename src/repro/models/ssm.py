"""Mamba2 (state-space duality / SSD) blocks: chunked train scan + O(1) decode.

Follows the SSD algorithm of Dao & Gu (arXiv 2405.21060): within-chunk
"attention-like" diagonal blocks + inter-chunk recurrence on the
(H, P, N) state, all in exact einsum form.  Decode keeps a constant-size
recurrent state plus a depthwise-conv ring buffer.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, init_rms, rms_norm


def init_ssm(key, cfg: ModelConfig) -> Dict:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads
    conv_ch = di + 2 * g * n
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * g * n + h)),
        "conv": jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32) * 0.1,
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": init_rms(di),
        "out_proj": dense_init(ks[2], (di, d)),
    }


def _segsum(dA):
    """dA: (..., L) -> (..., L, L) with out[i,j] = sum_{j<k<=i} dA_k (i>=j)."""
    L = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _split_proj(params, x, cfg: ModelConfig):
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads
    proj = x @ params["in_proj"].astype(x.dtype)
    z = proj[..., :di]
    xbc = proj[..., di: di + di + 2 * g * n]
    dt = proj[..., -h:]
    return z, xbc, dt


def ssd_scan(params: Dict, x, cfg: ModelConfig,
             init_state=None, init_conv=None):
    """x: (B, T, d_model) with T % chunk == 0. Returns (y, final_state)."""
    b, t, _ = x.shape
    di = cfg.d_inner
    g, n, h, p = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    ck = min(cfg.ssm_chunk, t)
    assert t % ck == 0
    nc = t // ck

    z, xbc, dt = _split_proj(params, x, cfg)
    # causal depthwise conv over (x, B, C) channels
    kw = params["conv"].astype(x.dtype)
    pad = jnp.zeros((b, cfg.ssm_conv - 1, xbc.shape[-1]), x.dtype)
    if init_conv is not None:
        pad = init_conv.astype(x.dtype)
    xpad = jnp.concatenate([pad, xbc], axis=1)
    conv = sum(xpad[:, i: i + t] * kw[i][None, None]
               for i in range(cfg.ssm_conv))
    conv = jax.nn.silu(conv)
    new_conv = xpad[:, t:]                                  # ring buffer tail
    xs = conv[..., :di].reshape(b, t, h, p)
    bmat = conv[..., di: di + g * n].reshape(b, t, g, n)
    cmat = conv[..., di + g * n:].reshape(b, t, g, n)

    a = -jnp.exp(params["a_log"]).astype(jnp.float32)       # (h,)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None])   # (b,t,h)
    dA = dt * a[None, None]                                 # (b,t,h)

    # chunked views
    xc = xs.reshape(b, nc, ck, h, p)
    bc = jnp.repeat(bmat.reshape(b, nc, ck, g, n), h // g, axis=3)
    cc = jnp.repeat(cmat.reshape(b, nc, ck, g, n), h // g, axis=3)
    dtc = dt.reshape(b, nc, ck, h)
    dAc = dA.reshape(b, nc, ck, h)
    xdt = (xc * dtc[..., None]).astype(jnp.float32)

    # 1) within-chunk (diagonal blocks)
    lmat = jnp.exp(_segsum(jnp.moveaxis(dAc, -1, -2)))      # (b,nc,h,ck,ck)
    y_diag = jnp.einsum("bclhn,bchls,bcshn,bcshp->bclhp",
                        cc.astype(jnp.float32), lmat,
                        bc.astype(jnp.float32), xdt)

    # 2) per-chunk states
    cs = jnp.cumsum(dAc, axis=2)
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)           # (b,nc,ck,h)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn",
                        bc.astype(jnp.float32), decay_to_end, xdt)

    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(cs[:, :, -1, :])                  # (b,nc,h)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                                    # emit PREV state

    init = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (b,nc,h,p,n)

    # 4) contribution of carried-in state
    decay_from_start = jnp.exp(cs)                          # (b,nc,ck,h)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       cc.astype(jnp.float32), prev_states, decay_from_start)

    y = (y_diag + y_off).reshape(b, t, h, p)
    y = y + xs.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(b, t, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = y @ params["out_proj"].astype(x.dtype)
    return out, (final, new_conv)


def ssd_decode(params: Dict, x, cfg: ModelConfig, state, conv_buf):
    """Single-token step. x: (B,1,d); state (B,H,P,N); conv_buf (B,K-1,ch)."""
    b = x.shape[0]
    di = cfg.d_inner
    g, n, h, p = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    z, xbc, dt = _split_proj(params, x, cfg)
    kw = params["conv"].astype(x.dtype)
    window = jnp.concatenate([conv_buf.astype(x.dtype), xbc], axis=1)
    conv = jnp.einsum("bkc,kc->bc", window, kw)[:, None]
    conv = jax.nn.silu(conv)
    new_buf = window[:, 1:]
    xs = conv[..., :di].reshape(b, h, p)
    bmat = jnp.repeat(conv[..., di: di + g * n].reshape(b, g, n), h // g, 1)
    cmat = jnp.repeat(conv[..., di + g * n:].reshape(b, g, n), h // g, 1)

    a = -jnp.exp(params["a_log"]).astype(jnp.float32)
    dts = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"][None])
    dA = jnp.exp(dts * a[None])                             # (b,h)
    state = (state.astype(jnp.float32) * dA[:, :, None, None]
             + jnp.einsum("bh,bhn,bhp->bhpn", dts, bmat.astype(jnp.float32),
                          xs.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhpn->bhp", cmat.astype(jnp.float32), state)
    y = y + xs.astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    return y @ params["out_proj"].astype(x.dtype), state, new_buf
