"""Shared utilities: persistent XLA compilation cache, timers."""
from __future__ import annotations

import contextlib
import os
import time

_CACHE_DIR = os.environ.get("REPRO_JAX_CACHE", "/root/.cache/jaxcache")


def enable_compilation_cache() -> None:
    """Persist compiled executables across processes (tests, benchmarks)."""
    import jax

    os.makedirs(_CACHE_DIR, exist_ok=True)
    try:
        jax.config.update("jax_compilation_cache_dir", _CACHE_DIR)
        # 0.0: the proof pipeline is built from hundreds of SMALL programs
        # (per-round IPA/sumcheck shapes); at the default 0.5s threshold none
        # of them persist and every process pays ~35s of recompiles.
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except Exception:
        pass  # older jax without the knobs


@contextlib.contextmanager
def timer(label: str, sink: dict | None = None):
    t0 = time.perf_counter()
    yield
    dt = time.perf_counter() - t0
    if sink is not None:
        sink[label] = sink.get(label, 0.0) + dt
