"""Ambient sharding hints for intermediate activations.

Model code cannot know the mesh, but a handful of intermediates (MoE
dispatch buffers above all) MUST carry explicit constraints or XLA SPMD
replicates them (the grok-1 train cell goes from 375 GiB/device to fitting
once the (E, C, d) buffers are constrained).  The launcher calls
``set_axes`` before tracing; model code calls ``constrain`` with symbolic
axes ("BATCH" / "MODEL") that resolve against the ambient mesh, and the
call is a no-op outside a configured mesh (smoke tests, 1 device).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

_AXES: dict = {"batch": None, "model": None, "sizes": {}, "mesh": None}


def set_axes(batch_axes: Optional[Tuple[str, ...]], model_axis: Optional[str],
             sizes: Optional[dict] = None, mesh=None) -> None:
    _AXES["batch"] = tuple(batch_axes) if batch_axes else None
    _AXES["model"] = model_axis
    _AXES["sizes"] = dict(sizes or {})
    _AXES["mesh"] = mesh


def clear() -> None:
    set_axes(None, None, None, None)


def mesh():
    """The ambient device mesh (None outside a configured launch)."""
    return _AXES["mesh"]


def batch_axis_names() -> Optional[Tuple[str, ...]]:
    return _AXES["batch"]


def axis_size(which: str) -> int:
    if which == "BATCH":
        return max(1, int(_AXES["sizes"].get("batch", 1)))
    return max(1, int(_AXES["sizes"].get("model", 1)))


def constrain(x, spec: Sequence):
    """spec entries: "BATCH" | "MODEL" | None. Dims that do not divide the
    axis size fall back to None. No-op when no mesh is configured."""
    if _AXES["batch"] is None and _AXES["model"] is None:
        return x
    dims = []
    for i, s in enumerate(spec):
        if s == "BATCH" and _AXES["batch"]:
            dims.append(_AXES["batch"] if x.shape[i] % axis_size("BATCH") == 0
                        else None)
        elif s == "MODEL" and _AXES["model"]:
            dims.append(_AXES["model"] if x.shape[i] % axis_size("MODEL") == 0
                        else None)
        else:
            dims.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*dims))
    except (ValueError, RuntimeError):
        return x
