"""Sharding rules: map every parameter / batch / cache leaf to a
PartitionSpec over the production mesh.

Strategy (MaxText-style logical rules, resolved per tensor):
  * 'model' (TP): attention heads / FFN hidden / vocab / expert axis
  * 'data' (DP + optional FSDP): batch; weight fan-in dim when cfg.fsdp
  * 'pod' (multi-pod DP): outermost batch axis only -- gradient all-reduce
    crosses pods once per step, everything else stays intra-pod.

Every rule is guarded by divisibility; an indivisible dim falls back to
replication, so any (arch x mesh) pair lowers.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

STACKED_KEYS = ("blocks", "dense_blocks", "enc", "dec")
NORM_KEYS = ("ln", "ln1", "ln2", "ln3", "norm", "final_norm", "q_norm",
             "k_norm", "ckv_norm", "a_log", "d_skip", "dt_bias")


def _axsize(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fits(dim: int, mesh: Mesh, name) -> bool:
    if name is None:
        return True
    return dim % _axsize(mesh, name) == 0 and _axsize(mesh, name) > 1


def _ax(dim: int, mesh: Mesh, name):
    return name if _fits(dim, mesh, name) else None


def param_spec(cfg: ModelConfig, mesh: Mesh, path: Tuple[str, ...],
               shape: Tuple[int, ...]) -> P:
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1]
    fsdp = "data" if cfg.fsdp else None
    stacked = any(k in STACKED_KEYS for k in keys)
    off = 1 if stacked else 0
    dims: list = [None] * len(shape)

    def set_ax(i, ax_name):
        if 0 <= i < len(shape):
            dims[i] = _ax(shape[i], mesh, ax_name)

    if name in NORM_KEYS or len(shape) <= 1 + off:
        pass
    elif name == "embed":
        set_ax(0, "model"); set_ax(1, fsdp)
    elif name == "head":
        set_ax(0, fsdp); set_ax(1, "model")
    elif "moe" in keys and name in ("wi", "wg"):
        # (L, E, d, f)
        e_i, d_i, f_i = off, off + 1, off + 2
        if _fits(shape[e_i], mesh, "model"):
            set_ax(e_i, "model"); set_ax(d_i, fsdp)
        else:
            set_ax(d_i, fsdp); set_ax(f_i, "model")
    elif "moe" in keys and name == "wo":
        e_i, f_i, d_i = off, off + 1, off + 2
        if _fits(shape[e_i], mesh, "model"):
            set_ax(e_i, "model"); set_ax(d_i, fsdp)
        else:
            set_ax(f_i, "model"); set_ax(d_i, fsdp)
    elif name == "router":
        set_ax(off, fsdp)
    elif name in ("wq", "wk", "wv", "wi", "wg", "in_proj", "wuk", "wuv"):
        set_ax(off, fsdp); set_ax(off + 1, "model")
    elif name in ("wo", "out_proj"):
        set_ax(off, "model"); set_ax(off + 1, fsdp)
    elif name in ("wdkv", "wkpe"):
        set_ax(off, fsdp)
    elif name == "conv":
        set_ax(off + 1, "model")
    return P(*dims)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_tree):
    def assign(path, leaf):
        return NamedSharding(mesh, param_spec(cfg, mesh, path, leaf.shape))
    return jax.tree_util.tree_map_with_path(assign, params_tree)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def _batch_ax(mesh: Mesh, b: int):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return axes if (axes and b % size == 0) else None


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_tree):
    def assign(path, leaf):
        keys = [getattr(k, "key", str(k)) for k in path]
        name = keys[-1]
        if name == "positions3":             # (3, B, S)
            bax = _batch_ax(mesh, leaf.shape[1])
            spec = P(None, bax)
        else:                                # leading batch dim
            bax = _batch_ax(mesh, leaf.shape[0])
            if name in ("embeds", "frames") and len(leaf.shape) == 3:
                spec = P(bax, None, _ax(leaf.shape[2], mesh, "model"))
            else:
                spec = P(bax)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(assign, batch_tree)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_tree):
    def assign(path, leaf):
        keys = [getattr(k, "key", str(k)) for k in path]
        name = keys[-1]
        shape = leaf.shape
        if name in ("k", "v", "xk", "xv"):    # (L, B, S, KV, Dh)
            bax = _batch_ax(mesh, shape[1])
            kv_ax = _ax(shape[3], mesh, "model")
            dh_ax = None if kv_ax else _ax(shape[4], mesh, "model")
            return NamedSharding(mesh, P(None, bax, None, kv_ax, dh_ax))
        if name == "ckv":                     # (L, B, S, r)
            bax = _batch_ax(mesh, shape[1])
            return NamedSharding(mesh, P(None, bax, None,
                                         _ax(shape[3], mesh, "model")))
        if name == "kpe":                     # (L, B, S, dr)
            bax = _batch_ax(mesh, shape[1])
            return NamedSharding(mesh, P(None, bax, None, None))
        if name == "state":                   # (L, B, H, P, N)
            bax = _batch_ax(mesh, shape[1])
            return NamedSharding(mesh, P(None, bax,
                                         _ax(shape[2], mesh, "model"),
                                         None, None))
        if name == "conv":                    # (L, B, K, ch)
            bax = _batch_ax(mesh, shape[1])
            return NamedSharding(mesh, P(None, bax, None,
                                         _ax(shape[3], mesh, "model")))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(assign, cache_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
