"""The paper's own architecture: 16-layer FCNN, width 4096 (CIFAR-10
padded), 268M params, batch 128 -- the Section 5 experiment scale.
This config drives the zkDL verifiable-training path."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="fcnn-zkdl-16l", family="fcnn", n_layers=16, d_model=4096,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=0, head_dim=1,
        act="relu", remat=False, tie_embeddings=False)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="fcnn-smoke", family="fcnn", n_layers=3, d_model=16,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=0, head_dim=1,
        act="relu", remat=False, tie_embeddings=False)
