"""mamba2-2.7b: 64L d_model=2560 attn-free, vocab=50280, ssm_state=128.
SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
        head_dim=1, ssm_state=128, ssm_headdim=64, ssm_expand=2,
        ssm_chunk=256, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=128, head_dim=1,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=16,
        remat=False)
