"""Architecture registry: --arch <id> resolves through here."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCHS: List[str] = [
    "mamba2_2p7b",
    "qwen3_0p6b",
    "internlm2_1p8b",
    "starcoder2_15b",
    "deepseek_7b",
    "grok1_314b",
    "deepseek_v2_lite_16b",
    "zamba2_2p7b",
    "seamless_m4t_medium",
    "qwen2_vl_2b",
    "fcnn_zkdl_16l",          # the paper's own architecture
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    # exact ids from the assignment spec
    "mamba2-2.7b": "mamba2_2p7b",
    "qwen3-0.6b": "qwen3_0p6b",
    "internlm2-1.8b": "internlm2_1p8b",
    "starcoder2-15b": "starcoder2_15b",
    "deepseek-7b": "deepseek_7b",
    "grok-1-314b": "grok1_314b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "zamba2-2.7b": "zamba2_2p7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "fcnn-zkdl-16l": "fcnn_zkdl_16l",
})


def get_config(name: str, **overrides) -> ModelConfig:
    name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{name}")
    cfg = mod.get_config()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.smoke_config()
