"""internlm2-1.8b: 24L d_model=2048 16H GQA kv=8, d_ff=8192, vocab=92544
[arXiv:2403.17297]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b", family="dense", n_layers=24, d_model=2048,
        n_heads=16, n_kv_heads=8, d_ff=8192, vocab=92544,
        head_dim=128, rope_theta=1e6, tie_embeddings=False)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
        tie_embeddings=False, remat=False)
