"""qwen2-vl-2b: 28L d_model=1536 12H GQA kv=2, d_ff=8960, vocab=151936,
M-RoPE; vision frontend stubbed (precomputed patch embeddings)
[arXiv:2409.12191]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
        n_heads=12, n_kv_heads=2, d_ff=8960, vocab=151936,
        head_dim=128, mrope_sections=(16, 24, 24), rope_theta=1e6,
        frontend="vision", tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2vl-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
        mrope_sections=(4, 2, 2), frontend="vision", remat=False)
