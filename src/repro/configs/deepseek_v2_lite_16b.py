"""deepseek-v2-lite-16b: 27L d_model=2048 16H, MLA kv_lora=512,
MoE 64 routed top-6 + 2 shared, expert d_ff=1408, first layer dense
(d_ff=10944), vocab=102400 [arXiv:2405.04434]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", n_layers=27,
        d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
        vocab=102400, head_dim=128,
        mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
        n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
        first_dense=1, rope_theta=1e4, tie_embeddings=False, fsdp=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dsv2lite-smoke", family="moe", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, head_dim=16,
        mla=True, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, n_experts=4, top_k=2, n_shared_experts=1,
        moe_d_ff=32, first_dense=1, tie_embeddings=False, remat=False)
