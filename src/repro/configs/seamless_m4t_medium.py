"""seamless-m4t-medium: encoder-decoder 12L+12L d_model=1024 16H,
d_ff=4096, vocab=256206; speech frontend stubbed (precomputed frames)
[arXiv:2308.11596]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec", n_layers=24,
        enc_layers=12, dec_layers=12, d_model=1024, n_heads=16,
        n_kv_heads=16, d_ff=4096, vocab=256206, head_dim=64,
        frontend="audio", tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke", family="encdec", n_layers=4,
        enc_layers=2, dec_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, head_dim=16, frontend="audio", remat=False)
