"""qwen3-0.6b: 28L d_model=1024 16H GQA kv=8, d_ff=3072, vocab=151936,
qk_norm [hf:Qwen/Qwen3-8B family]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense", n_layers=28, d_model=1024,
        n_heads=16, n_kv_heads=8, d_ff=3072, vocab=151936,
        head_dim=128, qk_norm=True, rope_theta=1e6, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
        qk_norm=True, remat=False)
