"""zamba2-2.7b: 54 Mamba2 layers d_model=2560 (ssm_state=64) with a
SHARED attention+MLP block (32H, d_ff=10240) applied every 6 layers
[arXiv:2411.15242]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000,
        head_dim=80, ssm_state=64, ssm_headdim=64, ssm_expand=2,
        ssm_chunk=256, attn_every=6, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, head_dim=16,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=16,
        attn_every=2, remat=False)
