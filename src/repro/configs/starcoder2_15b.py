"""starcoder2-15b: 40L d_model=6144 48H GQA kv=4, d_ff=24576, vocab=49152,
RoPE [arXiv:2402.19173]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
        n_heads=48, n_kv_heads=4, d_ff=24576, vocab=49152,
        head_dim=128, act="gelu", rope_theta=1e5, tie_embeddings=True,
        fsdp=True,
        # kv=4 does not divide the 16-way model axis, but pinning the
        # heads replicated up-front still beats SPMD's in-loop re-gathers
        # at this width: 55.2 s -> 15.5 s collective (EXPERIMENTS.md §Perf)
        blockwise_anchor="on")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
        act="gelu", remat=False)
