"""grok-1-314b: 64L d_model=6144 48H GQA kv=8, MoE 8 experts top-2,
d_ff(expert)=32768, vocab=131072 [hf:xai-org/grok-1]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=32768, vocab=131072,
        head_dim=128, n_experts=8, top_k=2, moe_d_ff=32768,
        rope_theta=1e4, tie_embeddings=True, fsdp=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok1-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
        n_experts=4, top_k=2, moe_d_ff=128, remat=False)
