"""deepseek-7b: 30L d_model=4096 32H (kv=32, i.e. MHA), d_ff=11008,
vocab=102400, llama-arch [arXiv:2401.02954]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b", family="dense", n_layers=30, d_model=4096,
        n_heads=32, n_kv_heads=32, d_ff=11008, vocab=102400,
        head_dim=128, rope_theta=1e4, tie_embeddings=False, fsdp=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, head_dim=16,
        tie_embeddings=False, remat=False)
