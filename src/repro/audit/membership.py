"""Data-membership audits on the v3 proof format (Section 4.4).

The prover pipeline already commits every training sample: ``coms.x``
in each proof is the per-sample Pedersen commitment list, in step-major
order, absorbed into the transcript before any challenge is drawn.
This module binds those commitments into a sparse-Merkle dataset root
(`core.merkle`, Protocols 3/4) and answers the audit question

    "were these committed samples used in window W?"

from bytes alone — a ``DatasetBinding`` artifact, an auditor-held
``MembershipAudit``, and the window's ``proof_*.bin``; no session
state, no key derivation on the verifier side.

Binding layout (``dataset.bin``, magic ``ZKDB``):

    ZKDB | u16 version | str hash_name | u16 root_len | root
         | u32 n_windows | per window (ascending):
             u32 window | u64 sample_start | u32 sample_count
             | u8 digest_len | sha256(com_bytes window-concat)

The per-window digest is over the window's concatenated 8-byte-LE
commitment encodings (the exact scalar encoding of the proof format),
so a proof presented for window W must carry EXACTLY window W's sample
commitments — cross-window replay of an otherwise-honest proof fails
the digest check before any Merkle work happens.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import struct
import time
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core import merkle

BINDING_MAGIC = b"ZKDB"
BINDING_VERSION = 1
AUDIT_MAGIC = b"ZKDM"
AUDIT_VERSION = 1
DATASET_QUERY = 0xFFFFFFFF       # wire encoding of window=-1 (whole dataset)

BINDING_FILE = "dataset.bin"


class AuditDecodeError(ValueError):
    pass


def com_to_bytes(com: int) -> bytes:
    """Canonical commitment encoding: the proof format's 8-byte LE
    scalar (proofio writes every group element this way)."""
    return struct.pack("<Q", int(com))


def sample_coms(proof_bytes: bytes) -> List[int]:
    """The per-sample data commitments of a serialized proof, step-major
    (T*B entries) — decoded, not verified."""
    from repro.core.pipeline.proofio import decode_proof
    return [int(c) for c in decode_proof(proof_bytes).coms.x]


def commit_sample(pk, row, blind: int) -> int:
    """Commit one data row exactly as the session prover does (the
    per-sample ``kx`` basis) — how a data owner turns a raw sample into
    the commitment they can later audit for."""
    from repro.core import group, pedersen
    from repro.core.pipeline.tables import enc_tensor
    import numpy as np

    row = np.asarray(row, dtype=np.int64).reshape(-1)
    kx = pk.keys.kx
    assert row.shape[0] == kx.n, (row.shape[0], kx.n)
    return int(group.decode_group(pedersen.commit(kx, enc_tensor(row),
                                                  blind)))


# -- binding artifact -------------------------------------------------------

@dataclasses.dataclass
class WindowSpan:
    start: int                   # global sample index of the window's row 0
    count: int                   # T * batch
    digest: bytes                # sha256 over the window's com bytes


@dataclasses.dataclass
class DatasetBinding:
    hash_name: str
    root: bytes
    windows: Dict[int, WindowSpan]

    @property
    def n_samples(self) -> int:
        return sum(s.count for s in self.windows.values())

    def to_bytes(self) -> bytes:
        out = [BINDING_MAGIC, struct.pack("<H", BINDING_VERSION)]
        name = self.hash_name.encode()
        out.append(struct.pack("<H", len(name)) + name)
        out.append(struct.pack("<H", len(self.root)) + self.root)
        out.append(struct.pack("<I", len(self.windows)))
        for w in sorted(self.windows):
            s = self.windows[w]
            out.append(struct.pack("<IQIB", w, s.start, s.count,
                                   len(s.digest)) + s.digest)
        return b"".join(out)

    @classmethod
    def from_bytes(cls, data: bytes) -> "DatasetBinding":
        r = _Reader(data)
        if r.take(4) != BINDING_MAGIC:
            raise AuditDecodeError("bad magic (not a dataset binding)")
        ver = r.u16()
        if ver != BINDING_VERSION:
            raise AuditDecodeError(f"unsupported binding version {ver}")
        hash_name = r.take(r.u16()).decode()
        root = r.take(r.u16())
        windows: Dict[int, WindowSpan] = {}
        for _ in range(r.u32()):
            w, start, count, dlen = struct.unpack("<IQIB", r.take(17))
            windows[w] = WindowSpan(start=start, count=count,
                                    digest=r.take(dlen))
        if not r.done():
            raise AuditDecodeError("trailing bytes after binding")
        return cls(hash_name=hash_name, root=root, windows=windows)


class _Reader:
    def __init__(self, data: bytes):
        self.data, self.off = data, 0

    def take(self, n: int) -> bytes:
        if self.off + n > len(self.data):
            raise AuditDecodeError("truncated audit artifact")
        out = self.data[self.off:self.off + n]
        self.off += n
        return out

    def u16(self) -> int:
        return struct.unpack("<H", self.take(2))[0]

    def u32(self) -> int:
        return struct.unpack("<I", self.take(4))[0]

    def done(self) -> bool:
        return self.off == len(self.data)


def window_digest(coms: List[int]) -> bytes:
    return hashlib.sha256(b"".join(com_to_bytes(c) for c in coms)).digest()


def build_binding(window_coms: Dict[int, List[int]],
                  hash_name: str = "sha256"
                  ) -> Tuple[merkle.MerkleTree, DatasetBinding]:
    """Bind per-window sample commitments into one dataset root.

    Returns the (prover-held) tree and the (published) binding; windows
    get contiguous sample index ranges in ascending window order."""
    if not window_coms:
        raise ValueError("empty window set")
    leaves: List[bytes] = []
    windows: Dict[int, WindowSpan] = {}
    for w in sorted(window_coms):
        coms = window_coms[w]
        windows[w] = WindowSpan(start=len(leaves), count=len(coms),
                                digest=window_digest(coms))
        leaves.extend(com_to_bytes(c) for c in coms)
    tree = merkle.MerkleTree(leaves, hash_name)
    return tree, DatasetBinding(hash_name=hash_name, root=tree.root,
                                windows=windows)


# -- audit artifact ---------------------------------------------------------

@dataclasses.dataclass
class MembershipAudit:
    """One audit interaction: which window is claimed (-1 = dataset
    level), which commitments are queried, and the Protocol-3 proof."""
    window: int
    queried: List[bytes]
    proof: merkle.MembershipProof

    def to_bytes(self) -> bytes:
        out = [AUDIT_MAGIC, struct.pack("<H", AUDIT_VERSION),
               struct.pack("<I", DATASET_QUERY if self.window < 0
                           else self.window),
               struct.pack("<I", len(self.queried))]
        for q in self.queried:
            out.append(struct.pack("<H", len(q)) + q)
        proof = self.proof.to_bytes()
        out.append(struct.pack("<I", len(proof)) + proof)
        return b"".join(out)

    @classmethod
    def from_bytes(cls, data: bytes) -> "MembershipAudit":
        r = _Reader(data)
        if r.take(4) != AUDIT_MAGIC:
            raise AuditDecodeError("bad magic (not a membership audit)")
        ver = r.u16()
        if ver != AUDIT_VERSION:
            raise AuditDecodeError(f"unsupported audit version {ver}")
        window = r.u32()
        queried = [r.take(r.u16()) for _ in range(r.u32())]
        try:
            proof = merkle.MembershipProof.from_bytes(r.take(r.u32()))
        except merkle.MembershipProofDecodeError as exc:
            raise AuditDecodeError(f"bad membership proof: {exc}") from exc
        if not r.done():
            raise AuditDecodeError("trailing bytes after audit")
        return cls(window=-1 if window == DATASET_QUERY else window,
                   queried=queried, proof=proof)


def prove_membership(tree: merkle.MerkleTree, binding: DatasetBinding,
                     window: int, queried: Iterable[bytes]
                     ) -> MembershipAudit:
    """Protocol 3, audit-shaped: trainer answers a query batch against
    the bound dataset.  ``window=-1`` asks dataset-level membership
    only (no proof bytes needed at verify time)."""
    queried = list(queried)
    if not all(isinstance(q, (bytes, bytearray)) for q in queried):
        raise TypeError("queried commitments must be bytes "
                        "(use com_to_bytes)")
    queried = [bytes(q) for q in queried]
    if window >= 0 and window not in binding.windows:
        raise ValueError(f"window {window} not in binding")
    return MembershipAudit(window=window, queried=queried,
                           proof=tree.prove_membership(queried))


# -- verification (bytes in, verdict out) -----------------------------------

@dataclasses.dataclass
class QueryResult:
    com: bytes
    in_dataset: bool
    in_window: Optional[bool]    # None on dataset-level audits


@dataclasses.dataclass
class MembershipVerdict:
    ok: bool                     # audit artifacts consistent & verified
    reason: str                  # first failing check when not ok
    results: List[QueryResult]

    @property
    def n_members(self) -> int:
        return sum(1 for r in self.results if r.in_dataset)

    @property
    def n_window_members(self) -> int:
        return sum(1 for r in self.results if r.in_window)


def _fail(reason: str) -> MembershipVerdict:
    return MembershipVerdict(ok=False, reason=reason, results=[])


def verify_membership(binding: DatasetBinding, audit: MembershipAudit,
                      proof_bytes: Optional[bytes] = None,
                      vk=None, label: bytes = b"zkdl"
                      ) -> MembershipVerdict:
    """Protocol 4, audit-shaped: the data owner's side, from bytes.

    Checks (1) the Merkle (non-)membership proof against the endorsed
    root, and, for a window-level audit, (2) that the presented proof
    bytes carry EXACTLY the bound window's sample commitments (count +
    digest against the binding — this is what kills cross-window
    replay) and which queried commitments appear among them.  Passing
    ``vk`` additionally runs the full ``verify_bytes`` on the proof, so
    one call answers "this window verifies AND trained on these
    samples"."""
    if not merkle.verify_membership(audit.queried, binding.root,
                                    audit.proof, binding.hash_name):
        return _fail("merkle proof rejected")
    member = set(audit.proof.included)
    in_dataset = [merkle.hash_bits(q, binding.hash_name) in member
                  for q in audit.queried]

    if audit.window < 0:
        return MembershipVerdict(ok=True, reason="", results=[
            QueryResult(com=q, in_dataset=m, in_window=None)
            for q, m in zip(audit.queried, in_dataset)])

    span = binding.windows.get(audit.window)
    if span is None:
        return _fail(f"window {audit.window} not bound")
    if proof_bytes is None:
        return _fail("window-level audit requires proof bytes")
    if vk is not None:
        from repro.core.pipeline.verifier import verify_bytes
        if not verify_bytes(vk, proof_bytes, label=label):
            return _fail("window proof rejected by verify_bytes")
    try:
        coms = sample_coms(proof_bytes)
    except Exception as exc:            # ProofDecodeError and kin
        return _fail(f"window proof undecodable: {exc}")
    if len(coms) != span.count:
        return _fail(f"window carries {len(coms)} samples, binding says "
                     f"{span.count}")
    if window_digest(coms) != span.digest:
        return _fail("window commitment digest mismatch (replayed or "
                     "wrong-window proof)")
    wset = {com_to_bytes(c) for c in coms}
    return MembershipVerdict(ok=True, reason="", results=[
        QueryResult(com=q, in_dataset=m, in_window=q in wset)
        for q, m in zip(audit.queried, in_dataset)])


# -- ProverService integration ----------------------------------------------

def bind_service_dir(out_dir: str, hash_name: str = "sha256"
                     ) -> Tuple[merkle.MerkleTree, DatasetBinding]:
    """Bind every COMMITTED window of a ProverService output directory:
    writes ``dataset.bin`` next to ``vk.bin`` and records the binding
    in ``MANIFEST.jsonl`` (an event line without a ``window`` key, which
    `serve.read_manifest` ignores by design)."""
    from repro.launch import serve

    man = serve.read_manifest(out_dir)
    committed = sorted(w for w, rec in man.items()
                       if rec.get("status") == "COMMITTED")
    if not committed:
        raise ValueError(f"no COMMITTED windows in {out_dir}")
    window_coms = {}
    for w in committed:
        with open(os.path.join(out_dir, f"proof_{w:06d}.bin"), "rb") as f:
            window_coms[w] = sample_coms(f.read())
    tree, binding = build_binding(window_coms, hash_name)

    path = os.path.join(out_dir, BINDING_FILE)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(binding.to_bytes())
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)

    line = json.dumps({"event": "DATASET_BINDING",
                       "hash": hash_name,
                       "root": binding.root.hex(),
                       "n_windows": len(binding.windows),
                       "n_samples": binding.n_samples,
                       "ts": time.time()})
    with open(os.path.join(out_dir, serve.MANIFEST), "a") as f:
        f.write(line + "\n")
        f.flush()
        os.fsync(f.fileno())
    return tree, binding
