"""CLI: ``python -m repro.audit run [--smoke]`` and the fresh-process
membership verifier ``python -m repro.audit verify-membership``.

``run`` proves a fresh model, fires the full adversarial battery, runs
the membership + SC-BD audits and writes ``AUDIT_report.json``; exit
status is nonzero unless EVERY attack was rejected and both audits
passed — the CI gate is the process exit code, the report is the
evidence.

``verify-membership`` is deliberately minimal: it loads only serialized
artifacts (``vk.bin``, ``dataset.bin``, ``proof_*.bin``, ``audit_*.bin``)
and prints a JSON verdict — the deployment-shaped data-owner side.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _cmd_run(args) -> int:
    from repro.audit.report import run_audit

    report = run_audit(smoke=args.smoke,
                       n_steps=args.steps,
                       seed=args.seed,
                       label=args.label.encode(),
                       attack_names=(args.attacks.split(",")
                                     if args.attacks else None),
                       work_dir=args.dir,
                       fresh_process=not args.no_fresh_process)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    s = report["summary"]
    for o in report["attacks"]:
        status = "REJECTED" if o["rejected"] else "ACCEPTED *** FORGERY ***"
        print(f"audit: {o['name']:<28s} [{o['family']}] {status} "
              f"({o['seconds']:.2f}s)")
    m = report["membership"]
    cp = m["cross_process"]
    print(f"audit: membership {'ok' if m['ok'] else 'FAILED'} "
          f"({m['n_members']}/{m['n_queried']} members, "
          f"{m['n_window_members']} in window, fresh-process="
          f"{cp['ok'] if cp['ran'] else 'skipped'})")
    print(f"audit: scbd {'ok' if report['scbd']['ok'] else 'FAILED'} "
          f"(d={report['scbd']['d']}, "
          f"digest={report['scbd']['digest'][:16]}...)")
    print(f"audit: {s['n_rejected']}/{s['n_attacks']} attacks rejected "
          f"across {len(s['families'])} families -> "
          f"{'OK' if report['ok'] else 'FAILED'} "
          f"({report['timings']['total_s']:.1f}s, report: {args.out})")
    return 0 if report["ok"] else 1


def _cmd_verify_membership(args) -> int:
    from repro.audit.membership import (DatasetBinding, MembershipAudit,
                                        verify_membership)
    from repro.core.pipeline.proofio import decode_vk

    d = args.dir
    with open(os.path.join(d, "vk.bin"), "rb") as f:
        vk = decode_vk(f.read())
    with open(os.path.join(d, "dataset.bin"), "rb") as f:
        binding = DatasetBinding.from_bytes(f.read())
    with open(os.path.join(d, f"audit_{args.window:06d}.bin"), "rb") as f:
        audit = MembershipAudit.from_bytes(f.read())
    proof_bytes = None
    if audit.window >= 0:
        with open(os.path.join(d, f"proof_{args.window:06d}.bin"),
                  "rb") as f:
            proof_bytes = f.read()
    verdict = verify_membership(binding, audit, proof_bytes=proof_bytes,
                                vk=vk, label=args.label.encode())
    print(json.dumps({
        "ok": verdict.ok,
        "reason": verdict.reason,
        "window": audit.window,
        "n_queried": len(audit.queried),
        "results": [{"com": r.com.hex(), "in_dataset": r.in_dataset,
                     "in_window": r.in_window}
                    for r in verdict.results],
    }))
    return 0 if verdict.ok else 1


def main(argv=None) -> int:
    from repro.util import enable_compilation_cache
    enable_compilation_cache()

    p = argparse.ArgumentParser(prog="python -m repro.audit")
    sub = p.add_subparsers(dest="cmd", required=True)

    runp = sub.add_parser("run", help="full adversarial battery + audits")
    runp.add_argument("--smoke", action="store_true",
                      help="T=2 window (CI); default is the T=8 window")
    runp.add_argument("--steps", type=int, default=None,
                      help="override the aggregation window length")
    runp.add_argument("--seed", type=int, default=11)
    runp.add_argument("--label", default="zkdl")
    runp.add_argument("--out", default="AUDIT_report.json")
    runp.add_argument("--dir", default=None,
                      help="artifact dir for the fresh-process membership "
                           "round-trip (default: a temp dir)")
    runp.add_argument("--attacks", default=None,
                      help="comma-separated subset of attack names")
    runp.add_argument("--no-fresh-process", action="store_true")
    runp.set_defaults(fn=_cmd_run)

    vm = sub.add_parser("verify-membership",
                        help="data-owner verifier: bytes in, verdict out")
    vm.add_argument("--dir", required=True)
    vm.add_argument("--window", type=int, required=True)
    vm.add_argument("--label", default="zkdl")
    vm.set_defaults(fn=_cmd_verify_membership)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
