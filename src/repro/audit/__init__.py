"""repro.audit — the verifier-side counterpart to the prover pipeline.

Three layers (ISSUE 9):

* ``attacks``    — a registry of structured adversaries over
  ``(witness trajectory, ProvingKey, proof bytes, vk)``, every one of
  which must be REJECTED by ``verify_bytes``;
* ``membership`` — the Section 4.4 data-membership audit revived onto
  the v3 proof format: bind per-step ``com_x`` sample commitments into
  a sparse-Merkle dataset root (``DatasetBinding``) and answer "were
  these committed samples used in window W" from bytes alone;
* ``report``     — ``python -m repro.audit run``: the full battery
  against a freshly proved model, producing ``AUDIT_report.json`` that
  CI gates on 100% rejection.
"""
from repro.audit.attacks import (ATTACKS, AttackContext, AttackOutcome,
                                 build_context, run_attack, run_battery)
from repro.audit.membership import (DatasetBinding, MembershipAudit,
                                    MembershipVerdict, QueryResult,
                                    WindowSpan, bind_service_dir,
                                    build_binding, com_to_bytes,
                                    commit_sample, prove_membership,
                                    sample_coms, verify_membership)
from repro.audit.report import run_audit, validate_report

__all__ = [
    "ATTACKS", "AttackContext", "AttackOutcome", "build_context",
    "run_attack", "run_battery",
    "DatasetBinding", "MembershipAudit", "MembershipVerdict",
    "QueryResult", "WindowSpan", "bind_service_dir", "build_binding",
    "com_to_bytes", "commit_sample", "prove_membership", "sample_coms",
    "verify_membership",
    "run_audit", "validate_report",
]
