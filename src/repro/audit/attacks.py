"""Structured adversaries against the v3 proof format.

Every attack is a named transformation over ``(witness trajectory,
ProvingKey, proof bytes, vk)`` that produces a *self-consistent* forgery
— commitments recomputed, transcripts replayed honestly over doctored
state — and the battery's contract is that ``verify_bytes`` rejects all
of them.  Random byte flips (the fuzz suite) exercise the decoder;
these exercise the soundness argument itself:

* ``spoofed-trajectory``: the SecurePoL spoof — fabricate gradients
  that "explain" an arbitrary weight update, then re-prove the rest of
  the trajectory honestly from the spoofed weights.  Every commitment
  is fresh and mutually consistent; only eq. (34) (G_W = G_Z^T A) is a
  lie, so rejection pins the gradient relation, not bookkeeping.
* ``cross-slot``: the PR-5/6 disjoint-slice argument — move claims,
  commitments, lambdas or generator slices between slots of the merged
  one-IPA and re-prove where possible.
* ``replay`` / ``splice``: honest bytes presented under the wrong vk,
  label, or window, or sections grafted between two honest proofs.
* ``validity-forgery``: self-consistent zkReLU table forgeries —
  out-of-range gap aliased into range, flipped bit planes with the
  negated matrix kept consistent.

Attacks that re-run the real prover patch ONLY module-level seams
(``openings.merged_lambdas``, ``zkrelu.build_aux_bits``, the mutable
``slot_keys`` dict) and always restore them; the honest context stays
reusable across the battery.
"""
from __future__ import annotations

import contextlib
import copy
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import pedersen, zkrelu
from repro.core.pipeline import openings as openings_mod
from repro.core.pipeline import (build_fcnn_graph, compile as zk_compile,
                                 decode_proof, encode_proof,
                                 prove_session, verify_bytes)
from repro.core.pipeline.api import VerifyingKey
from repro.core.pipeline.config import PipelineConfig
from repro.core.quantfc import (QuantConfig, sgd_apply,
                                synthetic_sgd_trajectory_widths,
                                train_step_witness)


@contextlib.contextmanager
def _patched(obj, name: str, value):
    """Temporarily replace an attribute; ALWAYS restore (a leaked patch
    would poison the honest prover for every later attack)."""
    orig = getattr(obj, name)
    setattr(obj, name, value)
    try:
        yield orig
    finally:
        setattr(obj, name, orig)


@dataclasses.dataclass
class VariantResult:
    variant: str
    rejected: bool
    trace: str = ""


@dataclasses.dataclass
class AttackOutcome:
    name: str
    family: str
    rejected: bool               # True iff EVERY variant was rejected
    variants: List[VariantResult]
    seconds: float

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "family": self.family,
            "rejected": self.rejected,
            "seconds": round(self.seconds, 3),
            "variants": [{"variant": v.variant, "rejected": v.rejected,
                          "trace": v.trace} for v in self.variants],
        }


@dataclasses.dataclass
class AttackContext:
    """One honest proved window plus everything an adversary controls."""
    pk: object
    vk: VerifyingKey
    quant: QuantConfig
    wits: list                    # honest trajectory (NEVER mutated in place)
    widths: tuple
    batch: int
    n_steps: int
    label: bytes
    seed: int
    lr_shift: int
    proof_bytes: bytes
    compile_seconds: float = 0.0
    prove_seconds: float = 0.0
    _second: Optional[Tuple[list, bytes]] = None

    @property
    def cfg(self):
        return self.pk.keys.cfg

    def reprove(self, wits, tag: int) -> bytes:
        rng = np.random.default_rng(self.seed * 1009 + tag)
        return encode_proof(prove_session(self.pk, wits, rng,
                                          label=self.label))

    def second_window(self) -> bytes:
        """A SECOND honest window (fresh data, same pk/vk/label), shared
        by the replay and splice attacks.  Cached: proving is the
        expensive step."""
        if self._second is None:
            wits2 = synthetic_sgd_trajectory_widths(
                self.n_steps, self.widths, self.batch, self.quant,
                seed=self.seed + 1, lr_shift=self.lr_shift)
            raw2 = self.reprove(wits2, 999)
            assert verify_bytes(self.vk, raw2, label=self.label), \
                "second honest window must verify"
            self._second = (wits2, raw2)
        return self._second[1]

    def expect_reject(self, variant: str, raw: bytes,
                      vk: Optional[VerifyingKey] = None,
                      label: Optional[bytes] = None) -> VariantResult:
        trace: list = []
        accepted = verify_bytes(vk if vk is not None else self.vk, raw,
                                label=label if label is not None
                                else self.label, trace=trace)
        return VariantResult(variant, rejected=not accepted,
                             trace=str(trace[0]) if trace else "")


def build_context(widths=(4, 4, 4), batch: int = 2, n_steps: int = 2,
                  q_bits: int = 16, r_bits: int = 4, seed: int = 11,
                  label: bytes = b"zkdl", lr_shift: int = 8,
                  warm: bool = False) -> AttackContext:
    widths = tuple(int(w) for w in widths)
    qc = QuantConfig(q_bits=q_bits, r_bits=r_bits)
    graph = build_fcnn_graph(widths, batch=batch)
    t0 = time.perf_counter()
    pk, vk = zk_compile(graph, qc, n_steps=n_steps, warm=warm)
    t1 = time.perf_counter()
    wits = synthetic_sgd_trajectory_widths(n_steps, widths, batch, qc,
                                           seed=seed, lr_shift=lr_shift)
    raw = encode_proof(prove_session(pk, wits, np.random.default_rng(seed),
                                     label=label))
    t2 = time.perf_counter()
    assert verify_bytes(vk, raw, label=label), "honest proof must verify"
    return AttackContext(pk=pk, vk=vk, quant=qc, wits=wits, widths=widths,
                         batch=batch, n_steps=n_steps, label=label,
                         seed=seed, lr_shift=lr_shift, proof_bytes=raw,
                         compile_seconds=t1 - t0, prove_seconds=t2 - t1)


# -- registry ---------------------------------------------------------------

ATTACKS: Dict[str, Callable[[AttackContext], List[VariantResult]]] = {}


def attack(name: str, family: str):
    def deco(fn):
        fn.attack_name = name
        fn.attack_family = family
        ATTACKS[name] = fn
        return fn
    return deco


def run_attack(ctx: AttackContext, name: str) -> AttackOutcome:
    fn = ATTACKS[name]
    t0 = time.perf_counter()
    variants = fn(ctx)
    dt = time.perf_counter() - t0
    return AttackOutcome(name=name, family=fn.attack_family,
                         rejected=bool(variants) and
                         all(v.rejected for v in variants),
                         variants=variants, seconds=dt)


def run_battery(ctx: AttackContext,
                names: Optional[List[str]] = None) -> List[AttackOutcome]:
    return [run_attack(ctx, n) for n in (names or list(ATTACKS))]


# -- trajectory forgeries ---------------------------------------------------

@attack("spoofed_sgd_trajectory", "spoofed-trajectory")
def _spoofed_sgd_trajectory(ctx: AttackContext) -> List[VariantResult]:
    """SecurePoL-style spoof: pick an arbitrary weight target, fabricate
    step-0 gradients G_W = (W - W_target)^T * 2^{lr_shift+R} that
    sgd_apply maps EXACTLY onto the target, then recompute every later
    step honestly from the spoofed weights.  All commitments are fresh
    and self-consistent; only eq. (34) in step 0 is false."""
    qc = ctx.quant
    wits = copy.deepcopy(ctx.wits)
    w0 = wits[0]
    lim = 1 << (qc.q_bits - 1)
    rng = np.random.default_rng(ctx.seed + 977)
    target = [np.clip(w + rng.integers(-3, 4, size=w.shape),
                      -lim, lim - 1).astype(np.int64) for w in w0.w]
    # guarantee the spoof actually moves at least one weight
    t00 = int(w0.w[0][0, 0])
    target[0][0, 0] = t00 - 1 if t00 > -lim else t00 + 1
    shift = 1 << (ctx.lr_shift + qc.r_bits)
    forged_gw = [((w.astype(np.int64) - tgt).T * shift).astype(np.int64)
                 for w, tgt in zip(w0.w, target)]
    wits[0] = dataclasses.replace(w0, gw=forged_gw)
    ws = target
    for t in range(1, len(wits)):
        step = wits[t]
        wits[t] = train_step_witness(step.x, step.y, ws, qc,
                                     skips=step.skips)
        ws = sgd_apply(ws, wits[t].gw, ctx.lr_shift, qc)
    raw = ctx.reprove(wits, 1)
    return [ctx.expect_reject("forged-gradient self-consistent reprove",
                              raw)]


@attack("wrong_committed_weights", "wrong-weights")
def _wrong_committed_weights(ctx: AttackContext) -> List[VariantResult]:
    """Honest transcript over tampered W^t: the forged weight is
    committed and opened consistently, but the forward product Z = X W
    it participates in is now false."""
    qc = ctx.quant
    wits = copy.deepcopy(ctx.wits)
    lim = 1 << (qc.q_bits - 1)
    wl = wits[-1].w[0]
    wl[0, 0] = wl[0, 0] - 1 if wl[0, 0] > -lim else wl[0, 0] + 1
    raw = ctx.reprove(wits, 2)
    return [ctx.expect_reject("tampered final-step weight, honest reprove",
                              raw)]


# -- cross-slot claim swaps (the disjoint-slice argument) -------------------

@attack("cross_slot_commit_swap", "cross-slot-claim-swap")
def _cross_slot_commit_swap(ctx: AttackContext) -> List[VariantResult]:
    forged = decode_proof(ctx.proof_bytes)
    slots = dict(forged.coms.slots)
    slots["rz"], slots["rga"] = slots["rga"], slots["rz"]
    forged.coms.slots = slots
    return [ctx.expect_reject("rz<->rga commitment vectors swapped",
                              encode_proof(forged))]


@attack("cross_slot_claim_swap", "cross-slot-claim-swap")
def _cross_slot_claim_swap(ctx: AttackContext) -> List[VariantResult]:
    """The stronger forgery: relocate the claimed openings ALONG WITH
    the commitments so each claim still 'matches' its commitment.  Only
    the disjointness of the generator slices kills this."""
    forged = decode_proof(ctx.proof_bytes)
    slots = dict(forged.coms.slots)
    slots["rz"], slots["rga"] = slots["rga"], slots["rz"]
    forged.coms.slots = slots
    op = forged.openings
    op["a3"], op["a5"] = op["a5"], op["a3"]
    op["a7"], op["a8"] = op["a8"], op["a7"]
    return [ctx.expect_reject("rz<->rga with relocated claims (a3/a5, "
                              "a7/a8)", encode_proof(forged))]


@attack("validity_lambda_swap", "cross-slot-claim-swap")
def _validity_lambda_swap(ctx: AttackContext) -> List[VariantResult]:
    """Re-prove with the two validity-statement lambdas exchanged: the
    main claim rides the remainder slice's weight and vice versa.  The
    prover is fully honest about everything else; the verifier's OWN
    lambda schedule must refuse the transposed weighting."""
    orig = openings_mod.merged_lambdas

    def swapped(cfg, rho):
        lam1, lam2 = orig(cfg, rho)
        return lam2, lam1

    with _patched(openings_mod, "merged_lambdas", swapped):
        raw = ctx.reprove(ctx.wits, 5)
    return [ctx.expect_reject("vmain/vrem lambda weights transposed", raw)]


@attack("bq_basis_splice", "cross-slot-claim-swap")
def _bq_basis_splice(ctx: AttackContext) -> List[VariantResult]:
    """Commit the bq slot under the zkReLU G-column basis (a sub-basis
    of the vmain slice) instead of its own fresh slice — the repeated-
    generator forgery the merged-key freshness invariant exists to
    block.  The prover is honest modulo the spliced key."""
    keys = ctx.pk.keys
    honest = keys.slot_keys["bq"]
    spliced = pedersen.CommitKey(keys.validity.g_col, honest.h,
                                 b"zkdl/audit/bq-splice")
    keys.slot_keys["bq"] = spliced
    try:
        raw = ctx.reprove(ctx.wits, 6)
    finally:
        keys.slot_keys["bq"] = honest
    return [ctx.expect_reject("bq slot committed under zkReLU g_col "
                              "basis", raw)]


@attack("bq_column_swap", "cross-slot-claim-swap")
def _bq_column_swap(ctx: AttackContext) -> List[VariantResult]:
    """Swap the bq slot commitment with the zkReLU column commitment
    com_bq1 — both commit (blinds aside) to the same B_{Q-1} bits, just
    under different bases, so a verifier that conflated the two slices
    would accept."""
    forged = decode_proof(ctx.proof_bytes)
    slots = dict(forged.coms.slots)
    slots["bq"], forged.coms.validity.com_bq1 = \
        forged.coms.validity.com_bq1, slots["bq"]
    forged.coms.slots = slots
    return [ctx.expect_reject("bq slot com <-> validity com_bq1",
                              encode_proof(forged))]


# -- replay and splicing ----------------------------------------------------

@attack("cross_vk_replay", "replay")
def _cross_vk_replay(ctx: AttackContext) -> List[VariantResult]:
    """Honest bytes presented to the WRONG verifier: a different model
    geometry, and the same geometry with a different step window."""
    qc = ctx.quant
    alt_widths = (ctx.widths[0] * 2,) + ctx.widths[1:]
    g2 = build_fcnn_graph(alt_widths, batch=ctx.batch)
    cfg2 = PipelineConfig.from_graph(g2, q_bits=qc.q_bits,
                                     r_bits=qc.r_bits, n_steps=ctx.n_steps)
    g3 = build_fcnn_graph(ctx.widths, batch=ctx.batch)
    cfg3 = PipelineConfig.from_graph(g3, q_bits=qc.q_bits,
                                     r_bits=qc.r_bits,
                                     n_steps=ctx.n_steps + 1)
    return [
        ctx.expect_reject(f"replayed under widths={alt_widths} vk",
                          ctx.proof_bytes, vk=VerifyingKey(cfg=cfg2)),
        ctx.expect_reject(f"replayed under n_steps={ctx.n_steps + 1} vk",
                          ctx.proof_bytes, vk=VerifyingKey(cfg=cfg3)),
    ]


@attack("cross_label_replay", "replay")
def _cross_label_replay(ctx: AttackContext) -> List[VariantResult]:
    """The transcript is domain-separated by deployment label: a proof
    minted for one domain must not verify in another."""
    return [ctx.expect_reject("replayed under label+'/replayed'",
                              ctx.proof_bytes,
                              label=ctx.label + b"/replayed")]


@attack("cross_window_replay", "replay")
def _cross_window_replay(ctx: AttackContext) -> List[VariantResult]:
    """Window-level replay against the membership audit: claim window 1
    trained on some samples, but present window 0's (honest, verifying)
    proof bytes.  `verify_bytes` alone accepts — the DatasetBinding's
    per-window commitment digest is what must refuse the swap."""
    from repro.audit import membership as mem

    raw2 = ctx.second_window()
    tree, binding = mem.build_binding({0: mem.sample_coms(ctx.proof_bytes),
                                       1: mem.sample_coms(raw2)})
    queried = [mem.com_to_bytes(c) for c in mem.sample_coms(raw2)[:3]]
    audit = mem.prove_membership(tree, binding, 1, queried)
    verdict = mem.verify_membership(binding, audit,
                                    proof_bytes=ctx.proof_bytes)
    honest = mem.verify_membership(binding, audit, proof_bytes=raw2)
    return [
        VariantResult("window-1 claim with window-0 proof bytes",
                      rejected=not verdict.ok, trace=verdict.reason),
        VariantResult("control: honest window-1 bytes accepted",
                      rejected=honest.ok,
                      trace="" if honest.ok else honest.reason),
    ]


@attack("proof_splice", "proof-splice")
def _proof_splice(ctx: AttackContext) -> List[VariantResult]:
    """Graft sections between two honest proofs under the SAME vk and
    label — each donor section verifies in its own proof, so only the
    transcript binding across sections can reject the hybrid."""
    a = decode_proof(ctx.proof_bytes)
    b = decode_proof(ctx.second_window())
    out = [
        ctx.expect_reject("IPA section grafted from a second window",
                          encode_proof(dataclasses.replace(
                              a, ipa_agg=b.ipa_agg))),
        ctx.expect_reject("commitment section grafted from a second "
                          "window",
                          encode_proof(dataclasses.replace(a, coms=b.coms))),
    ]
    return out


# -- zkReLU validity-table forgeries ----------------------------------------

@attack("validity_negative_gap", "validity-forgery")
def _validity_negative_gap(ctx: AttackContext) -> List[VariantResult]:
    """Alias one gap entry by +2^Q: the committed tensor changes (2^Q is
    not 0 in the field) while the bit decomposition — wrapped back into
    signed range so the real prover can still run — stays that of the
    in-range value.  A verifier that only checked bit-recomposition
    modulo 2^Q would accept this out-of-range witness."""
    qc = ctx.quant
    wits = copy.deepcopy(ctx.wits)
    g0 = wits[0].gap
    arr = g0[0] if isinstance(g0, (list, tuple)) else g0
    arr.reshape(-1)[0] += np.int64(1 << qc.q_bits)

    orig_bits = zkrelu.build_aux_bits

    def wrapping_bits(zpp, gap, bq, rz, rga, q_bits, r_bits):
        lim = 1 << (q_bits - 1)
        gap_in_range = ((gap.astype(np.int64) + lim) %
                        (1 << q_bits)) - lim
        return orig_bits(zpp, gap_in_range, bq, rz, rga, q_bits, r_bits)

    with _patched(zkrelu, "build_aux_bits", wrapping_bits):
        raw = ctx.reprove(wits, 12)
    return [ctx.expect_reject("gap entry aliased by +2^Q, bits wrapped "
                              "into range", raw)]


@attack("validity_wrong_bit_planes", "validity-forgery")
def _validity_wrong_bit_planes(ctx: AttackContext) -> List[VariantResult]:
    """Flip one bit of the zkReLU bit matrix and keep the negated matrix
    consistent (B' = 1 - B with the forced-zero column) — commitments
    and product tables all agree with the forged planes; only the
    recomposition against the committed tensors can reject."""
    orig_bits = zkrelu.build_aux_bits

    def flipped_bits(zpp, gap, bq, rz, rga, q_bits, r_bits):
        bits = orig_bits(zpp, gap, bq, rz, rga, q_bits, r_bits)
        b = bits.b_mat.copy()
        b[0, 0] ^= 1
        bneg = 1 - b
        bneg[:zpp.shape[0], q_bits - 1] = 0
        return dataclasses.replace(bits, b_mat=b, bneg=bneg)

    with _patched(zkrelu, "build_aux_bits", flipped_bits):
        raw = ctx.reprove(ctx.wits, 13)
    return [ctx.expect_reject("b_mat[0,0] flipped, bneg kept consistent",
                              raw)]


# -- metadata tampering -----------------------------------------------------

@attack("forged_step_count", "meta-tamper")
def _forged_step_count(ctx: AttackContext) -> List[VariantResult]:
    forged = decode_proof(ctx.proof_bytes)
    return [ctx.expect_reject(
        "META n_steps incremented",
        encode_proof(dataclasses.replace(forged,
                                         n_steps=forged.n_steps + 1)))]
