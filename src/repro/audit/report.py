"""The audit battery runner: prove a fresh model, attack it, audit it.

``run_audit`` produces the ``AUDIT_report.json`` dict that CI gates on:
every registered attack REJECTED, the membership audit round-tripping
end-to-end from bytes (including through a fresh verifier process), and
the revived SC-BD sumcheck proving/verifying on its pinned transcript
domains.  ``validate_report`` is the schema contract tier-1 checks.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import time
from typing import List, Optional

import numpy as np

REPORT_SCHEMA = "zkdl-audit-report/v1"

SCBD_TRANSCRIPT_LABEL = b"zkdl/scbd-audit"


def _membership_section(ctx, work_dir: Optional[str],
                        fresh_process: bool) -> dict:
    """Bind two honest windows, query trained-on + held-out samples,
    verify from bytes in-process and (optionally) in a fresh process."""
    from repro.audit import membership as mem
    from repro.core.pipeline.tables import rand_scalar

    t0 = time.perf_counter()
    raw0, raw1 = ctx.proof_bytes, ctx.second_window()
    coms0, coms1 = mem.sample_coms(raw0), mem.sample_coms(raw1)
    tree, binding = mem.build_binding({0: coms0, 1: coms1})

    # held-out samples: committed by the data owner exactly as the
    # prover would, but never part of any proved window
    rng = np.random.default_rng(ctx.seed + 4242)
    x_len = ctx.pk.keys.kx.n
    lim = 1 << (ctx.quant.q_bits - 1)
    held_out = [mem.com_to_bytes(mem.commit_sample(
        ctx.pk, rng.integers(-lim, lim, size=x_len), rand_scalar(rng)))
        for _ in range(3)]

    queried = ([mem.com_to_bytes(c) for c in coms0[:3]] +
               [mem.com_to_bytes(c) for c in coms1[:2]] +
               held_out)
    audit = mem.prove_membership(tree, binding, 0, queried)

    # byte round-trip BEFORE verification: the verifier side must work
    # from serialized artifacts alone
    binding_rt = mem.DatasetBinding.from_bytes(binding.to_bytes())
    audit_rt = mem.MembershipAudit.from_bytes(audit.to_bytes())
    verdict = mem.verify_membership(binding_rt, audit_rt,
                                    proof_bytes=raw0, vk=ctx.vk,
                                    label=ctx.label)

    want_dataset = [True] * 5 + [False] * 3
    want_window = [True] * 3 + [False] * 5
    got_dataset = [r.in_dataset for r in verdict.results]
    got_window = [bool(r.in_window) for r in verdict.results]
    ok = (verdict.ok and got_dataset == want_dataset and
          got_window == want_window)
    reason = verdict.reason if not verdict.ok else (
        "" if ok else "per-query membership answers wrong")

    section = {
        "ok": bool(ok),
        "reason": reason,
        "n_queried": len(queried),
        "n_members": verdict.n_members,
        "n_window_members": verdict.n_window_members,
        "n_non_members": len(queried) - verdict.n_members,
        "binding_bytes": len(binding.to_bytes()),
        "audit_bytes": len(audit.to_bytes()),
        "proof_nodes": audit.proof.size_nodes(),
        "cross_process": {"ran": False, "ok": None, "detail": ""},
    }

    if fresh_process and ok:
        d = work_dir or tempfile.mkdtemp(prefix="zkdl-audit-")
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, "vk.bin"), "wb") as f:
            f.write(ctx.vk.to_bytes())
        with open(os.path.join(d, "proof_000000.bin"), "wb") as f:
            f.write(raw0)
        with open(os.path.join(d, "dataset.bin"), "wb") as f:
            f.write(binding.to_bytes())
        with open(os.path.join(d, "audit_000000.bin"), "wb") as f:
            f.write(audit.to_bytes())
        proc = subprocess.run(
            [sys.executable, "-m", "repro.audit", "verify-membership",
             "--dir", d, "--window", "0",
             "--label", ctx.label.decode()],
            capture_output=True, text=True)
        cp = {"ran": True, "ok": False, "detail": ""}
        try:
            out = json.loads(proc.stdout.strip().splitlines()[-1])
            cp["ok"] = (proc.returncode == 0 and out["ok"] and
                        [r["in_dataset"] for r in out["results"]]
                        == want_dataset and
                        [r["in_window"] for r in out["results"]]
                        == want_window)
            if not cp["ok"]:
                cp["detail"] = f"rc={proc.returncode} out={out}"
        except (json.JSONDecodeError, KeyError, IndexError) as exc:
            cp["detail"] = (f"unparseable verifier output ({exc}): "
                            f"{proc.stdout[-400:]} {proc.stderr[-400:]}")
        section["cross_process"] = cp
        section["ok"] = bool(section["ok"] and cp["ok"])
    section["seconds"] = round(time.perf_counter() - t0, 3)
    return section


def _scbd_section(ctx) -> dict:
    """Revived SC-BD range sumcheck over a REAL transcript tensor (the
    stacked gap aux), with the golden-digest canonical encoding and a
    forged-claim rejection check."""
    from repro.core import scbd
    from repro.core.pipeline.witness import stack_witnesses
    from repro.core.transcript import Transcript

    t0 = time.perf_counter()
    cfg = ctx.cfg
    sw = stack_witnesses(ctx.wits, cfg)
    aux = np.asarray(sw.gap_s, dtype=np.int64).reshape(-1)
    proof = scbd.prove(aux, cfg.q_bits, Transcript(SCBD_TRANSCRIPT_LABEL))
    ok = scbd.verify(proof, aux.shape[0], cfg.q_bits,
                     Transcript(SCBD_TRANSCRIPT_LABEL))
    forged = dataclasses.replace(proof, claim=proof.claim + 1)
    tamper_rejected = not scbd.verify(forged, aux.shape[0], cfg.q_bits,
                                      Transcript(SCBD_TRANSCRIPT_LABEL))
    return {
        "ok": bool(ok and tamper_rejected),
        "d": int(aux.shape[0]),
        "q_bits": int(cfg.q_bits),
        "digest": proof.digest(),
        "size_bytes": proof.size_bytes(),
        "tamper_rejected": bool(tamper_rejected),
        "seconds": round(time.perf_counter() - t0, 3),
    }


def run_audit(smoke: bool = False, widths=(4, 4, 4), batch: int = 2,
              n_steps: Optional[int] = None, q_bits: int = 16,
              r_bits: int = 4, seed: int = 11, label: bytes = b"zkdl",
              attack_names: Optional[List[str]] = None,
              work_dir: Optional[str] = None,
              fresh_process: bool = True) -> dict:
    from repro.audit import attacks

    if n_steps is None:
        n_steps = 2 if smoke else 8
    t_start = time.perf_counter()
    ctx = attacks.build_context(widths=widths, batch=batch,
                                n_steps=n_steps, q_bits=q_bits,
                                r_bits=r_bits, seed=seed, label=label)
    t0 = time.perf_counter()
    battery = attacks.run_battery(ctx, names=attack_names)
    battery_s = time.perf_counter() - t0

    membership = _membership_section(ctx, work_dir, fresh_process)
    scbd_sec = _scbd_section(ctx)

    families = sorted({o.family for o in battery})
    all_rejected = bool(battery) and all(o.rejected for o in battery)
    report = {
        "schema": REPORT_SCHEMA,
        "config": {"widths": list(widths), "batch": batch,
                   "n_steps": n_steps, "q_bits": q_bits,
                   "r_bits": r_bits, "seed": seed,
                   "label": label.decode(), "smoke": bool(smoke)},
        "timings": {"compile_s": round(ctx.compile_seconds, 3),
                    "prove_s": round(ctx.prove_seconds, 3),
                    "battery_s": round(battery_s, 3),
                    "total_s": round(time.perf_counter() - t_start, 3)},
        "attacks": [o.as_dict() for o in battery],
        "summary": {"n_attacks": len(battery),
                    "n_rejected": sum(o.rejected for o in battery),
                    "n_accepted": sum(not o.rejected for o in battery),
                    "families": families,
                    "all_rejected": all_rejected},
        "membership": membership,
        "scbd": scbd_sec,
        "ok": bool(all_rejected and membership["ok"] and scbd_sec["ok"]),
    }
    validate_report(report)
    return report


def validate_report(report: dict) -> None:
    """Schema contract for AUDIT_report.json (raises ValueError)."""
    def need(cond, msg):
        if not cond:
            raise ValueError(f"audit report schema: {msg}")

    need(isinstance(report, dict), "not a dict")
    need(report.get("schema") == REPORT_SCHEMA,
         f"schema != {REPORT_SCHEMA}")
    for key in ("config", "timings", "attacks", "summary", "membership",
                "scbd", "ok"):
        need(key in report, f"missing key {key!r}")
    need(isinstance(report["attacks"], list) and report["attacks"],
         "empty attack list")
    for o in report["attacks"]:
        for key in ("name", "family", "rejected", "seconds", "variants"):
            need(key in o, f"attack missing {key!r}")
        need(isinstance(o["variants"], list) and o["variants"],
             f"attack {o.get('name')} has no variants")
        need(o["rejected"] == all(v["rejected"] for v in o["variants"]),
             f"attack {o['name']} rejected-bit inconsistent")
    s = report["summary"]
    need(s["n_attacks"] == len(report["attacks"]), "n_attacks mismatch")
    need(s["n_rejected"] + s["n_accepted"] == s["n_attacks"],
         "rejected/accepted split mismatch")
    need(s["all_rejected"] == (s["n_accepted"] == 0 and s["n_attacks"] > 0),
         "all_rejected inconsistent")
    need(set(s["families"]) ==
         {o["family"] for o in report["attacks"]}, "families mismatch")
    m = report["membership"]
    for key in ("ok", "reason", "n_queried", "n_members",
                "n_window_members", "n_non_members", "cross_process"):
        need(key in m, f"membership missing {key!r}")
    need(m["n_members"] + m["n_non_members"] == m["n_queried"],
         "membership counts mismatch")
    for key in ("ok", "d", "q_bits", "digest", "tamper_rejected"):
        need(key in report["scbd"], f"scbd missing {key!r}")
    need(report["ok"] == (s["all_rejected"] and m["ok"] and
                          report["scbd"]["ok"]),
         "top-level ok inconsistent with sections")
