"""Proofs of training-set (non-)membership (Section 4.4 / Appendix B).

A sparse Merkle tree over the hashes of per-data-point Pedersen
commitments.  The tree T_D = Tree(H_D) + Frontier(H_D): every internal
node has both children; leaves are either data hashes (value = the
commitment) or frontier nodes (value = epsilon).  Non-membership of a
point is proven by exhibiting a frontier node that prefixes its hash.

Implements Protocols 3 (prover) and 4 (verifier) and supports md5 / sha1 /
sha256 as in Table 3 of the paper.
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import Dict, Iterable, List, Optional, Set, Tuple

EPSILON = b""


def _hash_fn(name: str):
    return getattr(hashlib, name)


def hash_bits(data: bytes, hash_name: str) -> str:
    """Hash -> bit string (the leaf identifier / path)."""
    digest = _hash_fn(hash_name)(data).digest()
    return "".join(f"{b:08b}" for b in digest)


def _node_hash(left: bytes, right: bytes, hash_name: str) -> bytes:
    h = _hash_fn(hash_name)()
    h.update(b"L%d:" % len(left))
    h.update(left)
    h.update(b"R%d:" % len(right))
    h.update(right)
    return h.digest()


def _frontier(leaves: Set[str]) -> Set[str]:
    """Nodes not on any leaf path whose parent is (or is the root)."""
    tree: Set[str] = {""}
    for leaf in leaves:
        for i in range(1, len(leaf) + 1):
            tree.add(leaf[:i])
    out: Set[str] = set()
    for node in tree:
        for b in "01":
            child = node + b
            if child not in tree and not any(
                    leaf.startswith(child) or child.startswith(leaf)
                    for leaf in leaves):
                # child is off-tree; include it iff truly not covering a leaf
                out.add(child)
    # keep only children of tree nodes that are not themselves in tree
    return {v for v in out if v[:-1] in tree and v not in tree}


def merkle_root(values: Dict[str, bytes], hash_name: str) -> bytes:
    """Algorithm 2: roll up leaf values (keyed by bit-string id) to the root.

    Aborts (ValueError) if any node's sibling is missing.
    """
    if not values:
        raise ValueError("empty leaf set")
    if set(values) == {""}:
        return values[""]
    work = dict(values)
    depth = max(len(k) for k in work)
    for k in range(depth, 0, -1):
        level = [s for s in work if len(s) == k]
        parents: Dict[str, bytes] = {}
        done = set()
        for s in level:
            if s in done:
                continue
            sib = s[:-1] + ("1" if s[-1] == "0" else "0")
            if sib not in work:
                raise ValueError(f"missing sibling of {s}")
            done.add(s); done.add(sib)
            l_, r_ = (s, sib) if s[-1] == "0" else (sib, s)
            parents[s[:-1]] = _node_hash(work[l_], work[r_], hash_name)
        for s in level:
            del work[s]
        for p, v in parents.items():
            if p in work:
                raise ValueError(f"non-disjoint union at {p}")
            work[p] = v
    return work[""]


def _pack_id(bits: str) -> bytes:
    """Bit-string node id -> (u16 bit length, MSB-first packed bytes)."""
    nbits = len(bits)
    padded = bits + "0" * (-nbits % 8)
    packed = bytes(int(padded[i:i + 8], 2) for i in range(0, len(padded), 8))
    return struct.pack("<H", nbits) + packed


class MembershipProofDecodeError(ValueError):
    pass


class _ProofReader:
    def __init__(self, data: bytes):
        self.data = data
        self.off = 0

    def take(self, n: int) -> bytes:
        if self.off + n > len(self.data):
            raise MembershipProofDecodeError("truncated membership proof")
        out = self.data[self.off:self.off + n]
        self.off += n
        return out

    def u16(self) -> int:
        return struct.unpack("<H", self.take(2))[0]

    def u32(self) -> int:
        return struct.unpack("<I", self.take(4))[0]

    def node_id(self) -> str:
        nbits = self.u16()
        packed = self.take((nbits + 7) // 8)
        bits = "".join(f"{b:08b}" for b in packed)
        return bits[:nbits]


MEMBERSHIP_PROOF_MAGIC = b"ZKMP"
MEMBERSHIP_PROOF_VERSION = 1


@dataclasses.dataclass
class MembershipProof:
    """Protocol 3 output: hashes split by membership + released node values."""
    included: List[str]
    excluded: List[str]
    frontier_exc: List[str]            # F^exc: frontier prefixes of excluded
    node_values: Dict[str, bytes]      # values on Tree(inc u F^exc) frontier

    def size_nodes(self) -> int:
        return len(self.node_values) + len(self.frontier_exc)

    def to_bytes(self) -> bytes:
        """Canonical encoding so audits verify in a fresh process from
        bytes alone (node ids are bit strings; values are raw bytes)."""
        out = [MEMBERSHIP_PROOF_MAGIC,
               struct.pack("<H", MEMBERSHIP_PROOF_VERSION)]
        for group in (self.included, self.excluded, self.frontier_exc):
            out.append(struct.pack("<I", len(group)))
            out.extend(_pack_id(h) for h in group)
        out.append(struct.pack("<I", len(self.node_values)))
        for nid in sorted(self.node_values):
            val = self.node_values[nid]
            out.append(_pack_id(nid))
            out.append(struct.pack("<I", len(val)))
            out.append(val)
        return b"".join(out)

    @classmethod
    def from_bytes(cls, data: bytes) -> "MembershipProof":
        r = _ProofReader(data)
        if r.take(4) != MEMBERSHIP_PROOF_MAGIC:
            raise MembershipProofDecodeError("bad membership-proof magic")
        ver = r.u16()
        if ver != MEMBERSHIP_PROOF_VERSION:
            raise MembershipProofDecodeError(
                f"unsupported membership-proof version {ver}")
        groups = []
        for _ in range(3):
            groups.append([r.node_id() for _ in range(r.u32())])
        node_values: Dict[str, bytes] = {}
        for _ in range(r.u32()):
            nid = r.node_id()
            node_values[nid] = r.take(r.u32())
        if r.off != len(data):
            raise MembershipProofDecodeError("trailing bytes")
        return cls(included=groups[0], excluded=groups[1],
                   frontier_exc=groups[2], node_values=node_values)


class MerkleTree:
    """Trainer-side tree over {hash(com_d)} with stored node values."""

    def __init__(self, commitments: Iterable[bytes], hash_name: str = "sha256"):
        self.hash_name = hash_name
        self.leaf_value: Dict[str, bytes] = {}
        for com in commitments:
            hid = hash_bits(com, hash_name)
            self.leaf_value[hid] = com
        self.leaves: Set[str] = set(self.leaf_value)
        self.frontier = self._compute_frontier()
        values: Dict[str, bytes] = dict(self.leaf_value)
        for f in self.frontier:
            values[f] = EPSILON
        self.values = self._fill(values)
        self.root = self.values[""]

    def _compute_frontier(self) -> Set[str]:
        tree: Set[str] = {""}
        for leaf in self.leaves:
            for i in range(1, len(leaf) + 1):
                tree.add(leaf[:i])
        out: Set[str] = set()
        for node in tree:
            if node in self.leaves:
                continue
            for b in "01":
                child = node + b
                if child not in tree:
                    out.add(child)
        return out

    def _fill(self, values: Dict[str, bytes]) -> Dict[str, bytes]:
        # bucket nodes by depth once and sweep bottom-up: each node is
        # touched O(1) times (the per-level rescan of the whole pending
        # set made dataset-scale trees quadratic in practice)
        out = dict(values)
        by_len: Dict[int, List[str]] = {}
        for n in out:
            by_len.setdefault(len(n), []).append(n)
        for k in range(max(by_len, default=0), 0, -1):
            for s in by_len.get(k, ()):
                parent = s[:-1]
                sib = parent + ("1" if s[-1] == "0" else "0")
                if parent in out or sib not in out:
                    continue
                l_, r_ = (s, sib) if s[-1] == "0" else (sib, s)
                out[parent] = _node_hash(out[l_], out[r_], self.hash_name)
                by_len.setdefault(k - 1, []).append(parent)
        return out

    # -- Protocol 3 ---------------------------------------------------------
    def prove_membership(self, queried: Iterable[bytes]) -> MembershipProof:
        h_e = [hash_bits(c, self.hash_name) for c in queried]
        inc = [h for h in h_e if h in self.leaves]
        exc = [h for h in h_e if h not in self.leaves]
        f_exc: Set[str] = set()
        for h in exc:
            # walk h's prefixes instead of scanning the frontier set
            # (the frontier holds ~n*hash_bits nodes at dataset scale)
            pre = next((h[:i] for i in range(1, len(h) + 1)
                        if h[:i] in self.frontier), None)
            if pre is None:
                raise AssertionError("frontier must cover every non-member")
            f_exc.add(pre)
        # release the anchors plus every sibling along their paths to the
        # root (= Frontier(H_E^inc u F^exc) restricted to T_D, whose nodes
        # all exist because every internal node of T_D has two children)
        anchor = set(inc) | f_exc
        path_nodes: Set[str] = set()
        for a in anchor:
            for i in range(0, len(a) + 1):
                path_nodes.add(a[:i])
        release: Dict[str, bytes] = {a: self.values[a] for a in anchor}
        for node in path_nodes:
            if node == "":
                continue
            sib = node[:-1] + ("1" if node[-1] == "0" else "0")
            if sib not in path_nodes:
                release[sib] = self.values[sib]
        return MembershipProof(included=inc, excluded=exc,
                               frontier_exc=sorted(f_exc),
                               node_values=release)


def verify_membership(queried: Iterable[bytes], root: bytes,
                      proof: MembershipProof, hash_name: str = "sha256") -> bool:
    """Protocol 4: data-owner verification against the endorsed root."""
    h_e = [hash_bits(c, hash_name) for c in queried]
    if sorted(h_e) != sorted(proof.included + proof.excluded):
        return False
    if set(proof.included) & set(proof.excluded):
        return False
    # every excluded hash must be covered by a released frontier node = eps
    for h in proof.excluded:
        pre = next((f for f in proof.frontier_exc if h.startswith(f)), None)
        if pre is None:
            return False
        if proof.node_values.get(pre, None) != EPSILON:
            return False
    # every included hash must carry its commitment value whose hash matches
    for h in proof.included:
        val = proof.node_values.get(h)
        if val is None or hash_bits(val, hash_name) != h:
            return False
    try:
        rebuilt = merkle_root(dict(proof.node_values), hash_name)
    except ValueError:
        return False
    return rebuilt == root
