"""zkDL core: the paper protocols as composable modules."""
