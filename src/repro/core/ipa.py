"""Zero-knowledge inner-product arguments (Bulletproofs [45] style).

Two variants, both log-size and linear-prover-time:

* ``open_*``: proves <a, b_pub> = c for a *committed* vector a and a
  *public* vector b (the MLE-opening workhorse: b = e(u)).
* ``pair_*``: proves <a, b> = c where BOTH vectors are bound inside one
  commitment C = h^rho G^a H^b -- exactly the statement produced by
  Algorithm 1 for the zkReLU validity equation (19).

Honest-verifier zero knowledge comes from per-round blinding factors on
L/R plus a final Schnorr/sigma opening instead of revealing the folded
scalars.  The prover is JAX (limb arrays); the verifier mixes host ints
with vectorized JAX for the O(n) generator folds.

Prover rounds are FUSED: each round issues exactly one jitted multi-MSM
for the L/R cross terms (the two half-length MSMs, the u^{c} claim term
and the h^{rho} blind ride as extra rows/columns of `group.msm_many`),
one host transfer decoding both L and R, and one jitted fold of every
vector/generator half -- instead of the ~20 eager group-op dispatches
the unfused path paid per round.  All arithmetic is bit-identical to the
unfused primitives (`tests/test_ipa.py` pins the parity, blinds
included), so transcripts do not change.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.field import FQ, FP, add, mont_mul, from_mont, decode, int_to_limbs
from repro.core import execache, group
from repro.core import mle
from repro.core.mle import enc, fdot
from repro.core.transcript import Transcript

Q = FQ.modulus

# ---------------------------------------------------------------------------
# Round execution mode.
#
# "ladder" (default, single-statement proofs): rounds run on a small
# fixed set of buffer sizes (`_ladder_size`) with the live halves
# gathered/masked inside the round body, so log2(n) rounds compile O(1)
# distinct programs instead of one pair per halving shape.  "unrolled"
# keeps the legacy exact-shape schedule as the bit-identity parity
# oracle; multi-statement lockstep proofs always use it.
# ---------------------------------------------------------------------------

IPA_MODES = ("ladder", "unrolled")
_IPA_MODE_ENV = "ZKDL_IPA_MODE"
_ipa_mode_override: str | None = None


def round_mode() -> str:
    """Active IPA round mode: override > $ZKDL_IPA_MODE > "ladder"."""
    name = _ipa_mode_override or os.environ.get(_IPA_MODE_ENV,
                                                "ladder").lower()
    if name not in IPA_MODES:
        raise ValueError(f"unknown ipa mode {name!r}; "
                         f"choose from {IPA_MODES}")
    return name


def set_round_mode(name: str | None) -> None:
    """Process-wide override (None restores the env/default choice)."""
    global _ipa_mode_override
    if name is not None and name not in IPA_MODES:
        raise ValueError(f"unknown ipa mode {name!r}; "
                         f"choose from {IPA_MODES}")
    _ipa_mode_override = name


def _sub(prof, name: str):
    """Sub-phase context of an optional `PhaseProfile` (else a no-op)."""
    from repro.core.pipeline.profile import subphase
    return subphase(prof, name)


@dataclasses.dataclass
class IpaProof:
    ls: List[int]
    rs: List[int]
    # final sigma-protocol messages
    sigma: List[int]

    def size_bytes(self) -> int:
        return 32 * (len(self.ls) + len(self.rs) + len(self.sigma))


def _dec_scalar(x) -> int:
    return int(decode(FQ, x)[()])


def _g_pow_const(bases, e: int):
    """bases^e elementwise for one python-int exponent (jitted via g_pow)."""
    from repro.field import int_to_limbs
    e = int(e) % Q
    exps = jnp.broadcast_to(jnp.asarray(int_to_limbs(e)), bases.shape)
    return group.g_pow(bases, exps)


def _fold_vec(t, lo_coef: int, hi_coef: int):
    n2 = t.shape[0] // 2
    lo = mont_mul(FQ, t[:n2], enc(lo_coef)[None])
    hi = mont_mul(FQ, t[n2:], enc(hi_coef)[None])
    return add(FQ, lo, hi)


def _fold_gens(g, lo_exp: int, hi_exp: int):
    n2 = g.shape[0] // 2
    return group.g_mul(_g_pow_const(g[:n2], lo_exp), _g_pow_const(g[n2:], hi_exp))


def _s_vector(n: int, alphas: List[int], low_exp_is_inv: bool):
    """s_i = prod_j (alpha_j or its inverse) by the top-bit split pattern."""
    rounds = len(alphas)
    s = jnp.broadcast_to(enc(1), (n, 4)).astype(jnp.uint32)
    idx = np.arange(n)
    for j, a in enumerate(alphas):
        ai = pow(a, Q - 2, Q)
        lo, hi = (ai, a) if low_exp_is_inv else (a, ai)
        bit = (idx >> (rounds - 1 - j)) & 1
        coef = jnp.where(jnp.asarray(bit[:, None] == 0), enc(lo)[None], enc(hi)[None])
        s = mont_mul(FQ, s, coef)
    return s


def _u_gen():
    return group.derive_generators(b"zkdl/ipa-u", 1)[0]


# ---------------------------------------------------------------------------
# Fused prover rounds (one multi-MSM + one fold dispatch per round).
# ---------------------------------------------------------------------------

def _exp1(e: int) -> jnp.ndarray:
    """One python-int exponent (mod q) -> (4,) standard-form limbs."""
    return jnp.asarray(int_to_limbs(int(e) % Q))


def _lr_extras(up, h, c_l, c_r, rho_l, rho_r):
    """The up^{claim} * h^{rho} tails of both L/R as a tiny two-row MSM
    (kept OUT of the main MSM so its row length stays a power of two --
    appending two columns would force the Pippenger pad to the next
    power of four, quadrupling the sort width)."""
    pts = jnp.broadcast_to(jnp.stack([up, h])[None], (2, 2, 4))
    exps = jnp.stack([jnp.stack([c_l, rho_l]), jnp.stack([c_r, rho_r])])
    return group.msm_many(pts, exps)


def _open_round_lr(gens, a, b, up, h, rho_l, rho_r):
    """L/R of one `open` round fused into one executable:

    L = gens_hi^{a_lo} * up^{<a_lo, b_hi>} * h^{rho_l}
    R = gens_lo^{a_hi} * up^{<a_hi, b_lo>} * h^{rho_r}
    """
    n2 = a.shape[0] // 2
    c_l = from_mont(FQ, fdot(a[:n2], b[n2:]))
    c_r = from_mont(FQ, fdot(a[n2:], b[:n2]))
    a_std = from_mont(FQ, a)
    main = group.msm_many(jnp.stack([gens[n2:], gens[:n2]]),
                          jnp.stack([a_std[:n2], a_std[n2:]]))
    return group.g_mul(main, _lr_extras(up, h, c_l, c_r, rho_l, rho_r))


_open_round_lr = execache.wrap("ipa_open_round_lr", _open_round_lr)


def _pair_round_lr(gg, hh, a, b, up, h_blind, rho_l, rho_r,
                   gam_g_m, gam_h_m):
    """L/R of one `pair` round: both half-MSMs per side fused into one row.

    The stored bases carry deferred outer exponents (see `_pair_fold`):
    the true bases are gg^{gam_g} / hh^{gam_h}, so the deferral rides
    the MSM scalars for free — gg_true^{a} == gg^{gam_g * a} — and the
    emitted L/R equal the eager-fold values bit for bit."""
    n2 = a.shape[0] // 2
    c_l = from_mont(FQ, fdot(a[:n2], b[n2:]))
    c_r = from_mont(FQ, fdot(a[n2:], b[:n2]))
    a_std = from_mont(FQ, mont_mul(FQ, a, gam_g_m[None]))
    b_std = from_mont(FQ, mont_mul(FQ, b, gam_h_m[None]))
    main = group.msm_many(
        jnp.stack([jnp.concatenate([gg[n2:], hh[:n2]]),
                   jnp.concatenate([gg[:n2], hh[n2:]])]),
        jnp.stack([jnp.concatenate([a_std[:n2], b_std[n2:]]),
                   jnp.concatenate([a_std[n2:], b_std[:n2]])]))
    return group.g_mul(main, _lr_extras(up, h_blind, c_l, c_r, rho_l, rho_r))


_pair_round_lr = execache.wrap("ipa_pair_round_lr", _pair_round_lr)


def _fold_halves(vec, lo_m, hi_m):
    n2 = vec.shape[0] // 2
    return add(FQ, mont_mul(FQ, vec[:n2], lo_m[None]),
               mont_mul(FQ, vec[n2:], hi_m[None]))


def _open_fold(a, b, gens, al_m, ali_m, al_std, ali_std):
    """a' = al*a_L + al^-1*a_R, b' = al^-1*b_L + al*b_R, gens' likewise.

    The generator fold runs as ONE g_pow square-and-multiply scan over
    both halves (the 61-round scan is latency-bound on small vectors, so
    one wide scan beats two narrow ones)."""
    n2 = a.shape[0] // 2
    a2 = _fold_halves(a, al_m, ali_m)
    b2 = _fold_halves(b, ali_m, al_m)
    exps = jnp.concatenate([jnp.broadcast_to(ali_std, (n2, 4)),
                            jnp.broadcast_to(al_std, (n2, 4))])
    powed = group.g_pow(gens, exps)
    g2 = group.g_mul(powed[:n2], powed[n2:])
    return a2, b2, g2


_open_fold = execache.wrap("ipa_open_fold", _open_fold)


def _open_fold_dispatch(a, b, gens, al_m, ali_m, al_std, ali_std):
    """`_open_fold`, routed through the Pallas `kernels/sumcheck_fold`
    backend when ZKDL_FOLD_BACKEND=pallas (`mle.fold_backend`): the two
    scalar halves-folds stream through `fold_halves` and the generator
    fold through the fused square-and-multiply `pow_mul_halves` kernel.
    Bit-identical to the XLA path (tests/test_fold_dispatch.py)."""
    if mle.fold_backend() == "pallas":
        from repro.kernels.sumcheck_fold import fold_halves, pow_mul_halves
        a2 = fold_halves(a, al_m, ali_m)
        b2 = fold_halves(b, ali_m, al_m)
        g2 = pow_mul_halves(gens, ali_std, al_std)
        return a2, b2, g2
    return _open_fold(a, b, gens, al_m, ali_m, al_std, ali_std)


def _pair_round_lr_w(gg, h_base, w, a, b, up, h_blind, rho_l, rho_r):
    """First pair round with the H basis held as h_base^{w} (the zkReLU
    H' = H^{1/e} basis, never materialized): the weight rides in the
    MSM exponents — hh_lo^{b_hi} == h_base_lo^{w_lo * b_hi} — so the
    result is bit-identical to `_pair_round_lr` on the explicit basis."""
    n2 = a.shape[0] // 2
    c_l = from_mont(FQ, fdot(a[:n2], b[n2:]))
    c_r = from_mont(FQ, fdot(a[n2:], b[:n2]))
    a_std = from_mont(FQ, a)
    wl = from_mont(FQ, mont_mul(FQ, w[:n2], b[n2:]))
    wr = from_mont(FQ, mont_mul(FQ, w[n2:], b[:n2]))
    main = group.msm_many(
        jnp.stack([jnp.concatenate([gg[n2:], h_base[:n2]]),
                   jnp.concatenate([gg[:n2], h_base[n2:]])]),
        jnp.stack([jnp.concatenate([a_std[:n2], wl]),
                   jnp.concatenate([a_std[n2:], wr])]))
    return group.g_mul(main, _lr_extras(up, h_blind, c_l, c_r, rho_l, rho_r))


_pair_round_lr_w = execache.wrap("ipa_pair_round_lr_w", _pair_round_lr_w)


def _pair_fold_first(a, b, g_table, h_table, w, al_m, ali_m,
                     al2_std, ali2_m):
    """First pair fold over FIXED bases via precomputed squaring tables
    (`group.pow_table`): one conditional multiply per exponent bit
    instead of square-and-multiply, with the H-side weight vector w
    folded into the table exponents.  Like `_pair_fold`, the outer
    exponents are DEFERRED (gam_g = ali, gam_h = al after this round):
    the G side materializes gg_lo * gg_hi^{al^2} — only the hi half of
    the table is powed — and the H side h_base^{w_lo | w_hi * ali^2}.
    Bit-identical to an eager fold of the materialized bases once the
    deferred exponents are applied."""
    n2 = a.shape[0] // 2
    a2 = _fold_halves(a, al_m, ali_m)
    b2 = _fold_halves(b, ali_m, al_m)
    powed_g = group.g_pow_table(g_table[:, n2:],
                                jnp.broadcast_to(al2_std, (n2, 4)))
    gg2 = group.g_mul(g_table[0, :n2], powed_g)
    w_coef = jnp.concatenate([jnp.broadcast_to(enc(1), (n2, 4)),
                              jnp.broadcast_to(ali2_m, (n2, 4))])
    h_exps = from_mont(FQ, mont_mul(FQ, w, w_coef))
    powed_h = group.g_pow_table(h_table, h_exps)
    hh2 = group.g_mul(powed_h[:n2], powed_h[n2:])
    return a2, b2, gg2, hh2


_pair_fold_first = execache.wrap("ipa_pair_fold_first", _pair_fold_first)


def _pair_fold(a, b, gg, hh, al_m, ali_m, al2_std, ali2_std):
    """Pair fold with the OUTER generator exponent deferred.

    The true folded bases are (gg_lo * gg_hi^{al^2})^{ali} and
    (hh_lo * hh_hi^{ali^2})^{al}; only the inner merges are
    materialized — ONE g_pow over n elements instead of 2n — while the
    outer ali / al accumulate into the per-statement deferred exponents
    gam_g / gam_h held as host ints by `pair_prove_many`.  Those fold
    into later L/R MSM scalars (two cheap field muls) and are applied
    once to the two surviving generators before the sigma finale, so
    every emitted group element is bit-identical to folding eagerly."""
    n2 = a.shape[0] // 2
    a2 = _fold_halves(a, al_m, ali_m)
    b2 = _fold_halves(b, ali_m, al_m)
    exps = jnp.concatenate([jnp.broadcast_to(al2_std, (n2, 4)),
                            jnp.broadcast_to(ali2_std, (n2, 4))])
    powed = group.g_pow(jnp.concatenate([gg[n2:], hh[n2:]]), exps)
    gg2 = group.g_mul(gg[:n2], powed[:n2])
    hh2 = group.g_mul(hh[:n2], powed[n2:])
    return a2, b2, gg2, hh2


_pair_fold = execache.wrap("ipa_pair_fold", _pair_fold)


# ---------------------------------------------------------------------------
# Ladder rounds: masked fixed-size bodies.
#
# A pair statement of width n runs log2(n) rounds over halving shapes;
# unrolled, that is 2*log2(n) distinct programs to trace and compile.
# The ladder instead buckets the rounds onto O(1) buffer sizes
# (`_ladder_size`) and runs ONE masked body per size: the live vectors
# occupy a prefix of length n <= S, the live hi half is gathered with a
# host-built index vector, and dead rows are masked to zero field
# elements / zero MSM exponents.  Zero exponents contribute exactly the
# identity (Pippenger substitutes the identity point for zero digits:
# `group._msm_core`) and zero field terms add nothing to the claim dots,
# so every emitted L/R — and therefore the transcript — is bit-identical
# to the exact-shape schedule (tests/test_fold_dispatch.py pins it).
# ---------------------------------------------------------------------------

def _ladder_size(n: int, n0: int) -> int:
    """Round-body buffer size for live length n of a statement that
    started at n0: the five widest rounds (where masked tail rows would
    cost real MSM work) run exact, the rest on a power-of-four descent
    down to an absolute floor of 32 rows.  A
    handful of distinct compiled bodies per statement width (and the
    executable cache makes each a once-per-machine cost); the masked
    tail a round carries is at most 3x its live rows, so the ladder's
    steady-state work stays within a constant of the exact schedule —
    an earlier clamp at n0/16 instead ran every narrow round on a
    n0/16-row buffer, which at merged-key widths made the masked MSMs
    dominate the whole opening phase."""
    if 16 * n >= n0:
        return n
    s = n0 // 16
    while s // 4 >= n and s // 4 >= 32:
        s //= 4
    return s


@functools.lru_cache(maxsize=None)
def _round_mask(n: int, S: int):
    """Gather index + live mask for a masked round: buffer size S, live
    prefix n.  idx_hi[i] = n/2 + i for live rows (dead gathers clamp to
    slot 0 — their exponents are masked to zero, so the gathered value
    never matters)."""
    h = S // 2
    idx = np.zeros(h, np.int32)
    idx[:n // 2] = n // 2 + np.arange(n // 2, dtype=np.int32)
    mask = np.zeros((h, 1), np.uint32)
    mask[:n // 2] = 1
    return jnp.asarray(idx), jnp.asarray(mask)


def _pair_round_lr_m(gg, hh, a, b, up, h_blind, rho_l, rho_r,
                     gam_g_m, gam_h_m, idx_hi, mask):
    """Masked fixed-size `_pair_round_lr` (same deferred gam_g/gam_h
    convention): exact-size rounds pass a degenerate all-live mask, so
    one compiled body serves every round bucketed to this size."""
    h = a.shape[0] // 2
    sel = mask.astype(bool)
    a_lo = jnp.where(sel, a[:h], 0)
    b_lo = jnp.where(sel, b[:h], 0)
    a_hi = jnp.where(sel, a[idx_hi], 0)
    b_hi = jnp.where(sel, b[idx_hi], 0)
    c_l = from_mont(FQ, fdot(a_lo, b_hi))
    c_r = from_mont(FQ, fdot(a_hi, b_lo))
    al_std = from_mont(FQ, mont_mul(FQ, a_lo, gam_g_m[None]))
    ah_std = from_mont(FQ, mont_mul(FQ, a_hi, gam_g_m[None]))
    bl_std = from_mont(FQ, mont_mul(FQ, b_lo, gam_h_m[None]))
    bh_std = from_mont(FQ, mont_mul(FQ, b_hi, gam_h_m[None]))
    main = group.msm_many(
        jnp.stack([jnp.concatenate([gg[idx_hi], hh[:h]]),
                   jnp.concatenate([gg[:h], hh[idx_hi]])]),
        jnp.stack([jnp.concatenate([al_std, bh_std]),
                   jnp.concatenate([ah_std, bl_std])]))
    return group.g_mul(main, _lr_extras(up, h_blind, c_l, c_r, rho_l, rho_r))


_pair_round_lr_m = execache.wrap("ipa_pair_round_lr_m", _pair_round_lr_m)


def _pair_fold_m(a, b, gg, hh, al_m, ali_m, al2_std, ali2_std,
                 idx_hi, mask):
    """Masked fixed-size `_pair_fold`: live outputs land in the prefix
    of the halved buffer; dead scalars fold to zero and dead generators
    to the identity, keeping the buffer invariants for later rounds."""
    h = a.shape[0] // 2
    sel = mask.astype(bool)
    a2 = jnp.where(sel, add(FQ, mont_mul(FQ, a[:h], al_m[None]),
                            mont_mul(FQ, a[idx_hi], ali_m[None])), 0)
    b2 = jnp.where(sel, add(FQ, mont_mul(FQ, b[:h], ali_m[None]),
                            mont_mul(FQ, b[idx_hi], al_m[None])), 0)
    exps = jnp.concatenate([jnp.broadcast_to(al2_std, (h, 4)),
                            jnp.broadcast_to(ali2_std, (h, 4))])
    powed = group.g_pow(jnp.concatenate([gg[idx_hi], hh[idx_hi]]), exps)
    one = group.identity()
    gg2 = jnp.where(sel, group.g_mul(gg[:h], powed[:h]), one[None])
    hh2 = jnp.where(sel, group.g_mul(hh[:h], powed[h:]), one[None])
    return a2, b2, gg2, hh2


_pair_fold_m = execache.wrap("ipa_pair_fold_m", _pair_fold_m)


def _resize_state(st, S: int) -> None:
    """Move a ladder statement's buffers to size S (slice down, or grow
    with neutral elements: zero scalars, identity generators)."""
    cur = st["a"].shape[0]
    if cur == S:
        return
    if cur > S:
        for k in ("a", "b", "gg", "hh"):
            st[k] = st[k][:S]
        return
    zero = jnp.zeros((S - cur, 4), jnp.uint32)
    onep = jnp.broadcast_to(group.identity(),
                            (S - cur, 4)).astype(jnp.uint32)
    st["a"] = jnp.concatenate([st["a"], zero])
    st["b"] = jnp.concatenate([st["b"], zero])
    st["gg"] = jnp.concatenate([st["gg"], onep])
    st["hh"] = jnp.concatenate([st["hh"], onep])


def _pair_rounds_ladder(st, transcript: Transcript,
                        rng: np.random.Generator) -> None:
    """All halving rounds of ONE pair statement on the size ladder.

    Draw order, transcript schedule and emitted L/R values are
    bit-identical to the single-statement lockstep path — only the
    compiled-program schedule differs."""
    n0 = st["n"]
    while st["n"] > 1:
        n = st["n"]
        rho_l = int(rng.integers(0, Q, dtype=np.uint64)) % Q
        rho_r = int(rng.integers(0, Q, dtype=np.uint64)) % Q
        if st["accel"] is not None:
            _, h_base, _, w = st["accel"]
            lr = _pair_round_lr_w(st["gg"], h_base, w, st["a"], st["b"],
                                  st["up"], st["hb"],
                                  _exp1(rho_l), _exp1(rho_r))
        else:
            idx_hi, mask = _round_mask(n, st["a"].shape[0])
            lr = _pair_round_lr_m(st["gg"], st["hh"], st["a"], st["b"],
                                  st["up"], st["hb"], _exp1(rho_l),
                                  _exp1(rho_r), enc(st["gam_g"]),
                                  enc(st["gam_h"]), idx_hi, mask)
        li, ri = group.decode_group_many(lr)
        st["ls"].append(li)
        st["rs"].append(ri)
        transcript.absorb_ints(b"ipa2/lr", [li, ri])
        al = transcript.challenge_int(b"ipa2/alpha", Q)
        ali = pow(al, Q - 2, Q)
        al2, ali2 = al * al % Q, ali * ali % Q
        if st["accel"] is not None:
            g_table, _, h_table, w = st["accel"]
            st["a"], st["b"], st["gg"], st["hh"] = _pair_fold_first(
                st["a"], st["b"], g_table, h_table, w, enc(al),
                enc(ali), _exp1(al2), enc(ali2))
            st["accel"] = None
        else:
            idx_hi, mask = _round_mask(n, st["a"].shape[0])
            st["a"], st["b"], st["gg"], st["hh"] = _pair_fold_m(
                st["a"], st["b"], st["gg"], st["hh"], enc(al), enc(ali),
                _exp1(al2), _exp1(ali2), idx_hi, mask)
        st["gam_g"] = st["gam_g"] * ali % Q
        st["gam_h"] = st["gam_h"] * al % Q
        st["rho"] = (al2 * rho_l + st["rho"] + ali2 * rho_r) % Q
        st["n"] = n // 2
        if st["n"] > 1:
            _resize_state(st, _ladder_size(st["n"], n0))


# ---------------------------------------------------------------------------
# Variant 1: committed a, public b.
# ---------------------------------------------------------------------------

def open_prove(key, a_mont, b_mont, blind: int, claim: int,
               transcript: Transcript, rng: np.random.Generator,
               prof=None) -> IpaProof:
    n = a_mont.shape[0]
    assert n & (n - 1) == 0 and b_mont.shape[0] == n
    gens = key.gens[:n]
    transcript.absorb_int(b"ipa/claim", claim)
    x = transcript.challenge_int(b"ipa/x", Q)
    up = group.g_pow_int(_u_gen(), x)

    a, b, rho = a_mont, b_mont, int(blind)
    ls, rs = [], []
    with _sub(prof, "ipa-rounds"):
        while n > 1:
            n2 = n // 2
            rho_l = int(rng.integers(0, Q, dtype=np.uint64)) % Q
            rho_r = int(rng.integers(0, Q, dtype=np.uint64)) % Q
            lr = _open_round_lr(gens, a, b, up, key.h,
                                _exp1(rho_l), _exp1(rho_r))
            li, ri = group.decode_group_many(lr)
            ls.append(li); rs.append(ri)
            transcript.absorb_ints(b"ipa/lr", [li, ri])
            al = transcript.challenge_int(b"ipa/alpha", Q)
            ali = pow(al, Q - 2, Q)
            a, b, gens = _open_fold_dispatch(a, b, gens, enc(al), enc(ali),
                                             _exp1(al), _exp1(ali))
            rho = (al * al % Q * rho_l + rho + ali * ali % Q * rho_r) % Q
            n = n2

    with _sub(prof, "sigma"):
        # final Schnorr opening of P_f = base^a h^rho, base = g_f up^{b_f}
        a_f, b_f = (int(v) for v in decode(FQ, jnp.stack([a[0], b[0]])))
        s = int(rng.integers(0, Q, dtype=np.uint64)) % Q
        s_rho = int(rng.integers(0, Q, dtype=np.uint64)) % Q
        # K = base^s h^{s_rho} = gens_f^s up^{s b_f} h^{s_rho}: one 3-term MSM
        kk = group.msm(jnp.stack([gens[0], up, key.h]),
                       group.exps_from_ints([s, s * b_f % Q, s_rho]))
        ki = group.decode_group(kk)
        transcript.absorb_int(b"ipa/K", ki)
        e = transcript.challenge_int(b"ipa/e", Q)
        z = (s + e * a_f) % Q
        z_rho = (s_rho + e * rho) % Q
    return IpaProof(ls, rs, [ki, z, z_rho])


def open_verify(key, com, b_mont, claim: int, proof: IpaProof,
                transcript: Transcript) -> bool:
    n = b_mont.shape[0]
    assert n & (n - 1) == 0
    gens = key.gens[:n]
    transcript.absorb_int(b"ipa/claim", claim)
    x = transcript.challenge_int(b"ipa/x", Q)
    up = group.g_pow_int(_u_gen(), x)
    p = group.g_mul(com, group.g_pow_int(up, claim))

    b = b_mont
    alphas = []
    for li, ri in zip(proof.ls, proof.rs):
        transcript.absorb_ints(b"ipa/lr", [li, ri])
        al = transcript.challenge_int(b"ipa/alpha", Q)
        ali = pow(al, Q - 2, Q)
        alphas.append(al)
        b = _fold_vec(b, ali, al)
        p = group.g_mul(p, group.msm(
            jnp.stack([group.encode_group(li), group.encode_group(ri)]),
            group.exps_from_ints([al * al % Q, ali * ali % Q])))

    s = _s_vector(n, alphas, low_exp_is_inv=True)
    g_f = group.msm_field(gens, s)
    b_f = _dec_scalar(b[0])
    base = group.g_mul(g_f, group.g_pow_int(up, b_f))
    ki, z, z_rho = proof.sigma
    transcript.absorb_int(b"ipa/K", ki)
    e = transcript.challenge_int(b"ipa/e", Q)
    lhs = group.g_mul(group.g_pow_int(base, z), group.g_pow_int(key.h, z_rho))
    rhs = group.g_mul(group.encode_group(ki), group.g_pow_int(p, e))
    return group.decode_group(lhs) == group.decode_group(rhs)


# ---------------------------------------------------------------------------
# Variant 2: both vectors committed as C = h^rho G^a H^b (zkReLU eq. 19).
#
# Independent pair statements sharing one transcript run their rounds in
# LOCKSTEP (`pair_prove_many`): each round dispatches every active
# statement's fused L/R multi-MSM asynchronously and pays ONE host
# transfer decoding all of them, so S statements cost max_i(rounds_i)
# round-trip syncs instead of sum_i(rounds_i) — the zkReLU validity
# argument's main + remainder IPAs are exactly this shape.  The
# per-statement arithmetic (and therefore extraction) is unchanged; only
# the transcript interleaving differs, mirrored by `pair_verify_many`.
# ---------------------------------------------------------------------------

def pair_prove_many(stmts, transcript: Transcript,
                    rng: np.random.Generator,
                    prof=None) -> List[IpaProof]:
    """Prove S pair statements with interleaved rounds.

    ``stmts`` is a list of ``(g_gens, h_gens, h_blind, a_mont, b_mont,
    blind, claim)``, optionally extended with an 8th element
    ``accel = (g_table, h_base, h_table, w_mont)`` declaring that both
    bases are FIXED with precomputed squaring tables and that the true
    H basis is ``h_base^{w}`` (zkReLU's H' = H^{1/e}) — the first round
    then runs `_pair_round_lr_w` / `_pair_fold_first` without ever
    materializing H', bit-identically to the explicit path.  Transcript
    order per round: each active statement's (L, R) is absorbed and its
    alpha drawn, statement by statement in list order.  ``prof`` is an
    optional `PhaseProfile`: rounds book under the "ipa-rounds"
    sub-phase, the sigma finales under "sigma"."""
    states = []
    for stmt in stmts:
        gg, hh, hb, a, b, blind, claim = stmt[:7]
        accel = stmt[7] if len(stmt) > 7 else None
        n = a.shape[0]
        assert n & (n - 1) == 0 and b.shape[0] == n
        # an accel statement needs >= 1 round: the fold is what
        # materializes hh for the sigma finale
        assert accel is None or n > 1, "accel statement needs n >= 2"
        transcript.absorb_int(b"ipa2/claim", claim)
        x = transcript.challenge_int(b"ipa2/x", Q)
        states.append({"n": n, "gg": gg[:n],
                       "hh": hh[:n] if hh is not None else None,
                       "hb": hb, "a": a, "b": b, "rho": int(blind),
                       "up": group.g_pow_int(_u_gen(), x),
                       "accel": accel, "ls": [], "rs": [],
                       # deferred outer exponents: true bases are
                       # gg^{gam_g} / hh^{gam_h} (see `_pair_fold`)
                       "gam_g": 1, "gam_h": 1})

    # single-statement proofs (the aggregated pipeline's merged opening)
    # run the masked size-ladder rounds: O(1) compiled bodies instead of
    # 2 per halving shape, bit-identical transcripts (see above)
    ladder = len(states) == 1 and round_mode() == "ladder"
    with _sub(prof, "ipa-rounds"):
        if ladder:
            _pair_rounds_ladder(states[0], transcript, rng)
        while any(st["n"] > 1 for st in states):
            active = [st for st in states if st["n"] > 1]
            lrs, blind_draws = [], []
            for st in active:
                rho_l = int(rng.integers(0, Q, dtype=np.uint64)) % Q
                rho_r = int(rng.integers(0, Q, dtype=np.uint64)) % Q
                blind_draws.append((rho_l, rho_r))
                if st["accel"] is not None:
                    _, h_base, _, w = st["accel"]
                    lrs.append(_pair_round_lr_w(st["gg"], h_base, w, st["a"],
                                                st["b"], st["up"], st["hb"],
                                                _exp1(rho_l), _exp1(rho_r)))
                else:
                    lrs.append(_pair_round_lr(st["gg"], st["hh"], st["a"],
                                              st["b"], st["up"], st["hb"],
                                              _exp1(rho_l), _exp1(rho_r),
                                              enc(st["gam_g"]),
                                              enc(st["gam_h"])))
            flat = group.decode_group_many(jnp.concatenate(lrs))  # 1 transfer
            for k, (st, (rho_l, rho_r)) in enumerate(zip(active,
                                                         blind_draws)):
                li, ri = flat[2 * k], flat[2 * k + 1]
                st["ls"].append(li); st["rs"].append(ri)
                transcript.absorb_ints(b"ipa2/lr", [li, ri])
                al = transcript.challenge_int(b"ipa2/alpha", Q)
                ali = pow(al, Q - 2, Q)
                al2, ali2 = al * al % Q, ali * ali % Q
                if st["accel"] is not None:
                    g_table, _, h_table, w = st["accel"]
                    st["a"], st["b"], st["gg"], st["hh"] = _pair_fold_first(
                        st["a"], st["b"], g_table, h_table, w, enc(al),
                        enc(ali), _exp1(al2), enc(ali2))
                    st["accel"] = None
                else:
                    st["a"], st["b"], st["gg"], st["hh"] = _pair_fold(
                        st["a"], st["b"], st["gg"], st["hh"], enc(al),
                        enc(ali), _exp1(al2), _exp1(ali2))
                st["gam_g"] = st["gam_g"] * ali % Q
                st["gam_h"] = st["gam_h"] * al % Q
                st["rho"] = (al2 * rho_l + st["rho"] + ali2 * rho_r) % Q
                st["n"] //= 2

    with _sub(prof, "sigma"):
        # apply the deferred outer exponents to the two surviving
        # generators of every statement in ONE batched g_pow (a gam of 1
        # — no rounds, or already materialized — is an exact no-op)
        gam_fin = group.g_pow(
            jnp.stack([st[k][0] for st in states for k in ("gg", "hh")]),
            jnp.stack([_exp1(st[g]) for st in states
                       for g in ("gam_g", "gam_h")]))
        for i, st in enumerate(states):
            st["gg"] = gam_fin[2 * i][None]
            st["hh"] = gam_fin[2 * i + 1][None]
        # sigma finales: ALL statements' folded scalars decode in one
        # transfer, and every A/B commitment rides one batched multi-MSM
        finals = decode(FQ, jnp.stack([st[k][0] for st in states
                                       for k in ("a", "b")]))
        one = group.identity()
        pts, exps, sigmas = [], [], []
        for i, st in enumerate(states):
            a_f, b_f = int(finals[2 * i]), int(finals[2 * i + 1])
            s_a = int(rng.integers(0, Q, dtype=np.uint64)) % Q
            s_b = int(rng.integers(0, Q, dtype=np.uint64)) % Q
            s_rho = int(rng.integers(0, Q, dtype=np.uint64)) % Q
            t_rho = int(rng.integers(0, Q, dtype=np.uint64)) % Q
            # A = g_f^{s_a} h_f^{s_b} up^{a_f s_b + b_f s_a} h^{s_rho}
            # B = up^{s_a s_b} h^{t_rho}
            pts.append(jnp.stack([st["gg"][0], st["hh"][0], st["up"],
                                  st["hb"]]))
            pts.append(jnp.stack([st["up"], st["hb"], one, one]))
            exps.append(group.exps_from_ints(
                [s_a, s_b, (a_f * s_b + b_f * s_a) % Q, s_rho]))
            exps.append(group.exps_from_ints([s_a * s_b % Q, t_rho, 0, 0]))
            sigmas.append((a_f, b_f, s_a, s_b, s_rho, t_rho))
        ab_flat = group.decode_group_many(
            group.msm_many(jnp.stack(pts), jnp.stack(exps)))

        proofs = []
        for i, st in enumerate(states):
            a_f, b_f, s_a, s_b, s_rho, t_rho = sigmas[i]
            ai, bi = ab_flat[2 * i], ab_flat[2 * i + 1]
            transcript.absorb_ints(b"ipa2/AB", [ai, bi])
            e = transcript.challenge_int(b"ipa2/e", Q)
            z_a = (a_f * e + s_a) % Q
            z_b = (b_f * e + s_b) % Q
            z_rho = (st["rho"] * e % Q * e + s_rho * e + t_rho) % Q
            proofs.append(IpaProof(st["ls"], st["rs"],
                                   [ai, bi, z_a, z_b, z_rho]))
        return proofs


def pair_verify_many(stmts, proofs: List[IpaProof],
                     transcript: Transcript) -> bool:
    """Verify S pair statements proven by `pair_prove_many`.

    ``stmts`` is a list of ``(g_gens, h_gens, h_blind, com, claim, n)``;
    replays the interleaved transcript schedule and checks every sigma
    equation (all group comparisons decode in one transfer)."""
    states = []
    for (gg, hh, hb, com, claim, n), proof in zip(stmts, proofs):
        assert n & (n - 1) == 0
        transcript.absorb_int(b"ipa2/claim", claim)
        x = transcript.challenge_int(b"ipa2/x", Q)
        up = group.g_pow_int(_u_gen(), x)
        if len(proof.ls) != n.bit_length() - 1 or \
                len(proof.rs) != len(proof.ls):
            return False
        states.append({"n": n, "n0": n, "gg": gg, "hh": hh, "hb": hb,
                       "up": up, "proof": proof, "round": 0, "alphas": [],
                       "p": group.g_mul(com, group.g_pow_int(up, claim))})

    while any(st["n"] > 1 for st in states):
        for st in states:
            if st["n"] <= 1:
                continue
            li = st["proof"].ls[st["round"]]
            ri = st["proof"].rs[st["round"]]
            transcript.absorb_ints(b"ipa2/lr", [li, ri])
            al = transcript.challenge_int(b"ipa2/alpha", Q)
            ali = pow(al, Q - 2, Q)
            st["alphas"].append(al)
            st["p"] = group.g_mul(st["p"], group.msm(
                jnp.stack([group.encode_group(li),
                           group.encode_group(ri)]),
                group.exps_from_ints([al * al % Q, ali * ali % Q])))
            st["round"] += 1
            st["n"] //= 2

    sides = []
    for st in states:
        n = st["n0"]
        s = _s_vector(n, st["alphas"], low_exp_is_inv=True)
        s_inv = _s_vector(n, st["alphas"], low_exp_is_inv=False)
        g_f = group.msm_field(st["gg"][:n], s)
        h_f = group.msm_field(st["hh"][:n], s_inv)
        if len(st["proof"].sigma) != 5:
            return False
        ai, bi, z_a, z_b, z_rho = st["proof"].sigma
        transcript.absorb_ints(b"ipa2/AB", [ai, bi])
        e = transcript.challenge_int(b"ipa2/e", Q)
        lhs = group.g_mul(
            group.g_mul(group.g_pow_int(st["p"], e * e % Q),
                        group.g_pow_int(group.encode_group(ai), e)),
            group.encode_group(bi))
        rhs = group.g_mul(
            group.g_mul(group.g_pow_int(g_f, z_a * e % Q),
                        group.g_pow_int(h_f, z_b * e % Q)),
            group.g_mul(group.g_pow_int(st["up"], z_a * z_b % Q),
                        group.g_pow_int(st["hb"], z_rho)))
        sides.extend([lhs, rhs])
    flat = group.decode_group_many(jnp.stack(sides))
    return all(flat[2 * i] == flat[2 * i + 1] for i in range(len(states)))


def pair_prove(g_gens, h_gens, h_blind, a_mont, b_mont, blind: int, claim: int,
               transcript: Transcript, rng: np.random.Generator) -> IpaProof:
    """Single-statement pair argument (S=1 lockstep degenerates to the
    classic sequential schedule)."""
    (proof,) = pair_prove_many(
        [(g_gens, h_gens, h_blind, a_mont, b_mont, blind, claim)],
        transcript, rng)
    return proof


def pair_verify(g_gens, h_gens, h_blind, com, claim: int, proof: IpaProof,
                transcript: Transcript, n: int) -> bool:
    return pair_verify_many([(g_gens, h_gens, h_blind, com, claim, n)],
                            [proof], transcript)
