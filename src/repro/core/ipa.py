"""Zero-knowledge inner-product arguments (Bulletproofs [45] style).

Two variants, both log-size and linear-prover-time:

* ``open_*``: proves <a, b_pub> = c for a *committed* vector a and a
  *public* vector b (the MLE-opening workhorse: b = e(u)).
* ``pair_*``: proves <a, b> = c where BOTH vectors are bound inside one
  commitment C = h^rho G^a H^b -- exactly the statement produced by
  Algorithm 1 for the zkReLU validity equation (19).

Honest-verifier zero knowledge comes from per-round blinding factors on
L/R plus a final Schnorr/sigma opening instead of revealing the folded
scalars.  The prover is JAX (limb arrays); the verifier mixes host ints
with vectorized JAX for the O(n) generator folds.

Prover rounds are FUSED: each round issues exactly one jitted multi-MSM
for the L/R cross terms (the two half-length MSMs, the u^{c} claim term
and the h^{rho} blind ride as extra rows/columns of `group.msm_many`),
one host transfer decoding both L and R, and one jitted fold of every
vector/generator half -- instead of the ~20 eager group-op dispatches
the unfused path paid per round.  All arithmetic is bit-identical to the
unfused primitives (`tests/test_ipa.py` pins the parity, blinds
included), so transcripts do not change.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.field import FQ, FP, add, mont_mul, from_mont, decode, int_to_limbs
from repro.core import group
from repro.core.mle import enc, fdot
from repro.core.transcript import Transcript

Q = FQ.modulus


@dataclasses.dataclass
class IpaProof:
    ls: List[int]
    rs: List[int]
    # final sigma-protocol messages
    sigma: List[int]

    def size_bytes(self) -> int:
        return 32 * (len(self.ls) + len(self.rs) + len(self.sigma))


def _dec_scalar(x) -> int:
    return int(decode(FQ, x)[()])


def _g_pow_const(bases, e: int):
    """bases^e elementwise for one python-int exponent (jitted via g_pow)."""
    from repro.field import int_to_limbs
    e = int(e) % Q
    exps = jnp.broadcast_to(jnp.asarray(int_to_limbs(e)), bases.shape)
    return group.g_pow(bases, exps)


def _fold_vec(t, lo_coef: int, hi_coef: int):
    n2 = t.shape[0] // 2
    lo = mont_mul(FQ, t[:n2], enc(lo_coef)[None])
    hi = mont_mul(FQ, t[n2:], enc(hi_coef)[None])
    return add(FQ, lo, hi)


def _fold_gens(g, lo_exp: int, hi_exp: int):
    n2 = g.shape[0] // 2
    return group.g_mul(_g_pow_const(g[:n2], lo_exp), _g_pow_const(g[n2:], hi_exp))


def _s_vector(n: int, alphas: List[int], low_exp_is_inv: bool):
    """s_i = prod_j (alpha_j or its inverse) by the top-bit split pattern."""
    rounds = len(alphas)
    s = jnp.broadcast_to(enc(1), (n, 4)).astype(jnp.uint32)
    idx = np.arange(n)
    for j, a in enumerate(alphas):
        ai = pow(a, Q - 2, Q)
        lo, hi = (ai, a) if low_exp_is_inv else (a, ai)
        bit = (idx >> (rounds - 1 - j)) & 1
        coef = jnp.where(jnp.asarray(bit[:, None] == 0), enc(lo)[None], enc(hi)[None])
        s = mont_mul(FQ, s, coef)
    return s


def _u_gen():
    return group.derive_generators(b"zkdl/ipa-u", 1)[0]


# ---------------------------------------------------------------------------
# Fused prover rounds (one multi-MSM + one fold dispatch per round).
# ---------------------------------------------------------------------------

def _exp1(e: int) -> jnp.ndarray:
    """One python-int exponent (mod q) -> (4,) standard-form limbs."""
    return jnp.asarray(int_to_limbs(int(e) % Q))


def _lr_extras(up, h, c_l, c_r, rho_l, rho_r):
    """The up^{claim} * h^{rho} tails of both L/R as a tiny two-row MSM
    (kept OUT of the main MSM so its row length stays a power of two --
    appending two columns would force the Pippenger pad to the next
    power of four, quadrupling the sort width)."""
    pts = jnp.broadcast_to(jnp.stack([up, h])[None], (2, 2, 4))
    exps = jnp.stack([jnp.stack([c_l, rho_l]), jnp.stack([c_r, rho_r])])
    return group.msm_many(pts, exps)


@jax.jit
def _open_round_lr(gens, a, b, up, h, rho_l, rho_r):
    """L/R of one `open` round fused into one executable:

    L = gens_hi^{a_lo} * up^{<a_lo, b_hi>} * h^{rho_l}
    R = gens_lo^{a_hi} * up^{<a_hi, b_lo>} * h^{rho_r}
    """
    n2 = a.shape[0] // 2
    c_l = from_mont(FQ, fdot(a[:n2], b[n2:]))
    c_r = from_mont(FQ, fdot(a[n2:], b[:n2]))
    a_std = from_mont(FQ, a)
    main = group.msm_many(jnp.stack([gens[n2:], gens[:n2]]),
                          jnp.stack([a_std[:n2], a_std[n2:]]))
    return group.g_mul(main, _lr_extras(up, h, c_l, c_r, rho_l, rho_r))


@jax.jit
def _pair_round_lr(gg, hh, a, b, up, h_blind, rho_l, rho_r):
    """L/R of one `pair` round: both half-MSMs per side fused into one row."""
    n2 = a.shape[0] // 2
    c_l = from_mont(FQ, fdot(a[:n2], b[n2:]))
    c_r = from_mont(FQ, fdot(a[n2:], b[:n2]))
    a_std = from_mont(FQ, a)
    b_std = from_mont(FQ, b)
    main = group.msm_many(
        jnp.stack([jnp.concatenate([gg[n2:], hh[:n2]]),
                   jnp.concatenate([gg[:n2], hh[n2:]])]),
        jnp.stack([jnp.concatenate([a_std[:n2], b_std[n2:]]),
                   jnp.concatenate([a_std[n2:], b_std[:n2]])]))
    return group.g_mul(main, _lr_extras(up, h_blind, c_l, c_r, rho_l, rho_r))


def _fold_halves(vec, lo_m, hi_m):
    n2 = vec.shape[0] // 2
    return add(FQ, mont_mul(FQ, vec[:n2], lo_m[None]),
               mont_mul(FQ, vec[n2:], hi_m[None]))


@jax.jit
def _open_fold(a, b, gens, al_m, ali_m, al_std, ali_std):
    """a' = al*a_L + al^-1*a_R, b' = al^-1*b_L + al*b_R, gens' likewise.

    The generator fold runs as ONE g_pow square-and-multiply scan over
    both halves (the 61-round scan is latency-bound on small vectors, so
    one wide scan beats two narrow ones)."""
    n2 = a.shape[0] // 2
    a2 = _fold_halves(a, al_m, ali_m)
    b2 = _fold_halves(b, ali_m, al_m)
    exps = jnp.concatenate([jnp.broadcast_to(ali_std, (n2, 4)),
                            jnp.broadcast_to(al_std, (n2, 4))])
    powed = group.g_pow(gens, exps)
    g2 = group.g_mul(powed[:n2], powed[n2:])
    return a2, b2, g2


@jax.jit
def _pair_fold(a, b, gg, hh, al_m, ali_m, al_std, ali_std):
    n2 = a.shape[0] // 2
    a2 = _fold_halves(a, al_m, ali_m)
    b2 = _fold_halves(b, ali_m, al_m)
    exps = jnp.concatenate([jnp.broadcast_to(ali_std, (n2, 4)),
                            jnp.broadcast_to(al_std, (n2, 4)),
                            jnp.broadcast_to(al_std, (n2, 4)),
                            jnp.broadcast_to(ali_std, (n2, 4))])
    powed = group.g_pow(jnp.concatenate([gg, hh]), exps)
    gg2 = group.g_mul(powed[:n2], powed[n2:2 * n2])
    hh2 = group.g_mul(powed[2 * n2:3 * n2], powed[3 * n2:])
    return a2, b2, gg2, hh2


# ---------------------------------------------------------------------------
# Variant 1: committed a, public b.
# ---------------------------------------------------------------------------

def open_prove(key, a_mont, b_mont, blind: int, claim: int,
               transcript: Transcript, rng: np.random.Generator) -> IpaProof:
    n = a_mont.shape[0]
    assert n & (n - 1) == 0 and b_mont.shape[0] == n
    gens = key.gens[:n]
    transcript.absorb_int(b"ipa/claim", claim)
    x = transcript.challenge_int(b"ipa/x", Q)
    up = group.g_pow_int(_u_gen(), x)

    a, b, rho = a_mont, b_mont, int(blind)
    ls, rs = [], []
    while n > 1:
        n2 = n // 2
        rho_l = int(rng.integers(0, Q, dtype=np.uint64)) % Q
        rho_r = int(rng.integers(0, Q, dtype=np.uint64)) % Q
        lr = _open_round_lr(gens, a, b, up, key.h,
                            _exp1(rho_l), _exp1(rho_r))
        li, ri = group.decode_group_many(lr)
        ls.append(li); rs.append(ri)
        transcript.absorb_ints(b"ipa/lr", [li, ri])
        al = transcript.challenge_int(b"ipa/alpha", Q)
        ali = pow(al, Q - 2, Q)
        a, b, gens = _open_fold(a, b, gens, enc(al), enc(ali),
                                _exp1(al), _exp1(ali))
        rho = (al * al % Q * rho_l + rho + ali * ali % Q * rho_r) % Q
        n = n2

    # final Schnorr opening of P_f = base^{a} h^{rho}, base = g_f * up^{b_f}
    a_f, b_f = (int(v) for v in decode(FQ, jnp.stack([a[0], b[0]])))
    s = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    s_rho = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    # K = base^s h^{s_rho} = gens_f^s * up^{s b_f} * h^{s_rho}: one 3-term MSM
    kk = group.msm(jnp.stack([gens[0], up, key.h]),
                   group.exps_from_ints([s, s * b_f % Q, s_rho]))
    ki = group.decode_group(kk)
    transcript.absorb_int(b"ipa/K", ki)
    e = transcript.challenge_int(b"ipa/e", Q)
    z = (s + e * a_f) % Q
    z_rho = (s_rho + e * rho) % Q
    return IpaProof(ls, rs, [ki, z, z_rho])


def open_verify(key, com, b_mont, claim: int, proof: IpaProof,
                transcript: Transcript) -> bool:
    n = b_mont.shape[0]
    assert n & (n - 1) == 0
    gens = key.gens[:n]
    transcript.absorb_int(b"ipa/claim", claim)
    x = transcript.challenge_int(b"ipa/x", Q)
    up = group.g_pow_int(_u_gen(), x)
    p = group.g_mul(com, group.g_pow_int(up, claim))

    b = b_mont
    alphas = []
    for li, ri in zip(proof.ls, proof.rs):
        transcript.absorb_ints(b"ipa/lr", [li, ri])
        al = transcript.challenge_int(b"ipa/alpha", Q)
        ali = pow(al, Q - 2, Q)
        alphas.append(al)
        b = _fold_vec(b, ali, al)
        p = group.g_mul(p, group.msm(
            jnp.stack([group.encode_group(li), group.encode_group(ri)]),
            group.exps_from_ints([al * al % Q, ali * ali % Q])))

    s = _s_vector(n, alphas, low_exp_is_inv=True)
    g_f = group.msm_field(gens, s)
    b_f = _dec_scalar(b[0])
    base = group.g_mul(g_f, group.g_pow_int(up, b_f))
    ki, z, z_rho = proof.sigma
    transcript.absorb_int(b"ipa/K", ki)
    e = transcript.challenge_int(b"ipa/e", Q)
    lhs = group.g_mul(group.g_pow_int(base, z), group.g_pow_int(key.h, z_rho))
    rhs = group.g_mul(group.encode_group(ki), group.g_pow_int(p, e))
    return group.decode_group(lhs) == group.decode_group(rhs)


# ---------------------------------------------------------------------------
# Variant 2: both vectors committed as C = h^rho G^a H^b (zkReLU eq. 19).
# ---------------------------------------------------------------------------

def pair_prove(g_gens, h_gens, h_blind, a_mont, b_mont, blind: int, claim: int,
               transcript: Transcript, rng: np.random.Generator) -> IpaProof:
    n = a_mont.shape[0]
    assert n & (n - 1) == 0 and b_mont.shape[0] == n
    transcript.absorb_int(b"ipa2/claim", claim)
    x = transcript.challenge_int(b"ipa2/x", Q)
    up = group.g_pow_int(_u_gen(), x)

    a, b, rho = a_mont, b_mont, int(blind)
    gg, hh = g_gens[:n], h_gens[:n]
    ls, rs = [], []
    while n > 1:
        n2 = n // 2
        rho_l = int(rng.integers(0, Q, dtype=np.uint64)) % Q
        rho_r = int(rng.integers(0, Q, dtype=np.uint64)) % Q
        lr = _pair_round_lr(gg, hh, a, b, up, h_blind,
                            _exp1(rho_l), _exp1(rho_r))
        li, ri = group.decode_group_many(lr)
        ls.append(li); rs.append(ri)
        transcript.absorb_ints(b"ipa2/lr", [li, ri])
        al = transcript.challenge_int(b"ipa2/alpha", Q)
        ali = pow(al, Q - 2, Q)
        a, b, gg, hh = _pair_fold(a, b, gg, hh, enc(al), enc(ali),
                                  _exp1(al), _exp1(ali))
        rho = (al * al % Q * rho_l + rho + ali * ali % Q * rho_r) % Q
        n = n2

    a_f, b_f = (int(v) for v in decode(FQ, jnp.stack([a[0], b[0]])))
    s_a = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    s_b = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    s_rho = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    t_rho = int(rng.integers(0, Q, dtype=np.uint64)) % Q
    # A = g_f^{s_a} h_f^{s_b} up^{a_f s_b + b_f s_a} h^{s_rho}
    # B = up^{s_a s_b} h^{t_rho}: one two-row multi-MSM, one decode
    one = group.identity()
    pts = jnp.stack([
        jnp.stack([gg[0], hh[0], up, h_blind]),
        jnp.stack([up, h_blind, one, one])])
    exps = jnp.stack([
        group.exps_from_ints([s_a, s_b, (a_f * s_b + b_f * s_a) % Q, s_rho]),
        group.exps_from_ints([s_a * s_b % Q, t_rho, 0, 0])])
    ai, bi = group.decode_group_many(group.msm_many(pts, exps))
    transcript.absorb_ints(b"ipa2/AB", [ai, bi])
    e = transcript.challenge_int(b"ipa2/e", Q)
    z_a = (a_f * e + s_a) % Q
    z_b = (b_f * e + s_b) % Q
    z_rho = (rho * e % Q * e + s_rho * e + t_rho) % Q
    return IpaProof(ls, rs, [ai, bi, z_a, z_b, z_rho])


def pair_verify(g_gens, h_gens, h_blind, com, claim: int, proof: IpaProof,
                transcript: Transcript, n: int) -> bool:
    assert n & (n - 1) == 0
    transcript.absorb_int(b"ipa2/claim", claim)
    x = transcript.challenge_int(b"ipa2/x", Q)
    up = group.g_pow_int(_u_gen(), x)
    p = group.g_mul(com, group.g_pow_int(up, claim))

    alphas = []
    for li, ri in zip(proof.ls, proof.rs):
        transcript.absorb_ints(b"ipa2/lr", [li, ri])
        al = transcript.challenge_int(b"ipa2/alpha", Q)
        ali = pow(al, Q - 2, Q)
        alphas.append(al)
        p = group.g_mul(p, group.msm(
            jnp.stack([group.encode_group(li), group.encode_group(ri)]),
            group.exps_from_ints([al * al % Q, ali * ali % Q])))

    s = _s_vector(n, alphas, low_exp_is_inv=True)
    s_inv = _s_vector(n, alphas, low_exp_is_inv=False)
    g_f = group.msm_field(g_gens[:n], s)
    h_f = group.msm_field(h_gens[:n], s_inv)
    ai, bi, z_a, z_b, z_rho = proof.sigma
    transcript.absorb_ints(b"ipa2/AB", [ai, bi])
    e = transcript.challenge_int(b"ipa2/e", Q)
    lhs = group.g_mul(
        group.g_mul(group.g_pow_int(p, e * e % Q),
                    group.g_pow_int(group.encode_group(ai), e)),
        group.encode_group(bi))
    rhs = group.g_mul(
        group.g_mul(group.g_pow_int(g_f, z_a * e % Q),
                    group.g_pow_int(h_f, z_b * e % Q)),
        group.g_mul(group.g_pow_int(up, z_a * z_b % Q),
                    group.g_pow_int(h_blind, z_rho)))
    return group.decode_group(lhs) == group.decode_group(rhs)
