"""Pedersen vector commitments (Section 3.1 of zkDL).

Commit(v; r) = h^r * prod_i g_i^{v_i} over the order-q subgroup of F_p^*.
Homomorphic: com(v1;r1) * com(v2;r2) = com(v1+v2; r1+r2), and
com(v;r)^k = com(k*v; k*r) -- both used heavily by zkReLU (Algorithm 1)
and by the claim-batching in Protocol 2.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.field import FQ, from_mont
from repro.core import group

Q = FQ.modulus


@dataclasses.dataclass(frozen=True)
class CommitKey:
    gens: jnp.ndarray        # (n, 4) group elements (Montgomery form)
    h: jnp.ndarray           # (4,) blinding generator
    label: bytes

    @property
    def n(self) -> int:
        return int(self.gens.shape[0])

    def slice(self, start: int, stop: int) -> "CommitKey":
        return CommitKey(self.gens[start:stop], self.h, self.label)


def make_key(label: bytes, n: int) -> CommitKey:
    gens = group.derive_generators(b"zkdl/gens/" + label, n)
    h = group.derive_generators(b"zkdl/blind/" + label, 1)[0]
    return CommitKey(gens, h, label)


def commit_many(rows):
    """R Pedersen commitments in ONE multi-MSM dispatch -> (R, 4) elements.

    ``rows`` is a list of ``(key, values_mont, blind)`` triples; rows may
    use different keys and different vector lengths (shorter rows pad
    with zero exponents, which Pippenger skips).  Each row's blind rides
    as one extra ``(h, blind)`` term of its own MSM, so row r equals
    ``commit(key_r, values_r, blind_r)`` bit-for-bit while the whole
    batch is a single `group.msm_many` executable.  There is deliberately
    no ``nbits`` knob: the blind columns are full-width scalars, so the
    shared window schedule must always cover 61 bits.
    """
    vals = [v.reshape(-1, 4) for _, v, _ in rows]
    n_max = max(v.shape[0] for v in vals)
    one = group.identity()
    pts, exps = [], []
    for (key, _, _), v in zip(rows, vals):
        n = v.shape[0]
        assert n <= key.n, (n, key.n)
        pad = n_max - n
        pts.append(jnp.concatenate(
            [key.gens[:n]]
            + ([jnp.broadcast_to(one, (pad, 4)).astype(jnp.uint32)] if pad else [])
            + [key.h[None]]))
        exps.append(jnp.concatenate(
            [v] + ([jnp.zeros((pad, 4), jnp.uint32)] if pad else [])))
    exps_std = from_mont(FQ, jnp.stack(exps))
    blind_std = group.exps_from_ints([int(b) % Q for _, _, b in rows])
    exps_std = jnp.concatenate([exps_std, blind_std[:, None, :]], axis=1)
    return group.msm_many(jnp.stack(pts), exps_std)


def commit(key: CommitKey, values_mont, blind: int, nbits: int = 61):
    """Commit to an FQ vector (Montgomery limb form). Returns group element."""
    values_mont = values_mont.reshape(-1, 4)
    n = values_mont.shape[0]
    assert n <= key.n, (n, key.n)
    acc = group.msm(key.gens[:n], from_mont(FQ, values_mont), nbits=nbits)
    if blind:
        acc = group.g_mul(acc, group.g_pow_int(key.h, blind))
    return acc


def commit_bits(key: CommitKey, bits, blind: int):
    """Commit to a 0/1 vector: selection product, no exponentiation."""
    bits = jnp.asarray(bits).reshape(-1)
    acc = group.msm_bits(key.gens[: bits.shape[0]], bits)
    if blind:
        acc = group.g_mul(acc, group.g_pow_int(key.h, blind))
    return acc


def commit_ints(key: CommitKey, ints, blind: int, nbits: int = 61):
    """Commit to python/np ints (taken mod q)."""
    exps = group.exps_from_ints([int(v) for v in np.asarray(ints, dtype=object).reshape(-1)])
    acc = group.msm(key.gens[: exps.shape[0]], exps, nbits=nbits)
    if blind:
        acc = group.g_mul(acc, group.g_pow_int(key.h, blind))
    return acc
