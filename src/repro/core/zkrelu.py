"""zkReLU: batched validity proofs for the auxiliary inputs (Section 4.1).

Given the stacked (over layers) auxiliary tensors

    Z''  in [0, 2^{Q-1})^Ds        B_{Q-1} in {0,1}^Ds
    G_A' in [-2^{Q-1}, 2^{Q-1})^Ds
    R_Z, R_GA in [0, 2^R)^Ds

the prover commits to the bit matrices

    B  = [[bits(Z'') | 0], [signed-bits(G_A')]]   in {0,1}^{2Ds x Q}
    B' = B - 1 (except the forced-zero column, which stays 0)

via com_B^ip = h^r G^B H^{B'} (Protocol 1), and proves the single combined
inner-product relation (19)

    < B_k - z 1,  z^2 (e_relu (x) s_Q) + (z 1 + B'_k) . (e_relu (x) e_bit) >
        = z^3 - (1 - v_k) z^2 + z v'_k

with B_k = B + k \bar{B}_{Q-1}, via the commitment transformation of
Algorithm 1 followed by the two-sided zero-knowledge IPA.  Theorem 4.1
gives soundness: acceptance implies all range constraints hold.

The remainders R_Z / R_GA use the identical machinery with an unsigned
R-bit s-vector and no k-term (their own (19)-analogue), as the paper's
"combined ... using random linear combinations" step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.field import FQ, FP, add, sub, mont_mul, pow_const, batch_inv, encode_ints, decode
from repro.core import group, ipa
from repro.core.mle import enc, enc_vec, expand_point, hexpand_point, hmul, hadd, hsub
from repro.core.transcript import Transcript

Q_MOD = FQ.modulus
P_MOD = FP.modulus


def _rand_scalar(rng) -> int:
    return int(rng.integers(0, Q_MOD, dtype=np.uint64)) % Q_MOD


def bits_unsigned(v: np.ndarray, nbits: int) -> np.ndarray:
    """(n,) int64 in [0, 2^nbits) -> (n, nbits) 0/1 int8."""
    assert (v >= 0).all() and (v < (1 << nbits)).all()
    out = np.zeros((v.shape[0], nbits), dtype=np.int8)
    for j in range(nbits):
        out[:, j] = (v >> j) & 1
    return out


def bits_signed(v: np.ndarray, nbits: int) -> np.ndarray:
    """(n,) int64 in [-2^{nbits-1}, 2^{nbits-1}) -> (n, nbits) two's compl."""
    lim = 1 << (nbits - 1)
    assert (v >= -lim).all() and (v < lim).all()
    u = np.where(v < 0, v + (1 << nbits), v).astype(np.int64)
    out = np.zeros((v.shape[0], nbits), dtype=np.int8)
    for j in range(nbits):
        out[:, j] = (u >> j) & 1
    return out


@dataclasses.dataclass(frozen=True)
class ValidityKeys:
    """Generator bases. The B_{Q-1} sub-basis is the column Q-1 of the
    Z''-half of G (paper: G_{[0:D, Q-1]} = g), so a commitment to B_{Q-1}
    under g IS a commitment to \bar{B}_{Q-1} under G."""
    g_big: jnp.ndarray     # (2 Ds Q, 4)
    h_big: jnp.ndarray     # (2 Ds Q, 4)
    g_r: jnp.ndarray       # (2 Ds R, 4)  remainder bases
    h_r: jnp.ndarray       # (2 Ds R, 4)
    h_blind: jnp.ndarray   # (4,)
    ds: int
    q_bits: int
    r_bits: int

    @property
    def g_col(self) -> jnp.ndarray:
        """g = G[0:Ds, Q-1]: basis for standalone B_{Q-1} commitments."""
        idx = np.arange(self.ds) * self.q_bits + (self.q_bits - 1)
        return self.g_big[idx]

    @property
    def h_col(self) -> jnp.ndarray:
        idx = np.arange(self.ds) * self.q_bits + (self.q_bits - 1)
        return self.h_big[idx]

    # precomputed squaring chains (`group.pow_table`) for the fixed
    # bases: built lazily once per key, they let the validity IPAs run
    # their FIRST (widest) round with one conditional multiply per
    # exponent bit and skip materializing H' = H^{1/e} entirely.
    # Memory: each table is 61x its basis (976 bytes/element), so the
    # accel path only engages below POW_TABLE_MAX_ELEMS — larger keys
    # fall back to the explicit (bit-identical) H' path rather than
    # pinning hundreds of MB per table on the key.
    @functools.cached_property
    def g_big_table(self) -> jnp.ndarray:
        return group.pow_table(self.g_big)

    @functools.cached_property
    def h_big_table(self) -> jnp.ndarray:
        return group.pow_table(self.h_big)

    @functools.cached_property
    def g_r_table(self) -> jnp.ndarray:
        return group.pow_table(self.g_r)

    @functools.cached_property
    def h_r_table(self) -> jnp.ndarray:
        return group.pow_table(self.h_r)


# accel tables above this basis length would pin > ~64 MB each on the
# key; past it the first-round speedup no longer justifies the memory
POW_TABLE_MAX_ELEMS = 1 << 16


def make_validity_keys(ds: int, q_bits: int, r_bits: int) -> ValidityKeys:
    # Q and R must be powers of two so the bit index is a clean MLE variable
    # block (the paper pads tensors to powers of two for the same reason).
    assert q_bits & (q_bits - 1) == 0, "q_bits must be a power of two"
    assert r_bits & (r_bits - 1) == 0, "r_bits must be a power of two"
    assert ds & (ds - 1) == 0, "stacked aux length must be a power of two"
    tag = b"ds%d-q%d-r%d" % (ds, q_bits, r_bits)
    return ValidityKeys(
        g_big=group.derive_generators(b"zkrelu/G/" + tag, 2 * ds * q_bits),
        h_big=group.derive_generators(b"zkrelu/H/" + tag, 2 * ds * q_bits),
        g_r=group.derive_generators(b"zkrelu/GR/" + tag, 2 * ds * r_bits),
        h_r=group.derive_generators(b"zkrelu/HR/" + tag, 2 * ds * r_bits),
        h_blind=group.derive_generators(b"zkrelu/hb/" + tag, 1)[0],
        ds=ds, q_bits=q_bits, r_bits=r_bits)


def _commit_pm_bits(gens, plus_bits, minus_bits, h_blind, blind: int):
    """h^blind * gens^{plus} * gens^{-minus} for 0/1 matrices (flattened)."""
    acc = group.msm_bits(gens, jnp.asarray(plus_bits.reshape(-1).astype(np.uint32)))
    if minus_bits is not None:
        m = group.msm_bits(gens, jnp.asarray(minus_bits.reshape(-1).astype(np.uint32)))
        acc = group.g_mul(acc, pow_const(FP, m, P_MOD - 2))  # group inverse
    if blind:
        acc = group.g_mul(acc, group.g_pow_int(h_blind, blind))
    return acc


@dataclasses.dataclass
class AuxBits:
    """Bit matrices for the stacked aux tensors (host int8 arrays)."""
    b_mat: np.ndarray       # (2Ds, Q) bits of (Z'' ; G_A')
    bneg: np.ndarray        # (2Ds, Q) -B' = 1 - B, with forced-zero column 0
    bq: np.ndarray          # (Ds,) B_{Q-1}
    br_mat: np.ndarray      # (2Ds, R) bits of (R_Z ; R_GA)
    brneg: np.ndarray       # (2Ds, R) 1 - B_R


def build_aux_bits(zpp: np.ndarray, gap: np.ndarray, bq: np.ndarray,
                   rz: np.ndarray, rga: np.ndarray, q_bits: int,
                   r_bits: int) -> AuxBits:
    ds = zpp.shape[0]
    b_mat = np.zeros((2 * ds, q_bits), dtype=np.int8)
    b_mat[:ds, : q_bits - 1] = bits_unsigned(zpp, q_bits - 1)
    b_mat[ds:, :] = bits_signed(gap, q_bits)
    bneg = 1 - b_mat                       # -B' = 1 - B
    bneg[:ds, q_bits - 1] = 0              # forced-zero column: B' = 0 there
    br_mat = np.zeros((2 * ds, r_bits), dtype=np.int8)
    br_mat[:ds] = bits_unsigned(rz, r_bits)
    br_mat[ds:] = bits_unsigned(rga, r_bits)
    return AuxBits(b_mat=b_mat, bneg=bneg, bq=bq.astype(np.int8),
                   br_mat=br_mat, brneg=1 - br_mat)


@dataclasses.dataclass
class ValidityCommitments:
    com_b_ip: int          # h^r G^B H^{B'}
    com_bq1p: int          # h^{r'} h_col^{B'_{Q-1}}
    com_br_ip: int         # h^{rr} GR^{B_R} HR^{B'_R}


@dataclasses.dataclass
class ValidityBlinds:
    r: int
    rq1p: int
    rr: int


def commit_validity(keys: ValidityKeys, bits: AuxBits, rng) -> (
        tuple):
    """Protocol 1 (trainer side): commitments to bit matrices."""
    r = _rand_scalar(rng)
    rq1p = _rand_scalar(rng)
    rr = _rand_scalar(rng)
    com_b = _commit_pm_bits(keys.g_big, bits.b_mat, None, keys.h_blind, 0)
    com_bp = _commit_pm_bits(keys.h_big, np.zeros_like(bits.bneg), bits.bneg,
                             keys.h_blind, 0)
    com_b_ip = group.g_mul(group.g_mul(com_b, com_bp),
                           group.g_pow_int(keys.h_blind, r))
    # com of B'_{Q-1} = B_{Q-1} - 1 over h_col
    bq1p_neg = (1 - bits.bq).astype(np.int8)   # -(B_{Q-1}-1)
    com_bq1p = _commit_pm_bits(keys.h_col, np.zeros((keys.ds, 1), np.int8),
                               bq1p_neg.reshape(-1, 1), keys.h_blind, rq1p)
    com_br = _commit_pm_bits(keys.g_r, bits.br_mat, None, keys.h_blind, 0)
    com_brp = _commit_pm_bits(keys.h_r, np.zeros_like(bits.brneg), bits.brneg,
                              keys.h_blind, 0)
    com_br_ip = group.g_mul(group.g_mul(com_br, com_brp),
                            group.g_pow_int(keys.h_blind, rr))
    coms = ValidityCommitments(
        com_b_ip=group.decode_group(com_b_ip),
        com_bq1p=group.decode_group(com_bq1p),
        com_br_ip=group.decode_group(com_br_ip))
    return coms, ValidityBlinds(r=r, rq1p=rq1p, rr=rr)


def _s_q_vector(q_bits: int) -> List[int]:
    """s_Q = (1, 2, ..., 2^{Q-2}, -2^{Q-1}) mod q."""
    s = [pow(2, j, Q_MOD) for j in range(q_bits - 1)]
    s.append(Q_MOD - pow(2, q_bits - 1, Q_MOD))
    return s


def _field_table_from_bits(mat: np.ndarray) -> jnp.ndarray:
    return jnp.asarray(encode_ints(FQ, mat.reshape(-1).astype(object)))


@dataclasses.dataclass
class ValidityProof:
    ipa_main: ipa.IpaProof
    ipa_rem: ipa.IpaProof

    def size_bytes(self) -> int:
        return self.ipa_main.size_bytes() + self.ipa_rem.size_bytes()


def _transformed_b_vector(bk_neg_table, e_relu, e_bit, s_vals: List[int],
                          z: int, n_rows: int):
    """b = z^2 (e_relu (x) s) + (z 1 + B'_k) . (e_relu (x) e_bit).

    bk_neg_table holds -B'_k (as field elements); returns (n,4) table.
    """
    nb = len(s_vals)
    e_full = mont_mul(FQ, e_relu[:, None, :], e_bit[None, :, :]).reshape(-1, 4)
    s_tab = enc_vec(s_vals)
    es = mont_mul(FQ, e_relu[:, None, :], s_tab[None, :, :]).reshape(-1, 4)
    z2 = enc((z * z) % Q_MOD)
    term1 = mont_mul(FQ, es, z2[None])
    zt = enc(z)
    zb = sub(FQ, jnp.broadcast_to(zt, (n_rows * nb, 4)).astype(jnp.uint32),
             bk_neg_table)
    term2 = mont_mul(FQ, zb, e_full)
    return add(FQ, term1, term2), e_full


def _main_claim(v_k: int, vp_k: int, z: int, s_sum: int = -1) -> int:
    """RHS of (19): -z^3 sum(s) - (1 - v_k) z^2 + z v'_k.

    For the signed s_Q vector sum(s) = -1, recovering the paper's
    z^3 - (1-v_k) z^2 + z v'_k; the unsigned remainder s-vector has
    sum(s) = 2^R - 1.
    """
    return (-pow(z, 3, Q_MOD) * s_sum - (1 - v_k) * z * z + z * vp_k) % Q_MOD


def prove_validity(keys: ValidityKeys, bits: AuxBits, blinds: ValidityBlinds,
                   u_relu: List[int], v: int, v_q1: int, v_r: int,
                   r_q1: int, transcript: Transcript,
                   rng) -> ValidityProof:
    """Validity of aux inputs given claims already bound to the transcript.

    u_relu = (u_star..., u'') is the row point; v / v_q1 / v_r are the
    (already transcript-absorbed) MLE-evaluation claims; r_q1 is the blind
    of the standalone com_{B_{Q-1}} aux commitment.  Challenges k, u_bit, z
    are drawn from the shared transcript.
    """
    ds, qb, rb = keys.ds, keys.q_bits, keys.r_bits
    k = transcript.challenge_int(b"zkrelu/k", Q_MOD)
    u_bit = transcript.challenge_ints(b"zkrelu/ubit", Q_MOD,
                                      (qb - 1).bit_length())
    z = transcript.challenge_int(b"zkrelu/z", Q_MOD)
    u_bit_r = transcript.challenge_ints(b"zkrelu/ubitr", Q_MOD,
                                        (rb - 1).bit_length())
    z_r = transcript.challenge_int(b"zkrelu/zr", Q_MOD)

    # ---- main matrix: B_k = B + k Bbar, B'_k = B' + k Bbar' -------------
    bk = encode_ints(FQ, bits.b_mat.astype(object))
    bk = jnp.asarray(bk).reshape(-1, 4)
    kbar = np.zeros((2 * ds, qb), dtype=object)
    kbar[:ds, qb - 1] = [int(x) * k % Q_MOD for x in bits.bq]
    bk = add(FQ, bk, jnp.asarray(encode_ints(FQ, kbar)).reshape(-1, 4))
    # -B'_k = (1 - B masked) + k (1 - B_{Q-1}) on the forced column
    nbp = bits.bneg.astype(object)
    kbarp = np.zeros((2 * ds, qb), dtype=object)
    kbarp[:ds, qb - 1] = [int(1 - x) * k % Q_MOD for x in bits.bq]
    bkp_neg = add(FQ, jnp.asarray(encode_ints(FQ, nbp)).reshape(-1, 4),
                  jnp.asarray(encode_ints(FQ, kbarp)).reshape(-1, 4))

    e_relu = expand_point(u_relu)
    assert e_relu.shape[0] == 2 * ds
    e_bit = expand_point(u_bit)[:qb]
    # (qb is a power of two in all configs; assert to be safe)
    assert e_bit.shape[0] == qb

    a_vec = sub(FQ, bk, jnp.broadcast_to(enc(z), bk.shape).astype(jnp.uint32))
    b_vec, _ = _transformed_b_vector(bkp_neg, e_relu, e_bit,
                                     _s_q_vector(qb), z, 2 * ds)

    # derived claim values (the verifier recomputes these itself)
    upp = u_relu[-1]
    v_k = (v - k * pow(2, qb - 1, Q_MOD) % Q_MOD
           * ((1 - upp) % Q_MOD) % Q_MOD * v_q1) % Q_MOD
    vp_k = _vp_k(k, u_relu, u_bit, qb)
    claim = _main_claim(v_k, vp_k, z)
    blind_k = (blinds.r + k * (r_q1 + blinds.rq1p)) % Q_MOD

    w_main = _h_weights(e_relu, e_bit)

    # ---- remainder matrix (no k-term, unsigned s-vector) ----------------
    brk = jnp.asarray(encode_ints(FQ, bits.br_mat.astype(object))).reshape(-1, 4)
    brp_neg = jnp.asarray(encode_ints(FQ, bits.brneg.astype(object))).reshape(-1, 4)
    e_bit_r = expand_point(u_bit_r)[:rb]
    s_r = [pow(2, j, Q_MOD) for j in range(rb)]
    a_r = sub(FQ, brk, jnp.broadcast_to(enc(z_r), brk.shape).astype(jnp.uint32))
    b_r, _ = _transformed_b_vector(brp_neg, e_relu, e_bit_r, s_r, z_r, 2 * ds)
    claim_r = _main_claim(v_r, 1, z_r, s_sum=(1 << rb) - 1)
    w_rem = _h_weights(e_relu, e_bit_r)

    # the main and remainder arguments are independent statements on one
    # transcript: lockstep rounds pay max(rounds) syncs, not their sum,
    # and (below the table memory cap) the accel tuples run the wide
    # first round off the fixed-basis squaring tables with H' = H^{1/e}
    # kept in exponent form — bit-identical to the explicit fallback
    def stmt(g_basis, g_table, h_basis, h_table, w, e_bit_vec, a, b,
             blind, cl):
        if g_basis.shape[0] <= POW_TABLE_MAX_ELEMS:
            return (g_basis, None, keys.h_blind, a, b, blind, cl,
                    (g_table(), h_basis, h_table(), w))
        h_prime = _h_prime_basis(h_basis, e_relu, e_bit_vec)
        return (g_basis, h_prime, keys.h_blind, a, b, blind, cl)

    proof_main, proof_rem = ipa.pair_prove_many(
        [stmt(keys.g_big, lambda: keys.g_big_table, keys.h_big,
              lambda: keys.h_big_table, w_main, e_bit,
              a_vec, b_vec, blind_k, claim),
         stmt(keys.g_r, lambda: keys.g_r_table, keys.h_r,
              lambda: keys.h_r_table, w_rem, e_bit_r,
              a_r, b_r, blinds.rr, claim_r)],
        transcript, rng)
    return ValidityProof(ipa_main=proof_main, ipa_rem=proof_rem)


def _vp_k(k: int, u_relu: List[int], u_bit: List[int], qb: int) -> int:
    """v'_k = 1 + (k-1) beta(bin(Q-1), u_bit) (1 - u'')   (eq. 15)."""
    upp = u_relu[-1]
    e_bit = hexpand_point(u_bit)
    beta = e_bit[qb - 1]
    return (1 + (k - 1) * beta % Q_MOD * ((1 - upp) % Q_MOD)) % Q_MOD


def _h_weights(e_relu, e_bit):
    """1/e for e = e_relu (x) e_bit — the H-basis weights (Montgomery)."""
    e_full = mont_mul(FQ, e_relu[:, None, :], e_bit[None, :, :]).reshape(-1, 4)
    return batch_inv(FQ, e_full)


def _h_prime_basis(h_big, e_relu, e_bit):
    """H'_i = H_i^{1/e_i}, e = e_relu (x) e_bit (Algorithm 1 basis).

    Verifier-side only: the prover keeps the weights in exponent form
    (`ipa.pair_prove_many` accel statements) and never materializes H'."""
    from repro.field import from_mont
    return group.g_pow(h_big, from_mont(FQ, _h_weights(e_relu, e_bit)))


def transform_commitment(keys: ValidityKeys, com_b_ip: int, com_bq1_ip: int,
                         k: int, z: int, u_bit: List[int],
                         remainder: bool = False) -> jnp.ndarray:
    """Algorithm 1: transform com into a commitment of (B_k - z1, b) under
    the bases (G, H^{e^{o-1}}).  Returns the group element."""
    qb = keys.r_bits if remainder else keys.q_bits
    g_big = keys.g_r if remainder else keys.g_big
    h_big = keys.h_r if remainder else keys.h_big
    com = group.encode_group(com_b_ip)
    if not remainder and k is not None:
        com = group.g_mul(com, group.g_pow_int(group.encode_group(com_bq1_ip), k))
    # g^prod ^ {-z}
    gprod = group.tree_prod(g_big)
    com = group.g_mul(com, group.g_pow_int(gprod, (-z) % Q_MOD))
    # (h^prod_j)^{z^2 s_j / e_bit_j} column products
    e_bit = hexpand_point(u_bit)[:qb]
    s_vals = ([pow(2, j, Q_MOD) for j in range(qb)] if remainder
              else _s_q_vector(qb))
    n_rows = 2 * keys.ds
    h_cols = h_big.reshape(n_rows, qb, 4)
    for j in range(qb):
        colprod = group.tree_prod(h_cols[:, j])
        expo = (z * z % Q_MOD * s_vals[j] % Q_MOD
                * pow(e_bit[j], Q_MOD - 2, Q_MOD)) % Q_MOD
        expo = (expo + z) % Q_MOD            # + (h^prod)^z folded per column
        com = group.g_mul(com, group.g_pow_int(colprod, expo))
    return com


def verify_validity(keys: ValidityKeys, coms: ValidityCommitments,
                    com_bq1: int, v: int, v_q1: int, v_r: int,
                    u_relu: List[int], proof: ValidityProof,
                    transcript: Transcript) -> bool:
    ds, qb, rb = keys.ds, keys.q_bits, keys.r_bits
    k = transcript.challenge_int(b"zkrelu/k", Q_MOD)
    u_bit = transcript.challenge_ints(b"zkrelu/ubit", Q_MOD,
                                      (qb - 1).bit_length())
    z = transcript.challenge_int(b"zkrelu/z", Q_MOD)
    u_bit_r = transcript.challenge_ints(b"zkrelu/ubitr", Q_MOD,
                                        (rb - 1).bit_length())
    z_r = transcript.challenge_int(b"zkrelu/zr", Q_MOD)

    upp = u_relu[-1]
    v_k = (v - k * pow(2, qb - 1, Q_MOD) % Q_MOD
           * ((1 - upp) % Q_MOD) % Q_MOD * v_q1) % Q_MOD
    vp_k = _vp_k(k, u_relu, u_bit, qb)
    claim = _main_claim(v_k, vp_k, z)

    # com_{B_{Q-1}}^ip = com_{B_{Q-1}} * com_{B'_{Q-1}}   (Protocol 1 line 3)
    com_bq1_ip = group.decode_group(
        group.g_mul(group.encode_group(com_bq1),
                    group.encode_group(coms.com_bq1p)))
    com_t = transform_commitment(keys, coms.com_b_ip, com_bq1_ip, k, z, u_bit)
    e_relu = expand_point(u_relu)
    e_bit = expand_point(u_bit)[:qb]
    h_prime = _h_prime_basis(keys.h_big, e_relu, e_bit)

    claim_r = _main_claim(v_r, 1, z_r, s_sum=(1 << rb) - 1)
    com_tr = transform_commitment(keys, coms.com_br_ip, None, None, z_r,
                                  u_bit_r, remainder=True)
    e_bit_r = expand_point(u_bit_r)[:rb]
    h_prime_r = _h_prime_basis(keys.h_r, e_relu, e_bit_r)
    return ipa.pair_verify_many(
        [(keys.g_big, h_prime, keys.h_blind, com_t, claim, 2 * ds * qb),
         (keys.g_r, h_prime_r, keys.h_blind, com_tr, claim_r, 2 * ds * rb)],
        [proof.ipa_main, proof.ipa_rem], transcript)
