"""zkReLU: batched validity proofs for the auxiliary inputs (Section 4.1).

Given the stacked (over layers) auxiliary tensors

    Z''  in [0, 2^{Q-1})^Ds        B_{Q-1} in {0,1}^Ds
    G_A' in [-2^{Q-1}, 2^{Q-1})^Ds
    R_Z, R_GA in [0, 2^R)^Ds

the prover commits to the bit matrices

    B  = [[bits(Z'') | 0], [signed-bits(G_A')]]   in {0,1}^{2Ds x Q}
    B' = B - 1 (except the forced-zero column, which stays 0)

via com_B^ip = h^r G^B H^{B'} (Protocol 1), and proves the single combined
inner-product relation (19)

    < B_k - z 1,  z^2 (e_relu (x) s_Q) + (z 1 + B'_k) . (e_relu (x) e_bit) >
        = z^3 - (1 - v_k) z^2 + z v'_k

with B_k = B + k \bar{B}_{Q-1}, via the commitment transformation of
Algorithm 1 followed by the two-sided zero-knowledge IPA.  Theorem 4.1
gives soundness: acceptance implies all range constraints hold.

The remainders R_Z / R_GA use the identical machinery with an unsigned
R-bit s-vector and no k-term (their own (19)-analogue), as the paper's
"combined ... using random linear combinations" step.

Execution model: the eq. (19) witness tables are never materialized on
the host.  `prove_statements` hands the raw stacked integers to
`repro.kernels.validity_tables`, which shift/masks the bits out and
assembles both (main + remainder) a/b tables in one accelerator
dispatch; the bit matrices themselves (`build_aux_bits`, vectorized
shift/mask) exist only for the Pedersen commitments.  Both statements
are then folded into ONE pair IPA: callers either merge them into the
pipeline's direct-sum opening (`pipeline.openings`) or, standalone,
into a lam-weighted two-statement merge over the vk-level merged basis
(`prove_validity` / `verify_validity`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.field import FQ, FP, mont_mul, batch_inv, from_mont
from repro.core import group, ipa
from repro.core.mle import enc, enc_vec, expand_point, hexpand_point
from repro.core.transcript import Transcript
from repro.kernels import validity_tables as vtab

Q_MOD = FQ.modulus
P_MOD = FP.modulus


def _rand_scalar(rng) -> int:
    return int(rng.integers(0, Q_MOD, dtype=np.uint64)) % Q_MOD


def bits_unsigned(v: np.ndarray, nbits: int) -> np.ndarray:
    """(n,) int64 in [0, 2^nbits) -> (n, nbits) 0/1 int8."""
    assert (v >= 0).all() and (v < (1 << nbits)).all()
    return ((v[:, None] >> np.arange(nbits, dtype=np.int64)[None, :]) & 1
            ).astype(np.int8)


def bits_signed(v: np.ndarray, nbits: int) -> np.ndarray:
    """(n,) int64 in [-2^{nbits-1}, 2^{nbits-1}) -> (n, nbits) two's compl."""
    lim = 1 << (nbits - 1)
    assert (v >= -lim).all() and (v < lim).all()
    u = np.where(v < 0, v + (1 << nbits), v).astype(np.int64)
    return ((u[:, None] >> np.arange(nbits, dtype=np.int64)[None, :]) & 1
            ).astype(np.int8)


@dataclasses.dataclass(frozen=True)
class ValidityKeys:
    """Generator bases. The B_{Q-1} sub-basis is the column Q-1 of the
    Z''-half of G (paper: G_{[0:D, Q-1]} = g), so a commitment to B_{Q-1}
    under g IS a commitment to \bar{B}_{Q-1} under G."""
    g_big: jnp.ndarray     # (2 Ds Q, 4)
    h_big: jnp.ndarray     # (2 Ds Q, 4)
    g_r: jnp.ndarray       # (2 Ds R, 4)  remainder bases
    h_r: jnp.ndarray       # (2 Ds R, 4)
    h_blind: jnp.ndarray   # (4,)
    ds: int
    q_bits: int
    r_bits: int

    @property
    def g_col(self) -> jnp.ndarray:
        """g = G[0:Ds, Q-1]: basis for standalone B_{Q-1} commitments."""
        idx = np.arange(self.ds) * self.q_bits + (self.q_bits - 1)
        return self.g_big[idx]

    @property
    def h_col(self) -> jnp.ndarray:
        idx = np.arange(self.ds) * self.q_bits + (self.q_bits - 1)
        return self.h_big[idx]

    @property
    def n_main(self) -> int:
        return 2 * self.ds * self.q_bits

    @property
    def n_rem(self) -> int:
        return 2 * self.ds * self.r_bits

    @property
    def merged_len(self) -> int:
        """Power-of-two length of the lam-merged (main ++ rem) statement."""
        n, m = self.n_main + self.n_rem, 1
        while m < n:
            m <<= 1
        return m

    def _tag(self) -> bytes:
        return b"ds%d-q%d-r%d" % (self.ds, self.q_bits, self.r_bits)

    @functools.cached_property
    def g_merged(self) -> jnp.ndarray:
        """G basis of the merged statement: G ++ G_R ++ fresh pad."""
        pad = self.merged_len - self.n_main - self.n_rem
        parts = [self.g_big, self.g_r]
        if pad:
            parts.append(group.derive_generators(
                b"zkrelu/Gpad/" + self._tag(), pad))
        return jnp.concatenate(parts)

    @functools.cached_property
    def h_merged(self) -> jnp.ndarray:
        pad = self.merged_len - self.n_main - self.n_rem
        parts = [self.h_big, self.h_r]
        if pad:
            parts.append(group.derive_generators(
                b"zkrelu/Hpad/" + self._tag(), pad))
        return jnp.concatenate(parts)

    # precomputed squaring chains (`group.pow_table`) for the fixed
    # bases: built lazily once per key, they let the merged validity IPA
    # run its FIRST (widest) round with one conditional multiply per
    # exponent bit and skip materializing H' = H^{1/e} entirely.
    # Memory: each table is 61x its basis (976 bytes/element), so the
    # accel path only engages below POW_TABLE_MAX_ELEMS — larger keys
    # fall back to the explicit (bit-identical) H' path rather than
    # pinning hundreds of MB per table on the key.
    @functools.cached_property
    def g_merged_table(self) -> jnp.ndarray:
        return group.pow_table(self.g_merged)

    @functools.cached_property
    def h_merged_table(self) -> jnp.ndarray:
        return group.pow_table(self.h_merged)


# accel tables above this basis length would pin > ~64 MB each on the
# key; past it the first-round speedup no longer justifies the memory
POW_TABLE_MAX_ELEMS = 1 << 16


def make_validity_keys(ds: int, q_bits: int, r_bits: int) -> ValidityKeys:
    # Q and R must be powers of two so the bit index is a clean MLE variable
    # block (the paper pads tensors to powers of two for the same reason).
    assert q_bits & (q_bits - 1) == 0, "q_bits must be a power of two"
    assert r_bits & (r_bits - 1) == 0, "r_bits must be a power of two"
    assert ds & (ds - 1) == 0, "stacked aux length must be a power of two"
    tag = b"ds%d-q%d-r%d" % (ds, q_bits, r_bits)
    return ValidityKeys(
        g_big=group.derive_generators(b"zkrelu/G/" + tag, 2 * ds * q_bits),
        h_big=group.derive_generators(b"zkrelu/H/" + tag, 2 * ds * q_bits),
        g_r=group.derive_generators(b"zkrelu/GR/" + tag, 2 * ds * r_bits),
        h_r=group.derive_generators(b"zkrelu/HR/" + tag, 2 * ds * r_bits),
        h_blind=group.derive_generators(b"zkrelu/hb/" + tag, 1)[0],
        ds=ds, q_bits=q_bits, r_bits=r_bits)


def _commit_pm_bits(gens, plus_bits, minus_bits, h_blind, blind: int):
    """h^blind * gens^{plus} * gens^{-minus} for 0/1 matrices (flattened)."""
    acc = group.msm_bits(gens, jnp.asarray(plus_bits.reshape(-1).astype(np.uint32)))
    if minus_bits is not None:
        m = group.msm_bits(gens, jnp.asarray(minus_bits.reshape(-1).astype(np.uint32)))
        acc = group.g_mul(acc, group.g_inv(m))
    if blind:
        acc = group.g_mul(acc, group.g_pow_int(h_blind, blind))
    return acc


@dataclasses.dataclass
class AuxBits:
    """Bit matrices for the stacked aux tensors (host int8 arrays), plus
    the raw stacked integers they decompose — the validity-table kernel
    consumes the raw values directly and never reads the matrices."""
    b_mat: np.ndarray       # (2Ds, Q) bits of (Z'' ; G_A')
    bneg: np.ndarray        # (2Ds, Q) -B' = 1 - B, with forced-zero column 0
    bq: np.ndarray          # (Ds,) B_{Q-1}
    br_mat: np.ndarray      # (2Ds, R) bits of (R_Z ; R_GA)
    brneg: np.ndarray       # (2Ds, R) 1 - B_R
    zpp: np.ndarray         # (Ds,) int64 Z''
    gap: np.ndarray         # (Ds,) int64 G_A'
    rz: np.ndarray          # (Ds,) int64 R_Z
    rga: np.ndarray         # (Ds,) int64 R_GA


def build_aux_bits(zpp: np.ndarray, gap: np.ndarray, bq: np.ndarray,
                   rz: np.ndarray, rga: np.ndarray, q_bits: int,
                   r_bits: int) -> AuxBits:
    ds = zpp.shape[0]
    b_mat = np.zeros((2 * ds, q_bits), dtype=np.int8)
    b_mat[:ds, : q_bits - 1] = bits_unsigned(zpp, q_bits - 1)
    b_mat[ds:, :] = bits_signed(gap, q_bits)
    bneg = 1 - b_mat                       # -B' = 1 - B
    bneg[:ds, q_bits - 1] = 0              # forced-zero column: B' = 0 there
    br_mat = np.zeros((2 * ds, r_bits), dtype=np.int8)
    br_mat[:ds] = bits_unsigned(rz, r_bits)
    br_mat[ds:] = bits_unsigned(rga, r_bits)
    return AuxBits(b_mat=b_mat, bneg=bneg, bq=bq.astype(np.int8),
                   br_mat=br_mat, brneg=1 - br_mat,
                   zpp=zpp.astype(np.int64), gap=gap.astype(np.int64),
                   rz=rz.astype(np.int64), rga=rga.astype(np.int64))


@dataclasses.dataclass
class ValidityCommitments:
    com_b_ip: int          # h^r G^B H^{B'}
    com_bq1: int           # h^{rq1} g_col^{B_{Q-1}}
    com_bq1p: int          # h^{r'} h_col^{B'_{Q-1}}
    com_br_ip: int         # h^{rr} GR^{B_R} HR^{B'_R}


@dataclasses.dataclass
class ValidityBlinds:
    r: int
    rq1: int
    rq1p: int
    rr: int


def commit_validity(keys: ValidityKeys, bits: AuxBits, rng) -> (
        tuple):
    """Protocol 1 (trainer side): commitments to bit matrices.

    com_bq1 (B_{Q-1} under the g_col sub-basis, own blind rq1) is part of
    this bundle: the merged opening pins the bq MLE at the same random
    point through two routes — the slot commitment and, via the k-term,
    this column commitment — so the two must agree w.h.p.  Publishing it
    here (rather than splicing g_col into another key) keeps every slice
    of the merged IPA basis generator-disjoint.
    """
    r = _rand_scalar(rng)
    rq1 = _rand_scalar(rng)
    rq1p = _rand_scalar(rng)
    rr = _rand_scalar(rng)
    com_b = _commit_pm_bits(keys.g_big, bits.b_mat, None, keys.h_blind, 0)
    com_bp = _commit_pm_bits(keys.h_big, np.zeros_like(bits.bneg), bits.bneg,
                             keys.h_blind, 0)
    com_b_ip = group.g_mul(group.g_mul(com_b, com_bp),
                           group.g_pow_int(keys.h_blind, r))
    # com of B_{Q-1} over g_col, com of B'_{Q-1} = B_{Q-1} - 1 over h_col
    com_bq1 = _commit_pm_bits(keys.g_col, bits.bq.reshape(-1, 1), None,
                              keys.h_blind, rq1)
    bq1p_neg = (1 - bits.bq).astype(np.int8)   # -(B_{Q-1}-1)
    com_bq1p = _commit_pm_bits(keys.h_col, np.zeros((keys.ds, 1), np.int8),
                               bq1p_neg.reshape(-1, 1), keys.h_blind, rq1p)
    com_br = _commit_pm_bits(keys.g_r, bits.br_mat, None, keys.h_blind, 0)
    com_brp = _commit_pm_bits(keys.h_r, np.zeros_like(bits.brneg), bits.brneg,
                              keys.h_blind, 0)
    com_br_ip = group.g_mul(group.g_mul(com_br, com_brp),
                            group.g_pow_int(keys.h_blind, rr))
    coms = ValidityCommitments(
        com_b_ip=group.decode_group(com_b_ip),
        com_bq1=group.decode_group(com_bq1),
        com_bq1p=group.decode_group(com_bq1p),
        com_br_ip=group.decode_group(com_br_ip))
    return coms, ValidityBlinds(r=r, rq1=rq1, rq1p=rq1p, rr=rr)


def _s_q_vector(q_bits: int) -> List[int]:
    """s_Q = (1, 2, ..., 2^{Q-2}, -2^{Q-1}) mod q."""
    s = [pow(2, j, Q_MOD) for j in range(q_bits - 1)]
    s.append(Q_MOD - pow(2, q_bits - 1, Q_MOD))
    return s


def _main_claim(v_k: int, vp_k: int, z: int, s_sum: int = -1) -> int:
    """RHS of (19): -z^3 sum(s) - (1 - v_k) z^2 + z v'_k.

    For the signed s_Q vector sum(s) = -1, recovering the paper's
    z^3 - (1-v_k) z^2 + z v'_k; the unsigned remainder s-vector has
    sum(s) = 2^R - 1.
    """
    return (-pow(z, 3, Q_MOD) * s_sum - (1 - v_k) * z * z + z * vp_k) % Q_MOD


@dataclasses.dataclass
class ValidityStatements:
    """Both eq. (19) pair-IPA statements, ready to be folded into a
    single direct-sum opening.  a/b are (n, 4) Montgomery witness
    tables; w is the H-basis exponent weight vector 1/e (Montgomery);
    claims/blinds are canonical ints."""
    a_main: jnp.ndarray
    b_main: jnp.ndarray
    w_main: jnp.ndarray
    claim_main: int
    blind_main: int
    a_rem: jnp.ndarray
    b_rem: jnp.ndarray
    w_rem: jnp.ndarray
    claim_rem: int
    blind_rem: int


def prove_statements(keys: ValidityKeys, bits: AuxBits,
                     blinds: ValidityBlinds, u_relu: List[int], v: int,
                     v_q1: int, v_r: int,
                     transcript: Transcript) -> ValidityStatements:
    """Draw the validity challenges and build both statement witnesses.

    u_relu = (u_star..., u'') is the row point; v / v_q1 / v_r are the
    (already transcript-absorbed) MLE-evaluation claims.  Challenges
    k, u_bit, z (and the remainder's u_bit_r, z_r) are drawn from the
    shared transcript; the a/b tables for BOTH statements come out of
    one `validity_tables` kernel dispatch over the raw aux integers.
    """
    ds, qb, rb = keys.ds, keys.q_bits, keys.r_bits
    k = transcript.challenge_int(b"zkrelu/k", Q_MOD)
    u_bit = transcript.challenge_ints(b"zkrelu/ubit", Q_MOD,
                                      (qb - 1).bit_length())
    z = transcript.challenge_int(b"zkrelu/z", Q_MOD)
    u_bit_r = transcript.challenge_ints(b"zkrelu/ubitr", Q_MOD,
                                        (rb - 1).bit_length())
    z_r = transcript.challenge_int(b"zkrelu/zr", Q_MOD)

    e_relu = expand_point(u_relu)
    assert e_relu.shape[0] == 2 * ds
    e_bit = expand_point(u_bit)[:qb]
    e_bit_r = expand_point(u_bit_r)[:rb]

    # e_relu (x) e_bit and the z^2-scaled e_relu (x) s tables, both
    # statements concatenated in kernel-layout order
    e_full_m = mont_mul(FQ, e_relu[:, None, :],
                        e_bit[None, :, :]).reshape(-1, 4)
    e_full_r = mont_mul(FQ, e_relu[:, None, :],
                        e_bit_r[None, :, :]).reshape(-1, 4)
    es_m = mont_mul(FQ,
                    mont_mul(FQ, e_relu[:, None, :],
                             enc_vec(_s_q_vector(qb))[None, :, :]
                             ).reshape(-1, 4),
                    enc(z * z % Q_MOD)[None])
    s_r = [pow(2, j, Q_MOD) for j in range(rb)]
    es_r = mont_mul(FQ,
                    mont_mul(FQ, e_relu[:, None, :],
                             enc_vec(s_r)[None, :, :]).reshape(-1, 4),
                    enc(z_r * z_r % Q_MOD)[None])

    layout = vtab.build_layout(bits.zpp, bits.gap, bits.bq, bits.rz,
                               bits.rga, qb, rb)
    a, b = vtab.build_tables(layout, k, z, z_r,
                             jnp.concatenate([e_full_m, e_full_r]),
                             jnp.concatenate([es_m, es_r]))
    n_main = layout.n_main

    # derived claim values (the verifier recomputes these itself)
    upp = u_relu[-1]
    v_k = (v - k * pow(2, qb - 1, Q_MOD) % Q_MOD
           * ((1 - upp) % Q_MOD) % Q_MOD * v_q1) % Q_MOD
    vp_k = _vp_k(k, u_relu, u_bit, qb)
    return ValidityStatements(
        a_main=a[:n_main], b_main=b[:n_main],
        w_main=batch_inv(FQ, e_full_m),
        claim_main=_main_claim(v_k, vp_k, z),
        blind_main=(blinds.r + k * (blinds.rq1 + blinds.rq1p)) % Q_MOD,
        a_rem=a[n_main:], b_rem=b[n_main:],
        w_rem=batch_inv(FQ, e_full_r),
        claim_rem=_main_claim(v_r, 1, z_r, s_sum=(1 << rb) - 1),
        blind_rem=blinds.rr)


@dataclasses.dataclass
class ValidityVerifyCtx:
    """Verifier-side mirror of `ValidityStatements`: the transformed
    commitments (Algorithm 1), recomputed claims and materialized
    H' = H^{1/e} bases the merged-IPA verifier splices in."""
    com_t: jnp.ndarray
    com_tr: jnp.ndarray
    claim_main: int
    claim_rem: int
    h_prime_main: jnp.ndarray
    h_prime_rem: jnp.ndarray


def verify_statements(keys: ValidityKeys, coms: ValidityCommitments,
                      v: int, v_q1: int, v_r: int, u_relu: List[int],
                      transcript: Transcript) -> ValidityVerifyCtx:
    """Redraw the validity challenges and transform the commitments."""
    ds, qb, rb = keys.ds, keys.q_bits, keys.r_bits
    k = transcript.challenge_int(b"zkrelu/k", Q_MOD)
    u_bit = transcript.challenge_ints(b"zkrelu/ubit", Q_MOD,
                                      (qb - 1).bit_length())
    z = transcript.challenge_int(b"zkrelu/z", Q_MOD)
    u_bit_r = transcript.challenge_ints(b"zkrelu/ubitr", Q_MOD,
                                        (rb - 1).bit_length())
    z_r = transcript.challenge_int(b"zkrelu/zr", Q_MOD)

    upp = u_relu[-1]
    v_k = (v - k * pow(2, qb - 1, Q_MOD) % Q_MOD
           * ((1 - upp) % Q_MOD) % Q_MOD * v_q1) % Q_MOD
    vp_k = _vp_k(k, u_relu, u_bit, qb)

    # com_{B_{Q-1}}^ip = com_{B_{Q-1}} * com_{B'_{Q-1}}   (Protocol 1 line 3)
    com_bq1_ip = group.decode_group(
        group.g_mul(group.encode_group(coms.com_bq1),
                    group.encode_group(coms.com_bq1p)))
    com_t = transform_commitment(keys, coms.com_b_ip, com_bq1_ip, k, z, u_bit)
    com_tr = transform_commitment(keys, coms.com_br_ip, None, None, z_r,
                                  u_bit_r, remainder=True)
    e_relu = expand_point(u_relu)
    return ValidityVerifyCtx(
        com_t=com_t, com_tr=com_tr,
        claim_main=_main_claim(v_k, vp_k, z),
        claim_rem=_main_claim(v_r, 1, z_r, s_sum=(1 << rb) - 1),
        h_prime_main=_h_prime_basis(keys.h_big, e_relu,
                                    expand_point(u_bit)[:qb]),
        h_prime_rem=_h_prime_basis(keys.h_r, e_relu,
                                   expand_point(u_bit_r)[:rb]))


def prove_validity(keys: ValidityKeys, bits: AuxBits,
                   blinds: ValidityBlinds, u_relu: List[int], v: int,
                   v_q1: int, v_r: int, transcript: Transcript,
                   rng) -> ipa.IpaProof:
    """Standalone validity of aux inputs: ONE merged pair IPA.

    The main and remainder statements become disjoint slices of the
    merged basis (G ++ G_R ++ pad); the remainder slice is lam-scaled so
    claim monomials stay distinct (claim = c_main + lam^2 c_rem) and the
    verifier can assemble the merged commitment as com_t * com_tr^lam.
    The pipeline does the same fold with rho-powers inside its
    direct-sum opening — this wrapper is the two-statement special case.
    """
    st = prove_statements(keys, bits, blinds, u_relu, v, v_q1, v_r,
                          transcript)
    lam = transcript.challenge_int(b"zkrelu/lam", Q_MOD)
    lam_m = enc(lam)
    pad = keys.merged_len - keys.n_main - keys.n_rem
    zeros = jnp.zeros((pad, 4), dtype=jnp.uint32)
    a = jnp.concatenate([st.a_main,
                         mont_mul(FQ, st.a_rem, lam_m[None]), zeros])
    b = jnp.concatenate([st.b_main,
                         mont_mul(FQ, st.b_rem, lam_m[None]), zeros])
    ones = jnp.broadcast_to(enc(1), (pad, 4)).astype(jnp.uint32)
    w = jnp.concatenate([st.w_main, st.w_rem, ones])
    claim = (st.claim_main + lam * lam % Q_MOD * st.claim_rem) % Q_MOD
    blind = (st.blind_main + lam * st.blind_rem) % Q_MOD
    if keys.merged_len <= POW_TABLE_MAX_ELEMS:
        stmt = (keys.g_merged, None, keys.h_blind, a, b, blind, claim,
                (keys.g_merged_table, keys.h_merged, keys.h_merged_table, w))
    else:
        hh = group.g_pow(keys.h_merged, from_mont(FQ, w))
        stmt = (keys.g_merged, hh, keys.h_blind, a, b, blind, claim)
    (proof,) = ipa.pair_prove_many([stmt], transcript, rng)
    return proof


def _vp_k(k: int, u_relu: List[int], u_bit: List[int], qb: int) -> int:
    """v'_k = 1 + (k-1) beta(bin(Q-1), u_bit) (1 - u'')   (eq. 15)."""
    upp = u_relu[-1]
    e_bit = hexpand_point(u_bit)
    beta = e_bit[qb - 1]
    return (1 + (k - 1) * beta % Q_MOD * ((1 - upp) % Q_MOD)) % Q_MOD


def _h_weights(e_relu, e_bit):
    """1/e for e = e_relu (x) e_bit — the H-basis weights (Montgomery)."""
    e_full = mont_mul(FQ, e_relu[:, None, :], e_bit[None, :, :]).reshape(-1, 4)
    return batch_inv(FQ, e_full)


def _h_prime_basis(h_big, e_relu, e_bit):
    """H'_i = H_i^{1/e_i}, e = e_relu (x) e_bit (Algorithm 1 basis).

    Verifier-side only: the prover keeps the weights in exponent form
    (`ipa.pair_prove_many` accel statements) and never materializes H'."""
    return group.g_pow(h_big, from_mont(FQ, _h_weights(e_relu, e_bit)))


def transform_commitment(keys: ValidityKeys, com_b_ip: int, com_bq1_ip: int,
                         k: int, z: int, u_bit: List[int],
                         remainder: bool = False) -> jnp.ndarray:
    """Algorithm 1: transform com into a commitment of (B_k - z1, b) under
    the bases (G, H^{e^{o-1}}).  Returns the group element."""
    qb = keys.r_bits if remainder else keys.q_bits
    g_big = keys.g_r if remainder else keys.g_big
    h_big = keys.h_r if remainder else keys.h_big
    com = group.encode_group(com_b_ip)
    if not remainder and k is not None:
        com = group.g_mul(com, group.g_pow_int(group.encode_group(com_bq1_ip), k))
    # g^prod ^ {-z}
    gprod = group.tree_prod(g_big)
    com = group.g_mul(com, group.g_pow_int(gprod, (-z) % Q_MOD))
    # (h^prod_j)^{z^2 s_j / e_bit_j} column products
    e_bit = hexpand_point(u_bit)[:qb]
    s_vals = ([pow(2, j, Q_MOD) for j in range(qb)] if remainder
              else _s_q_vector(qb))
    n_rows = 2 * keys.ds
    h_cols = h_big.reshape(n_rows, qb, 4)
    for j in range(qb):
        colprod = group.tree_prod(h_cols[:, j])
        expo = (z * z % Q_MOD * s_vals[j] % Q_MOD
                * pow(e_bit[j], Q_MOD - 2, Q_MOD)) % Q_MOD
        expo = (expo + z) % Q_MOD            # + (h^prod)^z folded per column
        com = group.g_mul(com, group.g_pow_int(colprod, expo))
    return com


def verify_validity(keys: ValidityKeys, coms: ValidityCommitments,
                    v: int, v_q1: int, v_r: int, u_relu: List[int],
                    proof: ipa.IpaProof, transcript: Transcript) -> bool:
    """Standalone verifier for the merged validity IPA."""
    ctx = verify_statements(keys, coms, v, v_q1, v_r, u_relu, transcript)
    lam = transcript.challenge_int(b"zkrelu/lam", Q_MOD)
    com = group.g_mul(ctx.com_t, group.g_pow_int(ctx.com_tr, lam))
    claim = (ctx.claim_main + lam * lam % Q_MOD * ctx.claim_rem) % Q_MOD
    hh = jnp.concatenate([ctx.h_prime_main, ctx.h_prime_rem,
                          keys.h_merged[keys.n_main + keys.n_rem:]])
    return ipa.pair_verify_many(
        [(keys.g_merged, hh, keys.h_blind, com, claim, keys.merged_len)],
        [proof], transcript)
