"""Generic sum-of-products sumcheck prover/verifier over FQ.

Proves claims of the form

    claim = sum_{b in {0,1}^d}  sum_p  prod_{k in products[p]} T_k(b)

for a list of distinct MLE tables ``T_k`` and products given as index
tuples.  This single primitive instantiates every sumcheck zkDL needs:

* Thaler's specialized matmul GKR layer  -> one product of 2 tables,
* the zkReLU Hadamard relations (2)/(4)  -> products of 3 tables,
* the cross-layer stacking relation (27) -> two degree-3 products sharing
  the (1 - B_{Q-1}) table.

The prover is pure JAX (limb arrays); the verifier is host-side python-int
arithmetic.  Both drive the shared Fiat-Shamir transcript.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.field import FQ, add, sub, mont_mul, decode
from repro.core import mle
from repro.core.mle import enc, fsum, hadd, hmul, lagrange_eval
from repro.core.transcript import Transcript

Q = FQ.modulus


@dataclasses.dataclass
class SumcheckProof:
    # messages[r] = list of degree+1 ints: round poly evals at X=0..degree
    messages: List[List[int]]


def _decode_scalar(x) -> int:
    return int(decode(FQ, x)[()])


def sumcheck_prove(
    tables: List,
    products: Sequence[Tuple[int, ...]],
    transcript: Transcript,
    label: bytes,
    coefs: Sequence[int] | None = None,
) -> Tuple[SumcheckProof, List[int], List[int]]:
    """Returns (proof, point, final_values) where final_values[k] = T_k(point).

    ``coefs`` (optional) gives one public field coefficient per product:
    claim = sum_b sum_p coefs[p] * prod_k T_k(b) -- the random-linear-
    combination batching of per-layer GKR claims (Fig. 3 / Example 4.5).
    """
    n = tables[0].shape[0]
    assert all(t.shape[0] == n for t in tables)
    degree = max(len(p) for p in products)
    tables = list(tables)
    rounds = n.bit_length() - 1
    assert n == 1 << rounds
    coef_limbs = None
    if coefs is not None:
        coef_limbs = [enc(int(c) % Q) for c in coefs]

    messages: List[List[int]] = []
    point: List[int] = []
    for _ in range(rounds):
        evens = [t[0::2] for t in tables]
        odds = [t[1::2] for t in tables]
        diffs = [sub(FQ, o, e) for o, e in zip(odds, evens)]
        # evals[t][k] = table k evaluated at X=t (as (n/2,4) residual table)
        evals = [evens, odds]
        cur = odds
        for _ in range(2, degree + 1):
            cur = [add(FQ, c, d) for c, d in zip(cur, diffs)]
            evals.append(cur)
        msg = []
        for t in range(degree + 1):
            acc = None
            for pi, prod in enumerate(products):
                term = evals[t][prod[0]]
                for k in prod[1:]:
                    term = mont_mul(FQ, term, evals[t][k])
                if coef_limbs is not None:
                    term = mont_mul(FQ, term, coef_limbs[pi][None])
                acc = term if acc is None else add(FQ, acc, term)
            msg.append(_decode_scalar(fsum(acc)))
        messages.append(msg)
        transcript.absorb_ints(label + b"/round", msg)
        r = transcript.challenge_int(label + b"/r", Q)
        point.append(r)
        r_l = enc(r)
        if mle.fold_backend() == "pallas":
            # fused fold kernel: one VMEM pass per table instead of
            # materializing diff and diff*r (see kernels/sumcheck_fold)
            tables = [mle.fold(t, r_l) for t in tables]
        else:
            tables = [add(FQ, e, mont_mul(FQ, d, r_l[None]))
                      for e, d in zip(evens, diffs)]
    final_values = [_decode_scalar(t[0]) for t in tables]
    transcript.absorb_ints(label + b"/final", final_values)
    return SumcheckProof(messages), point, final_values


def sumcheck_verify(
    claim: int,
    proof: SumcheckProof,
    degree: int,
    rounds: int,
    transcript: Transcript,
    label: bytes,
) -> Tuple[List[int], int]:
    """Checks round consistency; returns (point, expected final combination).

    The caller must separately check that
        expected == sum_p prod_k final_values[k]
    using final values that are themselves bound to commitments.
    Raises ValueError on an inconsistent transcript.
    """
    if len(proof.messages) != rounds:
        raise ValueError("sumcheck: wrong number of rounds")
    running = claim % Q
    point: List[int] = []
    for msg in proof.messages:
        if len(msg) != degree + 1:
            raise ValueError("sumcheck: wrong round-poly degree")
        if hadd(msg[0], msg[1]) != running:
            raise ValueError("sumcheck: round consistency check failed")
        transcript.absorb_ints(label + b"/round", msg)
        r = transcript.challenge_int(label + b"/r", Q)
        point.append(r)
        running = lagrange_eval(msg, r)
    return point, running


def combine_final(products: Sequence[Tuple[int, ...]], final_values: List[int],
                  coefs: Sequence[int] | None = None) -> int:
    acc = 0
    for pi, prod in enumerate(products):
        term = 1
        for k in prod:
            term = hmul(term, final_values[k])
        if coefs is not None:
            term = hmul(term, int(coefs[pi]) % Q)
        acc = hadd(acc, term)
    return acc
