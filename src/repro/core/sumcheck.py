"""Generic sum-of-products sumcheck prover/verifier over FQ.

Proves claims of the form

    claim = sum_{b in {0,1}^d}  sum_p  prod_{k in products[p]} T_k(b)

for a list of distinct MLE tables ``T_k`` and products given as index
tuples.  This single primitive instantiates every sumcheck zkDL needs:

* Thaler's specialized matmul GKR layer  -> one product of 2 tables,
* the zkReLU Hadamard relations (2)/(4)  -> products of 3 tables,
* the cross-layer stacking relation (27) -> two degree-3 products sharing
  the (1 - B_{Q-1}) table.

The prover is pure JAX (limb arrays); the verifier is host-side python-int
arithmetic.  Both drive the shared Fiat-Shamir transcript.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.field import FQ, add, sub, mont_mul, decode
from repro.core import execache, mle
from repro.core.mle import enc, enc_vec, fsum, hadd, hmul, lagrange_eval
from repro.core.transcript import Transcript

Q = FQ.modulus

# ---------------------------------------------------------------------------
# Round execution mode.
#
# "scan" (default): every round runs on a FIXED (K, n0, 4) buffer — the
# fold writes the halved table back into the zeroed front half, so all
# ``rounds`` iterations reuse the SAME two compiled programs (one
# round-message body, one fold body) instead of tracing a fresh pair per
# shrinking shape.  Compile cost per bucket: O(1) in depth/T.  "unrolled"
# keeps the legacy shrinking-shape path as the bit-identity parity
# oracle (tests/test_fold_dispatch.py).
# ---------------------------------------------------------------------------

SCAN_MODES = ("scan", "unrolled")
_SCAN_MODE_ENV = "ZKDL_SUMCHECK_MODE"
_scan_mode_override: str | None = None


def scan_mode() -> str:
    """Active round mode: override > $ZKDL_SUMCHECK_MODE > "scan"."""
    name = _scan_mode_override or os.environ.get(_SCAN_MODE_ENV,
                                                 "scan").lower()
    if name not in SCAN_MODES:
        raise ValueError(f"unknown sumcheck mode {name!r}; "
                         f"choose from {SCAN_MODES}")
    return name


def set_scan_mode(name: str | None) -> None:
    """Process-wide override (None restores the env/default choice)."""
    global _scan_mode_override
    if name is not None and name not in SCAN_MODES:
        raise ValueError(f"unknown sumcheck mode {name!r}; "
                         f"choose from {SCAN_MODES}")
    _scan_mode_override = name


@dataclasses.dataclass
class SumcheckProof:
    # messages[r] = list of degree+1 ints: round poly evals at X=0..degree
    messages: List[List[int]]


def _decode_scalar(x) -> int:
    return int(decode(FQ, x)[()])


def _decode_scalars(x) -> List[int]:
    return [int(v) for v in decode(FQ, x)]


def _round_msgs_impl(stack, idx, coef_limbs, degree: int):
    """All degree+1 round-poly evaluations for a (K, n, 4) table stack in
    ONE executable: returns (degree+1, 4) sums.

    ``idx`` is the (P, degree) product-index matrix, ragged products
    padded with index K -- a synthetic Montgomery-ONE table appended to
    the eval stack (multiplying a canonical element by the Montgomery
    unit is exact identity, so padded factors change nothing).  The
    per-product work is a gather + a degree-step vectorized multiply,
    keeping the XLA graph small for any product count.

    Zero-padded tail columns (scan mode keeps dead halves as zeros) are
    exactly neutral: every product's first factor is a real table — zero
    on dead columns — and mont_mul(0, x) = 0, so padded terms add
    nothing to any message."""
    evens, odds = stack[:, 0::2], stack[:, 1::2]
    diffs = sub(FQ, odds, evens)
    one_row = jnp.broadcast_to(enc(1), (1,) + evens.shape[1:]).astype(jnp.uint32)
    zero_row = jnp.zeros((1,) + evens.shape[1:], jnp.uint32)
    evens = jnp.concatenate([evens, one_row])
    odds = jnp.concatenate([odds, one_row])
    diffs = jnp.concatenate([diffs, zero_row])
    evals = [evens, odds]
    cur = odds
    for _ in range(2, degree + 1):
        cur = add(FQ, cur, diffs)
        evals.append(cur)
    msgs = []
    for t in range(degree + 1):
        ev = evals[t]
        term = ev[idx[:, 0]]
        for k in range(1, degree):
            term = mont_mul(FQ, term, ev[idx[:, k]])
        term = mont_mul(FQ, term, coef_limbs[:, None, :])
        msgs.append(fsum(term.reshape(-1, 4)))
    return jnp.stack(msgs)


_round_msgs = execache.wrap("sc_round_msgs", _round_msgs_impl,
                            static_argnames=("degree",))


@jax.jit
def _fold_stack(stack, r_l):
    """Fix variable 0 of every table in the (K, n, 4) stack at r."""
    evens, odds = stack[:, 0::2], stack[:, 1::2]
    return add(FQ, evens, mont_mul(FQ, sub(FQ, odds, evens), r_l[None, None]))


def _fold_stack_fixed_impl(stack, r_l):
    """Shape-preserving fold: halve every table, zero-fill the freed
    tail.  Live entries occupy a prefix (cols 0..live-1); the even/odd
    split maps that prefix onto the folded prefix and the zero tail onto
    zeros (sub/mul/add of zeros is exactly zero), so iterating this ONE
    program ``rounds`` times is value-identical to the shrinking-shape
    unrolled path — the final value still lands at stack[:, 0]."""
    evens, odds = stack[:, 0::2], stack[:, 1::2]
    folded = add(FQ, evens, mont_mul(FQ, sub(FQ, odds, evens),
                                     r_l[None, None]))
    return jnp.concatenate([folded, jnp.zeros_like(folded)], axis=1)


_fold_stack_fixed = execache.wrap("sc_fold_fixed", _fold_stack_fixed_impl)


def _scan_fold_fixed_impl(stack, r_l):
    """The fixed-shape fold with the Pallas `kernels/sumcheck_fold`
    kernel as the per-table body, scanned over the stacked instance axis
    K — one compiled body regardless of how many tables the bucket
    stacks (the levanter scan-over-layers idiom applied to the proof
    tables)."""
    from repro.kernels.sumcheck_fold import fold as kernel_fold

    def body(carry, table):
        folded = kernel_fold(table, r_l)
        return carry, jnp.concatenate([folded, jnp.zeros_like(folded)])

    _, out = jax.lax.scan(body, None, stack)
    return out


_scan_fold_fixed = execache.wrap("sc_fold_fixed_pallas",
                                 _scan_fold_fixed_impl)


def sumcheck_prove(
    tables: List,
    products: Sequence[Tuple[int, ...]],
    transcript: Transcript,
    label: bytes,
    coefs: Sequence[int] | None = None,
) -> Tuple[SumcheckProof, List[int], List[int]]:
    """Returns (proof, point, final_values) where final_values[k] = T_k(point).

    ``coefs`` (optional) gives one public field coefficient per product:
    claim = sum_b sum_p coefs[p] * prod_k T_k(b) -- the random-linear-
    combination batching of per-layer GKR claims (Fig. 3 / Example 4.5).

    The K tables live as one (K, n, 4) stack and every round issues
    exactly two fused dispatches (round-poly evaluations, then the fold)
    plus one host transfer for the Fiat-Shamir absorb, instead of O(K *
    degree) eager ops and degree+1 transfers.
    """
    n = tables[0].shape[0]
    assert all(t.shape[0] == n for t in tables)
    degree = max(len(p) for p in products)
    rounds = n.bit_length() - 1
    assert n == 1 << rounds
    if coefs is not None:
        coef_limbs = enc_vec([int(c) % Q for c in coefs])
    else:
        coef_limbs = jnp.broadcast_to(enc(1), (len(products), 4))
    k_one = len(tables)            # index of the synthetic ONE pad table
    idx = jnp.asarray(np.array(
        [list(p) + [k_one] * (degree - len(p)) for p in products],
        dtype=np.int32))

    stack = jnp.stack(tables)
    messages: List[List[int]] = []
    point: List[int] = []
    pallas = mle.fold_backend() == "pallas"
    fixed = scan_mode() == "scan"
    for _ in range(rounds):
        msg = _decode_scalars(_round_msgs(stack, idx, coef_limbs,
                                          degree=degree))
        messages.append(msg)
        transcript.absorb_ints(label + b"/round", msg)
        r = transcript.challenge_int(label + b"/r", Q)
        point.append(r)
        r_l = enc(r)
        if fixed:
            # fixed-shape rounds: the buffer never shrinks, so all
            # log2(n) folds (and all round-message evaluations above)
            # share ONE compiled program each
            stack = (_scan_fold_fixed(stack, r_l) if pallas
                     else _fold_stack_fixed(stack, r_l))
        elif pallas:
            # legacy unrolled path, fused fold kernel: one VMEM pass per
            # table instead of materializing diff and diff*r
            stack = jnp.stack([mle.fold(stack[k], r_l)
                               for k in range(stack.shape[0])])
        else:
            stack = _fold_stack(stack, r_l)
    final_values = _decode_scalars(stack[:, 0])
    transcript.absorb_ints(label + b"/final", final_values)
    return SumcheckProof(messages), point, final_values


def sumcheck_verify(
    claim: int,
    proof: SumcheckProof,
    degree: int,
    rounds: int,
    transcript: Transcript,
    label: bytes,
) -> Tuple[List[int], int]:
    """Checks round consistency; returns (point, expected final combination).

    The caller must separately check that
        expected == sum_p prod_k final_values[k]
    using final values that are themselves bound to commitments.
    Raises ValueError on an inconsistent transcript.
    """
    if len(proof.messages) != rounds:
        raise ValueError("sumcheck: wrong number of rounds")
    running = claim % Q
    point: List[int] = []
    for msg in proof.messages:
        if len(msg) != degree + 1:
            raise ValueError("sumcheck: wrong round-poly degree")
        if hadd(msg[0], msg[1]) != running:
            raise ValueError("sumcheck: round consistency check failed")
        transcript.absorb_ints(label + b"/round", msg)
        r = transcript.challenge_int(label + b"/r", Q)
        point.append(r)
        running = lagrange_eval(msg, r)
    return point, running


def combine_final(products: Sequence[Tuple[int, ...]], final_values: List[int],
                  coefs: Sequence[int] | None = None) -> int:
    acc = 0
    for pi, prod in enumerate(products):
        term = 1
        for k in prod:
            term = hmul(term, final_values[k])
        if coefs is not None:
            term = hmul(term, int(coefs[pi]) % Q)
        acc = hadd(acc, term)
    return acc
