"""Step (a): the three batched matmul sumchecks (Fig. 3, eqs 30/33/34).

One forward, one backward, and one weight-gradient sumcheck, each
batching EVERY layer of EVERY aggregated training step under a single
set of randomness: pair (t, l) contributes two fixed tables and a public
coefficient e(u_s)[slot(t, l)], so the per-(step, layer) GKR claims
collapse into three sumchecks whose round count is log2(width) or
log2(batch) -- independent of both L and T.

Final-value indexing (shared with the anchor stage and the verifier):
fwd pair (t,l), l in 1..L   -> tables [A^{l-1,t}, W^{l,t}]
bwd pair (t,l), l in 1..L-1 -> tables [G_Z^{l+1,t}, W^{l+1,t}]
gw  pair (t,l), l in 1..L   -> tables [G_Z^{l,t},  A^{l-1,t}]
with pair index t*L + (l-1)  (t*(L-1) + (l-1) for bwd).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.field import FQ
from repro.core.mle import hexpand_point
from repro.core.sumcheck import (SumcheckProof, combine_final,
                                 sumcheck_prove, sumcheck_verify)
from repro.core.transcript import Transcript
from repro.core.pipeline.challenges import ChallengeSchedule
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.tables import fix_cols, fix_rows, log2_exact
from repro.core.pipeline.witness import FieldTables

Q_MOD = FQ.modulus


def fwd_pair(cfg: PipelineConfig, t: int, l: int) -> int:
    """Pair index of layer l (1-based) of step t in the fwd sumcheck."""
    return t * cfg.n_layers + (l - 1)


def bwd_pair(cfg: PipelineConfig, t: int, l: int) -> int:
    return t * (cfg.n_layers - 1) + (l - 1)


def gw_pair(cfg: PipelineConfig, t: int, l: int) -> int:
    return t * cfg.n_layers + (l - 1)


def _coefs(cfg: PipelineConfig, e_slot: List[int], layers: range):
    """e_slot[slot(t, l-1)] for every pair (t, l), in pair order."""
    return [e_slot[cfg.slot(t, l - 1)]
            for t in range(cfg.n_steps) for l in layers]


@dataclasses.dataclass
class MatmulOut:
    sc_fwd: SumcheckProof
    sc_bwd: SumcheckProof
    sc_gw: SumcheckProof
    fwd_finals: List[int]
    bwd_finals: List[int]
    gw_finals: List[int]
    w1: List[int]          # bound point of the fwd sumcheck (col vars)
    w2: List[int]          # bwd (col vars)
    w3: List[int]          # gw (row vars)


def prove(cfg: PipelineConfig, tabs: FieldTables, ch: ChallengeSchedule,
          t: Transcript) -> MatmulOut:
    T, L = cfg.n_steps, cfg.n_layers
    ef = hexpand_point(ch.u_sf)
    eb = hexpand_point(ch.u_sb)
    ew = hexpand_point(ch.u_sw)

    # forward: sum_{t,l} ef[slot] Z~^{l,t}(u_r,u_c) = sum_w A W
    fwd_tables, fwd_products = [], []
    for ti in range(T):
        for l in range(1, L + 1):
            fa = fix_rows(tabs.a_tabs[ti][l - 1], ch.u_r)
            fw = fix_cols(tabs.w_mats[ti][l - 1], ch.u_c)
            p = 2 * fwd_pair(cfg, ti, l)
            fwd_tables += [fa, fw]
            fwd_products.append((p, p + 1))
    sc_fwd, w1, fwd_finals = sumcheck_prove(
        fwd_tables, fwd_products, t, b"fwd",
        coefs=_coefs(cfg, ef, range(1, L + 1)))

    # backward: sum_{t,l} eb[slot] GA~^{l,t}(u_r2,u_c2) = sum GZ^{l+1} W^{l+1}
    bwd_tables, bwd_products = [], []
    for ti in range(T):
        for l in range(1, L):
            fg = fix_rows(tabs.gz_tabs[ti][l], ch.u_r2)     # GZ^{l+1,t}
            fw = fix_rows(tabs.w_mats[ti][l], ch.u_c2)      # W^{l+1,t} rows
            p = 2 * bwd_pair(cfg, ti, l)
            bwd_tables += [fg, fw]
            bwd_products.append((p, p + 1))
    sc_bwd, w2, bwd_finals = sumcheck_prove(
        bwd_tables, bwd_products, t, b"bwd",
        coefs=_coefs(cfg, eb, range(1, L)))

    # gw: sum_{t,l} ew[slot] GW~^{l,t}(u_i,u_j) = sum_b GZ^l A^{l-1}
    gw_tables, gw_products = [], []
    for ti in range(T):
        for l in range(1, L + 1):
            fg = fix_cols(tabs.gz_tabs[ti][l - 1], ch.u_i)
            fa = fix_cols(tabs.a_tabs[ti][l - 1], ch.u_j)
            p = 2 * gw_pair(cfg, ti, l)
            gw_tables += [fg, fa]
            gw_products.append((p, p + 1))
    sc_gw, w3, gw_finals = sumcheck_prove(
        gw_tables, gw_products, t, b"gw",
        coefs=_coefs(cfg, ew, range(1, L + 1)))

    return MatmulOut(sc_fwd=sc_fwd, sc_bwd=sc_bwd, sc_gw=sc_gw,
                     fwd_finals=fwd_finals, bwd_finals=bwd_finals,
                     gw_finals=gw_finals, w1=w1, w2=w2, w3=w3)


def verify(cfg: PipelineConfig, proof, op, ch: ChallengeSchedule,
           t: Transcript) -> Tuple[List[int], List[int], List[int]]:
    """Checks the three sumchecks; returns (w1, w2, w3) bound points.

    Raises ValueError on any inconsistency (caught by the caller)."""
    T, L = cfg.n_steps, cfg.n_layers
    lb, ld = log2_exact(cfg.batch), log2_exact(cfg.width)
    ef = hexpand_point(ch.u_sf)
    eb = hexpand_point(ch.u_sb)
    ew = hexpand_point(ch.u_sw)
    two_r = pow(2, cfg.r_bits, Q_MOD)
    two_qr1 = pow(2, cfg.q_bits + cfg.r_bits - 1, Q_MOD)

    claim_fwd = (two_r * op["a1"] - two_qr1 * op["a2"] + op["a3"]) % Q_MOD
    fwd_products = [(2 * i, 2 * i + 1) for i in range(T * L)]
    w1, exp_fwd = sumcheck_verify(claim_fwd, proof.sc_fwd, 2, ld, t, b"fwd")
    if exp_fwd != combine_final(fwd_products, proof.fwd_finals,
                                coefs=_coefs(cfg, ef, range(1, L + 1))):
        raise ValueError("fwd-final")
    t.absorb_ints(b"fwd/final", proof.fwd_finals)

    claim_bwd = (two_r * op["a4"] + op["a5"]) % Q_MOD
    bwd_products = [(2 * i, 2 * i + 1) for i in range(T * (L - 1))]
    w2, exp_bwd = sumcheck_verify(claim_bwd, proof.sc_bwd, 2, ld, t, b"bwd")
    if exp_bwd != combine_final(bwd_products, proof.bwd_finals,
                                coefs=_coefs(cfg, eb, range(1, L))):
        raise ValueError("bwd-final")
    t.absorb_ints(b"bwd/final", proof.bwd_finals)

    claim_gw = op["a6"]
    gw_products = [(2 * i, 2 * i + 1) for i in range(T * L)]
    w3, exp_gw = sumcheck_verify(claim_gw, proof.sc_gw, 2, lb, t, b"gw")
    if exp_gw != combine_final(gw_products, proof.gw_finals,
                               coefs=_coefs(cfg, ew, range(1, L + 1))):
        raise ValueError("gw-final")
    t.absorb_ints(b"gw/final", proof.gw_finals)
    return w1, w2, w3
