"""Step (a): shape-bucketed batched matmul sumchecks (Fig. 3, eqs 30/33/34).

The seed's three hardcoded fwd/bwd/gw sumchecks are the uniform-width
special case of a general rule: every matmul relation instance of the
layer graph is keyed by its sumcheck table length (padded inner
dimension) and all instances in a bucket — across layers AND aggregated
training steps — share ONE batched sumcheck.  Pair (t, instance) enters
with the public coefficient

    e(u_slot)[slot(t, node)] * padfac(instance)

where padfac is the zero-padding factor of the instance's claim tensor
inside its slot (1 for the widest shape).  The per-bucket initial claims
sum to the family target derived from the stacked-commitment openings
a1..a6; with more than one bucket the prover transmits the split (it is
redundant for a single bucket, so uniform graphs keep the exact seed
transcript).

Final-value indexing (shared with the anchor stage and the verifier):
within bucket b of a family, pair (t, pos) -> tables
[left, right] at indices [2p, 2p+1] with p = t * len(b.instances) + pos.
`MatmulOut.final` / `LayerGraph.locate` hide this arithmetic from the
other stages.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.field import FQ
from repro.core.mle import fdot, hexpand_point
from repro.core.sumcheck import (SumcheckProof, combine_final,
                                 sumcheck_prove, sumcheck_verify)
from repro.core.transcript import Transcript
from repro.core.pipeline.challenges import ChallengeSchedule, instance_slices
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.graph import MatmulInstance
from repro.core.pipeline.tables import dec_scalar, fix_cols, fix_rows
from repro.core.pipeline.witness import FieldTables

Q_MOD = FQ.modulus

FAMILY_LABELS = {"fwd": b"fwd", "bwd": b"bwd", "gw": b"gw"}


def slot_axis_point(ch: ChallengeSchedule, family: str) -> List[int]:
    return {"fwd": ch.u_sf, "bwd": ch.u_sb, "gw": ch.u_sw}[family]


def _slots_of(cfg: PipelineConfig, inst: MatmulInstance,
              ti: int) -> List[int]:
    if inst.family == "gw":
        return [cfg.wslot(ti, s) for s in inst.claim_slots]
    return [cfg.slot(ti, s) for s in inst.claim_slots]


def bucket_coefs(cfg: PipelineConfig, ch: ChallengeSchedule,
                 bucket) -> List[int]:
    """Public pair coefficients sum_s e(u_slot)[slot(t, s)] * padfac,
    t-major, in the bucket's pair order (identical on both sides of the
    protocol).  The sum over an instance's claim slots is the residual
    backward split: the gradient of A1 + A2 feeds both producers'
    committed gap/rga decompositions, so ONE sumcheck pair carries both
    slot coefficients."""
    e_slot = hexpand_point(slot_axis_point(ch, bucket.family))
    glob = ch.glob(bucket.family)
    out = []
    for ti in range(cfg.n_steps):
        for inst in bucket.instances:
            _, _, padfac = instance_slices(inst, glob)
            c = sum(e_slot[s] for s in _slots_of(cfg, inst, ti)) % Q_MOD
            out.append(c * padfac % Q_MOD)
    return out


def _fix_operands(tabs: FieldTables, inst: MatmulInstance, ti: int,
                  u_cols: List[int], u_rows: List[int]):
    """The two length-`inner` sumcheck tables of one (step, instance)."""
    l = inst.layer
    if inst.family == "fwd":
        # Z^l(u_rows, u_cols) = sum_k A^{l-1}(u_rows, k) W^l(k, u_cols)
        return (fix_rows(tabs.a_tabs[ti][l - 1], u_rows),
                fix_cols(tabs.w_mats[ti][l - 1], u_cols))
    if inst.family == "bwd":
        # G_A^l(u_rows, u_cols) = sum_j G_Z^{l+1}(u_rows, j) W^{l+1}(u_cols, j)
        return (fix_rows(tabs.gz_tabs[ti][l], u_rows),
                fix_rows(tabs.w_mats[ti][l], u_cols))
    # gw: G_W^l(u_rows, u_cols) = sum_b G_Z^l(b, u_rows) A^{l-1}(b, u_cols)
    return (fix_cols(tabs.gz_tabs[ti][l - 1], u_rows),
            fix_cols(tabs.a_tabs[ti][l - 1], u_cols))


@dataclasses.dataclass
class FamilyOut:
    claims: List[int]              # per-bucket initial claims
    scs: List[SumcheckProof]
    finals: List[List[int]]
    points: List[List[int]]        # bound (inner-variable) point per bucket


@dataclasses.dataclass
class MatmulOut:
    fams: Dict[str, FamilyOut]

    def point(self, cfg: PipelineConfig, family: str, layer: int) -> List[int]:
        bi, _ = cfg.graph.locate(family, layer)
        return self.fams[family].points[bi]

    def final(self, cfg: PipelineConfig, family: str, ti: int, layer: int,
              idx: int) -> int:
        """Final value of pair (step ti, layer)'s left (idx=0) or right
        (idx=1) table in its bucket's sumcheck."""
        bi, pos = cfg.graph.locate(family, layer)
        bucket = cfg.graph.buckets[family][bi]
        p = ti * len(bucket.instances) + pos
        return self.fams[family].finals[bi][2 * p + idx]


def pair_final(cfg: PipelineConfig, finals: List[List[int]], family: str,
               ti: int, layer: int, idx: int) -> int:
    """Verifier-side twin of `MatmulOut.final` over raw proof lists."""
    bi, pos = cfg.graph.locate(family, layer)
    bucket = cfg.graph.buckets[family][bi]
    p = ti * len(bucket.instances) + pos
    return finals[bi][2 * p + idx]


def prove(cfg: PipelineConfig, tabs: FieldTables, ch: ChallengeSchedule,
          t: Transcript) -> MatmulOut:
    fams: Dict[str, FamilyOut] = {}
    for fam in ("fwd", "bwd", "gw"):
        label = FAMILY_LABELS[fam]
        buckets = cfg.graph.buckets[fam]
        glob = ch.glob(fam)
        fixed = []                 # per bucket: (tables, products, coefs)
        for bucket in buckets:
            tables, products = [], []
            for ti in range(cfg.n_steps):
                for inst in bucket.instances:
                    u_cols, u_rows, _ = instance_slices(inst, glob)
                    left, right = _fix_operands(tabs, inst, ti,
                                                u_cols, u_rows)
                    p = len(tables)
                    tables += [left, right]
                    products.append((p, p + 1))
            fixed.append((tables, products, bucket_coefs(cfg, ch, bucket)))

        # the per-bucket claim split is only transmitted (and only
        # needed) when the family has more than one bucket; a single
        # bucket's claim is implicit in the a1..a6 openings
        claims = []
        if len(buckets) > 1:
            for tables, products, coefs in fixed:
                acc = 0
                for (i, j), c in zip(products, coefs):
                    acc = (acc + c * dec_scalar(fdot(tables[i],
                                                     tables[j]))) % Q_MOD
                claims.append(acc)
            t.absorb_ints(label + b"/claims", claims)

        out = FamilyOut(claims=claims, scs=[], finals=[], points=[])
        for tables, products, coefs in fixed:
            sc, w, finals = sumcheck_prove(tables, products, t, label,
                                           coefs=coefs)
            out.scs.append(sc)
            out.points.append(w)
            out.finals.append(finals)
        fams[fam] = out
    return MatmulOut(fams=fams)


def family_targets(cfg: PipelineConfig, op: Dict[str, int]) -> Dict[str, int]:
    """Family claim totals from the stacked-commitment openings: the
    opening points pi1/pi2/pi3 span the whole (elem, node, step) cube,
    so the linear zkReLU decompositions (3)/(5) turn a1..a6 into the
    batched matmul claims summed over every bucket."""
    two_r = pow(2, cfg.r_bits, Q_MOD)
    two_qr1 = pow(2, cfg.q_bits + cfg.r_bits - 1, Q_MOD)
    return {
        "fwd": (two_r * op["a1"] - two_qr1 * op["a2"] + op["a3"]) % Q_MOD,
        "bwd": (two_r * op["a4"] + op["a5"]) % Q_MOD,
        "gw": op["a6"] % Q_MOD,
    }


def verify(cfg: PipelineConfig, proof, op, ch: ChallengeSchedule,
           t: Transcript) -> Dict[str, List[List[int]]]:
    """Checks every bucket sumcheck; returns the bound points per family.

    Raises ValueError on any inconsistency (caught by the caller)."""
    targets = family_targets(cfg, op)
    points: Dict[str, List[List[int]]] = {}
    for fam in ("fwd", "bwd", "gw"):
        label = FAMILY_LABELS[fam]
        buckets = cfg.graph.buckets[fam]
        scs = getattr(proof, f"sc_{fam}")
        finals = getattr(proof, f"{fam}_finals")
        claims = getattr(proof, f"{fam}_claims")
        if len(scs) != len(buckets) or len(finals) != len(buckets):
            raise ValueError(f"{fam}-bucket-count")
        if len(buckets) == 1:
            if claims:
                raise ValueError(f"{fam}-claim-split")   # must be implicit
            claims = [targets[fam]]
        else:
            if len(claims) != len(buckets):
                raise ValueError(f"{fam}-claim-split")
            if sum(claims) % Q_MOD != targets[fam]:
                raise ValueError(f"{fam}-claim-split")
            t.absorb_ints(label + b"/claims", claims)
        points[fam] = []
        for bi, bucket in enumerate(buckets):
            n_pairs = cfg.n_steps * len(bucket.instances)
            products = [(2 * i, 2 * i + 1) for i in range(n_pairs)]
            w, expected = sumcheck_verify(claims[bi], scs[bi], 2,
                                          bucket.rounds, t, label)
            if expected != combine_final(products, finals[bi],
                                         coefs=bucket_coefs(cfg, ch, bucket)):
                raise ValueError(f"{fam}-final")
            t.absorb_ints(label + b"/final", finals[bi])
            points[fam].append(w)
    return points
