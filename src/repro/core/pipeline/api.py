"""The graph-first prover lifecycle: compile -> prove -> verify.

    graph = (GraphBuilder(batch=4).input(16)
             .dense(16).relu().dense(16).relu()
             .residual(to=1).dense(16).relu().output())
    pk, vk = compile(graph, quant=QuantConfig(16, 8), n_steps=T)

    session = ProofSession(pk)
    for wit in witnesses:                  # T of them
        session.add_step(wit)
    proof_bytes = encode_proof(session.prove())

    # any other process, from bytes alone:
    vk = decode_vk(vk_bytes)
    assert verify_bytes(vk, proof_bytes)

`compile` is the one-time setup phase: it freezes the graph's bucket and
slot layout into a `PipelineConfig` and derives every Pedersen/zkReLU
generator table — reusable across sessions, trajectories and processes.
The `ProvingKey` carries the full generator tables (big, prover-side
only); the `VerifyingKey` carries just the graph + quantization geometry
and re-derives its generators deterministically on first use, so its
serialized form (`VerifyingKey.to_bytes`) is a few hundred bytes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

from repro.core.quantfc import QuantConfig
from repro.core.pipeline.config import (PipelineConfig, PipelineKeys,
                                        make_keys)
from repro.core.pipeline.graph import LayerGraph


@dataclasses.dataclass(frozen=True)
class ProvingKey:
    """Prover-side setup artifact: config + full generator tables.

    The compiled executables behind a key are cached process-wide AND
    on disk (`repro.core.execache`), keyed by the argument shapes every
    program sees — which fully encode (graph_spec, quant, T, backend).
    `warm()` populates that cache ahead of time; `exec_stats()` reports
    hit/miss/disk counters, so "a second ProofSession never re-traces"
    is an observable property, not a hope."""
    keys: PipelineKeys

    @property
    def cfg(self) -> PipelineConfig:
        return self.keys.cfg

    @property
    def graph(self) -> LayerGraph:
        return self.keys.cfg.graph

    def warm(self, seed: int = 0) -> dict:
        """AOT-compile every prover executable for this key's geometry.

        Proves one throwaway synthetic window end to end (program
        shapes — not values — determine what compiles, and the
        executable cache keys on shapes), serializing each executable
        to the disk cache as it builds.  Returns the executable-cache
        stats delta; after a warm (this process or a fresh one sharing
        the disk cache) a `ProofSession(pk).prove()` re-traces nothing.
        """
        import numpy as np

        from repro.core import execache
        from repro.core.quantfc import synthetic_sgd_trajectory_widths
        from repro.core.pipeline.graph import graph_skips, graph_widths
        from repro.core.pipeline.session import ProofSession

        before = execache.stats()
        cfg = self.cfg
        quant = QuantConfig(q_bits=cfg.q_bits, r_bits=cfg.r_bits)
        wits = synthetic_sgd_trajectory_widths(
            cfg.n_steps, graph_widths(cfg.graph), cfg.batch, quant,
            seed=seed, skips=graph_skips(cfg.graph))
        session = ProofSession(self, np.random.default_rng(seed))
        for wit in wits:
            session.add_step(wit)
        proof = session.prove()
        assert session.verify(proof), "warm-up proof rejected"
        after = execache.stats()
        return {k: after[k] - before[k] for k in after}

    def exec_stats(self) -> dict:
        from repro.core import execache
        return execache.stats()


@dataclasses.dataclass(frozen=True)
class VerifyingKey:
    """Verifier-side setup artifact: graph + quantization geometry.

    Generator tables derive lazily (deterministic label-based
    derivation, identical to the prover's), so the key serializes to a
    few hundred bytes and `verify_bytes` needs no session state."""
    cfg: PipelineConfig

    @functools.cached_property
    def keys(self) -> PipelineKeys:
        return make_keys(self.cfg)

    @property
    def graph(self) -> LayerGraph:
        return self.cfg.graph

    def to_bytes(self) -> bytes:
        from repro.core.pipeline.proofio import encode_vk
        return encode_vk(self)

    @classmethod
    def from_bytes(cls, data: bytes) -> "VerifyingKey":
        from repro.core.pipeline.proofio import decode_vk
        return decode_vk(data)


def compile(graph: LayerGraph, quant: Optional[QuantConfig] = None,
            n_steps: int = 1,
            warm: bool = False) -> Tuple[ProvingKey, VerifyingKey]:
    """One-time setup for a proof graph: freeze the bucket/slot layout
    and derive the commitment generators.

    The graph is the single source of truth — shapes, slot maps, shape
    buckets and the challenge-schedule geometry all derive from it; only
    the quantization (`quant`) and the aggregation window (`n_steps`)
    are free parameters.  Returns ``(ProvingKey, VerifyingKey)``; both
    wrap the same deterministic generator derivation, so a vk
    reconstructed from bytes in another process verifies proofs made
    with this pk.

    ``warm=True`` additionally AOT-compiles every prover executable for
    this geometry (one throwaway synthetic window through the full
    prover; see `ProvingKey.warm`), so the first real `prove()` pays
    zero trace/compile time — and, via the serialized-executable disk
    cache, neither does any later process for the same config."""
    # setup is the natural choke point every prover/verifier process
    # passes through: enabling the persistent XLA compilation cache here
    # (idempotent config flips) turns the ~tens-of-seconds first-prove
    # jit cost into a disk-cache hit for every later process
    from repro.util import enable_compilation_cache
    enable_compilation_cache()
    quant = quant if quant is not None else QuantConfig()
    cfg = PipelineConfig.from_graph(graph, q_bits=quant.q_bits,
                                    r_bits=quant.r_bits, n_steps=n_steps)
    keys = make_keys(cfg)
    pk = ProvingKey(keys=keys)
    if warm:
        pk.warm()
    return pk, VerifyingKey(cfg=cfg)
