"""Standalone verifier for aggregated pipeline proofs.

Mirrors the prover's transcript schedule exactly: absorb commitments,
draw the challenge schedule, replay steps (a)/(b)/(c) over the graph's
shape buckets.  Step (c) ends in ONE merged pair-IPA check that covers
every committed-tensor opening AND both zkReLU validity statements
(format v3; see openings.verify).  Soundness checks are expressed as
ValueError raises inside the stage modules; this module converts them
into an accept/reject bit (plus an optional failure trace for
telemetry).
"""
from __future__ import annotations

from repro.core.pipeline import anchor as anchor_mod
from repro.core.pipeline import matmul as matmul_mod
from repro.core.pipeline import openings as openings_mod
from repro.core.pipeline.challenges import ChallengeSchedule, pi_bases
from repro.core.pipeline.config import PipelineKeys
from repro.core.pipeline.session import AggregatedProof
from repro.core.transcript import Transcript


def verify(keys: PipelineKeys, proof: AggregatedProof,
           transcript: Transcript, trace: list | None = None) -> bool:
    """Trusted-verifier side of the aggregated protocol.

    If ``trace`` is a list, the name of the first failing check is
    appended (debugging/telemetry; does not affect soundness).
    """
    cfg = keys.cfg
    t = transcript
    op = proof.openings
    try:
        if proof.n_steps != cfg.n_steps:
            raise ValueError("step-count")
        if len(proof.coms.x) != cfg.n_steps * cfg.batch:
            raise ValueError("x-commitment-count")
        # the slot names AND their order are part of the format contract
        # (transcript absorption order + every coms.<name> lookup below)
        if list(proof.coms.slots) != [s.name for s in
                                      cfg.graph.commit_slots]:
            raise ValueError("commitment-schema")
        t.absorb_ints(b"coms", proof.coms.as_ints())
        ch = ChallengeSchedule.draw(t, cfg)
        t.absorb_ints(b"op1", [op[k] for k in ("a1", "a2", "a3",
                                               "a4", "a5", "a6")])
        e_pi1, e_pi2, e_pi3 = pi_bases(ch)

        points = matmul_mod.verify(cfg, proof, op, ch, t)        # step (a)
        u_star = anchor_mod.verify(cfg, proof, ch, points, t)    # step (b)
        openings_mod.verify(cfg, keys, proof, proof.coms, ch,    # step (c)
                            points, u_star, e_pi1, e_pi2, e_pi3, t)
        return True
    # ValueError: failed soundness checks / inconsistent transcript;
    # KeyError/IndexError: structurally malformed proof fields;
    # TypeError/OverflowError/ZeroDivisionError: decoded-but-garbage
    # fields hitting arithmetic (all reachable from attacker bytes, per
    # the fuzz suite).  Verifier-side programming errors
    # (AssertionError etc.) propagate -- an infrastructure bug must not
    # masquerade as a forged proof.
    except (ValueError, KeyError, IndexError, TypeError, OverflowError,
            ZeroDivisionError) as exc:
        if trace is not None:
            arg = exc.args[0] if exc.args else exc
            trace.append(arg if isinstance(arg, str) else f"exception: {exc!r}")
        return False


def verify_session(keys: PipelineKeys, proof: AggregatedProof,
                   label: bytes = b"zkdl",
                   trace: list | None = None) -> bool:
    return verify(keys, proof, Transcript(label), trace=trace)


def verify_bytes(vk, proof_bytes: bytes, label: bytes = b"zkdl",
                 trace: list | None = None) -> bool:
    """The deployment-side verifier: accept/reject from SERIALIZED bytes
    and a `VerifyingKey` alone — no session, no prover state.  Malformed
    byte streams reject (with the decode error in ``trace``) rather than
    raise: a forged proof must never crash the verifier."""
    from repro.core.pipeline.proofio import ProofDecodeError, decode_proof
    from repro.core.pipeline.session import _as_pipeline_keys

    try:
        proof = decode_proof(proof_bytes)
    except ProofDecodeError as exc:
        if trace is not None:
            trace.append(f"decode: {exc}")
        return False
    return verify(_as_pipeline_keys(vk), proof, Transcript(label),
                  trace=trace)
