"""Per-phase prover wall-clock profiler.

The aggregated prover runs in well-separated phases (witness stacking,
the commitment phase, challenge derivation, the bucketed matmul
sumchecks, the anchor sumcheck, and the step-(c) openings); attributing
prove time to phases is what lets a perf PR claim "the win came from the
commitment batching" instead of pointing at end-to-end noise.  The
profiler is always on -- a handful of ``perf_counter`` calls per prove
-- and surfaces through ``ProofSession.last_profile``, the
``benchmarks/agg_steps.py`` rows, and ``BENCH_prover_phases.json``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict


# canonical phase order (rendering / JSON emission)
PHASES = ("stack", "commit", "challenges", "matmul", "anchor", "openings")

# sub-phases of the dominant `openings` phase: claim combination (the
# per-tensor rho folds, the direct-sum assembly AND the merged-vector
# concatenation), the merged pair-IPA's L/R round loop, its final
# Schnorr opening, and the zkReLU validity statement/table preparation
# (challenge draws + the Pallas/jnp table kernel; the validity IPA
# itself rides the merged pair IPA and is accounted under ipa-rounds/
# sigma).  Tracked separately from `phases_s` so `accounted_s` (which
# the --smoke attribution check compares against total_s) never double
# counts.
SUB_PHASES = ("claim-combine", "ipa-rounds", "sigma", "zkrelu-validity")


def subphase(prof, name: str):
    """Sub-phase context of an OPTIONAL profile: `prof.subphase(name)`
    when a `PhaseProfile` is passed, a no-op context otherwise — the
    shared helper for call sites whose profiler argument defaults to
    None (ipa.open_prove, openings.prove)."""
    return (prof.subphase(name) if prof is not None
            else contextlib.nullcontext())


@dataclasses.dataclass
class PhaseProfile:
    """Accumulated per-phase seconds plus the end-to-end total."""

    phases_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    sub_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    total_s: float = 0.0

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.phases_s[name] = (self.phases_s.get(name, 0.0)
                                   + time.perf_counter() - t0)

    @contextlib.contextmanager
    def subphase(self, name: str):
        """Nested attribution inside a phase (openings sub-phases); does
        NOT contribute to `accounted_s`."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.sub_s[name] = (self.sub_s.get(name, 0.0)
                                + time.perf_counter() - t0)

    @property
    def accounted_s(self) -> float:
        """Sum of the recorded top-level phases (should be ~total_s; the
        residual is proof-object assembly and python glue)."""
        return sum(self.phases_s.values())

    def as_dict(self) -> Dict:
        ordered = {k: self.phases_s[k] for k in PHASES if k in self.phases_s}
        ordered.update({k: v for k, v in self.phases_s.items()
                        if k not in ordered})
        out = {"total_s": self.total_s,
               "accounted_s": self.accounted_s,
               "phases_s": ordered}
        if self.sub_s:
            sub = {k: self.sub_s[k] for k in SUB_PHASES if k in self.sub_s}
            sub.update({k: v for k, v in self.sub_s.items()
                        if k not in sub})
            out["sub_phases_s"] = sub
        return out
