"""Pipeline configuration and commitment keys.

`PipelineConfig` carries the per-tensor shape table of the proof graph:
``widths`` is the full MLP shape vector d_0..d_L (input width, then one
out-width per layer), so heterogeneous pyramids like 784-512-256-128-10
are first-class.  The scalar ``width`` remains as the uniform shorthand
(``widths=None`` means every d_i = width).

Commitment keys are carved out of ONE unified generator vector
(`cfg.agg_blocks` / `make_keys`): every committed tensor slot owns a
disjoint slice of the direct-sum basis the single aggregated opening
IPA runs over (see openings.py), all sharing one blinding generator.

All committed tensors are stacked over graph slots AND training steps
(the layer-stacking trick of eq. 27, applied per FAC4DNN to the whole
(step, node) axis): each aux node gets a ``d_slot``-element slot, each
weight node a ``w_slot``-element slot, with per-node zero padding to the
common slot size.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core import group, pedersen, zkrelu
from repro.core.pipeline.graph import (LayerGraph, LayerOp, build_fcnn_graph,
                                       graph_widths)
from repro.core.pipeline.tables import log2_exact, next_pow2


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_layers: int
    batch: int            # power of 2
    width: int = 0        # uniform layer width shorthand (widths wins)
    q_bits: int = 16
    r_bits: int = 8
    n_steps: int = 1      # T: training steps aggregated into one proof
    widths: Optional[Tuple[int, ...]] = None   # shape table d_0..d_L
    #: explicit graph nodes (residual MLPs etc.); None -> chain fcnn
    #: built from `widths`.  `compile()` is the usual way to set this.
    graph_spec: Optional[Tuple[LayerOp, ...]] = None

    def __post_init__(self):
        assert self.n_layers >= 2, "pipeline needs >= 2 layers (eq. 33)"
        assert self.n_steps >= 1
        assert self.batch == next_pow2(self.batch), "batch must be pow2"
        if self.widths is None:
            assert self.width >= 1, "pass width or widths"
            object.__setattr__(self, "widths",
                               (self.width,) * (self.n_layers + 1))
        else:
            object.__setattr__(self, "widths",
                               tuple(int(w) for w in self.widths))
            assert len(self.widths) == self.n_layers + 1, \
                "widths must be d_0..d_L (n_layers + 1 entries)"
            assert all(w >= 1 for w in self.widths)

    @classmethod
    def from_graph(cls, graph: LayerGraph, q_bits: int = 16,
                   r_bits: int = 8, n_steps: int = 1) -> "PipelineConfig":
        """Derive the full config from a `LayerGraph`: the graph is the
        single source of truth for shapes; only the quantization and the
        aggregation window are free parameters."""
        widths = graph_widths(graph)
        return cls(n_layers=len(widths) - 1, batch=graph.batch,
                   q_bits=q_bits, r_bits=r_bits, n_steps=n_steps,
                   widths=widths, graph_spec=graph.nodes)

    @property
    def is_uniform(self) -> bool:
        return len(set(self.widths)) == 1

    @functools.cached_property
    def graph(self) -> LayerGraph:
        """The layer-graph IR every pipeline stage iterates over."""
        if self.graph_spec is not None:
            return LayerGraph(self.graph_spec)
        return build_fcnn_graph(self.widths, self.batch)

    # -- stacked-axis geometry (all powers of two) ------------------------
    @property
    def l_pad(self) -> int:
        """Aux-slot axis length (one slot per zkReLU node)."""
        return next_pow2(len(self.graph.aux_nodes))

    @property
    def lw_pad(self) -> int:
        """Weight-slot axis length (one slot per qmatmul node)."""
        return next_pow2(len(self.graph.weight_nodes))

    @property
    def t_pad(self) -> int:
        return next_pow2(self.n_steps)

    @property
    def s_pad(self) -> int:
        """Slots on the stacked (step, aux node) axis; node varies fastest."""
        return self.t_pad * self.l_pad

    @property
    def sw_pad(self) -> int:
        return self.t_pad * self.lw_pad

    @property
    def d_elem(self) -> int:
        """Element area of one aux slot (batch x max padded width)."""
        return self.graph.d_slot

    @property
    def w_elem(self) -> int:
        return self.graph.w_slot

    @property
    def d_stack(self) -> int:
        """Stacked aux length: elem vars low, then node vars, then step."""
        return self.s_pad * self.d_elem

    @property
    def w_stack(self) -> int:
        return self.sw_pad * self.w_elem

    @property
    def y_elem(self) -> int:
        return self.graph.y_elem

    @property
    def y_stack(self) -> int:
        return self.t_pad * self.y_elem

    @property
    def x_len(self) -> int:
        """Per-sample data vector length (padded input width)."""
        return self.graph.input_node.cols_pad

    def slot(self, t: int, node_idx: int) -> int:
        """Flat (step, aux node) slot index; node_idx is 0-based."""
        assert 0 <= t < self.t_pad and 0 <= node_idx < self.l_pad
        return t * self.l_pad + node_idx

    def wslot(self, t: int, node_idx: int) -> int:
        """Flat (step, weight node) slot index."""
        assert 0 <= t < self.t_pad and 0 <= node_idx < self.lw_pad
        return t * self.lw_pad + node_idx

    # -- unified direct-sum opening layout (see openings.py) --------------
    def slot_stack_len(self, spec) -> int:
        """Stacked commitment length of one schema `TensorSlot`."""
        return {"aux": self.d_stack, "weight": self.w_stack,
                "label": self.y_stack}[spec.axis]

    @functools.cached_property
    def agg_blocks(self) -> Tuple[Tuple[str, int, int], ...]:
        """The direct-sum block table of the ONE aggregated opening IPA:
        ``(name, offset, length)`` per block, schema slots first (in
        `commit_slots` order — the transcript absorption order), then the
        two homomorphic data-fold blocks "x1"/"x2".  Block k's evaluation
        vector is weighted by rho^k, so this order is part of the
        protocol."""
        out, off = [], 0
        for spec in self.graph.commit_slots:
            n = self.slot_stack_len(spec)
            out.append((spec.name, off, n))
            off += n
        for tag in ("x1", "x2"):
            out.append((tag, off, self.x_len))
            off += self.x_len
        return tuple(out)

    @property
    def agg_len(self) -> int:
        """Unified opening vector length: the block sum padded to a
        power of two (pad generators are fresh; pad witness is zero)."""
        last = self.agg_blocks[-1]
        return next_pow2(last[1] + last[2])

    @functools.cached_property
    def validity_blocks(self) -> Tuple[Tuple[str, int, int], ...]:
        """The zkReLU validity statements' slices of the MERGED opening
        vector: ``(name, offset, length)`` with offsets continuing past
        the (padded) open region, so the one aggregated IPA covers
        open blocks ++ main validity ++ remainder validity ++ pad."""
        n_main = 2 * self.d_stack * self.q_bits
        n_rem = 2 * self.d_stack * self.r_bits
        return (("vmain", self.agg_len, n_main),
                ("vrem", self.agg_len + n_main, n_rem))

    @property
    def merged_len(self) -> int:
        """Length of the merged (open + validity) opening vector."""
        last = self.validity_blocks[-1]
        return next_pow2(last[1] + last[2])

    # -- challenge-point sizes (see challenges.py) ------------------------
    @property
    def lb(self) -> int:
        return log2_exact(self.batch)

    @property
    def la(self) -> int:
        """log2 of one aux slot's element area."""
        return log2_exact(self.d_elem)

    @property
    def lw(self) -> int:
        return log2_exact(self.w_elem)

    @property
    def lj(self) -> int:
        """Low-var split of the weight elem point: log2(max padded
        in-width).  Uniform graphs give lj = log2(width) so the drawn
        u_i / u_j vectors match the seed transcript exactly."""
        return log2_exact(max(self.graph.weight_shape(n)[0]
                              for n in self.graph.weight_nodes))


@dataclasses.dataclass(frozen=True)
class PipelineKeys:
    """Commitment key material laid out for the ONE aggregated opening.

    ``k_agg`` is the unified direct-sum basis of `cfg.agg_blocks`: every
    commitment slot's generators are a DISJOINT slice of it (disjointness
    is what makes the cross-slot batching sound — shared generators would
    let a prover shift witness mass between blocks), all under one shared
    blinding generator so the per-slot blinds sum into the aggregated
    Schnorr opening.  One exception to freshness: the "x2" block reuses
    the "x1" slice, because both data folds derive homomorphically from
    the same per-sample commitments — those fold claims are additionally
    pinned by the bucket sumcheck finals they must equal.  (The ``bq``
    block is fresh too: the validity argument's own B_{Q-1} column
    commitment is published separately by `zkrelu.commit_validity`, so
    no zkReLU generator repeats inside the merged basis.)

    ``g_merged`` / ``h_merged`` extend the opening basis with the zkReLU
    validity slices (`cfg.validity_blocks`): G side is k_agg.gens ++
    validity G ++ G_R ++ fresh pad, H side is the fresh ``h_open`` ++
    validity H ++ H_R ++ fresh pad.  The single pair IPA of
    `openings.prove` runs over these; the open region's b-vector is
    public, so its H-slice commitment factor is added by the verifier.
    """
    cfg: PipelineConfig
    k_agg: pedersen.CommitKey     # unified basis (agg_len), one blind gen
    slot_keys: Dict[str, pedersen.CommitKey]   # schema slot -> basis slice
    kx: pedersen.CommitKey        # per-sample data vectors (x1/x2 slice)
    validity: zkrelu.ValidityKeys
    h_open: jnp.ndarray           # (agg_len, 4) H basis of the open region
    g_merged: jnp.ndarray         # (merged_len, 4)
    h_merged: jnp.ndarray         # (merged_len, 4)

    # first-round accel squaring chains for the merged bases (see
    # zkrelu.POW_TABLE_MAX_ELEMS for the size guard at the call site)
    @functools.cached_property
    def g_merged_table(self) -> jnp.ndarray:
        return group.pow_table(self.g_merged)

    @functools.cached_property
    def h_merged_table(self) -> jnp.ndarray:
        return group.pow_table(self.h_merged)

    @property
    def k_bq(self) -> pedersen.CommitKey:
        """B_{Q-1} bit commitments (zkReLU G-column basis slice)."""
        return self.slot_keys["bq"]

    def slot_key(self, spec) -> pedersen.CommitKey:
        """The commitment key of one schema `TensorSlot` (bit-matrix
        slots use k_bq via `pedersen.commit_bits` instead)."""
        return self.slot_keys[spec.name]


def make_keys(cfg: PipelineConfig) -> PipelineKeys:
    vk = zkrelu.make_validity_keys(cfg.d_stack, cfg.q_bits, cfg.r_bits)
    h = vk.h_blind
    # one deterministic derivation covers every fresh block plus the
    # power-of-two pad tail; only x2 (the x1 slice) is spliced in
    blocks = cfg.agg_blocks
    fresh_len = sum(n for name, _, n in blocks if name != "x2")
    total = blocks[-1][1] + blocks[-1][2]
    fresh = group.derive_generators(b"zkdl/gens/agg",
                                    fresh_len + (cfg.agg_len - total))
    parts, taken, slot_gens = [], 0, {}
    for name, _, n in blocks:
        if name == "x2":
            gens = slot_gens["x1"]
        else:
            gens = fresh[taken: taken + n]
            taken += n
        slot_gens[name] = gens
        parts.append(gens)
    parts.append(fresh[taken:])                       # pad tail
    k_agg = pedersen.CommitKey(jnp.concatenate(parts), h, b"zkdl/agg")
    slot_keys = {s.name: pedersen.CommitKey(slot_gens[s.name], h,
                                            b"zkdl/slot/" + s.name.encode())
                 for s in cfg.graph.commit_slots}
    # merged (open + validity) bases: the open region gets a fresh H
    # side (its b-vector is public — the verifier multiplies the H
    # factor in itself), the validity slices reuse the vk bases so
    # Algorithm 1's transformed commitments line up, and the tail pad
    # is fresh on both sides
    vtotal = cfg.validity_blocks[-1][1] + cfg.validity_blocks[-1][2]
    vpad = cfg.merged_len - vtotal
    h_open = group.derive_generators(b"zkdl/gens/aggH", cfg.agg_len)
    gparts = [k_agg.gens, vk.g_big, vk.g_r]
    hparts = [h_open, vk.h_big, vk.h_r]
    if vpad:
        gparts.append(group.derive_generators(b"zkdl/gens/vpadG", vpad))
        hparts.append(group.derive_generators(b"zkdl/gens/vpadH", vpad))
    g_merged = jnp.concatenate(gparts)
    h_merged = jnp.concatenate(hparts)
    return PipelineKeys(
        cfg=cfg, k_agg=k_agg, slot_keys=slot_keys,
        kx=pedersen.CommitKey(slot_gens["x1"], h, b"zkdl/x"),
        validity=vk, h_open=h_open, g_merged=g_merged, h_merged=h_merged)
