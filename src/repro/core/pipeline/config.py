"""Pipeline configuration and commitment keys.

`PipelineConfig` generalizes the seed's per-step `ZkdlConfig` with a step
count T: the committed auxiliary tensors are stacked over BOTH layers and
training steps, so the stacked hypercube gains log2(t_pad) variables (the
layer-stacking trick of eq. 27 applied once more, per FAC4DNN).  With
``n_steps=1`` every size below degenerates to the seed layout, so the
single-step keys are bit-identical to the old `zkdl.make_keys`.
"""
from __future__ import annotations

import dataclasses

from repro.core import pedersen, zkrelu
from repro.core.pipeline.tables import next_pow2


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_layers: int
    batch: int            # power of 2
    width: int            # power of 2 (layer in/out dim, padded)
    q_bits: int
    r_bits: int
    n_steps: int = 1      # T: training steps aggregated into one proof

    def __post_init__(self):
        assert self.n_layers >= 2, "pipeline needs >= 2 layers (eq. 33)"
        assert self.n_steps >= 1

    @property
    def l_pad(self) -> int:
        return next_pow2(self.n_layers)

    @property
    def t_pad(self) -> int:
        return next_pow2(self.n_steps)

    @property
    def s_pad(self) -> int:
        """Slots on the stacked (step, layer) axis; layer varies fastest."""
        return self.t_pad * self.l_pad

    @property
    def d_elem(self) -> int:
        return self.batch * self.width

    @property
    def d_stack(self) -> int:
        """Stacked aux length: elem vars low, then layer vars, then step."""
        return self.s_pad * self.d_elem

    @property
    def w_stack(self) -> int:
        return self.s_pad * self.width * self.width

    @property
    def y_stack(self) -> int:
        return self.t_pad * self.d_elem

    def slot(self, t: int, layer_idx: int) -> int:
        """Flat (step, layer) slot index; layer_idx is 0-based storage."""
        assert 0 <= t < self.t_pad and 0 <= layer_idx < self.l_pad
        return t * self.l_pad + layer_idx


@dataclasses.dataclass(frozen=True)
class PipelineKeys:
    cfg: PipelineConfig
    kd: pedersen.CommitKey        # stacked aux tensors (d_stack)
    kw: pedersen.CommitKey        # stacked W / G_W (s_pad * width^2)
    kx: pedersen.CommitKey        # per-sample data vectors (width)
    ky: pedersen.CommitKey        # labels, stacked over steps (y_stack)
    k_bq: pedersen.CommitKey      # B_{Q-1} under the G-column basis
    validity: zkrelu.ValidityKeys


def make_keys(cfg: PipelineConfig) -> PipelineKeys:
    vk = zkrelu.make_validity_keys(cfg.d_stack, cfg.q_bits, cfg.r_bits)
    return PipelineKeys(
        cfg=cfg,
        kd=pedersen.make_key(b"zkdl/aux", cfg.d_stack),
        kw=pedersen.make_key(b"zkdl/w", cfg.w_stack),
        kx=pedersen.make_key(b"zkdl/x", cfg.width),
        ky=pedersen.make_key(b"zkdl/y", cfg.y_stack),
        k_bq=pedersen.CommitKey(vk.g_col, vk.h_blind, b"zkdl/bq"),
        validity=vk)
