"""Step (c): derived claims, the ONE direct-sum opening IPA, and zkReLU
validity.

Everything the anchor reduced to point-claims on COMMITTED tensors is
discharged here:

* the per-step eq. (32) reduction of G_Z^{L,t} to Z''/B/Y claims (the
  loss layer is linear, so the verifier assembles it from openings);
* per committed tensor, ALL of its claims -- across points, graph nodes
  and aggregated steps -- fold into a single (basis, claim) pair via
  <T, b1> + rho <T, b2> = <T, b1 + rho b2>; claims on narrow nodes
  embed into the stacked commitment by zero-extending their points
  (`pad_point`), so heterogeneous shapes share the same fold;
* the per-sample data commitments (Section 4.4) fold homomorphically
  over rows AND steps into two more (basis, claim) blocks;
* then ALL of those per-tensor blocks aggregate into ONE inner-product
  argument: a batching challenge rho weights block k's evaluation vector
  by rho^k, the witness is the direct sum ``a = (+)_k a_k`` over the
  block-concatenated generator basis of `cfg.agg_blocks` (disjoint
  slices of one unified key, zero-padded to the next power of two), and
  the blinds sum;
* the zkReLU validity argument over the full stacked bit matrices RIDES
  THE SAME IPA: the main and remainder eq. (19) statements occupy the
  `cfg.validity_blocks` slices of the merged basis, scaled by the next
  two rho powers (`merged_lambdas`), so a single log(merged_len)-round
  pair IPA plus one sigma finale replaces the K per-tensor arguments
  AND the two former standalone validity IPAs -- one round schedule,
  one L/R chain, 2 log(N) + 5 scalars on the wire instead of
  sum_k (2 log(n_k) + 3) + sum_v (2 log(n_v) + 5).

Soundness of the cross-tensor batching rests on the blocks' generator
slices being pairwise disjoint (see `make_keys`); the one shared slice
-- "x1"/"x2", both derived from the same per-sample data commitments --
is additionally pinned because both fold claims must equal bucket
sumcheck finals the verifier computes itself.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.field import FQ, add, encode_i64, mont_mul
from repro.core import group, ipa, zkrelu
from repro.core.mle import (enc, enc_vec, expand_point, fdot, fdot_many,
                            hexpand_point, weighted_sum)
from repro.core.transcript import Transcript
from repro.core.pipeline import matmul
from repro.core.pipeline import profile as profile_mod
from repro.core.pipeline.anchor import output_gz_points
from repro.core.pipeline.challenges import (ChallengeSchedule, WeightDraws,
                                            instance_slices, pad_point,
                                            pi_bases)
from repro.core.pipeline.config import PipelineConfig, PipelineKeys
from repro.core.pipeline.tables import (dec_scalar, dec_scalars, kron,
                                        kron_many, weight_table)
from repro.core.pipeline.witness import FieldTables

Q_MOD = FQ.modulus

# canonical per-step opening-claim names for the eq. (32) reduction
GZ_TOP_KEYS = ("zL_b", "bL_b", "y_b", "zL_w", "bL_w", "y_w")


def gz_top_keys(cfg: PipelineConfig) -> List[str]:
    return [f"{k}/{t}" for t in range(cfg.n_steps) for k in GZ_TOP_KEYS]


def initial_claims(cfg: PipelineConfig, tabs: FieldTables,
                   ch: ChallengeSchedule, op: Dict[str, int],
                   t: Transcript) -> tuple:
    """Openings a1..a6 of the stacked aux tensors at pi1/pi2/pi3."""
    e_pi1, e_pi2, e_pi3 = pi_bases(ch)
    op["a1"] = dec_scalar(fdot(tabs.zpp_t, e_pi1))
    op["a2"] = dec_scalar(fdot(tabs.bq_t, e_pi1))
    op["a3"] = dec_scalar(fdot(tabs.rz_t, e_pi1))
    op["a4"] = dec_scalar(fdot(tabs.gap_t, e_pi2))
    op["a5"] = dec_scalar(fdot(tabs.rga_t, e_pi2))
    op["a6"] = dec_scalar(fdot(tabs.gw_t, e_pi3))
    t.absorb_ints(b"op1", [op[k] for k in ("a1", "a2", "a3",
                                           "a4", "a5", "a6")])
    return e_pi1, e_pi2, e_pi3


def gz_top_bases(cfg: PipelineConfig, pt_b: List[int], pt_w: List[int]):
    """Per-step bases selecting the output node's slot of the stacked
    aux tensors at pt_b / pt_w, plus the per-step selectors on the
    stacked labels (whose per-step area is the output node's own padded
    size, so the label points need no slot padding).

    Returns four (T, n, 4) stacks (index [ti] for one step's basis); the
    T Kronecker products per point batch into one `kron_many` dispatch
    over a stacked one-hot selector matrix."""
    g = cfg.graph
    T = cfg.n_steps
    out_slot = g.aux_slot(g.node_for_layer("zkrelu", cfg.n_layers).name)
    e_b = expand_point(pad_point(pt_b, cfg.la))
    e_w = expand_point(pad_point(pt_w, cfg.la))
    e_b_y = expand_point(pt_b)
    e_w_y = expand_point(pt_w)
    sel_slot = np.zeros((T, cfg.s_pad), dtype=np.int64)
    sel_t = np.zeros((T, cfg.t_pad), dtype=np.int64)
    for t in range(T):
        sel_slot[t, cfg.slot(t, out_slot)] = 1
        sel_t[t, t] = 1
    eL = jnp.asarray(encode_i64(FQ, sel_slot))
    e_t = jnp.asarray(encode_i64(FQ, sel_t))
    return (kron_many(eL, e_b), kron_many(eL, e_w),
            kron_many(e_t, e_b_y), kron_many(e_t, e_w_y))


def w_opening(cfg: PipelineConfig, dlt: WeightDraws, ch: ChallengeSchedule,
              points: Dict[str, List[List[int]]],
              fwd_finals: List[List[int]], bwd_finals: List[List[int]]):
    """Combined bases/claims folding every W^{l,t} claim into two
    openings of the single stacked-W commitment.  Each claim's point is
    the bucket's bound inner point plus the instance's own slices, zero-
    extended to the common weight-slot area; claims sharing a point are
    grouped into one Kronecker term (a uniform graph gives one group)."""
    g = cfg.graph

    def _combine(draws, family, w_layer_of, pair_layer_of, final_idx,
                 finals, point_of):
        groups: Dict[tuple, Dict[int, int]] = {}
        claim = 0
        for (ti, l), c in draws.items():
            mm = g.node_for_layer("qmatmul", w_layer_of(l))
            slot = cfg.wslot(ti, g.weight_slot(mm.name))
            pt = point_of(w_layer_of(l))
            w = groups.setdefault(pt, {})
            w[slot] = (w.get(slot, 0) + c) % Q_MOD
            claim = (claim + c * matmul.pair_final(
                cfg, finals, family, ti, pair_layer_of(l),
                final_idx)) % Q_MOD
        base = None
        for pt, weights in groups.items():
            term = kron(weight_table(weights, cfg.sw_pad),
                        expand_point(pad_point(list(pt), cfg.lw)))
            base = term if base is None else add(FQ, base, term)
        return base, claim

    def _fwd_w_point(lyr):
        inst = cfg.graph.instance("fwd", lyr)
        bi, _ = cfg.graph.locate("fwd", lyr)
        u_cols, _, _ = instance_slices(inst, ch.glob_f)
        return tuple(u_cols) + tuple(points["fwd"][bi])

    def _bwd_w_point(lyr):
        # W^{lyr} read by the bwd instance of pair lyr-1: rows fixed at
        # the pair's column slice, columns bound by the bucket sumcheck
        inst = cfg.graph.instance("bwd", lyr - 1)
        bi, _ = cfg.graph.locate("bwd", lyr - 1)
        u_cols, _, _ = instance_slices(inst, ch.glob_b)
        return tuple(points["bwd"][bi]) + tuple(u_cols)

    b_w1, cl_w1 = _combine(dlt.w1, "fwd", lambda l: l, lambda l: l, 1,
                           fwd_finals, _fwd_w_point)
    b_w2, cl_w2 = _combine(dlt.w2, "bwd", lambda l: l + 1, lambda l: l, 1,
                           bwd_finals, _bwd_w_point)
    return b_w1, b_w2, cl_w1, cl_w2


def _combine_claims(t: Transcript, name: str, claims_pts):
    """Fold several (public vector, claim) pairs for one tensor into one
    (vector, claim) via transcript powers of rho.  The vector side is a
    single `weighted_sum` dispatch over the stacked bases."""
    rho = t.challenge_int(b"rho/" + name.encode(), Q_MOD)
    coefs, combined_claim, rpow = [], 0, 1
    for _, claim in claims_pts:
        coefs.append(rpow)
        combined_claim = (combined_claim + rpow * claim) % Q_MOD
        rpow = rpow * rho % Q_MOD
    combined_b = weighted_sum(jnp.stack([b for b, _ in claims_pts]),
                              enc_vec(coefs))
    return combined_b, combined_claim


def x_fold_openings(cfg: PipelineConfig, ch: ChallengeSchedule,
                    points: Dict[str, List[List[int]]],
                    fwd_finals: List[List[int]],
                    gw_finals: List[List[int]]):
    """The two cross-step data-opening specs: (tag, row point, column
    point, per-step claims) for the layer-1 instances touching the input
    node.  Per-step claims are batched with a rho challenge on top of
    the per-row fold, so all T*B per-sample commitments collapse into
    ONE commitment fold per tag."""
    T = cfg.n_steps
    f_inst = cfg.graph.instance("fwd", 1)
    f_bi, _ = cfg.graph.locate("fwd", 1)
    _, f_rows, _ = instance_slices(f_inst, ch.glob_f)
    g_inst = cfg.graph.instance("gw", 1)
    g_bi, _ = cfg.graph.locate("gw", 1)
    g_cols, _, _ = instance_slices(g_inst, ch.glob_w)
    return (
        ("x1", f_rows, points["fwd"][f_bi],
         [matmul.pair_final(cfg, fwd_finals, "fwd", t, 1, 0)
          for t in range(T)]),
        ("x2", points["gw"][g_bi], g_cols,
         [matmul.pair_final(cfg, gw_finals, "gw", t, 1, 1)
          for t in range(T)]),
    )


def _x_coefs(cfg: PipelineConfig, t: Transcript, tag: str, row_pt,
             claims: List[int]):
    """Per-(step, sample) fold coefficients rho^t * e_row[i] plus the
    combined claim; shared by prover and verifier."""
    e_row = hexpand_point(row_pt)
    rho = t.challenge_int(b"rho/" + tag.encode(), Q_MOD)
    coefs, combined_claim, rpow = [], 0, 1
    for ti in range(cfg.n_steps):
        coefs.extend(rpow * e_row[i] % Q_MOD for i in range(cfg.batch))
        combined_claim = (combined_claim + rpow * claims[ti]) % Q_MOD
        rpow = rpow * rho % Q_MOD
    return coefs, combined_claim


# ---------------------------------------------------------------------------
# Direct-sum aggregation of every per-tensor opening into ONE IPA.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AggClaim:
    """One block of the direct-sum opening: a committed tensor's combined
    evaluation vector and claim.  The prover side carries the witness
    table and blind; the verifier side the commitment group element."""
    name: str
    basis: jnp.ndarray                      # (block_len, 4) Montgomery
    claim: int
    table: Optional[jnp.ndarray] = None     # prover witness (Montgomery)
    blind: int = 0
    com: Optional[jnp.ndarray] = None       # verifier commitment point


def slot_claim_lists(cfg: PipelineConfig, op: Dict[str, int], e_pi1, e_pi2,
                     e_pi3, e_star, f_zpp: int, f_gap: int, v_q1: int,
                     gz_bases) -> Dict[str, list]:
    """The per-tensor (public basis, claim) lists both sides fold with
    `_combine_claims` -- one shared enumeration, so the prover and the
    standalone verifier can never drift apart.  The "w" list (which
    needs the transcript-drawn `WeightDraws`) is appended by the
    caller."""
    T = cfg.n_steps
    b_gzl_b, b_gzl_w, yb_bases, yw_bases = gz_bases
    return {
        "zpp": [(e_pi1, op["a1"]), (e_star, f_zpp)]
        + [(b_gzl_b[ti], op[f"zL_b/{ti}"]) for ti in range(T)]
        + [(b_gzl_w[ti], op[f"zL_w/{ti}"]) for ti in range(T)],
        "bq": [(e_pi1, op["a2"]), (e_star, v_q1)]
        + [(b_gzl_b[ti], op[f"bL_b/{ti}"]) for ti in range(T)]
        + [(b_gzl_w[ti], op[f"bL_w/{ti}"]) for ti in range(T)],
        "rz": [(e_pi1, op["a3"]), (e_star, op["a7"])],
        "gap": [(e_pi2, op["a4"]), (e_star, f_gap)],
        "rga": [(e_pi2, op["a5"]), (e_star, op["a8"])],
        "gw": [(e_pi3, op["a6"])],
        "y": [(yb_bases[ti], op[f"y_b/{ti}"]) for ti in range(T)]
        + [(yw_bases[ti], op[f"y_w/{ti}"]) for ti in range(T)],
    }


def direct_sum(cfg: PipelineConfig, t: Transcript,
               blocks: Dict[str, AggClaim]):
    """Draw the batching challenge and assemble the aggregated statement:
    block k's basis scales by rho^k (so the single inner product equals
    the rho-weighted sum of the per-block claims), blocks concatenate at
    their `cfg.agg_blocks` offsets, and the tail zero-pads to the
    power-of-two `cfg.agg_len`."""
    rho = t.challenge_int(b"rho/agg", Q_MOD)
    parts, claim, rpow = [], 0, 1
    for name, _, n in cfg.agg_blocks:
        blk = blocks[name]
        assert blk.basis.shape[0] == n, (name, blk.basis.shape, n)
        parts.append(mont_mul(FQ, blk.basis, enc(rpow)[None]))
        claim = (claim + rpow * blk.claim) % Q_MOD
        rpow = rpow * rho % Q_MOD
    b_agg = _pad_concat(cfg, parts)
    return b_agg, claim, rho


def _pad_concat(cfg: PipelineConfig, parts) -> jnp.ndarray:
    total = sum(p.shape[0] for p in parts)
    pad = cfg.agg_len - total
    if pad:
        parts = list(parts) + [jnp.zeros((pad, 4), jnp.uint32)]
    return jnp.concatenate(parts)


def stacked_witness(cfg: PipelineConfig,
                    blocks: Dict[str, AggClaim]) -> jnp.ndarray:
    """Prover side: the direct-sum witness a = (+)_k a_k, zero-padded
    (zero IS the Montgomery encoding of zero, so pad generators never
    contribute)."""
    return _pad_concat(cfg, [blocks[name].table
                             for name, _, _ in cfg.agg_blocks])


def _sub(prof, name: str):
    return profile_mod.subphase(prof, name)


def prover_blocks(cfg: PipelineConfig, tabs: FieldTables,
                  blinds: Dict[str, int], x_blinds: List[int],
                  ch: ChallengeSchedule, mat: matmul.MatmulOut, anc,
                  op: Dict[str, int], e_pi1, e_pi2, e_pi3, t: Transcript):
    """Derived claims, every per-tensor combine and the two data folds:
    the complete prover-side block table of the direct-sum opening, plus
    the zkReLU claim context ``(u_relu, v, v_q1, v_r)``.  Factored out
    of `prove` so tests can pin the value-level parity of the
    aggregation (every block claim is a true inner product of its
    witness, and the aggregated claim is their rho-weighted sum)."""
    T = cfg.n_steps
    points = {fam: mat.fams[fam].points for fam in mat.fams}
    u_star = anc.u_star
    e_star = expand_point(u_star)
    op["a7"] = dec_scalar(fdot(tabs.rz_t, e_star))
    op["a8"] = dec_scalar(fdot(tabs.rga_t, e_star))
    t.absorb_ints(b"op2", [op["a7"], op["a8"]])
    upp = t.challenge_int(b"upp", Q_MOD)
    u_relu = u_star + [upp]
    f_oneb, f_zpp, f_gap = anc.anchor_finals[:3]
    v = ((1 - upp) * f_zpp + upp * f_gap) % Q_MOD
    v_q1 = (1 - f_oneb) % Q_MOD
    v_r = ((1 - upp) * op["a7"] + upp * op["a8"]) % Q_MOD
    t.absorb_ints(b"vclaims", [v, v_q1, v_r])

    # per-step GZ^{L,t} linear reduction claims (eq. 32): the 6T stacked-
    # tensor evaluations batch into three fdot_many dispatches (one per
    # tensor) and three host transfers instead of 6T of each
    pt_b, pt_w = output_gz_points(cfg, ch, points)
    b_gzl_b, b_gzl_w, yb_bases, yw_bases = gz_top_bases(cfg, pt_b, pt_w)
    gzl_bases = jnp.concatenate([b_gzl_b, b_gzl_w])
    zl_vals = dec_scalars(fdot_many(tabs.zpp_t, gzl_bases))
    bl_vals = dec_scalars(fdot_many(tabs.bq_t, gzl_bases))
    y_vals = dec_scalars(fdot_many(tabs.y_t,
                                   jnp.concatenate([yb_bases, yw_bases])))
    for ti in range(T):
        op[f"zL_b/{ti}"] = zl_vals[ti]
        op[f"bL_b/{ti}"] = bl_vals[ti]
        op[f"y_b/{ti}"] = y_vals[ti]
        op[f"zL_w/{ti}"] = zl_vals[T + ti]
        op[f"bL_w/{ti}"] = bl_vals[T + ti]
        op[f"y_w/{ti}"] = y_vals[T + ti]
    t.absorb_ints(b"op3", [op[k] for k in gz_top_keys(cfg)])

    dlt = WeightDraws.draw(t, cfg)
    b_w1, b_w2, cl_w1, cl_w2 = w_opening(
        cfg, dlt, ch, points, mat.fams["fwd"].finals,
        mat.fams["bwd"].finals)
    lists = slot_claim_lists(cfg, op, e_pi1, e_pi2, e_pi3, e_star,
                             f_zpp, f_gap, v_q1,
                             (b_gzl_b, b_gzl_w, yb_bases, yw_bases))
    lists["w"] = [(b_w1, cl_w1), (b_w2, cl_w2)]

    blocks: Dict[str, AggClaim] = {}
    for name, _, _ in cfg.agg_blocks:
        if name in ("x1", "x2"):
            continue
        comb_b, comb_c = _combine_claims(t, name, lists[name])
        blocks[name] = AggClaim(name, comb_b, comb_c,
                                table=tabs.tabs[name],
                                blind=blinds[name])

    # data blocks: per-sample commitments folded over rows AND steps;
    # the T*B-row table fold is ONE weighted_sum dispatch per tag
    x_stack = jnp.stack(tabs.x_tabs)
    for tag, row_pt, col_pt, claims in x_fold_openings(
            cfg, ch, points, mat.fams["fwd"].finals,
            mat.fams["gw"].finals):
        coefs, combined_claim = _x_coefs(cfg, t, tag, row_pt, claims)
        folded = weighted_sum(x_stack, enc_vec(coefs))
        blind_f = sum(c * xb
                      for c, xb in zip(coefs, x_blinds)) % Q_MOD
        blocks[tag] = AggClaim(tag, expand_point(col_pt),
                               combined_claim, table=folded,
                               blind=blind_f)
    return blocks, (u_relu, v, v_q1, v_r)


def merged_lambdas(cfg: PipelineConfig, rho: int):
    """The validity blocks' batching weights inside the merged opening:
    the open blocks consume rho^0..rho^{K-1}, so the main/remainder
    validity statements take the next two powers.  Their claims enter
    squared (lam^2 c: both witness sides carry lam), so the claim
    monomials rho^{2K} / rho^{2K+2} stay distinct from the open blocks'
    rho^0..rho^{K-1} — the Schwartz-Zippel batching argument is
    unchanged."""
    K = len(cfg.agg_blocks)
    lam1 = pow(rho, K, Q_MOD)
    return lam1, lam1 * rho % Q_MOD


def _merged_pad(cfg: PipelineConfig):
    last = cfg.validity_blocks[-1]
    return cfg.merged_len - (last[1] + last[2])


def prove(cfg: PipelineConfig, keys: PipelineKeys, tabs: FieldTables,
          blinds: Dict[str, int], x_blinds: List[int],
          aux_bits: zkrelu.AuxBits, vblinds, ch: ChallengeSchedule,
          mat: matmul.MatmulOut, anc, op: Dict[str, int],
          e_pi1, e_pi2, e_pi3, t: Transcript, rng, prof=None):
    """Runs the whole of step (c) prover-side; returns the single merged
    pair-IPA proof covering every opening block AND both zkReLU validity
    statements.  ``prof`` (a `PhaseProfile`) attributes the sub-phases
    claim-combine / zkrelu-validity / ipa-rounds / sigma."""
    with _sub(prof, "claim-combine"):
        blocks, (u_relu, v, v_q1, v_r) = prover_blocks(
            cfg, tabs, blinds, x_blinds, ch, mat, anc, op,
            e_pi1, e_pi2, e_pi3, t)

    with _sub(prof, "zkrelu-validity"):
        # validity challenges draw BEFORE rho/agg; the a/b tables for
        # both statements come out of one validity_tables dispatch
        st = zkrelu.prove_statements(keys.validity, aux_bits, vblinds,
                                     u_relu, v, v_q1, v_r, t)
        jax.block_until_ready((st.a_main, st.b_main, st.a_rem, st.b_rem))

    with _sub(prof, "claim-combine"):
        b_agg, claim_agg, rho = direct_sum(cfg, t, blocks)
        a_agg = stacked_witness(cfg, blocks)
        blind_agg = sum(blk.blind for blk in blocks.values()) % Q_MOD
        lam1, lam2 = merged_lambdas(cfg, rho)
        l1, l2 = enc(lam1), enc(lam2)
        pad = _merged_pad(cfg)
        zeros = jnp.zeros((pad, 4), jnp.uint32)
        a_hat = jnp.concatenate([a_agg, mont_mul(FQ, st.a_main, l1[None]),
                                 mont_mul(FQ, st.a_rem, l2[None]), zeros])
        b_hat = jnp.concatenate([b_agg, mont_mul(FQ, st.b_main, l1[None]),
                                 mont_mul(FQ, st.b_rem, l2[None]), zeros])
        ones = jnp.broadcast_to(enc(1), (cfg.agg_len, 4)).astype(jnp.uint32)
        pones = jnp.broadcast_to(enc(1), (pad, 4)).astype(jnp.uint32)
        w = jnp.concatenate([ones, st.w_main, st.w_rem, pones])
        claim = (claim_agg + lam1 * lam1 % Q_MOD * st.claim_main
                 + lam2 * lam2 % Q_MOD * st.claim_rem) % Q_MOD
        blind = (blind_agg + lam1 * st.blind_main
                 + lam2 * st.blind_rem) % Q_MOD
        jax.block_until_ready((a_hat, b_hat))

    if cfg.merged_len <= zkrelu.POW_TABLE_MAX_ELEMS:
        stmt = (keys.g_merged, None, keys.validity.h_blind, a_hat, b_hat,
                blind, claim,
                (keys.g_merged_table, keys.h_merged, keys.h_merged_table, w))
    else:
        from repro.field import from_mont
        hh = group.g_pow(keys.h_merged, from_mont(FQ, w))
        stmt = (keys.g_merged, hh, keys.validity.h_blind, a_hat, b_hat,
                blind, claim)
    (ipa_agg,) = ipa.pair_prove_many([stmt], t, rng, prof=prof)
    return ipa_agg


def verify(cfg: PipelineConfig, keys: PipelineKeys, proof, coms,
           ch: ChallengeSchedule, points: Dict[str, List[List[int]]],
           u_star, e_pi1, e_pi2, e_pi3, t: Transcript) -> None:
    """Verifier side of step (c).  Raises ValueError naming the first
    failing check."""
    T = cfg.n_steps
    op = proof.openings
    two_q1 = pow(2, cfg.q_bits - 1, Q_MOD)
    e_star = expand_point(u_star)
    f_oneb, f_zpp, f_gap = proof.anchor_finals[:3]

    t.absorb_ints(b"op2", [op["a7"], op["a8"]])
    upp = t.challenge_int(b"upp", Q_MOD)
    u_relu = u_star + [upp]
    v = ((1 - upp) * f_zpp + upp * f_gap) % Q_MOD
    v_q1 = (1 - f_oneb) % Q_MOD
    v_r = ((1 - upp) * op["a7"] + upp * op["a8"]) % Q_MOD
    t.absorb_ints(b"vclaims", [v, v_q1, v_r])
    t.absorb_ints(b"op3", [op[k] for k in gz_top_keys(cfg)])

    # per-step GZ^{L,t} linear checks (eq. 32)
    L = cfg.n_layers
    for ti in range(T):
        gzl_b = (op[f"zL_b/{ti}"] - two_q1 * op[f"bL_b/{ti}"]
                 - op[f"y_b/{ti}"]) % Q_MOD
        if matmul.pair_final(cfg, proof.bwd_finals, "bwd", ti, L - 1,
                             0) != gzl_b:
            raise ValueError("gzL-bwd")
        gzl_w = (op[f"zL_w/{ti}"] - two_q1 * op[f"bL_w/{ti}"]
                 - op[f"y_w/{ti}"]) % Q_MOD
        if matmul.pair_final(cfg, proof.gw_finals, "gw", ti, L,
                             0) != gzl_w:
            raise ValueError("gzL-gw")

    pt_b, pt_w = output_gz_points(cfg, ch, points)
    b_gzl_b, b_gzl_w, yb_bases, yw_bases = gz_top_bases(cfg, pt_b, pt_w)

    dlt = WeightDraws.draw(t, cfg)
    b_w1, b_w2, cl_w1, cl_w2 = w_opening(cfg, dlt, ch, points,
                                         proof.fwd_finals,
                                         proof.bwd_finals)
    lists = slot_claim_lists(cfg, op, e_pi1, e_pi2, e_pi3, e_star,
                             f_zpp, f_gap, v_q1,
                             (b_gzl_b, b_gzl_w, yb_bases, yw_bases))
    lists["w"] = [(b_w1, cl_w1), (b_w2, cl_w2)]

    blocks: Dict[str, AggClaim] = {}
    for name, _, _ in cfg.agg_blocks:
        if name in ("x1", "x2"):
            continue
        comb_b, comb_c = _combine_claims(t, name, lists[name])
        blocks[name] = AggClaim(
            name, comb_b, comb_c,
            com=group.encode_group(coms.slots[name]))

    # data blocks: fold the per-sample commitments homomorphically
    com_pts = jnp.stack([group.encode_group(ci) for ci in coms.x])
    for tag, row_pt, col_pt, claims in x_fold_openings(
            cfg, ch, points, proof.fwd_finals, proof.gw_finals):
        coefs, combined_claim = _x_coefs(cfg, t, tag, row_pt, claims)
        com_fold = group.msm(com_pts, group.exps_from_ints(coefs))
        blocks[tag] = AggClaim(tag, expand_point(col_pt), combined_claim,
                               com=com_fold)

    # validity statements: redraw challenges, transform commitments
    # (Algorithm 1) — BEFORE rho/agg, matching the prover's schedule
    ctx = zkrelu.verify_statements(keys.validity, coms.validity,
                                   v, v_q1, v_r, u_relu, t)

    # the merged commitment is the product of every block's commitment
    # (shared blind generator; zero pad witness), times the open
    # region's public H-side factor, times the lam-scaled transformed
    # validity commitments; ONE pair-IPA check replaces everything
    b_agg, claim_agg, rho = direct_sum(cfg, t, blocks)
    com_agg = blocks[cfg.agg_blocks[0][0]].com
    for name, _, _ in cfg.agg_blocks[1:]:
        com_agg = group.g_mul(com_agg, blocks[name].com)
    lam1, lam2 = merged_lambdas(cfg, rho)
    com_hat = group.g_mul(com_agg, group.msm_field(keys.h_open, b_agg))
    com_hat = group.g_mul(com_hat, group.g_pow_int(ctx.com_t, lam1))
    com_hat = group.g_mul(com_hat, group.g_pow_int(ctx.com_tr, lam2))
    claim = (claim_agg + lam1 * lam1 % Q_MOD * ctx.claim_main
             + lam2 * lam2 % Q_MOD * ctx.claim_rem) % Q_MOD
    vtail = cfg.validity_blocks[-1][1] + cfg.validity_blocks[-1][2]
    hh = jnp.concatenate([keys.h_open, ctx.h_prime_main, ctx.h_prime_rem,
                          keys.h_merged[vtail:]])
    if not ipa.pair_verify_many(
            [(keys.g_merged, hh, keys.validity.h_blind, com_hat, claim,
              cfg.merged_len)],
            [proof.ipa_agg], t):
        raise ValueError("open-agg")
