"""Layer-graph zkDL proof pipeline with FAC4DNN aggregation across
heterogeneous layers AND training steps.

Public surface:

* `LayerOp` / `LayerGraph` / `OP_REGISTRY` / `build_fcnn_graph` /
  `proof_graph_for_family`                         -- the IR (graph.py)
* `PipelineConfig` / `PipelineKeys` / `make_keys`  -- setup (config.py)
* `ProofSession` / `prove_session` / `AggregatedProof` -- prover (session.py)
* `verify` / `verify_session`                      -- verifier (verifier.py)
* `stack_witnesses` / `StackedWitness`             -- witness stacking

See README.md in this package for the module <-> paper map.
"""
from repro.core.pipeline.config import (PipelineConfig, PipelineKeys,
                                        make_keys)
from repro.core.pipeline.graph import (OP_REGISTRY, LayerGraph, LayerOp,
                                       OpSpec, build_fcnn_graph,
                                       proof_graph_for_family, register_op)
from repro.core.pipeline.session import (AggregatedProof, ProofSession,
                                         SessionCommitments, SessionProver,
                                         prove_session)
from repro.core.pipeline.verifier import verify, verify_session
from repro.core.pipeline.witness import (StackedWitness, build_field_tables,
                                         stack_witnesses)

__all__ = [
    "LayerOp", "LayerGraph", "OpSpec", "OP_REGISTRY", "register_op",
    "build_fcnn_graph", "proof_graph_for_family",
    "PipelineConfig", "PipelineKeys", "make_keys",
    "AggregatedProof", "ProofSession", "SessionCommitments",
    "SessionProver", "prove_session",
    "verify", "verify_session",
    "StackedWitness", "build_field_tables", "stack_witnesses",
]
