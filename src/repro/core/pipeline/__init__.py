"""Layer-graph zkDL proof pipeline with FAC4DNN aggregation across
heterogeneous layers AND training steps.

Public surface (the compile -> prove -> verify lifecycle):

* `GraphBuilder` / `LayerOp` / `LayerGraph` / `OP_REGISTRY` /
  `build_fcnn_graph` / `build_residual_fcnn_graph` /
  `proof_graph_for_family`                        -- the IR (graph.py)
* `compile` / `ProvingKey` / `VerifyingKey`       -- setup (api.py)
* `ProofSession` / `prove_session` / `AggregatedProof` -- prover (session.py)
* `encode_proof` / `decode_proof` / `VerifyingKey.to_bytes` --
  the canonical byte format (proofio.py)
* `verify_bytes` / `verify` / `verify_session`    -- verifier (verifier.py)
* `PipelineConfig` / `PipelineKeys` / `make_keys` -- raw setup (config.py)
* `stack_witnesses` / `StackedWitness`            -- witness stacking

See README.md in this package for the lifecycle and the byte-format
layout.
"""
from repro.core.pipeline.api import ProvingKey, VerifyingKey, compile
from repro.core.pipeline.config import (PipelineConfig, PipelineKeys,
                                        make_keys)
from repro.core.pipeline.graph import (OP_REGISTRY, GraphBuilder, LayerGraph,
                                       LayerOp, OpSpec, TensorSlot,
                                       build_fcnn_graph,
                                       build_residual_fcnn_graph,
                                       graph_skips, graph_widths,
                                       proof_graph_for_family, register_op)
from repro.core.pipeline.proofio import (ProofDecodeError, decode_proof,
                                         encode_proof)
from repro.core.pipeline.session import (AggregatedProof, ProofSession,
                                         SessionCommitments, SessionProver,
                                         prove_session)
from repro.core.pipeline.verifier import verify, verify_bytes, verify_session
from repro.core.pipeline.witness import (StackedWitness, build_field_tables,
                                         stack_witnesses)

__all__ = [
    "GraphBuilder", "LayerOp", "LayerGraph", "OpSpec", "TensorSlot",
    "OP_REGISTRY", "register_op",
    "build_fcnn_graph", "build_residual_fcnn_graph",
    "graph_skips", "graph_widths", "proof_graph_for_family",
    "compile", "ProvingKey", "VerifyingKey",
    "PipelineConfig", "PipelineKeys", "make_keys",
    "AggregatedProof", "ProofSession", "SessionCommitments",
    "SessionProver", "prove_session",
    "encode_proof", "decode_proof", "ProofDecodeError",
    "verify", "verify_bytes", "verify_session",
    "StackedWitness", "build_field_tables", "stack_witnesses",
]
