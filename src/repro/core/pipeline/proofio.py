"""Canonical byte encoding for aggregated proofs and verifying keys.

This is the deployment contract: the trainer writes ``proof.bin`` (and
once, ``vk.bin``); the verifier — any process, any machine — rebuilds
the proof object and the key material from bytes alone and runs the
standalone verifier.  Nothing here touches live session state.

Layout (all integers little-endian):

    proof:  magic b"ZKDL" | u16 version | sections
    vk:     magic b"ZKVK" | u16 version | quant/steps | graph nodes

Every proof section is framed ``u8 tag | u32 length | payload`` and
appears exactly once, in tag order:

    1 META      n_steps
    2 COMS      per-sample x commitments, schema-slot commitments
                (name-keyed, in the graph's commit_slots order — the
                transcript absorption order), the four validity
                commitments (com_b_ip, com_bq1, com_bq1p, com_br_ip)
    3 OPEN      claim openings, name-keyed
    4 SC        per-family bucket sumchecks + the anchor sumcheck
    5 FINALS    per-family bucket finals + claim splits + anchor finals
    6 IPA       the ONE merged pair IPA: every direct-sum opening block
                AND both zkReLU validity statements (v3; v2 carried the
                two validity IPAs in a separate section 7, v1 a
                name-keyed dict of per-tensor IPAs)

Scalars are 8-byte words: both the proof field (61-bit) and the group
field (62-bit) fit.  The encoding is canonical — encode(decode(b)) == b
and decode(encode(p)) == p — so byte digests are stable and any
single-byte tamper either fails framing (`ProofDecodeError`) or changes
a transcript value and is rejected by verification.

Version negotiation is explicit: v3 readers reject v1/v2 streams (whose
separate opening/validity arguments and key layouts no longer exist)
with a dedicated `ProofDecodeError` naming the migration, and reject
unknown future versions rather than guessing.
"""
from __future__ import annotations

import io
import struct
from typing import Dict, List

from repro.core import ipa, zkrelu
from repro.core.sumcheck import SumcheckProof

MAGIC_PROOF = b"ZKDL"
MAGIC_VK = b"ZKVK"
# v3: the two standalone zkReLU validity IPAs folded into the single
# direct-sum opening (now a pair IPA over the merged basis) and the
# VALIDITY section disappeared; keys grew the merged/h_open bases and a
# fresh bq slice — v1/v2 bytes (and their verifying keys) cannot verify
# under v3 keys, so decode refuses them instead of mis-verifying
VERSION = 3

_SECTIONS = ("META", "COMS", "OPEN", "SC", "FINALS", "IPA")
FAMILIES = ("fwd", "bwd", "gw")


class ProofDecodeError(ValueError):
    """Malformed / truncated / version-mismatched byte stream."""


def _check_version(ver: int, what: str) -> None:
    if ver == VERSION:
        return
    if ver == 1:
        raise ProofDecodeError(
            f"{what} format v1 (per-slot IPA openings) is no longer "
            "supported: v3 aggregates every opening AND the zkReLU "
            "validity statements into one merged pair IPA over a new "
            "key layout — re-prove under v3 keys")
    if ver == 2:
        raise ProofDecodeError(
            f"{what} format v2 (separate zkReLU validity IPAs) is no "
            "longer supported: v3 folds the validity statements into "
            "the single direct-sum pair IPA and drops the VALIDITY "
            "section — re-prove under v3 keys")
    raise ProofDecodeError(f"unsupported {what} version {ver} "
                           f"(this decoder speaks v{VERSION})")


# -- primitives -------------------------------------------------------------

def _w_u8(b: io.BytesIO, v: int) -> None:
    b.write(struct.pack("<B", v))


def _w_u16(b: io.BytesIO, v: int) -> None:
    b.write(struct.pack("<H", v))


def _w_u32(b: io.BytesIO, v: int) -> None:
    b.write(struct.pack("<I", v))


def _w_scalar(b: io.BytesIO, v: int) -> None:
    if not 0 <= v < (1 << 64):
        raise ValueError(f"scalar out of u64 range: {v}")
    b.write(struct.pack("<Q", v))


def _w_scalars(b: io.BytesIO, vs: List[int], count: str = "u32") -> None:
    (_w_u32 if count == "u32" else _w_u16)(b, len(vs))
    for v in vs:
        _w_scalar(b, v)


def _w_str(b: io.BytesIO, s: str) -> None:
    raw = s.encode()
    _w_u16(b, len(raw))
    b.write(raw)


class _Reader:
    def __init__(self, data: bytes):
        self.data = data
        self.pos = 0

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.data):
            raise ProofDecodeError("truncated stream")
        out = self.data[self.pos: self.pos + n]
        self.pos += n
        return out

    def u8(self) -> int:
        return struct.unpack("<B", self.take(1))[0]

    def u16(self) -> int:
        return struct.unpack("<H", self.take(2))[0]

    def u32(self) -> int:
        return struct.unpack("<I", self.take(4))[0]

    def scalar(self) -> int:
        return struct.unpack("<Q", self.take(8))[0]

    def scalars(self, count: str = "u32") -> List[int]:
        n = self.u32() if count == "u32" else self.u16()
        if self.pos + 8 * n > len(self.data):    # framing sanity first
            raise ProofDecodeError("implausible vector length")
        return [self.scalar() for _ in range(n)]

    def str_(self) -> str:
        n = self.u16()
        try:
            return self.take(n).decode()
        except UnicodeDecodeError as exc:
            raise ProofDecodeError("bad string") from exc

    def done(self) -> bool:
        return self.pos == len(self.data)


# -- sumcheck / ipa helpers -------------------------------------------------

def _w_sumcheck(b: io.BytesIO, sc: SumcheckProof) -> None:
    _w_u16(b, len(sc.messages))
    for msg in sc.messages:
        _w_u8(b, len(msg))
        for v in msg:
            _w_scalar(b, v)


def _r_sumcheck(r: _Reader) -> SumcheckProof:
    n_rounds = r.u16()
    msgs = []
    for _ in range(n_rounds):
        k = r.u8()
        msgs.append([r.scalar() for _ in range(k)])
    return SumcheckProof(messages=msgs)


def _w_ipa(b: io.BytesIO, p: ipa.IpaProof) -> None:
    if len(p.ls) != len(p.rs):
        raise ValueError("IPA L/R length mismatch")
    _w_u16(b, len(p.ls))
    for v in p.ls:
        _w_scalar(b, v)
    for v in p.rs:
        _w_scalar(b, v)
    _w_u8(b, len(p.sigma))
    for v in p.sigma:
        _w_scalar(b, v)


def _r_ipa(r: _Reader) -> ipa.IpaProof:
    n = r.u16()
    ls = [r.scalar() for _ in range(n)]
    rs = [r.scalar() for _ in range(n)]
    k = r.u8()
    return ipa.IpaProof(ls=ls, rs=rs, sigma=[r.scalar() for _ in range(k)])


# -- proof ------------------------------------------------------------------

def encode_proof(proof) -> bytes:
    """`AggregatedProof` -> canonical bytes (versioned header)."""
    out = io.BytesIO()
    out.write(MAGIC_PROOF)
    _w_u16(out, VERSION)

    def section(tag: int, body: io.BytesIO) -> None:
        raw = body.getvalue()
        _w_u8(out, tag)
        _w_u32(out, len(raw))
        out.write(raw)

    b = io.BytesIO()                                   # 1 META
    _w_u32(b, proof.n_steps)
    section(1, b)

    b = io.BytesIO()                                   # 2 COMS
    _w_scalars(b, proof.coms.x)
    _w_u16(b, len(proof.coms.slots))
    for name, v in proof.coms.slots.items():           # schema order
        _w_str(b, name)
        _w_scalar(b, v)
    val = proof.coms.validity
    for v in (val.com_b_ip, val.com_bq1, val.com_bq1p, val.com_br_ip):
        _w_scalar(b, v)
    section(2, b)

    b = io.BytesIO()                                   # 3 OPEN
    _w_u32(b, len(proof.openings))
    for name in sorted(proof.openings):
        _w_str(b, name)
        _w_scalar(b, proof.openings[name])
    section(3, b)

    b = io.BytesIO()                                   # 4 SC
    for fam in FAMILIES:
        scs = getattr(proof, f"sc_{fam}")
        _w_u16(b, len(scs))
        for sc in scs:
            _w_sumcheck(b, sc)
    _w_sumcheck(b, proof.sc_anchor)
    section(4, b)

    b = io.BytesIO()                                   # 5 FINALS
    for fam in FAMILIES:
        finals = getattr(proof, f"{fam}_finals")
        _w_u16(b, len(finals))
        for f in finals:
            _w_scalars(b, f)
        _w_scalars(b, getattr(proof, f"{fam}_claims"), count="u16")
    _w_scalars(b, proof.anchor_finals, count="u16")
    section(5, b)

    b = io.BytesIO()                                   # 6 IPA (merged)
    _w_ipa(b, proof.ipa_agg)
    section(6, b)

    return out.getvalue()


def decode_proof(data: bytes):
    """Canonical bytes -> `AggregatedProof` (raises `ProofDecodeError`)."""
    from repro.core.pipeline.session import (AggregatedProof,
                                             SessionCommitments)

    r = _Reader(data)
    if r.take(4) != MAGIC_PROOF:
        raise ProofDecodeError("bad magic (not a zkDL proof)")
    _check_version(r.u16(), "proof")

    sections: Dict[int, _Reader] = {}
    for tag_want in range(1, len(_SECTIONS) + 1):
        tag = r.u8()
        if tag != tag_want:
            raise ProofDecodeError(f"expected section {tag_want}, got {tag}")
        sections[tag] = _Reader(r.take(r.u32()))

    if not r.done():
        raise ProofDecodeError("trailing bytes after final section")

    s = sections[1]
    n_steps = s.u32()

    s = sections[2]
    x = s.scalars()
    slots = {}
    for _ in range(s.u16()):
        name = s.str_()
        slots[name] = s.scalar()
    validity_coms = zkrelu.ValidityCommitments(
        com_b_ip=s.scalar(), com_bq1=s.scalar(), com_bq1p=s.scalar(),
        com_br_ip=s.scalar())
    coms = SessionCommitments(x=x, slots=slots, validity=validity_coms)

    s = sections[3]
    openings = {}
    for _ in range(s.u32()):
        name = s.str_()
        openings[name] = s.scalar()

    s = sections[4]
    scs = {fam: [_r_sumcheck(s) for _ in range(s.u16())]
           for fam in FAMILIES}
    sc_anchor = _r_sumcheck(s)

    s = sections[5]
    finals, claims = {}, {}
    for fam in FAMILIES:
        finals[fam] = [s.scalars() for _ in range(s.u16())]
        claims[fam] = s.scalars(count="u16")
    anchor_finals = s.scalars(count="u16")

    s = sections[6]
    ipa_agg = _r_ipa(s)

    for tag, sec in sections.items():
        if not sec.done():
            raise ProofDecodeError(
                f"trailing bytes in section {_SECTIONS[tag - 1]}")

    return AggregatedProof(
        coms=coms, openings=openings,
        sc_fwd=scs["fwd"], sc_bwd=scs["bwd"], sc_gw=scs["gw"],
        sc_anchor=sc_anchor,
        fwd_finals=finals["fwd"], bwd_finals=finals["bwd"],
        gw_finals=finals["gw"],
        fwd_claims=claims["fwd"], bwd_claims=claims["bwd"],
        gw_claims=claims["gw"],
        anchor_finals=anchor_finals, ipa_agg=ipa_agg, n_steps=n_steps)


# -- verifying key ----------------------------------------------------------

def encode_vk(vk) -> bytes:
    """`VerifyingKey` -> bytes: the graph spec plus the quantization and
    aggregation-window parameters.  Generators are NOT serialized — they
    re-derive deterministically from the geometry on load, so vk.bin is
    a few hundred bytes for any model size."""
    cfg = vk.cfg
    out = io.BytesIO()
    out.write(MAGIC_VK)
    _w_u16(out, VERSION)
    _w_u8(out, cfg.q_bits)
    _w_u8(out, cfg.r_bits)
    _w_u32(out, cfg.n_steps)
    nodes = cfg.graph.nodes
    _w_u16(out, len(nodes))
    for n in nodes:
        _w_str(out, n.name)
        _w_str(out, n.kind)
        _w_u8(out, len(n.inputs))
        for src in n.inputs:
            _w_str(out, src)
        _w_u32(out, n.shape[0])
        _w_u32(out, n.shape[1])
        _w_u32(out, n.layer)
    return out.getvalue()


# Resource bound on vk-declared geometry (PR-8 fuzz finding): the vk is
# a TRUSTED input in the protocol, but `decode_vk` is reachable from
# attacker-supplied bytes in deployments that fetch vks by reference.
# Key material re-derives from the declared geometry, so a mutated vk
# claiming a huge graph turns `make_keys` into an unbounded hash-to-
# curve workload.  `cfg.merged_len` is pure arithmetic over the
# geometry (every derived basis — slot keys, the unified agg key, the
# zkReLU bases, the merged IPA key — is a slice of, or smaller than,
# the merged basis), so one cap on it bounds ALL generator derivation.
# 1<<22 generators is ~100x the largest geometry the benchmarks prove
# and already represents minutes of derivation work.
VK_MAX_MERGED_LEN = 1 << 22


def decode_vk(data: bytes, max_merged_len: int = VK_MAX_MERGED_LEN):
    """Bytes -> `VerifyingKey` (generators derive lazily on first use)."""
    from repro.core.pipeline.api import VerifyingKey
    from repro.core.pipeline.config import PipelineConfig
    from repro.core.pipeline.graph import LayerGraph, LayerOp

    r = _Reader(data)
    if r.take(4) != MAGIC_VK:
        raise ProofDecodeError("bad magic (not a zkDL verifying key)")
    _check_version(r.u16(), "vk")
    q_bits, r_bits = r.u8(), r.u8()
    n_steps = r.u32()
    nodes = []
    for _ in range(r.u16()):
        name = r.str_()
        kind = r.str_()
        inputs = tuple(r.str_() for _ in range(r.u8()))
        shape = (r.u32(), r.u32())
        layer = r.u32()
        nodes.append(LayerOp(name, kind, inputs, shape, layer=layer))
    if not r.done():
        raise ProofDecodeError("trailing bytes after vk")
    try:
        graph = LayerGraph(tuple(nodes))
        cfg = PipelineConfig.from_graph(graph, q_bits=q_bits,
                                        r_bits=r_bits, n_steps=n_steps)
    except (ValueError, KeyError, AssertionError, IndexError, TypeError,
            OverflowError, ZeroDivisionError) as exc:
        # config derivation asserts geometry (>= 2 layers, pow2 batch,
        # resolvable op inputs); from attacker-supplied bytes ANY of
        # these are format errors, not bugs — the fuzz suite
        # (tests/test_proofio_fuzz.py) holds this to "ProofDecodeError
        # or clean verify-reject, never a crash"
        raise ProofDecodeError(f"invalid graph in vk: {exc}") from exc
    if cfg.merged_len > max_merged_len:
        raise ProofDecodeError(
            f"vk geometry implies a {cfg.merged_len}-generator merged key "
            f"(cap {max_merged_len}): refusing key derivation")
    return VerifyingKey(cfg=cfg)
