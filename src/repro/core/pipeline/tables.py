"""Shared field-table helpers for the staged proof pipeline.

All proof tensors are flat ``(n, 4)`` uint32 limb tables in Montgomery
form (see `repro.core.mle` for the variable-ordering convention).  The
helpers here are the witness-to-table plumbing every stage shares:
encoding int64 tensors, fixing row/column variable blocks, Kronecker
products of expanded points, and sparse "weight" tables over the stacked
(step, layer) slot axis.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.field import FQ, add, sub, mont_mul, encode_i64, decode
from repro.core import execache
from repro.core.mle import enc, enc_vec

Q_MOD = FQ.modulus


def next_pow2(n: int) -> int:
    m = 1
    while m < n:
        m *= 2
    return m


def log2_exact(n: int) -> int:
    assert n & (n - 1) == 0
    return n.bit_length() - 1


def rand_scalar(rng) -> int:
    return int(rng.integers(0, Q_MOD, dtype=np.uint64)) % Q_MOD


def enc_tensor(x: np.ndarray) -> jnp.ndarray:
    """int64 array -> flat (n,4) Montgomery table."""
    return jnp.asarray(encode_i64(FQ, x.reshape(-1))).reshape(-1, 4)


def dec_scalar(x) -> int:
    return int(decode(FQ, x)[()])


def dec_scalars(x) -> List[int]:
    """(k, 4) limb array -> k python ints, one host transfer."""
    return [int(v) for v in decode(FQ, x)]


def kron_many(his, lo) -> jnp.ndarray:
    """Batched `kron`: (k,a,4) x (b,4) -> (k,a*b,4), one dispatch."""
    return _kron_many(his, lo)


def _kron_many(his, lo):
    out = mont_mul(FQ, his[:, :, None, :], lo[None, None, :, :])
    return out.reshape(his.shape[0], -1, 4)


_kron_many = execache.wrap("tab_kron_many", _kron_many)


def fix_rows(table: jnp.ndarray, point: List[int]) -> jnp.ndarray:
    """table (R, C, 4); fold ROW vars (little-endian) -> (C, 4)."""
    for r in point:
        rl = enc(r)
        even, odd = table[0::2], table[1::2]
        table = add(FQ, even, mont_mul(FQ, sub(FQ, odd, even), rl[None, None]))
    return table[0]


def fix_cols(table: jnp.ndarray, point: List[int]) -> jnp.ndarray:
    """table (R, C, 4); fold COL vars -> (R, 4)."""
    for r in point:
        rl = enc(r)
        even, odd = table[:, 0::2], table[:, 1::2]
        table = add(FQ, even, mont_mul(FQ, sub(FQ, odd, even), rl[None, None]))
    return table[:, 0]


def kron(hi: jnp.ndarray, lo: jnp.ndarray) -> jnp.ndarray:
    """(a,4) x (b,4) -> (a*b,4) with lo varying fastest (low MLE vars)."""
    return mont_mul(FQ, hi[:, None, :], lo[None, :, :]).reshape(-1, 4)


def weight_table(weights: Dict[int, int], n: int) -> jnp.ndarray:
    """Sparse coefficient vector over an n-slot axis as a field table."""
    vec = np.zeros(n, dtype=object)
    for i, w in weights.items():
        vec[i] = w % Q_MOD
    return enc_vec(list(vec))


def wt_eval(weights: Dict[int, int], e_host: List[int]) -> int:
    """<weights, e(u)> for a host-expanded point (verifier side)."""
    return sum(w * e_host[i] for i, w in weights.items()) % Q_MOD
