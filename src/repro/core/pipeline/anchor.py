"""Step (b): the anchor sumcheck -- generalized eq. (27) over the stacked
(elem, node, step) hypercube.

Every claim on the uncommitted tensors A^{l,t} / G_Z^{l,t} produced by
the step-(a) bucket sumchecks is random-linearly combined (coefficients
`AnchorCoefs`) and reduced, through ONE degree-3 sumcheck over all
log2(d_stack) = log2(d_slot) + log2(l_pad) + log2(t_pad) variables, to
claims on the committed auxiliary tensors at a single point u_star.
Aggregating T steps therefore costs log2(t_pad) extra rounds -- not T
extra proofs -- and heterogeneous layers cost nothing extra at all: a
claim at a narrow node's point is embedded into its slot by zero-
extending the point (`pad_point`), so the same batching table handles
every shape.

The public batching tables pa / pg are sums of Kronecker products of
sparse slot-axis coefficient vectors with expanded claim points, grouped
by distinct point (a uniform graph has exactly one fwd/gw/bwd point, so
the seed's two-term tables fall out unchanged); the verifier
re-evaluates them at u_star in O(#claims * log d) host work.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax.numpy as jnp

from repro.field import FQ, add, sub
from repro.core.mle import (enc, expand_point, heval_point_product,
                            hexpand_point)
from repro.core.sumcheck import SumcheckProof, sumcheck_prove, sumcheck_verify
from repro.core.transcript import Transcript
from repro.core.pipeline import matmul
from repro.core.pipeline.challenges import (AnchorCoefs, ChallengeSchedule,
                                            instance_slices, pad_point)
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.tables import kron, log2_exact, weight_table, wt_eval
from repro.core.pipeline.witness import FieldTables

Q_MOD = FQ.modulus


@dataclasses.dataclass(frozen=True)
class AnchorClaim:
    """One step-(a) claim on an uncommitted activation / gradient tensor:
    its aux slot(s), its element point (in the tensor's own variables),
    the drawn batching coefficient, and where its value lives in the
    bucket sumcheck finals (family, layer, left/right index).

    ``slots`` has one entry for a chain operand; a residual-sum operand
    lists every producer slot — the claimed sumcheck value is then
    A1(p) + A2(p), matched on the table side by the SAME coefficient on
    each producer's slot selector (linear split, no extra transcript)."""
    slots: Tuple[int, ...]
    point: Tuple[int, ...]
    coef: int
    family: str
    layer: int
    idx: int
    step: int


def _act_point(cfg: PipelineConfig, ch: ChallengeSchedule,
               points: Dict[str, List[List[int]]], family: str,
               layer: int) -> Tuple[int, ...]:
    """Element point of the ACTIVATION-side operand claim produced by the
    (family, layer) instance: the bound inner point takes the operand's
    free variables, the claim-tensor slices its fixed ones."""
    inst = cfg.graph.instance(family, layer)
    bi, _ = cfg.graph.locate(family, layer)
    w = points[family][bi]
    u_cols, u_rows, _ = instance_slices(inst, ch.glob(family))
    if family == "fwd":     # A^{layer-1}(u_rows, w): cols bound at w
        return tuple(w) + tuple(u_rows)
    if family == "bwd":     # G_Z^{layer+1}(u_rows, w)
        return tuple(w) + tuple(u_rows)
    # gw left (idx 0): G_Z^layer(w, u_rows); right (idx 1): A^{layer-1}(w, u_cols)
    raise AssertionError("gw handled by _gw_point")


def _gw_point(cfg: PipelineConfig, ch: ChallengeSchedule,
              points: Dict[str, List[List[int]]], layer: int,
              idx: int) -> Tuple[int, ...]:
    inst = cfg.graph.instance("gw", layer)
    bi, _ = cfg.graph.locate("gw", layer)
    w3 = points["gw"][bi]
    u_cols, u_rows, _ = instance_slices(inst, ch.glob("gw"))
    # G_W^l rows select G_Z^l columns, G_W^l cols select A^{l-1} columns
    return (tuple(u_rows) if idx == 0 else tuple(u_cols)) + tuple(w3)


def collect_claims(cfg: PipelineConfig, ch: ChallengeSchedule,
                   al: AnchorCoefs, points: Dict[str, List[List[int]]]
                   ) -> Tuple[List[AnchorClaim], List[AnchorClaim]]:
    """(A claims, G_Z claims), in the fixed a1/a2/g1/g2 draw order."""
    g = cfg.graph
    a_claims: List[AnchorClaim] = []
    g_claims: List[AnchorClaim] = []

    def _operand_slots(family: str, layer: int) -> Tuple[int, ...]:
        """Producer slot(s) of the instance's activation operand: a chain
        operand is its own zkrelu slot; a residual sum lists both
        producers (the claim value splits linearly across them)."""
        return g.producer_aux_slots(g.instance(family, layer).a_node)

    for (ti, l), c in al.a1.items():      # operand A of fwd instance l+1
        a_claims.append(AnchorClaim(
            slots=_operand_slots("fwd", l + 1),
            point=_act_point(cfg, ch, points, "fwd", l + 1),
            coef=c, family="fwd", layer=l + 1, idx=0, step=ti))
    for (ti, l), c in al.a2.items():      # operand A of gw instance l+1
        a_claims.append(AnchorClaim(
            slots=_operand_slots("gw", l + 1),
            point=_gw_point(cfg, ch, points, l + 1, 1),
            coef=c, family="gw", layer=l + 1, idx=1, step=ti))
    for (ti, l), c in al.g1.items():      # G_Z^l from bwd instance l-1
        g_claims.append(AnchorClaim(
            slots=(g.aux_slot(g.node_for_layer("zkrelu", l).name),),
            point=_act_point(cfg, ch, points, "bwd", l - 1),
            coef=c, family="bwd", layer=l - 1, idx=0, step=ti))
    for (ti, l), c in al.g2.items():      # G_Z^l from gw instance l
        g_claims.append(AnchorClaim(
            slots=(g.aux_slot(g.node_for_layer("zkrelu", l).name),),
            point=_gw_point(cfg, ch, points, l, 0),
            coef=c, family="gw", layer=l, idx=0, step=ti))
    return a_claims, g_claims


def _group_claims(cfg: PipelineConfig, claims: List[AnchorClaim]
                  ) -> Dict[Tuple[int, ...], Dict[int, int]]:
    """Claims grouped by distinct element point, coefficients summed per
    stacked slot.  Prover table construction and verifier re-evaluation
    MUST use this same grouping, so it is the single shared helper."""
    groups: Dict[Tuple[int, ...], Dict[int, int]] = {}
    for cl in claims:
        w = groups.setdefault(cl.point, {})
        for s in cl.slots:
            slot = cfg.slot(cl.step, s)
            w[slot] = (w.get(slot, 0) + cl.coef) % Q_MOD
    return groups


def _batch_table(cfg: PipelineConfig, claims: List[AnchorClaim]):
    """Prover-side public batching table over the full stacked cube:
    sum over claims of coef * (slot selector (x) padded point expansion),
    grouped by distinct point so a uniform graph builds exactly the
    seed's Kronecker terms."""
    groups = _group_claims(cfg, claims)
    acc = None
    for point, weights in groups.items():
        term = kron(weight_table(weights, cfg.s_pad),
                    expand_point(pad_point(list(point), cfg.la)))
        acc = term if acc is None else add(FQ, acc, term)
    return acc


def _batch_eval(cfg: PipelineConfig, claims: List[AnchorClaim],
                el: List[int], u_elem: List[int]) -> int:
    """Verifier-side evaluation of the batching table at u_star."""
    acc = 0
    for point, weights in _group_claims(cfg, claims).items():
        acc = (acc + wt_eval(weights, el) * heval_point_product(
            pad_point(list(point), cfg.la), u_elem)) % Q_MOD
    return acc


@dataclasses.dataclass
class AnchorOut:
    sc_anchor: SumcheckProof
    anchor_finals: List[int]
    u_star: List[int]


def prove(cfg: PipelineConfig, tabs: FieldTables, ch: ChallengeSchedule,
          mat: matmul.MatmulOut, t: Transcript) -> AnchorOut:
    points = {fam: mat.fams[fam].points for fam in mat.fams}
    al = AnchorCoefs.draw(t, cfg)
    a_claims, g_claims = collect_claims(cfg, ch, al, points)
    pa = _batch_table(cfg, a_claims)
    pg = _batch_table(cfg, g_claims)
    one_tab = jnp.broadcast_to(enc(1), (cfg.d_stack, 4)).astype(jnp.uint32)
    one_b = sub(FQ, one_tab, tabs.bq_t)
    anchor_tables = [one_b, tabs.zpp_t, tabs.gap_t, pa, pg]
    anchor_products = [(0, 3, 1), (0, 4, 2)]
    sc_anchor, u_star, anchor_finals = sumcheck_prove(
        anchor_tables, anchor_products, t, b"anchor")
    return AnchorOut(sc_anchor=sc_anchor, anchor_finals=anchor_finals,
                     u_star=u_star)


def verify(cfg: PipelineConfig, proof, ch: ChallengeSchedule,
           points: Dict[str, List[List[int]]],
           t: Transcript) -> List[int]:
    """Checks the anchor sumcheck against the step-(a) finals and the
    public batching tables; returns u_star.  Raises ValueError on
    failure."""
    al = AnchorCoefs.draw(t, cfg)
    a_claims, g_claims = collect_claims(cfg, ch, al, points)

    # LHS: the batched claims assembled from the bucket sumcheck finals
    lhs = 0
    for cl in a_claims + g_claims:
        finals = getattr(proof, f"{cl.family}_finals")
        v = matmul.pair_final(cfg, finals, cl.family, cl.step, cl.layer,
                              cl.idx)
        lhs = (lhs + cl.coef * v) % Q_MOD

    u_star, exp_anchor = sumcheck_verify(
        lhs, proof.sc_anchor, 3, log2_exact(cfg.d_stack), t, b"anchor")
    f_oneb, f_zpp, f_gap, f_pa, f_pg = proof.anchor_finals
    if exp_anchor != (f_oneb * f_pa % Q_MOD * f_zpp
                      + f_oneb * f_pg % Q_MOD * f_gap) % Q_MOD:
        raise ValueError("anchor-final")
    t.absorb_ints(b"anchor/final", proof.anchor_finals)

    # recompute the public batching tables at u_star
    u_elem, u_slot = u_star[: cfg.la], u_star[cfg.la:]
    el = hexpand_point(u_slot)
    if f_pa != _batch_eval(cfg, a_claims, el, u_elem):
        raise ValueError("anchor-public-tables")
    if f_pg != _batch_eval(cfg, g_claims, el, u_elem):
        raise ValueError("anchor-public-tables")
    return u_star


def output_gz_points(cfg: PipelineConfig, ch: ChallengeSchedule,
                     points: Dict[str, List[List[int]]]
                     ) -> Tuple[List[int], List[int]]:
    """The two element points carrying the G_Z^{L,t} claims that bypass
    the anchor and discharge through the eq. (32) loss-layer reduction:
    pt_b from the bwd instance of pair L-1, pt_w from the gw instance of
    layer L.  Both span log2(batch * padded output width) variables."""
    L = cfg.n_layers
    pt_b = list(_act_point(cfg, ch, points, "bwd", L - 1))
    pt_w = list(_gw_point(cfg, ch, points, L, 0))
    return pt_b, pt_w
