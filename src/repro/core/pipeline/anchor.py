"""Step (b): the anchor sumcheck -- generalized eq. (27) over the stacked
(elem, layer, step) hypercube.

Every claim on the uncommitted tensors A^{l,t} / G_Z^{l,t} produced by
step (a) is random-linearly combined (coefficients `AnchorCoefs`) and
reduced, through ONE degree-3 sumcheck over all log2(d_stack) =
log2(B*d) + log2(l_pad) + log2(t_pad) variables, to claims on the
committed auxiliary tensors at a single point u_star.  Aggregating T
steps therefore costs log2(t_pad) extra rounds -- not T extra proofs.

The public batching tables pa / pg are Kronecker products of a sparse
slot-axis coefficient vector with the expanded element points, so the
verifier re-evaluates them at u_star in O(T*L + log d) host work.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax.numpy as jnp

from repro.field import FQ, add, sub
from repro.core.mle import enc, expand_point, heval_point_product, hexpand_point
from repro.core.sumcheck import SumcheckProof, sumcheck_prove, sumcheck_verify
from repro.core.transcript import Transcript
from repro.core.pipeline import matmul
from repro.core.pipeline.challenges import AnchorCoefs, ChallengeSchedule
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.tables import kron, log2_exact, weight_table, wt_eval
from repro.core.pipeline.witness import FieldTables

Q_MOD = FQ.modulus


@dataclasses.dataclass
class AnchorPoints:
    """The four stacked element-points carrying step-(a) claims."""
    pt_f: List[int]    # A claims from fwd
    pt_g: List[int]    # A claims from gw
    pt_b: List[int]    # G_Z claims from bwd
    pt_w: List[int]    # G_Z claims from gw

    @classmethod
    def build(cls, ch: ChallengeSchedule, w1, w2, w3) -> "AnchorPoints":
        return cls(pt_f=w1 + ch.u_r, pt_g=ch.u_j + w3,
                   pt_b=w2 + ch.u_r2, pt_w=ch.u_i + w3)


def _slot_dicts(cfg: PipelineConfig, al: AnchorCoefs) -> Tuple[Dict, ...]:
    """AnchorCoefs -> sparse slot-axis weight dicts (A^l lives at layer
    index l-1 of the stacked tensors, as does G_Z^l)."""
    wA1 = {cfg.slot(t, l - 1): c for (t, l), c in al.a1.items()}
    wA2 = {cfg.slot(t, l - 1): c for (t, l), c in al.a2.items()}
    wG1 = {cfg.slot(t, l - 1): c for (t, l), c in al.g1.items()}
    wG2 = {cfg.slot(t, l - 1): c for (t, l), c in al.g2.items()}
    return wA1, wA2, wG1, wG2


@dataclasses.dataclass
class AnchorOut:
    sc_anchor: SumcheckProof
    anchor_finals: List[int]
    u_star: List[int]
    pts: AnchorPoints


def prove(cfg: PipelineConfig, tabs: FieldTables, ch: ChallengeSchedule,
          mat: matmul.MatmulOut, t: Transcript) -> AnchorOut:
    pts = AnchorPoints.build(ch, mat.w1, mat.w2, mat.w3)
    al = AnchorCoefs.draw(t, cfg)
    wA1, wA2, wG1, wG2 = _slot_dicts(cfg, al)
    pa = add(FQ, kron(weight_table(wA1, cfg.s_pad), expand_point(pts.pt_f)),
             kron(weight_table(wA2, cfg.s_pad), expand_point(pts.pt_g)))
    pg = add(FQ, kron(weight_table(wG1, cfg.s_pad), expand_point(pts.pt_b)),
             kron(weight_table(wG2, cfg.s_pad), expand_point(pts.pt_w)))
    one_tab = jnp.broadcast_to(enc(1), (cfg.d_stack, 4)).astype(jnp.uint32)
    one_b = sub(FQ, one_tab, tabs.bq_t)
    anchor_tables = [one_b, tabs.zpp_t, tabs.gap_t, pa, pg]
    anchor_products = [(0, 3, 1), (0, 4, 2)]
    sc_anchor, u_star, anchor_finals = sumcheck_prove(
        anchor_tables, anchor_products, t, b"anchor")
    return AnchorOut(sc_anchor=sc_anchor, anchor_finals=anchor_finals,
                     u_star=u_star, pts=pts)


def verify(cfg: PipelineConfig, proof, ch: ChallengeSchedule,
           w1, w2, w3, t: Transcript) -> Tuple[AnchorPoints, List[int]]:
    """Checks the anchor sumcheck against the step-(a) finals and the
    public batching tables; returns (points, u_star).  Raises ValueError
    on failure."""
    T, L = cfg.n_steps, cfg.n_layers
    lb, ld = log2_exact(cfg.batch), log2_exact(cfg.width)
    pts = AnchorPoints.build(ch, w1, w2, w3)
    al = AnchorCoefs.draw(t, cfg)

    # LHS: the batched claims assembled from the matmul sumcheck finals
    lhs = 0
    for (ti, l), c in al.a1.items():      # A^l from fwd pair (t, l+1)
        lhs = (lhs + c * proof.fwd_finals[2 * matmul.fwd_pair(cfg, ti, l + 1)]) % Q_MOD
    for (ti, l), c in al.a2.items():      # A^l from gw pair (t, l+1)
        lhs = (lhs + c * proof.gw_finals[2 * matmul.gw_pair(cfg, ti, l + 1) + 1]) % Q_MOD
    for (ti, l), c in al.g1.items():      # G_Z^l from bwd pair (t, l-1)
        lhs = (lhs + c * proof.bwd_finals[2 * matmul.bwd_pair(cfg, ti, l - 1)]) % Q_MOD
    for (ti, l), c in al.g2.items():      # G_Z^l from gw pair (t, l)
        lhs = (lhs + c * proof.gw_finals[2 * matmul.gw_pair(cfg, ti, l)]) % Q_MOD

    u_star, exp_anchor = sumcheck_verify(
        lhs, proof.sc_anchor, 3, log2_exact(cfg.d_stack), t, b"anchor")
    f_oneb, f_zpp, f_gap, f_pa, f_pg = proof.anchor_finals
    if exp_anchor != (f_oneb * f_pa % Q_MOD * f_zpp
                      + f_oneb * f_pg % Q_MOD * f_gap) % Q_MOD:
        raise ValueError("anchor-final")
    t.absorb_ints(b"anchor/final", proof.anchor_finals)

    # recompute the public batching tables at u_star
    u_elem, u_slot = u_star[: lb + ld], u_star[lb + ld:]
    el = hexpand_point(u_slot)
    wA1, wA2, wG1, wG2 = _slot_dicts(cfg, al)
    pa_check = (wt_eval(wA1, el) * heval_point_product(pts.pt_f, u_elem)
                + wt_eval(wA2, el) * heval_point_product(pts.pt_g, u_elem)) % Q_MOD
    pg_check = (wt_eval(wG1, el) * heval_point_product(pts.pt_b, u_elem)
                + wt_eval(wG2, el) * heval_point_product(pts.pt_w, u_elem)) % Q_MOD
    if f_pa != pa_check or f_pg != pg_check:
        raise ValueError("anchor-public-tables")
    return pts, u_star
