"""Witness stacking: T `StepWitness`es -> one stacked proof witness.

The stacked auxiliary tensors put the element variables low, the layer
variables next, and the step variables on top (little-endian MLE
ordering), so flat index = (t * l_pad + layer) * d_elem + elem.  Padded
layers AND padded steps are zero, which keeps every stacked relation
exact: zero slots contribute nothing to any sumcheck and pass the zkReLU
range constraints trivially.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core.quantfc import StepWitness
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.tables import enc_tensor


def _stack_aux(per_step: List[List[np.ndarray]],
               cfg: PipelineConfig) -> np.ndarray:
    """per_step[t] = list of (B, d) int64 -> (d_stack,) with zero padding."""
    out = np.zeros((cfg.t_pad, cfg.l_pad, cfg.d_elem), dtype=np.int64)
    for t, layers in enumerate(per_step):
        for i, tensor in enumerate(layers):
            out[t, i] = tensor.reshape(-1)
    return out.reshape(-1)


@dataclasses.dataclass
class StackedWitness:
    """Stacked int64 tensors plus the per-step raw witnesses."""
    cfg: PipelineConfig
    steps: List[StepWitness]
    zpp_s: np.ndarray      # (d_stack,)
    bq_s: np.ndarray
    rz_s: np.ndarray
    gap_s: np.ndarray
    rga_s: np.ndarray
    w_s: np.ndarray        # (w_stack,)
    gw_s: np.ndarray
    y_s: np.ndarray        # (y_stack,)
    x: List[np.ndarray]    # T*B per-sample rows (width,), t-major

    @property
    def n_steps(self) -> int:
        return len(self.steps)


def stack_witnesses(steps: List[StepWitness],
                    cfg: PipelineConfig) -> StackedWitness:
    if len(steps) != cfg.n_steps:
        raise ValueError(
            f"session holds {len(steps)} step witnesses, "
            f"config requires exactly {cfg.n_steps}")
    for t, wit in enumerate(steps):
        if wit.n_layers != cfg.n_layers:
            raise ValueError(f"step {t}: {wit.n_layers} layers != "
                             f"{cfg.n_layers}")
        if wit.x.shape != (cfg.batch, cfg.width):
            raise ValueError(f"step {t}: x shape {wit.x.shape} != "
                             f"{(cfg.batch, cfg.width)}")

    w_stack = np.zeros((cfg.t_pad, cfg.l_pad, cfg.width * cfg.width),
                       dtype=np.int64)
    gw_stack = np.zeros_like(w_stack)
    y_stack = np.zeros((cfg.t_pad, cfg.d_elem), dtype=np.int64)
    xs: List[np.ndarray] = []
    for t, wit in enumerate(steps):
        for i in range(cfg.n_layers):
            w_stack[t, i] = wit.w[i].reshape(-1)
            gw_stack[t, i] = wit.gw[i].reshape(-1)
        y_stack[t] = wit.y.reshape(-1)
        xs.extend(wit.x[i] for i in range(cfg.batch))

    return StackedWitness(
        cfg=cfg, steps=list(steps),
        zpp_s=_stack_aux([w.zpp for w in steps], cfg),
        bq_s=_stack_aux([w.b for w in steps], cfg),
        rz_s=_stack_aux([w.rz for w in steps], cfg),
        gap_s=_stack_aux([w.gap for w in steps], cfg),
        rga_s=_stack_aux([w.rga for w in steps], cfg),
        w_s=w_stack.reshape(-1), gw_s=gw_stack.reshape(-1),
        y_s=y_stack.reshape(-1), x=xs)


@dataclasses.dataclass
class FieldTables:
    """The stacked witness re-encoded as Montgomery limb tables (prover)."""
    zpp_t: jnp.ndarray
    bq_t: jnp.ndarray
    rz_t: jnp.ndarray
    gap_t: jnp.ndarray
    rga_t: jnp.ndarray
    w_t: jnp.ndarray
    gw_t: jnp.ndarray
    y_t: jnp.ndarray
    x_tabs: List[jnp.ndarray]            # T*B tables (width, 4), t-major
    a_tabs: List[List[jnp.ndarray]]      # [t][l] (B, d, 4)
    gz_tabs: List[List[jnp.ndarray]]     # [t][l] (B, d, 4)
    w_mats: List[List[jnp.ndarray]]      # [t][l] (d, d, 4)


def build_field_tables(sw: StackedWitness) -> FieldTables:
    cfg = sw.cfg
    B, d = cfg.batch, cfg.width
    return FieldTables(
        zpp_t=enc_tensor(sw.zpp_s), bq_t=enc_tensor(sw.bq_s),
        rz_t=enc_tensor(sw.rz_s), gap_t=enc_tensor(sw.gap_s),
        rga_t=enc_tensor(sw.rga_s), w_t=enc_tensor(sw.w_s),
        gw_t=enc_tensor(sw.gw_s), y_t=enc_tensor(sw.y_s),
        x_tabs=[enc_tensor(x) for x in sw.x],
        a_tabs=[[enc_tensor(a).reshape(B, d, 4) for a in w.a]
                for w in sw.steps],
        gz_tabs=[[enc_tensor(g).reshape(B, d, 4) for g in w.gz]
                 for w in sw.steps],
        w_mats=[[enc_tensor(m).reshape(d, d, 4) for m in w.w]
                for w in sw.steps])
