"""Witness stacking: T `StepWitness`es -> one stacked proof witness.

Stacking is driven by the layer graph's slot maps: each aux node's
tensors land in slot ``cfg.slot(t, graph.aux_slot(node))``, each weight
node's in ``cfg.wslot(t, graph.weight_slot(node))``, with the element
variables low, the node variables next, and the step variables on top
(little-endian MLE ordering).  Heterogeneous shapes are zero-padded
twice: each (rows, cols) tensor first pads per-dimension to powers of
two (so its own row/column MLE variables stay aligned), then the padded
block zero-extends to the common slot area.  Zero padding keeps every
stacked relation exact: zero slots contribute nothing to any sumcheck
and pass the zkReLU range constraints trivially.  A uniform-width graph
makes both paddings no-ops, reproducing the seed layout bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core.quantfc import StepWitness
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.graph import extract_node_tensors
from repro.core.pipeline.tables import enc_tensor

AUX_NAMES = ("zpp", "bq", "rz", "gap", "rga")


def pad2d(tensor: np.ndarray, rows_pad: int, cols_pad: int) -> np.ndarray:
    """(r, c) int64 -> (rows_pad, cols_pad) with zero padding."""
    r, c = tensor.shape
    assert r <= rows_pad and c <= cols_pad, (tensor.shape, rows_pad, cols_pad)
    if (r, c) == (rows_pad, cols_pad):
        return tensor
    out = np.zeros((rows_pad, cols_pad), dtype=tensor.dtype)
    out[:r, :c] = tensor
    return out


def node_tensors(cfg: PipelineConfig, wit: StepWitness) -> Dict[str, Dict]:
    return extract_node_tensors(cfg.graph, wit)


def _stack_aux(per_step: List[Dict[str, Dict]], name: str,
               cfg: PipelineConfig) -> np.ndarray:
    """Aux tensor `name` of every (step, node) -> (d_stack,) stacked."""
    g = cfg.graph
    out = np.zeros((cfg.t_pad, cfg.l_pad, cfg.d_elem), dtype=np.int64)
    for t, tensors in enumerate(per_step):
        for i, node in enumerate(g.aux_nodes):
            padded = pad2d(tensors[node.name][name],
                           node.rows_pad, node.cols_pad)
            out[t, i, : node.elem_pad] = padded.reshape(-1)
    return out.reshape(-1)


@dataclasses.dataclass
class StackedWitness:
    """Stacked int64 tensors plus the per-step raw witnesses."""
    cfg: PipelineConfig
    steps: List[StepWitness]
    zpp_s: np.ndarray      # (d_stack,)
    bq_s: np.ndarray
    rz_s: np.ndarray
    gap_s: np.ndarray
    rga_s: np.ndarray
    w_s: np.ndarray        # (w_stack,)
    gw_s: np.ndarray
    y_s: np.ndarray        # (y_stack,)
    x: List[np.ndarray]    # T*B per-sample rows (x_len,), t-major

    @property
    def n_steps(self) -> int:
        return len(self.steps)


def stack_witnesses(steps: List[StepWitness],
                    cfg: PipelineConfig) -> StackedWitness:
    if len(steps) != cfg.n_steps:
        raise ValueError(
            f"session holds {len(steps)} step witnesses, "
            f"config requires exactly {cfg.n_steps}")
    g = cfg.graph
    for t, wit in enumerate(steps):
        if wit.n_layers != cfg.n_layers:
            raise ValueError(f"step {t}: {wit.n_layers} layers != "
                             f"{cfg.n_layers}")
        if wit.x.shape != (cfg.batch, cfg.widths[0]):
            raise ValueError(f"step {t}: x shape {wit.x.shape} != "
                             f"{(cfg.batch, cfg.widths[0])}")
        for l in range(1, cfg.n_layers + 1):
            want = (cfg.widths[l - 1], cfg.widths[l])
            if wit.w[l - 1].shape != want:
                raise ValueError(f"step {t}: W^{l} shape "
                                 f"{wit.w[l - 1].shape} != {want}")

    per_step = [node_tensors(cfg, wit) for wit in steps]

    w_stack = np.zeros((cfg.t_pad, cfg.lw_pad, cfg.w_elem), dtype=np.int64)
    gw_stack = np.zeros_like(w_stack)
    y_stack = np.zeros((cfg.t_pad, cfg.y_elem), dtype=np.int64)
    xs: List[np.ndarray] = []
    out_node = g.output_node
    x_node = g.input_node
    for t, (wit, tensors) in enumerate(zip(steps, per_step)):
        for i, node in enumerate(g.weight_nodes):
            rp, cp = g.weight_shape(node)
            w_stack[t, i, : rp * cp] = pad2d(
                tensors[node.name]["w"], rp, cp).reshape(-1)
            gw_stack[t, i, : rp * cp] = pad2d(
                tensors[node.name]["gw"], cp, rp).reshape(-1)
        y_stack[t] = pad2d(tensors[out_node.name]["y"], out_node.rows_pad,
                           out_node.cols_pad).reshape(-1)
        x_pad = pad2d(wit.x, cfg.batch, x_node.cols_pad)
        xs.extend(x_pad[i] for i in range(cfg.batch))

    return StackedWitness(
        cfg=cfg, steps=list(steps),
        **{f"{name}_s": _stack_aux(per_step, name, cfg)
           for name in AUX_NAMES},
        w_s=w_stack.reshape(-1), gw_s=gw_stack.reshape(-1),
        y_s=y_stack.reshape(-1), x=xs)


@dataclasses.dataclass
class FieldTables:
    """The stacked witness re-encoded as Montgomery limb tables (prover).

    The per-(step, layer) operand tables are padded to per-node power-of-
    two shapes so `fix_rows`/`fix_cols` see aligned MLE variables:
    a_tabs[t][l] is A^l (batch, cols_pad of layer l's activation; l=0 is
    the padded input), gz_tabs[t][l] is G_Z^{l+1}, w_mats[t][l] is
    W^{l+1} at its padded (in, out) shape.
    """
    zpp_t: jnp.ndarray
    bq_t: jnp.ndarray
    rz_t: jnp.ndarray
    gap_t: jnp.ndarray
    rga_t: jnp.ndarray
    w_t: jnp.ndarray
    gw_t: jnp.ndarray
    y_t: jnp.ndarray
    x_tabs: List[jnp.ndarray]            # T*B tables (x_len, 4), t-major
    a_tabs: List[List[jnp.ndarray]]      # [t][l] (B, cpad_l, 4)
    gz_tabs: List[List[jnp.ndarray]]     # [t][l] (B, cpad_{l+1}, 4)
    w_mats: List[List[jnp.ndarray]]      # [t][l] (ipad_{l+1}, opad_{l+1}, 4)


def _enc2d(tensor: np.ndarray, rows_pad: int, cols_pad: int) -> jnp.ndarray:
    return enc_tensor(pad2d(tensor, rows_pad, cols_pad)).reshape(
        rows_pad, cols_pad, 4)


def build_field_tables(sw: StackedWitness) -> FieldTables:
    cfg = sw.cfg
    g = cfg.graph
    B = cfg.batch
    cpads = [g.input_node.cols_pad] + [
        g.node_for_layer("zkrelu", l).cols_pad
        for l in range(1, cfg.n_layers + 1)]
    wshapes = [g.weight_shape(g.node_for_layer("qmatmul", l))
               for l in range(1, cfg.n_layers + 1)]
    return FieldTables(
        zpp_t=enc_tensor(sw.zpp_s), bq_t=enc_tensor(sw.bq_s),
        rz_t=enc_tensor(sw.rz_s), gap_t=enc_tensor(sw.gap_s),
        rga_t=enc_tensor(sw.rga_s), w_t=enc_tensor(sw.w_s),
        gw_t=enc_tensor(sw.gw_s), y_t=enc_tensor(sw.y_s),
        x_tabs=[enc_tensor(x) for x in sw.x],
        a_tabs=[[_enc2d(a, B, cpads[l]) for l, a in enumerate(w.a)]
                for w in sw.steps],
        gz_tabs=[[_enc2d(gz, B, cpads[l + 1]) for l, gz in enumerate(w.gz)]
                 for w in sw.steps],
        w_mats=[[_enc2d(m, *wshapes[l]) for l, m in enumerate(w.w)]
                for w in sw.steps])
