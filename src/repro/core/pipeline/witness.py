"""Witness stacking: T `StepWitness`es -> one stacked proof witness.

Stacking is driven entirely by the layer graph's commitment schema
(`LayerGraph.commit_slots`): each named tensor slot an `OpSpec` declares
("zpp", "w", "y", ...) becomes one stacked int64 vector, with each
node's tensor landing at ``cfg.slot(t, graph.aux_slot(node))`` (aux
axis) / ``cfg.wslot(t, graph.weight_slot(node))`` (weight axis) /
step ``t`` (label axis), element variables low, node variables next,
step variables on top (little-endian MLE ordering).  A new op kind's
tensors flow through by declaring `TensorSlot`s — nothing here names a
specific tensor.

Heterogeneous shapes are zero-padded twice: each (rows, cols) tensor
first pads per-dimension to powers of two (so its own row/column MLE
variables stay aligned), then the padded block zero-extends to the
common slot area.  Zero padding keeps every stacked relation exact:
zero slots contribute nothing to any sumcheck and pass the zkReLU range
constraints trivially.  A uniform-width graph makes both paddings
no-ops, reproducing the seed layout bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core.quantfc import StepWitness
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.graph import extract_node_tensors
from repro.core.pipeline.tables import enc_tensor

def pad2d(tensor: np.ndarray, rows_pad: int, cols_pad: int) -> np.ndarray:
    """(r, c) int64 -> (rows_pad, cols_pad) with zero padding."""
    r, c = tensor.shape
    assert r <= rows_pad and c <= cols_pad, (tensor.shape, rows_pad, cols_pad)
    if (r, c) == (rows_pad, cols_pad):
        return tensor
    out = np.zeros((rows_pad, cols_pad), dtype=tensor.dtype)
    out[:r, :c] = tensor
    return out


def node_tensors(cfg: PipelineConfig, wit: StepWitness) -> Dict[str, Dict]:
    return extract_node_tensors(cfg.graph, wit)


@dataclasses.dataclass
class StackedWitness:
    """Slot-keyed stacked int64 tensors plus the per-step raw witnesses.

    ``tensors[name]`` is the stacked vector of commitment slot `name`
    (d_stack for aux slots, w_stack for weight, y_stack for label); the
    legacy ``<name>_s`` attributes resolve through it.
    """
    cfg: PipelineConfig
    steps: List[StepWitness]
    tensors: Dict[str, np.ndarray]
    x: List[np.ndarray]    # T*B per-sample rows (x_len,), t-major

    @property
    def n_steps(self) -> int:
        return len(self.steps)

    def __getattr__(self, name: str):
        if name.endswith("_s"):
            try:
                return self.tensors[name[:-2]]
            except KeyError:
                pass
        raise AttributeError(name)


def _stack_slot(cfg: PipelineConfig, spec, per_step) -> np.ndarray:
    """One commitment slot's tensors of every (step, node) -> stacked."""
    g = cfg.graph
    if spec.axis == "aux":
        out = np.zeros((cfg.t_pad, cfg.l_pad, cfg.d_elem), dtype=np.int64)
    elif spec.axis == "weight":
        out = np.zeros((cfg.t_pad, cfg.lw_pad, cfg.w_elem), dtype=np.int64)
    else:                                     # label: per-step, no node axis
        out = np.zeros((cfg.t_pad, 1, cfg.y_elem), dtype=np.int64)
    for t, tensors in enumerate(per_step):
        for i, node in enumerate(g.slot_nodes(spec)):
            if spec.name not in tensors[node.name]:
                continue
            rp, cp = g.slot_pad_shape(spec, node)
            out[t, i, : rp * cp] = pad2d(tensors[node.name][spec.name],
                                         rp, cp).reshape(-1)
    return out.reshape(-1)


def stack_witnesses(steps: List[StepWitness],
                    cfg: PipelineConfig) -> StackedWitness:
    if len(steps) != cfg.n_steps:
        raise ValueError(
            f"session holds {len(steps)} step witnesses, "
            f"config requires exactly {cfg.n_steps}")
    g = cfg.graph
    for t, wit in enumerate(steps):
        if wit.n_layers != cfg.n_layers:
            raise ValueError(f"step {t}: {wit.n_layers} layers != "
                             f"{cfg.n_layers}")
        if wit.x.shape != (cfg.batch, cfg.widths[0]):
            raise ValueError(f"step {t}: x shape {wit.x.shape} != "
                             f"{(cfg.batch, cfg.widths[0])}")
        for l in range(1, cfg.n_layers + 1):
            want = (cfg.widths[l - 1], cfg.widths[l])
            if wit.w[l - 1].shape != want:
                raise ValueError(f"step {t}: W^{l} shape "
                                 f"{wit.w[l - 1].shape} != {want}")

    per_step = [node_tensors(cfg, wit) for wit in steps]
    tensors = {spec.name: _stack_slot(cfg, spec, per_step)
               for spec in g.commit_slots}

    x_node = g.input_node
    xs: List[np.ndarray] = []
    for wit in steps:
        x_pad = pad2d(wit.x, cfg.batch, x_node.cols_pad)
        xs.extend(x_pad[i] for i in range(cfg.batch))

    return StackedWitness(cfg=cfg, steps=list(steps), tensors=tensors, x=xs)


@dataclasses.dataclass
class FieldTables:
    """The stacked witness re-encoded as Montgomery limb tables (prover).

    ``tabs[name]`` is commitment slot `name`'s stacked table (legacy
    ``<name>_t`` attributes resolve through it).  The per-(step, layer)
    operand tables are padded to per-node power-of-two shapes so
    `fix_rows`/`fix_cols` see aligned MLE variables: a_tabs[t][l] is the
    OPERAND of matmul l+1 — the resolved value of its input node, which
    for a residual sum is A1 + A2 (computed, never committed; claims on
    it split onto the producer slots) — gz_tabs[t][l] is G_Z^{l+1},
    w_mats[t][l] is W^{l+1} at its padded (in, out) shape.
    """
    tabs: Dict[str, jnp.ndarray]
    x_tabs: List[jnp.ndarray]            # T*B tables (x_len, 4), t-major
    a_tabs: List[List[jnp.ndarray]]      # [t][l] (B, cpad_l, 4)
    gz_tabs: List[List[jnp.ndarray]]     # [t][l] (B, cpad_{l+1}, 4)
    w_mats: List[List[jnp.ndarray]]      # [t][l] (ipad_{l+1}, opad_{l+1}, 4)

    def __getattr__(self, name: str):
        if name.endswith("_t"):
            try:
                return self.tabs[name[:-2]]
            except KeyError:
                pass
        raise AttributeError(name)


def _enc2d(tensor: np.ndarray, rows_pad: int, cols_pad: int) -> jnp.ndarray:
    return enc_tensor(pad2d(tensor, rows_pad, cols_pad)).reshape(
        rows_pad, cols_pad, 4)


def build_field_tables(sw: StackedWitness) -> FieldTables:
    cfg = sw.cfg
    g = cfg.graph
    B = cfg.batch
    mms = [g.node_for_layer("qmatmul", l)
           for l in range(1, cfg.n_layers + 1)]
    operands = [g.node(mm.inputs[0]) for mm in mms]
    wshapes = [g.weight_shape(mm) for mm in mms]
    return FieldTables(
        tabs={name: enc_tensor(t) for name, t in sw.tensors.items()},
        x_tabs=[enc_tensor(x) for x in sw.x],
        a_tabs=[[_enc2d(g.node_value(op.name, w), B, op.cols_pad)
                 for op in operands] for w in sw.steps],
        gz_tabs=[[_enc2d(gz, B, mms[l].cols_pad)
                  for l, gz in enumerate(w.gz)] for w in sw.steps],
        w_mats=[[_enc2d(m, *wshapes[l]) for l, m in enumerate(w.w)]
                for w in sw.steps])
