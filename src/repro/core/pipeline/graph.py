"""FAC4DNN layer-graph IR: heterogeneous-layer proof aggregation.

The paper's point (Section 5) is that proofs aggregate over *different
layers and training steps without being constrained by their sequential
order*.  The seed pipeline realized that only for the uniform-width
quantized FCNN of Example 4.5; this module makes the network shape a
first-class object instead:

* `LayerOp` — one node of the proof graph (input, quantized matmul,
  zkReLU rescale/activation, residual add, output gradient) with an
  explicit unpadded shape and explicit edges to its producers.
* `OP_REGISTRY` — per-kind `OpSpec` supplying shape validation, the
  witness extractor (node -> named int64 tensors of one `StepWitness`),
  and the sumcheck relation handler (node -> `MatmulInstance`s).  The
  zkReLU / output-gradient relation checks live in `anchor.py` /
  `openings.py` but are *driven* by the slot and claim enumerations
  defined here.
* `LayerGraph` — the validated graph plus everything the prover and the
  standalone verifier both derive from it: aux/weight slot maps, padded
  slot sizes, matmul relation instances, and the **shape buckets**.

Shape buckets are the aggregation mechanism: every matmul relation
instance (one per (family, layer), replicated per aggregated training
step) is keyed by its sumcheck table length (the padded inner dimension)
and all instances in a bucket — across layers AND steps — share ONE
batched sumcheck, entering with public coefficient
``e(u_slot)[slot(t, node)] * padfac`` exactly like the seed's three
hardcoded fwd/bwd/gw sumchecks.  A uniform-width graph degenerates to
one bucket per family, reproducing the seed transcript bit-for-bit.

Slot layout (little-endian MLE variables, low to high):

    aux slot:    [cols of node tensor | rows (batch) | zero pad]  d_slot
    weight slot: [cols (in-width)     | rows (out)   | zero pad]  w_slot

so a claim on a node tensor at point ``p`` becomes a claim on the
stacked commitment at ``p ++ zeros ++ slot-selector``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pipeline.tables import log2_exact, next_pow2

FAMILIES = ("fwd", "bwd", "gw")


@dataclasses.dataclass(frozen=True)
class LayerOp:
    """One node of the proof graph.

    ``shape`` is the UNPADDED (rows, cols) of the node's output tensor;
    padded sizes are derived (each dim to the next power of two).
    ``layer`` is the 1-based layer index used for witness extraction and
    transcript tags (0 for the input node).
    """
    name: str
    kind: str                      # key into OP_REGISTRY
    inputs: Tuple[str, ...]
    shape: Tuple[int, int]
    layer: int = 0

    @property
    def rows_pad(self) -> int:
        return next_pow2(self.shape[0])

    @property
    def cols_pad(self) -> int:
        return next_pow2(self.shape[1])

    @property
    def elem_pad(self) -> int:
        return self.rows_pad * self.cols_pad


@dataclasses.dataclass(frozen=True)
class MatmulInstance:
    """One matmul relation of one layer (replicated per training step).

    The claim tensor is the product result: Z^l for fwd (eq. 30),
    G_A^l for bwd (eq. 33), G_W^l for gw (eq. 34).  ``claim_slots`` are
    the stacked-axis slots the claim reduces to (aux slots for fwd/bwd,
    weight slot for gw) — more than one exactly when the claim tensor is
    the gradient of a residual sum, whose committed decomposition splits
    linearly over every producer slot; ``inner`` is the padded inner
    dimension — the sumcheck table length and therefore the bucket key.
    """
    family: str
    layer: int
    claim_rows: int        # padded rows of the claim tensor
    claim_cols: int        # padded cols of the claim tensor
    inner: int             # padded contraction length (bucket key)
    claim_slots: Tuple[int, ...]   # aux (fwd/bwd) or weight (gw) slot indices
    a_node: str            # activation operand node name ("" for bwd)


@dataclasses.dataclass(frozen=True)
class TensorSlot:
    """One named committed-tensor family an op kind contributes to.

    ``axis`` names the stacked commitment the tensors land in: "aux"
    (per-(step, aux-node) slots under key kd), "weight" (per-(step,
    weight-node) slots under kw) or "label" (per-step, under ky).
    ``bits`` marks the B_{Q-1} bit matrix, committed under the zkReLU
    G-column basis via `pedersen.commit_bits` instead of an MSM.
    ``pad_shape(op, graph)`` gives the padded (rows, cols) of one node's
    tensor inside its slot; None means the node's own padded shape.

    The ordered union of these specs over a graph's nodes
    (`LayerGraph.commit_slots`) IS the commitment schema: witness
    stacking, the commit phase, blind drawing, transcript absorption and
    proof serialization all iterate it, so a new op kind only declares
    its slots here and every downstream layer picks them up.
    """
    name: str
    axis: str                  # "aux" | "weight" | "label"
    bits: bool = False
    pad_shape: Optional[Callable[["LayerOp", "LayerGraph"],
                                 Tuple[int, int]]] = None


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Registry entry: everything the pipeline needs to know per op kind."""
    kind: str
    owns_aux_slot: bool        # node gets a slot in the stacked aux tensors
    owns_weight_slot: bool     # node gets a slot in the stacked W / G_W
    validate: Callable[["LayerOp", "LayerGraph"], None]
    extract: Callable[["LayerOp", object], Dict[str, np.ndarray]]
    relations: Callable[["LayerOp", "LayerGraph"], List[MatmulInstance]]
    slots: Tuple[TensorSlot, ...] = ()


OP_REGISTRY: Dict[str, OpSpec] = {}


def register_op(spec: OpSpec) -> OpSpec:
    if spec.kind in OP_REGISTRY:
        raise ValueError(f"op kind {spec.kind!r} already registered")
    OP_REGISTRY[spec.kind] = spec
    return spec


def _no_relations(op, graph):
    return []


def _no_tensors(op, wit):
    return {}


# ---------------------------------------------------------------------------
# Op kinds
# ---------------------------------------------------------------------------

def _validate_input(op: LayerOp, graph: "LayerGraph") -> None:
    if op.inputs:
        raise ValueError(f"{op.name}: input node takes no inputs")


def _validate_qmatmul(op: LayerOp, graph: "LayerGraph") -> None:
    (src,) = op.inputs
    a = graph.node(src)
    if a.shape[0] != op.shape[0]:
        raise ValueError(f"{op.name}: batch {op.shape[0]} != producer "
                         f"{src} batch {a.shape[0]}")
    # implied weight shape: (in=a.cols, out=op.cols); both must be >= 1
    if a.shape[1] < 1 or op.shape[1] < 1:
        raise ValueError(f"{op.name}: degenerate weight shape")


def _validate_same_shape(op: LayerOp, graph: "LayerGraph") -> None:
    for src in op.inputs:
        if graph.node(src).shape != op.shape:
            raise ValueError(f"{op.name}: shape {op.shape} != producer "
                             f"{src} shape {graph.node(src).shape}")


def _extract_qmatmul(op: LayerOp, wit) -> Dict[str, np.ndarray]:
    l = op.layer
    return {"w": wit.w[l - 1], "gw": wit.gw[l - 1]}


def _extract_zkrelu(op: LayerOp, wit) -> Dict[str, np.ndarray]:
    l, L = op.layer, len(wit.w)
    zero = np.zeros_like(wit.zpp[l - 1])
    return {
        "zpp": wit.zpp[l - 1], "bq": wit.b[l - 1], "rz": wit.rz[l - 1],
        # the output layer has no downstream G_A (its gradient comes from
        # the loss, eq. 32), so its grad-aux slots stay exactly zero
        "gap": wit.gap[l - 1] if l < L else zero,
        "rga": wit.rga[l - 1] if l < L else zero,
    }


def _extract_output_grad(op: LayerOp, wit) -> Dict[str, np.ndarray]:
    return {"y": wit.y}


def _extract_residual(op: LayerOp, wit) -> Dict[str, np.ndarray]:
    # A residual sum commits nothing of its own: its value is implied by
    # its producers' committed decompositions, and every claim on it
    # splits linearly onto their slots (see producer_aux_slots).
    return {}


def _validate_residual(op: LayerOp, graph: "LayerGraph") -> None:
    if len(op.inputs) != 2:
        raise ValueError(f"{op.name}: residual_add takes exactly 2 inputs")
    _validate_same_shape(op, graph)
    for src in op.inputs:
        kind = graph.node(src).kind
        if kind not in ("zkrelu", "residual_add"):
            raise ValueError(
                f"{op.name}: residual producer {src!r} is a {kind!r} node; "
                "claims on a residual sum must discharge onto committed "
                "activation slots, so both producers must be zkrelu (or "
                "nested residual_add) nodes")


def _relations_qmatmul(op: LayerOp, graph: "LayerGraph") -> List[MatmulInstance]:
    """The three Fig. 3 relation instances a quantized matmul owns.

    fwd (eq. 30): Z^l = A^{l-1} W^l, claim on layer l's aux slot.
    gw  (eq. 34): G_W^l = G_Z^{l,T} A^{l-1}, claim on weight slot l.
    bwd (eq. 33): G_A^{l-1} = G_Z^l W^{l,T} — attached to layer l because
    it contracts over layer l's OUT width and reads W^l; the claim lands
    on the producer slot(s) of layer l's OPERAND: the upstream zkrelu
    node for a chain, BOTH producer slots when the operand is a residual
    sum (the gradient of A1 + A2 flows to both branches, and each
    branch's committed gap/rga decomposes its accumulated total, so the
    instance enters its bucket with the SUM of both slot coefficients).
    Layer 1 has no upstream activation, so it emits no bwd instance (and
    its A-operand is the input node, whose claims discharge through the
    per-sample data commitments instead of the anchor).
    """
    (src,) = op.inputs
    a = graph.node(src)
    act = graph.node_for_layer("zkrelu", op.layer)
    out: List[MatmulInstance] = []
    out.append(MatmulInstance(
        family="fwd", layer=op.layer, claim_rows=op.rows_pad,
        claim_cols=op.cols_pad, inner=a.cols_pad,
        claim_slots=(graph.aux_slot(act.name),), a_node=src))
    if op.layer > 1:
        out.append(MatmulInstance(
            family="bwd", layer=op.layer - 1, claim_rows=a.rows_pad,
            claim_cols=a.cols_pad, inner=op.cols_pad,
            claim_slots=graph.producer_aux_slots(src), a_node=""))
    out.append(MatmulInstance(
        family="gw", layer=op.layer, claim_rows=op.cols_pad,
        claim_cols=a.cols_pad, inner=op.rows_pad,
        claim_slots=(graph.weight_slot(op.name),), a_node=src))
    return out


def _w_shape(op: LayerOp, graph: "LayerGraph") -> Tuple[int, int]:
    return graph.weight_shape(op)


def _gw_shape(op: LayerOp, graph: "LayerGraph") -> Tuple[int, int]:
    rp, cp = graph.weight_shape(op)
    return cp, rp           # G_W^l = G_Z^{l,T} A^{l-1} is (out, in)


register_op(OpSpec("input", False, False, _validate_input,
                   _no_tensors, _no_relations))
register_op(OpSpec("qmatmul", False, True, _validate_qmatmul,
                   _extract_qmatmul, _relations_qmatmul,
                   slots=(TensorSlot("w", "weight", pad_shape=_w_shape),
                          TensorSlot("gw", "weight", pad_shape=_gw_shape))))
register_op(OpSpec("zkrelu", True, False, _validate_same_shape,
                   _extract_zkrelu, _no_relations,
                   slots=(TensorSlot("zpp", "aux"),
                          TensorSlot("bq", "aux", bits=True),
                          TensorSlot("rz", "aux"),
                          TensorSlot("gap", "aux"),
                          TensorSlot("rga", "aux"))))
register_op(OpSpec("residual_add", False, False, _validate_residual,
                   _extract_residual, _no_relations))
register_op(OpSpec("output_grad", False, False, _validate_same_shape,
                   _extract_output_grad, _no_relations,
                   slots=(TensorSlot("y", "label"),)))


# ---------------------------------------------------------------------------
# The graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Bucket:
    """All relation instances of one family sharing a sumcheck table
    length; ONE batched sumcheck proves every (instance, step) pair."""
    family: str
    inner: int
    instances: Tuple[MatmulInstance, ...]

    @property
    def rounds(self) -> int:
        return log2_exact(self.inner)


@dataclasses.dataclass(frozen=True)
class LayerGraph:
    nodes: Tuple[LayerOp, ...]

    def __post_init__(self):
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError("duplicate node names")
        for op in self.nodes:
            if op.kind not in OP_REGISTRY:
                raise ValueError(f"{op.name}: unregistered op kind "
                                 f"{op.kind!r}; known: {sorted(OP_REGISTRY)}")
            for src in op.inputs:
                if src not in names[:names.index(op.name)]:
                    raise ValueError(f"{op.name}: input {src!r} is not an "
                                     "earlier node (graph must be in "
                                     "topological order)")
            OP_REGISTRY[op.kind].validate(op, self)

    # -- lookups ----------------------------------------------------------
    def node(self, name: str) -> LayerOp:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def node_for_layer(self, kind: str, layer: int) -> LayerOp:
        for n in self.nodes:
            if n.kind == kind and n.layer == layer:
                return n
        raise KeyError((kind, layer))

    # -- slot maps --------------------------------------------------------
    @functools.cached_property
    def aux_nodes(self) -> Tuple[LayerOp, ...]:
        """Nodes owning a stacked-aux slot, in slot order."""
        return tuple(n for n in self.nodes
                     if OP_REGISTRY[n.kind].owns_aux_slot)

    @functools.cached_property
    def weight_nodes(self) -> Tuple[LayerOp, ...]:
        return tuple(n for n in self.nodes
                     if OP_REGISTRY[n.kind].owns_weight_slot)

    def aux_slot(self, name: str) -> int:
        return [n.name for n in self.aux_nodes].index(name)

    def weight_slot(self, name: str) -> int:
        return [n.name for n in self.weight_nodes].index(name)

    def producer_aux_slots(self, name: str) -> Tuple[int, ...]:
        """The aux slots a claim on node `name`'s value decomposes onto.

        A zkrelu node is its own slot; a residual_add resolves through
        both producers (a claim on A1 + A2 at point p IS the sum of the
        claims on A1 and A2 at p, so it splits linearly onto every
        producer slot — the FAC4DNN claim routing for skip connections).
        """
        node = self.node(name)
        if OP_REGISTRY[node.kind].owns_aux_slot:
            return (self.aux_slot(name),)
        if node.kind == "residual_add":
            out: List[int] = []
            for src in node.inputs:
                out.extend(self.producer_aux_slots(src))
            return tuple(out)
        raise ValueError(f"{name}: {node.kind!r} node owns no aux slot and "
                         "is not a residual sum of slot owners")

    # -- commitment schema ------------------------------------------------
    @functools.cached_property
    def commit_slots(self) -> Tuple[TensorSlot, ...]:
        """The ordered named-tensor commitment schema of this graph: the
        union of every node's `OpSpec.slots`, label axis first, then
        weight, then aux (the canonical transcript absorption order),
        declaration order within an axis.  Witness stacking, the commit
        phase, blind drawing and proof serialization all iterate this —
        a new op kind's tensors flow through by declaring slots alone."""
        axis_rank = {"label": 0, "weight": 1, "aux": 2}
        seen, out = set(), []
        for op in self.nodes:
            for s in OP_REGISTRY[op.kind].slots:
                if s.name not in seen:
                    seen.add(s.name)
                    out.append(s)
        return tuple(sorted(out, key=lambda s: axis_rank[s.axis]))

    def slot_nodes(self, spec: TensorSlot) -> Tuple[LayerOp, ...]:
        """The nodes contributing tensors to one named commitment slot,
        in stacked-slot order."""
        if spec.axis == "aux":
            return self.aux_nodes
        if spec.axis == "weight":
            return self.weight_nodes
        return (self.output_node,)

    def slot_pad_shape(self, spec: TensorSlot, op: LayerOp) -> Tuple[int, int]:
        if spec.pad_shape is not None:
            return spec.pad_shape(op, self)
        return op.rows_pad, op.cols_pad

    # -- node activation values (prover-side operand resolution) ----------
    def node_value(self, name: str, wit) -> np.ndarray:
        """The int64 forward value of an activation-producing node in one
        `StepWitness`: input -> x, zkrelu layer l -> A^l, residual_add ->
        the elementwise sum of its producers (computed, never committed)."""
        node = self.node(name)
        if node.kind == "input":
            return wit.x
        if node.kind == "zkrelu":
            return wit.a[node.layer]
        if node.kind == "residual_add":
            vals = [self.node_value(src, wit) for src in node.inputs]
            return vals[0] + vals[1]
        raise ValueError(f"{name}: {node.kind!r} has no activation value")

    # -- padded geometry --------------------------------------------------
    @property
    def batch(self) -> int:
        return self.nodes[0].shape[0]

    @functools.cached_property
    def d_slot(self) -> int:
        """Element area of one aux slot (shared by all aux nodes)."""
        return max(n.rows_pad * n.cols_pad for n in self.aux_nodes)

    @functools.cached_property
    def w_slot(self) -> int:
        """Element area of one weight slot: max padded in*out."""
        return max(self.weight_shape(n)[0] * self.weight_shape(n)[1]
                   for n in self.weight_nodes)

    def weight_shape(self, op: LayerOp) -> Tuple[int, int]:
        """Padded (rows=in, cols=out) of a qmatmul node's weight."""
        (src,) = op.inputs
        return self.node(src).cols_pad, op.cols_pad

    @functools.cached_property
    def output_node(self) -> LayerOp:
        outs = [n for n in self.nodes if n.kind == "output_grad"]
        if len(outs) != 1:
            raise ValueError(f"graph needs exactly one output_grad node, "
                             f"got {len(outs)}")
        return outs[0]

    @property
    def y_elem(self) -> int:
        """Per-step padded label area: batch x padded output width."""
        o = self.output_node
        return o.rows_pad * o.cols_pad

    @functools.cached_property
    def input_node(self) -> LayerOp:
        ins = [n for n in self.nodes if n.kind == "input"]
        if len(ins) != 1:
            raise ValueError("graph needs exactly one input node")
        return ins[0]

    # -- relation instances and shape buckets -----------------------------
    @functools.cached_property
    def instances(self) -> Dict[str, Tuple[MatmulInstance, ...]]:
        """Per family, all relation instances in layer order."""
        per: Dict[str, List[MatmulInstance]] = {f: [] for f in FAMILIES}
        for op in self.nodes:
            for inst in OP_REGISTRY[op.kind].relations(op, self):
                per[inst.family].append(inst)
        for fam in per:
            per[fam].sort(key=lambda i: i.layer)
        return {f: tuple(v) for f, v in per.items()}

    @functools.cached_property
    def buckets(self) -> Dict[str, Tuple[Bucket, ...]]:
        """Instances grouped by sumcheck table length (first-seen order,
        so a uniform graph yields exactly one bucket per family)."""
        out: Dict[str, Tuple[Bucket, ...]] = {}
        for fam, insts in self.instances.items():
            grouped: Dict[int, List[MatmulInstance]] = {}
            for inst in insts:
                grouped.setdefault(inst.inner, []).append(inst)
            out[fam] = tuple(Bucket(fam, inner, tuple(g))
                             for inner, g in grouped.items())
        return out

    @functools.cached_property
    def locators(self) -> Dict[str, Dict[int, Tuple[int, int]]]:
        """Per family: layer -> (bucket index, position inside bucket).

        The pair index of (step t, layer) inside its bucket's sumcheck is
        ``t * len(bucket.instances) + position``."""
        out: Dict[str, Dict[int, Tuple[int, int]]] = {}
        for fam, buckets in self.buckets.items():
            m: Dict[int, Tuple[int, int]] = {}
            for bi, b in enumerate(buckets):
                for pos, inst in enumerate(b.instances):
                    m[inst.layer] = (bi, pos)
            out[fam] = m
        return out

    def locate(self, family: str, layer: int) -> Tuple[int, int]:
        return self.locators[family][layer]

    def instance(self, family: str, layer: int) -> MatmulInstance:
        bi, pos = self.locate(family, layer)
        return self.buckets[family][bi].instances[pos]


def extract_node_tensors(graph: LayerGraph, wit) -> Dict[str, Dict]:
    """One step's tensors keyed by graph node name, via the op
    registry's witness extractors -- the single graph-native view of a
    `StepWitness` (used by both witness stacking and
    `quantfc.step_graph_witness`)."""
    return {op.name: OP_REGISTRY[op.kind].extract(op, wit)
            for op in graph.nodes}


# ---------------------------------------------------------------------------
# Builders + the family registry (launch-time lookup)
# ---------------------------------------------------------------------------

def build_fcnn_graph(widths: Tuple[int, ...], batch: int) -> LayerGraph:
    """The (possibly pyramid) MLP graph of Example 4.5: widths is the
    full shape table d_0..d_L (input width, then one out-width per
    layer).  Uniform widths reproduce the seed pipeline exactly."""
    widths = tuple(int(w) for w in widths)
    if len(widths) < 3:
        raise ValueError("fcnn graph needs >= 2 layers (eq. 33): pass "
                         "widths d_0..d_L with L >= 2")
    L = len(widths) - 1
    nodes: List[LayerOp] = [LayerOp("x", "input", (), (batch, widths[0]))]
    prev = "x"
    for l in range(1, L + 1):
        nodes.append(LayerOp(f"mm{l}", "qmatmul", (prev,),
                             (batch, widths[l]), layer=l))
        nodes.append(LayerOp(f"act{l}", "zkrelu", (f"mm{l}",),
                             (batch, widths[l]), layer=l))
        prev = f"act{l}"
    nodes.append(LayerOp("loss", "output_grad", (prev,),
                         (batch, widths[L]), layer=L))
    return LayerGraph(tuple(nodes))


def build_residual_fcnn_graph(widths: Tuple[int, ...], batch: int,
                              skips: Dict[int, int]) -> LayerGraph:
    """A residual MLP: ``skips`` maps matmul layer l -> earlier
    activation layer j (1 <= j <= l - 2), meaning layer l's operand is
    A^{l-1} + A^j (both zkrelu outputs, so shapes must match:
    widths[l-1] == widths[j]).  Equivalent to `GraphBuilder` with a
    ``residual(to=...)`` before each skipped dense."""
    widths = tuple(int(w) for w in widths)
    L = len(widths) - 1
    b = GraphBuilder(batch).input(widths[0])
    for l in range(1, L + 1):
        if l in skips:
            b.residual(to=skips[l])
        b.dense(widths[l]).relu()
    return b.output()


class GraphBuilder:
    """Fluent frontend for proof graphs:

        graph = (GraphBuilder(batch=4)
                 .input(16).dense(16).relu()
                 .dense(16).relu()
                 .residual(to=1)          # tip := act2 + act1
                 .dense(8).relu()
                 .output())

    ``dense(h)`` appends a quantized matmul to width h consuming the
    current tip, ``relu()`` its zkReLU rescale/activation, and
    ``residual(to=...)`` replaces the tip with tip + (an earlier
    activation, by layer index or node name) so the NEXT dense consumes
    the sum.  ``output()`` closes the graph and returns the validated
    `LayerGraph`."""

    def __init__(self, batch: int):
        self.batch = int(batch)
        self._nodes: List[LayerOp] = []
        self._tip: Optional[str] = None
        self._layer = 0
        self._n_res = 0

    def _shape(self, name: str) -> Tuple[int, int]:
        for n in self._nodes:
            if n.name == name:
                return n.shape
        raise KeyError(name)

    def _expect(self, what: str, ok: bool) -> None:
        if not ok:
            raise ValueError(f"GraphBuilder: {what}")

    def input(self, d: int) -> "GraphBuilder":
        self._expect("input() must come first", not self._nodes)
        self._nodes.append(LayerOp("x", "input", (), (self.batch, int(d))))
        self._tip = "x"
        return self

    def dense(self, h: int) -> "GraphBuilder":
        self._expect("dense() needs an input/relu/residual tip",
                     self._tip is not None and not self._tip.startswith("mm"))
        self._layer += 1
        l = self._layer
        self._nodes.append(LayerOp(f"mm{l}", "qmatmul", (self._tip,),
                                   (self.batch, int(h)), layer=l))
        self._tip = f"mm{l}"
        return self

    def relu(self) -> "GraphBuilder":
        self._expect("relu() must follow dense()",
                     self._tip is not None and self._tip.startswith("mm"))
        l = self._layer
        self._nodes.append(LayerOp(f"act{l}", "zkrelu", (self._tip,),
                                   self._shape(self._tip), layer=l))
        self._tip = f"act{l}"
        return self

    def residual(self, to) -> "GraphBuilder":
        """tip := tip + act{to}; `to` is an activation layer index or a
        node name of an earlier zkrelu / residual node."""
        self._expect("residual() must follow relu()",
                     self._tip is not None and self._tip.startswith("act"))
        src = f"act{to}" if isinstance(to, int) else str(to)
        self._expect(f"residual target {src!r} must be an earlier node",
                     any(n.name == src for n in self._nodes))
        self._expect(
            f"residual shapes differ: {self._shape(self._tip)} vs "
            f"{self._shape(src)}", self._shape(self._tip) == self._shape(src))
        self._n_res += 1
        name = f"res{self._n_res}"
        self._nodes.append(LayerOp(name, "residual_add", (self._tip, src),
                                   self._shape(self._tip), layer=self._layer))
        self._tip = name
        return self

    def output(self) -> LayerGraph:
        self._expect("output() must follow relu()",
                     self._tip is not None and self._tip.startswith("act"))
        self._expect("graph needs >= 2 layers (eq. 33)", self._layer >= 2)
        self._nodes.append(LayerOp("loss", "output_grad", (self._tip,),
                                   self._shape(self._tip), layer=self._layer))
        return LayerGraph(tuple(self._nodes))

def graph_skips(graph: LayerGraph) -> Dict[int, int]:
    """Recover the matmul-layer -> skip-source-layer map of a (possibly
    residual) chain-backbone graph — the shape quantfc's witness
    generator consumes.

    Raises for NESTED residual sums: the IR validates them (and the
    claim routing handles them), but quantfc's chain emitter only
    computes single-level skips, so silently flattening one would
    produce witnesses inconsistent with the graph's claim routing."""
    out: Dict[int, int] = {}
    for n in graph.nodes:
        if n.kind == "qmatmul":
            src = graph.node(n.inputs[0])
            if src.kind == "residual_add":
                tip, skip = (graph.node(s) for s in src.inputs)
                if tip.kind != "zkrelu" or skip.kind != "zkrelu":
                    raise ValueError(
                        f"{src.name}: nested residual_add producers are "
                        "valid IR but quantfc's witness emitter supports "
                        "single-level skips only (both producers must be "
                        "zkrelu nodes)")
                out[n.layer] = skip.layer
    return out


def graph_widths(graph: LayerGraph) -> Tuple[int, ...]:
    """The chain shape table d_0..d_L of a graph (input width, then one
    out-width per qmatmul layer, in layer order)."""
    mms = sorted((n for n in graph.nodes if n.kind == "qmatmul"),
                 key=lambda n: n.layer)
    return (graph.input_node.shape[1],) + tuple(n.shape[1] for n in mms)


PROOF_GRAPH_BUILDERS: Dict[str, Callable[..., LayerGraph]] = {
    "fcnn": build_fcnn_graph,
}


def proof_graph_for_family(family: str, **kwargs) -> LayerGraph:
    """Launch-time lookup: model family -> proof graph builder."""
    try:
        builder = PROOF_GRAPH_BUILDERS[family]
    except KeyError:
        raise LookupError(
            f"no proof graph registered for family {family!r}; provable "
            f"families: {sorted(PROOF_GRAPH_BUILDERS)} (register a builder "
            "in repro.core.pipeline.graph.PROOF_GRAPH_BUILDERS)") from None
    return builder(**kwargs)
