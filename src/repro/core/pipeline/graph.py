"""FAC4DNN layer-graph IR: heterogeneous-layer proof aggregation.

The paper's point (Section 5) is that proofs aggregate over *different
layers and training steps without being constrained by their sequential
order*.  The seed pipeline realized that only for the uniform-width
quantized FCNN of Example 4.5; this module makes the network shape a
first-class object instead:

* `LayerOp` — one node of the proof graph (input, quantized matmul,
  zkReLU rescale/activation, residual add, output gradient) with an
  explicit unpadded shape and explicit edges to its producers.
* `OP_REGISTRY` — per-kind `OpSpec` supplying shape validation, the
  witness extractor (node -> named int64 tensors of one `StepWitness`),
  and the sumcheck relation handler (node -> `MatmulInstance`s).  The
  zkReLU / output-gradient relation checks live in `anchor.py` /
  `openings.py` but are *driven* by the slot and claim enumerations
  defined here.
* `LayerGraph` — the validated graph plus everything the prover and the
  standalone verifier both derive from it: aux/weight slot maps, padded
  slot sizes, matmul relation instances, and the **shape buckets**.

Shape buckets are the aggregation mechanism: every matmul relation
instance (one per (family, layer), replicated per aggregated training
step) is keyed by its sumcheck table length (the padded inner dimension)
and all instances in a bucket — across layers AND steps — share ONE
batched sumcheck, entering with public coefficient
``e(u_slot)[slot(t, node)] * padfac`` exactly like the seed's three
hardcoded fwd/bwd/gw sumchecks.  A uniform-width graph degenerates to
one bucket per family, reproducing the seed transcript bit-for-bit.

Slot layout (little-endian MLE variables, low to high):

    aux slot:    [cols of node tensor | rows (batch) | zero pad]  d_slot
    weight slot: [cols (in-width)     | rows (out)   | zero pad]  w_slot

so a claim on a node tensor at point ``p`` becomes a claim on the
stacked commitment at ``p ++ zeros ++ slot-selector``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pipeline.tables import log2_exact, next_pow2

FAMILIES = ("fwd", "bwd", "gw")


@dataclasses.dataclass(frozen=True)
class LayerOp:
    """One node of the proof graph.

    ``shape`` is the UNPADDED (rows, cols) of the node's output tensor;
    padded sizes are derived (each dim to the next power of two).
    ``layer`` is the 1-based layer index used for witness extraction and
    transcript tags (0 for the input node).
    """
    name: str
    kind: str                      # key into OP_REGISTRY
    inputs: Tuple[str, ...]
    shape: Tuple[int, int]
    layer: int = 0

    @property
    def rows_pad(self) -> int:
        return next_pow2(self.shape[0])

    @property
    def cols_pad(self) -> int:
        return next_pow2(self.shape[1])

    @property
    def elem_pad(self) -> int:
        return self.rows_pad * self.cols_pad


@dataclasses.dataclass(frozen=True)
class MatmulInstance:
    """One matmul relation of one layer (replicated per training step).

    The claim tensor is the product result: Z^l for fwd (eq. 30),
    G_A^l for bwd (eq. 33), G_W^l for gw (eq. 34).  ``claim_slot`` is
    the stacked-axis slot the claim reduces to (aux slot for fwd/bwd,
    weight slot for gw); ``inner`` is the padded inner dimension — the
    sumcheck table length and therefore the bucket key.
    """
    family: str
    layer: int
    claim_rows: int        # padded rows of the claim tensor
    claim_cols: int        # padded cols of the claim tensor
    inner: int             # padded contraction length (bucket key)
    claim_slot: int        # slot index on the aux (fwd/bwd) or weight (gw) axis
    a_node: str            # activation operand node name ("" for bwd)


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Registry entry: everything the pipeline needs to know per op kind."""
    kind: str
    owns_aux_slot: bool        # node gets a slot in the stacked aux tensors
    owns_weight_slot: bool     # node gets a slot in the stacked W / G_W
    validate: Callable[["LayerOp", "LayerGraph"], None]
    extract: Callable[["LayerOp", object], Dict[str, np.ndarray]]
    relations: Callable[["LayerOp", "LayerGraph"], List[MatmulInstance]]


OP_REGISTRY: Dict[str, OpSpec] = {}


def register_op(spec: OpSpec) -> OpSpec:
    if spec.kind in OP_REGISTRY:
        raise ValueError(f"op kind {spec.kind!r} already registered")
    OP_REGISTRY[spec.kind] = spec
    return spec


def _no_relations(op, graph):
    return []


def _no_tensors(op, wit):
    return {}


# ---------------------------------------------------------------------------
# Op kinds
# ---------------------------------------------------------------------------

def _validate_input(op: LayerOp, graph: "LayerGraph") -> None:
    if op.inputs:
        raise ValueError(f"{op.name}: input node takes no inputs")


def _validate_qmatmul(op: LayerOp, graph: "LayerGraph") -> None:
    (src,) = op.inputs
    a = graph.node(src)
    if a.shape[0] != op.shape[0]:
        raise ValueError(f"{op.name}: batch {op.shape[0]} != producer "
                         f"{src} batch {a.shape[0]}")
    # implied weight shape: (in=a.cols, out=op.cols); both must be >= 1
    if a.shape[1] < 1 or op.shape[1] < 1:
        raise ValueError(f"{op.name}: degenerate weight shape")


def _validate_same_shape(op: LayerOp, graph: "LayerGraph") -> None:
    for src in op.inputs:
        if graph.node(src).shape != op.shape:
            raise ValueError(f"{op.name}: shape {op.shape} != producer "
                             f"{src} shape {graph.node(src).shape}")


def _extract_qmatmul(op: LayerOp, wit) -> Dict[str, np.ndarray]:
    l = op.layer
    return {"w": wit.w[l - 1], "gw": wit.gw[l - 1]}


def _extract_zkrelu(op: LayerOp, wit) -> Dict[str, np.ndarray]:
    l, L = op.layer, len(wit.w)
    zero = np.zeros_like(wit.zpp[l - 1])
    return {
        "zpp": wit.zpp[l - 1], "bq": wit.b[l - 1], "rz": wit.rz[l - 1],
        # the output layer has no downstream G_A (its gradient comes from
        # the loss, eq. 32), so its grad-aux slots stay exactly zero
        "gap": wit.gap[l - 1] if l < L else zero,
        "rga": wit.rga[l - 1] if l < L else zero,
    }


def _extract_output_grad(op: LayerOp, wit) -> Dict[str, np.ndarray]:
    return {"y": wit.y}


def _extract_residual(op: LayerOp, wit) -> Dict[str, np.ndarray]:
    raise NotImplementedError(
        "residual_add is a first-class IR node (shape-checked, claim-"
        "routable through the anchor: a claim on A1+A2 splits linearly "
        "onto both producer slots) but quantfc witness generation does "
        "not emit residual trajectories yet — see ROADMAP.md")


def _relations_qmatmul(op: LayerOp, graph: "LayerGraph") -> List[MatmulInstance]:
    """The three Fig. 3 relation instances a quantized matmul owns.

    fwd (eq. 30): Z^l = A^{l-1} W^l, claim on layer l's aux slot.
    gw  (eq. 34): G_W^l = G_Z^{l,T} A^{l-1}, claim on weight slot l.
    bwd (eq. 33): G_A^{l-1} = G_Z^l W^{l,T} — attached to layer l because
    it contracts over layer l's OUT width and reads W^l; the claim lands
    on layer l-1's aux slot.  Layer 1 has no upstream activation, so it
    emits no bwd instance (and its A-operand is the input node, whose
    claims discharge through the per-sample data commitments instead of
    the anchor).
    """
    (src,) = op.inputs
    a = graph.node(src)
    act = graph.node_for_layer("zkrelu", op.layer)
    out: List[MatmulInstance] = []
    out.append(MatmulInstance(
        family="fwd", layer=op.layer, claim_rows=op.rows_pad,
        claim_cols=op.cols_pad, inner=a.cols_pad,
        claim_slot=graph.aux_slot(act.name), a_node=src))
    if op.layer > 1:
        prev_act = graph.node_for_layer("zkrelu", op.layer - 1)
        out.append(MatmulInstance(
            family="bwd", layer=op.layer - 1, claim_rows=prev_act.rows_pad,
            claim_cols=prev_act.cols_pad, inner=op.cols_pad,
            claim_slot=graph.aux_slot(prev_act.name), a_node=""))
    out.append(MatmulInstance(
        family="gw", layer=op.layer, claim_rows=op.cols_pad,
        claim_cols=a.cols_pad, inner=op.rows_pad,
        claim_slot=graph.weight_slot(op.name), a_node=src))
    return out


register_op(OpSpec("input", False, False, _validate_input,
                   _no_tensors, _no_relations))
register_op(OpSpec("qmatmul", False, True, _validate_qmatmul,
                   _extract_qmatmul, _relations_qmatmul))
register_op(OpSpec("zkrelu", True, False, _validate_same_shape,
                   _extract_zkrelu, _no_relations))
register_op(OpSpec("residual_add", False, False, _validate_same_shape,
                   _extract_residual, _no_relations))
register_op(OpSpec("output_grad", False, False, _validate_same_shape,
                   _extract_output_grad, _no_relations))


# ---------------------------------------------------------------------------
# The graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Bucket:
    """All relation instances of one family sharing a sumcheck table
    length; ONE batched sumcheck proves every (instance, step) pair."""
    family: str
    inner: int
    instances: Tuple[MatmulInstance, ...]

    @property
    def rounds(self) -> int:
        return log2_exact(self.inner)


@dataclasses.dataclass(frozen=True)
class LayerGraph:
    nodes: Tuple[LayerOp, ...]

    def __post_init__(self):
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError("duplicate node names")
        for op in self.nodes:
            if op.kind not in OP_REGISTRY:
                raise ValueError(f"{op.name}: unregistered op kind "
                                 f"{op.kind!r}; known: {sorted(OP_REGISTRY)}")
            for src in op.inputs:
                if src not in names[:names.index(op.name)]:
                    raise ValueError(f"{op.name}: input {src!r} is not an "
                                     "earlier node (graph must be in "
                                     "topological order)")
            OP_REGISTRY[op.kind].validate(op, self)

    # -- lookups ----------------------------------------------------------
    def node(self, name: str) -> LayerOp:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def node_for_layer(self, kind: str, layer: int) -> LayerOp:
        for n in self.nodes:
            if n.kind == kind and n.layer == layer:
                return n
        raise KeyError((kind, layer))

    # -- slot maps --------------------------------------------------------
    @functools.cached_property
    def aux_nodes(self) -> Tuple[LayerOp, ...]:
        """Nodes owning a stacked-aux slot, in slot order."""
        return tuple(n for n in self.nodes
                     if OP_REGISTRY[n.kind].owns_aux_slot)

    @functools.cached_property
    def weight_nodes(self) -> Tuple[LayerOp, ...]:
        return tuple(n for n in self.nodes
                     if OP_REGISTRY[n.kind].owns_weight_slot)

    def aux_slot(self, name: str) -> int:
        return [n.name for n in self.aux_nodes].index(name)

    def weight_slot(self, name: str) -> int:
        return [n.name for n in self.weight_nodes].index(name)

    # -- padded geometry --------------------------------------------------
    @property
    def batch(self) -> int:
        return self.nodes[0].shape[0]

    @functools.cached_property
    def d_slot(self) -> int:
        """Element area of one aux slot (shared by all aux nodes)."""
        return max(n.rows_pad * n.cols_pad for n in self.aux_nodes)

    @functools.cached_property
    def w_slot(self) -> int:
        """Element area of one weight slot: max padded in*out."""
        return max(self.weight_shape(n)[0] * self.weight_shape(n)[1]
                   for n in self.weight_nodes)

    def weight_shape(self, op: LayerOp) -> Tuple[int, int]:
        """Padded (rows=in, cols=out) of a qmatmul node's weight."""
        (src,) = op.inputs
        return self.node(src).cols_pad, op.cols_pad

    @functools.cached_property
    def output_node(self) -> LayerOp:
        outs = [n for n in self.nodes if n.kind == "output_grad"]
        if len(outs) != 1:
            raise ValueError(f"graph needs exactly one output_grad node, "
                             f"got {len(outs)}")
        return outs[0]

    @property
    def y_elem(self) -> int:
        """Per-step padded label area: batch x padded output width."""
        o = self.output_node
        return o.rows_pad * o.cols_pad

    @functools.cached_property
    def input_node(self) -> LayerOp:
        ins = [n for n in self.nodes if n.kind == "input"]
        if len(ins) != 1:
            raise ValueError("graph needs exactly one input node")
        return ins[0]

    # -- relation instances and shape buckets -----------------------------
    @functools.cached_property
    def instances(self) -> Dict[str, Tuple[MatmulInstance, ...]]:
        """Per family, all relation instances in layer order."""
        per: Dict[str, List[MatmulInstance]] = {f: [] for f in FAMILIES}
        for op in self.nodes:
            for inst in OP_REGISTRY[op.kind].relations(op, self):
                per[inst.family].append(inst)
        for fam in per:
            per[fam].sort(key=lambda i: i.layer)
        return {f: tuple(v) for f, v in per.items()}

    @functools.cached_property
    def buckets(self) -> Dict[str, Tuple[Bucket, ...]]:
        """Instances grouped by sumcheck table length (first-seen order,
        so a uniform graph yields exactly one bucket per family)."""
        out: Dict[str, Tuple[Bucket, ...]] = {}
        for fam, insts in self.instances.items():
            grouped: Dict[int, List[MatmulInstance]] = {}
            for inst in insts:
                grouped.setdefault(inst.inner, []).append(inst)
            out[fam] = tuple(Bucket(fam, inner, tuple(g))
                             for inner, g in grouped.items())
        return out

    @functools.cached_property
    def locators(self) -> Dict[str, Dict[int, Tuple[int, int]]]:
        """Per family: layer -> (bucket index, position inside bucket).

        The pair index of (step t, layer) inside its bucket's sumcheck is
        ``t * len(bucket.instances) + position``."""
        out: Dict[str, Dict[int, Tuple[int, int]]] = {}
        for fam, buckets in self.buckets.items():
            m: Dict[int, Tuple[int, int]] = {}
            for bi, b in enumerate(buckets):
                for pos, inst in enumerate(b.instances):
                    m[inst.layer] = (bi, pos)
            out[fam] = m
        return out

    def locate(self, family: str, layer: int) -> Tuple[int, int]:
        return self.locators[family][layer]

    def instance(self, family: str, layer: int) -> MatmulInstance:
        bi, pos = self.locate(family, layer)
        return self.buckets[family][bi].instances[pos]


def extract_node_tensors(graph: LayerGraph, wit) -> Dict[str, Dict]:
    """One step's tensors keyed by graph node name, via the op
    registry's witness extractors -- the single graph-native view of a
    `StepWitness` (used by both witness stacking and
    `quantfc.step_graph_witness`)."""
    return {op.name: OP_REGISTRY[op.kind].extract(op, wit)
            for op in graph.nodes}


# ---------------------------------------------------------------------------
# Builders + the family registry (launch-time lookup)
# ---------------------------------------------------------------------------

def build_fcnn_graph(widths: Tuple[int, ...], batch: int) -> LayerGraph:
    """The (possibly pyramid) MLP graph of Example 4.5: widths is the
    full shape table d_0..d_L (input width, then one out-width per
    layer).  Uniform widths reproduce the seed pipeline exactly."""
    widths = tuple(int(w) for w in widths)
    if len(widths) < 3:
        raise ValueError("fcnn graph needs >= 2 layers (eq. 33): pass "
                         "widths d_0..d_L with L >= 2")
    L = len(widths) - 1
    nodes: List[LayerOp] = [LayerOp("x", "input", (), (batch, widths[0]))]
    prev = "x"
    for l in range(1, L + 1):
        nodes.append(LayerOp(f"mm{l}", "qmatmul", (prev,),
                             (batch, widths[l]), layer=l))
        nodes.append(LayerOp(f"act{l}", "zkrelu", (f"mm{l}",),
                             (batch, widths[l]), layer=l))
        prev = f"act{l}"
    nodes.append(LayerOp("loss", "output_grad", (prev,),
                         (batch, widths[L]), layer=L))
    return LayerGraph(tuple(nodes))


PROOF_GRAPH_BUILDERS: Dict[str, Callable[..., LayerGraph]] = {
    "fcnn": build_fcnn_graph,
}


def proof_graph_for_family(family: str, **kwargs) -> LayerGraph:
    """Launch-time lookup: model family -> proof graph builder."""
    try:
        builder = PROOF_GRAPH_BUILDERS[family]
    except KeyError:
        raise LookupError(
            f"no proof graph registered for family {family!r}; provable "
            f"families: {sorted(PROOF_GRAPH_BUILDERS)} (register a builder "
            "in repro.core.pipeline.graph.PROOF_GRAPH_BUILDERS)") from None
    return builder(**kwargs)
